//! Span-tracing invariants (PR8): nesting, parent containment, serve
//! reconciliation against the PR7 stage traces, and byte-deterministic
//! export at any thread count.

use std::sync::Arc;
use std::time::Duration;

use vsa::config::json::Json;
use vsa::coordinator::{Coordinator, CoordinatorConfig, GoldenEngine, ModelRegistry};
use vsa::snn::params::{DeployedModel, Kind, Layer};
use vsa::telemetry::spans::pids;
use vsa::telemetry::{SpanCollector, Stage, TRACE_SCHEMA};

fn model() -> DeployedModel {
    DeployedModel {
        name: "s".into(),
        num_steps: 2,
        in_channels: 1,
        in_size: 4,
        layers: vec![
            Layer::Conv {
                kind: Kind::EncConv,
                c_out: 2,
                c_in: 1,
                k: 1,
                w: vec![1, -1],
                bias: vec![0, 0],
                theta: vec![256 * 10, 256 * 10],
            },
            Layer::Readout { n_out: 10, n_in: 32, w: vec![1; 320] },
        ],
    }
}

/// Stack-API spans recorded concurrently from several threads keep
/// proper per-track nesting, with every child contained in its parent.
#[test]
fn concurrent_stack_spans_nest_per_thread() {
    let col = SpanCollector::new();
    std::thread::scope(|s| {
        for t in 0..4u64 {
            let col = &col;
            s.spawn(move || {
                let mut rec = col.recorder(t as u32, 0, t, 64);
                for _ in 0..5 {
                    rec.begin("outer");
                    rec.begin("inner");
                    std::hint::black_box(0u64);
                    rec.end();
                    rec.end();
                }
            });
        }
    });
    let sheet = col.sheet();
    sheet.check_nesting().expect("per-thread nesting holds");
    assert_eq!(sheet.records().len(), 4 * 5 * 2);
    for tid in 0..4u64 {
        let track: Vec<_> = sheet.records().iter().filter(|r| r.tid == tid).collect();
        assert_eq!(track.len(), 10, "each thread's spans land on its own track");
        let outers: Vec<_> = track.iter().filter(|r| r.name == "outer").collect();
        for inner in track.iter().filter(|r| r.name == "inner") {
            assert!(
                outers.iter().any(|o| o.ts_ns <= inner.ts_ns
                    && inner.ts_ns + inner.dur_ns <= o.ts_ns + o.dur_ns),
                "every inner span sits inside an outer span"
            );
        }
    }
}

/// The per-request span trees the coordinator emits reconcile with the
/// request's own `Trace` stage breakdown within 1 ms, and the export
/// is valid Chrome trace JSON carrying the nested spans.
#[test]
fn serve_span_trees_reconcile_with_stage_traces() {
    const TOL_NS: u64 = 1_000_000; // 1 ms
    let spans = SpanCollector::new();
    let (reg, m) = ModelRegistry::single(model());
    let regc = Arc::clone(&reg);
    let coord = Coordinator::start_with_spans(
        CoordinatorConfig {
            workers: 2,
            max_batch: 4,
            max_wait: Duration::from_millis(2),
            ..CoordinatorConfig::default()
        },
        reg,
        Some(Arc::clone(&spans)),
        move |_| Box::new(GoldenEngine::new(Arc::clone(&regc), 4)),
    );
    let rxs: Vec<_> =
        (0..24).map(|i| coord.submit(m, vec![(i * 11) as u8; 16]).unwrap()).collect();
    let results: Vec<_> = rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
    coord.shutdown();

    let sheet = spans.sheet();
    sheet.check_nesting().expect("request trees nest");
    for res in &results {
        let track: Vec<_> = sheet
            .records()
            .iter()
            .filter(|r| r.pid == pids::SERVE_REQUESTS && r.tid == res.id)
            .collect();
        let request = track.iter().find(|r| r.name == "request").expect("request span");
        let lat_ns = res.latency.as_nanos() as u64;
        assert!(
            request.dur_ns.abs_diff(lat_ns) <= TOL_NS,
            "request {} span {} ns vs latency {lat_ns} ns",
            res.id,
            request.dur_ns
        );
        for stage in Stage::ALL {
            let span_ns: u64 =
                track.iter().filter(|r| r.name == stage.name()).map(|r| r.dur_ns).sum();
            let trace_ns = res.trace.stage(stage).as_nanos() as u64;
            assert!(
                span_ns.abs_diff(trace_ns) <= TOL_NS,
                "request {} stage {}: spans {span_ns} ns vs trace {trace_ns} ns",
                res.id,
                stage.name()
            );
            for r in track.iter().filter(|r| r.name == stage.name()) {
                assert!(r.ts_ns >= request.ts_ns, "child starts inside the request span");
                assert!(
                    r.ts_ns + r.dur_ns <= request.ts_ns + request.dur_ns,
                    "child ends inside the request span"
                );
            }
        }
    }

    let text = sheet.to_chrome_json();
    let doc = Json::parse(&text).expect("export parses as JSON");
    let schema = doc.get("otherData").and_then(|o| o.get("schema")).and_then(Json::as_str);
    assert_eq!(schema, Some(TRACE_SCHEMA));
    let events = doc.get("traceEvents").and_then(Json::as_arr).expect("traceEvents");
    let complete = events
        .iter()
        .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
        .count();
    assert!(complete >= 24 * 4, "nested coordinator spans exported, got {complete}");
}

/// The exported bytes depend only on what was recorded and its lane
/// assignment — never on how many threads recorded it or the order
/// their recorders flushed.
#[test]
fn export_bytes_identical_at_1_2_4_threads() {
    fn export_with_threads(n: usize) -> String {
        let col = SpanCollector::new();
        col.name_process(0, "det");
        std::thread::scope(|s| {
            for t in 0..n {
                let col = &col;
                s.spawn(move || {
                    // Fixed job → lane mapping; only the job → thread
                    // mapping varies with n.
                    for job in (t..8).step_by(n) {
                        let mut rec = col.recorder(job as u32, 0, job as u64, 64);
                        for k in 0..3u64 {
                            let ts = 1_000 * job as u64 + 100 * k;
                            let name = format!("job{job}-{k}");
                            rec.span_at(0, job as u64, &name, ts, 50, &[("k", k as f64)], None);
                        }
                    }
                });
            }
        });
        col.sheet().to_chrome_json()
    }
    let one = export_with_threads(1);
    let two = export_with_threads(2);
    let four = export_with_threads(4);
    assert_eq!(one, two, "1 vs 2 threads");
    assert_eq!(two, four, "2 vs 4 threads");
    assert!(one.contains("job7-2"), "all jobs exported");
}

/// Ring overflow keeps the latest records and reports an exact drop
/// count all the way into the export.
#[test]
fn overflow_is_counted_in_the_export() {
    let col = SpanCollector::new();
    let mut rec = col.recorder(0, 0, 0, 4);
    for k in 0..10u64 {
        rec.span_at(0, 0, "s", 100 * k, 10, &[], None);
    }
    drop(rec);
    let sheet = col.sheet();
    assert_eq!(sheet.records().len(), 4, "ring keeps the latest cap records");
    assert_eq!(sheet.dropped, 6);
    assert_eq!(sheet.records()[0].ts_ns, 600, "oldest survivor is record #6");
    let doc = Json::parse(&sheet.to_chrome_json()).unwrap();
    let dropped = doc.get("otherData").and_then(|o| o.get("dropped")).and_then(Json::as_i64);
    assert_eq!(dropped, Some(6));
}

//! Cross-language integration: the rust golden model and the chip
//! simulator must reproduce the JAX model's logits exactly.
//!
//! `python -m compile.aot` writes, per model, a `*_selfcheck.json` with
//! the logits the deployed JAX graph produced on deterministic synthetic
//! samples.  This test regenerates the identical samples (bit-identical
//! splitmix64 generator) and checks every layer of the rust stack against
//! them.  Requires `make artifacts` to have run.

use vsa::arch::{Chip, SimMode};
use vsa::config::json::Json;
use vsa::config::HwConfig;
use vsa::data::synth;
use vsa::snn::Network;

struct SelfCheck {
    data_seed: u64,
    start: u64,
    count: usize,
    logits: Vec<Vec<i64>>,
}

fn load_selfcheck(path: &str) -> Option<SelfCheck> {
    let text = std::fs::read_to_string(path).ok()?;
    let v = Json::parse(&text).ok()?;
    let logits = v
        .get("logits")?
        .as_arr()?
        .iter()
        .map(|row| {
            row.as_arr()
                .unwrap()
                .iter()
                .map(|x| x.as_i64().unwrap())
                .collect()
        })
        .collect();
    Some(SelfCheck {
        data_seed: v.get("data_seed")?.as_i64()? as u64,
        start: v.get("start")?.as_i64()? as u64,
        count: v.get("count")?.as_usize()?,
        logits,
    })
}

fn check_model(vsaw: &str, selfcheck: &str, model_name: &str, exact_too: bool) {
    let Some(check) = load_selfcheck(selfcheck) else {
        eprintln!("skipping {model_name}: run `make artifacts` first");
        return;
    };
    let net = Network::from_vsaw_file(vsaw).expect("vsaw loads");
    let samples = synth::for_model(model_name, check.data_seed, check.start, check.count);

    for (i, sample) in samples.iter().enumerate() {
        let got = net.infer_u8(&sample.image);
        assert_eq!(
            got, check.logits[i],
            "{model_name} golden logits diverge from JAX on sample {i}"
        );
    }

    // The chip simulator (fast mode) must agree too.
    let chip = Chip::new(HwConfig::default(), SimMode::Fast);
    for (i, sample) in samples.iter().enumerate() {
        let report = chip.run(&net.model, &sample.image);
        assert_eq!(
            report.logits, check.logits[i],
            "{model_name} fast-sim logits diverge from JAX on sample {i}"
        );
    }

    if exact_too {
        let chip = Chip::new(HwConfig::default(), SimMode::Exact);
        let report = chip.run(&net.model, &samples[0].image);
        assert_eq!(
            report.logits, check.logits[0],
            "{model_name} exact-sim logits diverge from JAX"
        );
    }
}

#[test]
fn tiny_matches_jax() {
    check_model(
        "artifacts/tiny_t4.vsaw",
        "artifacts/tiny_t4_selfcheck.json",
        "tiny",
        true,
    );
}

#[test]
fn mnist_matches_jax() {
    check_model(
        "artifacts/mnist_t8.vsaw",
        "artifacts/mnist_t8_selfcheck.json",
        "mnist",
        true,
    );
}

#[test]
fn cifar10_matches_jax() {
    check_model(
        "artifacts/cifar10_t8.vsaw",
        "artifacts/cifar10_t8_selfcheck.json",
        "cifar10",
        false, // exact mode on the full CIFAR net is too slow for CI
    );
}

//! Failure injection: the VSAW and JSON parsers must reject arbitrary
//! corruption with errors, never panic or accept garbage silently.

use vsa::config::json::Json;
use vsa::snn::params::DeployedModel;
use vsa::testing::{check, Gen};

/// A small well-formed VSAW buffer to corrupt.
fn valid_vsaw() -> Vec<u8> {
    let mut b = Vec::new();
    b.extend(b"VSAW");
    b.extend(1u32.to_le_bytes());
    b.extend(4u32.to_le_bytes());
    b.extend(b"fuzz");
    b.extend(2u32.to_le_bytes()); // T
    b.extend(1u32.to_le_bytes()); // in_ch
    b.extend(4u32.to_le_bytes()); // in_size
    b.extend(2u32.to_le_bytes()); // layers
    b.push(0); // enc conv 2x1x1
    b.extend(2u32.to_le_bytes());
    b.extend(1u32.to_le_bytes());
    b.extend(1u32.to_le_bytes());
    b.extend([1u8, 0xFF]); // +1, -1
    b.extend(0i32.to_le_bytes());
    b.extend(0i32.to_le_bytes());
    b.extend(256i32.to_le_bytes());
    b.extend(256i32.to_le_bytes());
    b.push(4); // readout 10 x 32
    b.extend(10u32.to_le_bytes());
    b.extend(32u32.to_le_bytes());
    b.extend(std::iter::repeat_n(1u8, 320));
    b
}

#[test]
fn vsaw_baseline_parses() {
    assert!(DeployedModel::parse(&valid_vsaw()).is_ok());
}

#[test]
fn vsaw_truncation_never_panics() {
    let buf = valid_vsaw();
    for len in 0..buf.len() {
        // every strict prefix must fail cleanly
        assert!(
            DeployedModel::parse(&buf[..len]).is_err(),
            "prefix of {len} bytes accepted"
        );
    }
}

#[test]
fn vsaw_random_byte_flips_never_panic() {
    check("vsaw byte flips", 300, |g: &mut Gen| {
        let mut buf = valid_vsaw();
        let flips = g.usize_in(1, 8);
        for _ in 0..flips {
            let i = g.usize_in(0, buf.len() - 1);
            buf[i] ^= g.u64() as u8 | 1;
        }
        let _ = DeployedModel::parse(&buf); // Ok or Err both fine; no panic
    });
}

#[test]
fn vsaw_random_garbage_rejected() {
    check("vsaw garbage", 200, |g: &mut Gen| {
        let n = g.usize_in(0, 300);
        let buf: Vec<u8> = (0..n).map(|_| g.u64() as u8).collect();
        if buf.get(..4) != Some(b"VSAW") {
            assert!(DeployedModel::parse(&buf).is_err());
        }
    });
}

#[test]
fn json_random_garbage_never_panics() {
    check("json garbage", 500, |g: &mut Gen| {
        let n = g.usize_in(0, 120);
        let s: String = (0..n)
            .map(|_| {
                let c = *g.choose(&[
                    b'{', b'}', b'[', b']', b'"', b':', b',', b'1', b'e', b'-', b'.',
                    b't', b'n', b' ', b'\\', b'x',
                ]);
                c as char
            })
            .collect();
        let _ = Json::parse(&s); // must not panic
    });
}

#[test]
fn json_deep_nesting_ok() {
    // 1000-deep arrays parse (recursive descent headroom check).
    let depth = 1000;
    let s = "[".repeat(depth) + &"]".repeat(depth);
    assert!(Json::parse(&s).is_ok());
}

#[test]
fn json_mutated_manifest_never_panics() {
    let base = r#"[{"name":"m","hlo":"a.hlo.txt","weights":"m.vsaw","batch":1,
                   "num_steps":8,"in_channels":1,"in_size":28,"num_classes":10}]"#;
    check("manifest mutations", 300, |g: &mut Gen| {
        let mut bytes = base.as_bytes().to_vec();
        let i = g.usize_in(0, bytes.len() - 1);
        bytes[i] = g.u64() as u8;
        if let Ok(s) = String::from_utf8(bytes) {
            let _ = Json::parse(&s);
        }
    });
}

//! PR7 stage-trace suite: every served request carries a [`Trace`]
//! whose stages sum exactly to the end-to-end latency (deliver is the
//! residual by construction), and the coordinator's sketch-derived
//! percentiles agree with an exact client-side oracle within the
//! documented `REL_ERROR` bound — the acceptance criterion for
//! replacing the per-request latency vector.

use std::sync::Arc;
use std::time::Duration;

use vsa::config::models;
use vsa::coordinator::{
    Coordinator, CoordinatorConfig, InferenceEngine, ModelId, ModelRegistry, ServeError,
};
use vsa::snn::params::DeployedModel;
use vsa::telemetry::{Registry, Stage, REL_ERROR};
use vsa::util::stats::quantile;

/// One-model registry: the scripted engines here ignore the model, the
/// coordinator just needs a valid [`ModelId`] per request.
fn single() -> (Arc<ModelRegistry>, ModelId) {
    ModelRegistry::single(DeployedModel::synthesize(&models::tiny(2), 42))
}

/// Engine with a known minimum service time: sleeps `delay` per batch,
/// then returns deterministic logits.
struct SleepEngine {
    batch: usize,
    delay: Duration,
}

impl InferenceEngine for SleepEngine {
    fn batch_size(&self) -> usize {
        self.batch
    }
    fn infer(&mut self, _model: ModelId, images: &[Vec<u8>]) -> anyhow::Result<Vec<Vec<i64>>> {
        std::thread::sleep(self.delay);
        Ok(images.iter().map(|img| vec![img.len() as i64, 0, 1]).collect())
    }
    fn name(&self) -> &'static str {
        "sleep"
    }
}

/// Engine that fails its first `fail_first` calls, then succeeds —
/// drives the retry/backoff path deterministically.
struct FlakyEngine {
    inner: SleepEngine,
    fail_first: u32,
    calls: u32,
}

impl InferenceEngine for FlakyEngine {
    fn batch_size(&self) -> usize {
        self.inner.batch_size()
    }
    fn infer(&mut self, model: ModelId, images: &[Vec<u8>]) -> anyhow::Result<Vec<Vec<i64>>> {
        self.calls += 1;
        if self.calls <= self.fail_first {
            anyhow::bail!("injected transient failure #{}", self.calls);
        }
        self.inner.infer(model, images)
    }
    fn name(&self) -> &'static str {
        "flaky"
    }
}

const IMG: usize = 32;

#[test]
fn trace_stages_sum_to_latency_and_percentiles_match_exact() {
    const REQUESTS: usize = 64;
    let delay = Duration::from_millis(2);
    let (reg, m) = single();
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 2,
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            queue_depth: REQUESTS,
            ..CoordinatorConfig::default()
        },
        reg,
        move |_| Box::new(SleepEngine { batch: 4, delay }) as Box<dyn InferenceEngine>,
    );

    let rxs: Vec<_> = (0..REQUESTS)
        .map(|i| coord.submit(m, vec![i as u8; IMG]).expect("accepted"))
        .collect();
    let mut exact_ms: Vec<f64> = Vec::with_capacity(REQUESTS);
    for rx in rxs {
        let res = rx.recv().expect("worker alive").expect("no faults injected");
        // Deliver is the residual, so the stage times sum *exactly* to
        // the end-to-end latency — no drift, no double counting.
        assert_eq!(res.trace.total(), res.latency, "stages must sum to latency");
        assert!(
            res.trace.engine >= delay,
            "engine stage {:?} must cover the batch attempt ({delay:?})",
            res.trace.engine
        );
        assert_eq!(res.trace.backoff, Duration::ZERO, "clean run never backs off");
        exact_ms.push(res.latency.as_secs_f64() * 1e3);
    }

    // Registry export before shutdown: per-stage sketches carry every
    // completed request.
    let reg = Registry::new();
    coord.export_into(&reg, "serve");
    let snap = reg.snapshot();
    assert_eq!(snap.counters["serve.completed"], REQUESTS as u64);
    for s in Stage::ALL {
        let key = format!("serve.stage.{}", s.name());
        let sk = snap.sketches.get(&key).expect("stage sketch exported");
        assert_eq!(sk.count(), REQUESTS as u64, "{key} records every request");
    }

    let stats = coord.shutdown();
    assert_eq!(stats.completed, REQUESTS as u64);
    for s in Stage::ALL {
        assert_eq!(stats.stages.get(s).count, REQUESTS as u64, "{s:?} summary count");
    }

    // Acceptance criterion: the sketch quantiles agree with the exact
    // per-request latencies (same nearest-rank convention) within the
    // documented relative-error bound.
    for (est, q) in [
        (stats.latency_ms_p50, 0.50),
        (stats.latency_ms_p95, 0.95),
        (stats.latency_ms_p99, 0.99),
        (stats.latency_ms_p999, 0.999),
    ] {
        let truth = quantile(&exact_ms, q);
        let tol = truth * REL_ERROR + 1e-6;
        assert!(
            (est - truth).abs() <= tol,
            "p{q}: sketch {est} vs exact {truth} (tol {tol})"
        );
    }
    let exact_max = exact_ms.iter().cloned().fold(0.0f64, f64::max);
    assert!(
        (stats.latency_ms_max - exact_max).abs() <= 1e-6,
        "max is tracked exactly: {} vs {exact_max}",
        stats.latency_ms_max
    );
    assert!(stats.latency_ms_p50 <= stats.latency_ms_p95);
    assert!(stats.latency_ms_p95 <= stats.latency_ms_p99);
    assert!(stats.latency_ms_p99 <= stats.latency_ms_p999);
    assert!(stats.latency_ms_p999 <= stats.latency_ms_max + 1e-9);
}

#[test]
fn retry_path_charges_backoff_and_still_sums_exactly() {
    let backoff = Duration::from_millis(1);
    let (reg, m) = single();
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 1,
            max_batch: 2,
            max_wait: Duration::from_millis(1),
            queue_depth: 8,
            max_retries: 3,
            retry_backoff: backoff,
            ..CoordinatorConfig::default()
        },
        reg,
        move |_| {
            Box::new(FlakyEngine {
                inner: SleepEngine { batch: 2, delay: Duration::from_micros(200) },
                fail_first: 1,
                calls: 0,
            }) as Box<dyn InferenceEngine>
        },
    );

    let res = match coord.infer_blocking(m, vec![7u8; IMG]) {
        Ok(res) => res,
        Err(e) => panic!("one failure then success must be retried, got {e:?}"),
    };
    assert_eq!(res.trace.total(), res.latency, "retried request still sums exactly");
    assert!(
        res.trace.backoff >= backoff,
        "backoff stage {:?} must cover the retry sleep ({backoff:?})",
        res.trace.backoff
    );

    // A second request on the now-healthy engine completes cleanly.
    match coord.infer_blocking(m, vec![8u8; IMG]) {
        Ok(res) => assert_eq!(res.trace.backoff, Duration::ZERO, "healthy engine: no backoff"),
        Err(ServeError::Rejected(r)) => panic!("unexpected shed: {r:?}"),
        Err(e) => panic!("unexpected failure: {e:?}"),
    }

    let stats = coord.shutdown();
    assert_eq!(stats.completed, 2);
    assert!(stats.retries >= 1, "the injected failure must be counted as a retry");
    assert!(
        stats.stages.backoff.max_ms >= backoff.as_secs_f64() * 1e3,
        "backoff sketch saw the retry sleep"
    );
}

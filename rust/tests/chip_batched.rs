//! PR5 property suite: the time-batched chip fast mode is spike-for-spike
//! and **counter-for-counter** identical to the frozen per-step baseline
//! (`baselines::chip_stepwise`), to the gate-level `SimMode::Exact`
//! datapath, and to the golden engine — on randomized networks
//! (≥100 per mode), on the edge cases the older suites skip (T=1, c_out
//! off the u64 word boundary, odd spatial sizes with pooling, all-zero
//! spike trains through every `PlanKind`), and across hardware configs.
//! Also pins the per-`Chip` packed-model cache: batch loops calling
//! `Chip::run` per image must pack exactly once per distinct model.

use vsa::arch::dram::Traffic;
use vsa::arch::{Chip, RunReport, SimMode};
use vsa::baselines::chip_stepwise::StepwiseChip;
use vsa::config::HwConfig;
use vsa::snn::params::{DeployedModel, Kind, Layer};
use vsa::snn::Network;
use vsa::testing::models::{random_model, random_model_tiny};
use vsa::testing::{check, Gen};
use vsa::util::FIXED_POINT;

const TRAFFIC: [Traffic; 6] = [
    Traffic::Image,
    Traffic::Weights,
    Traffic::SpikesIn,
    Traffic::SpikesOut,
    Traffic::Membrane,
    Traffic::Logits,
];

/// Field-for-field [`RunReport`] equality: logits, every counter, every
/// per-layer report, and bit-equal f64 derived metrics.
fn assert_reports_identical(a: &RunReport, b: &RunReport) {
    assert_eq!(a.logits, b.logits, "logits");
    assert_eq!(a.cycles, b.cycles, "cycles");
    assert_eq!(a.pe_ops, b.pe_ops, "pe_ops");
    for t in TRAFFIC {
        assert_eq!(a.dram.category(t), b.dram.category(t), "dram {t:?}");
    }
    assert_eq!(a.dram.total(), b.dram.total(), "dram total");
    assert_eq!(a.sram.spike_reads, b.sram.spike_reads, "sram spike_reads");
    assert_eq!(a.sram.weight_reads, b.sram.weight_reads, "sram weight_reads");
    assert_eq!(a.sram.membrane_rmw, b.sram.membrane_rmw, "sram membrane_rmw");
    assert_eq!(a.sram.temp_writes, b.sram.temp_writes, "sram temp_writes");
    assert_eq!(a.sram.boundary_ops, b.sram.boundary_ops, "sram boundary_ops");
    assert_eq!(a.latency_us.to_bits(), b.latency_us.to_bits(), "latency_us");
    assert_eq!(a.gops.to_bits(), b.gops.to_bits(), "gops");
    assert_eq!(a.utilization.to_bits(), b.utilization.to_bits(), "utilization");
    assert_eq!(a.layers.len(), b.layers.len(), "layer count");
    for (i, (la, lb)) in a.layers.iter().zip(&b.layers).enumerate() {
        assert_eq!(la.kind, lb.kind, "layer {i} kind");
        assert_eq!(la.cycles, lb.cycles, "layer {i} cycles");
        assert_eq!(la.spikes_emitted, lb.spikes_emitted, "layer {i} spikes_emitted");
        assert_eq!(la.membrane_accesses, lb.membrane_accesses, "layer {i} membrane");
        assert_eq!(la.pe_ops, lb.pe_ops, "layer {i} pe_ops");
        assert_eq!(la.dram_bytes, lb.dram_bytes, "layer {i} dram_bytes");
        assert_eq!(la.sram.total(), lb.sram.total(), "layer {i} sram");
        assert_eq!(
            la.utilization.to_bits(),
            lb.utilization.to_bits(),
            "layer {i} utilization"
        );
    }
}

/// Run all four engines on one case: batched fast == stepwise baseline ==
/// exact datapath (full reports), and all match the golden logits.
fn engines_all_agree(model: &DeployedModel, image: &[u8]) {
    let fast = Chip::new(HwConfig::default(), SimMode::Fast).run(model, image);
    let step = StepwiseChip::new(HwConfig::default()).run(model, image);
    assert_reports_identical(&fast, &step);
    let exact = Chip::new(HwConfig::default(), SimMode::Exact).run(model, image);
    assert_reports_identical(&fast, &exact);
    assert_eq!(fast.logits, Network::new(model.clone()).infer_u8(image), "golden");
}

/// Explicit-geometry model: enc(c1)[+pool] -> conv(c2)[+pool] ->
/// fc(n_fc) -> readout, random weights/thresholds from `g`.
#[allow(clippy::too_many_arguments)]
fn layered_model(
    g: &mut Gen,
    in_size: usize,
    c1: usize,
    pool1: bool,
    c2: usize,
    pool2: bool,
    n_fc: usize,
    t: usize,
) -> (DeployedModel, Vec<u8>) {
    let mid = if pool1 { in_size / 2 } else { in_size };
    let end = if pool2 { mid / 2 } else { mid };
    let mut layers = vec![Layer::Conv {
        kind: Kind::EncConv,
        c_out: c1,
        c_in: 1,
        k: 3,
        w: g.weights(c1 * 9),
        bias: (0..c1).map(|_| g.i32_in(-200, 200) * FIXED_POINT / 4).collect(),
        theta: (0..c1).map(|_| g.i32_in(1, 150) * FIXED_POINT).collect(),
    }];
    if pool1 {
        layers.push(Layer::MaxPool);
    }
    layers.push(Layer::Conv {
        kind: Kind::Conv,
        c_out: c2,
        c_in: c1,
        k: 3,
        w: g.weights(c2 * c1 * 9),
        bias: (0..c2).map(|_| g.i32_in(-3, 3) * FIXED_POINT).collect(),
        theta: (0..c2).map(|_| g.i32_in(1, 8) * FIXED_POINT).collect(),
    });
    if pool2 {
        layers.push(Layer::MaxPool);
    }
    layers.push(Layer::Fc {
        n_out: n_fc,
        n_in: c2 * end * end,
        w: g.weights(n_fc * c2 * end * end),
        bias: (0..n_fc).map(|_| g.i32_in(-2, 2) * FIXED_POINT).collect(),
        theta: (0..n_fc).map(|_| g.i32_in(1, 4) * FIXED_POINT).collect(),
    });
    layers.push(Layer::Readout { n_out: 10, n_in: n_fc, w: g.weights(10 * n_fc) });
    let model = DeployedModel {
        name: "edge".into(),
        num_steps: t,
        in_channels: 1,
        in_size,
        layers,
    };
    let image: Vec<u8> = (0..in_size * in_size).map(|_| g.i32_in(0, 255) as u8).collect();
    (model, image)
}

/// Acceptance (fast mode, ≥100 nets): the time-batched datapath is
/// counter-for-counter equal to the frozen per-step baseline and matches
/// the golden engine.  One shared `Chip` across every case also soaks the
/// packed-model cache's invalidation path (each case is a new model).
#[test]
fn fast_batched_equals_stepwise_and_golden_on_random_networks() {
    let chip = Chip::new(HwConfig::default(), SimMode::Fast);
    let stepwise = StepwiseChip::new(HwConfig::default());
    check("chip fast: batched vs stepwise vs golden", 110, |g: &mut Gen| {
        let (model, image) = random_model(g);
        let fast = chip.run(&model, &image);
        let step = stepwise.run(&model, &image);
        assert_reports_identical(&fast, &step);
        assert_eq!(fast.logits, Network::new(model.clone()).infer_u8(&image), "golden");
    });
}

/// Acceptance (exact mode, ≥100 nets): the gate-level datapath, the
/// batched fast mode, the stepwise baseline and the golden engine agree
/// on tiny geometries (the PE-level sim is slow in debug builds).
#[test]
fn exact_mode_agrees_on_random_tiny_networks() {
    check("chip exact vs batched vs stepwise vs golden", 100, |g: &mut Gen| {
        let (model, image) = random_model_tiny(g);
        engines_all_agree(&model, &image);
    });
}

/// Counters must stay identical between the batched and stepwise engines
/// under reconfigured hardware (PE geometry, fusion on/off) — the
/// counters change, the agreement must not.
#[test]
fn reports_identical_across_hw_configs() {
    check("hw sweep: batched vs stepwise", 12, |g: &mut Gen| {
        let (model, image) = random_model(g);
        let hw = HwConfig {
            pe_blocks: *g.choose(&[8usize, 32, 64]),
            rows_per_array: *g.choose(&[4usize, 8]),
            layer_fusion: g.bool(),
            ..HwConfig::default()
        };
        let fast = Chip::new(hw.clone(), SimMode::Fast).run(&model, &image);
        let step = StepwiseChip::new(hw).run(&model, &image);
        assert_reports_identical(&fast, &step);
    });
}

/// Edge: T=1 (no temporal reuse to batch) across the full-size generator,
/// fast mode against the baseline + golden.
#[test]
fn edge_t1_full_size() {
    check("T=1 full size", 20, |g: &mut Gen| {
        let (mut model, image) = random_model(g);
        model.num_steps = 1;
        let fast = Chip::new(HwConfig::default(), SimMode::Fast).run(&model, &image);
        let step = StepwiseChip::new(HwConfig::default()).run(&model, &image);
        assert_reports_identical(&fast, &step);
        assert_eq!(fast.logits, Network::new(model.clone()).infer_u8(&image), "golden");
    });
}

/// Edge: T=1 through the exact datapath too (tiny geometries).
#[test]
fn edge_t1_both_modes() {
    for seed in [1u64, 2, 3] {
        let g = &mut Gen::new(seed);
        let (mut model, image) = random_model_tiny(g);
        model.num_steps = 1;
        engines_all_agree(&model, &image);
    }
}

/// Edge: `c_out` off the u64 word boundary (63/65 channels pack into
/// 1/2 words per pixel), in both sim modes.
#[test]
fn edge_c_out_off_word_boundary() {
    for &c2 in &[63usize, 65] {
        let g = &mut Gen::new(c2 as u64);
        let (model, image) = layered_model(g, 6, 2, false, c2, false, 7, 2);
        engines_all_agree(&model, &image);
    }
}

/// Edge: odd spatial sizes with pooling (the pool drops the trailing
/// row/column), pooled after the encoding layer and after a conv layer,
/// in both sim modes.
#[test]
fn edge_odd_spatial_with_pooling() {
    let g = &mut Gen::new(7);
    // 7x7 enc output pooled -> 3x3.
    let (m1, i1) = layered_model(g, 7, 2, true, 3, false, 5, 2);
    engines_all_agree(&m1, &i1);
    // 9x9 conv output pooled -> 4x4 (two row tiles in the exact schedule).
    let (m2, i2) = layered_model(g, 9, 3, false, 2, true, 4, 3);
    engines_all_agree(&m2, &i2);
}

/// Edge: an all-zero spike train through every `PlanKind`, in both sim
/// modes.  Variant (a): only the encoding layer is silenced — downstream
/// layers may still fire from negative biases (spikes out of silence);
/// the engines must agree.  Variant (b): all biases zeroed — nothing can
/// fire anywhere and every spike/logit must be exactly zero.
#[test]
fn edge_all_zero_spike_train_through_every_plan_kind() {
    let g = &mut Gen::new(99);
    let (mut model, image) = layered_model(g, 8, 3, true, 4, false, 5, 4);
    for ly in &mut model.layers {
        if let Layer::Conv { kind: Kind::EncConv, bias, theta, .. } = ly {
            bias.fill(0);
            theta.fill(1_000_000_000); // unreachable: enc never fires
        }
    }
    engines_all_agree(&model, &image);

    let mut silent = model.clone();
    for ly in &mut silent.layers {
        match ly {
            Layer::Conv { kind: Kind::Conv, bias, .. } | Layer::Fc { bias, .. } => {
                bias.fill(0)
            }
            _ => {}
        }
    }
    let fast = Chip::new(HwConfig::default(), SimMode::Fast).run(&silent, &image);
    assert!(
        fast.layers.iter().all(|l| l.spikes_emitted == 0),
        "a fully silent net must emit zero spikes"
    );
    assert!(fast.logits.iter().all(|&l| l == 0), "silent net logits must be zero");
    engines_all_agree(&silent, &image);
}

/// Regression (pack-counter hook): a `vsa eval`-style scoring loop — one
/// model, many images through `Chip::run` — must build the packed model
/// exactly once, and produce the same logits as per-image fresh chips.
#[test]
fn batch_loops_pack_once_per_model() {
    let g = &mut Gen::new(11);
    let (model, _) = random_model(g);
    let n_px = model.in_size * model.in_size;
    let images: Vec<Vec<u8>> = (0..6)
        .map(|i| (0..n_px).map(|j| ((i * 31 + j * 7) % 256) as u8).collect())
        .collect();
    let fresh: Vec<Vec<i64>> = images
        .iter()
        .map(|img| Chip::new(HwConfig::default(), SimMode::Fast).run(&model, img).logits)
        .collect();
    let chip = Chip::new(HwConfig::default(), SimMode::Fast);
    for (img, want) in images.iter().zip(&fresh) {
        assert_eq!(&chip.run(&model, img).logits, want);
    }
    assert_eq!(chip.pack_count(), 1, "batch loop must pack exactly once per model");
}

/// Regression: interleaving two models through one chip re-packs on each
/// switch (single-entry cache) and never serves stale packed weights.
#[test]
fn interleaved_models_stay_correct() {
    let g = &mut Gen::new(5);
    let (ma, ia) = random_model(g);
    let (mb, ib) = random_model(g);
    let fa = Chip::new(HwConfig::default(), SimMode::Fast).run(&ma, &ia);
    let fb = Chip::new(HwConfig::default(), SimMode::Fast).run(&mb, &ib);
    let chip = Chip::new(HwConfig::default(), SimMode::Fast);
    for _ in 0..2 {
        assert_eq!(chip.run(&ma, &ia).logits, fa.logits);
        assert_eq!(chip.run(&mb, &ib).logits, fb.logits);
    }
    assert_eq!(chip.pack_count(), 4, "A,B,A,B through a single-entry cache");
}

//! SIMD-vs-scalar differential suite (PR10).
//!
//! The AND-popcount kernels ship three flavors (scalar, POPCNT, AVX2)
//! behind runtime dispatch, and the golden engine shards batches over
//! worker threads.  Every one of those paths must produce the SAME
//! bytes: i32 popcount sums are order-independent, so lane unrolling,
//! channel blocking, SIMD reduction and batch sharding are all bit-exact
//! by construction — and this suite holds them to it on random networks,
//! pinned lane-boundary shapes, and degenerate spike patterns.

use std::sync::Mutex;
use vsa::coordinator::{GoldenEngine, InferenceEngine, ModelRegistry};
use vsa::snn::conv::PackedFc;
use vsa::snn::popcount;
use vsa::snn::Network;
use vsa::testing::models::random_model;
use vsa::testing::{check, Gen};

/// `set_force_scalar` flips process-global dispatch state; the
/// differential tests hold this lock across the whole
/// dispatched-then-scalar comparison so concurrent tests can neither
/// interleave flips nor observe each other's forced state.
static FORCE_LOCK: Mutex<()> = Mutex::new(());

/// Restores hardware dispatch even when the comparison panics.
struct Unforce;
impl Drop for Unforce {
    fn drop(&mut self) {
        popcount::set_force_scalar(false);
    }
}

/// Run `f` once under normal dispatch and once pinned to the scalar
/// kernels; assert the results are identical.
fn assert_scalar_matches_dispatched<T, F>(label: &str, f: F)
where
    T: PartialEq + std::fmt::Debug,
    F: Fn() -> T,
{
    let _lock = FORCE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    popcount::set_force_scalar(false);
    let dispatched = f();
    let kernel = popcount::active_kernel();
    popcount::set_force_scalar(true);
    let _restore = Unforce;
    let scalar = f();
    assert_eq!(dispatched, scalar, "{label}: '{kernel}' kernels diverged from scalar");
}

#[test]
fn random_networks_bit_identical_scalar_vs_dispatched() {
    // random_model spans c_in 4..33 (crossing the 64-bit word boundary
    // at c2 = 33 via the fc's n_in), T 1..6, optional pooling — the
    // whole inference path runs through conv, tap_ones, and matvec
    // kernels in both flavors.
    check("scalar == dispatched inference", 20, |g: &mut Gen| {
        let (model, image) = random_model(g);
        let net = Network::new(model);
        assert_scalar_matches_dispatched("random network", || net.infer_u8(&image));
    });
}

#[test]
fn single_step_networks_bit_identical() {
    // T = 1 pins the degenerate time loop (no membrane carry-over).
    check("T=1 scalar == dispatched", 5, |g: &mut Gen| {
        let (mut model, image) = random_model(g);
        model.num_steps = 1;
        let net = Network::new(model);
        assert_scalar_matches_dispatched("T=1 network", || net.infer_u8(&image));
    });
}

/// Word-at-a-time reference for the fc psum: `popcnt(s) − 2·popcnt(s &
/// w_neg)` with no unrolling, blocking, or SIMD.
fn naive_fc(w: &[i8], n_out: usize, n_in: usize, spikes: &[u8]) -> Vec<i32> {
    (0..n_out)
        .map(|o| {
            (0..n_in)
                .map(|i| w[o * n_in + i] as i32 * spikes[i] as i32)
                .sum()
        })
        .collect()
}

fn pack_spike_words(spikes: &[u8]) -> Vec<u64> {
    let mut words = vec![0u64; ((spikes.len() + 63) / 64).max(1)];
    for (i, &s) in spikes.iter().enumerate() {
        if s != 0 {
            words[i / 64] |= 1u64 << (i % 64);
        }
    }
    words
}

#[test]
fn fc_lane_boundaries_pinned() {
    // n_in values straddling the unroll width (4 words) and the AVX2
    // width (4 words/vector): 1..9 words, plus off-by-one around the
    // 64-bit boundary; n_out 63/65 straddles the channel-block width.
    let n_ins = [1usize, 63, 64, 65, 127, 128, 192, 256, 320, 512, 576];
    let n_outs = [1usize, 8, 63, 65];
    let mut g = Gen::new(0xF00D);
    for &n_in in &n_ins {
        for &n_out in &n_outs {
            let w = g.weights(n_out * n_in);
            let fc = PackedFc::pack(n_out, n_in, &w);
            let spike_sets: [Vec<u8>; 3] =
                [vec![0u8; n_in], vec![1u8; n_in], g.spikes(n_in, 37)];
            for (si, spikes) in spike_sets.iter().enumerate() {
                let words = pack_spike_words(spikes);
                let naive = naive_fc(&w, n_out, n_in, spikes);
                let label = format!("fc n_in={n_in} n_out={n_out} spikes#{si}");
                assert_scalar_matches_dispatched(&label, || fc.matvec(&words));
                assert_eq!(fc.matvec(&words), naive, "{label}: matvec vs naive");
                let mut into = vec![-7i32; n_out];
                fc.matvec_into(&words, &mut into);
                assert_eq!(into, naive, "{label}: matvec_into vs naive");
            }
        }
    }
}

#[test]
fn fc_time_batched_matches_per_step_at_boundaries() {
    let mut g = Gen::new(0xBEEF);
    for &(n_in, n_out, t_steps) in
        &[(64usize, 63usize, 1usize), (65, 65, 3), (320, 8, 4), (576, 5, 2)]
    {
        let w = g.weights(n_out * n_in);
        let fc = PackedFc::pack(n_out, n_in, &w);
        let per_step: Vec<Vec<u8>> = (0..t_steps).map(|_| g.spikes(n_in, 45)).collect();
        let flat: Vec<u64> =
            per_step.iter().flat_map(|s| pack_spike_words(s)).collect();
        let label = format!("matvec_t n_in={n_in} n_out={n_out} T={t_steps}");
        assert_scalar_matches_dispatched(&label, || {
            let mut out = vec![0i32; t_steps * n_out];
            fc.matvec_t(&flat, t_steps, &mut out);
            out
        });
        let mut out = vec![0i32; t_steps * n_out];
        fc.matvec_t(&flat, t_steps, &mut out);
        for (t, spikes) in per_step.iter().enumerate() {
            assert_eq!(
                &out[t * n_out..(t + 1) * n_out],
                &naive_fc(&w, n_out, n_in, spikes)[..],
                "{label}: step {t} vs naive"
            );
        }
    }
}

#[test]
fn golden_engine_batches_byte_identical_across_thread_counts() {
    check("engine threads are invisible", 6, |g: &mut Gen| {
        let (model, image) = random_model(g);
        let mid_geom = image.len();
        // 13 distinct images: prime count so no thread count divides it.
        let images: Vec<Vec<u8>> = (0..13u8)
            .map(|i| image.iter().map(|&p| p.wrapping_add(i.wrapping_mul(31))).collect())
            .collect();
        assert_eq!(images[0].len(), mid_geom);
        let serial = {
            let (registry, mid) = ModelRegistry::single(model.clone());
            let mut engine = GoldenEngine::new(registry, 8);
            engine.infer(mid, &images).expect("serial batch")
        };
        for threads in [2usize, 3, 4, 8] {
            let (registry, mid) = ModelRegistry::single(model.clone());
            let mut engine = GoldenEngine::new(registry, 8).with_threads(threads);
            let got = engine.infer(mid, &images).expect("threaded batch");
            assert_eq!(serial, got, "threads={threads} changed the logits");
        }
    });
}

//! Design-space exploration acceptance tests: the `small` grid sweep is
//! laptop-scale, deterministic, produces a well-formed Pareto frontier,
//! and the paper's published design point — 32x3x(8x3) PEs, 500 MHz,
//! 96 KiB weight SRAM, T = 8 — lies on (or within a small documented
//! slack of) the extracted frontier.

use vsa::config::json::{self, Json};
use vsa::dse::{self, report::SweepMeta, Candidate, SearchSpace};

/// Tolerated epsilon-dominance slack for the paper's design point: no
/// other candidate at the same T may beat it by more than 5% in *every*
/// objective (throughput, core power, area) simultaneously.
///
/// The comparison is pinned to the paper's T = 8: fewer time steps do
/// strictly less compute, so lower-T candidates dominate trivially while
/// paying an accuracy cost the analytic model does not score (Fig. 8's
/// accuracy-vs-T trade-off).  Chip-vs-chip comparisons are only
/// meaningful at a fixed workload setting.  The measured slack on the
/// small grid is 0.000 for MNIST (tied by smaller-SRAM configs with
/// identical timing) and ~0.036 for MNIST+CIFAR-10 (a 1152-PE 800 MHz
/// point edges the paper chip on the geomean objective).
const PAPER_SLACK_TOLERANCE: f64 = 0.05;

fn sweep(workloads: &[&str]) -> (Vec<dse::CandidateResult>, Vec<usize>) {
    let space = SearchSpace::small();
    let candidates: Vec<Candidate> = space
        .cartesian()
        .filter(|c| dse::validate(c, workloads).is_ok())
        .collect();
    assert!(
        candidates.len() >= 200,
        "acceptance: small grid must keep >= 200 valid candidates, got {}",
        candidates.len()
    );
    let results = dse::evaluate_all(&candidates, workloads, 4);
    let front = dse::frontier(&results);
    (results, front)
}

#[test]
fn small_sweep_frontier_is_well_formed() {
    let (results, front) = sweep(&["mnist"]);
    assert!(!front.is_empty());
    // every frontier pair is mutually non-dominating
    for (a, &i) in front.iter().enumerate() {
        for &j in &front[a + 1..] {
            assert!(
                !dse::dominates(&results[i], &results[j])
                    && !dse::dominates(&results[j], &results[i]),
                "frontier points {i} and {j} dominate each other"
            );
        }
    }
    // every non-frontier point is dominated by someone
    for i in 0..results.len() {
        if front.contains(&i) {
            continue;
        }
        assert!(
            results.iter().any(|o| dse::dominates(o, &results[i])),
            "point {i} excluded from the frontier but undominated"
        );
    }
    // frontier is sorted by descending throughput
    for w in front.windows(2) {
        assert!(results[w[0]].throughput_ips >= results[w[1]].throughput_ips);
    }
}

#[test]
fn paper_design_point_is_pareto_optimal_on_mnist() {
    let (results, _) = sweep(&["mnist"]);
    let slack = dse::paper_slack_at_t(&results)
        .expect("paper design point must be a valid candidate of the small space");
    assert!(
        slack <= PAPER_SLACK_TOLERANCE,
        "paper design point off the T=8 frontier with slack {slack:.4} > {PAPER_SLACK_TOLERANCE}"
    );
}

#[test]
fn paper_design_point_is_pareto_optimal_on_both_workloads() {
    let (results, _) = sweep(&["mnist", "cifar10"]);
    let slack = dse::paper_slack_at_t(&results).expect("paper point valid for both workloads");
    assert!(
        slack <= PAPER_SLACK_TOLERANCE,
        "paper design point off the joint T=8 frontier with slack {slack:.4}"
    );
}

/// Lower T trivially dominates (less compute, unmodeled accuracy cost):
/// the reason the paper-point regression pins T.  This documents the
/// behaviour instead of hiding it.
#[test]
fn lower_t_dominates_across_the_t_axis() {
    let (results, _) = sweep(&["mnist"]);
    let paper = Candidate::paper();
    let i = dse::find_by_id(&results, &paper.id()).unwrap();
    let full_slack = dse::slack(&results[i], &results);
    let pinned_slack = dse::paper_slack_at_t(&results).unwrap();
    assert!(
        full_slack > pinned_slack,
        "expected cross-T domination: full {full_slack:.4} vs pinned {pinned_slack:.4}"
    );
}

/// A fixed seed makes the whole pipeline reproducible: sampling,
/// evaluation (any thread count) and frontier extraction, down to the
/// serialized JSON bytes.
#[test]
fn sweep_is_deterministic_for_fixed_seed() {
    let space = SearchSpace::wide();
    let mut docs = Vec::new();
    for threads in [1usize, 4] {
        let candidates: Vec<Candidate> = space
            .sample(64, 123)
            .into_iter()
            .filter(|c| dse::validate(c, &["mnist"]).is_ok())
            .collect();
        let results = dse::evaluate_all(&candidates, &["mnist"], threads);
        let front = dse::frontier(&results);
        let meta = SweepMeta {
            space: space.name.clone(),
            workloads: vec!["mnist".into()],
            grid_size: space.len(),
            sampled: 64,
            seed: 123,
            threads: 1, // keep provenance identical so the bytes can match
        };
        docs.push(json::to_string(&dse::report::to_json(&meta, &results, &front, None)));
    }
    assert_eq!(docs[0], docs[1], "sweep output depends on thread count");
}

#[test]
fn report_json_parses_and_counts_match() {
    let (results, front) = sweep(&["mnist"]);
    let meta = SweepMeta {
        space: "small".into(),
        workloads: vec!["mnist".into()],
        grid_size: SearchSpace::small().len(),
        sampled: 0,
        seed: 7,
        threads: 4,
    };
    let text = json::to_string(&dse::report::to_json(&meta, &results, &front, Some(0.0)));
    let doc = Json::parse(&text).expect("valid JSON");
    assert_eq!(doc.get("candidates_evaluated").unwrap().as_usize(), Some(results.len()));
    assert_eq!(doc.get("frontier").unwrap().as_arr().unwrap().len(), front.len());
    assert_eq!(doc.get("schema").unwrap().as_str(), Some("vsa-dse-v1"));
}

/// PR3 satellite: the frontier CSV export carries one row per frontier
/// point with every knob and objective, in frontier order.
#[test]
fn csv_export_one_row_per_frontier_point() {
    let (results, front) = sweep(&["mnist"]);
    let csv = dse::report::to_csv(&results, &front);
    let lines: Vec<&str> = csv.lines().collect();
    assert_eq!(lines.len(), front.len() + 1, "header + one row per point");
    let header: Vec<&str> = lines[0].split(',').collect();
    assert_eq!(header[0], "rank");
    assert!(header.contains(&"throughput_ips"));
    assert!(header.contains(&"num_steps"));
    assert!(header.contains(&"accuracy"));
    for (rank, (&i, line)) in front.iter().zip(&lines[1..]).enumerate() {
        let cols: Vec<&str> = line.split(',').collect();
        assert_eq!(cols.len(), header.len(), "row {rank} column count");
        assert_eq!(cols[0], format!("{}", rank + 1));
        assert_eq!(cols[1], results[i].candidate.id());
        let thr: f64 = cols[header.iter().position(|&h| h == "throughput_ips").unwrap()]
            .parse()
            .expect("numeric throughput");
        assert_eq!(thr, results[i].throughput_ips);
        // no artifact in this sweep: accuracy column is empty
        assert_eq!(*cols.last().unwrap(), "");
    }
}

/// PR3 tentpole follow-through: with a trained artifact the sweep gains
/// a measured accuracy objective; low-T candidates then stop dominating
/// "for free" and the frontier separates by T where accuracy differs.
#[test]
fn accuracy_objective_joins_sweep_and_report() {
    use vsa::config::models;
    use vsa::snn::params::DeployedModel;

    let space = SearchSpace::tiny();
    let candidates: Vec<Candidate> = space
        .cartesian()
        .filter(|c| dse::validate(c, &["mnist"]).is_ok())
        .collect();
    // A deterministic stand-in artifact (synthesized weights): accuracy
    // is near-chance but *measured*, which is all the plumbing needs.
    let artifact = DeployedModel::synthesize(&models::micro(4), 7);
    let acc = dse::accuracy_by_t(&artifact, candidates.iter().map(|c| c.num_steps), 16, 7);
    let results = dse::evaluate_all_with(&candidates, &["mnist"], 2, Some(&acc));
    assert!(results.iter().all(|r| r.accuracy.is_some()));
    for r in &results {
        assert_eq!(r.accuracy, Some(acc[&r.candidate.num_steps]));
    }
    // byte-determinism holds with the objective attached
    let again = dse::evaluate_all_with(&candidates, &["mnist"], 4, Some(&acc));
    for (a, b) in results.iter().zip(&again) {
        assert_eq!(a.accuracy, b.accuracy);
        assert_eq!(a.throughput_ips.to_bits(), b.throughput_ips.to_bits());
    }
    // the CSV now fills the accuracy column
    let front = dse::frontier(&results);
    let csv = dse::report::to_csv(&results, &front);
    let last_col = csv.lines().nth(1).unwrap().split(',').next_back().unwrap().to_string();
    assert!(last_col.parse::<f64>().is_ok(), "accuracy column filled, got '{last_col}'");
}

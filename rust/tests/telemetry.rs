//! Property suite for the PR7 telemetry stack: the log-bucketed
//! latency sketch's error bound against an exact oracle, merge algebra,
//! cross-thread determinism of sharded recording, the shared quantile
//! conventions between `util::stats` and the sketch, and registry
//! snapshot stability.

use std::time::Duration;

use vsa::config::json::Json;
use vsa::telemetry::{AtomicSketch, HistogramSketch, Registry, REL_ERROR, SCHEMA, SUB};
use vsa::testing::{check, Gen};
use vsa::util::stats::quantile;

/// Random nanosecond sample spanning many octaves (sub-bucket-exact
/// values through multi-second latencies).
fn gen_ns(g: &mut Gen) -> u64 {
    let bits = g.usize_in(1, 40) as u32;
    g.u64() % (1u64 << bits)
}

const QS: [f64; 7] = [0.0, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0];

#[test]
fn sketch_quantiles_match_exact_within_documented_bound() {
    check("sketch vs exact quantile", 200, |g: &mut Gen| {
        let n = g.usize_in(1, 300);
        let mut sketch = HistogramSketch::new();
        let mut exact: Vec<f64> = Vec::with_capacity(n);
        for _ in 0..n {
            let v = gen_ns(g);
            sketch.record_ns(v);
            exact.push(v as f64);
        }
        for q in QS {
            let est = sketch.quantile_ns(q);
            let truth = quantile(&exact, q);
            // The documented bound, plus half-a-tick absolute slack for
            // the integer-ns oracle at tiny values.
            let tol = truth * REL_ERROR + 0.5;
            assert!(
                (est - truth).abs() <= tol,
                "q={q}: estimate {est} vs exact {truth} (tol {tol}, n={n})"
            );
        }
        assert_eq!(sketch.quantile_ns(1.0), quantile(&exact, 1.0), "max is exact");
    });
}

#[test]
fn merge_is_associative_commutative_and_matches_sequential() {
    check("sketch merge algebra", 100, |g: &mut Gen| {
        let draw = |g: &mut Gen| -> Vec<u64> {
            let n = g.usize_in(0, 60);
            (0..n).map(|_| gen_ns(g)).collect()
        };
        let (xs, ys, zs) = (draw(g), draw(g), draw(g));
        let sk = |vals: &[u64]| {
            let mut s = HistogramSketch::new();
            for &v in vals {
                s.record_ns(v);
            }
            s
        };
        let (a, b, c) = (sk(&xs), sk(&ys), sk(&zs));

        // Commutativity: a + b == b + a.
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab, ba, "merge commutes");

        // Associativity: (a + b) + c == a + (b + c).
        let mut ab_c = ab.clone();
        ab_c.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);
        assert_eq!(ab_c, a_bc, "merge associates");

        // Sharded recording == sequential recording of the union.
        let all: Vec<u64> = xs.iter().chain(&ys).chain(&zs).copied().collect();
        assert_eq!(ab_c, sk(&all), "merge of shards == one-stream sketch");
    });
}

#[test]
fn sharded_recording_is_deterministic_at_any_thread_count() {
    // The coordinator's per-worker shards merged in fixed order must
    // produce a byte-identical sketch no matter how many threads did
    // the recording — the property `Coordinator::stats()` relies on.
    let values: Vec<u64> = {
        let mut g = Gen::new(0xC0FFEE);
        (0..4096).map(|_| gen_ns(&mut g)).collect()
    };
    let run = |threads: usize| -> HistogramSketch {
        let shards: Vec<AtomicSketch> = (0..threads).map(|_| AtomicSketch::new()).collect();
        std::thread::scope(|scope| {
            for (t, shard) in shards.iter().enumerate() {
                let values = &values;
                scope.spawn(move || {
                    for v in values.iter().skip(t).step_by(threads) {
                        shard.record_ns(*v);
                    }
                });
            }
        });
        let mut merged = HistogramSketch::new();
        for shard in &shards {
            merged.merge(&shard.snapshot());
        }
        merged
    };
    let base = run(1);
    assert_eq!(base.count(), 4096);
    for threads in [2, 3, 4, 7] {
        assert_eq!(base, run(threads), "threads={threads} must match threads=1");
    }
}

#[test]
fn sketch_and_util_stats_share_one_quantile_convention() {
    // Values below 2*SUB ns land in width-1 buckets, so the sketch is
    // *exact* there — any disagreement with `util::stats::quantile` on
    // such inputs is a rank-convention mismatch, not approximation.
    check("rank conventions agree", 200, |g: &mut Gen| {
        let n = g.usize_in(1, 50);
        let mut sketch = HistogramSketch::new();
        let mut exact = Vec::with_capacity(n);
        for _ in 0..n {
            let v = g.u64() % (2 * SUB);
            sketch.record_ns(v);
            exact.push(v as f64);
        }
        for q in [0.0, 0.1, 0.5, 0.77, 0.95, 1.0, 1.5, -0.5, f64::NAN] {
            assert_eq!(
                sketch.quantile_ns(q),
                quantile(&exact, q),
                "q={q} must agree exactly on width-1 buckets (n={n})"
            );
        }
    });
    // Empty-input convention matches too.
    assert_eq!(HistogramSketch::new().quantile_ns(0.5), quantile(&[], 0.5));
}

#[test]
fn registry_snapshot_round_trips_and_is_stable() {
    let build = || {
        let reg = Registry::new();
        reg.set_counter("serve.completed", 41);
        reg.counter("serve.completed").inc();
        reg.set_gauge("serve.throughput_rps", 123.5);
        let lat = reg.sketch("serve.latency");
        for ms in [1u64, 2, 3, 40] {
            lat.record(Duration::from_millis(ms));
        }
        reg.snapshot()
    };
    let snap = build();
    assert_eq!(snap, build(), "identical inputs give identical snapshots");
    assert_eq!(snap.render_text(), build().render_text(), "text is byte-deterministic");

    let doc = Json::parse(&snap.to_json()).expect("snapshot JSON parses");
    assert_eq!(doc.get("schema").unwrap().as_str(), Some(SCHEMA));
    let counters = doc.get("counters").unwrap();
    assert_eq!(counters.get("serve.completed").unwrap().as_i64(), Some(42));
    let lat = doc.get("sketches").unwrap().get("serve.latency").unwrap();
    assert_eq!(lat.get("count").unwrap().as_i64(), Some(4));
    let p50 = lat.get("p50_ms").unwrap().as_f64().unwrap();
    let max = lat.get("max_ms").unwrap().as_f64().unwrap();
    assert!((p50 - 2.0).abs() <= 2.0 * REL_ERROR, "p50 ~ 2ms, got {p50}");
    assert_eq!(max, 40.0, "max is exact");
}

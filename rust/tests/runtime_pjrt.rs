//! PJRT runtime integration: the AOT artifacts compile and execute on the
//! CPU PJRT client, and their outputs are bit-identical to the golden
//! model and the chip simulator.  Requires `make artifacts`.

use vsa::coordinator::{InferenceEngine, PjrtEngine};
use vsa::data::synth;
use vsa::runtime::{Manifest, PjrtExecutor};
use vsa::snn::Network;

fn manifest() -> Option<Manifest> {
    match Manifest::load("artifacts") {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("skipping PJRT tests: {e:#}");
            None
        }
    }
}

#[test]
fn tiny_batch1_matches_golden() {
    let Some(m) = manifest() else { return };
    let e = m.find("tiny", 1).unwrap();
    let exe = PjrtExecutor::load(&m.hlo_path(e), 1, e.in_channels, e.in_size).unwrap();
    let net = Network::from_vsaw_file(&m.weights_path(e)).unwrap();
    let mut engine = PjrtEngine::new(exe);
    for s in synth::tiny_like(3, 0, 4) {
        let got = engine.infer(&[s.image.clone()]).unwrap();
        assert_eq!(got[0], net.infer_u8(&s.image));
    }
}

#[test]
fn tiny_batch8_pads_partial_batches() {
    let Some(m) = manifest() else { return };
    let e = m.find("tiny", 8).unwrap();
    assert_eq!(e.batch, 8);
    let exe = PjrtExecutor::load(&m.hlo_path(e), 8, e.in_channels, e.in_size).unwrap();
    let net = Network::from_vsaw_file(&m.weights_path(e)).unwrap();
    let mut engine = PjrtEngine::new(exe);

    // full batch
    let samples = synth::tiny_like(9, 0, 8);
    let images: Vec<Vec<u8>> = samples.iter().map(|s| s.image.clone()).collect();
    let got = engine.infer(&images).unwrap();
    for (s, l) in samples.iter().zip(&got) {
        assert_eq!(l, &net.infer_u8(&s.image));
    }

    // partial batch (padded internally, padding results dropped)
    let got = engine.infer(&images[..3]).unwrap();
    assert_eq!(got.len(), 3);
    for (s, l) in samples[..3].iter().zip(&got) {
        assert_eq!(l, &net.infer_u8(&s.image));
    }
}

#[test]
fn mnist_pallas_artifact_matches_golden() {
    // The mnist artifact routes through the Pallas kernels (interpret
    // mode) — this is the L1-through-PJRT correctness check.
    let Some(m) = manifest() else { return };
    let e = m.find("mnist", 1).unwrap();
    assert!(e.pallas, "mnist artifact should use the pallas kernels");
    let exe = PjrtExecutor::load(&m.hlo_path(e), 1, e.in_channels, e.in_size).unwrap();
    let net = Network::from_vsaw_file(&m.weights_path(e)).unwrap();
    let mut engine = PjrtEngine::new(exe);
    for s in synth::mnist_like(17, 0, 2) {
        let got = engine.infer(&[s.image.clone()]).unwrap();
        assert_eq!(got[0], net.infer_u8(&s.image));
    }
}

#[test]
fn wrong_geometry_rejected() {
    let Some(m) = manifest() else { return };
    let e = m.find("tiny", 1).unwrap();
    let exe = PjrtExecutor::load(&m.hlo_path(e), 1, e.in_channels, e.in_size).unwrap();
    let bad = vec![vec![0u8; 7]]; // wrong pixel count
    assert!(exe.infer(&bad).is_err());
}

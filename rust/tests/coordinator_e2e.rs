//! Coordinator end-to-end: concurrent submission, batching behaviour,
//! backpressure, and engine equivalence under load.

use std::time::Duration;
use vsa::config::models;
use vsa::config::HwConfig;
use vsa::coordinator::{
    ChipEngine, Coordinator, CoordinatorConfig, GoldenEngine, InferenceEngine,
};
use vsa::data::synth;
use vsa::snn::params::DeployedModel;
use vsa::snn::Network;

/// The tiny model: artifact weights when present, deterministic
/// synthesized weights otherwise, so the suite runs from a clean
/// checkout (`make artifacts` is optional).  A *present but unparsable*
/// artifact still fails loudly — only a missing file falls back.
fn tiny_net() -> Network {
    const PATH: &str = "artifacts/tiny_t4.vsaw";
    if std::path::Path::new(PATH).exists() {
        Network::from_vsaw_file(PATH).expect("artifacts/tiny_t4.vsaw exists but fails to parse")
    } else {
        Network::new(DeployedModel::synthesize(&models::tiny(4), 42))
    }
}

#[test]
fn concurrent_submitters_all_complete() {
    let coord = std::sync::Arc::new(Coordinator::start(
        CoordinatorConfig {
            workers: 3,
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            queue_depth: 16, // small: exercises backpressure blocking
        },
        |_| Box::new(GoldenEngine::new(tiny_net(), 4)) as Box<dyn InferenceEngine>,
    ));

    let mut handles = Vec::new();
    for t in 0..4u64 {
        let coord = std::sync::Arc::clone(&coord);
        handles.push(std::thread::spawn(move || {
            let samples = synth::tiny_like(t, t * 100, 25);
            let mut ok = 0;
            for s in &samples {
                let res = coord.infer_blocking(s.image.clone()).unwrap();
                assert_eq!(res.logits.len(), 10);
                ok += 1;
            }
            ok
        }));
    }
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, 100);
    let stats = coord.stats();
    assert_eq!(stats.completed, 100);
    assert!(stats.mean_batch >= 1.0);
}

#[test]
fn batched_results_match_unbatched() {
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 2,
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            queue_depth: 128,
        },
        |_| Box::new(GoldenEngine::new(tiny_net(), 8)) as Box<dyn InferenceEngine>,
    );
    let net = tiny_net();
    let samples = synth::tiny_like(55, 0, 32);
    let rxs: Vec<_> = samples
        .iter()
        .map(|s| coord.submit(s.image.clone()).unwrap())
        .collect();
    for (rx, s) in rxs.into_iter().zip(&samples) {
        assert_eq!(rx.recv().unwrap().logits, net.infer_u8(&s.image));
    }
    coord.shutdown();
}

#[test]
fn chip_engine_reports_simulated_latency() {
    let mut engine = ChipEngine::new(HwConfig::default(), tiny_net(), 4);
    let samples = synth::tiny_like(2, 0, 3);
    let images: Vec<Vec<u8>> = samples.iter().map(|s| s.image.clone()).collect();
    engine.infer(&images).unwrap();
    assert!(engine.simulated_us > 0.0);
}

#[test]
fn stats_percentiles_ordered() {
    let coord = Coordinator::start(CoordinatorConfig::default(), |_| {
        Box::new(GoldenEngine::new(tiny_net(), 8)) as Box<dyn InferenceEngine>
    });
    for s in synth::tiny_like(3, 0, 20) {
        coord.infer_blocking(s.image).unwrap();
    }
    let stats = coord.shutdown();
    assert!(stats.latency_ms_p50 <= stats.latency_ms_p95);
    assert!(stats.latency_ms_p95 <= stats.latency_ms_p99);
    assert!(stats.throughput_rps > 0.0);
}

//! Coordinator end-to-end: concurrent submission, batching behaviour,
//! backpressure, and engine equivalence under load.

use std::sync::Arc;
use std::time::Duration;
use vsa::config::models;
use vsa::config::HwConfig;
use vsa::coordinator::{
    ChipEngine, Coordinator, CoordinatorConfig, GoldenEngine, InferenceEngine, ModelId,
    ModelRegistry,
};
use vsa::data::synth;
use vsa::snn::params::DeployedModel;
use vsa::snn::Network;

/// The tiny model: artifact weights when present, deterministic
/// synthesized weights otherwise, so the suite runs from a clean
/// checkout (`make artifacts` is optional).  A *present but unparsable*
/// artifact still fails loudly — only a missing file falls back.
fn tiny_model() -> DeployedModel {
    const PATH: &str = "artifacts/tiny_t4.vsaw";
    if std::path::Path::new(PATH).exists() {
        DeployedModel::from_file(PATH).expect("artifacts/tiny_t4.vsaw exists but fails to parse")
    } else {
        DeployedModel::synthesize(&models::tiny(4), 42)
    }
}

/// One-model coordinator over golden workers (the common case here).
fn start(cfg: CoordinatorConfig, batch: usize) -> (Coordinator, ModelId) {
    let (reg, m) = ModelRegistry::single(tiny_model());
    let regc = Arc::clone(&reg);
    let coord = Coordinator::start(cfg, reg, move |_| {
        Box::new(GoldenEngine::new(Arc::clone(&regc), batch)) as Box<dyn InferenceEngine>
    });
    (coord, m)
}

#[test]
fn concurrent_submitters_all_complete() {
    let (coord, m) = start(
        CoordinatorConfig {
            workers: 3,
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            queue_depth: 16, // small: exercises backpressure blocking
            ..CoordinatorConfig::default()
        },
        4,
    );
    let coord = Arc::new(coord);

    let mut handles = Vec::new();
    for t in 0..4u64 {
        let coord = Arc::clone(&coord);
        handles.push(std::thread::spawn(move || {
            let samples = synth::tiny_like(t, t * 100, 25);
            let mut ok = 0;
            for s in &samples {
                let res = coord.infer_blocking(m, s.image.clone()).unwrap();
                assert_eq!(res.logits.len(), 10);
                ok += 1;
            }
            ok
        }));
    }
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, 100);
    let stats = coord.stats();
    assert_eq!(stats.completed, 100);
    assert!(stats.mean_batch >= 1.0);
}

#[test]
fn batched_results_match_unbatched() {
    let (coord, m) = start(
        CoordinatorConfig {
            workers: 2,
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            queue_depth: 128,
            ..CoordinatorConfig::default()
        },
        8,
    );
    let net = Network::new(tiny_model());
    let samples = synth::tiny_like(55, 0, 32);
    let rxs: Vec<_> = samples
        .iter()
        .map(|s| coord.submit(m, s.image.clone()).unwrap())
        .collect();
    for (rx, s) in rxs.into_iter().zip(&samples) {
        assert_eq!(rx.recv().unwrap().unwrap().logits, net.infer_u8(&s.image));
    }
    coord.shutdown();
}

#[test]
fn chip_engine_reports_simulated_latency() {
    let (reg, m) = ModelRegistry::single(tiny_model());
    let mut engine = ChipEngine::new(HwConfig::default(), reg, 4);
    let samples = synth::tiny_like(2, 0, 3);
    let images: Vec<Vec<u8>> = samples.iter().map(|s| s.image.clone()).collect();
    engine.infer(m, &images).unwrap();
    assert!(engine.simulated_us > 0.0);
}

#[test]
fn stats_percentiles_ordered() {
    let (coord, m) = start(CoordinatorConfig::default(), 8);
    for s in synth::tiny_like(3, 0, 20) {
        coord.infer_blocking(m, s.image).unwrap();
    }
    let stats = coord.shutdown();
    assert!(stats.latency_ms_p50 <= stats.latency_ms_p95);
    assert!(stats.latency_ms_p95 <= stats.latency_ms_p99);
    assert!(stats.throughput_rps > 0.0);
}

/// Engine whose infer() blocks until the test releases a gate — lets the
/// backpressure test freeze the single worker deterministically.
struct GatedEngine {
    gate: std::sync::Arc<(std::sync::Mutex<GateState>, std::sync::Condvar)>,
}

#[derive(Default)]
struct GateState {
    started: usize,
    released: bool,
}

impl InferenceEngine for GatedEngine {
    fn batch_size(&self) -> usize {
        1
    }
    fn infer(&mut self, _model: ModelId, images: &[Vec<u8>]) -> anyhow::Result<Vec<Vec<i64>>> {
        let (lock, cv) = &*self.gate;
        let mut st = lock.lock().unwrap();
        st.started += 1;
        cv.notify_all();
        while !st.released {
            st = cv.wait(st).unwrap();
        }
        Ok(images.iter().map(|_| vec![0i64; 10]).collect())
    }
    fn name(&self) -> &'static str {
        "gated"
    }
}

/// PR3 satellite: submissions beyond `queue_depth` block until the
/// worker drains — the bounded queue is real backpressure, not a drop.
#[test]
fn submit_blocks_at_queue_depth() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::{Condvar, Mutex};

    let gate = Arc::new((Mutex::new(GateState::default()), Condvar::new()));
    let (reg, m) = ModelRegistry::single(tiny_model());
    let coord = Arc::new(Coordinator::start(
        CoordinatorConfig {
            workers: 1,
            max_batch: 1,
            max_wait: Duration::ZERO,
            queue_depth: 2,
            ..CoordinatorConfig::default()
        },
        reg,
        {
            let gate = Arc::clone(&gate);
            move |_| Box::new(GatedEngine { gate: Arc::clone(&gate) }) as Box<dyn InferenceEngine>
        },
    ));

    // First request: wait until the worker is *inside* infer (gated), so
    // exactly queue_depth slots remain.
    let rx0 = coord.submit(m, vec![0u8; 16]).unwrap();
    {
        let (lock, cv) = &*gate;
        let mut st = lock.lock().unwrap();
        while st.started == 0 {
            st = cv.wait(st).unwrap();
        }
    }
    // Fill the queue to its bound; these must not block.
    let mut rxs = vec![rx0];
    for _ in 0..2 {
        rxs.push(coord.submit(m, vec![0u8; 16]).unwrap());
    }
    // One more submission must block until the gate opens.
    let done = Arc::new(AtomicUsize::new(0));
    let handle = {
        let coord = Arc::clone(&coord);
        let done = Arc::clone(&done);
        std::thread::spawn(move || {
            let rx = coord.submit(m, vec![0u8; 16]).unwrap();
            done.store(1, Ordering::SeqCst);
            rx.recv().unwrap().unwrap()
        })
    };
    std::thread::sleep(Duration::from_millis(150));
    assert_eq!(
        done.load(Ordering::SeqCst),
        0,
        "submit #4 must block: queue_depth 2 + 1 in flight are taken"
    );
    // Open the gate: everything drains, including the blocked submitter.
    {
        let (lock, cv) = &*gate;
        lock.lock().unwrap().released = true;
        cv.notify_all();
    }
    let res = handle.join().unwrap();
    assert_eq!(done.load(Ordering::SeqCst), 1);
    assert_eq!(res.logits.len(), 10);
    for rx in rxs {
        assert_eq!(rx.recv().unwrap().unwrap().logits.len(), 10);
    }
    let stats = Arc::try_unwrap(coord).ok().expect("sole owner").shutdown();
    assert_eq!(stats.completed, 4);
}

/// PR3 satellite: a single-request run produces sane percentiles — all
/// three quantiles collapse onto the one sample instead of reading 0.
#[test]
fn single_request_stats_are_sane() {
    let (coord, m) = start(CoordinatorConfig::default(), 8);
    let res = coord.infer_blocking(m, synth::tiny_like(1, 0, 1)[0].image.clone()).unwrap();
    let stats = coord.shutdown();
    assert_eq!(stats.completed, 1);
    assert_eq!(stats.batches, 1);
    assert_eq!(stats.mean_batch, 1.0);
    let lat_ms = res.latency.as_secs_f64() * 1e3;
    assert_eq!(stats.latency_ms_p50, stats.latency_ms_p95);
    assert_eq!(stats.latency_ms_p95, stats.latency_ms_p99);
    assert!(stats.latency_ms_p50 > 0.0, "one sample: p50 is that sample");
    assert!((stats.latency_ms_p50 - lat_ms).abs() < 1e-9);
    assert!(stats.throughput_rps > 0.0);
}

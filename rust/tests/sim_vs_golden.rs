//! Property-based integration: the cycle-accurate simulator is
//! spike-for-spike identical to the golden functional model on randomized
//! networks and inputs, in both simulation modes.

use vsa::arch::{Chip, SimMode};
use vsa::config::HwConfig;
use vsa::snn::Network;
use vsa::testing::models::random_model;
use vsa::testing::{check, Gen};

#[test]
fn fast_sim_matches_golden_on_random_networks() {
    check("fast sim == golden", 20, |g: &mut Gen| {
        let (model, image) = random_model(g);
        let golden = Network::new(model.clone()).infer_u8(&image);
        let report = Chip::new(HwConfig::default(), SimMode::Fast).run(&model, &image);
        assert_eq!(report.logits, golden);
    });
}

#[test]
fn exact_sim_matches_golden_on_random_networks() {
    check("exact sim == golden", 6, |g: &mut Gen| {
        let (model, image) = random_model(g);
        let golden = Network::new(model.clone()).infer_u8(&image);
        let report = Chip::new(HwConfig::default(), SimMode::Exact).run(&model, &image);
        assert_eq!(report.logits, golden);
    });
}

#[test]
fn counters_identical_across_modes() {
    check("mode counters agree", 5, |g: &mut Gen| {
        let (model, image) = random_model(g);
        let fast = Chip::new(HwConfig::default(), SimMode::Fast).run(&model, &image);
        let exact = Chip::new(HwConfig::default(), SimMode::Exact).run(&model, &image);
        assert_eq!(fast.cycles, exact.cycles);
        assert_eq!(fast.pe_ops, exact.pe_ops);
        assert_eq!(fast.dram.total(), exact.dram.total());
        assert_eq!(fast.sram.total(), exact.sram.total());
        assert_eq!(fast.logits, exact.logits);
    });
}

#[test]
fn reconfigurable_across_time_steps() {
    // The same weights run at any T (paper: reconfigurable inference time
    // steps); more steps can only add spikes.
    check("reconfigure T", 10, |g: &mut Gen| {
        let (mut model, image) = random_model(g);
        let chip = Chip::new(HwConfig::default(), SimMode::Fast);
        model.num_steps = 2;
        let r2 = chip.run(&model, &image);
        model.num_steps = 6;
        let r6 = chip.run(&model, &image);
        // logits magnitude grows with T for the same network
        let s2: i64 = r2.logits.iter().map(|x| x.abs()).sum();
        let s6: i64 = r6.logits.iter().map(|x| x.abs()).sum();
        assert!(s6 >= s2 || s2 == 0 || s6 == 0);
        assert!(r6.cycles > r2.cycles);
    });
}

#[test]
fn pe_array_geometry_reconfigures() {
    // Different PE geometries change cycles, never results.
    check("reconfigure geometry", 6, |g: &mut Gen| {
        let (model, image) = random_model(g);
        let base = Chip::new(HwConfig::default(), SimMode::Fast).run(&model, &image);
        let small = Chip::new(
            HwConfig { pe_blocks: 8, rows_per_array: 4, ..HwConfig::default() },
            SimMode::Fast,
        )
        .run(&model, &image);
        assert_eq!(base.logits, small.logits);
        assert!(small.cycles > base.cycles, "fewer PEs must cost cycles");
    });
}

#[test]
fn table3_design_point_calibration() {
    // The energy/area model must reproduce the paper's Table III design
    // point on the CIFAR-10 workload (requires `make artifacts`).
    let Ok(net) = Network::from_vsaw_file("artifacts/cifar10_t8.vsaw") else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let hw = HwConfig::default();
    let img = &vsa::data::synth::cifar_like(7, 0, 1)[0].image;
    let r = Chip::new(hw.clone(), SimMode::Fast).run(&net.model, img);

    let mw = vsa::energy::power::core_power_mw(&hw, &r);
    assert!((mw - 88.968).abs() / 88.968 < 0.02, "core power {mw} vs 88.968");

    let eff = vsa::energy::power::power_efficiency_tops_w(&hw, mw);
    assert!((eff - 25.9).abs() / 25.9 < 0.03, "power eff {eff} vs 25.9");

    let kge = vsa::energy::area::logic_area(&hw).total();
    assert!((kge - 114.98).abs() / 114.98 < 0.02, "area {kge} vs 114.98");

    // throughput: peak exact, achieved utilization high on CIFAR-10
    assert_eq!(hw.total_pes(), 2304);
    assert!(r.utilization > 0.85, "utilization {}", r.utilization);
}

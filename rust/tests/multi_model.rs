//! PR9 acceptance: a coordinator loaded with two models on a mixed
//! golden + chip-sim pool serves an interleaved workload under seeded
//! fault injection with **zero cross-model contamination** — every
//! completed request returns logits bit-identical to its own model's
//! golden reference — while the LRU cache counters balance, per-model
//! latency sketches land in the exported snapshot, and the accounting
//! invariant (`completed + failed + shed == submitted`) holds with no
//! hangs.

use std::sync::Arc;
use std::time::Duration;
use vsa::config::models;
use vsa::config::HwConfig;
use vsa::coordinator::{
    parse_pool, ChipEngine, Coordinator, CoordinatorConfig, EngineKind, FaultEngine, FaultProfile,
    GoldenEngine, InferenceEngine, ModelRegistry, ServeError,
};
use vsa::data::synth;
use vsa::snn::params::DeployedModel;
use vsa::snn::Network;
use vsa::telemetry::Registry;

const RECV_PATIENCE: Duration = Duration::from_secs(30);

#[test]
fn mixed_pool_serves_two_models_without_contamination_under_chaos() {
    const REQUESTS: usize = 64;

    // Two same-geometry models with different weights: identical images
    // are valid for both, so only correct (model, logits) pairing can
    // satisfy the bit-exactness asserts below.
    let model_a = DeployedModel::synthesize(&models::tiny(2), 0xA);
    let model_b = DeployedModel::synthesize(&models::tiny(2), 0xB);
    let images: Vec<Vec<u8>> = synth::tiny_like(5, 0, 8).into_iter().map(|s| s.image).collect();
    let ref_a = Network::new(model_a.clone());
    let ref_b = Network::new(model_b.clone());
    let want_a: Vec<Vec<i64>> = images.iter().map(|i| ref_a.infer_u8(i)).collect();
    let want_b: Vec<Vec<i64>> = images.iter().map(|i| ref_b.infer_u8(i)).collect();
    assert_ne!(want_a, want_b, "models must be distinguishable or the check proves nothing");

    let mut registry = ModelRegistry::new();
    let a = registry.register("alpha", model_a).unwrap();
    let b = registry.register("beta", model_b).unwrap();
    let registry = Arc::new(registry);

    // Heterogeneous pool from the CLI spec grammar, every engine wrapped
    // in a seeded FaultEngine (errors + panics + latency spikes).
    let pool = parse_pool("golden:3,chip-sim:1").unwrap();
    assert_eq!(pool.len(), 4);
    assert_eq!(pool.iter().filter(|&&k| k == EngineKind::Golden).count(), 3);
    assert_eq!(pool.iter().filter(|&&k| k == EngineKind::ChipSim).count(), 1);
    let cfg = CoordinatorConfig {
        workers: pool.len(),
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        queue_depth: 32,
        max_retries: 2,
        retry_backoff: Duration::from_micros(100),
        restart_budget: 10_000,
        ..CoordinatorConfig::default()
    };
    let regc = Arc::clone(&registry);
    let mut coord = Coordinator::start(cfg, Arc::clone(&registry), move |w| {
        let inner: Box<dyn InferenceEngine> = match pool[w] {
            EngineKind::Golden => Box::new(GoldenEngine::new(Arc::clone(&regc), 4)),
            EngineKind::ChipSim => {
                Box::new(ChipEngine::new(HwConfig::default(), Arc::clone(&regc), 4))
            }
        };
        let profile = FaultProfile::mixed(0.10, Duration::from_millis(2));
        Box::new(FaultEngine::new(inner, profile, FaultEngine::seed_for(0xC0FFEE, w)))
    });

    // Strictly interleaved traffic: even requests hit alpha, odd hit
    // beta, so co-arriving neighbours always name different models and
    // any batch that ignored the partition key would cross the streams.
    let mut rxs = Vec::new();
    let mut submit_rejects = 0u64;
    for i in 0..REQUESTS {
        let model = if i % 2 == 0 { a } else { b };
        match coord.submit(model, images[i % images.len()].clone()) {
            Ok(rx) => rxs.push((i, rx)),
            Err(ServeError::Rejected(_)) => submit_rejects += 1,
            Err(e) => panic!("submit must reject typed, got {e:?}"),
        }
    }
    let accepted = rxs.len() as u64;

    let (mut ok, mut failed, mut shed) = (0u64, 0u64, 0u64);
    for (i, rx) in rxs {
        match rx.recv_timeout(RECV_PATIENCE).expect("no terminal outcome — request hung") {
            Ok(res) => {
                let want = if i % 2 == 0 { &want_a } else { &want_b };
                assert_eq!(res.logits, want[i % images.len()], "request {i}: wrong model's logits");
                ok += 1;
            }
            Err(ServeError::Rejected(_)) => shed += 1,
            Err(_) => failed += 1,
        }
    }

    // Quiesce, then check the mirrored LRU counters and the export.
    coord.drain();
    let cache = coord.cache_totals();
    assert!(cache.lookups > 0, "engines ran at least one batch");
    assert_eq!(cache.hits + cache.misses, cache.lookups, "cache counters balance");
    assert_eq!(cache.packs, cache.misses, "every miss packs exactly once");

    let treg = Registry::new();
    coord.export_into(&treg, "serve");
    let snap = treg.snapshot();
    assert!(snap.sketches.contains_key("serve.model.alpha.latency"), "per-model sketch");
    assert!(snap.sketches.contains_key("serve.model.beta.latency"), "per-model sketch");
    assert_eq!(
        snap.counters["serve.model.alpha.completed"] + snap.counters["serve.model.beta.completed"],
        ok,
        "per-model completions sum to the client-side tally"
    );
    assert_eq!(snap.counters["serve.backend.golden.workers"], 3);
    assert_eq!(snap.counters["serve.backend.chip-sim.workers"], 1);
    assert_eq!(snap.counters["serve.model_cache.lookups"], cache.lookups);

    let stats = coord.shutdown();
    assert_eq!(accepted + submit_rejects, REQUESTS as u64, "all requests accounted");
    assert_eq!(stats.submitted, accepted);
    assert_eq!(stats.completed, ok);
    assert_eq!(stats.failed, failed);
    assert_eq!(stats.shed, shed);
    assert_eq!(stats.completed + stats.failed + stats.shed, stats.submitted, "counters balance");
}

//! PR6 chaos suite: the coordinator under seeded fault injection.
//!
//! The property, for every seeded fault profile: each submitted request
//! terminates with an `InferResult` or a typed `ServeError` — zero
//! hangs, zero lost requests — the stats counters balance
//! (`completed + failed + shed == submitted`), and every request that
//! does complete returns logits bit-identical to a fault-free run on
//! the same image.  Fault schedules come from `FaultEngine`'s SplitMix64
//! stream, so each (profile, seed) test replays the same faults every
//! run.  CI pins three fixed base seeds: 7, 0xBEEF, 0xC0FFEE.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};
use vsa::config::models;
use vsa::coordinator::{
    Coordinator, CoordinatorConfig, FaultEngine, FaultProfile, FaultStats, GoldenEngine,
    InferenceEngine, ModelId, ModelRegistry, RejectReason, ServeError,
};
use vsa::data::synth;
use vsa::snn::params::DeployedModel;
use vsa::snn::Network;

fn tiny_model() -> DeployedModel {
    DeployedModel::synthesize(&models::tiny(2), 42)
}

const RECV_PATIENCE: Duration = Duration::from_secs(30);

/// Drive one seeded chaos run and assert the liveness + accounting +
/// bit-exactness property.
fn chaos_run(label: &str, profile: FaultProfile, seed: u64, deadline: Option<Duration>) {
    const REQUESTS: usize = 48;
    let reference = Network::new(tiny_model());
    let samples = synth::tiny_like(seed, 0, 16);
    let images: Vec<Vec<u8>> = samples.into_iter().map(|s| s.image).collect();
    let expected: Vec<Vec<i64>> = images.iter().map(|i| reference.infer_u8(i)).collect();

    let fstats = Arc::new(FaultStats::default());
    let (reg, m) = ModelRegistry::single(tiny_model());
    let regc = Arc::clone(&reg);
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 2,
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            queue_depth: 16,
            deadline,
            max_retries: 2,
            retry_backoff: Duration::from_micros(100),
            restart_budget: 10_000,
        },
        reg,
        {
            let fstats = Arc::clone(&fstats);
            move |w| {
                let inner = Box::new(GoldenEngine::new(Arc::clone(&regc), 4));
                let seed_w = FaultEngine::seed_for(seed, w);
                let fe = FaultEngine::with_stats(inner, profile, seed_w, Arc::clone(&fstats));
                Box::new(fe) as Box<dyn InferenceEngine>
            }
        },
    );

    // Mixed submission modes: blocking, bounded-wait, fail-fast.
    let mut rxs = Vec::new();
    let mut submit_rejects = 0u64;
    for i in 0..REQUESTS {
        let img = images[i % images.len()].clone();
        let sub = match i % 3 {
            0 => coord.submit(m, img),
            1 => coord.submit_timeout(m, img, Duration::from_millis(200)),
            _ => coord.try_submit(m, img),
        };
        match sub {
            Ok(rx) => rxs.push((i, rx)),
            Err(ServeError::Rejected(_)) => submit_rejects += 1,
            Err(e) => panic!("{label}: submit must reject typed, got {e:?}"),
        }
    }
    let accepted = rxs.len() as u64;

    let (mut ok, mut failed, mut shed) = (0u64, 0u64, 0u64);
    for (i, rx) in rxs {
        match rx.recv_timeout(RECV_PATIENCE) {
            Ok(Ok(res)) => {
                assert_eq!(
                    res.logits,
                    expected[i % expected.len()],
                    "{label}: completed request {i} must be bit-identical to fault-free"
                );
                ok += 1;
            }
            Ok(Err(ServeError::Rejected(_))) => shed += 1,
            Ok(Err(_)) => failed += 1,
            Err(_) => panic!("{label}: request {i} hung — no terminal outcome"),
        }
    }

    let stats = coord.shutdown();
    assert_eq!(accepted + submit_rejects, REQUESTS as u64, "{label}: all accounted");
    assert_eq!(stats.submitted, accepted, "{label}: submitted == accepted");
    assert_eq!(stats.completed, ok, "{label}: completed counter");
    assert_eq!(stats.failed, failed, "{label}: failed counter");
    assert_eq!(stats.shed, shed, "{label}: shed counter");
    assert_eq!(
        stats.completed + stats.failed + stats.shed,
        stats.submitted,
        "{label}: counters balance"
    );
}

#[test]
fn chaos_clean_zero_faults() {
    chaos_run("clean", FaultProfile::clean(), 7, None);
}

#[test]
fn chaos_errors_1pct() {
    chaos_run("errors-1%", FaultProfile::errors(0.01), 7, None);
}

#[test]
fn chaos_errors_10pct() {
    chaos_run("errors-10%", FaultProfile::errors(0.10), 0xBEEF, None);
}

#[test]
fn chaos_errors_50pct() {
    chaos_run("errors-50%", FaultProfile::errors(0.50), 0xC0FFEE, None);
}

#[test]
fn chaos_panics_1pct() {
    chaos_run("panics-1%", FaultProfile::panics(0.01), 7, None);
}

#[test]
fn chaos_panics_10pct() {
    chaos_run("panics-10%", FaultProfile::panics(0.10), 0xBEEF, None);
}

#[test]
fn chaos_panics_50pct() {
    chaos_run("panics-50%", FaultProfile::panics(0.50), 0xC0FFEE, None);
}

#[test]
fn chaos_spikes_1pct() {
    let p = FaultProfile::spikes(0.01, Duration::from_millis(40));
    chaos_run("spikes-1%", p, 7, Some(Duration::from_millis(25)));
}

#[test]
fn chaos_spikes_10pct() {
    let p = FaultProfile::spikes(0.10, Duration::from_millis(40));
    chaos_run("spikes-10%", p, 0xBEEF, Some(Duration::from_millis(25)));
}

#[test]
fn chaos_spikes_50pct() {
    let p = FaultProfile::spikes(0.50, Duration::from_millis(40));
    chaos_run("spikes-50%", p, 0xC0FFEE, Some(Duration::from_millis(25)));
}

#[test]
fn chaos_mixed_10pct_all_seeds() {
    for seed in [7u64, 0xBEEF, 0xC0FFEE] {
        let p = FaultProfile::mixed(0.10, Duration::from_millis(5));
        chaos_run("mixed-10%", p, seed, None);
    }
}

// ---------------------------------------------------------------------
// Deterministic edge cases (gated / scripted engines)
// ---------------------------------------------------------------------

/// One-model registry for the scripted-engine tests (the engines ignore
/// the model — they are batching/accounting probes).
fn single() -> (Arc<ModelRegistry>, ModelId) {
    ModelRegistry::single(tiny_model())
}

/// Engine whose infer() blocks until the test releases a gate — the
/// PR3 edge-case pattern for freezing a single worker deterministically.
struct GatedEngine {
    gate: Arc<(Mutex<GateState>, Condvar)>,
}

#[derive(Default)]
struct GateState {
    started: usize,
    released: bool,
}

impl InferenceEngine for GatedEngine {
    fn batch_size(&self) -> usize {
        1
    }
    fn infer(&mut self, _model: ModelId, images: &[Vec<u8>]) -> anyhow::Result<Vec<Vec<i64>>> {
        let (lock, cv) = &*self.gate;
        let mut st = lock.lock().unwrap();
        st.started += 1;
        cv.notify_all();
        while !st.released {
            st = cv.wait(st).unwrap();
        }
        Ok(images.iter().map(|_| vec![0i64; 10]).collect())
    }
    fn name(&self) -> &'static str {
        "gated"
    }
}

fn new_gate() -> Arc<(Mutex<GateState>, Condvar)> {
    Arc::new((Mutex::new(GateState::default()), Condvar::new()))
}

fn wait_started(gate: &Arc<(Mutex<GateState>, Condvar)>, n: usize) {
    let (lock, cv) = &**gate;
    let mut st = lock.lock().unwrap();
    while st.started < n {
        st = cv.wait(st).unwrap();
    }
}

fn release(gate: &Arc<(Mutex<GateState>, Condvar)>) {
    let (lock, cv) = &**gate;
    lock.lock().unwrap().released = true;
    cv.notify_all();
}

/// A request that expires while *queued* is shed with
/// `Rejected(Deadline)` at dequeue; one already inside the engine when
/// its deadline passes still completes (deadlines gate dispatch, they
/// do not abort in-flight work).
#[test]
fn deadline_expiry_sheds_queued_requests() {
    let gate = new_gate();
    let (reg, m) = single();
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 1,
            max_batch: 1,
            max_wait: Duration::ZERO,
            queue_depth: 8,
            deadline: Some(Duration::from_millis(40)),
            max_retries: 0,
            ..CoordinatorConfig::default()
        },
        reg,
        {
            let gate = Arc::clone(&gate);
            move |_| Box::new(GatedEngine { gate: Arc::clone(&gate) }) as Box<dyn InferenceEngine>
        },
    );
    let rx0 = coord.submit(m, vec![0u8; 16]).unwrap();
    wait_started(&gate, 1); // r0 is inside infer, holding the worker
    let rx1 = coord.submit(m, vec![0u8; 16]).unwrap(); // r1 waits in queue
    std::thread::sleep(Duration::from_millis(80)); // r1's deadline passes
    release(&gate);
    let r0 = rx0.recv_timeout(RECV_PATIENCE).unwrap();
    assert!(r0.is_ok(), "in-flight request completes past its deadline: {r0:?}");
    match rx1.recv_timeout(RECV_PATIENCE).unwrap() {
        Err(ServeError::Rejected(RejectReason::Deadline)) => {}
        other => panic!("queued-expired request must shed, got {other:?}"),
    }
    let stats = coord.shutdown();
    assert_eq!(stats.submitted, 2);
    assert_eq!(stats.completed, 1);
    assert_eq!(stats.shed, 1);
    assert_eq!(stats.completed + stats.failed + stats.shed, stats.submitted);
}

/// `try_submit` sheds immediately on a full queue; `submit_timeout`
/// waits its bounded patience first.  Neither counts as submitted.
#[test]
fn queue_full_shedding_fast_and_bounded() {
    let gate = new_gate();
    let (reg, m) = single();
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 1,
            max_batch: 1,
            max_wait: Duration::ZERO,
            queue_depth: 1,
            ..CoordinatorConfig::default()
        },
        reg,
        {
            let gate = Arc::clone(&gate);
            move |_| Box::new(GatedEngine { gate: Arc::clone(&gate) }) as Box<dyn InferenceEngine>
        },
    );
    let rx0 = coord.submit(m, vec![0u8; 16]).unwrap();
    wait_started(&gate, 1); // worker busy; exactly one queue slot left
    let rx1 = coord.submit(m, vec![0u8; 16]).unwrap(); // fills the queue
    match coord.try_submit(m, vec![0u8; 16]) {
        Err(ServeError::Rejected(RejectReason::QueueFull)) => {}
        other => panic!("try_submit on a full queue must shed, got {other:?}"),
    }
    let t0 = Instant::now();
    match coord.submit_timeout(m, vec![0u8; 16], Duration::from_millis(60)) {
        Err(ServeError::Rejected(RejectReason::QueueFull)) => {}
        other => panic!("submit_timeout must shed after its wait, got {other:?}"),
    }
    assert!(t0.elapsed() >= Duration::from_millis(50), "bounded wait was honored");
    release(&gate);
    assert!(rx0.recv_timeout(RECV_PATIENCE).unwrap().is_ok());
    assert!(rx1.recv_timeout(RECV_PATIENCE).unwrap().is_ok());
    let stats = coord.shutdown();
    assert_eq!(stats.submitted, 2, "shed-at-submit requests are not 'submitted'");
    assert_eq!(stats.completed, 2);
    assert_eq!(stats.shed, 0);
}

/// Panics on the first call of the pool's lifetime (shared counter),
/// then behaves: exercises respawn + retry recovery.
struct PanicOnceEngine {
    calls: Arc<AtomicU64>,
}

impl InferenceEngine for PanicOnceEngine {
    fn batch_size(&self) -> usize {
        1
    }
    fn infer(&mut self, _model: ModelId, images: &[Vec<u8>]) -> anyhow::Result<Vec<Vec<i64>>> {
        if self.calls.fetch_add(1, Ordering::SeqCst) == 0 {
            panic!("scripted first-call panic");
        }
        Ok(images.iter().map(|i| vec![i[0] as i64; 10]).collect())
    }
    fn name(&self) -> &'static str {
        "panic-once"
    }
}

#[test]
fn panic_respawns_engine_and_retry_recovers() {
    let calls = Arc::new(AtomicU64::new(0));
    let (reg, m) = single();
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 1,
            max_batch: 1,
            max_wait: Duration::ZERO,
            queue_depth: 8,
            max_retries: 2,
            retry_backoff: Duration::ZERO,
            restart_budget: 4,
            ..CoordinatorConfig::default()
        },
        reg,
        {
            let calls = Arc::clone(&calls);
            move |_| -> Box<dyn InferenceEngine> {
                Box::new(PanicOnceEngine { calls: Arc::clone(&calls) })
            }
        },
    );
    let res = coord.infer_blocking(m, vec![5u8; 16]).expect("retry after respawn succeeds");
    assert_eq!(res.logits, vec![5i64; 10]);
    let stats = coord.shutdown();
    assert_eq!(stats.completed, 1);
    assert_eq!(stats.failed, 0);
    assert_eq!(stats.worker_restarts, 1, "exactly one respawn");
    assert_eq!(stats.retries, 1, "exactly one retry");
    assert_eq!(stats.alive_workers, 1, "pool fully recovered");
}

/// Always panics: with a zero restart budget the lone worker goes dark
/// after the first attempt.  The first request fails typed, everything
/// already queued is shed, new submissions fail fast, and shutdown
/// still drains without deadlocking.
struct AlwaysPanicEngine;

impl InferenceEngine for AlwaysPanicEngine {
    fn batch_size(&self) -> usize {
        1
    }
    fn infer(&mut self, _model: ModelId, _images: &[Vec<u8>]) -> anyhow::Result<Vec<Vec<i64>>> {
        panic!("scripted permanent panic");
    }
    fn name(&self) -> &'static str {
        "always-panic"
    }
}

#[test]
fn dead_pool_rejects_new_submits_and_drains() {
    let (reg, m) = single();
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 1,
            max_batch: 1,
            max_wait: Duration::ZERO,
            queue_depth: 8,
            max_retries: 0,
            restart_budget: 0,
            ..CoordinatorConfig::default()
        },
        reg,
        |_| Box::new(AlwaysPanicEngine),
    );
    let rx0 = coord.submit(m, vec![0u8; 16]).unwrap();
    // Race-tolerant: these are either queued then shed by the dark
    // worker, or rejected at submit once the pool registers dead —
    // both are Rejected(Shutdown)-shaped outcomes.
    let mut shutdown_rejects = 0;
    for _ in 0..4 {
        match coord.submit(m, vec![0u8; 16]) {
            Ok(rx) => match rx.recv_timeout(RECV_PATIENCE).unwrap() {
                Err(ServeError::Rejected(RejectReason::Shutdown)) => shutdown_rejects += 1,
                other => panic!("queued request on a dead pool must shed, got {other:?}"),
            },
            Err(ServeError::Rejected(RejectReason::Shutdown)) => shutdown_rejects += 1,
            other => panic!("submit on a dead pool must reject, got {other:?}"),
        }
    }
    assert_eq!(shutdown_rejects, 4);
    match rx0.recv_timeout(RECV_PATIENCE).unwrap() {
        Err(ServeError::WorkerPanicked) => {}
        other => panic!("first request sees the panic typed, got {other:?}"),
    }
    // The pool must register fully dark, then fail fast.
    let t0 = Instant::now();
    while coord.stats().alive_workers > 0 {
        assert!(t0.elapsed() < Duration::from_secs(5), "worker never went dark");
        std::thread::sleep(Duration::from_millis(1));
    }
    assert!(matches!(
        coord.submit(m, vec![0u8; 16]),
        Err(ServeError::Rejected(RejectReason::Shutdown))
    ));
    assert!(matches!(
        coord.try_submit(m, vec![0u8; 16]),
        Err(ServeError::Rejected(RejectReason::Shutdown))
    ));
    let stats = coord.shutdown(); // must not deadlock
    assert_eq!(stats.failed, 1);
    assert_eq!(stats.completed, 0);
    assert_eq!(stats.worker_restarts, 0);
    assert_eq!(stats.alive_workers, 0);
    assert_eq!(stats.completed + stats.failed + stats.shed, stats.submitted);
}

/// Fails any batch containing a poisoned image, succeeds otherwise:
/// after the shared failure the batch is split, so batchmates complete
/// and only the poisoned request returns `EngineFailed`.
struct PoisonEngine;

impl InferenceEngine for PoisonEngine {
    fn batch_size(&self) -> usize {
        8
    }
    fn infer(&mut self, _model: ModelId, images: &[Vec<u8>]) -> anyhow::Result<Vec<Vec<i64>>> {
        if images.iter().any(|i| i[0] == 255) {
            anyhow::bail!("poisoned image in batch");
        }
        Ok(images.iter().map(|i| vec![i[0] as i64; 10]).collect())
    }
    fn name(&self) -> &'static str {
        "poison"
    }
}

#[test]
fn poisoned_image_cannot_sink_batchmates() {
    let (reg, m) = single();
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 1,
            max_batch: 8,
            // Wide batching window so the four submits co-batch.
            max_wait: Duration::from_millis(200),
            queue_depth: 16,
            max_retries: 1,
            retry_backoff: Duration::ZERO,
            ..CoordinatorConfig::default()
        },
        reg,
        |_| Box::new(PoisonEngine),
    );
    let rx_bad = coord.submit(m, vec![255u8; 16]).unwrap();
    let pixels = [10u8, 20, 30];
    let rx_good: Vec<_> = pixels.iter().map(|&p| coord.submit(m, vec![p; 16]).unwrap()).collect();
    match rx_bad.recv_timeout(RECV_PATIENCE).unwrap() {
        Err(ServeError::EngineFailed { attempts, cause }) => {
            assert_eq!(attempts, 2, "1 shared batch attempt + 1 solo retry");
            assert!(cause.contains("poisoned"), "cause survives: {cause}");
        }
        other => panic!("poisoned request must fail typed, got {other:?}"),
    }
    for (rx, p) in rx_good.iter().zip(pixels) {
        let res = rx.recv_timeout(RECV_PATIENCE).unwrap().unwrap();
        assert_eq!(res.logits, vec![p as i64; 10], "batchmate survives the poison");
    }
    let stats = coord.shutdown();
    assert_eq!(stats.completed, 3);
    assert_eq!(stats.failed, 1);
    assert!(stats.retries >= 1);
    assert_eq!(stats.completed + stats.failed + stats.shed, stats.submitted);
}

//! Property-based coverage of the time-batched inference hot path (PR1):
//!
//! * `PackedConv::conv_t` against the dense `conv_naive` oracle across
//!   word-boundary channel counts, kernel sizes and time steps;
//! * `PackedFc::matvec_t` against a dense dot-product oracle;
//! * the fused conv→IF→maxpool network path bit-exact against the frozen
//!   pre-refactor per-step engine (`baselines::golden_stepwise`) and the
//!   cycle-accurate chip simulator (`engines_agree`-style);
//! * scratch-arena reuse across different model geometries.

use vsa::arch::{Chip, SimMode};
use vsa::baselines::golden_stepwise::StepwiseGolden;
use vsa::config::models;
use vsa::coordinator::{ChipEngine, GoldenEngine, InferenceEngine, ModelRegistry};
use vsa::config::HwConfig;
use vsa::data::synth;
use vsa::snn::conv::{conv_naive, PackedConv, PackedFc};
use vsa::snn::params::{DeployedModel, Kind, Layer};
use vsa::snn::{Network, Scratch, SpikeMap};
use vsa::testing::{check, Gen};
use vsa::util::FIXED_POINT;

fn random_train(g: &mut Gen, t: usize, c: usize, h: usize, w: usize) -> Vec<SpikeMap> {
    (0..t)
        .map(|_| {
            let mut m = SpikeMap::zeros(c, h, w);
            for ch in 0..c {
                for y in 0..h {
                    for x in 0..w {
                        m.set(ch, y, x, g.bool());
                    }
                }
            }
            m
        })
        .collect()
}

/// conv_t == conv_naive per step, across odd channel counts (word
/// boundaries at 63/64/65/130), k in {1, 3, 5}, T in {1, 4, 8}.
#[test]
fn conv_t_matches_naive_across_geometries() {
    let mut scratch = Scratch::new(); // shared across cases: exercises reuse
    for &c_in in &[1usize, 63, 64, 65, 130] {
        for &k in &[1usize, 3, 5] {
            for &t in &[1usize, 4, 8] {
                let mut g = Gen::new((c_in * 1000 + k * 10 + t) as u64);
                let c_out = 1 + (c_in + k + t) % 4;
                let hw = 5 + (k + t) % 3;
                let weights = g.weights(c_out * c_in * k * k);
                let train = random_train(&mut g, t, c_in, hw, hw);
                let packed = PackedConv::pack(c_out, c_in, k, &weights);
                packed.conv_t(&train, &mut scratch);
                let plane = c_out * hw * hw;
                for (ti, s) in train.iter().enumerate() {
                    let naive =
                        conv_naive(&s.to_dense(), c_in, hw, hw, &weights, c_out, k);
                    assert_eq!(
                        &scratch.psums()[ti * plane..(ti + 1) * plane],
                        &naive[..],
                        "c_in={c_in} k={k} T={t} step={ti}"
                    );
                }
            }
        }
    }
}

/// matvec_t == dense dot product per step across word boundaries.
#[test]
fn matvec_t_matches_naive() {
    for &n_in in &[1usize, 63, 64, 65, 130, 1000] {
        for &t in &[1usize, 4, 8] {
            let mut g = Gen::new((n_in * 17 + t) as u64);
            let n_out = 1 + (n_in + t) % 7;
            let w = g.weights(n_out * n_in);
            let packed = PackedFc::pack(n_out, n_in, &w);
            let words = packed.words();
            let dense: Vec<Vec<u8>> =
                (0..t).map(|_| g.spikes(n_in, 40)).collect();
            let mut flat = vec![0u64; t * words];
            for (ti, step) in dense.iter().enumerate() {
                for (i, &s) in step.iter().enumerate() {
                    if s == 1 {
                        flat[ti * words + i / 64] |= 1u64 << (i % 64);
                    }
                }
            }
            let mut out = vec![0i32; t * n_out];
            packed.matvec_t(&flat, t, &mut out);
            for (ti, step) in dense.iter().enumerate() {
                for o in 0..n_out {
                    let want: i32 = (0..n_in)
                        .map(|i| step[i] as i32 * w[o * n_in + i] as i32)
                        .sum();
                    assert_eq!(
                        out[ti * n_out + o],
                        want,
                        "n_in={n_in} T={t} step={ti} o={o}"
                    );
                }
            }
        }
    }
}

/// Build a random small network: enc conv -> [pool] -> conv -> [pool] ->
/// fc -> readout, mirroring sim_vs_golden's generator but always forcing
/// at least one pooled conv so the fused path is exercised.
fn random_model(g: &mut Gen) -> (DeployedModel, Vec<u8>) {
    let in_size = *g.choose(&[8usize, 12, 16]);
    let c1 = *g.choose(&[4usize, 8, 16]);
    let c2 = *g.choose(&[4usize, 8, 33]);
    let t = g.usize_in(1, 6);
    let pool2 = g.bool();
    let mid = in_size / 2; // enc layer always pooled
    let end = if pool2 { mid / 2 } else { mid };
    let n_fc = g.usize_in(4, 12);

    let mut layers = vec![
        Layer::Conv {
            kind: Kind::EncConv,
            c_out: c1,
            c_in: 1,
            k: 3,
            w: g.weights(c1 * 9),
            bias: (0..c1).map(|_| g.i32_in(-500, 500) * FIXED_POINT / 4).collect(),
            theta: (0..c1).map(|_| g.i32_in(1, 300) * FIXED_POINT).collect(),
        },
        Layer::MaxPool,
        Layer::Conv {
            kind: Kind::Conv,
            c_out: c2,
            c_in: c1,
            k: 3,
            w: g.weights(c2 * c1 * 9),
            bias: (0..c2).map(|_| g.i32_in(-4, 4) * FIXED_POINT).collect(),
            theta: (0..c2).map(|_| g.i32_in(1, 12) * FIXED_POINT).collect(),
        },
    ];
    if pool2 {
        layers.push(Layer::MaxPool);
    }
    layers.push(Layer::Fc {
        n_out: n_fc,
        n_in: c2 * end * end,
        w: g.weights(n_fc * c2 * end * end),
        bias: (0..n_fc).map(|_| g.i32_in(-2, 2) * FIXED_POINT).collect(),
        theta: (0..n_fc).map(|_| g.i32_in(1, 6) * FIXED_POINT).collect(),
    });
    layers.push(Layer::Readout {
        n_out: 10,
        n_in: n_fc,
        w: g.weights(10 * n_fc),
    });

    let model = DeployedModel {
        name: "prop".into(),
        num_steps: t,
        in_channels: 1,
        in_size,
        layers,
    };
    let image: Vec<u8> =
        (0..in_size * in_size).map(|_| g.i32_in(0, 255) as u8).collect();
    (model, image)
}

/// The fused conv→IF→pool path is bit-exact with the unfused pre-refactor
/// engine on randomized pooled networks.
#[test]
fn fused_pool_path_matches_stepwise_oracle() {
    let mut scratch = Scratch::new();
    check("fused pool == stepwise", 25, |g: &mut Gen| {
        let (model, image) = random_model(g);
        let fused = Network::new(model.clone());
        let oracle = StepwiseGolden::new(model);
        assert_eq!(fused.infer_u8_with(&image, &mut scratch), oracle.infer_u8(&image));
    });
}

/// Traced inference (which disables fusion to expose pre-pool trains)
/// produces the same logits as the fused fast path.
#[test]
fn traced_unfused_matches_fused() {
    check("traced == fused", 10, |g: &mut Gen| {
        let (model, image) = random_model(g);
        let net = Network::new(model);
        let fast = net.infer_u8(&image);
        let (traced, trace) = net.infer_traced(&image);
        assert_eq!(fast, traced);
        // enc, pool, conv, [pool], fc emit trains; readout does not
        assert!(trace.spike_trains.len() >= 4);
        // every firing layer leaves a residue
        assert_eq!(trace.residues.len(), 3);
    });
}

/// `engines_agree`-style: the golden engine (with scratch reuse across a
/// batch) and the chip-sim engine produce identical logits.
#[test]
fn golden_and_chip_engines_agree_on_synth_models() {
    for (name, t) in [("tiny", 4), ("mnist", 2)] {
        let spec = models::by_name(name, t).unwrap();
        let model = DeployedModel::synthesize(&spec, 13);
        let images: Vec<Vec<u8>> = synth::for_model(name, 9, 0, 3)
            .into_iter()
            .map(|s| s.image)
            .collect();
        let (reg, m) = ModelRegistry::single(model);
        let mut golden = GoldenEngine::new(std::sync::Arc::clone(&reg), 4);
        let mut chip = ChipEngine::new(HwConfig::default(), reg, 4);
        assert_eq!(
            golden.infer(m, &images).unwrap(),
            chip.infer(m, &images).unwrap(),
            "{name}: golden != chip-sim"
        );
    }
}

/// One scratch arena survives alternating between models of different
/// geometry (the serving worker's reconfiguration scenario).
#[test]
fn scratch_survives_model_reconfiguration() {
    let tiny = Network::new(DeployedModel::synthesize(&models::tiny(4), 3));
    let mnist = Network::new(DeployedModel::synthesize(&models::mnist(2), 3));
    let tiny_img = &synth::tiny_like(1, 0, 1)[0].image;
    let mnist_img = &synth::mnist_like(1, 0, 1)[0].image;
    let want_tiny = tiny.infer_u8(tiny_img);
    let want_mnist = mnist.infer_u8(mnist_img);
    let mut scratch = Scratch::new();
    for _ in 0..3 {
        assert_eq!(tiny.infer_u8_with(tiny_img, &mut scratch), want_tiny);
        assert_eq!(mnist.infer_u8_with(mnist_img, &mut scratch), want_mnist);
    }
}

/// Golden vs chip-sim on the randomized pooled models too (the fused path
/// must agree with the hardware schedule, not just the oracle).
#[test]
fn fused_path_matches_chip_sim() {
    check("fused == chip sim", 10, |g: &mut Gen| {
        let (model, image) = random_model(g);
        let golden = Network::new(model.clone()).infer_u8(&image);
        let report = Chip::new(HwConfig::default(), SimMode::Fast).run(&model, &image);
        assert_eq!(report.logits, golden);
    });
}

//! PR4 trainer acceptance: the rebuilt hot path against the frozen PR3
//! scalar baseline, and byte-determinism at every `--threads`.
//!
//! * the PR4 forward (blocked kernels, broadcast enc psums, cached
//!   binarized weights, sharded BN) is **bit-exact** against
//!   `baselines::stbp_scalar` — logit for logit, spike for spike;
//! * forward + backward produce identical bytes for every thread count
//!   (fixed shard partition + fixed-order gradient reductions);
//! * an end-to-end `train()` exports byte-identical artifacts at
//!   `--threads 1` and `--threads 4` (the CLI-level twin runs in CI and
//!   `cmp`s the release binary's artifacts).

use vsa::baselines::stbp_scalar;
use vsa::config::models;
use vsa::data::synth;
use vsa::train::{self, tensor, Net, SpikeMode};

/// Load a synthetic batch for `spec` as (images/255, labels).
fn batch_for(spec: &models::ModelSpec, seed: u64, count: usize) -> (Vec<f32>, Vec<usize>) {
    let samples = synth::batch(seed, 0, count, spec.in_channels, spec.in_size);
    let plane = spec.in_channels * spec.in_size * spec.in_size;
    let mut images = vec![0.0f32; count * plane];
    let mut labels = vec![0usize; count];
    for (r, s) in samples.iter().enumerate() {
        for (dst, &px) in images[r * plane..(r + 1) * plane].iter_mut().zip(&s.image) {
            *dst = px as f32 / 255.0;
        }
        labels[r] = s.label;
    }
    (images, labels)
}

/// The PR4 forward must reproduce the frozen PR3 scalar forward bit for
/// bit: the kernel blocking only interleaves independent outputs, the
/// broadcast-psum encoding IF reads the same values the T copies held,
/// and the cached binarized weights are the same `sign_vec` the
/// baseline recomputes.  Checked on specs covering every layer kind, at
/// several thread counts.
#[test]
fn forward_is_bit_exact_against_frozen_pr3_scalar() {
    for (spec, batch) in [(models::tiny(3), 4), (models::micro(4), 6)] {
        let net = Net::init(&spec, 23);
        let (images, _) = batch_for(&spec, 23, batch);
        let frozen = stbp_scalar::forward(&net, &images, batch);
        for threads in [1usize, 2, 4] {
            let cur = net.forward(&images, batch, SpikeMode::Hard, true, threads);
            assert_eq!(
                cur.logits, frozen.logits,
                "{} logits diverged from the PR3 baseline (threads={threads})",
                spec.name
            );
            // Every layer's spike train and membrane record, bit for bit.
            for (li, fc) in frozen.caches.iter().enumerate() {
                let (spikes, v_pre) = cur.layer_cache(li);
                assert_eq!(spikes, &fc.spikes[..], "{} layer {li} spikes", spec.name);
                assert_eq!(v_pre, &fc.v_pre[..], "{} layer {li} membranes", spec.name);
            }
        }
    }
}

/// Gradients are byte-identical for every thread count: the shard
/// partition is fixed and every cross-shard reduction (conv/fc weight
/// gradients, BN statistics) runs in fixed shard order.
#[test]
fn backward_grads_identical_across_thread_counts() {
    let spec = models::tiny(3);
    let net = Net::init(&spec, 31);
    let batch = 5;
    let (images, labels) = batch_for(&spec, 31, batch);
    let classes = net.classes();
    let run = |threads: usize| {
        let fwd = net.forward(&images, batch, SpikeMode::Hard, true, threads);
        let mut dlogits = vec![0.0f32; batch * classes];
        tensor::softmax_ce(
            &fwd.logits,
            batch,
            classes,
            &labels,
            spec.num_steps as f32,
            &mut dlogits,
        );
        (fwd.logits.clone(), net.backward(&fwd, &images, &dlogits, true, threads))
    };
    let base = run(1);
    for threads in [2usize, 3, 4, 8] {
        assert_eq!(base, run(threads), "grads must not depend on threads={threads}");
    }
}

/// End-to-end: multi-epoch training exports byte-identical artifacts at
/// 1, 3 and 4 threads (the in-process half of the CI `cmp` job).
#[test]
fn trained_artifact_bytes_independent_of_threads() {
    let base_cfg = train::TrainConfig {
        model: "micro".into(),
        num_steps: 3,
        epochs: 2,
        batches_per_epoch: 4,
        batch: 10,
        seed: 13,
        log_every: 0,
        ..train::TrainConfig::default()
    };
    let reference = {
        let cfg = train::TrainConfig { threads: 1, ..base_cfg.clone() };
        train::deploy(&train::train(&cfg).unwrap().net).to_bytes()
    };
    for threads in [3usize, 4] {
        let cfg = train::TrainConfig { threads, ..base_cfg.clone() };
        let bytes = train::deploy(&train::train(&cfg).unwrap().net).to_bytes();
        assert_eq!(reference, bytes, "artifact changed at --threads {threads}");
    }
}

/// The NaN-safety fix end to end: a forward whose logits are poisoned
/// to NaN must report zero correct rows instead of crediting label 0.
#[test]
fn diverged_logits_never_count_as_correct() {
    let logits = vec![f32::NAN; 4 * 10];
    let labels: Vec<usize> = (0..4).collect();
    assert_eq!(train::count_correct(&logits, 10, &labels), 0);
    // The old bug: argmax always 0, so label 0 rows counted.  Guard the
    // specific shape too.
    assert_eq!(train::count_correct(&logits[..10], 10, &[0]), 0);
}

//! STBP training subsystem acceptance tests (PR3 tentpole).
//!
//! * gradient correctness: central finite differences against the
//!   backward pass in the continuous (`Soft`) spike mode — the same
//!   backward code real training uses, checked without the Heaviside
//!   discontinuity (tolerances calibrated against an f64 reference
//!   implementation);
//! * optimization sanity: a micro net overfits one batch to 100% train
//!   accuracy within 50 steps;
//! * export-time IF-BN folding: with dyadic-rational BN parameters and
//!   `eps = 0` every quantity on both sides is computed without rounding
//!   error, so the folded integer artifact must match the unfolded
//!   float train-time reference **bit-exactly**, spike train for spike
//!   train, logit for logit;
//! * byte-determinism of the train → export pipeline.

use vsa::config::models::{self, LayerKind, LayerSpec, ModelSpec};
use vsa::data::synth;
use vsa::snn::params::DeployedModel;
use vsa::snn::Network;
use vsa::train::stbp::TrainLayer;
use vsa::train::{self, optim, tensor, Net, SpikeMode};
use vsa::util::rng::SplitMix64;

/// Load a synthetic batch for `spec` as (images/255, labels).
fn batch_for(spec: &ModelSpec, seed: u64, start: u64, count: usize) -> (Vec<f32>, Vec<usize>) {
    let samples = synth::batch(seed, start, count, spec.in_channels, spec.in_size);
    let plane = spec.in_channels * spec.in_size * spec.in_size;
    let mut images = vec![0.0f32; count * plane];
    let mut labels = vec![0usize; count];
    for (r, s) in samples.iter().enumerate() {
        for (dst, &px) in images[r * plane..(r + 1) * plane].iter_mut().zip(&s.image) {
            *dst = px as f32 / 255.0;
        }
        labels[r] = s.label;
    }
    (images, labels)
}

fn loss_of(net: &Net, images: &[f32], batch: usize, labels: &[usize]) -> f32 {
    let fwd = net.forward(images, batch, SpikeMode::Soft, false, 1);
    let classes = net.classes();
    let mut dlogits = vec![0.0f32; batch * classes];
    tensor::softmax_ce(
        &fwd.logits,
        batch,
        classes,
        labels,
        net.spec.num_steps as f32,
        &mut dlogits,
    )
}

/// Mutable access to one trainable leaf of a layer by key.
fn leaf_mut<'a>(ly: &'a mut TrainLayer, key: &str) -> Option<&'a mut Vec<f32>> {
    match (ly, key) {
        (TrainLayer::Conv { w, .. }, "w") | (TrainLayer::Fc { w, .. }, "w") => Some(w),
        (TrainLayer::Readout { w, .. }, "w") => Some(w),
        (TrainLayer::Conv { bn, .. }, "gamma") | (TrainLayer::Fc { bn, .. }, "gamma") => {
            Some(&mut bn.gamma)
        }
        (TrainLayer::Conv { bn, .. }, "beta") | (TrainLayer::Fc { bn, .. }, "beta") => {
            Some(&mut bn.beta)
        }
        _ => None,
    }
}

/// Finite-difference check of the full STBP backward (conv, pool, fc,
/// readout, BN, IF-through-time) in the continuous spike mode.  The
/// rel-error distribution is gated robustly: a backward bug makes most
/// sampled gradients wrong, while an occasional kink straddle (the ramp
/// is piecewise linear) perturbs at most a few.
#[test]
fn stbp_gradients_match_finite_differences() {
    let spec = models::micro(2);
    let mut net = Net::init(&spec, 11);
    let batch = 8;
    let (images, labels) = batch_for(&spec, 11, 0, batch);

    let fwd = net.forward(&images, batch, SpikeMode::Soft, false, 1);
    let classes = net.classes();
    let mut dlogits = vec![0.0f32; batch * classes];
    tensor::softmax_ce(
        &fwd.logits,
        batch,
        classes,
        &labels,
        spec.num_steps as f32,
        &mut dlogits,
    );
    let grads = net.backward(&fwd, &images, &dlogits, false, 1);

    let eps = 3e-3f32;
    let mut rng = SplitMix64::new(1);
    let mut rels: Vec<f64> = Vec::new();
    for li in 0..net.layers.len() {
        for key in ["w", "gamma", "beta"] {
            let Some(len) = leaf_mut(&mut net.layers[li], key).map(|v| v.len()) else {
                continue;
            };
            let analytic = match key {
                "w" => grads[li].w.clone(),
                "gamma" => grads[li].gamma.clone(),
                _ => grads[li].beta.clone(),
            };
            for _ in 0..6.min(len) {
                let idx = rng.next_index(len);
                let orig = leaf_mut(&mut net.layers[li], key).unwrap()[idx];
                leaf_mut(&mut net.layers[li], key).unwrap()[idx] = orig + eps;
                let lp = loss_of(&net, &images, batch, &labels) as f64;
                leaf_mut(&mut net.layers[li], key).unwrap()[idx] = orig - eps;
                let lm = loss_of(&net, &images, batch, &labels) as f64;
                leaf_mut(&mut net.layers[li], key).unwrap()[idx] = orig;
                let fd = (lp - lm) / (2.0 * eps as f64);
                let an = analytic[idx] as f64;
                rels.push((fd - an).abs() / fd.abs().max(an.abs()).max(0.05));
            }
        }
    }
    assert!(rels.len() >= 20, "sampled too few parameters: {}", rels.len());
    let mut sorted = rels.clone();
    sorted.sort_by(f64::total_cmp);
    let median = sorted[sorted.len() / 2];
    let outliers = rels.iter().filter(|&&r| r > 0.25).count();
    assert!(
        median < 0.05,
        "median FD rel-error {median:.4} (backward is systematically wrong); rels {rels:?}"
    );
    assert!(
        outliers * 10 <= rels.len(),
        "{outliers}/{} FD outliers above 0.25: {rels:?}",
        rels.len()
    );
}

/// Satellite: a micro net must overfit one 16-sample batch to 100%
/// train accuracy within 50 steps (constant lr — no schedule), in the
/// real Hard/binarized training mode.
#[test]
fn overfits_one_batch_within_50_steps() {
    let spec = models::micro(4);
    let mut net = Net::init(&spec, 3);
    let mut opt = optim::Sgd::new(&net, 0.9);
    let batch = 16;
    let (images, labels) = batch_for(&spec, 3, 0, batch);
    let classes = net.classes();
    let mut dlogits = vec![0.0f32; batch * classes];
    let mut reached = None;
    for step in 0..50 {
        let fwd = net.forward(&images, batch, SpikeMode::Hard, true, 1);
        tensor::softmax_ce(
            &fwd.logits,
            batch,
            classes,
            &labels,
            spec.num_steps as f32,
            &mut dlogits,
        );
        let correct = (0..batch)
            .filter(|&r| {
                train::argmax_f32(&fwd.logits[r * classes..(r + 1) * classes]) == labels[r]
            })
            .count();
        if correct == batch {
            reached = Some(step);
            break;
        }
        let grads = net.backward(&fwd, &images, &dlogits, true, 1);
        opt.step(&mut net, &grads, 0.1);
        net.apply_bn_ema(&fwd);
    }
    assert!(
        reached.is_some(),
        "failed to overfit 16 samples in 50 steps (reference run reaches it by ~15)"
    );
}

/// All-layer-kinds spec for the fold test: enc conv, plain conv, pool,
/// fc, readout.
fn fold_spec(t: usize) -> ModelSpec {
    ModelSpec {
        name: "foldtest".into(),
        in_channels: 1,
        in_size: 8,
        layers: vec![
            LayerSpec { kind: LayerKind::EncConv, c_out: 4, ksize: 3 },
            LayerSpec { kind: LayerKind::Conv, c_out: 6, ksize: 3 },
            LayerSpec { kind: LayerKind::MaxPool, c_out: 0, ksize: 0 },
            LayerSpec { kind: LayerKind::Fc, c_out: 16, ksize: 0 },
            LayerSpec { kind: LayerKind::Readout, c_out: 10, ksize: 0 },
        ],
        num_steps: t,
    }
}

/// Install dyadic-rational IF-BN parameters: gamma and sigma powers of
/// two, mu on the 1/256 grid, beta on the 1/64 grid.  Every fold
/// product and every membrane update is then exact in f32/f64 *and* the
/// quantized integers land exactly on the FIXED_POINT grid, so the
/// folded and unfolded paths must agree bit for bit (acceptance
/// criterion; cross-checked against an f64 reference over 400 random
/// layer instances before porting).
fn make_dyadic(net: &mut Net, seed: u64) {
    let mut rng = SplitMix64::new(seed);
    let mut pick = |vals: &[f32]| vals[rng.next_index(vals.len())];
    for ly in &mut net.layers {
        let bn = match ly {
            TrainLayer::Conv { bn, .. } | TrainLayer::Fc { bn, .. } => bn,
            _ => continue,
        };
        for ch in 0..bn.channels() {
            bn.gamma[ch] = pick(&[0.5, 1.0, 2.0]);
            let sigma = pick(&[0.5, 1.0, 2.0]);
            bn.var[ch] = sigma * sigma;
            bn.mu[ch] = pick(&[-32.0, -8.0, 0.0, 8.0, 16.0]) / 256.0;
            bn.beta[ch] = pick(&[-4.0, -1.0, 0.0, 1.0, 2.0]) / 64.0;
        }
    }
}

/// Acceptance: folded-threshold integer inference (the exported VSAW
/// artifact through the golden model) is bit-exact against the unfolded
/// train-time float reference on the same inputs — including the
/// encoding layer's x255 input rescale, exercised with binary {0, 255}
/// pixels so the train-side /255 is exact.
#[test]
fn ifbn_fold_is_bit_exact_against_unfolded_reference() {
    let spec = fold_spec(5);
    for seed in [1u64, 2, 3] {
        let mut net = Net::init(&spec, seed);
        make_dyadic(&mut net, seed ^ 0xD1AD);
        // Export at eps = 0 and round-trip the actual bytes.
        let artifact = train::deploy_with_eps(&net, 0.0);
        let golden = Network::new(
            DeployedModel::parse(&artifact.to_bytes()).expect("artifact parses"),
        );

        let mut rng = SplitMix64::new(seed.wrapping_mul(77));
        for _ in 0..8 {
            let img_u8: Vec<u8> = (0..spec.in_size * spec.in_size)
                .map(|_| if rng.next_below(2) == 1 { 255 } else { 0 })
                .collect();
            let img_f: Vec<f32> = img_u8.iter().map(|&p| p as f32 / 255.0).collect();
            // Unfolded train-time reference: running-stats BN (eps 0),
            // float IF at v_th = 1.
            let float_logits = net.forward_eval(&img_f, 1, 0.0);
            // Folded integer path: the golden model on raw u8 pixels.
            let int_logits = golden.infer_u8(&img_u8);
            for (o, (&f, &i)) in float_logits.iter().zip(&int_logits).enumerate() {
                assert_eq!(f.fract(), 0.0, "float readout must be integer-valued");
                assert_eq!(
                    f as i64, i,
                    "seed {seed} logit {o}: unfolded {f} vs folded {i} \
                     (IF-BN fold is not bit-exact)"
                );
            }
        }
    }
}

/// With realistic (non-dyadic) statistics the quantized export still
/// keeps theta positive and loads into the golden model — the rounding
/// the dyadic test deliberately eliminates must stay benign.
#[test]
fn quantization_error_is_bounded() {
    let spec = models::micro(4);
    let mut net = Net::init(&spec, 21);
    // realistic (non-dyadic) stats
    if let TrainLayer::Conv { bn, .. } = &mut net.layers[0] {
        for ch in 0..bn.channels() {
            bn.mu[ch] = 0.173 + ch as f32 * 0.041;
            bn.var[ch] = 0.9 + ch as f32 * 0.13;
            bn.gamma[ch] = 0.7;
            bn.beta[ch] = -0.2;
        }
    }
    let artifact = train::deploy(&net);
    for ly in &artifact.layers {
        if let vsa::snn::params::Layer::Conv { theta, .. }
        | vsa::snn::params::Layer::Fc { theta, .. } = ly
        {
            assert!(theta.iter().all(|&t| t >= 1), "theta floored at 1");
        }
    }
    // and the artifact still loads into the golden model
    let _ = Network::new(artifact);
}

/// Acceptance: identically-seeded training runs export byte-identical
/// artifacts (the CLI-level twin runs in CI with the release binary).
#[test]
fn train_export_is_byte_deterministic() {
    let cfg = train::TrainConfig {
        model: "micro".into(),
        num_steps: 2,
        epochs: 1,
        batches_per_epoch: 4,
        batch: 8,
        seed: 7,
        log_every: 0,
        ..train::TrainConfig::default()
    };
    let a = train::deploy(&train::train(&cfg).unwrap().net).to_bytes();
    let b = train::deploy(&train::train(&cfg).unwrap().net).to_bytes();
    assert_eq!(a, b, "same seed must give byte-identical artifacts");
    let other = train::TrainConfig { seed: 8, ..cfg };
    let c = train::deploy(&train::train(&other).unwrap().net).to_bytes();
    assert_ne!(a, c, "different seeds must differ");
}

/// A short micro training run clearly beats chance on *held-out* data
/// and its artifact round-trips through `vsa eval`'s code path.  (The
/// full >90% acceptance run uses the tiny model through the release CLI
/// — see CI's train smoke; debug-mode tests keep to the micro net.)
#[test]
fn short_micro_training_beats_chance_end_to_end() {
    let cfg = train::TrainConfig {
        model: "micro".into(),
        num_steps: 4,
        epochs: 6,
        batches_per_epoch: 25,
        batch: 16,
        seed: 11,
        log_every: 0,
        ..train::TrainConfig::default()
    };
    let outcome = train::train(&cfg).unwrap();
    let artifact = train::deploy(&outcome.net);
    let reparsed = DeployedModel::parse(&artifact.to_bytes()).unwrap();
    let samples = train::holdout_synth(&outcome.net.spec, cfg.seed, 128);
    let (correct, total) = train::eval_golden(&reparsed, &samples);
    // 10 balanced classes: chance is ~13/128.  The f64 reference run
    // reaches ~67% at this config; gate at 30% for f32/ordering slack.
    assert!(
        correct * 10 >= total * 3,
        "trained micro net should beat 30% held out, got {correct}/{total}"
    );
}

//! Latent-weight binarization with a straight-through estimator.
//!
//! Forward: `w_bin = sign(w)` with `sign(0) = +1` (the convention of
//! `python/compile/model.py::binarize_ste` and of the VSAW format, which
//! only stores ±1).  Backward: the gradient computed with respect to the
//! binarized weights is applied to the latent weights unchanged
//! (identity STE, BinaryConnect / BW-SNN style) — so the latent f32
//! weights drift across sign boundaries over training while the network
//! always *computes* with ±1.

/// Binarize `latent` into `out` (both same length).
pub fn sign_into(latent: &[f32], out: &mut [f32]) {
    for (o, &w) in out.iter_mut().zip(latent) {
        *o = if w >= 0.0 { 1.0 } else { -1.0 };
    }
}

/// Binarize into a fresh buffer.
pub fn sign_vec(latent: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0; latent.len()];
    sign_into(latent, &mut out);
    out
}

/// Export-time binarization to the i8 form `snn::params` stores.
pub fn sign_i8(latent: &[f32]) -> Vec<i8> {
    latent.iter().map(|&w| if w >= 0.0 { 1 } else { -1 }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sign_convention_matches_deploy() {
        // sign(0) = +1, matching jnp.where(w >= 0, 1, -1).
        assert_eq!(sign_vec(&[-0.5, 0.0, 0.5]), vec![-1.0, 1.0, 1.0]);
        assert_eq!(sign_i8(&[-0.5, 0.0, 0.5]), vec![-1, 1, 1]);
    }
}

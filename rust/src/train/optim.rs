//! SGD with classical momentum and a cosine learning-rate schedule.
//!
//! Matches the update `compile/train.py` performs structurally (one
//! velocity slot per trainable leaf; `gamma` clamped positive after the
//! step so the IF-BN fold keeps its firing-inequality direction), but
//! with momentum-SGD + cosine decay instead of Adam: no per-parameter
//! second moments to serialize, and bit-deterministic with plain f32
//! arithmetic.

use crate::train::stbp::{LayerGrads, Net, TrainLayer};

/// Lower clamp for BN gamma — matches `compile/train.py::GAMMA_MIN`.
pub const GAMMA_MIN: f32 = 0.05;

/// Cosine-annealed learning rate: `lr/2 * (1 + cos(pi * step/total))`.
pub fn cosine_lr(base_lr: f64, step: usize, total_steps: usize) -> f64 {
    let frac = step as f64 / total_steps.max(1) as f64;
    0.5 * base_lr * (1.0 + (std::f64::consts::PI * frac).cos())
}

/// Per-layer velocity slots mirroring [`LayerGrads`].
#[derive(Debug, Clone, Default)]
struct LayerVel {
    w: Vec<f32>,
    gamma: Vec<f32>,
    beta: Vec<f32>,
}

/// Momentum-SGD state over a [`Net`].
#[derive(Debug, Clone)]
pub struct Sgd {
    pub momentum: f32,
    vel: Vec<LayerVel>,
}

impl Sgd {
    /// Zero-initialized velocities for every trainable leaf of `net`.
    pub fn new(net: &Net, momentum: f32) -> Self {
        let vel = net
            .layers
            .iter()
            .map(|ly| match ly {
                TrainLayer::Conv { w, bn, .. } | TrainLayer::Fc { w, bn, .. } => LayerVel {
                    w: vec![0.0; w.len()],
                    gamma: vec![0.0; bn.channels()],
                    beta: vec![0.0; bn.channels()],
                },
                TrainLayer::Readout { w, .. } => LayerVel {
                    w: vec![0.0; w.len()],
                    gamma: Vec::new(),
                    beta: Vec::new(),
                },
                TrainLayer::MaxPool => LayerVel::default(),
            })
            .collect();
        Self { momentum, vel }
    }

    /// One update: `v = momentum * v + g; p -= lr * v`, then the gamma
    /// clamp.  `grads` must be parallel to `net.layers`.
    pub fn step(&mut self, net: &mut Net, grads: &[LayerGrads], lr: f64) {
        let lr = lr as f32;
        let mom = self.momentum;
        let apply = |p: &mut [f32], g: &[f32], v: &mut [f32]| {
            for ((pv, &gv), vv) in p.iter_mut().zip(g).zip(v.iter_mut()) {
                *vv = mom * *vv + gv;
                *pv -= lr * *vv;
            }
        };
        for (ly, (g, v)) in net.layers.iter_mut().zip(grads.iter().zip(&mut self.vel)) {
            match ly {
                TrainLayer::Conv { w, bn, .. } | TrainLayer::Fc { w, bn, .. } => {
                    apply(w, &g.w, &mut v.w);
                    apply(&mut bn.gamma, &g.gamma, &mut v.gamma);
                    apply(&mut bn.beta, &g.beta, &mut v.beta);
                    for gm in bn.gamma.iter_mut() {
                        *gm = gm.max(GAMMA_MIN);
                    }
                }
                TrainLayer::Readout { w, .. } => apply(w, &g.w, &mut v.w),
                TrainLayer::MaxPool => {}
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::models;
    use crate::train::stbp::Net;

    #[test]
    fn cosine_schedule_endpoints() {
        assert!((cosine_lr(0.1, 0, 100) - 0.1).abs() < 1e-12);
        assert!((cosine_lr(0.1, 50, 100) - 0.05).abs() < 1e-12);
        assert!(cosine_lr(0.1, 100, 100).abs() < 1e-12);
    }

    #[test]
    fn momentum_accumulates_and_gamma_clamps() {
        let spec = models::micro(2);
        let mut net = Net::init(&spec, 1);
        let mut opt = Sgd::new(&net, 0.9);
        // Gradients that push gamma of the first layer far negative.
        let mut grads: Vec<LayerGrads> =
            net.layers.iter().map(|_| LayerGrads::default()).collect();
        if let TrainLayer::Conv { w, bn, .. } = &net.layers[0] {
            grads[0] = LayerGrads {
                w: vec![1.0; w.len()],
                gamma: vec![100.0; bn.channels()],
                beta: vec![0.0; bn.channels()],
            };
        }
        let w0 = match &net.layers[0] {
            TrainLayer::Conv { w, .. } => w[0],
            _ => unreachable!(),
        };
        opt.step(&mut net, &grads, 0.1);
        opt.step(&mut net, &grads, 0.1);
        match &net.layers[0] {
            TrainLayer::Conv { w, bn, .. } => {
                // two momentum steps move further than two plain steps
                assert!(w[0] < w0 - 2.0 * 0.1);
                assert!(bn.gamma.iter().all(|&g| g == GAMMA_MIN));
            }
            _ => unreachable!(),
        }
    }
}

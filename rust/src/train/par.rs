//! Deterministic work-sharding for the training hot path.
//!
//! The trainer's parallelism contract is stronger than "same result for
//! a fixed thread count": `vsa train` must produce **byte-identical**
//! artifacts at any `--threads`.  The scheme that guarantees it:
//!
//! 1. Work is cut into a *fixed* number of shards ([`SHARDS`]) derived
//!    only from the problem size — never from the thread count.  Each
//!    shard owns a disjoint slice of the output (rows of a conv/matmul
//!    output, a channel range of BN statistics) and computes it with
//!    exactly the scalar kernel's iteration order.
//! 2. Threads merely *execute* shards ([`run`] stripes shard indices
//!    over `threads` scoped OS threads).  Which thread runs a shard can
//!    never change the arithmetic, because no two shards write the same
//!    element and no shard reads another's output.
//! 3. The only cross-shard reductions are the weight gradients and they
//!    use per-shard buffers summed on the caller thread in fixed shard
//!    order (see `tensor::conv2d_same_grads_mt`) — f32 addition is
//!    non-associative, so the grouping is pinned by construction.
//!
//! Consequence: for every thread count (including 1, which skips thread
//! spawning entirely) the same shards run the same scalar code and the
//! same reductions in the same order, so the trained artifact bytes
//! cannot depend on `--threads`.  This is the trainer's analogue of
//! PR1's one-`Scratch`-per-worker ownership model: each worker owns its
//! working set outright for the duration of a parallel section
//! (`std::thread::scope` is the only synchronization primitive used).

use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};

/// Wall nanoseconds spent in the fixed-order gradient reductions of the
/// `_mt` kernels since the last [`take_reduce_ns`] — the trainer's
/// per-epoch "reduce" phase (telemetry, PR7).  Process-global and
/// observational only: concurrent `train()` calls (e.g. parallel tests)
/// share it, so consumers must treat it as a best-effort attribution,
/// never an invariant.  It cannot affect training arithmetic.
static REDUCE_NS: AtomicU64 = AtomicU64::new(0);

/// Charge reduction wall time (called by `tensor::*_grads_mt`).
pub fn add_reduce_ns(ns: u64) {
    REDUCE_NS.fetch_add(ns, Ordering::Relaxed);
}

/// Read and reset the accumulated reduction nanoseconds.
pub fn take_reduce_ns() -> u64 {
    REDUCE_NS.swap(0, Ordering::Relaxed)
}

/// Fixed shard count — a constant so the work partition (and therefore
/// every reduction order) is independent of `--threads`.  Sixteen keeps
/// 4–8 worker threads load-balanced.  Note the cost: the gradient
/// `_mt` kernels transiently hold up to 16x the largest layer's weight
/// gradient (tens of MB for cifar-scale layers), freshly zeroed per
/// call — a reusable per-`Net` scratch arena is a known follow-on
/// (ROADMAP, training follow-ons).
pub const SHARDS: usize = 16;

/// Sections below this approximate f32-op count run inline even when
/// `--threads` is higher: a `thread::scope` spawn/join cycle costs tens
/// of microseconds, more than the arithmetic of a small BN or micro-net
/// stage.  Pure scheduling — the shard partition and every reduction
/// order are unchanged, so the bytes cannot depend on this gate
/// (covered by the cross-thread-count determinism tests).
pub const MIN_PAR_OPS: usize = 1 << 16;

/// Clamp `threads` to 1 for sections whose work is too small to
/// amortize thread spawns.
pub fn threads_for(ops: usize, threads: usize) -> usize {
    if ops < MIN_PAR_OPS {
        1
    } else {
        threads
    }
}

/// Cut `0..n` into up to [`SHARDS`] contiguous, equally-sized (ceil)
/// ranges.  Depends only on `n`; empty ranges are never produced.
pub fn shard_ranges(n: usize, max_shards: usize) -> Vec<Range<usize>> {
    if n == 0 || max_shards == 0 {
        return Vec::new();
    }
    let size = (n + max_shards - 1) / max_shards;
    let mut out = Vec::with_capacity(max_shards.min(n));
    let mut start = 0;
    while start < n {
        out.push(start..(start + size).min(n));
        start += size;
    }
    out
}

/// Split `buf` (whose rows are `row_len` elements) into per-range
/// mutable chunks — the disjoint output views handed to shards.
/// `ranges` must be ascending, contiguous from 0 and cover exactly
/// `buf.len() / row_len` rows (what [`shard_ranges`] produces).
pub fn split_rows<'a>(
    mut buf: &'a mut [f32],
    ranges: &[Range<usize>],
    row_len: usize,
) -> Vec<&'a mut [f32]> {
    let mut out = Vec::with_capacity(ranges.len());
    for r in ranges {
        let (head, tail) = buf.split_at_mut((r.end - r.start) * row_len);
        out.push(head);
        buf = tail;
    }
    assert!(buf.is_empty(), "ranges must cover the whole buffer");
    out
}

/// Execute one closure call per shard context, striping shards over at
/// most `threads` scoped OS threads.  `ctxs[s]` is shard `s`'s private
/// mutable context (disjoint views prepared by the caller); the closure
/// also receives the shard index.  With `threads <= 1` (or a single
/// shard) everything runs on the caller thread with no spawning — the
/// arithmetic is identical either way, only the schedule changes.
pub fn run<C: Send>(threads: usize, ctxs: Vec<C>, f: impl Fn(usize, C) + Sync) {
    let threads = threads.max(1).min(ctxs.len());
    if threads <= 1 {
        for (s, c) in ctxs.into_iter().enumerate() {
            f(s, c);
        }
        return;
    }
    let mut buckets: Vec<Vec<(usize, C)>> = (0..threads).map(|_| Vec::new()).collect();
    for (s, c) in ctxs.into_iter().enumerate() {
        buckets[s % threads].push((s, c));
    }
    std::thread::scope(|scope| {
        for bucket in buckets {
            let f = &f;
            scope.spawn(move || {
                for (s, c) in bucket {
                    f(s, c);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_cover_and_never_depend_on_threads() {
        for n in [0usize, 1, 5, 16, 17, 100, 1000] {
            let rs = shard_ranges(n, SHARDS);
            assert!(rs.len() <= SHARDS);
            let mut next = 0;
            for r in &rs {
                assert_eq!(r.start, next, "contiguous from 0");
                assert!(r.end > r.start, "no empty shards");
                next = r.end;
            }
            assert_eq!(next, n, "ranges cover 0..{n}");
        }
    }

    #[test]
    fn split_rows_is_disjoint_and_complete() {
        let mut buf = vec![0.0f32; 10 * 3];
        let ranges = shard_ranges(10, 4);
        let chunks = split_rows(&mut buf, &ranges, 3);
        let total: usize = chunks.iter().map(|c| c.len()).sum();
        assert_eq!(total, 30);
        assert_eq!(chunks.len(), ranges.len());
    }

    #[test]
    fn run_gives_identical_results_for_any_thread_count() {
        let compute = |threads: usize| -> Vec<f32> {
            let mut out = vec![0.0f32; 103];
            let ranges = shard_ranges(103, SHARDS);
            let chunks = split_rows(&mut out, &ranges, 1);
            let ctxs: Vec<_> = ranges.iter().cloned().zip(chunks).collect();
            run(threads, ctxs, |_, (r, chunk)| {
                for (k, v) in chunk.iter_mut().enumerate() {
                    *v = ((r.start + k) as f32).sqrt();
                }
            });
            out
        };
        let base = compute(1);
        for t in [2, 3, 4, 9] {
            assert_eq!(base, compute(t), "threads={t} must match threads=1");
        }
    }
}

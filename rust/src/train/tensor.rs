//! Minimal dense f32 kernels for training — just the ops STBP needs.
//!
//! Everything operates on flat `&[f32]` buffers with explicit dimensions
//! (the same convention as `snn::conv`) in a fixed iteration order so
//! training runs are byte-reproducible per seed.  Reductions accumulate
//! in f64: cheap at these sizes and it keeps batch statistics stable
//! regardless of batch layout.
//!
//! Since PR4 the scalar kernels block their inner loops over output
//! channels (each input value loaded once feeds [`CONV_BLOCK`] /
//! [`MM_BLOCK`] accumulators) — **bit-exactly**: every output element's
//! reduction still runs in the original order, only independent output
//! elements are interleaved.  The `_mt` variants shard rows over
//! [`crate::train::par`]'s fixed, thread-count-independent partition;
//! the weight-gradient reduction uses per-shard buffers summed in fixed
//! shard order, so results are identical for every thread count.

use crate::train::par;

/// Output channels swept together per input-plane pass of
/// [`conv2d_same`].
pub const CONV_BLOCK: usize = 4;

/// Output rows swept together per x-row pass of [`matmul_nt`].
pub const MM_BLOCK: usize = 4;

/// SAME-padded stride-1 2-D convolution.
///
/// `x` is `(n, c_in, h, w)`, `w` is `(c_out, c_in, k, k)` (both row-major);
/// the result lands in `out` as `(n, c_out, h, w)`.  Matches
/// `python/compile/kernels/ref.py::conv2d_binary` (pad `k/2` on each side).
///
/// Blocked over [`CONV_BLOCK`] output channels so each input pixel read
/// feeds several accumulations; per output element the `(c_in, kh, kw)`
/// summation order is unchanged, so results are bit-identical to the
/// unblocked loop (asserted against `baselines::stbp_scalar`).
pub fn conv2d_same(
    x: &[f32],
    n: usize,
    c_in: usize,
    h: usize,
    w: usize,
    wts: &[f32],
    c_out: usize,
    k: usize,
    out: &mut [f32],
) {
    assert_eq!(x.len(), n * c_in * h * w, "conv input geometry");
    assert_eq!(wts.len(), c_out * c_in * k * k, "conv weight geometry");
    assert_eq!(out.len(), n * c_out * h * w, "conv output geometry");
    let pad = (k / 2) as isize;
    let hw = h * w;
    out.fill(0.0);
    for img in 0..n {
        let xin = &x[img * c_in * hw..(img + 1) * c_in * hw];
        let xout = &mut out[img * c_out * hw..(img + 1) * c_out * hw];
        let mut o0 = 0;
        while o0 < c_out {
            let ob = (c_out - o0).min(CONV_BLOCK);
            for i in 0..c_in {
                let plane = &xin[i * hw..(i + 1) * hw];
                for kh in 0..k {
                    for kw in 0..k {
                        let dy = kh as isize - pad;
                        let dx = kw as isize - pad;
                        let y0 = (-dy).max(0) as usize;
                        let y1 = (h as isize - dy).clamp(0, h as isize) as usize;
                        let x0 = (-dx).max(0) as usize;
                        let x1 = (w as isize - dx).clamp(0, w as isize) as usize;
                        let mut wv = [0.0f32; CONV_BLOCK];
                        for (bo, wvb) in wv.iter_mut().enumerate().take(ob) {
                            *wvb = wts[((o0 + bo) * c_in + i) * k * k + kh * k + kw];
                        }
                        for y in y0..y1 {
                            let src = ((y as isize + dy) as usize) * w;
                            let row = y * w;
                            for xx in x0..x1 {
                                let pv = plane[src + (xx as isize + dx) as usize];
                                for bo in 0..ob {
                                    xout[(o0 + bo) * hw + row + xx] += wv[bo] * pv;
                                }
                            }
                        }
                    }
                }
            }
            o0 += ob;
        }
    }
}

/// [`conv2d_same`] with rows (images) sharded over `threads` scoped
/// worker threads.  Images are independent, so any schedule of the
/// fixed shard partition produces bit-identical output.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_same_mt(
    x: &[f32],
    n: usize,
    c_in: usize,
    h: usize,
    w: usize,
    wts: &[f32],
    c_out: usize,
    k: usize,
    out: &mut [f32],
    threads: usize,
) {
    assert_eq!(x.len(), n * c_in * h * w, "conv input geometry");
    assert_eq!(out.len(), n * c_out * h * w, "conv output geometry");
    let (in_row, out_row) = (c_in * h * w, c_out * h * w);
    let threads = par::threads_for(n * out_row * c_in * k * k, threads);
    let ranges = par::shard_ranges(n, par::SHARDS);
    let outs = par::split_rows(out, &ranges, out_row);
    let ctxs: Vec<_> = ranges.iter().cloned().zip(outs).collect();
    par::run(threads, ctxs, |_, (r, o)| {
        let rows = r.end - r.start;
        conv2d_same(&x[r.start * in_row..r.end * in_row], rows, c_in, h, w, wts, c_out, k, o);
    });
}

/// Gradients of [`conv2d_same`]: `dy` is `(n, c_out, h, w)`; accumulates
/// the input gradient into `dx` (same shape as `x`, zeroed here) and the
/// weight gradient into `dw` (same shape as `wts`, zeroed here).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_same_grads(
    x: &[f32],
    n: usize,
    c_in: usize,
    h: usize,
    w: usize,
    wts: &[f32],
    c_out: usize,
    k: usize,
    dy: &[f32],
    dx: &mut [f32],
    dw: &mut [f32],
) {
    let pad = (k / 2) as isize;
    let hw = h * w;
    dx.fill(0.0);
    dw.fill(0.0);
    for img in 0..n {
        let xin = &x[img * c_in * hw..(img + 1) * c_in * hw];
        let dyi = &dy[img * c_out * hw..(img + 1) * c_out * hw];
        let dxi = &mut dx[img * c_in * hw..(img + 1) * c_in * hw];
        for o in 0..c_out {
            let dplane = &dyi[o * hw..(o + 1) * hw];
            for i in 0..c_in {
                let plane = &xin[i * hw..(i + 1) * hw];
                let gplane = &mut dxi[i * hw..(i + 1) * hw];
                for kh in 0..k {
                    for kw in 0..k {
                        let widx = ((o * c_in + i) * k + kh) * k + kw;
                        let wv = wts[widx];
                        let dyk = kh as isize - pad;
                        let dxk = kw as isize - pad;
                        let y0 = (-dyk).max(0) as usize;
                        let y1 = (h as isize - dyk).clamp(0, h as isize) as usize;
                        let x0 = (-dxk).max(0) as usize;
                        let x1 = (w as isize - dxk).clamp(0, w as isize) as usize;
                        let mut acc = 0.0f32;
                        for y in y0..y1 {
                            let src = ((y as isize + dyk) as usize) * w;
                            let dst = y * w;
                            for xx in x0..x1 {
                                let xi = src + (xx as isize + dxk) as usize;
                                let g = dplane[dst + xx];
                                acc += g * plane[xi];
                                gplane[xi] += g * wv;
                            }
                        }
                        dw[widx] += acc;
                    }
                }
            }
        }
    }
}

/// [`conv2d_same_grads`] with rows sharded over `threads` workers.  The
/// input gradient is row-disjoint (each shard zeroes and fills its own
/// rows); the weight gradient is reduced from per-shard buffers in
/// fixed shard order, so every thread count produces identical bytes.
/// Like the scalar kernel, `dx` and `dw` are (re)computed from zero.
///
/// Allocates the per-shard buffer internally; the training loop uses
/// [`conv2d_same_grads_mt_with`] to recycle one across steps (the
/// SHARDS×|dW| churn was tens of MB per step at CIFAR scale).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_same_grads_mt(
    x: &[f32],
    n: usize,
    c_in: usize,
    h: usize,
    w: usize,
    wts: &[f32],
    c_out: usize,
    k: usize,
    dy: &[f32],
    dx: &mut [f32],
    dw: &mut [f32],
    threads: usize,
) {
    let mut parts = Vec::new();
    conv2d_same_grads_mt_with(x, n, c_in, h, w, wts, c_out, k, dy, dx, dw, threads, &mut parts);
}

/// [`conv2d_same_grads_mt`] with a caller-owned per-shard gradient
/// buffer (cleared and zero-filled here — contents identical to the
/// allocating variant, bit for bit).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_same_grads_mt_with(
    x: &[f32],
    n: usize,
    c_in: usize,
    h: usize,
    w: usize,
    wts: &[f32],
    c_out: usize,
    k: usize,
    dy: &[f32],
    dx: &mut [f32],
    dw: &mut [f32],
    threads: usize,
    parts: &mut Vec<f32>,
) {
    let (in_row, out_row) = (c_in * h * w, c_out * h * w);
    assert_eq!(x.len(), n * in_row, "conv-grad input geometry");
    assert_eq!(dy.len(), n * out_row, "conv-grad dy geometry");
    assert_eq!(dx.len(), n * in_row, "conv-grad dx geometry");
    assert_eq!(dw.len(), c_out * c_in * k * k, "conv-grad dw geometry");
    let threads = par::threads_for(2 * n * out_row * c_in * k * k, threads);
    let ranges = par::shard_ranges(n, par::SHARDS);
    parts.clear();
    parts.resize(ranges.len() * dw.len(), 0.0);
    {
        let dxs = par::split_rows(dx, &ranges, in_row);
        let ctxs: Vec<_> = ranges
            .iter()
            .cloned()
            .zip(dxs)
            .zip(parts.chunks_mut(dw.len().max(1)))
            .map(|((r, dxc), dwc)| (r, dxc, dwc))
            .collect();
        par::run(threads, ctxs, |_, (r, dxc, dwc)| {
            conv2d_same_grads(
                &x[r.start * in_row..r.end * in_row],
                r.end - r.start,
                c_in,
                h,
                w,
                wts,
                c_out,
                k,
                &dy[r.start * out_row..r.end * out_row],
                dxc,
                dwc,
            );
        });
    }
    let t_reduce = std::time::Instant::now();
    dw.fill(0.0);
    for part in parts.chunks(dw.len().max(1)) {
        for (d, &p) in dw.iter_mut().zip(part) {
            *d += p;
        }
    }
    par::add_reduce_ns(t_reduce.elapsed().as_nanos() as u64);
}

/// Dense layer forward: `x` is `(n, n_in)`, `wts` is `(n_out, n_in)`;
/// writes `out = x @ wts^T` as `(n, n_out)`.
///
/// Blocked over [`MM_BLOCK`] weight rows per x-row sweep: each `x` load
/// feeds four independent accumulator chains.  Each output's dot
/// product still sums over `j` in order — bit-identical to the
/// unblocked loop.
pub fn matmul_nt(x: &[f32], n: usize, n_in: usize, wts: &[f32], n_out: usize, out: &mut [f32]) {
    assert_eq!(x.len(), n * n_in, "matmul input geometry");
    assert_eq!(wts.len(), n_out * n_in, "matmul weight geometry");
    assert_eq!(out.len(), n * n_out, "matmul output geometry");
    for r in 0..n {
        let xi = &x[r * n_in..(r + 1) * n_in];
        let oi = &mut out[r * n_out..(r + 1) * n_out];
        let mut o = 0;
        while o + MM_BLOCK <= n_out {
            let w0 = &wts[o * n_in..(o + 1) * n_in];
            let w1 = &wts[(o + 1) * n_in..(o + 2) * n_in];
            let w2 = &wts[(o + 2) * n_in..(o + 3) * n_in];
            let w3 = &wts[(o + 3) * n_in..(o + 4) * n_in];
            let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for (j, &xv) in xi.iter().enumerate() {
                a0 += xv * w0[j];
                a1 += xv * w1[j];
                a2 += xv * w2[j];
                a3 += xv * w3[j];
            }
            oi[o] = a0;
            oi[o + 1] = a1;
            oi[o + 2] = a2;
            oi[o + 3] = a3;
            o += MM_BLOCK;
        }
        while o < n_out {
            let wr = &wts[o * n_in..(o + 1) * n_in];
            let mut acc = 0.0f32;
            for (a, b) in xi.iter().zip(wr) {
                acc += a * b;
            }
            oi[o] = acc;
            o += 1;
        }
    }
}

/// [`matmul_nt`] with rows sharded over `threads` workers.  Rows are
/// independent — bit-identical for any thread count.
pub fn matmul_nt_mt(
    x: &[f32],
    n: usize,
    n_in: usize,
    wts: &[f32],
    n_out: usize,
    out: &mut [f32],
    threads: usize,
) {
    assert_eq!(x.len(), n * n_in, "matmul input geometry");
    assert_eq!(out.len(), n * n_out, "matmul output geometry");
    let threads = par::threads_for(n * n_in * n_out, threads);
    let ranges = par::shard_ranges(n, par::SHARDS);
    let outs = par::split_rows(out, &ranges, n_out);
    let ctxs: Vec<_> = ranges.iter().cloned().zip(outs).collect();
    par::run(threads, ctxs, |_, (r, o)| {
        matmul_nt(&x[r.start * n_in..r.end * n_in], r.end - r.start, n_in, wts, n_out, o);
    });
}

/// Gradients of [`matmul_nt`]: accumulates `dx = dy @ wts` (zeroed here)
/// and `dw += dy^T @ x` (NOT zeroed — callers may accumulate).
///
/// Blocked over pairs of outputs sharing each `x`/`dx` access; `dx[j]`
/// still receives the pair's contributions sequentially (`o` before
/// `o + 1`) and zero-gradient outputs are skipped exactly as before, so
/// results are bit-identical to the unblocked loop.
pub fn matmul_nt_grads(
    x: &[f32],
    n: usize,
    n_in: usize,
    wts: &[f32],
    n_out: usize,
    dy: &[f32],
    dx: &mut [f32],
    dw: &mut [f32],
) {
    dx.fill(0.0);
    for r in 0..n {
        let xi = &x[r * n_in..(r + 1) * n_in];
        let dyi = &dy[r * n_out..(r + 1) * n_out];
        let dxi = &mut dx[r * n_in..(r + 1) * n_in];
        let single = |o: usize, g: f32, dxi: &mut [f32], dw: &mut [f32]| {
            let wr = &wts[o * n_in..(o + 1) * n_in];
            let dwr = &mut dw[o * n_in..(o + 1) * n_in];
            for j in 0..n_in {
                dxi[j] += g * wr[j];
                dwr[j] += g * xi[j];
            }
        };
        let mut o = 0;
        while o + 2 <= n_out {
            let (g0, g1) = (dyi[o], dyi[o + 1]);
            match (g0 != 0.0, g1 != 0.0) {
                (true, true) => {
                    let w0 = &wts[o * n_in..(o + 1) * n_in];
                    let w1 = &wts[(o + 1) * n_in..(o + 2) * n_in];
                    let (dw0, dw1) = dw[o * n_in..(o + 2) * n_in].split_at_mut(n_in);
                    for j in 0..n_in {
                        let xv = xi[j];
                        let t = dxi[j] + g0 * w0[j];
                        dxi[j] = t + g1 * w1[j];
                        dw0[j] += g0 * xv;
                        dw1[j] += g1 * xv;
                    }
                }
                (true, false) => single(o, g0, dxi, dw),
                (false, true) => single(o + 1, g1, dxi, dw),
                (false, false) => {}
            }
            o += 2;
        }
        if o < n_out && dyi[o] != 0.0 {
            single(o, dyi[o], dxi, dw);
        }
    }
}

/// [`matmul_nt_grads`] with rows sharded over `threads` workers: `dx`
/// rows are disjoint per shard, `dw` is reduced from per-shard buffers
/// in fixed shard order (accumulate semantics preserved) — identical
/// bytes for every thread count.  Allocating variant of
/// [`matmul_nt_grads_mt_with`].
#[allow(clippy::too_many_arguments)]
pub fn matmul_nt_grads_mt(
    x: &[f32],
    n: usize,
    n_in: usize,
    wts: &[f32],
    n_out: usize,
    dy: &[f32],
    dx: &mut [f32],
    dw: &mut [f32],
    threads: usize,
) {
    let mut parts = Vec::new();
    matmul_nt_grads_mt_with(x, n, n_in, wts, n_out, dy, dx, dw, threads, &mut parts);
}

/// [`matmul_nt_grads_mt`] with a caller-owned per-shard gradient buffer
/// (cleared and zero-filled here, so each shard still accumulates into
/// zeros exactly like the allocating variant — note `dw` itself keeps
/// its accumulate semantics and is NOT zeroed).
#[allow(clippy::too_many_arguments)]
pub fn matmul_nt_grads_mt_with(
    x: &[f32],
    n: usize,
    n_in: usize,
    wts: &[f32],
    n_out: usize,
    dy: &[f32],
    dx: &mut [f32],
    dw: &mut [f32],
    threads: usize,
    parts: &mut Vec<f32>,
) {
    assert_eq!(x.len(), n * n_in, "matmul-grad input geometry");
    assert_eq!(dy.len(), n * n_out, "matmul-grad dy geometry");
    assert_eq!(dx.len(), n * n_in, "matmul-grad dx geometry");
    assert_eq!(dw.len(), n_out * n_in, "matmul-grad dw geometry");
    let threads = par::threads_for(2 * n * n_in * n_out, threads);
    let ranges = par::shard_ranges(n, par::SHARDS);
    parts.clear();
    parts.resize(ranges.len() * dw.len(), 0.0);
    {
        let dxs = par::split_rows(dx, &ranges, n_in);
        let ctxs: Vec<_> = ranges
            .iter()
            .cloned()
            .zip(dxs)
            .zip(parts.chunks_mut(dw.len().max(1)))
            .map(|((r, dxc), dwc)| (r, dxc, dwc))
            .collect();
        par::run(threads, ctxs, |_, (r, dxc, dwc)| {
            matmul_nt_grads(
                &x[r.start * n_in..r.end * n_in],
                r.end - r.start,
                n_in,
                wts,
                n_out,
                &dy[r.start * n_out..r.end * n_out],
                dxc,
                dwc,
            );
        });
    }
    let t_reduce = std::time::Instant::now();
    for part in parts.chunks(dw.len().max(1)) {
        for (d, &p) in dw.iter_mut().zip(part) {
            *d += p;
        }
    }
    par::add_reduce_ns(t_reduce.elapsed().as_nanos() as u64);
}

/// 2x2/stride-2 max pool over `(n, c, h, w)` maps; writes
/// `(n, c, h/2, w/2)` into `out` (odd trailing rows/cols dropped, like
/// `SpikeMap::maxpool2`).
pub fn maxpool2(x: &[f32], n: usize, c: usize, h: usize, w: usize, out: &mut [f32]) {
    let (oh, ow) = (h / 2, w / 2);
    assert_eq!(out.len(), n * c * oh * ow, "pool output geometry");
    for m in 0..n * c {
        let xi = &x[m * h * w..(m + 1) * h * w];
        let oi = &mut out[m * oh * ow..(m + 1) * oh * ow];
        for y in 0..oh {
            for xx in 0..ow {
                let base = 2 * y * w + 2 * xx;
                let v = xi[base]
                    .max(xi[base + 1])
                    .max(xi[base + w])
                    .max(xi[base + w + 1]);
                oi[y * ow + xx] = v;
            }
        }
    }
}

/// Backward of [`maxpool2`]: routes each pooled gradient to the FIRST
/// element of its 2x2 window equal to the max (scan order (0,0), (0,1),
/// (1,0), (1,1)).  `dx` is zeroed here.
#[allow(clippy::too_many_arguments)]
pub fn maxpool2_grads(
    x: &[f32],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    pooled: &[f32],
    dy: &[f32],
    dx: &mut [f32],
) {
    let (oh, ow) = (h / 2, w / 2);
    dx.fill(0.0);
    for m in 0..n * c {
        let xi = &x[m * h * w..(m + 1) * h * w];
        let pi = &pooled[m * oh * ow..(m + 1) * oh * ow];
        let di = &dy[m * oh * ow..(m + 1) * oh * ow];
        let gi = &mut dx[m * h * w..(m + 1) * h * w];
        for y in 0..oh {
            for xx in 0..ow {
                let j = y * ow + xx;
                let base = 2 * y * w + 2 * xx;
                let top = pi[j];
                for off in [0, 1, w, w + 1] {
                    if xi[base + off] == top {
                        gi[base + off] += di[j];
                        break;
                    }
                }
            }
        }
    }
}

/// Mean softmax cross-entropy of `logits / t_scale` against integer
/// labels.  Returns the loss and writes `dlogits` (gradient wrt the RAW
/// logits, i.e. already divided by `n * t_scale`).
pub fn softmax_ce(
    logits: &[f32],
    n: usize,
    classes: usize,
    labels: &[usize],
    t_scale: f32,
    dlogits: &mut [f32],
) -> f32 {
    assert_eq!(logits.len(), n * classes, "logit geometry");
    assert_eq!(labels.len(), n, "label count");
    let mut loss = 0.0f64;
    for r in 0..n {
        let row = &logits[r * classes..(r + 1) * classes];
        let drow = &mut dlogits[r * classes..(r + 1) * classes];
        let mut mx = f32::NEG_INFINITY;
        for &v in row {
            mx = mx.max(v / t_scale);
        }
        let mut denom = 0.0f32;
        for (j, &v) in row.iter().enumerate() {
            let e = ((v / t_scale) - mx).exp();
            drow[j] = e;
            denom += e;
        }
        for d in drow.iter_mut() {
            *d /= denom;
        }
        loss -= (drow[labels[r]].max(1e-30) as f64).ln();
        drow[labels[r]] -= 1.0;
        for d in drow.iter_mut() {
            *d /= n as f32 * t_scale;
        }
    }
    (loss / n as f64) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_identity_kernel() {
        // 1x1 kernel of +1 is the identity.
        let x: Vec<f32> = (0..12).map(|v| v as f32).collect();
        let mut out = vec![0.0; 12];
        conv2d_same(&x, 1, 1, 3, 4, &[1.0], 1, 1, &mut out);
        assert_eq!(x, out);
    }

    #[test]
    fn conv_same_padding_edges() {
        // 3x3 all-ones kernel on a 3x3 all-ones image: corner sees 4,
        // edge 6, center 9.
        let x = vec![1.0f32; 9];
        let mut out = vec![0.0; 9];
        conv2d_same(&x, 1, 1, 3, 3, &[1.0; 9], 1, 3, &mut out);
        assert_eq!(out, vec![4.0, 6.0, 4.0, 6.0, 9.0, 6.0, 4.0, 6.0, 4.0]);
    }

    #[test]
    fn conv_grads_match_fd() {
        // Central finite differences on a small conv, f32 with a loose
        // but discriminating gate.
        let mut rng = crate::util::rng::SplitMix64::new(5);
        let mut draw = |n: usize| -> Vec<f32> {
            (0..n).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect()
        };
        let (n, c_in, h, w, c_out, k) = (2, 2, 4, 4, 3, 3);
        let x = draw(n * c_in * h * w);
        let wts = draw(c_out * c_in * k * k);
        let r = draw(n * c_out * h * w); // random cotangent
        let loss = |x: &[f32], wts: &[f32]| -> f64 {
            let mut out = vec![0.0; n * c_out * h * w];
            conv2d_same(x, n, c_in, h, w, wts, c_out, k, &mut out);
            out.iter().zip(&r).map(|(&o, &g)| (o * g) as f64).sum()
        };
        let mut dx = vec![0.0; x.len()];
        let mut dw = vec![0.0; wts.len()];
        conv2d_same_grads(&x, n, c_in, h, w, &wts, c_out, k, &r, &mut dx, &mut dw);
        let eps = 1e-2f32;
        for idx in [0usize, 7, 31, 63] {
            let mut xp = x.clone();
            xp[idx] += eps;
            let mut xm = x.clone();
            xm[idx] -= eps;
            let fd = (loss(&xp, &wts) - loss(&xm, &wts)) / (2.0 * eps as f64);
            assert!((fd - dx[idx] as f64).abs() < 1e-2, "dx[{idx}] {fd} vs {}", dx[idx]);
        }
        for idx in [0usize, 10, 26] {
            let mut wp = wts.clone();
            wp[idx] += eps;
            let mut wm = wts.clone();
            wm[idx] -= eps;
            let fd = (loss(&x, &wp) - loss(&x, &wm)) / (2.0 * eps as f64);
            assert!((fd - dw[idx] as f64).abs() < 1e-2, "dw[{idx}] {fd} vs {}", dw[idx]);
        }
    }

    #[test]
    fn maxpool_routes_gradient_to_first_max() {
        let x = vec![1.0, 3.0, 3.0, 2.0]; // 2x2 window, max 3 at index 1
        let mut out = vec![0.0; 1];
        maxpool2(&x, 1, 1, 2, 2, &mut out);
        assert_eq!(out[0], 3.0);
        let mut dx = vec![0.0; 4];
        maxpool2_grads(&x, 1, 1, 2, 2, &out, &[5.0], &mut dx);
        assert_eq!(dx, vec![0.0, 5.0, 0.0, 0.0]); // first max wins
    }

    /// Unblocked per-element reference: same `(c_in, kh, kw)` summation
    /// order as the production kernel, one output element at a time.
    #[allow(clippy::too_many_arguments)]
    fn conv_naive(
        x: &[f32],
        n: usize,
        ci: usize,
        h: usize,
        w: usize,
        wts: &[f32],
        co: usize,
        k: usize,
        out: &mut [f32],
    ) {
        let pad = (k / 2) as isize;
        let hw = h * w;
        for (idx, ov) in out.iter_mut().enumerate().take(n * co * hw) {
            let img = idx / (co * hw);
            let o = (idx / hw) % co;
            let y = ((idx % hw) / w) as isize;
            let xx = (idx % w) as isize;
            let mut acc = 0.0f32;
            for i in 0..ci {
                for kh in 0..k {
                    for kw in 0..k {
                        let sy = y + kh as isize - pad;
                        let sx = xx + kw as isize - pad;
                        if sy < 0 || sy >= h as isize || sx < 0 || sx >= w as isize {
                            continue;
                        }
                        let wv = wts[((o * ci + i) * k + kh) * k + kw];
                        let xi = (img * ci + i) * hw + sy as usize * w + sx as usize;
                        acc += wv * x[xi];
                    }
                }
            }
            *ov = acc;
        }
    }

    fn draw(rng: &mut crate::util::rng::SplitMix64, n: usize) -> Vec<f32> {
        (0..n).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect()
    }

    #[test]
    fn blocked_conv_is_bit_exact_vs_naive() {
        let mut rng = crate::util::rng::SplitMix64::new(17);
        for (n, ci, co, k, h, w) in [(2, 3, 7, 3, 5, 6), (1, 1, 4, 1, 4, 4), (3, 2, 5, 3, 3, 3)] {
            let x = draw(&mut rng, n * ci * h * w);
            let wts = draw(&mut rng, co * ci * k * k);
            let mut a = vec![0.0f32; n * co * h * w];
            let mut b = a.clone();
            conv2d_same(&x, n, ci, h, w, &wts, co, k, &mut a);
            conv_naive(&x, n, ci, h, w, &wts, co, k, &mut b);
            assert_eq!(a, b, "blocked conv must match the naive order bitwise");
        }
    }

    #[test]
    fn blocked_matmul_is_bit_exact_vs_naive() {
        let mut rng = crate::util::rng::SplitMix64::new(23);
        for (n, n_in, n_out) in [(3, 17, 9), (2, 8, 4), (1, 5, 3)] {
            let x = draw(&mut rng, n * n_in);
            let wts = draw(&mut rng, n_out * n_in);
            let mut a = vec![0.0f32; n * n_out];
            matmul_nt(&x, n, n_in, &wts, n_out, &mut a);
            for r in 0..n {
                for o in 0..n_out {
                    let mut acc = 0.0f32;
                    for j in 0..n_in {
                        acc += x[r * n_in + j] * wts[o * n_in + j];
                    }
                    assert_eq!(a[r * n_out + o], acc, "row {r} out {o}");
                }
            }
        }
    }

    #[test]
    fn blocked_matmul_grads_match_unblocked_order() {
        // Reference: the pre-blocking loop (per row: skip zero grads,
        // accumulate dx then dw output-by-output).
        let mut rng = crate::util::rng::SplitMix64::new(29);
        let (n, n_in, n_out) = (4, 11, 5);
        let x = draw(&mut rng, n * n_in);
        let wts = draw(&mut rng, n_out * n_in);
        let mut dy = draw(&mut rng, n * n_out);
        dy[2] = 0.0; // exercise the zero-skip paths
        dy[7] = 0.0;
        dy[8] = 0.0;
        let mut dx = vec![0.0f32; n * n_in];
        let mut dw = vec![0.0f32; n_out * n_in];
        matmul_nt_grads(&x, n, n_in, &wts, n_out, &dy, &mut dx, &mut dw);
        let mut dx_ref = vec![0.0f32; n * n_in];
        let mut dw_ref = vec![0.0f32; n_out * n_in];
        for r in 0..n {
            for o in 0..n_out {
                let g = dy[r * n_out + o];
                if g == 0.0 {
                    continue;
                }
                for j in 0..n_in {
                    dx_ref[r * n_in + j] += g * wts[o * n_in + j];
                    dw_ref[o * n_in + j] += g * x[r * n_in + j];
                }
            }
        }
        assert_eq!(dx, dx_ref);
        assert_eq!(dw, dw_ref);
    }

    #[test]
    fn mt_kernels_identical_for_every_thread_count() {
        let mut rng = crate::util::rng::SplitMix64::new(31);
        let (n, ci, co, k, h, w) = (9, 2, 5, 3, 4, 4);
        let x = draw(&mut rng, n * ci * h * w);
        let wts = draw(&mut rng, co * ci * k * k);
        let dy = draw(&mut rng, n * co * h * w);
        let run = |threads: usize| {
            let mut out = vec![0.0f32; n * co * h * w];
            conv2d_same_mt(&x, n, ci, h, w, &wts, co, k, &mut out, threads);
            let mut dx = vec![0.0f32; x.len()];
            let mut dw = vec![0.0f32; wts.len()];
            conv2d_same_grads_mt(&x, n, ci, h, w, &wts, co, k, &dy, &mut dx, &mut dw, threads);
            (out, dx, dw)
        };
        let base = run(1);
        for t in [2, 3, 4, 8] {
            assert_eq!(base, run(t), "conv results must not depend on threads={t}");
        }

        let (mn, m_in, m_out) = (10, 13, 6);
        let mx = draw(&mut rng, mn * m_in);
        let mw = draw(&mut rng, m_out * m_in);
        let mdy = draw(&mut rng, mn * m_out);
        let runm = |threads: usize| {
            let mut out = vec![0.0f32; mn * m_out];
            matmul_nt_mt(&mx, mn, m_in, &mw, m_out, &mut out, threads);
            let mut dx = vec![0.0f32; mx.len()];
            let mut dw = vec![0.0f32; mw.len()];
            matmul_nt_grads_mt(&mx, mn, m_in, &mw, m_out, &mdy, &mut dx, &mut dw, threads);
            (out, dx, dw)
        };
        let mbase = runm(1);
        for t in [2, 4, 7] {
            assert_eq!(mbase, runm(t), "matmul results must not depend on threads={t}");
        }
    }

    /// PR10 bugfix regression: the `_with` variants recycling one parts
    /// buffer across calls (stale capacity from a LARGER previous call)
    /// are bit-identical to the allocating `_mt` kernels.
    #[test]
    fn grads_mt_with_recycled_parts_is_bit_exact() {
        let mut rng = crate::util::rng::SplitMix64::new(41);
        let (n, ci, co, k, h, w) = (7, 2, 4, 3, 4, 4);
        let x = draw(&mut rng, n * ci * h * w);
        let wts = draw(&mut rng, co * ci * k * k);
        let dy = draw(&mut rng, n * co * h * w);
        let mut parts = vec![f32::NAN; 1 << 16]; // poisoned, oversized
        for threads in [1usize, 3] {
            let mut dx_a = vec![0.0f32; x.len()];
            let mut dw_a = vec![0.0f32; wts.len()];
            conv2d_same_grads_mt(&x, n, ci, h, w, &wts, co, k, &dy, &mut dx_a, &mut dw_a, threads);
            let mut dx_b = vec![0.0f32; x.len()];
            let mut dw_b = vec![0.0f32; wts.len()];
            conv2d_same_grads_mt_with(
                &x, n, ci, h, w, &wts, co, k, &dy, &mut dx_b, &mut dw_b, threads, &mut parts,
            );
            assert_eq!((dx_a, dw_a), (dx_b, dw_b), "conv threads={threads}");
        }
        let (mn, m_in, m_out) = (9, 6, 5);
        let mx = draw(&mut rng, mn * m_in);
        let mw = draw(&mut rng, m_out * m_in);
        let mdy = draw(&mut rng, mn * m_out);
        for threads in [1usize, 4] {
            let mut dx_a = vec![0.0f32; mx.len()];
            let mut dw_a = vec![0.5f32; mw.len()]; // accumulate semantics
            matmul_nt_grads_mt(&mx, mn, m_in, &mw, m_out, &mdy, &mut dx_a, &mut dw_a, threads);
            let mut dx_b = vec![0.0f32; mx.len()];
            let mut dw_b = vec![0.5f32; mw.len()];
            matmul_nt_grads_mt_with(
                &mx, mn, m_in, &mw, m_out, &mdy, &mut dx_b, &mut dw_b, threads, &mut parts,
            );
            assert_eq!((dx_a, dw_a), (dx_b, dw_b), "matmul threads={threads}");
        }
    }

    #[test]
    fn softmax_ce_gradient_sums_to_zero() {
        let logits = vec![2.0f32, -1.0, 0.5, 0.0, 0.0, 4.0];
        let mut d = vec![0.0; 6];
        let loss = softmax_ce(&logits, 2, 3, &[0, 2], 2.0, &mut d);
        assert!(loss > 0.0);
        for r in 0..2 {
            let s: f32 = d[r * 3..(r + 1) * 3].iter().sum();
            assert!(s.abs() < 1e-6, "per-row gradient sums to 0, got {s}");
        }
    }
}

//! Minimal dense f32 kernels for training — just the ops STBP needs.
//!
//! Everything operates on flat `&[f32]` buffers with explicit dimensions
//! (the same convention as `snn::conv`), single-threaded and in a fixed
//! iteration order so training runs are byte-reproducible per seed.
//! Reductions accumulate in f64: cheap at these sizes and it keeps batch
//! statistics stable regardless of batch layout.

/// SAME-padded stride-1 2-D convolution.
///
/// `x` is `(n, c_in, h, w)`, `w` is `(c_out, c_in, k, k)` (both row-major);
/// the result lands in `out` as `(n, c_out, h, w)`.  Matches
/// `python/compile/kernels/ref.py::conv2d_binary` (pad `k/2` on each side).
pub fn conv2d_same(
    x: &[f32],
    n: usize,
    c_in: usize,
    h: usize,
    w: usize,
    wts: &[f32],
    c_out: usize,
    k: usize,
    out: &mut [f32],
) {
    assert_eq!(x.len(), n * c_in * h * w, "conv input geometry");
    assert_eq!(wts.len(), c_out * c_in * k * k, "conv weight geometry");
    assert_eq!(out.len(), n * c_out * h * w, "conv output geometry");
    let pad = (k / 2) as isize;
    let hw = h * w;
    out.fill(0.0);
    for img in 0..n {
        let xin = &x[img * c_in * hw..(img + 1) * c_in * hw];
        let xout = &mut out[img * c_out * hw..(img + 1) * c_out * hw];
        for o in 0..c_out {
            for i in 0..c_in {
                let plane = &xin[i * hw..(i + 1) * hw];
                for kh in 0..k {
                    for kw in 0..k {
                        let wv = wts[((o * c_in + i) * k + kh) * k + kw];
                        let dy = kh as isize - pad;
                        let dx = kw as isize - pad;
                        let y0 = (-dy).max(0) as usize;
                        let y1 = (h as isize - dy).clamp(0, h as isize) as usize;
                        let x0 = (-dx).max(0) as usize;
                        let x1 = (w as isize - dx).clamp(0, w as isize) as usize;
                        for y in y0..y1 {
                            let src = ((y as isize + dy) as usize) * w;
                            let dst = o * hw + y * w;
                            for xx in x0..x1 {
                                xout[dst + xx] +=
                                    wv * plane[src + (xx as isize + dx) as usize];
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Gradients of [`conv2d_same`]: `dy` is `(n, c_out, h, w)`; accumulates
/// the input gradient into `dx` (same shape as `x`, zeroed here) and the
/// weight gradient into `dw` (same shape as `wts`, zeroed here).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_same_grads(
    x: &[f32],
    n: usize,
    c_in: usize,
    h: usize,
    w: usize,
    wts: &[f32],
    c_out: usize,
    k: usize,
    dy: &[f32],
    dx: &mut [f32],
    dw: &mut [f32],
) {
    let pad = (k / 2) as isize;
    let hw = h * w;
    dx.fill(0.0);
    dw.fill(0.0);
    for img in 0..n {
        let xin = &x[img * c_in * hw..(img + 1) * c_in * hw];
        let dyi = &dy[img * c_out * hw..(img + 1) * c_out * hw];
        let dxi = &mut dx[img * c_in * hw..(img + 1) * c_in * hw];
        for o in 0..c_out {
            let dplane = &dyi[o * hw..(o + 1) * hw];
            for i in 0..c_in {
                let plane = &xin[i * hw..(i + 1) * hw];
                let gplane = &mut dxi[i * hw..(i + 1) * hw];
                for kh in 0..k {
                    for kw in 0..k {
                        let widx = ((o * c_in + i) * k + kh) * k + kw;
                        let wv = wts[widx];
                        let dyk = kh as isize - pad;
                        let dxk = kw as isize - pad;
                        let y0 = (-dyk).max(0) as usize;
                        let y1 = (h as isize - dyk).clamp(0, h as isize) as usize;
                        let x0 = (-dxk).max(0) as usize;
                        let x1 = (w as isize - dxk).clamp(0, w as isize) as usize;
                        let mut acc = 0.0f32;
                        for y in y0..y1 {
                            let src = ((y as isize + dyk) as usize) * w;
                            let dst = y * w;
                            for xx in x0..x1 {
                                let xi = src + (xx as isize + dxk) as usize;
                                let g = dplane[dst + xx];
                                acc += g * plane[xi];
                                gplane[xi] += g * wv;
                            }
                        }
                        dw[widx] += acc;
                    }
                }
            }
        }
    }
}

/// Dense layer forward: `x` is `(n, n_in)`, `wts` is `(n_out, n_in)`;
/// writes `out = x @ wts^T` as `(n, n_out)`.
pub fn matmul_nt(x: &[f32], n: usize, n_in: usize, wts: &[f32], n_out: usize, out: &mut [f32]) {
    assert_eq!(x.len(), n * n_in, "matmul input geometry");
    assert_eq!(wts.len(), n_out * n_in, "matmul weight geometry");
    assert_eq!(out.len(), n * n_out, "matmul output geometry");
    for r in 0..n {
        let xi = &x[r * n_in..(r + 1) * n_in];
        let oi = &mut out[r * n_out..(r + 1) * n_out];
        for (o, ov) in oi.iter_mut().enumerate() {
            let wr = &wts[o * n_in..(o + 1) * n_in];
            let mut acc = 0.0f32;
            for (a, b) in xi.iter().zip(wr) {
                acc += a * b;
            }
            *ov = acc;
        }
    }
}

/// Gradients of [`matmul_nt`]: accumulates `dx = dy @ wts` (zeroed here)
/// and `dw += dy^T @ x` (NOT zeroed — fc layers sum over time steps).
pub fn matmul_nt_grads(
    x: &[f32],
    n: usize,
    n_in: usize,
    wts: &[f32],
    n_out: usize,
    dy: &[f32],
    dx: &mut [f32],
    dw: &mut [f32],
) {
    dx.fill(0.0);
    for r in 0..n {
        let xi = &x[r * n_in..(r + 1) * n_in];
        let dyi = &dy[r * n_out..(r + 1) * n_out];
        let dxi = &mut dx[r * n_in..(r + 1) * n_in];
        for (o, &g) in dyi.iter().enumerate() {
            if g == 0.0 {
                continue;
            }
            let wr = &wts[o * n_in..(o + 1) * n_in];
            let dwr = &mut dw[o * n_in..(o + 1) * n_in];
            for j in 0..n_in {
                dxi[j] += g * wr[j];
                dwr[j] += g * xi[j];
            }
        }
    }
}

/// 2x2/stride-2 max pool over `(n, c, h, w)` maps; writes
/// `(n, c, h/2, w/2)` into `out` (odd trailing rows/cols dropped, like
/// `SpikeMap::maxpool2`).
pub fn maxpool2(x: &[f32], n: usize, c: usize, h: usize, w: usize, out: &mut [f32]) {
    let (oh, ow) = (h / 2, w / 2);
    assert_eq!(out.len(), n * c * oh * ow, "pool output geometry");
    for m in 0..n * c {
        let xi = &x[m * h * w..(m + 1) * h * w];
        let oi = &mut out[m * oh * ow..(m + 1) * oh * ow];
        for y in 0..oh {
            for xx in 0..ow {
                let base = 2 * y * w + 2 * xx;
                let v = xi[base]
                    .max(xi[base + 1])
                    .max(xi[base + w])
                    .max(xi[base + w + 1]);
                oi[y * ow + xx] = v;
            }
        }
    }
}

/// Backward of [`maxpool2`]: routes each pooled gradient to the FIRST
/// element of its 2x2 window equal to the max (scan order (0,0), (0,1),
/// (1,0), (1,1)).  `dx` is zeroed here.
#[allow(clippy::too_many_arguments)]
pub fn maxpool2_grads(
    x: &[f32],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    pooled: &[f32],
    dy: &[f32],
    dx: &mut [f32],
) {
    let (oh, ow) = (h / 2, w / 2);
    dx.fill(0.0);
    for m in 0..n * c {
        let xi = &x[m * h * w..(m + 1) * h * w];
        let pi = &pooled[m * oh * ow..(m + 1) * oh * ow];
        let di = &dy[m * oh * ow..(m + 1) * oh * ow];
        let gi = &mut dx[m * h * w..(m + 1) * h * w];
        for y in 0..oh {
            for xx in 0..ow {
                let j = y * ow + xx;
                let base = 2 * y * w + 2 * xx;
                let top = pi[j];
                for off in [0, 1, w, w + 1] {
                    if xi[base + off] == top {
                        gi[base + off] += di[j];
                        break;
                    }
                }
            }
        }
    }
}

/// Mean softmax cross-entropy of `logits / t_scale` against integer
/// labels.  Returns the loss and writes `dlogits` (gradient wrt the RAW
/// logits, i.e. already divided by `n * t_scale`).
pub fn softmax_ce(
    logits: &[f32],
    n: usize,
    classes: usize,
    labels: &[usize],
    t_scale: f32,
    dlogits: &mut [f32],
) -> f32 {
    assert_eq!(logits.len(), n * classes, "logit geometry");
    assert_eq!(labels.len(), n, "label count");
    let mut loss = 0.0f64;
    for r in 0..n {
        let row = &logits[r * classes..(r + 1) * classes];
        let drow = &mut dlogits[r * classes..(r + 1) * classes];
        let mut mx = f32::NEG_INFINITY;
        for &v in row {
            mx = mx.max(v / t_scale);
        }
        let mut denom = 0.0f32;
        for (j, &v) in row.iter().enumerate() {
            let e = ((v / t_scale) - mx).exp();
            drow[j] = e;
            denom += e;
        }
        for d in drow.iter_mut() {
            *d /= denom;
        }
        loss -= (drow[labels[r]].max(1e-30) as f64).ln();
        drow[labels[r]] -= 1.0;
        for d in drow.iter_mut() {
            *d /= n as f32 * t_scale;
        }
    }
    (loss / n as f64) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_identity_kernel() {
        // 1x1 kernel of +1 is the identity.
        let x: Vec<f32> = (0..12).map(|v| v as f32).collect();
        let mut out = vec![0.0; 12];
        conv2d_same(&x, 1, 1, 3, 4, &[1.0], 1, 1, &mut out);
        assert_eq!(x, out);
    }

    #[test]
    fn conv_same_padding_edges() {
        // 3x3 all-ones kernel on a 3x3 all-ones image: corner sees 4,
        // edge 6, center 9.
        let x = vec![1.0f32; 9];
        let mut out = vec![0.0; 9];
        conv2d_same(&x, 1, 1, 3, 3, &[1.0; 9], 1, 3, &mut out);
        assert_eq!(out, vec![4.0, 6.0, 4.0, 6.0, 9.0, 6.0, 4.0, 6.0, 4.0]);
    }

    #[test]
    fn conv_grads_match_fd() {
        // Central finite differences on a small conv, f32 with a loose
        // but discriminating gate.
        let mut rng = crate::util::rng::SplitMix64::new(5);
        let mut draw = |n: usize| -> Vec<f32> {
            (0..n).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect()
        };
        let (n, c_in, h, w, c_out, k) = (2, 2, 4, 4, 3, 3);
        let x = draw(n * c_in * h * w);
        let wts = draw(c_out * c_in * k * k);
        let r = draw(n * c_out * h * w); // random cotangent
        let loss = |x: &[f32], wts: &[f32]| -> f64 {
            let mut out = vec![0.0; n * c_out * h * w];
            conv2d_same(x, n, c_in, h, w, wts, c_out, k, &mut out);
            out.iter().zip(&r).map(|(&o, &g)| (o * g) as f64).sum()
        };
        let mut dx = vec![0.0; x.len()];
        let mut dw = vec![0.0; wts.len()];
        conv2d_same_grads(&x, n, c_in, h, w, &wts, c_out, k, &r, &mut dx, &mut dw);
        let eps = 1e-2f32;
        for idx in [0usize, 7, 31, 63] {
            let mut xp = x.clone();
            xp[idx] += eps;
            let mut xm = x.clone();
            xm[idx] -= eps;
            let fd = (loss(&xp, &wts) - loss(&xm, &wts)) / (2.0 * eps as f64);
            assert!((fd - dx[idx] as f64).abs() < 1e-2, "dx[{idx}] {fd} vs {}", dx[idx]);
        }
        for idx in [0usize, 10, 26] {
            let mut wp = wts.clone();
            wp[idx] += eps;
            let mut wm = wts.clone();
            wm[idx] -= eps;
            let fd = (loss(&x, &wp) - loss(&x, &wm)) / (2.0 * eps as f64);
            assert!((fd - dw[idx] as f64).abs() < 1e-2, "dw[{idx}] {fd} vs {}", dw[idx]);
        }
    }

    #[test]
    fn maxpool_routes_gradient_to_first_max() {
        let x = vec![1.0, 3.0, 3.0, 2.0]; // 2x2 window, max 3 at index 1
        let mut out = vec![0.0; 1];
        maxpool2(&x, 1, 1, 2, 2, &mut out);
        assert_eq!(out[0], 3.0);
        let mut dx = vec![0.0; 4];
        maxpool2_grads(&x, 1, 1, 2, 2, &out, &[5.0], &mut dx);
        assert_eq!(dx, vec![0.0, 5.0, 0.0, 0.0]); // first max wins
    }

    #[test]
    fn softmax_ce_gradient_sums_to_zero() {
        let logits = vec![2.0f32, -1.0, 0.5, 0.0, 0.0, 4.0];
        let mut d = vec![0.0; 6];
        let loss = softmax_ce(&logits, 2, 3, &[0, 2], 2.0, &mut d);
        assert!(loss > 0.0);
        for r in 0..2 {
            let s: f32 = d[r * 3..(r + 1) * 3].iter().sum();
            assert!(s.abs() < 1e-6, "per-row gradient sums to 0, got {s}");
        }
    }
}

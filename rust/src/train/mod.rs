//! In-repo STBP training of the binary-weight spiking models (paper
//! §II) — no external ML stack, just f32 loops over the repo's own
//! datasets, producing deployable VSAW artifacts.
//!
//! The paper's contribution is algorithm/hardware co-design: a
//! binary-weight SNN with IF-based BatchNorm trained *directly* with
//! spatio-temporal backprop at small T, which the VSA chip then
//! executes.  This module is the algorithm half in Rust:
//!
//! * [`tensor`] — the dense f32 kernels training needs (SAME conv,
//!   dense matmul, 2x2 max pool, softmax cross-entropy) with hand-rolled
//!   backward passes;
//! * [`stbp`] — the trainable network and forward/backward through the
//!   T time steps with a rectangular surrogate for the IF spike;
//! * [`binarize`] — sign() weights forward, straight-through backward;
//! * [`ifbn`] — train-time BatchNorm folded into per-channel integer IF
//!   thresholds at export (paper Eq. (4));
//! * [`optim`] — momentum SGD with a cosine schedule;
//! * [`export`] — fold + binarize + serialize into the VSAW v1 format
//!   [`crate::snn::Network`] loads, closing the `vsa train → vsa infer →
//!   vsa dse` loop on one artifact.
//!
//! Everything is seeded from one `SplitMix64` stream and runs in a
//! fixed order — including under `--threads N` batch parallelism
//! ([`par`]: fixed work shards, per-shard gradient buffers reduced in
//! fixed shard order): training is **byte-reproducible** — the same
//! `(model, T, dataset, hyperparameters, seed)` produce a
//! byte-identical artifact on every run at every thread count (see
//! README §TRAINING).

pub mod binarize;
pub mod export;
pub mod ifbn;
pub mod optim;
pub mod par;
pub mod stbp;
pub mod tensor;

pub use export::{deploy, deploy_with_eps, write_artifact};
pub use stbp::{Net, SpikeMode, TrainArena};

use crate::config::models::{self, ModelSpec};
use crate::data::{idx, synth, Sample};
use crate::snn::params::DeployedModel;
use crate::snn::{Network, Scratch};
use crate::telemetry::spans::{pids, SpanCollector};
use crate::telemetry::Registry;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Span-ring capacity for the trainer recorder (~6 records per step;
/// overflow keeps the latest and is counted in the export).
const TRAIN_RING_CAP: usize = 1 << 16;

/// Training data source.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dataset {
    /// The deterministic synthetic corpus (`data::synth`), generated on
    /// the fly in the model's input geometry — always available.
    Synth,
    /// Real MNIST IDX files under `data/mnist/` (train split for
    /// training, t10k for held-out eval); requires the files on disk.
    Mnist,
}

/// Hyperparameters of one training run.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Model preset (`config::models::by_name`).
    pub model: String,
    /// Time steps T.
    pub num_steps: usize,
    pub dataset: Dataset,
    pub epochs: usize,
    /// Batches per epoch for the (infinite) synthetic corpus; MNIST
    /// derives it from the dataset size instead.
    pub batches_per_epoch: usize,
    pub batch: usize,
    /// Base learning rate (cosine-annealed to 0 across the run).
    pub lr: f64,
    pub momentum: f32,
    pub seed: u64,
    /// Print a progress line every N steps (0 = silent).
    pub log_every: usize,
    /// Worker threads for the batch-parallel hot path (1 = in-line).
    /// Artifacts are byte-identical for every value (see [`par`]).
    pub threads: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            model: "tiny".into(),
            num_steps: 4,
            dataset: Dataset::Synth,
            epochs: 6,
            batches_per_epoch: 50,
            batch: 32,
            lr: 0.1,
            momentum: 0.9,
            seed: 7,
            log_every: 25,
            threads: 1,
        }
    }
}

/// Result of a training run.
pub struct TrainOutcome {
    pub net: Net,
    pub steps: usize,
    pub final_loss: f32,
    /// Training-batch accuracy of the last step.
    pub final_batch_acc: f64,
    /// Whole-run wall-time phase breakdown (telemetry, PR7).
    pub phases: PhaseTimes,
}

/// Wall-time phase breakdown of a training run: where the steps spend
/// their time (README §OBSERVABILITY).  Printed per epoch when
/// `log_every > 0` and exportable into a `telemetry::Registry`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PhaseTimes {
    /// Batch assembly (`load_batch`).
    pub load: Duration,
    /// Forward pass including the softmax-CE loss.
    pub forward: Duration,
    /// Backward pass (surrogate-gradient STBP).
    pub backward: Duration,
    /// Fixed-order gradient reduction inside the `_mt` kernels — a
    /// *subset* of forward/backward wall time sampled from
    /// [`par::take_reduce_ns`], and best-effort when several `train()`
    /// calls share the process (the counter is global).
    pub reduce: Duration,
    /// Optimizer step + BN EMA fold.
    pub optim: Duration,
}

impl PhaseTimes {
    fn add(&mut self, o: &PhaseTimes) {
        self.load += o.load;
        self.forward += o.forward;
        self.backward += o.backward;
        self.reduce += o.reduce;
        self.optim += o.optim;
    }

    /// One-line rendering in milliseconds.
    pub fn render(&self) -> String {
        let ms = |d: Duration| d.as_secs_f64() * 1e3;
        format!(
            "load {:.1} fwd {:.1} bwd {:.1} (reduce {:.1}) optim {:.1} ms",
            ms(self.load),
            ms(self.forward),
            ms(self.backward),
            ms(self.reduce),
            ms(self.optim)
        )
    }

    /// Publish the phase totals as `{prefix}.phase.*_ms` gauges.
    pub fn export_into(&self, reg: &Registry, prefix: &str) {
        let ms = |d: Duration| d.as_secs_f64() * 1e3;
        reg.set_gauge(&format!("{prefix}.phase.load_ms"), ms(self.load));
        reg.set_gauge(&format!("{prefix}.phase.forward_ms"), ms(self.forward));
        reg.set_gauge(&format!("{prefix}.phase.backward_ms"), ms(self.backward));
        reg.set_gauge(&format!("{prefix}.phase.reduce_ms"), ms(self.reduce));
        reg.set_gauge(&format!("{prefix}.phase.optim_ms"), ms(self.optim));
    }
}

/// Re-exported from `util::stats` (one definition since PR4): f32
/// argmax under the IEEE total order — NaN can no longer make every
/// comparison fail and silently return index 0.
pub use crate::util::stats::argmax_f32;

/// Rows of `(n, classes)` logits whose argmax matches the label.  A row
/// containing ANY non-finite logit (diverged run) never counts as
/// correct — the NaN-safety half of the `argmax_f32` fix.  The whole
/// row is scanned because under the IEEE total order a *negative* NaN
/// sorts below -inf and would otherwise hide behind a finite maximum.
pub fn count_correct(logits: &[f32], classes: usize, labels: &[usize]) -> usize {
    labels
        .iter()
        .enumerate()
        .filter(|&(r, &label)| {
            let row = &logits[r * classes..(r + 1) * classes];
            row.iter().all(|v| v.is_finite()) && argmax_f32(row) == label
        })
        .count()
}

/// Resolve the spec and run STBP training to completion.
pub fn train(cfg: &TrainConfig) -> anyhow::Result<TrainOutcome> {
    train_traced(cfg, None)
}

/// [`train`] with span tracing (PR8): when a [`SpanCollector`] is
/// attached, every step leaves an `epoch → step → load/forward/
/// backward/optim` span tree on the trainer track, built from the very
/// same `Instant` stamps as [`PhaseTimes`] — the two views agree.
pub fn train_traced(
    cfg: &TrainConfig,
    spans: Option<&Arc<SpanCollector>>,
) -> anyhow::Result<TrainOutcome> {
    let spec = models::by_name(&cfg.model, cfg.num_steps).ok_or_else(|| {
        anyhow::anyhow!("unknown model '{}' (tiny|mnist|cifar10|micro)", cfg.model)
    })?;
    anyhow::ensure!(cfg.num_steps > 0, "--steps (T) must be positive");
    anyhow::ensure!(cfg.batch > 0, "--batch must be positive");
    anyhow::ensure!(cfg.epochs > 0, "--epochs must be positive");

    let mnist_train: Option<Vec<Sample>> = match cfg.dataset {
        Dataset::Synth => None,
        Dataset::Mnist => {
            let data = idx::mnist_train_if_available(usize::MAX).ok_or_else(|| {
                anyhow::anyhow!(
                    "--dataset mnist needs data/mnist/train-images-idx3-ubyte and \
                     train-labels-idx1-ubyte (synthetic fallback: --dataset synth)"
                )
            })?;
            anyhow::ensure!(!data.is_empty(), "MNIST train split is empty");
            let s = &data[0];
            anyhow::ensure!(
                s.channels == spec.in_channels && s.size == spec.in_size,
                "MNIST geometry ({}, {}) does not match model '{}' ({}, {})",
                s.channels,
                s.size,
                spec.name,
                spec.in_channels,
                spec.in_size
            );
            Some(data)
        }
    };
    let batches_per_epoch = match &mnist_train {
        // Ceil division: the tail of the dataset forms a short final
        // batch instead of being silently dropped.
        Some(data) => (data.len() + cfg.batch - 1) / cfg.batch,
        None => cfg.batches_per_epoch.max(1),
    };
    let total_steps = cfg.epochs * batches_per_epoch;
    let threads = cfg.threads.max(1);

    let mut net = Net::init(&spec, cfg.seed);
    let mut opt = optim::Sgd::new(&net, cfg.momentum);
    let classes = net.classes();
    let plane = spec.in_channels * spec.in_size * spec.in_size;
    let mut images = vec![0.0f32; cfg.batch * plane];
    let mut labels = vec![0usize; cfg.batch];
    let mut dlogits = vec![0.0f32; cfg.batch * classes];
    let (mut final_loss, mut final_acc) = (f32::NAN, 0.0f64);
    let mut phases = PhaseTimes::default();
    let mut epoch_phases = PhaseTimes::default();
    // Clear residue another in-process run may have left in the global
    // reduce counter (observational attribution only).
    par::take_reduce_ns();
    // Reusable activation/gradient storage (PR10): after the first step
    // warms the pool the loop allocates nothing per step; every buffer
    // is handed back zero-filled, so artifacts stay byte-identical to
    // the allocating path (stbp::tests::arena_paths_are_bit_identical_
    // to_allocating_paths).
    let mut arena = TrainArena::new();
    let mut rec = spans.map(|sp| {
        sp.name_process(pids::TRAIN, "train");
        sp.name_track(pids::TRAIN, 0, "steps");
        sp.recorder(0, pids::TRAIN, 0, TRAIN_RING_CAP)
    });
    // Start of the current epoch's first step on the collector clock.
    let mut epoch_start: Option<u64> = None;

    for step in 0..total_steps {
        let t0 = Instant::now();
        let count = load_batch(
            &spec,
            cfg,
            mnist_train.as_deref(),
            step,
            batches_per_epoch,
            &mut images,
            &mut labels,
        );
        let t1 = Instant::now();
        let fwd = net.forward_with(
            &images[..count * plane],
            count,
            SpikeMode::Hard,
            true,
            threads,
            &mut arena,
        );
        let loss = tensor::softmax_ce(
            &fwd.logits,
            count,
            classes,
            &labels[..count],
            spec.num_steps as f32,
            &mut dlogits[..count * classes],
        );
        let t2 = Instant::now();
        let grads = net.backward_with(
            &fwd,
            &images[..count * plane],
            &dlogits[..count * classes],
            true,
            threads,
            &mut arena,
        );
        let t3 = Instant::now();
        let reduce = Duration::from_nanos(par::take_reduce_ns());
        opt.step(&mut net, &grads, optim::cosine_lr(cfg.lr, step, total_steps));
        net.apply_bn_ema(&fwd);
        let t4 = Instant::now();
        let step_phases = PhaseTimes {
            load: t1 - t0,
            forward: t2 - t1,
            backward: t3 - t2,
            reduce,
            optim: t4 - t3,
        };
        phases.add(&step_phases);
        epoch_phases.add(&step_phases);
        if let Some(rec) = rec.as_mut() {
            let (pid, tid) = (pids::TRAIN, 0u64);
            let s0 = rec.ns_of(t0);
            let s1 = rec.ns_of(t1);
            let s2 = rec.ns_of(t2);
            let s3 = rec.ns_of(t3);
            let s4 = rec.ns_of(t4);
            if step % batches_per_epoch == 0 {
                epoch_start = Some(s0);
            }
            let args = [("step", step as f64), ("reduce_ns", reduce.as_nanos() as f64)];
            rec.span_at(pid, tid, "step", s0, s4.saturating_sub(s0), &args, None);
            rec.span_at(pid, tid, "load", s0, s1.saturating_sub(s0), &[], None);
            rec.span_at(pid, tid, "forward", s1, s2.saturating_sub(s1), &[], None);
            rec.span_at(pid, tid, "backward", s2, s3.saturating_sub(s2), &[], None);
            rec.span_at(pid, tid, "optim", s3, s4.saturating_sub(s3), &[], None);
            if (step + 1) % batches_per_epoch == 0 {
                let e0 = epoch_start.take().unwrap_or(s0);
                let epoch = (step / batches_per_epoch) as f64;
                let dur = s4.saturating_sub(e0);
                rec.span_at(pid, tid, "epoch", e0, dur, &[("epoch", epoch)], None);
            }
        }

        let correct = count_correct(&fwd.logits, classes, &labels[..count]);
        final_loss = loss;
        final_acc = correct as f64 / count as f64;
        if cfg.log_every > 0 && (step % cfg.log_every == 0 || step + 1 == total_steps) {
            println!(
                "[train:{} T={}] step {:4}/{} loss {:.4} acc {:.3}",
                spec.name, spec.num_steps, step, total_steps, loss, final_acc
            );
        }
        if cfg.log_every > 0 && (step + 1) % batches_per_epoch == 0 {
            let epoch = step / batches_per_epoch;
            println!(
                "[train:{} T={}] epoch {epoch} {}",
                spec.name,
                spec.num_steps,
                epoch_phases.render()
            );
            epoch_phases = PhaseTimes::default();
        }
        // Everything reading fwd/grads is done — hand the storage back
        // for the next step.
        arena.recycle_grads(grads);
        arena.recycle_forward(fwd);
    }
    Ok(TrainOutcome { net, steps: total_steps, final_loss, final_batch_acc: final_acc, phases })
}

/// Fill `images`/`labels` with the samples of `step`; returns the count.
/// The MNIST branch borrows straight from the resident dataset — no
/// per-step `Sample` clones in the hot loop.
fn load_batch(
    spec: &ModelSpec,
    cfg: &TrainConfig,
    mnist: Option<&[Sample]>,
    step: usize,
    batches_per_epoch: usize,
    images: &mut [f32],
    labels: &mut [usize],
) -> usize {
    let plane = spec.in_channels * spec.in_size * spec.in_size;
    let fill = |samples: &[Sample], images: &mut [f32], labels: &mut [usize]| {
        for (r, s) in samples.iter().enumerate() {
            for (dst, &px) in images[r * plane..(r + 1) * plane].iter_mut().zip(&s.image) {
                *dst = px as f32 / 255.0;
            }
            labels[r] = s.label;
        }
        samples.len()
    };
    match mnist {
        None => {
            let samples = synth::batch(
                cfg.seed,
                (step * cfg.batch) as u64,
                cfg.batch,
                spec.in_channels,
                spec.in_size,
            );
            fill(&samples, images, labels)
        }
        Some(data) => {
            let start = (step % batches_per_epoch) * cfg.batch;
            fill(&data[start..(start + cfg.batch).min(data.len())], images, labels)
        }
    }
}

/// Held-out synthetic samples in an explicit input geometry — the ONE
/// definition of the held-out convention (shifted seed, indices from
/// 10M, disjoint from every training batch; same as
/// `compile/train.py::evaluate_deployed`).  `vsa train`'s final report,
/// `vsa eval` and the DSE accuracy objective all sample through here.
pub fn holdout_samples(channels: usize, size: usize, seed: u64, count: usize) -> Vec<Sample> {
    synth::batch(seed + 1000, 10_000_000, count, channels, size)
}

/// [`holdout_samples`] in a spec's geometry.
pub fn holdout_synth(spec: &ModelSpec, seed: u64, count: usize) -> Vec<Sample> {
    holdout_samples(spec.in_channels, spec.in_size, seed, count)
}

/// Golden-model accuracy of a deployed artifact on `samples`.
/// Returns (correct, total).
pub fn eval_golden(model: &DeployedModel, samples: &[Sample]) -> (usize, usize) {
    eval_golden_threaded(model, samples, 1)
}

/// [`eval_golden`] sharded over up to `threads` scoped workers (PR10).
/// The shard partition is fixed ([`par::shard_ranges`], independent of
/// `threads`), each worker owns a private [`Scratch`], per-sample
/// results are independent, and the per-shard counts are summed in
/// shard order — so the result is identical at every thread count.
pub fn eval_golden_threaded(
    model: &DeployedModel,
    samples: &[Sample],
    threads: usize,
) -> (usize, usize) {
    let net = Network::new(model.clone());
    let ranges = par::shard_ranges(samples.len(), par::SHARDS);
    let mut counts = vec![0usize; ranges.len()];
    let ctxs: Vec<_> = ranges
        .into_iter()
        .zip(counts.iter_mut())
        .map(|(r, slot)| (r, slot, Scratch::new()))
        .collect();
    par::run(threads.max(1), ctxs, |_s, (r, slot, mut scratch)| {
        *slot = samples[r]
            .iter()
            .filter(|s| {
                let logits = net.infer_u8_with(&s.image, &mut scratch);
                crate::util::stats::argmax(&logits) == s.label
            })
            .count();
    });
    (counts.iter().sum(), samples.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn micro_training_step_runs_and_is_deterministic() {
        let cfg = TrainConfig {
            model: "micro".into(),
            num_steps: 2,
            epochs: 1,
            batches_per_epoch: 3,
            batch: 4,
            log_every: 0,
            ..TrainConfig::default()
        };
        let a = train(&cfg).unwrap();
        let b = train(&cfg).unwrap();
        assert_eq!(a.steps, 3);
        assert_eq!(deploy(&a.net).to_bytes(), deploy(&b.net).to_bytes());
        assert!(a.final_loss.is_finite());
        // Phase telemetry is populated (no cross-phase inequalities
        // here: the reduce counter is process-global and tests run
        // concurrently).
        assert!(a.phases.forward > Duration::ZERO, "forward time measured");
        assert!(a.phases.optim > Duration::ZERO, "optim time measured");
        let reg = Registry::new();
        a.phases.export_into(&reg, "train");
        let snap = reg.snapshot();
        assert!(snap.gauges["train.phase.forward_ms"] > 0.0);
        assert!(snap.gauges.contains_key("train.phase.reduce_ms"));
    }

    /// With a collector attached, training leaves a nested
    /// epoch/step/phase span tree whose durations reconcile with the
    /// `PhaseTimes` aggregate (same stamps, ≤ 1 µs rounding per step).
    #[test]
    fn train_spans_nest_and_reconcile_with_phases() {
        let cfg = TrainConfig {
            model: "micro".into(),
            num_steps: 2,
            epochs: 2,
            batches_per_epoch: 3,
            batch: 4,
            log_every: 0,
            ..TrainConfig::default()
        };
        let spans = SpanCollector::new();
        let out = train_traced(&cfg, Some(&spans)).unwrap();
        let sheet = spans.sheet();
        sheet.check_nesting().expect("epoch/step/phase spans nest");
        let named = |n: &str| sheet.records().iter().filter(|r| r.name == n);
        assert_eq!(named("step").count(), 6);
        assert_eq!(named("epoch").count(), 2);
        for phase in ["load", "forward", "backward", "optim"] {
            assert_eq!(named(phase).count(), 6, "one {phase} span per step");
        }
        let fwd_ns: u64 = named("forward").map(|r| r.dur_ns).sum();
        let agg_ns = out.phases.forward.as_nanos() as u64;
        assert!(
            fwd_ns.abs_diff(agg_ns) <= 6_000,
            "span forward {fwd_ns} ns vs PhaseTimes {agg_ns} ns"
        );
    }

    /// Hand-built "MNIST" split in micro geometry for load_batch tests.
    fn fake_mnist(n: usize, plane_side: usize) -> Vec<Sample> {
        (0..n)
            .map(|i| Sample {
                image: vec![(i + 1) as u8 * 10; plane_side * plane_side],
                channels: 1,
                size: plane_side,
                label: i % 10,
            })
            .collect()
    }

    #[test]
    fn load_batch_short_final_batch_and_wraparound() {
        let spec = models::micro(2);
        let cfg = TrainConfig { batch: 4, ..TrainConfig::default() };
        let data = fake_mnist(6, spec.in_size); // 6 % 4 != 0
        let bpe = (data.len() + cfg.batch - 1) / cfg.batch; // = 2, as train() derives
        let plane = spec.in_size * spec.in_size;
        let mut images = vec![0.0f32; cfg.batch * plane];
        let mut labels = vec![0usize; cfg.batch];
        // step 0: full batch of 4
        let c0 = load_batch(&spec, &cfg, Some(&data[..]), 0, bpe, &mut images, &mut labels);
        assert_eq!(c0, 4);
        assert_eq!(labels[..4], [0, 1, 2, 3]);
        // step 1: short final batch of 2 — the tail is NOT dropped
        let c1 = load_batch(&spec, &cfg, Some(&data[..]), 1, bpe, &mut images, &mut labels);
        assert_eq!(c1, 2, "tail of len % batch samples must form a short batch");
        assert_eq!(labels[..2], [4, 5]);
        assert_eq!(images[0], 50.0f32 / 255.0, "short batch holds samples 4..6");
        // step 2 wraps around to the first batch of the next epoch
        let c2 = load_batch(&spec, &cfg, Some(&data[..]), 2, bpe, &mut images, &mut labels);
        assert_eq!(c2, 4);
        assert_eq!(labels[..4], [0, 1, 2, 3]);
    }

    #[test]
    fn load_batch_stale_tail_rows_never_reach_loss_or_accuracy() {
        let spec = models::micro(2);
        let cfg = TrainConfig { batch: 4, ..TrainConfig::default() };
        let data = fake_mnist(2, spec.in_size); // short batch of 2
        let plane = spec.in_size * spec.in_size;
        // Poison the buffers: rows >= count keep whatever was there.
        let mut poisoned = vec![777.0f32; cfg.batch * plane];
        let mut clean = vec![0.0f32; cfg.batch * plane];
        let mut labels = vec![9usize; cfg.batch];
        let count = load_batch(&spec, &cfg, Some(&data[..]), 0, 1, &mut poisoned, &mut labels);
        let count_b = load_batch(&spec, &cfg, Some(&data[..]), 0, 1, &mut clean, &mut labels);
        assert_eq!((count, count_b), (2, 2));
        // The live prefix is identical; the stale tail differs...
        assert_eq!(poisoned[..count * plane], clean[..count * plane]);
        assert_eq!(poisoned[count * plane], 777.0, "tail rows are untouched");
        // ...and everything downstream (forward/loss/accuracy) slices by
        // `count`, so the poisoned tail cannot leak into training math.
        let net = Net::init(&spec, 5);
        let classes = net.classes();
        let mut dl = vec![0.0f32; count * classes];
        let fa = net.forward(&poisoned[..count * plane], count, SpikeMode::Hard, true, 1);
        let fb = net.forward(&clean[..count * plane], count, SpikeMode::Hard, true, 1);
        assert_eq!(fa.logits, fb.logits);
        let la = tensor::softmax_ce(&fa.logits, count, classes, &labels[..count], 2.0, &mut dl);
        let lb = tensor::softmax_ce(&fb.logits, count, classes, &labels[..count], 2.0, &mut dl);
        assert_eq!(la, lb);
        assert_eq!(
            count_correct(&fa.logits, classes, &labels[..count]),
            count_correct(&fb.logits, classes, &labels[..count])
        );
    }

    #[test]
    fn count_correct_rejects_nan_rows() {
        // Diverged logits (NaN) must never count as correct, whatever
        // index argmax lands on.
        let logits = vec![f32::NAN, 0.0, 0.0, /* row 2 */ 3.0, 1.0, 0.0];
        let labels = [0usize, 0];
        assert_eq!(count_correct(&logits, 3, &labels), 1, "only the finite row counts");
        let all_nan = vec![f32::NAN; 3];
        assert_eq!(count_correct(&all_nan, 3, &[0]), 0);
        // Negative NaN sorts BELOW -inf under the total order: argmax
        // lands on the finite 1.0, but the row is still diverged.
        let neg_nan_row = vec![-f32::NAN, 1.0, 0.0];
        assert_eq!(argmax_f32(&neg_nan_row), 1);
        assert_eq!(count_correct(&neg_nan_row, 3, &[1]), 0, "diverged row must not count");
    }

    // (Thread-count byte-identity of full train() runs lives in
    // rust/tests/train_parallel.rs — broader coverage, not duplicated
    // here.)

    #[test]
    fn holdout_disjoint_from_training_indices() {
        let spec = models::micro(2);
        let train_s = synth::batch(7, 0, 8, spec.in_channels, spec.in_size);
        let hold = holdout_synth(&spec, 7, 8);
        assert_eq!(hold.len(), 8);
        assert!(train_s.iter().zip(&hold).any(|(a, b)| a.image != b.image));
    }

    #[test]
    fn eval_golden_counts_correct() {
        let spec = models::micro(2);
        let model = deploy(&Net::init(&spec, 5));
        let samples = holdout_synth(&spec, 5, 10);
        let (correct, total) = eval_golden(&model, &samples);
        assert_eq!(total, 10);
        assert!(correct <= total);
    }

    #[test]
    fn eval_golden_threaded_matches_serial_at_every_thread_count() {
        let spec = models::micro(3);
        let model = deploy(&Net::init(&spec, 11));
        // 13 samples: not a multiple of any shard/thread count below.
        let samples = holdout_synth(&spec, 11, 13);
        let serial = eval_golden(&model, &samples);
        for t in [2usize, 3, 4, 8, 32] {
            assert_eq!(
                eval_golden_threaded(&model, &samples, t),
                serial,
                "eval count must not depend on threads={t}"
            );
        }
        assert_eq!(eval_golden_threaded(&model, &[], 4), (0, 0), "empty sample set");
    }

    #[test]
    fn unknown_model_is_an_error() {
        let cfg = TrainConfig { model: "nope".into(), ..TrainConfig::default() };
        assert!(train(&cfg).is_err());
    }

    #[test]
    fn mnist_without_files_reports_clearly() {
        let cfg = TrainConfig {
            dataset: Dataset::Mnist,
            model: "mnist".into(),
            ..TrainConfig::default()
        };
        if idx::mnist_train_if_available(1).is_none() {
            let err = train(&cfg).unwrap_err().to_string();
            assert!(err.contains("data/mnist"), "unhelpful error: {err}");
        }
    }
}

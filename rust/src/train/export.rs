//! Export: trained [`Net`] → deployed integer artifact.
//!
//! Binarizes the latent weights, folds each layer's IF-BN into the
//! quantized per-channel `(bias, theta)` pair (see
//! [`crate::train::ifbn`]), and assembles the
//! [`crate::snn::params::DeployedModel`] the golden model, the chip
//! simulator and `vsa dse` all consume.  `write_artifact` serializes it
//! in VSAW v1 via [`DeployedModel::to_bytes`] — the byte format is a
//! pure function of the trained parameters, so identically-seeded
//! training runs produce byte-identical artifacts.

use crate::snn::params::{DeployedModel, Kind, Layer};
use crate::train::binarize::sign_i8;
use crate::train::ifbn::BN_EPS;
use crate::train::stbp::{Net, TrainLayer};

/// Input scale of the encoding layer's fold: training consumes
/// pixels/255, the deployed graph raw u8 pixels.
pub const ENC_INPUT_SCALE: f64 = 255.0;

/// Fold + binarize into the deployed integer model.
pub fn deploy(net: &Net) -> DeployedModel {
    deploy_with_eps(net, BN_EPS)
}

/// [`deploy`] with an explicit BN epsilon.  The fold-exactness test runs
/// at `eps = 0`, where dyadic-rational BN parameters make the folded
/// integer model *provably* bit-equivalent to the unfolded float
/// reference; production exports use [`BN_EPS`].
pub fn deploy_with_eps(net: &Net, eps: f64) -> DeployedModel {
    let layers = net
        .layers
        .iter()
        .map(|ly| match ly {
            TrainLayer::Conv { enc, c_out, c_in, k, w, bn } => {
                let scale = if *enc { ENC_INPUT_SCALE } else { 1.0 };
                let (bias, theta) = bn.quantize(scale, eps);
                Layer::Conv {
                    kind: if *enc { Kind::EncConv } else { Kind::Conv },
                    c_out: *c_out,
                    c_in: *c_in,
                    k: *k,
                    w: sign_i8(w),
                    bias,
                    theta,
                }
            }
            TrainLayer::MaxPool => Layer::MaxPool,
            TrainLayer::Fc { n_out, n_in, w, bn } => {
                let (bias, theta) = bn.quantize(1.0, eps);
                Layer::Fc { n_out: *n_out, n_in: *n_in, w: sign_i8(w), bias, theta }
            }
            TrainLayer::Readout { n_out, n_in, w } => {
                Layer::Readout { n_out: *n_out, n_in: *n_in, w: sign_i8(w) }
            }
        })
        .collect();
    DeployedModel {
        name: net.spec.name.clone(),
        num_steps: net.spec.num_steps,
        in_channels: net.spec.in_channels,
        in_size: net.spec.in_size,
        layers,
    }
}

/// Deploy and write the VSAW v1 artifact; creates parent directories.
pub fn write_artifact(net: &Net, path: &str) -> std::io::Result<DeployedModel> {
    let model = deploy(net);
    if let Some(dir) = std::path::Path::new(path).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, model.to_bytes())?;
    Ok(model)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::models;
    use crate::train::stbp::Net;

    #[test]
    fn deploy_geometry_matches_spec() {
        let spec = models::micro(3);
        let net = Net::init(&spec, 9);
        let model = deploy(&net);
        assert_eq!(model.num_steps, 3);
        assert_eq!(model.layers.len(), spec.layers.len());
        match &model.layers[0] {
            Layer::Conv { kind: Kind::EncConv, w, theta, .. } => {
                assert!(w.iter().all(|&v| v == 1 || v == -1));
                assert!(theta.iter().all(|&t| t > 0));
            }
            other => panic!("expected enc conv, got {other:?}"),
        }
    }

    #[test]
    fn artifact_roundtrips_through_parser() {
        let spec = models::micro(2);
        let net = Net::init(&spec, 4);
        let model = deploy(&net);
        let bytes = model.to_bytes();
        let parsed = DeployedModel::parse(&bytes).expect("exported artifact parses");
        assert_eq!(parsed.to_bytes(), bytes);
        assert_eq!(parsed.name, model.name);
        assert_eq!(parsed.layers.len(), model.layers.len());
    }

    #[test]
    fn export_is_deterministic() {
        let spec = models::micro(2);
        let a = deploy(&Net::init(&spec, 11)).to_bytes();
        let b = deploy(&Net::init(&spec, 11)).to_bytes();
        assert_eq!(a, b);
    }
}

//! IF-based Batch Normalization (paper §II, Eq. (3)-(4)).
//!
//! During STBP training every weight layer is followed by standard BN
//! whose statistics are shared across the T time steps (Eq. (3)):
//! batch statistics normalize the psums, then the IF neuron fires
//! against the fixed threshold `v_th`.  At export the affine BN and the
//! threshold fold into two per-channel integers (Eq. (4)) the hardware's
//! IF unit consumes:
//!
//! ```text
//! sigma  = sqrt(var + eps)
//! bias   = mu - sigma/gamma * beta          (psum-domain offset)
//! theta  = sigma/gamma * v_th               (psum-domain threshold)
//! bias_q = round(bias * input_scale * FIXED_POINT)
//! theta_q = max(round(theta * input_scale * FIXED_POINT), 1)
//! ```
//!
//! because, for `gamma > 0`,
//! `gamma * (x - mu) / sigma + beta >= v_th  <=>  x - bias >= theta`
//! and the same rescaling maps the hard-reset membrane recurrences onto
//! each other step by step.  `gamma` is clamped positive by the
//! optimizer ([`crate::train::optim`]) so the inequality never flips.
//! `input_scale` is 255 for the encoding layer (training consumes
//! pixels/255, the deployed graph raw u8 pixels) and 1 elsewhere.
//!
//! The fold is verified bit-exactly in `rust/tests/train_stbp.rs`
//! (`ifbn_fold_is_bit_exact_*`): with dyadic-rational parameters both
//! sides are computed without rounding error, so folded integer
//! inference must reproduce the unfolded train-time reference
//! spike-for-spike.

use crate::train::par;
use crate::util::FIXED_POINT;

/// Default BN epsilon — matches `python/compile/model.py::BN_EPS`.
pub const BN_EPS: f64 = 1e-5;

/// Training threshold — matches `python/compile/model.py::DEFAULT_V_TH`.
pub const V_TH: f32 = 1.0;

/// Running-stat EMA momentum — matches `compile/train.py::BN_MOMENTUM`.
pub const BN_MOMENTUM: f32 = 0.9;

/// Per-channel IF-BN parameters of one weight layer.
#[derive(Debug, Clone)]
pub struct IfBn {
    pub gamma: Vec<f32>,
    pub beta: Vec<f32>,
    /// Running mean (EMA of batch means) — the deployed statistics.
    pub mu: Vec<f32>,
    /// Running variance (EMA of batch variances).
    pub var: Vec<f32>,
}

/// Backward cache of one training-mode normalization.
#[derive(Debug, Clone, Default)]
pub struct BnCache {
    /// Normalized activations `(x - mu_b) / sigma_b`, caller layout.
    pub xn: Vec<f32>,
    /// Per-channel `sqrt(var_b + eps)`.
    pub sigma: Vec<f32>,
    /// Per-channel batch mean (for the EMA update).
    pub mu_b: Vec<f32>,
    /// Per-channel batch variance (for the EMA update).
    pub var_b: Vec<f32>,
}

impl IfBn {
    /// Identity-initialized BN for `c` channels (gamma 1, beta 0, running
    /// stats standard normal) — matching `compile/model.py::init_params`.
    pub fn new(c: usize) -> Self {
        Self {
            gamma: vec![1.0; c],
            beta: vec![0.0; c],
            mu: vec![0.0; c],
            var: vec![1.0; c],
        }
    }

    pub fn channels(&self) -> usize {
        self.gamma.len()
    }

    /// Training-mode normalization of `x` laid out as `(n, c, s)`
    /// (channel-major maps, `s = 1` for fc): batch statistics per
    /// channel over the `n * s` samples, written in place.  Returns the
    /// backward cache.
    ///
    /// Statistics are sharded over *channels* (each channel's f64 sums
    /// run in the scalar row order on exactly one worker) and the
    /// normalization over rows — both disjoint-output splits, so the
    /// result is bit-identical for every `threads` value.
    pub fn normalize_train(&self, x: &mut [f32], n: usize, s: usize, threads: usize) -> BnCache {
        let c = self.channels();
        assert_eq!(x.len(), n * c * s, "bn input geometry");
        let threads = par::threads_for(4 * n * c * s, threads);
        let cnt = (n * s) as f64;
        let mut mu_b = vec![0.0f32; c];
        let mut var_b = vec![0.0f32; c];
        let mut sigma = vec![0.0f32; c];
        {
            let ch_ranges = par::shard_ranges(c, par::SHARDS);
            let mus = par::split_rows(&mut mu_b, &ch_ranges, 1);
            let vars = par::split_rows(&mut var_b, &ch_ranges, 1);
            let sigmas = par::split_rows(&mut sigma, &ch_ranges, 1);
            let ctxs: Vec<_> = ch_ranges
                .iter()
                .cloned()
                .zip(mus)
                .zip(vars)
                .zip(sigmas)
                .map(|(((r, m), v), sg)| (r, m, v, sg))
                .collect();
            let x_ro: &[f32] = x;
            par::run(threads, ctxs, |_, (range, mus, vars, sigmas)| {
                for (i, ch) in range.enumerate() {
                    let mut sum = 0.0f64;
                    let mut sumsq = 0.0f64;
                    for r in 0..n {
                        let plane = &x_ro[(r * c + ch) * s..(r * c + ch + 1) * s];
                        for &v in plane {
                            sum += v as f64;
                            sumsq += v as f64 * v as f64;
                        }
                    }
                    let m = sum / cnt;
                    let v = (sumsq / cnt - m * m).max(0.0);
                    mus[i] = m as f32;
                    vars[i] = v as f32;
                    sigmas[i] = ((v + BN_EPS).sqrt()) as f32;
                }
            });
        }
        let mut xn = vec![0.0f32; x.len()];
        {
            let row_ranges = par::shard_ranges(n, par::SHARDS);
            let xs = par::split_rows(x, &row_ranges, c * s);
            let xns = par::split_rows(&mut xn, &row_ranges, c * s);
            let ctxs: Vec<_> = xs.into_iter().zip(xns).collect();
            let (mu_b, sigma) = (&mu_b, &sigma);
            par::run(threads, ctxs, |_, (xc, xnc)| {
                for (xr, xnr) in xc.chunks_mut(c * s).zip(xnc.chunks_mut(c * s)) {
                    for ch in 0..c {
                        let base = ch * s;
                        let (m, sg) = (mu_b[ch], sigma[ch]);
                        let (g, b) = (self.gamma[ch], self.beta[ch]);
                        for j in 0..s {
                            let z = (xr[base + j] - m) / sg;
                            xnr[base + j] = z;
                            xr[base + j] = g * z + b;
                        }
                    }
                }
            });
        }
        BnCache { xn, sigma, mu_b, var_b }
    }

    /// Eval-mode normalization with the running statistics, in place.
    /// `eps` is exposed so the fold-exactness test can run at `eps = 0`.
    pub fn normalize_eval(&self, x: &mut [f32], n: usize, s: usize, eps: f64) {
        let c = self.channels();
        assert_eq!(x.len(), n * c * s, "bn input geometry");
        for r in 0..n {
            for ch in 0..c {
                let base = (r * c + ch) * s;
                let sg = ((self.var[ch] as f64 + eps).sqrt()) as f32;
                let (m, g, b) = (self.mu[ch], self.gamma[ch], self.beta[ch]);
                for j in 0..s {
                    x[base + j] = g * (x[base + j] - m) / sg + b;
                }
            }
        }
    }

    /// Backward through training-mode BN.  `dy` (caller layout `(n, c,
    /// s)`) is consumed into `dx` in place; gradients for gamma/beta are
    /// accumulated into `dgamma`/`dbeta` (zeroed here).
    ///
    /// `dx = gamma/sigma * (dy' - mean(dy') - xn * mean(dy' * xn))` with
    /// `dy' = dy` per channel — the full batch-statistics gradient.
    /// Sharded like [`Self::normalize_train`] (channel-sharded sums,
    /// row-sharded scaling): bit-identical for every `threads` value.
    pub fn backward(
        &self,
        cache: &BnCache,
        dy: &mut [f32],
        n: usize,
        s: usize,
        dgamma: &mut [f32],
        dbeta: &mut [f32],
        threads: usize,
    ) {
        let c = self.channels();
        let threads = par::threads_for(6 * n * c * s, threads);
        let cnt = (n * s) as f64;
        let mut mean_dy = vec![0.0f32; c];
        let mut mean_dyxn = vec![0.0f32; c];
        {
            let ch_ranges = par::shard_ranges(c, par::SHARDS);
            let dgs = par::split_rows(dgamma, &ch_ranges, 1);
            let dbs = par::split_rows(dbeta, &ch_ranges, 1);
            let mds = par::split_rows(&mut mean_dy, &ch_ranges, 1);
            let mxs = par::split_rows(&mut mean_dyxn, &ch_ranges, 1);
            let ctxs: Vec<_> = ch_ranges
                .iter()
                .cloned()
                .zip(dgs)
                .zip(dbs)
                .zip(mds)
                .zip(mxs)
                .map(|((((r, dg), db), md), mx)| (r, dg, db, md, mx))
                .collect();
            let dy_ro: &[f32] = dy;
            par::run(threads, ctxs, |_, (range, dgs, dbs, mds, mxs)| {
                for (i, ch) in range.enumerate() {
                    let mut sum_dy = 0.0f64;
                    let mut sum_dyxn = 0.0f64;
                    for r in 0..n {
                        let base = (r * c + ch) * s;
                        for j in 0..s {
                            let g = dy_ro[base + j] as f64;
                            sum_dy += g;
                            sum_dyxn += g * cache.xn[base + j] as f64;
                        }
                    }
                    dgs[i] = sum_dyxn as f32;
                    dbs[i] = sum_dy as f32;
                    mds[i] = (sum_dy / cnt) as f32;
                    mxs[i] = (sum_dyxn / cnt) as f32;
                }
            });
        }
        let row_ranges = par::shard_ranges(n, par::SHARDS);
        let dys = par::split_rows(dy, &row_ranges, c * s);
        let ctxs: Vec<_> = row_ranges.iter().cloned().zip(dys).collect();
        let (mean_dy, mean_dyxn) = (&mean_dy, &mean_dyxn);
        par::run(threads, ctxs, |_, (range, dyc)| {
            for (k, dyr) in dyc.chunks_mut(c * s).enumerate() {
                let r = range.start + k;
                for ch in 0..c {
                    let scale = self.gamma[ch] / cache.sigma[ch];
                    let xnr = &cache.xn[(r * c + ch) * s..(r * c + ch + 1) * s];
                    let base = ch * s;
                    for j in 0..s {
                        dyr[base + j] = scale
                            * (dyr[base + j] - mean_dy[ch] - xnr[j] * mean_dyxn[ch]);
                    }
                }
            }
        });
    }

    /// EMA update of the running statistics from one batch's statistics.
    pub fn ema_update(&mut self, cache: &BnCache) {
        for ch in 0..self.channels() {
            self.mu[ch] = BN_MOMENTUM * self.mu[ch] + (1.0 - BN_MOMENTUM) * cache.mu_b[ch];
            self.var[ch] = BN_MOMENTUM * self.var[ch] + (1.0 - BN_MOMENTUM) * cache.var_b[ch];
        }
    }

    /// Fold BN + threshold into the psum-domain `(bias, theta)` pair
    /// (unquantized, f64) — Eq. (4) before the fixed-point rounding.
    pub fn fold(&self, input_scale: f64, eps: f64) -> (Vec<f64>, Vec<f64>) {
        let c = self.channels();
        let mut bias = vec![0.0f64; c];
        let mut theta = vec![0.0f64; c];
        for ch in 0..c {
            let sigma = (self.var[ch] as f64 + eps).sqrt();
            let ratio = sigma / self.gamma[ch] as f64;
            bias[ch] = (self.mu[ch] as f64 - ratio * self.beta[ch] as f64) * input_scale;
            theta[ch] = ratio * V_TH as f64 * input_scale;
        }
        (bias, theta)
    }

    /// Quantize the fold onto the `FIXED_POINT` grid: the i32 pair the
    /// VSAW format stores and the golden model / chip execute.  Theta is
    /// floored at 1 so the firing inequality stays well-defined.
    pub fn quantize(&self, input_scale: f64, eps: f64) -> (Vec<i32>, Vec<i32>) {
        let (bias, theta) = self.fold(input_scale, eps);
        let q = |v: f64| (v * FIXED_POINT as f64).round();
        (
            bias.iter().map(|&b| q(b) as i32).collect(),
            theta.iter().map(|&t| q(t).max(1.0) as i32).collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalize_train_standardizes() {
        let bn = IfBn::new(2);
        // channel 0: 1..4, channel 1: constant 5
        let mut x = vec![1.0, 5.0, 2.0, 5.0, 3.0, 5.0, 4.0, 5.0];
        let cache = bn.normalize_train(&mut x, 4, 1, 1);
        assert!((cache.mu_b[0] - 2.5).abs() < 1e-6);
        assert!((cache.mu_b[1] - 5.0).abs() < 1e-6);
        // normalized channel 0 has ~zero mean
        let m: f32 = (0..4).map(|r| x[r * 2]).sum::<f32>() / 4.0;
        assert!(m.abs() < 1e-6);
        // constant channel collapses to beta = 0 (sigma = sqrt(eps))
        assert!(x[1].abs() < 1e-3);
    }

    #[test]
    fn normalize_and_backward_identical_for_every_thread_count() {
        let mut bn = IfBn::new(3);
        bn.gamma = vec![1.5, 0.7, 1.0];
        bn.beta = vec![0.1, -0.2, 0.0];
        let (n, s) = (5, 4);
        let mut rng = crate::util::rng::SplitMix64::new(41);
        let mut draw = |len: usize| -> Vec<f32> {
            (0..len).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect()
        };
        let x0 = draw(n * 3 * s);
        let dy0 = draw(n * 3 * s);
        let run = |threads: usize| {
            let mut x = x0.clone();
            let cache = bn.normalize_train(&mut x, n, s, threads);
            let mut dy = dy0.clone();
            let mut dgamma = vec![0.0f32; 3];
            let mut dbeta = vec![0.0f32; 3];
            bn.backward(&cache, &mut dy, n, s, &mut dgamma, &mut dbeta, threads);
            (x, cache.xn, cache.mu_b, dy, dgamma, dbeta)
        };
        let base = run(1);
        for t in [2, 3, 4, 8] {
            assert_eq!(base, run(t), "BN results must not depend on threads={t}");
        }
    }

    #[test]
    fn eval_uses_running_stats() {
        let mut bn = IfBn::new(1);
        bn.mu = vec![2.0];
        bn.var = vec![4.0];
        let mut x = vec![4.0];
        bn.normalize_eval(&mut x, 1, 1, 0.0);
        assert_eq!(x[0], 1.0); // (4 - 2) / 2
    }

    #[test]
    fn fold_quantize_matches_hand_math() {
        let mut bn = IfBn::new(1);
        bn.gamma = vec![0.5];
        bn.beta = vec![0.125];
        bn.mu = vec![0.25];
        bn.var = vec![4.0];
        let (bias, theta) = bn.fold(1.0, 0.0);
        // sigma/gamma = 4: bias = 0.25 - 4*0.125 = -0.25, theta = 4
        assert_eq!(bias[0], -0.25);
        assert_eq!(theta[0], 4.0);
        let (bq, tq) = bn.quantize(1.0, 0.0);
        assert_eq!(bq[0], -64);
        assert_eq!(tq[0], 1024);
    }

    #[test]
    fn theta_floor_keeps_positive() {
        let mut bn = IfBn::new(1);
        bn.var = vec![0.0];
        let (_, tq) = bn.quantize(1.0, 0.0);
        assert_eq!(tq[0], 1); // sigma 0 would give theta 0; floored to 1
    }

    #[test]
    fn ema_moves_toward_batch_stats() {
        let mut bn = IfBn::new(1);
        let cache = BnCache {
            xn: vec![],
            sigma: vec![1.0],
            mu_b: vec![10.0],
            var_b: vec![2.0],
        };
        bn.ema_update(&cache);
        assert!((bn.mu[0] - 1.0).abs() < 1e-6); // 0.9*0 + 0.1*10
        assert!((bn.var[0] - 1.1).abs() < 1e-6); // 0.9*1 + 0.1*2
    }
}

//! STBP: spatio-temporal backpropagation through the binary-weight
//! spiking network (paper §II; Wu et al.'s STBP with a rectangular
//! surrogate).
//!
//! The trainable network mirrors [`crate::config::models::ModelSpec`]
//! layer for layer: encoding conv (multi-bit input, psums shared across
//! the T steps, §III-F), spiking convs, 2x2 max pools, spiking fc, and a
//! non-firing readout.  Weight layers hold *latent* f32 weights that are
//! binarized to ±1 in the forward pass (straight-through backward, see
//! [`crate::train::binarize`]) and an [`IfBn`] normalizer (batch
//! statistics during training, running statistics at export).
//!
//! ## Surrogate gradient
//!
//! The hard fire `o = H(v_pre - v_th)` is not differentiable; the
//! backward pass uses the rectangular window `do/dv = 1(|v_pre - v_th| <
//! 1/2)` and differentiates the hard reset `v_res = v_pre * (1 - o)`
//! through both factors.  [`SpikeMode::Soft`] replaces the forward fire
//! with the *continuous* ramp `clamp(v_pre - v_th + 1/2, 0, 1)` whose
//! exact derivative is that same window — the finite-difference
//! correctness test runs in this mode, so the identical backward code is
//! checked against numerics without the Heaviside discontinuity.
//!
//! Spike trains are laid out `(T, B, F)` with `F = C*H*W` flat, so the
//! `(T*B, F)` views the conv/fc kernels need are free reinterpretations.
//!
//! ## Hot path (PR4)
//!
//! The forward binarizes each layer's weights **once** and caches them
//! in the [`Forward`] ([`Cache::wb`]) so `backward` never re-runs
//! `sign_vec`; the encoding layer drives all T steps from **one** psum
//! plane ([`if_forward_broadcast`] — the trainer's analogue of the
//! golden engine's `if_fire_constant`) instead of materializing T
//! copies; and every conv/matmul/BN stage shards its rows or channels
//! over `threads` scoped workers via [`crate::train::par`] — a fixed,
//! thread-count-independent partition, so logits, gradients and
//! exported artifacts are byte-identical at any `--threads`.

use crate::config::models::{LayerKind, ModelSpec};
use crate::train::binarize::sign_into;
use crate::train::ifbn::{BnCache, IfBn, V_TH};
use crate::train::tensor;
use crate::util::rng::SplitMix64;

/// Half-width of the rectangular surrogate window (STBP `a/2` with
/// `a = 1`, matching `compile/model.py::SURROGATE_WIDTH`).
pub const SURR_HALF: f32 = 0.5;

/// Seed salt for weight init (keeps the trainer's stream independent of
/// the dataset streams derived from the same user seed).
const INIT_SALT: u64 = 0x5EED_7261_11E5;

/// Forward spike semantics: `Hard` is real training/eval; `Soft` is the
/// continuous relaxation used by the gradient finite-difference test.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpikeMode {
    Hard,
    Soft,
}

/// One trainable layer (parallel to `ModelSpec::layers`).
#[derive(Debug, Clone)]
pub enum TrainLayer {
    /// Encoding or spiking conv: latent weights `(c_out, c_in, k, k)`.
    Conv { enc: bool, c_out: usize, c_in: usize, k: usize, w: Vec<f32>, bn: IfBn },
    MaxPool,
    /// Spiking fully-connected: latent weights `(n_out, n_in)`.
    Fc { n_out: usize, n_in: usize, w: Vec<f32>, bn: IfBn },
    /// Non-firing accumulation layer.
    Readout { n_out: usize, n_in: usize, w: Vec<f32> },
}

/// The trainable network.
#[derive(Debug, Clone)]
pub struct Net {
    pub spec: ModelSpec,
    pub layers: Vec<TrainLayer>,
}

/// Per-layer caches of one forward pass.
#[derive(Debug, Clone, Default)]
struct Cache {
    /// Output spike train `(T, B, F)` (for the readout: empty).
    spikes: Vec<f32>,
    /// Pre-reset membrane `(T, B, F)` (firing layers only).
    v_pre: Vec<f32>,
    /// BN cache (weight layers in train mode only).
    bn: BnCache,
    /// Weights the forward computed with: `sign_vec` of the latent
    /// weights when the pass ran binarized, empty otherwise (backward
    /// then falls back to the latent weights).  Cached here so
    /// `backward` performs zero `sign_vec` calls.
    wb: Vec<f32>,
    /// Output feature dims per map.
    c: usize,
    h: usize,
    w: usize,
}

/// Everything one forward pass produces.
pub struct Forward {
    /// `(B, classes)` accumulated readout logits.
    pub logits: Vec<f32>,
    pub batch: usize,
    caches: Vec<Cache>,
}

impl Forward {
    /// Read-only view of one layer's cached `(spikes, v_pre)` trains —
    /// the oracle hook for the bit-exactness tests against
    /// `baselines::stbp_scalar` (empty slices for pool/readout caches
    /// where not recorded).
    pub fn layer_cache(&self, li: usize) -> (&[f32], &[f32]) {
        (&self.caches[li].spikes, &self.caches[li].v_pre)
    }
}

/// Per-layer parameter gradients (empty vecs where not applicable).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LayerGrads {
    pub w: Vec<f32>,
    pub gamma: Vec<f32>,
    pub beta: Vec<f32>,
}

/// Reusable activation/gradient buffers for the training loop (PR10
/// bugfix): `forward`/`backward` used to allocate a fresh
/// `vec![0.0f32; ...]` per layer per step (activations, spike trains,
/// membranes, gradients) and the `_mt` kernels another SHARDS×|dW| —
/// tens of MB of churn per step at CIFAR scale.  The arena is a LIFO
/// pool of recycled `Vec<f32>` storage plus the per-shard gradient
/// buffer; every buffer handed out is cleared and zero-filled, so the
/// recycled paths are byte-identical to the allocating ones (asserted
/// by the `--threads 1/4` artifact-identity suite).
///
/// Ownership flow per step: [`Net::forward_with`] /
/// [`Net::backward_with`] draw from and return transient buffers to the
/// arena; buffers that outlive the call (the [`Forward`] caches, the
/// returned [`LayerGrads`]) come back via [`TrainArena::recycle_forward`]
/// / [`TrainArena::recycle_grads`] once the optimizer has consumed them.
#[derive(Debug, Default)]
pub struct TrainArena {
    pool: Vec<Vec<f32>>,
    /// Per-shard weight-gradient buffer for
    /// `tensor::*_grads_mt_with` (the SHARDS×|dW| churn).
    parts: Vec<f32>,
}

impl TrainArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// A zero-filled buffer of length `n` — contents identical to
    /// `vec![0.0f32; n]`, storage recycled LIFO from the pool.
    fn take_zeroed(&mut self, n: usize) -> Vec<f32> {
        let mut v = self.pool.pop().unwrap_or_default();
        v.clear();
        v.resize(n, 0.0);
        v
    }

    /// Return a buffer's storage to the pool.
    fn give(&mut self, v: Vec<f32>) {
        if v.capacity() > 0 {
            self.pool.push(v);
        }
    }

    /// Recycle a consumed forward pass (call after `apply_bn_ema` and
    /// anything else reading its logits/caches is done with it).
    pub fn recycle_forward(&mut self, fwd: Forward) {
        self.give(fwd.logits);
        for c in fwd.caches {
            self.give(c.spikes);
            self.give(c.v_pre);
            self.give(c.wb);
        }
    }

    /// Recycle consumed per-layer gradients (call after the optimizer
    /// step).
    pub fn recycle_grads(&mut self, grads: Vec<LayerGrads>) {
        for g in grads {
            self.give(g.w);
            self.give(g.gamma);
            self.give(g.beta);
        }
    }
}

impl Net {
    /// Initialize latent weights from one seeded SplitMix64 stream:
    /// uniform in `±1/sqrt(fan_in)`, drawn in layer order, row-major —
    /// byte-reproducible per seed.
    pub fn init(spec: &ModelSpec, seed: u64) -> Self {
        let mut rng = SplitMix64::new(seed ^ INIT_SALT);
        let mut draw = |n: usize, fan_in: usize| -> Vec<f32> {
            let bound = 1.0 / (fan_in as f64).sqrt();
            (0..n).map(|_| ((rng.next_f64() * 2.0 - 1.0) * bound) as f32).collect()
        };
        let shapes = spec.feature_shapes();
        let layers = spec
            .layers
            .iter()
            .zip(&shapes)
            .map(|(ly, &(c_in, fh, fw))| match ly.kind {
                LayerKind::EncConv | LayerKind::Conv => {
                    let fan_in = c_in * ly.ksize * ly.ksize;
                    TrainLayer::Conv {
                        enc: ly.kind == LayerKind::EncConv,
                        c_out: ly.c_out,
                        c_in,
                        k: ly.ksize,
                        w: draw(ly.c_out * fan_in, fan_in),
                        bn: IfBn::new(ly.c_out),
                    }
                }
                LayerKind::MaxPool => TrainLayer::MaxPool,
                LayerKind::Fc => {
                    let n_in = c_in * fh * fw;
                    TrainLayer::Fc {
                        n_out: ly.c_out,
                        n_in,
                        w: draw(ly.c_out * n_in, n_in),
                        bn: IfBn::new(ly.c_out),
                    }
                }
                LayerKind::Readout => {
                    let n_in = c_in * fh * fw;
                    TrainLayer::Readout {
                        n_out: ly.c_out,
                        n_in,
                        w: draw(ly.c_out * n_in, n_in),
                    }
                }
            })
            .collect();
        Self { spec: spec.clone(), layers }
    }

    /// Number of readout classes.
    pub fn classes(&self) -> usize {
        match self.layers.last() {
            Some(TrainLayer::Readout { n_out, .. }) => *n_out,
            _ => panic!("network has no readout layer"),
        }
    }

    /// Training forward (batch-statistics BN).  `images` is `(B, C_in *
    /// H * W)` f32 in `[0, 1]`; `binarized = false` runs on the latent
    /// weights (gradient-test mode).  `threads` only changes which
    /// worker computes which shard — never the bytes of the result.
    pub fn forward(
        &self,
        images: &[f32],
        batch: usize,
        mode: SpikeMode,
        binarized: bool,
        threads: usize,
    ) -> Forward {
        self.forward_with(images, batch, mode, binarized, threads, &mut TrainArena::new())
    }

    /// [`Net::forward`] drawing its buffers from `arena` instead of the
    /// allocator — the training-loop entry point.  Bit-identical to
    /// `forward` (every arena buffer is handed out zero-filled).
    pub fn forward_with(
        &self,
        images: &[f32],
        batch: usize,
        mode: SpikeMode,
        binarized: bool,
        threads: usize,
        arena: &mut TrainArena,
    ) -> Forward {
        self.forward_impl(images, batch, mode, binarized, true, 0.0, threads, arena)
    }

    /// Eval forward: running-statistics BN, hard spikes, binarized
    /// weights — the float twin of the deployed graph.  `eps` is the BN
    /// epsilon ([`crate::train::ifbn::BN_EPS`] normally; the
    /// fold-exactness test passes 0).
    pub fn forward_eval(&self, images: &[f32], batch: usize, eps: f64) -> Vec<f32> {
        let mut arena = TrainArena::new();
        self.forward_impl(images, batch, SpikeMode::Hard, true, false, eps, 1, &mut arena)
            .logits
    }

    #[allow(clippy::too_many_arguments)]
    fn forward_impl(
        &self,
        images: &[f32],
        batch: usize,
        mode: SpikeMode,
        binarized: bool,
        train: bool,
        eps: f64,
        threads: usize,
        arena: &mut TrainArena,
    ) -> Forward {
        let t_steps = self.spec.num_steps;
        let (mut h, mut w) = (self.spec.in_size, self.spec.in_size);
        assert_eq!(
            images.len(),
            batch * self.spec.in_channels * h * w,
            "image geometry mismatch"
        );
        let mut caches: Vec<Cache> = Vec::with_capacity(self.layers.len());
        let mut logits: Option<Vec<f32>> = None;
        // IF membrane-residue scratch, shared across layers (the strided
        // recurrence clears and resizes it per call).
        let mut v_res = arena.take_zeroed(0);
        let binarize = |arena: &mut TrainArena, wts: &[f32]| -> Vec<f32> {
            if binarized {
                let mut b = arena.take_zeroed(wts.len());
                sign_into(wts, &mut b);
                b
            } else {
                Vec::new()
            }
        };

        for ly in &self.layers {
            // Input spike train of this layer: previous cache (or none
            // for the encoding layer, which reads `images`).
            match ly {
                TrainLayer::Conv { enc: true, c_out, c_in, k, w: wts, bn } => {
                    let (ci, co, kk) = (*c_in, *c_out, *k);
                    let wb = binarize(arena, wts);
                    let wref: &[f32] = if binarized { &wb } else { wts };
                    let hw = h * w;
                    let f = co * hw;
                    let mut y = arena.take_zeroed(batch * f);
                    tensor::conv2d_same_mt(images, batch, ci, h, w, wref, co, kk, &mut y, threads);
                    let bn_cache = if train {
                        bn.normalize_train(&mut y, batch, hw, threads)
                    } else {
                        bn.normalize_eval(&mut y, batch, hw, eps);
                        BnCache::default()
                    };
                    // §III-F: the same psum plane drives every step —
                    // broadcast into the IF recurrence, never copied T
                    // times (O(batch·f) psum storage).
                    let mut spikes = arena.take_zeroed(t_steps * batch * f);
                    let mut v_pre = arena.take_zeroed(t_steps * batch * f);
                    if_forward_strided(
                        &y, 0, t_steps, batch * f, mode, &mut spikes, &mut v_pre, &mut v_res,
                    );
                    arena.give(y);
                    caches.push(Cache { spikes, v_pre, bn: bn_cache, wb, c: co, h, w });
                }
                TrainLayer::Conv { enc: false, c_out, c_in, k, w: wts, bn } => {
                    let (ci, co, kk) = (*c_in, *c_out, *k);
                    let wb = binarize(arena, wts);
                    let wref: &[f32] = if binarized { &wb } else { wts };
                    let hw = h * w;
                    let f = co * hw;
                    let n = t_steps * batch;
                    let mut y = arena.take_zeroed(n * f);
                    let x_in = &caches.last().expect("conv input").spikes;
                    tensor::conv2d_same_mt(x_in, n, ci, h, w, wref, co, kk, &mut y, threads);
                    let bn_cache = if train {
                        bn.normalize_train(&mut y, n, hw, threads)
                    } else {
                        bn.normalize_eval(&mut y, n, hw, eps);
                        BnCache::default()
                    };
                    let mut spikes = arena.take_zeroed(n * f);
                    let mut v_pre = arena.take_zeroed(n * f);
                    let m = batch * f;
                    if_forward_strided(
                        &y, m, t_steps, m, mode, &mut spikes, &mut v_pre, &mut v_res,
                    );
                    arena.give(y);
                    caches.push(Cache { spikes, v_pre, bn: bn_cache, wb, c: co, h, w });
                }
                TrainLayer::MaxPool => {
                    let n = t_steps * batch;
                    let (c, oh, ow) = (caches.last().expect("pool input").c, h / 2, w / 2);
                    let mut spikes = arena.take_zeroed(n * c * oh * ow);
                    let prev = caches.last().expect("pool input");
                    tensor::maxpool2(&prev.spikes, n, c, h, w, &mut spikes);
                    h = oh;
                    w = ow;
                    caches.push(Cache { spikes, c, h, w, ..Cache::default() });
                }
                TrainLayer::Fc { n_out, n_in, w: wts, bn } => {
                    let (ni, no) = (*n_in, *n_out);
                    let wb = binarize(arena, wts);
                    let wref: &[f32] = if binarized { &wb } else { wts };
                    let n = t_steps * batch;
                    let mut y = arena.take_zeroed(n * no);
                    let x_in = &caches.last().expect("fc input").spikes;
                    tensor::matmul_nt_mt(x_in, n, ni, wref, no, &mut y, threads);
                    let bn_cache = if train {
                        bn.normalize_train(&mut y, n, 1, threads)
                    } else {
                        bn.normalize_eval(&mut y, n, 1, eps);
                        BnCache::default()
                    };
                    let mut spikes = arena.take_zeroed(n * no);
                    let mut v_pre = arena.take_zeroed(n * no);
                    let m = batch * no;
                    if_forward_strided(
                        &y, m, t_steps, m, mode, &mut spikes, &mut v_pre, &mut v_res,
                    );
                    arena.give(y);
                    h = 1;
                    w = 1;
                    caches.push(Cache { spikes, v_pre, bn: bn_cache, wb, c: no, h, w });
                }
                TrainLayer::Readout { n_out, n_in, w: wts } => {
                    let wb = binarize(arena, wts);
                    let wref: &[f32] = if binarized { &wb } else { wts };
                    let n = t_steps * batch;
                    let mut y = arena.take_zeroed(n * n_out);
                    let x_in = &caches.last().expect("readout input").spikes;
                    tensor::matmul_nt_mt(x_in, n, *n_in, wref, *n_out, &mut y, threads);
                    let mut lg = arena.take_zeroed(batch * n_out);
                    for t in 0..t_steps {
                        for (l, &v) in lg.iter_mut().zip(&y[t * batch * n_out..]) {
                            *l += v;
                        }
                    }
                    arena.give(y);
                    logits = Some(lg);
                    caches.push(Cache { wb, ..Cache::default() });
                    break;
                }
            }
        }
        arena.give(v_res);
        Forward {
            logits: logits.expect("network has no readout layer"),
            batch,
            caches,
        }
    }

    /// Update every layer's BN running statistics from the batch
    /// statistics a training forward recorded (EMA, momentum
    /// [`crate::train::ifbn::BN_MOMENTUM`]).  Call after the optimizer
    /// step, mirroring `compile/train.py`.
    pub fn apply_bn_ema(&mut self, fwd: &Forward) {
        for (ly, cache) in self.layers.iter_mut().zip(&fwd.caches) {
            match ly {
                TrainLayer::Conv { bn, .. } | TrainLayer::Fc { bn, .. } => {
                    if !cache.bn.mu_b.is_empty() {
                        bn.ema_update(&cache.bn);
                    }
                }
                TrainLayer::MaxPool | TrainLayer::Readout { .. } => {}
            }
        }
    }

    /// Backward pass.  `dlogits` is `(B, classes)`; `binarized` must
    /// match the forward call (the binarized weights are read from the
    /// forward's cache — no re-binarization happens here).  Returns
    /// per-layer gradients (with respect to the latent weights via the
    /// straight-through estimator).  Like the forward, `threads` can
    /// never change the resulting bytes.
    pub fn backward(
        &self,
        fwd: &Forward,
        images: &[f32],
        dlogits: &[f32],
        binarized: bool,
        threads: usize,
    ) -> Vec<LayerGrads> {
        self.backward_with(fwd, images, dlogits, binarized, threads, &mut TrainArena::new())
    }

    /// [`Net::backward`] drawing its buffers from `arena` — the
    /// training-loop entry point.  Bit-identical to `backward`: arena
    /// buffers come out zero-filled and the recycled per-shard `parts`
    /// buffer feeding `tensor::*_grads_mt_with` is likewise re-zeroed
    /// before the shards write into it.
    #[allow(clippy::too_many_arguments)]
    pub fn backward_with(
        &self,
        fwd: &Forward,
        images: &[f32],
        dlogits: &[f32],
        binarized: bool,
        threads: usize,
        arena: &mut TrainArena,
    ) -> Vec<LayerGrads> {
        let t_steps = self.spec.num_steps;
        let batch = fwd.batch;
        let mut grads: Vec<LayerGrads> =
            self.layers.iter().map(|_| LayerGrads::default()).collect();
        // Gradient flowing into the current layer's OUTPUT spike train.
        let mut d_spikes: Vec<f32> = Vec::new();
        // Residue-gradient scratch shared by every if_backward call.
        let mut g_vres = arena.take_zeroed(0);

        for li in (0..self.layers.len()).rev() {
            let cache = &fwd.caches[li];
            let x_in_spikes = if li > 0 { Some(&fwd.caches[li - 1].spikes) } else { None };
            match &self.layers[li] {
                TrainLayer::Readout { n_out, n_in, w: wts } => {
                    let (ni, no) = (*n_in, *n_out);
                    let wb: &[f32] = if binarized { &cache.wb } else { wts };
                    let x_in = x_in_spikes.expect("readout has an input layer");
                    // The same dlogits row feeds every time step, so
                    // `dx` is computed once and broadcast, and `dw`
                    // contracts against the spike train summed over T.
                    // The sum itself is exact for hard 0/1 spikes, but
                    // the contraction groups rounding differently than
                    // PR3's per-step accumulation (g*k vs k additions
                    // of g) — deterministic, NOT bit-identical to the
                    // frozen baseline (see baselines::stbp_scalar).
                    let mut x_sum = arena.take_zeroed(batch * ni);
                    for t in 0..t_steps {
                        let plane = &x_in[t * batch * ni..(t + 1) * batch * ni];
                        for (a, &v) in x_sum.iter_mut().zip(plane) {
                            *a += v;
                        }
                    }
                    let mut dw = arena.take_zeroed(wts.len());
                    let mut dx1 = arena.take_zeroed(batch * ni);
                    tensor::matmul_nt_grads_mt_with(
                        &x_sum,
                        batch,
                        ni,
                        wb,
                        no,
                        dlogits,
                        &mut dx1,
                        &mut dw,
                        threads,
                        &mut arena.parts,
                    );
                    let mut dx = arena.take_zeroed(t_steps * batch * ni);
                    for plane in dx.chunks_mut(batch * ni) {
                        plane.copy_from_slice(&dx1);
                    }
                    arena.give(x_sum);
                    arena.give(dx1);
                    grads[li].w = dw;
                    arena.give(std::mem::replace(&mut d_spikes, dx));
                }
                TrainLayer::Fc { n_out, n_in, w: wts, bn } => {
                    let (ni, no) = (*n_in, *n_out);
                    let wb: &[f32] = if binarized { &cache.wb } else { wts };
                    let x_in = x_in_spikes.expect("fc has an input layer");
                    if_backward_with(
                        &mut d_spikes,
                        &cache.spikes,
                        &cache.v_pre,
                        t_steps,
                        batch * no,
                        &mut g_vres,
                    );
                    let n = t_steps * batch;
                    let mut dgamma = arena.take_zeroed(no);
                    let mut dbeta = arena.take_zeroed(no);
                    bn.backward(&cache.bn, &mut d_spikes, n, 1, &mut dgamma, &mut dbeta, threads);
                    let mut dw = arena.take_zeroed(wts.len());
                    let mut dx = arena.take_zeroed(n * ni);
                    tensor::matmul_nt_grads_mt_with(
                        x_in,
                        n,
                        ni,
                        wb,
                        no,
                        &d_spikes,
                        &mut dx,
                        &mut dw,
                        threads,
                        &mut arena.parts,
                    );
                    grads[li] = LayerGrads { w: dw, gamma: dgamma, beta: dbeta };
                    arena.give(std::mem::replace(&mut d_spikes, dx));
                }
                TrainLayer::MaxPool => {
                    let prev = &fwd.caches[li - 1];
                    let n = t_steps * batch;
                    let mut dx = arena.take_zeroed(n * prev.c * prev.h * prev.w);
                    tensor::maxpool2_grads(
                        &prev.spikes,
                        n,
                        prev.c,
                        prev.h,
                        prev.w,
                        &cache.spikes,
                        &d_spikes,
                        &mut dx,
                    );
                    arena.give(std::mem::replace(&mut d_spikes, dx));
                }
                TrainLayer::Conv { enc, c_out, c_in, k, w: wts, bn } => {
                    let (ci, co, kk) = (*c_in, *c_out, *k);
                    let wb: &[f32] = if binarized { &cache.wb } else { wts };
                    let (h, w) = (cache.h, cache.w);
                    let hw = h * w;
                    let m = batch * co * hw;
                    if_backward_with(
                        &mut d_spikes,
                        &cache.spikes,
                        &cache.v_pre,
                        t_steps,
                        m,
                        &mut g_vres,
                    );
                    let mut dgamma = arena.take_zeroed(co);
                    let mut dbeta = arena.take_zeroed(co);
                    let mut dw = arena.take_zeroed(wts.len());
                    if *enc {
                        // The broadcast over T sums the per-step grads.
                        let bf = batch * co * hw;
                        let mut dy = arena.take_zeroed(bf);
                        for t in 0..t_steps {
                            for (d, &g) in dy.iter_mut().zip(&d_spikes[t * bf..(t + 1) * bf]) {
                                *d += g;
                            }
                        }
                        bn.backward(
                            &cache.bn, &mut dy, batch, hw, &mut dgamma, &mut dbeta, threads,
                        );
                        let mut dx = arena.take_zeroed(batch * ci * hw);
                        tensor::conv2d_same_grads_mt_with(
                            images,
                            batch,
                            ci,
                            h,
                            w,
                            wb,
                            co,
                            kk,
                            &dy,
                            &mut dx,
                            &mut dw,
                            threads,
                            &mut arena.parts,
                        );
                        arena.give(dy);
                        arena.give(dx);
                        // input image needs no gradient
                        arena.give(std::mem::take(&mut d_spikes));
                    } else {
                        let n = t_steps * batch;
                        let x_in = x_in_spikes.expect("conv has an input layer");
                        bn.backward(
                            &cache.bn, &mut d_spikes, n, hw, &mut dgamma, &mut dbeta, threads,
                        );
                        let mut dx = arena.take_zeroed(n * ci * hw);
                        tensor::conv2d_same_grads_mt_with(
                            x_in,
                            n,
                            ci,
                            h,
                            w,
                            wb,
                            co,
                            kk,
                            &d_spikes,
                            &mut dx,
                            &mut dw,
                            threads,
                            &mut arena.parts,
                        );
                        arena.give(std::mem::replace(&mut d_spikes, dx));
                    }
                    grads[li] = LayerGrads { w: dw, gamma: dgamma, beta: dbeta };
                }
            }
        }
        arena.give(g_vres);
        arena.give(d_spikes);
        grads
    }
}

/// IF dynamics over `(T, m)` psums with hard reset, fixed `v_th`.
/// `Hard`: `o = H(v_pre - v_th)`.  `Soft`: `o = clamp(v_pre - v_th +
/// 1/2, 0, 1)` (continuous ramp with the same surrogate window).
pub fn if_forward(
    psums: &[f32],
    t_steps: usize,
    m: usize,
    mode: SpikeMode,
    spikes: &mut [f32],
    v_pre_out: &mut [f32],
) {
    assert_eq!(psums.len(), t_steps * m, "psum geometry");
    if_forward_strided(psums, m, t_steps, m, mode, spikes, v_pre_out, &mut Vec::new());
}

/// [`if_forward`] for the encoding layer's constant drive (§III-F, the
/// trainer's twin of the golden engine's `if_fire_constant`): one
/// `(m,)` psum plane feeds every time step, so the caller never
/// materializes T copies.  Spikes and membranes still differ per step
/// (the hard reset couples them through time) and are written out in
/// full for the backward pass.
pub fn if_forward_broadcast(
    psum: &[f32],
    t_steps: usize,
    m: usize,
    mode: SpikeMode,
    spikes: &mut [f32],
    v_pre_out: &mut [f32],
) {
    assert_eq!(psum.len(), m, "broadcast psum geometry");
    if_forward_strided(psum, 0, t_steps, m, mode, spikes, v_pre_out, &mut Vec::new());
}

/// Shared IF recurrence: step `t` reads its psums at `psums[t * stride
/// ..][..m]` (`stride = m` per-step, `stride = 0` broadcast).  `v_res`
/// is caller-owned membrane-residue scratch (cleared and re-zeroed here,
/// so reuse across calls is bit-identical to a fresh buffer).
#[allow(clippy::too_many_arguments)]
fn if_forward_strided(
    psums: &[f32],
    stride: usize,
    t_steps: usize,
    m: usize,
    mode: SpikeMode,
    spikes: &mut [f32],
    v_pre_out: &mut [f32],
    v_res: &mut Vec<f32>,
) {
    assert_eq!(spikes.len(), t_steps * m, "spike geometry");
    assert_eq!(v_pre_out.len(), t_steps * m, "membrane geometry");
    v_res.clear();
    v_res.resize(m, 0.0);
    for t in 0..t_steps {
        let ps = &psums[t * stride..t * stride + m];
        let sp = &mut spikes[t * m..(t + 1) * m];
        let vp = &mut v_pre_out[t * m..(t + 1) * m];
        for j in 0..m {
            let pre = v_res[j] + ps[j];
            let o = match mode {
                SpikeMode::Hard => {
                    if pre >= V_TH {
                        1.0
                    } else {
                        0.0
                    }
                }
                SpikeMode::Soft => (pre - V_TH + SURR_HALF).clamp(0.0, 1.0),
            };
            v_res[j] = pre * (1.0 - o);
            sp[j] = o;
            vp[j] = pre;
        }
    }
}

/// Backward of [`if_forward`], in place over `d_spikes` (which becomes
/// the psum gradient).  Rectangular surrogate `do/dv = 1(|v_pre - v_th|
/// < 1/2)`; the reset is differentiated through both `v_pre` and `o`.
pub fn if_backward(d_spikes: &mut [f32], spikes: &[f32], v_pre: &[f32], t_steps: usize, m: usize) {
    if_backward_with(d_spikes, spikes, v_pre, t_steps, m, &mut Vec::new());
}

/// [`if_backward`] with caller-owned residue-gradient scratch (cleared
/// and re-zeroed here — reuse is bit-identical to a fresh buffer).
pub fn if_backward_with(
    d_spikes: &mut [f32],
    spikes: &[f32],
    v_pre: &[f32],
    t_steps: usize,
    m: usize,
    g_vres: &mut Vec<f32>,
) {
    assert_eq!(d_spikes.len(), t_steps * m, "spike-grad geometry");
    g_vres.clear();
    g_vres.resize(m, 0.0);
    for t in (0..t_steps).rev() {
        let base = t * m;
        for j in 0..m {
            let vp = v_pre[base + j];
            let g_o = d_spikes[base + j] - g_vres[j] * vp;
            let window = if (vp - V_TH).abs() < SURR_HALF { 1.0 } else { 0.0 };
            let g = g_vres[j] * (1.0 - spikes[base + j]) + g_o * window;
            d_spikes[base + j] = g;
            g_vres[j] = g;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::models;

    #[test]
    fn forward_shapes_and_determinism() {
        let spec = models::micro(2);
        let net = Net::init(&spec, 7);
        let images = vec![0.5f32; 3 * spec.in_channels * spec.in_size * spec.in_size];
        let a = net.forward(&images, 3, SpikeMode::Hard, true, 1);
        assert_eq!(a.logits.len(), 3 * net.classes());
        let b = net.forward(&images, 3, SpikeMode::Hard, true, 1);
        assert_eq!(a.logits, b.logits);
        // different seeds give different nets
        let other = Net::init(&spec, 8);
        let c = other.forward(&images, 3, SpikeMode::Hard, true, 1);
        assert_ne!(a.logits, c.logits);
    }

    #[test]
    fn hard_spikes_are_binary() {
        let spec = models::micro(3);
        let net = Net::init(&spec, 1);
        let images: Vec<f32> = (0..spec.in_size * spec.in_size)
            .map(|v| (v % 256) as f32 / 255.0)
            .collect();
        let fwd = net.forward(&images, 1, SpikeMode::Hard, true, 1);
        for cache in &fwd.caches {
            for &s in &cache.spikes {
                assert!(s == 0.0 || s == 1.0, "non-binary hard spike {s}");
            }
        }
    }

    #[test]
    fn if_soft_matches_hard_away_from_threshold() {
        // psums far from v_th: the ramp saturates to the hard value.
        let psums = vec![3.0f32, -2.0, 3.0, -2.0]; // T=2, m=2
        let mut hard_s = vec![0.0; 4];
        let mut hard_v = vec![0.0; 4];
        let mut soft_s = vec![0.0; 4];
        let mut soft_v = vec![0.0; 4];
        if_forward(&psums, 2, 2, SpikeMode::Hard, &mut hard_s, &mut hard_v);
        if_forward(&psums, 2, 2, SpikeMode::Soft, &mut soft_s, &mut soft_v);
        assert_eq!(hard_s, soft_s);
        assert_eq!(hard_v, soft_v);
    }

    #[test]
    fn broadcast_if_matches_materialized_psums() {
        // The broadcast recurrence must equal if_forward fed T copies.
        let m = 5;
        let t_steps = 4;
        let mut rng = crate::util::rng::SplitMix64::new(13);
        let plane: Vec<f32> = (0..m).map(|_| (rng.next_f64() * 3.0 - 1.0) as f32).collect();
        let mut copies = vec![0.0f32; t_steps * m];
        for chunk in copies.chunks_mut(m) {
            chunk.copy_from_slice(&plane);
        }
        for mode in [SpikeMode::Hard, SpikeMode::Soft] {
            let mut s_a = vec![0.0; t_steps * m];
            let mut v_a = vec![0.0; t_steps * m];
            let mut s_b = vec![0.0; t_steps * m];
            let mut v_b = vec![0.0; t_steps * m];
            if_forward(&copies, t_steps, m, mode, &mut s_a, &mut v_a);
            if_forward_broadcast(&plane, t_steps, m, mode, &mut s_b, &mut v_b);
            assert_eq!(s_a, s_b);
            assert_eq!(v_a, v_b);
        }
    }

    #[test]
    fn backward_produces_grads_for_every_weight_layer() {
        let spec = models::micro(2);
        let net = Net::init(&spec, 3);
        let b = 2;
        let images = vec![0.3f32; b * spec.in_size * spec.in_size];
        let fwd = net.forward(&images, b, SpikeMode::Hard, true, 1);
        let dlogits = vec![0.1f32; b * net.classes()];
        let grads = net.backward(&fwd, &images, &dlogits, true, 1);
        assert_eq!(grads.len(), net.layers.len());
        for (ly, g) in net.layers.iter().zip(&grads) {
            match ly {
                TrainLayer::Conv { w, bn, .. } => {
                    assert_eq!(g.w.len(), w.len());
                    assert_eq!(g.gamma.len(), bn.channels());
                }
                TrainLayer::Fc { w, bn, .. } => {
                    assert_eq!(g.w.len(), w.len());
                    assert_eq!(g.gamma.len(), bn.channels());
                }
                TrainLayer::Readout { w, .. } => assert_eq!(g.w.len(), w.len()),
                TrainLayer::MaxPool => assert!(g.w.is_empty()),
            }
        }
    }

    #[test]
    fn arena_paths_are_bit_identical_to_allocating_paths() {
        // `tiny` exercises every layer kind (enc conv, pool, spiking
        // conv, fc, readout).  Run three steps through ONE arena so the
        // later steps consume recycled (previously dirty) buffers — the
        // logits, every cached train, and every gradient must still
        // match the fresh-allocation path byte for byte.
        let spec = models::tiny(3);
        let net = Net::init(&spec, 23);
        let b = 3;
        let plane = spec.in_channels * spec.in_size * spec.in_size;
        let nc = net.classes();
        let images: Vec<f32> = (0..b * plane).map(|v| (v % 97) as f32 / 96.0).collect();
        let dlogits: Vec<f32> = (0..b * nc).map(|v| (v as f32 - 3.0) * 0.01).collect();
        let fwd = net.forward(&images, b, SpikeMode::Hard, true, 2);
        let grads = net.backward(&fwd, &images, &dlogits, true, 2);
        let mut arena = TrainArena::new();
        for step in 0..3 {
            let f2 = net.forward_with(&images, b, SpikeMode::Hard, true, 2, &mut arena);
            assert_eq!(fwd.logits, f2.logits, "logits drifted at arena step {step}");
            for li in 0..net.layers.len() {
                assert_eq!(
                    fwd.layer_cache(li),
                    f2.layer_cache(li),
                    "layer {li} cache drifted at arena step {step}"
                );
            }
            let g2 = net.backward_with(&f2, &images, &dlogits, true, 2, &mut arena);
            assert_eq!(grads, g2, "grads drifted at arena step {step}");
            arena.recycle_grads(g2);
            arena.recycle_forward(f2);
        }
    }

    #[test]
    fn forward_and_backward_identical_across_thread_counts() {
        let spec = models::micro(3);
        let net = Net::init(&spec, 19);
        let b = 5;
        let plane = spec.in_size * spec.in_size;
        let nc = net.classes();
        let images: Vec<f32> = (0..b * plane).map(|v| (v % 97) as f32 / 96.0).collect();
        let dlogits: Vec<f32> = (0..b * nc).map(|v| (v as f32 - 3.0) * 0.01).collect();
        let run = |threads: usize| {
            let fwd = net.forward(&images, b, SpikeMode::Hard, true, threads);
            let grads = net.backward(&fwd, &images, &dlogits, true, threads);
            (fwd.logits, grads)
        };
        let base = run(1);
        for t in [2, 4, 7] {
            assert_eq!(base, run(t), "training math must not depend on threads={t}");
        }
    }
}

//! Closed-loop synthetic load generator for the coordinator — shared by
//! `vsa serve-bench` and `benches/bench_serve.rs`.
//!
//! `submitters` threads each drive a closed loop (submit, wait for the
//! typed outcome, repeat) over a round-robin slice of the image set, so
//! concurrency is bounded and the tally is exact: every request lands in
//! exactly one [`LoadReport`] bucket, which the callers cross-check
//! against the coordinator's own counters.

use crate::coordinator::server::{Coordinator, RejectReason, ServeError, ServeResult};
use std::time::{Duration, Instant};

/// How the generator drives the pool.
#[derive(Debug, Clone)]
pub struct LoadSpec {
    /// Total requests across all submitters.
    pub requests: usize,
    /// Concurrent closed-loop submitter threads.
    pub submitters: usize,
    /// `None` = blocking submit (backpressure); `Some(ZERO)` = fail-fast
    /// `try_submit`; `Some(w)` = `submit_timeout(w)`.  Per-request
    /// deadlines come from the coordinator's config, not from here.
    pub submit_wait: Option<Duration>,
}

impl Default for LoadSpec {
    fn default() -> Self {
        Self { requests: 256, submitters: 4, submit_wait: None }
    }
}

/// Terminal-outcome tally over one load run.
#[derive(Debug, Default, Clone)]
pub struct LoadReport {
    pub ok: u64,
    pub engine_failed: u64,
    pub panicked: u64,
    pub shed_queue: u64,
    pub shed_deadline: u64,
    pub shed_shutdown: u64,
    pub wall: Duration,
}

impl LoadReport {
    fn absorb(&mut self, outcome: &ServeResult) {
        match outcome {
            Ok(_) => self.ok += 1,
            Err(ServeError::Rejected(RejectReason::QueueFull)) => self.shed_queue += 1,
            Err(ServeError::Rejected(RejectReason::Deadline)) => self.shed_deadline += 1,
            Err(ServeError::Rejected(RejectReason::Shutdown)) => self.shed_shutdown += 1,
            Err(ServeError::EngineFailed { .. }) => self.engine_failed += 1,
            Err(ServeError::WorkerPanicked) => self.panicked += 1,
        }
    }

    fn merge(&mut self, other: &LoadReport) {
        self.ok += other.ok;
        self.engine_failed += other.engine_failed;
        self.panicked += other.panicked;
        self.shed_queue += other.shed_queue;
        self.shed_deadline += other.shed_deadline;
        self.shed_shutdown += other.shed_shutdown;
    }

    /// Total requests tallied (must equal the spec's request count).
    pub fn total(&self) -> u64 {
        self.ok
            + self.engine_failed
            + self.panicked
            + self.shed_queue
            + self.shed_deadline
            + self.shed_shutdown
    }

    /// One-line summary for logs and bench output.
    pub fn render(&self) -> String {
        format!(
            "ok {} | engine-failed {} | panicked {} | shed queue/deadline/shutdown {}/{}/{} \
             | wall {:.1} ms",
            self.ok,
            self.engine_failed,
            self.panicked,
            self.shed_queue,
            self.shed_deadline,
            self.shed_shutdown,
            self.wall.as_secs_f64() * 1e3
        )
    }
}

/// Drive `spec.requests` requests through `coord`, cycling over
/// `images`, and tally every typed outcome.  Submit-time rejections
/// (queue full, dead pool) are tallied in the same buckets as
/// post-acceptance sheds, so the report always sums to the request
/// count.
pub fn run_load(coord: &Coordinator, images: &[Vec<u8>], spec: &LoadSpec) -> LoadReport {
    assert!(!images.is_empty(), "run_load needs at least one image");
    let t0 = Instant::now();
    let subs = spec.submitters.max(1);
    let n = spec.requests;
    let mut total = LoadReport::default();
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(subs);
        for t in 0..subs {
            handles.push(s.spawn(move || {
                let mut tally = LoadReport::default();
                let mut i = t;
                while i < n {
                    let image = images[i % images.len()].clone();
                    let submitted = match spec.submit_wait {
                        None => coord.submit(image),
                        Some(w) if w.is_zero() => coord.try_submit(image),
                        Some(w) => coord.submit_timeout(image, w),
                    };
                    let outcome = match submitted {
                        Ok(rx) => rx.recv().unwrap_or(Err(ServeError::WorkerPanicked)),
                        Err(e) => Err(e),
                    };
                    tally.absorb(&outcome);
                    i += subs;
                }
                tally
            }));
        }
        for h in handles {
            total.merge(&h.join().expect("submitter thread panicked"));
        }
    });
    total.wall = t0.elapsed();
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::models;
    use crate::coordinator::engine::GoldenEngine;
    use crate::coordinator::server::CoordinatorConfig;
    use crate::data::synth;
    use crate::snn::params::DeployedModel;
    use crate::snn::Network;

    fn tiny_net() -> Network {
        Network::new(DeployedModel::synthesize(&models::tiny(2), 42))
    }

    #[test]
    fn clean_load_completes_everything_and_balances() {
        let coord = Coordinator::start(
            CoordinatorConfig { workers: 2, max_batch: 4, ..CoordinatorConfig::default() },
            |_| Box::new(GoldenEngine::new(tiny_net(), 4)),
        );
        let samples = synth::tiny_like(3, 0, 8);
        let images: Vec<Vec<u8>> = samples.into_iter().map(|s| s.image).collect();
        let spec = LoadSpec { requests: 40, submitters: 4, submit_wait: None };
        let report = run_load(&coord, &images, &spec);
        assert_eq!(report.total(), 40);
        assert_eq!(report.ok, 40, "clean run: everything completes");
        let stats = coord.shutdown();
        assert_eq!(stats.submitted, 40);
        assert_eq!(stats.completed + stats.failed + stats.shed, stats.submitted);
    }
}

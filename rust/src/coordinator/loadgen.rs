//! Closed-loop synthetic load generator for the coordinator — shared by
//! `vsa serve-bench` and `benches/bench_serve.rs`.
//!
//! `submitters` threads each drive a closed loop (submit, wait for the
//! typed outcome, repeat) over a weighted model mix, so concurrency is
//! bounded and the tally is exact: every request lands in exactly one
//! [`LoadReport`] bucket, which the callers cross-check against the
//! coordinator's own counters.
//!
//! Multi-model (PR9): traffic is a weighted set of [`ModelTraffic`]
//! entries.  The model for global request `i` is picked by a
//! deterministic hash of `i` (no RNG state, no clock), so the same spec
//! replays the same interleaving on every run and across submitter
//! counts.

use crate::coordinator::registry::ModelId;
use crate::coordinator::server::{Coordinator, RejectReason, ServeError, ServeResult};
use std::time::{Duration, Instant};

/// One model's share of the generated traffic.
#[derive(Debug, Clone)]
pub struct ModelTraffic {
    pub model: ModelId,
    /// Relative weight of this model in the mix (picked per request).
    pub weight: u32,
    /// Images cycled round-robin for this model's requests.
    pub images: Vec<Vec<u8>>,
}

/// How the generator drives the pool.
#[derive(Debug, Clone)]
pub struct LoadSpec {
    /// Total requests across all submitters.
    pub requests: usize,
    /// Concurrent closed-loop submitter threads.
    pub submitters: usize,
    /// `None` = blocking submit (backpressure); `Some(ZERO)` = fail-fast
    /// `try_submit`; `Some(w)` = `submit_timeout(w)`.  Per-request
    /// deadlines come from the coordinator's config, not from here.
    pub submit_wait: Option<Duration>,
}

impl Default for LoadSpec {
    fn default() -> Self {
        Self { requests: 256, submitters: 4, submit_wait: None }
    }
}

/// Terminal-outcome tally over one load run.
#[derive(Debug, Default, Clone)]
pub struct LoadReport {
    pub ok: u64,
    pub engine_failed: u64,
    pub panicked: u64,
    pub shed_queue: u64,
    pub shed_deadline: u64,
    pub shed_shutdown: u64,
    pub wall: Duration,
}

impl LoadReport {
    fn absorb(&mut self, outcome: &ServeResult) {
        match outcome {
            Ok(_) => self.ok += 1,
            Err(ServeError::Rejected(RejectReason::QueueFull)) => self.shed_queue += 1,
            Err(ServeError::Rejected(RejectReason::Deadline)) => self.shed_deadline += 1,
            Err(ServeError::Rejected(RejectReason::Shutdown)) => self.shed_shutdown += 1,
            Err(ServeError::EngineFailed { .. }) => self.engine_failed += 1,
            Err(ServeError::WorkerPanicked) => self.panicked += 1,
        }
    }

    fn merge(&mut self, other: &LoadReport) {
        self.ok += other.ok;
        self.engine_failed += other.engine_failed;
        self.panicked += other.panicked;
        self.shed_queue += other.shed_queue;
        self.shed_deadline += other.shed_deadline;
        self.shed_shutdown += other.shed_shutdown;
    }

    /// Total requests tallied (must equal the spec's request count).
    pub fn total(&self) -> u64 {
        self.ok
            + self.engine_failed
            + self.panicked
            + self.shed_queue
            + self.shed_deadline
            + self.shed_shutdown
    }

    /// One-line summary for logs and bench output.
    pub fn render(&self) -> String {
        format!(
            "ok {} | engine-failed {} | panicked {} | shed queue/deadline/shutdown {}/{}/{} \
             | wall {:.1} ms",
            self.ok,
            self.engine_failed,
            self.panicked,
            self.shed_queue,
            self.shed_deadline,
            self.shed_shutdown,
            self.wall.as_secs_f64() * 1e3
        )
    }
}

/// Which traffic entry serves global request `i`: a SplitMix-style hash
/// of the request index walks the cumulative weights — deterministic,
/// stateless, and independent of the submitter thread that issues it.
pub fn pick_traffic(traffic: &[ModelTraffic], i: usize) -> usize {
    let total: u64 = traffic.iter().map(|t| t.weight as u64).sum();
    debug_assert!(total > 0, "traffic weights must not all be zero");
    let h = (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let mut r = (h >> 33) % total;
    for (t, tr) in traffic.iter().enumerate() {
        if r < tr.weight as u64 {
            return t;
        }
        r -= tr.weight as u64;
    }
    unreachable!("cumulative weight walk covers the draw range")
}

/// Drive `spec.requests` requests through `coord` over the weighted
/// model mix, cycling each model's image set, and tally every typed
/// outcome.  Submit-time rejections (queue full, dead pool) are tallied
/// in the same buckets as post-acceptance sheds, so the report always
/// sums to the request count.
pub fn run_load(coord: &Coordinator, traffic: &[ModelTraffic], spec: &LoadSpec) -> LoadReport {
    assert!(!traffic.is_empty(), "run_load needs at least one traffic entry");
    assert!(
        traffic.iter().all(|t| !t.images.is_empty()),
        "every traffic entry needs at least one image"
    );
    assert!(traffic.iter().any(|t| t.weight > 0), "at least one weight must be positive");
    let t0 = Instant::now();
    let subs = spec.submitters.max(1);
    let n = spec.requests;
    let mut total = LoadReport::default();
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(subs);
        for t in 0..subs {
            handles.push(s.spawn(move || {
                let mut tally = LoadReport::default();
                let mut i = t;
                while i < n {
                    let tr = &traffic[pick_traffic(traffic, i)];
                    let image = tr.images[i % tr.images.len()].clone();
                    let submitted = match spec.submit_wait {
                        None => coord.submit(tr.model, image),
                        Some(w) if w.is_zero() => coord.try_submit(tr.model, image),
                        Some(w) => coord.submit_timeout(tr.model, image, w),
                    };
                    let outcome = match submitted {
                        Ok(rx) => rx.recv().unwrap_or(Err(ServeError::WorkerPanicked)),
                        Err(e) => Err(e),
                    };
                    tally.absorb(&outcome);
                    i += subs;
                }
                tally
            }));
        }
        for h in handles {
            total.merge(&h.join().expect("submitter thread panicked"));
        }
    });
    total.wall = t0.elapsed();
    total
}

/// Single-model convenience: all requests go to `model`.
pub fn run_load_single(
    coord: &Coordinator,
    model: ModelId,
    images: &[Vec<u8>],
    spec: &LoadSpec,
) -> LoadReport {
    let traffic = [ModelTraffic { model, weight: 1, images: images.to_vec() }];
    run_load(coord, &traffic, spec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::models;
    use crate::coordinator::engine::GoldenEngine;
    use crate::coordinator::registry::ModelRegistry;
    use crate::coordinator::server::CoordinatorConfig;
    use crate::data::synth;
    use crate::snn::params::DeployedModel;
    use crate::telemetry::Registry;
    use std::sync::Arc;

    fn tiny(seed: u64) -> DeployedModel {
        DeployedModel::synthesize(&models::tiny(2), seed)
    }

    fn images() -> Vec<Vec<u8>> {
        synth::tiny_like(3, 0, 8).into_iter().map(|s| s.image).collect()
    }

    #[test]
    fn clean_load_completes_everything_and_balances() {
        let (reg, m) = ModelRegistry::single(tiny(42));
        let regc = Arc::clone(&reg);
        let coord = Coordinator::start(
            CoordinatorConfig { workers: 2, max_batch: 4, ..CoordinatorConfig::default() },
            reg,
            move |_| Box::new(GoldenEngine::new(Arc::clone(&regc), 4)),
        );
        let spec = LoadSpec { requests: 40, submitters: 4, submit_wait: None };
        let report = run_load_single(&coord, m, &images(), &spec);
        assert_eq!(report.total(), 40);
        assert_eq!(report.ok, 40, "clean run: everything completes");
        let stats = coord.shutdown();
        assert_eq!(stats.submitted, 40);
        assert_eq!(stats.completed + stats.failed + stats.shed, stats.submitted);
    }

    #[test]
    fn pick_traffic_is_deterministic_and_roughly_weighted() {
        let mut reg = ModelRegistry::new();
        let a = reg.register("a", tiny(1)).unwrap();
        let b = reg.register("b", tiny(2)).unwrap();
        let traffic = [
            ModelTraffic { model: a, weight: 3, images: vec![vec![0u8; 4]] },
            ModelTraffic { model: b, weight: 1, images: vec![vec![0u8; 4]] },
        ];
        let picks: Vec<usize> = (0..4000).map(|i| pick_traffic(&traffic, i)).collect();
        let again: Vec<usize> = (0..4000).map(|i| pick_traffic(&traffic, i)).collect();
        assert_eq!(picks, again, "same index, same pick — replayable");
        let heavy = picks.iter().filter(|&&p| p == 0).count();
        // 4000 draws at p=0.75: expect ~3000; allow a wide 6-sigma band.
        assert!((2800..=3200).contains(&heavy), "got {heavy} picks of the 3-weight model");
    }

    #[test]
    fn mixed_load_reaches_both_models() {
        let mut reg = ModelRegistry::new();
        let a = reg.register("a", tiny(1)).unwrap();
        let b = reg.register("b", tiny(2)).unwrap();
        let reg = Arc::new(reg);
        let regc = Arc::clone(&reg);
        let mut coord = Coordinator::start(
            CoordinatorConfig { workers: 2, max_batch: 4, ..CoordinatorConfig::default() },
            Arc::clone(&reg),
            move |_| Box::new(GoldenEngine::new(Arc::clone(&regc), 4)),
        );
        let traffic = [
            ModelTraffic { model: a, weight: 1, images: images() },
            ModelTraffic { model: b, weight: 1, images: images() },
        ];
        let spec = LoadSpec { requests: 48, submitters: 4, submit_wait: None };
        let report = run_load(&coord, &traffic, &spec);
        assert_eq!(report.ok, 48);
        coord.drain();
        let treg = Registry::new();
        coord.export_into(&treg, "serve");
        let snap = treg.snapshot();
        let ca = snap.counters["serve.model.a.completed"];
        let cb = snap.counters["serve.model.b.completed"];
        assert_eq!(ca + cb, 48, "per-model completions sum to the request count");
        assert!(ca > 0 && cb > 0, "both models saw traffic (got {ca}/{cb})");
    }
}

//! The coordinator: bounded submission queue, batcher loop, worker pool.

use crate::coordinator::batcher::{next_batch, Request};
use crate::coordinator::engine::InferenceEngine;
use crate::util::stats::Accumulator;
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Coordinator tuning knobs.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Bounded queue depth — submissions beyond this block (backpressure).
    pub queue_depth: usize,
    /// Maximum images per engine batch.
    pub max_batch: usize,
    /// Max time the batcher waits for a batch to fill.
    pub max_wait: Duration,
    /// Worker threads (each owns one engine instance).
    pub workers: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            queue_depth: 256,
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            workers: 2,
        }
    }
}

/// One classification result.
#[derive(Debug, Clone)]
pub struct InferResult {
    pub id: u64,
    pub logits: Vec<i64>,
    pub latency: Duration,
}

/// Aggregate serving statistics.
#[derive(Debug, Clone)]
pub struct ServeStats {
    pub completed: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub latency_ms_p50: f64,
    pub latency_ms_p95: f64,
    pub latency_ms_p99: f64,
    pub throughput_rps: f64,
}

struct Shared {
    latency: Mutex<Accumulator>,
    completed: AtomicU64,
    batches: AtomicU64,
    batched_requests: AtomicU64,
}

type Payload = (Vec<u8>, Sender<InferResult>);

/// A running coordinator instance.
pub struct Coordinator {
    tx: Option<SyncSender<Request<Payload>>>,
    workers: Vec<JoinHandle<()>>,
    shared: Arc<Shared>,
    next_id: AtomicU64,
    started: Instant,
}

impl Coordinator {
    /// Start the worker pool.  `make_engine` builds one engine per worker
    /// and runs *inside* that worker's thread (engines need not be `Send`
    /// — PJRT client handles are thread-local).
    pub fn start(
        cfg: CoordinatorConfig,
        make_engine: impl Fn(usize) -> Box<dyn InferenceEngine> + Send + Sync + 'static,
    ) -> Self {
        let (tx, rx) = sync_channel::<Request<Payload>>(cfg.queue_depth);
        let rx = Arc::new(Mutex::new(rx));
        let make_engine = Arc::new(make_engine);
        let shared = Arc::new(Shared {
            latency: Mutex::new(Accumulator::default()),
            completed: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
        });

        let mut workers = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let rx = Arc::clone(&rx);
            let shared = Arc::clone(&shared);
            let make_engine = Arc::clone(&make_engine);
            let cfg_max_batch = cfg.max_batch;
            let max_wait = cfg.max_wait;
            workers.push(std::thread::spawn(move || {
                let mut engine = make_engine(w);
                let max_batch = cfg_max_batch.min(engine.batch_size()).max(1);
                loop {
                    // Only one worker holds the queue lock while *forming*
                    // a batch; inference runs outside the lock.
                    let batch = {
                        let rx = rx.lock().unwrap();
                        next_batch(&rx, max_batch, max_wait)
                    };
                    let Some(batch) = batch else { break };
                    shared.batches.fetch_add(1, Ordering::Relaxed);
                    shared
                        .batched_requests
                        .fetch_add(batch.len() as u64, Ordering::Relaxed);

                    let images: Vec<Vec<u8>> =
                        batch.iter().map(|r| r.payload.0.clone()).collect();
                    match engine.infer(&images) {
                        Ok(results) => {
                            for (req, logits) in batch.into_iter().zip(results) {
                                let latency = req.enqueued.elapsed();
                                shared
                                    .latency
                                    .lock()
                                    .unwrap()
                                    .push(latency.as_secs_f64() * 1e3);
                                shared.completed.fetch_add(1, Ordering::Relaxed);
                                let _ = req.payload.1.send(InferResult {
                                    id: req.id,
                                    logits,
                                    latency,
                                });
                            }
                        }
                        Err(e) => {
                            eprintln!("worker {w} ({}) failed: {e:#}", engine.name());
                            // Responses dropped; submitters see a closed
                            // channel and surface the error.
                        }
                    }
                }
            }));
        }

        Self {
            tx: Some(tx),
            workers,
            shared,
            next_id: AtomicU64::new(0),
            started: Instant::now(),
        }
    }

    /// Submit one image; blocks when the queue is full (backpressure).
    /// Returns the receiver for the result.
    pub fn submit(&self, image: Vec<u8>) -> Result<Receiver<InferResult>> {
        let (rtx, rrx) = std::sync::mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.tx
            .as_ref()
            .expect("coordinator not shut down")
            .send(Request { id, payload: (image, rtx), enqueued: Instant::now() })
            .map_err(|_| anyhow::anyhow!("coordinator is shut down"))?;
        Ok(rrx)
    }

    /// Convenience: submit and wait.
    pub fn infer_blocking(&self, image: Vec<u8>) -> Result<InferResult> {
        let rx = self.submit(image)?;
        rx.recv().map_err(|_| anyhow::anyhow!("worker dropped the request"))
    }

    /// Drain the queue and join the workers.
    pub fn shutdown(mut self) -> ServeStats {
        drop(self.tx.take()); // close the queue; workers exit after drain
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.stats()
    }

    /// Current aggregate stats.
    pub fn stats(&self) -> ServeStats {
        let completed = self.shared.completed.load(Ordering::Relaxed);
        let batches = self.shared.batches.load(Ordering::Relaxed);
        let batched = self.shared.batched_requests.load(Ordering::Relaxed);
        let lat = self.shared.latency.lock().unwrap();
        let (p50, p95, p99) = lat.percentiles();
        ServeStats {
            completed,
            batches,
            mean_batch: if batches > 0 { batched as f64 / batches as f64 } else { 0.0 },
            latency_ms_p50: p50,
            latency_ms_p95: p95,
            latency_ms_p99: p99,
            throughput_rps: completed as f64 / self.started.elapsed().as_secs_f64(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::GoldenEngine;
    use crate::snn::params::{DeployedModel, Kind, Layer};
    use crate::snn::Network;

    fn net() -> Network {
        Network::new(DeployedModel {
            name: "s".into(),
            num_steps: 2,
            in_channels: 1,
            in_size: 4,
            layers: vec![
                Layer::Conv {
                    kind: Kind::EncConv,
                    c_out: 2,
                    c_in: 1,
                    k: 1,
                    w: vec![1, -1],
                    bias: vec![0, 0],
                    theta: vec![256 * 10, 256 * 10],
                },
                Layer::Readout { n_out: 10, n_in: 32, w: vec![1; 320] },
            ],
        })
    }

    #[test]
    fn serves_requests_and_batches() {
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 2,
                max_batch: 4,
                max_wait: Duration::from_millis(5),
                queue_depth: 64,
            },
            |_| Box::new(GoldenEngine::new(net(), 4)),
        );
        let receivers: Vec<_> =
            (0..20).map(|i| coord.submit(vec![(i * 12) as u8; 16]).unwrap()).collect();
        for rx in receivers {
            let res = rx.recv().unwrap();
            assert_eq!(res.logits.len(), 10);
        }
        let stats = coord.shutdown();
        assert_eq!(stats.completed, 20);
        assert!(stats.batches <= 20);
        assert!(stats.mean_batch >= 1.0);
    }

    #[test]
    fn results_match_direct_inference() {
        let coord = Coordinator::start(CoordinatorConfig::default(), |_| {
            Box::new(GoldenEngine::new(net(), 8))
        });
        let image = vec![123u8; 16];
        let served = coord.infer_blocking(image.clone()).unwrap();
        assert_eq!(served.logits, net().infer_u8(&image));
        coord.shutdown();
    }

    #[test]
    fn shutdown_drains_inflight() {
        let coord = Coordinator::start(CoordinatorConfig::default(), |_| {
            Box::new(GoldenEngine::new(net(), 8))
        });
        let rxs: Vec<_> = (0..10).map(|_| coord.submit(vec![50; 16]).unwrap()).collect();
        let stats = coord.shutdown();
        assert_eq!(stats.completed, 10);
        for rx in rxs {
            assert!(rx.recv().is_ok());
        }
    }
}

//! The coordinator: bounded submission queue, batcher loop, worker pool
//! with typed per-request failures, deadlines, bounded retry, panic
//! isolation + budgeted respawn, and load shedding (README §SERVING).
//!
//! The liveness contract: every request that [`Coordinator::submit`] (or
//! a sibling) accepts terminates with exactly one [`ServeResult`] — an
//! [`InferResult`] or a typed [`ServeError`] — and is charged to exactly
//! one of the `completed` / `failed` / `shed` counters, so
//! `completed + failed + shed == submitted` once the queue drains.  The
//! chaos suite (`rust/tests/serve_faults.rs`) drives this invariant
//! through seeded fault schedules.
//!
//! Telemetry (PR7): each worker owns a lock-free [`WorkerShard`] of
//! histogram sketches + outcome counters, so delivering a result takes
//! no shared lock and latency memory is O(buckets) instead of
//! per-request; every completed request carries a [`Trace`] stage
//! breakdown, and [`Coordinator::export_into`] publishes the merged
//! telemetry into a `telemetry::Registry`.
//!
//! Span tracing (PR8): [`Coordinator::start_with_spans`] attaches a
//! [`SpanCollector`]; each worker then records flat spans on its own
//! track (form-batch / engine / backoff) and, at delivery, rebuilds a
//! per-request span *tree* (queue → batch → engine/backoff → deliver)
//! from the very same stamps the request's [`Trace`] is built from —
//! the two views agree by construction, and `rust/tests/spans.rs`
//! asserts it.
//!
//! Multi-model serving (PR9): the pool is started against an
//! `Arc<ModelRegistry>` and every submit names a [`ModelId`].  All
//! models drain one queue, but a formed batch is partitioned by
//! `(ModelId, deadline-class)` before it reaches an engine — requests
//! for different models never share an engine batch.  Workers may run
//! heterogeneous backends (one engine factory per slot — e.g.
//! `golden:3,chip-sim:1`), and the telemetry export gains per-model
//! latency sketches/counters, per-backend rows, and the pool-wide
//! packed-model LRU cache counters.

use crate::arch::CacheStats;
use crate::coordinator::batcher::{next_batch, partition_by_key, split_expired, Request};
use crate::coordinator::engine::InferenceEngine;
use crate::coordinator::registry::{ModelId, ModelRegistry};
use crate::telemetry::spans::{pids, SpanCollector, SpanRecorder};
use crate::telemetry::{AtomicSketch, HistogramSketch, LatencySummary, Registry, Stage, Trace};
use std::cell::RefCell;
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicI64, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Why a request was turned away without (further) inference.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// The bounded queue was full (shed at submit time by `try_submit`
    /// or `submit_timeout`; the blocking `submit` waits instead).
    QueueFull,
    /// The request's deadline expired before an engine ran it.
    Deadline,
    /// The coordinator is shut down or every worker engine is dead.
    Shutdown,
}

/// Typed per-request serving failure — every accepted request ends in an
/// [`InferResult`] or exactly one of these.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Shed without inference; the reason names the gate that fired.
    Rejected(RejectReason),
    /// Every inference attempt returned an error; `cause` is the last.
    EngineFailed { attempts: u32, cause: String },
    /// The engine panicked on the final attempt (the worker respawned
    /// its engine, or went dark once the restart budget was spent).
    WorkerPanicked,
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Rejected(RejectReason::QueueFull) => write!(f, "rejected: queue full"),
            ServeError::Rejected(RejectReason::Deadline) => {
                write!(f, "rejected: deadline expired")
            }
            ServeError::Rejected(RejectReason::Shutdown) => write!(f, "rejected: shutting down"),
            ServeError::EngineFailed { attempts, cause } => {
                write!(f, "engine failed after {attempts} attempt(s): {cause}")
            }
            ServeError::WorkerPanicked => write!(f, "engine panicked"),
        }
    }
}

impl std::error::Error for ServeError {}

/// What every result receiver yields.
pub type ServeResult = Result<InferResult, ServeError>;

/// Coordinator tuning knobs.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Bounded queue depth — blocking submissions beyond this wait
    /// (backpressure); `try_submit`/`submit_timeout` shed instead.
    pub queue_depth: usize,
    /// Maximum images per engine batch.
    pub max_batch: usize,
    /// Max time the batcher waits for a batch to fill.
    pub max_wait: Duration,
    /// Worker threads (each owns one engine instance).
    pub workers: usize,
    /// Default per-request deadline measured from submission (`None` =
    /// no deadline).  Expired requests are shed at dequeue and before
    /// each retry — never inferred.
    pub deadline: Option<Duration>,
    /// Extra inference attempts after the first failure (0 = no retry).
    /// A failed batch is split so each member retries alone — one
    /// poisoned image cannot sink its batchmates.
    pub max_retries: u32,
    /// Deterministic linear backoff: the k-th retry of a request sleeps
    /// `k * retry_backoff` first (truncated at its deadline).
    pub retry_backoff: Duration,
    /// Pool-wide respawn budget for panicked engines; once spent, a
    /// panicking worker goes dark and the pool degrades.  When every
    /// worker is dark, new submissions fail fast with
    /// `Rejected(Shutdown)` and queued ones are shed — never stranded.
    pub restart_budget: u32,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            queue_depth: 256,
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            workers: 2,
            deadline: None,
            max_retries: 2,
            retry_backoff: Duration::from_micros(500),
            restart_budget: 4,
        }
    }
}

/// One classification result.
#[derive(Debug, Clone)]
pub struct InferResult {
    pub id: u64,
    pub logits: Vec<i64>,
    pub latency: Duration,
    /// Stage breakdown of `latency` (queue / batch / engine / backoff /
    /// deliver); the stages sum to `latency` by construction.
    pub trace: Trace,
}

/// Aggregate serving statistics.
#[derive(Debug, Clone)]
pub struct ServeStats {
    /// Requests accepted into the queue (excludes submit-time rejects).
    pub submitted: u64,
    /// Requests that returned logits.
    pub completed: u64,
    /// Requests that exhausted attempts (`EngineFailed` /
    /// `WorkerPanicked`).
    pub failed: u64,
    /// Requests shed after acceptance (deadline expiry, dead pool).
    pub shed: u64,
    /// Engine attempts beyond each request's first.
    pub retries: u64,
    /// Engines rebuilt after a panic.
    pub worker_restarts: u64,
    /// Workers whose engine is currently alive.
    pub alive_workers: u64,
    pub batches: u64,
    pub mean_batch: f64,
    /// Latency percentiles from the merged histogram sketch — within
    /// `telemetry::REL_ERROR` (≤ 1.5625%) of the exact nearest-rank
    /// percentiles of the per-request latencies (O(buckets) memory; the
    /// old exact-but-unbounded latency vector is gone).
    pub latency_ms_p50: f64,
    pub latency_ms_p95: f64,
    pub latency_ms_p99: f64,
    pub latency_ms_p999: f64,
    /// Exact maximum completed-request latency (tracked outside the
    /// buckets, no sketch error).
    pub latency_ms_max: f64,
    /// Per-stage latency summaries over completed requests.
    pub stages: StageBreakdown,
    pub throughput_rps: f64,
}

/// Per-stage latency summaries of completed requests ("where did my
/// p99 go"): each field summarizes that stage's sketch.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StageBreakdown {
    pub queue: LatencySummary,
    pub batch: LatencySummary,
    pub engine: LatencySummary,
    pub backoff: LatencySummary,
    pub deliver: LatencySummary,
}

impl StageBreakdown {
    /// The summary for one stage (for iterating [`Stage::ALL`]).
    pub fn get(&self, s: Stage) -> &LatencySummary {
        match s {
            Stage::Queue => &self.queue,
            Stage::Batch => &self.batch,
            Stage::Engine => &self.engine,
            Stage::Backoff => &self.backoff,
            Stage::Deliver => &self.deliver,
        }
    }

    /// Multi-line per-stage rows for `vsa serve` / `vsa serve-bench`.
    pub fn render(&self) -> String {
        Stage::ALL
            .iter()
            .map(|&s| format!("stage {:<8} {}", s.name(), self.get(s).render()))
            .collect::<Vec<_>>()
            .join("\n")
    }
}

/// Lock-free per-worker telemetry shard: each worker records completed
/// latencies, stage times and outcome counts into its own sketches and
/// counters, so the delivery hot path takes **no shared lock**.
/// `stats()` / `export_into()` merge the shards in fixed worker order —
/// sketch merge is commutative `u64` arithmetic, so snapshots are
/// byte-deterministic at any thread count.
struct WorkerShard {
    latency: AtomicSketch,
    /// Indexed in [`Stage::ALL`] order.
    stages: [AtomicSketch; 5],
    /// Per-model latency sketch, indexed by `ModelId::index()` (PR9).
    models: Vec<AtomicSketch>,
    /// Per-model completion counter, indexed by `ModelId::index()`.
    model_completed: Vec<AtomicU64>,
    completed: AtomicU64,
    failed: AtomicU64,
    shed: AtomicU64,
    retries: AtomicU64,
    restarts: AtomicU64,
    batches: AtomicU64,
    batched_requests: AtomicU64,
    /// This worker's engine backend name (`"-"` until the engine is
    /// built; set off the hot path at engine (re)construction).
    backend: Mutex<&'static str>,
    /// Absolute packed-model cache counters mirrored from the worker's
    /// engine after each batch (stored, not added — the engine owns the
    /// running totals; see [`CacheStats`]).
    cache_lookups: AtomicU64,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    cache_evictions: AtomicU64,
    cache_packs: AtomicU64,
}

impl WorkerShard {
    fn new(n_models: usize) -> Self {
        Self {
            latency: AtomicSketch::new(),
            stages: std::array::from_fn(|_| AtomicSketch::new()),
            models: (0..n_models).map(|_| AtomicSketch::new()).collect(),
            model_completed: (0..n_models).map(|_| AtomicU64::new(0)).collect(),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            restarts: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
            backend: Mutex::new("-"),
            cache_lookups: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            cache_misses: AtomicU64::new(0),
            cache_evictions: AtomicU64::new(0),
            cache_packs: AtomicU64::new(0),
        }
    }

    /// This shard's mirrored packed-model cache counters.
    fn cache_stats(&self) -> CacheStats {
        CacheStats {
            lookups: self.cache_lookups.load(Ordering::Relaxed),
            hits: self.cache_hits.load(Ordering::Relaxed),
            misses: self.cache_misses.load(Ordering::Relaxed),
            evictions: self.cache_evictions.load(Ordering::Relaxed),
            packs: self.cache_packs.load(Ordering::Relaxed),
        }
    }
}

/// Fixed-order merge of every worker shard (an owned point-in-time
/// aggregate; the source of `stats()` and `export_into()`).
struct MergedShards {
    latency: HistogramSketch,
    stages: [HistogramSketch; 5],
    completed: u64,
    failed: u64,
    shed: u64,
    retries: u64,
    restarts: u64,
    batches: u64,
    batched_requests: u64,
}

/// Ring capacity per worker span recorder — deep enough that smoke
/// runs never drop; overflow keeps the latest and is counted.
const SPAN_RING_CAP: usize = 1 << 15;

/// Mark kinds stored in [`Pending::marks`].
const MARK_ENGINE: u8 = 0;
const MARK_BACKOFF: u8 = 1;

struct Shared {
    /// The deployed models every submit names a [`ModelId`] into.
    registry: Arc<ModelRegistry>,
    submitted: AtomicU64,
    /// Span sink when tracing is on (see [`Coordinator::start_with_spans`]).
    spans: Option<Arc<SpanCollector>>,
    /// One telemetry shard per worker, indexed by worker id.
    shards: Vec<WorkerShard>,
    /// Remaining engine respawns (pool-wide).  May briefly go negative
    /// on the losing side of a race, which simply denies that respawn.
    restart_budget: AtomicI64,
    /// Workers whose engine is currently alive.
    alive: AtomicUsize,
}

/// Per-request payload travelling through the queue.
struct Job {
    model: ModelId,
    image: Vec<u8>,
    resp: Sender<ServeResult>,
    deadline: Option<Instant>,
}

/// A request whose image has been handed (or is about to be handed) to
/// the engine; everything needed to deliver its terminal outcome, plus
/// the stage-time bookkeeping its [`Trace`] is built from.
struct Pending {
    id: u64,
    model: ModelId,
    enqueued: Instant,
    /// When a worker pulled it off the queue (ends the queue stage).
    dequeued: Instant,
    /// When its batch finished forming (ends the batch stage).
    batch_ready: Instant,
    /// Wall nanoseconds spent inside engine attempts (summed over
    /// retries; the shared batch attempt charges each member in full —
    /// that is the wall time the member spent waiting on the engine).
    engine_ns: u64,
    /// Measured retry-backoff sleep nanoseconds.
    backoff_ns: u64,
    /// Span marks on the collector clock — `(kind, start_ns, dur_ns)`
    /// per engine attempt / backoff sleep, pushed only when tracing and
    /// from the *same* measurements as `engine_ns` / `backoff_ns`, so
    /// the span tree and the [`Trace`] stages agree exactly.
    marks: Vec<(u8, u64, u64)>,
    resp: Sender<ServeResult>,
    deadline: Option<Instant>,
}

fn into_pending(req: Request<Job>, batch_ready: Instant) -> (Vec<u8>, Pending) {
    let Request { id, payload, enqueued, dequeued } = req;
    let Job { model, image, resp, deadline } = payload;
    let dequeued = dequeued.unwrap_or(enqueued);
    let pending = Pending {
        id,
        model,
        enqueued,
        dequeued,
        batch_ready,
        engine_ns: 0,
        backoff_ns: 0,
        marks: Vec::new(),
        resp,
        deadline,
    };
    (image, pending)
}

/// One guarded engine call's failure mode.
#[derive(Clone)]
enum AttemptError {
    /// The engine returned `Err` (or broke the length contract); its
    /// state is intact and it can be retried as-is.
    Failed(String),
    /// The engine panicked; its state may be corrupt — the caller must
    /// respawn it before reuse.
    Panicked,
}

type EngineBox = Box<dyn InferenceEngine>;
type MakeEngine = dyn Fn(usize) -> EngineBox + Send + Sync;

/// Per-worker knobs copied out of [`CoordinatorConfig`].
#[derive(Clone, Copy)]
struct WorkerCfg {
    max_batch: usize,
    max_wait: Duration,
    max_retries: u32,
    retry_backoff: Duration,
}

/// Everything one worker thread needs: its index, knobs, the shared
/// counters, and the engine factory (for panic respawn).
struct WorkerCtx {
    w: usize,
    cfg: WorkerCfg,
    shared: Arc<Shared>,
    make_engine: Arc<MakeEngine>,
    /// This worker's span recorder when tracing is on (created at the
    /// top of [`run`](WorkerCtx::run); the `RefCell` is fine because
    /// the ctx never leaves its own thread).
    rec: RefCell<Option<SpanRecorder>>,
}

impl WorkerCtx {
    /// This worker's lock-free telemetry shard.
    fn shard(&self) -> &WorkerShard {
        &self.shared.shards[self.w]
    }

    /// Nanosecond stamp on the collector clock — `Some` iff tracing.
    fn span_now(&self) -> Option<u64> {
        self.shared.spans.as_ref().map(|s| s.now_ns())
    }

    /// Run `f` against this worker's recorder when tracing is on.
    fn with_rec(&self, f: impl FnOnce(&mut SpanRecorder)) {
        if let Some(rec) = self.rec.borrow_mut().as_mut() {
            f(rec);
        }
    }

    /// Record a flat span on this worker's own track.
    fn worker_span(&self, name: &str, start_ns: u64, dur_ns: u64, args: &[(&'static str, f64)]) {
        let (pid, tid) = (pids::SERVE_WORKERS, self.w as u64);
        self.with_rec(|rec| rec.span_at(pid, tid, name, start_ns, dur_ns, args, None));
    }

    /// The worker loop.  A worker never exits before the queue closes,
    /// even with a dead engine: a dark worker keeps pulling batches and
    /// shedding them as `Rejected(Shutdown)`, so no request is ever
    /// stranded in the queue and shutdown always drains.
    fn run(&self, rx: &Mutex<Receiver<Request<Job>>>) {
        if let Some(spans) = &self.shared.spans {
            let rec =
                spans.recorder(self.w as u32, pids::SERVE_WORKERS, self.w as u64, SPAN_RING_CAP);
            *self.rec.borrow_mut() = Some(rec);
        }
        // A panicking engine constructor counts like a panicking engine:
        // the worker starts dark instead of taking the thread down.
        let mut engine = match catch_unwind(AssertUnwindSafe(|| (self.make_engine)(self.w))) {
            Ok(e) => Some(e),
            Err(_) => {
                eprintln!("worker {}: engine constructor panicked; worker is dark", self.w);
                self.shared.alive.fetch_sub(1, Ordering::SeqCst);
                None
            }
        };
        if let Some(e) = &engine {
            *self.shard().backend.lock().unwrap() = e.name();
        }
        let max_batch = match &engine {
            Some(e) => self.cfg.max_batch.min(e.batch_size()).max(1),
            None => self.cfg.max_batch.max(1),
        };
        loop {
            // Only one worker holds the queue lock while *forming* a
            // batch; inference runs outside the lock.
            let t_form = self.span_now();
            let batch = {
                let rx = rx.lock().unwrap();
                next_batch(&rx, max_batch, self.cfg.max_wait)
            };
            let Some(batch) = batch else { break };
            let batch_ready = Instant::now();
            if let Some(start) = t_form {
                let end = self.span_now().unwrap_or(start);
                let args = [("requests", batch.len() as f64)];
                self.worker_span("form-batch", start, end.saturating_sub(start), &args);
            }
            self.shard().batches.fetch_add(1, Ordering::Relaxed);
            self.shard().batched_requests.fetch_add(batch.len() as u64, Ordering::Relaxed);

            // Deadline gate at dequeue: expired requests are shed.
            let (live, expired) = split_expired(batch, Instant::now(), |j: &Job| j.deadline);
            for req in expired {
                let (_, pending) = into_pending(req, batch_ready);
                self.respond(pending, Err(ServeError::Rejected(RejectReason::Deadline)));
            }
            if live.is_empty() {
                continue;
            }
            if engine.is_some() {
                // One queue, many models: split the formed batch by
                // `(ModelId, deadline-class)` — requests for different
                // models never share an engine batch (PR9).
                for group in partition_by_key(live, |j: &Job| (j.model, j.deadline.is_some())) {
                    if engine.is_some() {
                        self.run_batch(&mut engine, group, batch_ready);
                    } else {
                        for req in group {
                            let (_, pending) = into_pending(req, batch_ready);
                            let err = ServeError::Rejected(RejectReason::Shutdown);
                            self.respond(pending, Err(err));
                        }
                    }
                }
                self.sync_cache_counters(&engine);
            } else {
                for req in live {
                    let (_, pending) = into_pending(req, batch_ready);
                    self.respond(pending, Err(ServeError::Rejected(RejectReason::Shutdown)));
                }
            }
        }
    }

    /// Mirror the engine's packed-model cache counters into this
    /// worker's shard (absolute store — the engine owns the totals).
    fn sync_cache_counters(&self, engine: &Option<EngineBox>) {
        let Some(e) = engine else { return };
        let c = e.cache_stats();
        let shard = self.shard();
        shard.cache_lookups.store(c.lookups, Ordering::Relaxed);
        shard.cache_hits.store(c.hits, Ordering::Relaxed);
        shard.cache_misses.store(c.misses, Ordering::Relaxed);
        shard.cache_evictions.store(c.evictions, Ordering::Relaxed);
        shard.cache_packs.store(c.packs, Ordering::Relaxed);
    }

    /// Run one formed single-model batch: a shared first attempt, then —
    /// on failure — the batch is split and each member retried alone, so
    /// one poisoned image cannot sink its batchmates.  The caller has
    /// already partitioned by model, so `batch` is homogeneous.
    fn run_batch(&self, engine: &mut Option<EngineBox>, batch: Vec<Request<Job>>, ready: Instant) {
        let model = batch[0].payload.model;
        debug_assert!(batch.iter().all(|r| r.payload.model == model), "mixed-model batch");
        let mut images = Vec::with_capacity(batch.len());
        let mut members = Vec::with_capacity(batch.len());
        for req in batch {
            // Move the payload out — the engine reads slices, no clones.
            let (image, pending) = into_pending(req, ready);
            images.push(image);
            members.push(pending);
        }
        let eng = engine.as_mut().expect("run_batch requires a live engine");
        let span_start = self.span_now();
        let t0 = Instant::now();
        let outcome = Self::attempt(eng, model, &images);
        let spent_ns = t0.elapsed().as_nanos().min(u64::MAX as u128) as u64;
        for pending in members.iter_mut() {
            pending.engine_ns = pending.engine_ns.saturating_add(spent_ns);
            if let Some(start) = span_start {
                pending.marks.push((MARK_ENGINE, start, spent_ns));
            }
        }
        if let Some(start) = span_start {
            self.worker_span("engine", start, spent_ns, &[("images", images.len() as f64)]);
        }
        match outcome {
            Ok(results) => {
                for (pending, logits) in members.into_iter().zip(results) {
                    self.complete(pending, logits);
                }
            }
            Err(first) => {
                if matches!(first, AttemptError::Panicked) {
                    self.respawn(engine);
                }
                for (pending, image) in members.into_iter().zip(images) {
                    self.finish_one(engine, pending, image, first.clone());
                }
            }
        }
    }

    /// Drive one request to its terminal outcome after a failed shared
    /// attempt: bounded retries with deterministic linear backoff, the
    /// deadline re-checked before every attempt.
    fn finish_one(
        &self,
        engine: &mut Option<EngineBox>,
        mut pending: Pending,
        image: Vec<u8>,
        mut last: AttemptError,
    ) {
        // The shared batch attempt was this request's attempt #1.
        let mut attempts: u32 = 1;
        while attempts <= self.cfg.max_retries {
            if engine.is_none() {
                break; // dark worker: report the last failure below
            }
            // Deterministic linear backoff before retry k (1-based),
            // truncated at the deadline so a shed stays a shed.
            let mut pause = self.cfg.retry_backoff * attempts;
            if let Some(d) = pending.deadline {
                pause = pause.min(d.saturating_duration_since(Instant::now()));
            }
            if pause > Duration::ZERO {
                let span_start = self.span_now();
                let t0 = Instant::now();
                std::thread::sleep(pause);
                let slept = t0.elapsed().as_nanos().min(u64::MAX as u128) as u64;
                pending.backoff_ns = pending.backoff_ns.saturating_add(slept);
                if let Some(start) = span_start {
                    pending.marks.push((MARK_BACKOFF, start, slept));
                    self.worker_span("backoff", start, slept, &[]);
                }
            }
            if let Some(d) = pending.deadline {
                if Instant::now() >= d {
                    self.respond(pending, Err(ServeError::Rejected(RejectReason::Deadline)));
                    return;
                }
            }
            attempts += 1;
            self.shard().retries.fetch_add(1, Ordering::Relaxed);
            let eng = engine.as_mut().expect("checked above");
            let span_start = self.span_now();
            let t0 = Instant::now();
            let outcome = Self::attempt(eng, pending.model, std::slice::from_ref(&image));
            let spent = t0.elapsed().as_nanos().min(u64::MAX as u128) as u64;
            pending.engine_ns = pending.engine_ns.saturating_add(spent);
            if let Some(start) = span_start {
                pending.marks.push((MARK_ENGINE, start, spent));
                self.worker_span("engine", start, spent, &[("images", 1.0)]);
            }
            match outcome {
                Ok(mut out) => {
                    let logits = out.pop().expect("length checked by attempt()");
                    self.complete(pending, logits);
                    return;
                }
                Err(e) => {
                    if matches!(e, AttemptError::Panicked) {
                        self.respawn(engine);
                    }
                    last = e;
                }
            }
        }
        let err = match last {
            AttemptError::Failed(cause) => ServeError::EngineFailed { attempts, cause },
            AttemptError::Panicked => ServeError::WorkerPanicked,
        };
        self.respond(pending, Err(err));
    }

    /// One guarded engine call.  A panic is caught and reported as
    /// [`AttemptError::Panicked`]; the caller must respawn the engine.
    fn attempt(
        engine: &mut EngineBox,
        model: ModelId,
        images: &[Vec<u8>],
    ) -> Result<Vec<Vec<i64>>, AttemptError> {
        match catch_unwind(AssertUnwindSafe(|| engine.infer(model, images))) {
            Ok(Ok(out)) if out.len() == images.len() => Ok(out),
            Ok(Ok(out)) => Err(AttemptError::Failed(format!(
                "engine returned {} results for {} images",
                out.len(),
                images.len()
            ))),
            Ok(Err(e)) => Err(AttemptError::Failed(format!("{e:#}"))),
            Err(_) => Err(AttemptError::Panicked),
        }
    }

    /// Replace a panicked engine, spending one unit of the pool-wide
    /// restart budget.  Leaves the slot empty (the worker goes dark)
    /// once the budget is spent or the constructor itself panics.
    fn respawn(&self, engine: &mut Option<EngineBox>) {
        *engine = None;
        if self.shared.restart_budget.fetch_sub(1, Ordering::SeqCst) <= 0 {
            eprintln!("worker {}: engine panicked, restart budget spent; worker is dark", self.w);
            self.shared.alive.fetch_sub(1, Ordering::SeqCst);
            return;
        }
        match catch_unwind(AssertUnwindSafe(|| (self.make_engine)(self.w))) {
            Ok(e) => {
                *self.shard().backend.lock().unwrap() = e.name();
                *engine = Some(e);
                self.shard().restarts.fetch_add(1, Ordering::Relaxed);
            }
            Err(_) => {
                eprintln!("worker {}: engine constructor panicked on respawn; dark", self.w);
                self.shared.alive.fetch_sub(1, Ordering::SeqCst);
            }
        }
    }

    /// Deliver a successful result, building its stage trace from the
    /// accumulated stamps (the deliver stage absorbs the residual, so
    /// the stages sum back to the end-to-end latency exactly).
    fn complete(&self, pending: Pending, logits: Vec<i64>) {
        let latency = pending.enqueued.elapsed();
        let trace = Trace::from_parts(
            latency,
            pending.dequeued.saturating_duration_since(pending.enqueued),
            pending.batch_ready.saturating_duration_since(pending.dequeued),
            Duration::from_nanos(pending.engine_ns),
            Duration::from_nanos(pending.backoff_ns),
        );
        let res = InferResult { id: pending.id, logits, latency, trace };
        self.respond(pending, Ok(res));
    }

    /// Deliver the terminal outcome for one request and charge the
    /// matching counter — the single place the completed/failed/shed
    /// accounting lives, so the counters balance by construction.
    /// Everything recorded here lands in this worker's own shard:
    /// the delivery hot path takes **no shared lock**.
    fn respond(&self, pending: Pending, outcome: ServeResult) {
        let shard = self.shard();
        match &outcome {
            Ok(res) => {
                shard.latency.record(res.latency);
                for (i, &s) in Stage::ALL.iter().enumerate() {
                    shard.stages[i].record(res.trace.stage(s));
                }
                shard.completed.fetch_add(1, Ordering::Relaxed);
                let m = pending.model.index();
                shard.models[m].record(res.latency);
                shard.model_completed[m].fetch_add(1, Ordering::Relaxed);
            }
            Err(ServeError::Rejected(_)) => {
                shard.shed.fetch_add(1, Ordering::Relaxed);
            }
            Err(_) => {
                shard.failed.fetch_add(1, Ordering::Relaxed);
            }
        }
        if self.shared.spans.is_some() {
            self.record_request_tree(&pending, &outcome);
        }
        // The submitter may have given up on its receiver; that is fine.
        let _ = pending.resp.send(outcome);
    }

    /// Rebuild this request's span tree on the per-request track
    /// (pid [`pids::SERVE_REQUESTS`], tid = request id) from the same
    /// stamps and attempt measurements its [`Trace`] is built from.
    /// Stage spans are named exactly by [`Stage::name`], the deliver
    /// span absorbs the residual, and every child is clamped inside
    /// the `request` parent so the nesting invariant holds.
    fn record_request_tree(&self, pending: &Pending, outcome: &ServeResult) {
        let Some(spans) = &self.shared.spans else { return };
        let (pid, tid) = (pids::SERVE_REQUESTS, pending.id);
        let enq = spans.ns_of(pending.enqueued);
        let deq = spans.ns_of(pending.dequeued).max(enq);
        let ready = spans.ns_of(pending.batch_ready).max(deq);
        let end = spans.now_ns().max(ready);
        let note = match outcome {
            Ok(_) => "ok",
            Err(ServeError::Rejected(_)) => "shed",
            Err(_) => "failed",
        };
        self.with_rec(|rec| {
            rec.span_at(pid, tid, "request", enq, end - enq, &[], Some(note));
            rec.span_at(pid, tid, Stage::Queue.name(), enq, deq - enq, &[], None);
            rec.span_at(pid, tid, Stage::Batch.name(), deq, ready - deq, &[], None);
            let mut cursor = ready;
            for &(kind, start, dur) in &pending.marks {
                let name = match kind {
                    MARK_BACKOFF => Stage::Backoff.name(),
                    _ => Stage::Engine.name(),
                };
                let start = start.clamp(ready, end);
                let dur = dur.min(end - start);
                rec.span_at(pid, tid, name, start, dur, &[], None);
                cursor = cursor.max(start + dur);
            }
            rec.span_at(pid, tid, Stage::Deliver.name(), cursor, end - cursor, &[], None);
        });
    }
}

/// How a submission behaves when the bounded queue is full.
enum SubmitMode {
    /// Block until a slot frees (backpressure).
    Block,
    /// Fail immediately with `Rejected(QueueFull)`.
    Fail,
    /// Wait up to the limit, then fail with `Rejected(QueueFull)`.
    Wait(Duration),
}

/// Poll interval for `submit_timeout` (std's `SyncSender` has no native
/// timed send).
const SUBMIT_POLL: Duration = Duration::from_micros(200);

/// A running coordinator instance.
pub struct Coordinator {
    tx: Option<SyncSender<Request<Job>>>,
    workers: Vec<JoinHandle<()>>,
    shared: Arc<Shared>,
    next_id: AtomicU64,
    started: Instant,
    deadline: Option<Duration>,
}

impl Coordinator {
    /// Start the worker pool against a model registry.  `make_engine`
    /// builds one engine per worker slot and runs *inside* that worker's
    /// thread (engines need not be `Send`); it is also re-invoked to
    /// respawn an engine after a caught panic.  Heterogeneous pools hand
    /// a factory that dispatches on the worker index (see
    /// [`parse_pool`](crate::coordinator::engine::parse_pool)).
    pub fn start(
        cfg: CoordinatorConfig,
        registry: Arc<ModelRegistry>,
        make_engine: impl Fn(usize) -> Box<dyn InferenceEngine> + Send + Sync + 'static,
    ) -> Self {
        Self::start_with_spans(cfg, registry, None, make_engine)
    }

    /// [`start`](Coordinator::start) with span tracing attached: each
    /// worker records onto `spans` (worker tracks + per-request trees;
    /// see the module docs).  Worker recorders flush when their thread
    /// joins, so take [`SpanCollector::sheet`] after
    /// [`shutdown`](Coordinator::shutdown) for a complete export.
    pub fn start_with_spans(
        cfg: CoordinatorConfig,
        registry: Arc<ModelRegistry>,
        spans: Option<Arc<SpanCollector>>,
        make_engine: impl Fn(usize) -> Box<dyn InferenceEngine> + Send + Sync + 'static,
    ) -> Self {
        assert!(!registry.is_empty(), "coordinator needs at least one deployed model");
        if let Some(sp) = &spans {
            sp.name_process(pids::SERVE_WORKERS, "serve workers");
            sp.name_process(pids::SERVE_REQUESTS, "serve requests");
            for w in 0..cfg.workers {
                sp.name_track(pids::SERVE_WORKERS, w as u64, &format!("worker-{w}"));
            }
        }
        let (tx, rx) = sync_channel::<Request<Job>>(cfg.queue_depth);
        let rx = Arc::new(Mutex::new(rx));
        let make_engine: Arc<MakeEngine> = Arc::new(make_engine);
        let n_models = registry.len();
        let shared = Arc::new(Shared {
            registry,
            submitted: AtomicU64::new(0),
            spans,
            shards: (0..cfg.workers).map(|_| WorkerShard::new(n_models)).collect(),
            restart_budget: AtomicI64::new(cfg.restart_budget as i64),
            alive: AtomicUsize::new(cfg.workers),
        });

        let wcfg = WorkerCfg {
            max_batch: cfg.max_batch,
            max_wait: cfg.max_wait,
            max_retries: cfg.max_retries,
            retry_backoff: cfg.retry_backoff,
        };
        let mut workers = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let ctx = WorkerCtx {
                w,
                cfg: wcfg,
                shared: Arc::clone(&shared),
                make_engine: Arc::clone(&make_engine),
                rec: RefCell::new(None),
            };
            let rx = Arc::clone(&rx);
            workers.push(std::thread::spawn(move || ctx.run(&rx)));
        }

        Self {
            tx: Some(tx),
            workers,
            shared,
            next_id: AtomicU64::new(0),
            started: Instant::now(),
            deadline: cfg.deadline,
        }
    }

    fn enqueue(
        &self,
        model: ModelId,
        image: Vec<u8>,
        deadline: Option<Duration>,
        mode: SubmitMode,
    ) -> Result<Receiver<ServeResult>, ServeError> {
        assert!(
            model.index() < self.shared.registry.len(),
            "{model} is not from this coordinator's registry ({} models)",
            self.shared.registry.len()
        );
        if self.shared.alive.load(Ordering::SeqCst) == 0 {
            return Err(ServeError::Rejected(RejectReason::Shutdown));
        }
        let (rtx, rrx) = std::sync::mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let job = Job { model, image, resp: rtx, deadline: deadline.map(|d| Instant::now() + d) };
        let req = Request { id, payload: job, enqueued: Instant::now(), dequeued: None };
        let Some(tx) = self.tx.as_ref() else {
            // `drain()` already closed the queue.
            return Err(ServeError::Rejected(RejectReason::Shutdown));
        };
        match mode {
            SubmitMode::Block => tx
                .send(req)
                .map_err(|_| ServeError::Rejected(RejectReason::Shutdown))?,
            SubmitMode::Fail => match tx.try_send(req) {
                Ok(()) => {}
                Err(TrySendError::Full(_)) => {
                    return Err(ServeError::Rejected(RejectReason::QueueFull));
                }
                Err(TrySendError::Disconnected(_)) => {
                    return Err(ServeError::Rejected(RejectReason::Shutdown));
                }
            },
            SubmitMode::Wait(limit) => {
                let give_up = Instant::now() + limit;
                let mut req = req;
                loop {
                    match tx.try_send(req) {
                        Ok(()) => break,
                        Err(TrySendError::Full(r)) => {
                            if Instant::now() >= give_up {
                                return Err(ServeError::Rejected(RejectReason::QueueFull));
                            }
                            req = r;
                            std::thread::sleep(SUBMIT_POLL);
                        }
                        Err(TrySendError::Disconnected(_)) => {
                            return Err(ServeError::Rejected(RejectReason::Shutdown));
                        }
                    }
                }
            }
        }
        self.shared.submitted.fetch_add(1, Ordering::Relaxed);
        Ok(rrx)
    }

    /// Submit one image for `model`; blocks when the queue is full
    /// (backpressure).  Returns the receiver for the typed outcome.
    /// Fails fast with `Rejected(Shutdown)` when every worker engine is
    /// dead.  Panics if `model` is not from this coordinator's registry.
    pub fn submit(
        &self,
        model: ModelId,
        image: Vec<u8>,
    ) -> Result<Receiver<ServeResult>, ServeError> {
        self.enqueue(model, image, self.deadline, SubmitMode::Block)
    }

    /// Submit without blocking: a full queue sheds the request with
    /// `Rejected(QueueFull)` instead of applying backpressure.
    pub fn try_submit(
        &self,
        model: ModelId,
        image: Vec<u8>,
    ) -> Result<Receiver<ServeResult>, ServeError> {
        self.enqueue(model, image, self.deadline, SubmitMode::Fail)
    }

    /// Submit, waiting at most `wait` for a queue slot before shedding
    /// with `Rejected(QueueFull)` — the bounded-patience middle ground.
    pub fn submit_timeout(
        &self,
        model: ModelId,
        image: Vec<u8>,
        wait: Duration,
    ) -> Result<Receiver<ServeResult>, ServeError> {
        self.enqueue(model, image, self.deadline, SubmitMode::Wait(wait))
    }

    /// Blocking submit with an explicit per-request deadline overriding
    /// the configured default (`None` = no deadline for this request).
    pub fn submit_with_deadline(
        &self,
        model: ModelId,
        image: Vec<u8>,
        deadline: Option<Duration>,
    ) -> Result<Receiver<ServeResult>, ServeError> {
        self.enqueue(model, image, deadline, SubmitMode::Block)
    }

    /// Convenience: submit and wait for the typed outcome.
    pub fn infer_blocking(&self, model: ModelId, image: Vec<u8>) -> ServeResult {
        let rx = self.submit(model, image)?;
        // A dropped sender means a worker died outside the engine guard;
        // surface it as a panic-shaped failure rather than hanging.
        rx.recv().unwrap_or(Err(ServeError::WorkerPanicked))
    }

    /// The registry this pool serves from.
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.shared.registry
    }

    /// Pool-wide packed-model cache counters: the sum of every worker
    /// engine's [`CacheStats`], as last mirrored after a batch.
    pub fn cache_totals(&self) -> CacheStats {
        let mut total = CacheStats::default();
        for shard in &self.shared.shards {
            total.merge(&shard.cache_stats());
        }
        total
    }

    /// Close the queue and join the workers, leaving the coordinator
    /// readable: after `drain` returns, `stats()`, `export_into()` and
    /// `cache_totals()` are exact (the cache counters are mirrored from
    /// the engines once per batch, so mid-run reads lag by at most one
    /// batch).  Dark workers drain too (shedding), so this never
    /// deadlocks.  Idempotent.
    pub fn drain(&mut self) {
        drop(self.tx.take()); // close the queue; workers exit after drain
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    /// Drain the queue, join the workers and return the final stats.
    pub fn shutdown(mut self) -> ServeStats {
        self.drain();
        self.stats()
    }

    /// Merge every worker shard in fixed worker order.  Sketch merging
    /// is commutative/associative `u64` arithmetic, so the aggregate is
    /// byte-deterministic at any thread count once the pool is
    /// quiescent (and merely point-in-time mid-run).
    fn merged(&self) -> MergedShards {
        let mut m = MergedShards {
            latency: HistogramSketch::new(),
            stages: std::array::from_fn(|_| HistogramSketch::new()),
            completed: 0,
            failed: 0,
            shed: 0,
            retries: 0,
            restarts: 0,
            batches: 0,
            batched_requests: 0,
        };
        for shard in &self.shared.shards {
            m.latency.merge(&shard.latency.snapshot());
            for (dst, src) in m.stages.iter_mut().zip(&shard.stages) {
                dst.merge(&src.snapshot());
            }
            m.completed += shard.completed.load(Ordering::Relaxed);
            m.failed += shard.failed.load(Ordering::Relaxed);
            m.shed += shard.shed.load(Ordering::Relaxed);
            m.retries += shard.retries.load(Ordering::Relaxed);
            m.restarts += shard.restarts.load(Ordering::Relaxed);
            m.batches += shard.batches.load(Ordering::Relaxed);
            m.batched_requests += shard.batched_requests.load(Ordering::Relaxed);
        }
        m
    }

    /// Current aggregate stats.
    pub fn stats(&self) -> ServeStats {
        let m = self.merged();
        ServeStats {
            submitted: self.shared.submitted.load(Ordering::Relaxed),
            completed: m.completed,
            failed: m.failed,
            shed: m.shed,
            retries: m.retries,
            worker_restarts: m.restarts,
            alive_workers: self.shared.alive.load(Ordering::SeqCst) as u64,
            batches: m.batches,
            mean_batch: if m.batches > 0 {
                m.batched_requests as f64 / m.batches as f64
            } else {
                0.0
            },
            latency_ms_p50: m.latency.quantile_ms(0.50),
            latency_ms_p95: m.latency.quantile_ms(0.95),
            latency_ms_p99: m.latency.quantile_ms(0.99),
            latency_ms_p999: m.latency.quantile_ms(0.999),
            latency_ms_max: m.latency.max_ms(),
            stages: StageBreakdown {
                queue: m.stages[0].summary(),
                batch: m.stages[1].summary(),
                engine: m.stages[2].summary(),
                backoff: m.stages[3].summary(),
                deliver: m.stages[4].summary(),
            },
            throughput_rps: m.completed as f64 / self.started.elapsed().as_secs_f64(),
        }
    }

    /// Export the pool's telemetry into a [`Registry`] under `prefix`:
    /// pool-level counters/gauges, per-worker outcome counters, the
    /// merged latency sketch, one sketch per pipeline stage, and (PR9)
    /// per-model latency sketches + completion counters
    /// (`{prefix}.model.{name}.*`), per-backend rows
    /// (`{prefix}.backend.{name}.*`), and the pool-wide packed-model
    /// LRU cache counters (`{prefix}.model_cache.*`).
    /// Sketch export is merge-additive — callers publishing periodic
    /// snapshots should export into a fresh registry per tick.
    pub fn export_into(&self, reg: &Registry, prefix: &str) {
        let m = self.merged();
        let submitted = self.shared.submitted.load(Ordering::Relaxed);
        reg.set_counter(&format!("{prefix}.submitted"), submitted);
        reg.set_counter(&format!("{prefix}.completed"), m.completed);
        reg.set_counter(&format!("{prefix}.failed"), m.failed);
        reg.set_counter(&format!("{prefix}.shed"), m.shed);
        reg.set_counter(&format!("{prefix}.retries"), m.retries);
        reg.set_counter(&format!("{prefix}.worker_restarts"), m.restarts);
        reg.set_counter(&format!("{prefix}.batches"), m.batches);
        reg.set_counter(&format!("{prefix}.batched_requests"), m.batched_requests);
        reg.set_counter(
            &format!("{prefix}.alive_workers"),
            self.shared.alive.load(Ordering::SeqCst) as u64,
        );
        reg.set_gauge(
            &format!("{prefix}.throughput_rps"),
            m.completed as f64 / self.started.elapsed().as_secs_f64(),
        );
        reg.merge_sketch(&format!("{prefix}.latency"), &m.latency);
        for (i, &s) in Stage::ALL.iter().enumerate() {
            reg.merge_sketch(&format!("{prefix}.stage.{}", s.name()), &m.stages[i]);
        }
        for (w, shard) in self.shared.shards.iter().enumerate() {
            for (name, v) in [
                ("completed", shard.completed.load(Ordering::Relaxed)),
                ("failed", shard.failed.load(Ordering::Relaxed)),
                ("shed", shard.shed.load(Ordering::Relaxed)),
                ("retries", shard.retries.load(Ordering::Relaxed)),
                ("restarts", shard.restarts.load(Ordering::Relaxed)),
                ("batches", shard.batches.load(Ordering::Relaxed)),
            ] {
                reg.set_counter(&format!("{prefix}.worker.{w}.{name}"), v);
            }
        }
        // Per-model rows: merged latency sketch + completion counter
        // keyed by the registry name (fixed iteration order — the
        // registry is append-only, so snapshots stay deterministic).
        for id in self.shared.registry.ids() {
            let (mi, name) = (id.index(), self.shared.registry.name(id));
            let mut sketch = HistogramSketch::new();
            let mut done = 0u64;
            for shard in &self.shared.shards {
                sketch.merge(&shard.models[mi].snapshot());
                done += shard.model_completed[mi].load(Ordering::Relaxed);
            }
            reg.set_counter(&format!("{prefix}.model.{name}.completed"), done);
            reg.merge_sketch(&format!("{prefix}.model.{name}.latency"), &sketch);
        }
        // Per-backend rows: shards grouped by the engine name each
        // worker reported at engine (re)construction ("-" = dark or
        // never built, skipped).
        let mut backends: Vec<(&'static str, HistogramSketch, u64, u64)> = Vec::new();
        for shard in &self.shared.shards {
            let b = *shard.backend.lock().unwrap();
            if b == "-" {
                continue;
            }
            let slot = match backends.iter().position(|(name, ..)| *name == b) {
                Some(i) => &mut backends[i],
                None => {
                    backends.push((b, HistogramSketch::new(), 0, 0));
                    backends.last_mut().expect("just pushed")
                }
            };
            slot.1.merge(&shard.latency.snapshot());
            slot.2 += shard.completed.load(Ordering::Relaxed);
            slot.3 += 1;
        }
        for (b, sketch, done, workers) in &backends {
            reg.set_counter(&format!("{prefix}.backend.{b}.completed"), *done);
            reg.set_counter(&format!("{prefix}.backend.{b}.workers"), *workers);
            reg.merge_sketch(&format!("{prefix}.backend.{b}.latency"), sketch);
        }
        self.cache_totals().export_into(reg, &format!("{prefix}.model_cache"));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::GoldenEngine;
    use crate::snn::params::{DeployedModel, Kind, Layer};
    use crate::snn::Network;

    /// A 2-step 1x4x4 model whose readout weight is a knob — different
    /// weights give bit-distinguishable logits (theta 1 guarantees the
    /// positive encoder channel spikes on any nonzero pixel).
    fn toy(name: &str, readout_w: i8) -> DeployedModel {
        DeployedModel {
            name: name.into(),
            num_steps: 2,
            in_channels: 1,
            in_size: 4,
            layers: vec![
                Layer::Conv {
                    kind: Kind::EncConv,
                    c_out: 2,
                    c_in: 1,
                    k: 1,
                    w: vec![1, -1],
                    bias: vec![0, 0],
                    theta: vec![1, 1],
                },
                Layer::Readout { n_out: 10, n_in: 32, w: vec![readout_w; 320] },
            ],
        }
    }

    fn model() -> DeployedModel {
        toy("s", 1)
    }

    /// A one-model registry + the coordinator serving it (golden pool).
    fn start_single(cfg: CoordinatorConfig, batch: usize) -> (Coordinator, ModelId) {
        let (reg, m) = ModelRegistry::single(model());
        let regc = Arc::clone(&reg);
        let coord = Coordinator::start(cfg, reg, move |_| {
            Box::new(GoldenEngine::new(Arc::clone(&regc), batch))
        });
        (coord, m)
    }

    #[test]
    fn serves_requests_and_batches() {
        let (coord, m) = start_single(
            CoordinatorConfig {
                workers: 2,
                max_batch: 4,
                max_wait: Duration::from_millis(5),
                queue_depth: 64,
                ..CoordinatorConfig::default()
            },
            4,
        );
        let receivers: Vec<_> =
            (0..20).map(|i| coord.submit(m, vec![(i * 12) as u8; 16]).unwrap()).collect();
        for rx in receivers {
            let res = rx.recv().unwrap().unwrap();
            assert_eq!(res.logits.len(), 10);
        }
        let stats = coord.shutdown();
        assert_eq!(stats.submitted, 20);
        assert_eq!(stats.completed, 20);
        assert_eq!(stats.failed, 0);
        assert_eq!(stats.shed, 0);
        assert!(stats.batches <= 20);
        assert!(stats.mean_batch >= 1.0);
    }

    #[test]
    fn results_match_direct_inference() {
        let (coord, m) = start_single(CoordinatorConfig::default(), 8);
        let image = vec![123u8; 16];
        let served = coord.infer_blocking(m, image.clone()).unwrap();
        assert_eq!(served.logits, Network::new(model()).infer_u8(&image));
        assert_eq!(served.trace.total(), served.latency, "stages sum to the latency exactly");
        assert!(served.trace.engine > Duration::ZERO, "engine stage measured");
        coord.shutdown();
    }

    #[test]
    fn shutdown_drains_inflight() {
        let (coord, m) = start_single(CoordinatorConfig::default(), 8);
        let rxs: Vec<_> = (0..10).map(|_| coord.submit(m, vec![50; 16]).unwrap()).collect();
        let stats = coord.shutdown();
        assert_eq!(stats.completed, 10);
        for rx in rxs {
            assert!(rx.recv().unwrap().is_ok());
        }
    }

    /// The PR9 core contract in miniature: two models with
    /// bit-distinguishable logits share one queue and one golden pool —
    /// every interleaved request gets exactly its own model's logits,
    /// and the export carries per-model, per-backend and model-cache
    /// rows whose counters balance once the pool is drained.
    #[test]
    fn multi_model_traffic_never_mixes_and_exports_per_model_rows() {
        let mut reg = ModelRegistry::new();
        let a = reg.register("alpha", toy("alpha", 1)).unwrap();
        let b = reg.register("beta", toy("beta", 3)).unwrap();
        let reg = Arc::new(reg);
        let regc = Arc::clone(&reg);
        let mut coord = Coordinator::start(
            CoordinatorConfig {
                workers: 2,
                max_batch: 4,
                max_wait: Duration::from_millis(2),
                ..CoordinatorConfig::default()
            },
            Arc::clone(&reg),
            move |_| Box::new(GoldenEngine::new(Arc::clone(&regc), 4)),
        );
        let image = vec![200u8; 16];
        let want_a = Network::new(toy("alpha", 1)).infer_u8(&image);
        let want_b = Network::new(toy("beta", 3)).infer_u8(&image);
        assert_ne!(want_a, want_b, "the two models must be distinguishable");
        let rxs: Vec<_> = (0..16)
            .map(|i| {
                let m = if i % 2 == 0 { a } else { b };
                (m, coord.submit(m, image.clone()).unwrap())
            })
            .collect();
        for (m, rx) in rxs {
            let res = rx.recv().unwrap().unwrap();
            let want = if m == a { &want_a } else { &want_b };
            assert_eq!(&res.logits, want, "request for {m} got another model's logits");
        }
        coord.drain();
        let cache = coord.cache_totals();
        assert_eq!(cache.hits + cache.misses, cache.lookups, "cache counters balance");
        assert_eq!(cache.packs, cache.misses, "every miss packs exactly once");
        assert!(cache.lookups >= 2, "both models looked up");
        let treg = Registry::new();
        coord.export_into(&treg, "serve");
        let snap = treg.snapshot();
        assert_eq!(snap.counters["serve.model.alpha.completed"], 8);
        assert_eq!(snap.counters["serve.model.beta.completed"], 8);
        assert!(snap.sketches.contains_key("serve.model.alpha.latency"));
        assert!(snap.sketches.contains_key("serve.model.beta.latency"));
        assert_eq!(snap.counters["serve.backend.golden.workers"], 2);
        assert_eq!(snap.counters["serve.backend.golden.completed"], 16);
        assert_eq!(snap.counters["serve.model_cache.lookups"], cache.lookups);
        let stats = coord.stats();
        assert_eq!(stats.completed, 16);
    }

    #[test]
    fn serve_error_messages_name_the_cause() {
        let msgs = [
            ServeError::Rejected(RejectReason::QueueFull).to_string(),
            ServeError::Rejected(RejectReason::Deadline).to_string(),
            ServeError::Rejected(RejectReason::Shutdown).to_string(),
            ServeError::EngineFailed { attempts: 3, cause: "boom".into() }.to_string(),
            ServeError::WorkerPanicked.to_string(),
        ];
        assert!(msgs[0].contains("queue full"));
        assert!(msgs[1].contains("deadline"));
        assert!(msgs[2].contains("shutting down"));
        assert!(msgs[3].contains("3 attempt(s)") && msgs[3].contains("boom"));
        assert!(msgs[4].contains("panicked"));
    }

    /// With a collector attached, every completed request leaves a
    /// properly nested span tree on its own track, and the worker
    /// tracks carry the flat form-batch/engine spans.
    #[test]
    fn span_trees_cover_every_completed_request() {
        let spans = SpanCollector::new();
        let (reg, m) = ModelRegistry::single(model());
        let regc = Arc::clone(&reg);
        let coord = Coordinator::start_with_spans(
            CoordinatorConfig {
                workers: 2,
                max_batch: 4,
                max_wait: Duration::from_millis(2),
                ..CoordinatorConfig::default()
            },
            reg,
            Some(Arc::clone(&spans)),
            move |_| Box::new(GoldenEngine::new(Arc::clone(&regc), 4)),
        );
        let rxs: Vec<_> = (0..12).map(|i| coord.submit(m, vec![i as u8; 16]).unwrap()).collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let stats = coord.shutdown();
        assert_eq!(stats.completed, 12);

        let sheet = spans.sheet();
        sheet.check_nesting().expect("request trees nest");
        let count = |pid: u32, name: &str| {
            sheet.records().iter().filter(|r| r.pid == pid && r.name == name).count()
        };
        assert_eq!(count(pids::SERVE_REQUESTS, "request"), 12);
        assert_eq!(count(pids::SERVE_REQUESTS, "queue"), 12);
        assert_eq!(count(pids::SERVE_REQUESTS, "deliver"), 12);
        assert!(count(pids::SERVE_REQUESTS, "engine") >= 12, "≥1 engine attempt per request");
        assert!(count(pids::SERVE_WORKERS, "engine") >= 1);
        assert!(count(pids::SERVE_WORKERS, "form-batch") >= 1);
        assert_eq!(sheet.dropped, 0);
    }

    /// Without a collector the hot path records nothing (marks stay
    /// empty, no recorder exists) and behaviour is unchanged.
    #[test]
    fn tracing_off_leaves_no_sheet() {
        let spans = SpanCollector::new();
        let (coord, m) = start_single(CoordinatorConfig::default(), 8);
        coord.infer_blocking(m, vec![9u8; 16]).unwrap();
        coord.shutdown();
        assert!(spans.sheet().is_empty());
    }

    /// An engine `Err` must reach every member of the failed batch as a
    /// typed `EngineFailed` carrying the cause — never a dropped sender.
    #[test]
    fn engine_error_reaches_every_submitter_typed() {
        struct FailEngine;
        impl InferenceEngine for FailEngine {
            fn batch_size(&self) -> usize {
                4
            }
            fn infer(&mut self, _m: ModelId, _images: &[Vec<u8>]) -> anyhow::Result<Vec<Vec<i64>>> {
                anyhow::bail!("injector offline")
            }
            fn name(&self) -> &'static str {
                "fail"
            }
        }
        let (reg, m) = ModelRegistry::single(model());
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 1,
                max_batch: 4,
                max_retries: 1,
                retry_backoff: Duration::ZERO,
                ..CoordinatorConfig::default()
            },
            reg,
            |_| Box::new(FailEngine),
        );
        let rxs: Vec<_> = (0..4).map(|_| coord.submit(m, vec![1u8; 16]).unwrap()).collect();
        for rx in rxs {
            match rx.recv().unwrap() {
                Err(ServeError::EngineFailed { attempts, cause }) => {
                    assert_eq!(attempts, 2, "1 batch attempt + 1 retry");
                    assert!(cause.contains("injector offline"), "cause survives: {cause}");
                }
                other => panic!("expected EngineFailed, got {other:?}"),
            }
        }
        let stats = coord.shutdown();
        assert_eq!(stats.failed, 4);
        assert_eq!(stats.completed + stats.failed + stats.shed, stats.submitted);
    }
}

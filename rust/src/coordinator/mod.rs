//! Serving coordinator: request queue → dynamic batcher → worker pool.
//!
//! The VSA chip is a batch-1 accelerator per image, but the *system*
//! around it (this crate's L3 role) serves concurrent classification
//! requests: a bounded submission queue applies backpressure, a batcher
//! groups requests up to the compiled batch size with a small timeout, and
//! worker threads run the batches on an [`engine::InferenceEngine`]
//! (golden model, chip simulator, or the PJRT executable — python is never
//! involved).  Built on std threads + channels (tokio is unavailable in
//! this offline environment).

pub mod batcher;
pub mod engine;
pub mod server;

pub use engine::{ChipEngine, EngineKind, GoldenEngine, InferenceEngine, PjrtEngine};
pub use server::{Coordinator, CoordinatorConfig, ServeStats};

//! Serving coordinator: request queue → dynamic batcher → worker pool.
//!
//! The VSA chip is a batch-1 accelerator per image, but the *system*
//! around it (this crate's L3 role) serves concurrent classification
//! requests: a bounded submission queue applies backpressure, a batcher
//! groups requests up to the compiled batch size with a small timeout, and
//! worker threads run the batches on an [`engine::InferenceEngine`]
//! (golden model or chip simulator).  Built on std threads + channels
//! (tokio is unavailable in this offline environment).
//!
//! Since PR6 the coordinator is fault-tolerant end to end: every request
//! terminates with an [`InferResult`] or a typed [`ServeError`]
//! (deadlines, queue-full shedding, bounded batch-splitting retries,
//! panic isolation with budgeted respawn — see README §SERVING), and
//! [`fault::FaultEngine`] + [`loadgen`] exist to prove it under seeded
//! fault schedules.
//!
//! Since PR7 it is observable end to end: lock-free per-worker latency
//! sketch shards, per-request stage traces, and a registry exporter
//! (README §OBSERVABILITY, `crate::telemetry`).
//!
//! Since PR9 the model is a per-request property: a [`registry::ModelRegistry`]
//! holds the deployed models, every submit names a [`registry::ModelId`],
//! batches are partitioned so models never mix, engines keep bounded LRU
//! caches of packed models, and heterogeneous pools (`golden:3,chip-sim:1`)
//! drain one queue with per-model/per-backend telemetry.

pub mod batcher;
pub mod engine;
pub mod fault;
pub mod loadgen;
pub mod registry;
pub mod server;

pub use engine::{parse_pool, ChipEngine, EngineKind, GoldenEngine, InferenceEngine};
pub use fault::{FaultEngine, FaultProfile, FaultStats};
pub use loadgen::{run_load, run_load_single, LoadReport, LoadSpec, ModelTraffic};
pub use registry::{ModelId, ModelRegistry};
pub use server::{
    Coordinator, CoordinatorConfig, InferResult, RejectReason, ServeError, ServeResult, ServeStats,
    StageBreakdown,
};

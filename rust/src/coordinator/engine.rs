//! Inference engines the workers can run batches on.

use std::ops::Range;
use std::sync::Arc;

use crate::arch::{CacheStats, Chip, SimMode, DEFAULT_MODEL_CACHE};
use crate::config::HwConfig;
use crate::coordinator::registry::{ModelId, ModelRegistry};
use crate::snn::{Network, Scratch};
use crate::train::par;
use anyhow::{bail, Result};

/// A batch-capable, multi-model inference backend.
///
/// Not required to be `Send`: the coordinator constructs one engine *per
/// worker thread* via the factory passed to `Coordinator::start`.
///
/// Model contract (PR9): every call names the [`ModelId`] the batch
/// belongs to — the batcher guarantees a batch never mixes models, and
/// the engine resolves the id against its shared [`ModelRegistry`]
/// (packing resolved models into a bounded LRU cache so steady-state
/// multi-model traffic re-packs nothing).
///
/// Failure contract (PR6): `infer` may return `Err` for transient
/// failures — the coordinator retries the batch split into singles and
/// surfaces `ServeError::EngineFailed` with the cause once attempts are
/// exhausted.  A *panic* in `infer` is caught by the worker
/// (`catch_unwind`); the engine is assumed corrupted and is rebuilt via
/// the factory, charged against the pool's restart budget.
/// `fault::FaultEngine` wraps any engine with seeded injections of both,
/// plus latency spikes.
pub trait InferenceEngine {
    /// Preferred batch size (the batcher targets this).
    fn batch_size(&self) -> usize;
    /// Classify a batch of raw u8 CHW images for `model` into integer
    /// logits.  Images whose pixel count does not match the model's
    /// geometry are a typed `Err` (→ `EngineFailed`), never a panic.
    fn infer(&mut self, model: ModelId, images: &[Vec<u8>]) -> Result<Vec<Vec<i64>>>;
    /// Human-readable backend name for logs/metrics.
    fn name(&self) -> &'static str;
    /// Packed-model cache counters, if this backend multiplexes models.
    fn cache_stats(&self) -> CacheStats {
        CacheStats::default()
    }
}

/// Engine selector used by the CLI and pool specs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    Golden,
    ChipSim,
}

impl EngineKind {
    /// Parse a backend name (`golden`, `chip-sim`/`chip`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "golden" => Ok(Self::Golden),
            "chip-sim" | "chip" => Ok(Self::ChipSim),
            other => bail!("unknown engine {other:?} (expected golden|chip-sim)"),
        }
    }

    /// Canonical backend name (matches `InferenceEngine::name`).
    pub fn name(self) -> &'static str {
        match self {
            Self::Golden => "golden",
            Self::ChipSim => "chip-sim",
        }
    }
}

/// Parse a heterogeneous pool spec like `golden:3,chip-sim:1` into one
/// [`EngineKind`] per worker slot (a bare name counts as `:1`).
pub fn parse_pool(spec: &str) -> Result<Vec<EngineKind>> {
    let mut out = Vec::new();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (kind, count) = match part.split_once(':') {
            Some((k, c)) => {
                let n: usize = c
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad worker count {c:?} in {part:?}"))?;
                (EngineKind::parse(k.trim())?, n)
            }
            None => (EngineKind::parse(part)?, 1),
        };
        out.extend(std::iter::repeat(kind).take(count));
    }
    if out.is_empty() {
        bail!("empty pool spec {spec:?}");
    }
    Ok(out)
}

/// The geometry gate every engine runs before touching a batch: a pixel
/// count that doesn't match the model is a typed error (→
/// `ServeError::EngineFailed`), never a downstream panic or garbage
/// logits.
fn check_geometry(registry: &ModelRegistry, model: ModelId, images: &[Vec<u8>]) -> Result<()> {
    let m = registry.get(model);
    let want = m.in_channels * m.in_size * m.in_size;
    for (i, img) in images.iter().enumerate() {
        if img.len() != want {
            bail!(
                "image {i}: {} pixels, but model {:?} expects {} ({}x{}x{})",
                img.len(),
                registry.name(model),
                want,
                m.in_channels,
                m.in_size,
                m.in_size
            );
        }
    }
    Ok(())
}

/// Golden functional model engine (pure rust, any batch size).
///
/// Owns a [`Scratch`] arena reused across every request the worker
/// serves plus a bounded LRU of packed [`Network`]s (capacity-K, keyed
/// by [`ModelId`]), so steady-state multi-model inference allocates and
/// packs nothing — the worker thread's analogue of the chip's fixed SRAM
/// working set.
pub struct GoldenEngine {
    registry: Arc<ModelRegistry>,
    batch: usize,
    scratch: Scratch,
    /// Batch-parallelism width (1 = serial on the caller thread).
    threads: usize,
    /// One persistent arena per worker for threaded batches — PR1's
    /// one-`Scratch`-per-worker ownership model, pooled so steady-state
    /// threaded inference allocates nothing.
    scratch_pool: Vec<Scratch>,
    /// Packed networks, most-recently-used first.
    cache: Vec<(ModelId, Network)>,
    capacity: usize,
    stats: CacheStats,
}

impl GoldenEngine {
    /// Engine over `registry`; `batch` is the batcher's grouping target.
    pub fn new(registry: Arc<ModelRegistry>, batch: usize) -> Self {
        Self::with_cache_capacity(registry, batch, DEFAULT_MODEL_CACHE)
    }

    /// Engine keeping up to `capacity` models packed (clamped to ≥ 1).
    pub fn with_cache_capacity(
        registry: Arc<ModelRegistry>,
        batch: usize,
        capacity: usize,
    ) -> Self {
        Self {
            registry,
            batch,
            scratch: Scratch::new(),
            threads: 1,
            scratch_pool: Vec::new(),
            cache: Vec::new(),
            capacity: capacity.max(1),
            stats: CacheStats::default(),
        }
    }

    /// Run batches across `threads` worker threads (clamped to ≥ 1).
    ///
    /// Determinism: batch items are independent, the shard partition is
    /// fixed by [`par::SHARDS`] (never by the thread count), each worker
    /// owns its own [`Scratch`], and every result lands in a pre-split
    /// output slot — so any thread count returns byte-identical logits.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Move `model`'s packed network to the cache front, packing it on a
    /// miss (evicting the LRU entry when full).
    fn prepare(&mut self, model: ModelId) {
        self.stats.lookups += 1;
        if let Some(pos) = self.cache.iter().position(|(id, _)| *id == model) {
            self.stats.hits += 1;
            let hit = self.cache.remove(pos);
            self.cache.insert(0, hit);
        } else {
            self.stats.misses += 1;
            self.stats.packs += 1;
            let net = Network::new(self.registry.get(model).as_ref().clone());
            if self.cache.len() >= self.capacity {
                self.cache.pop();
                self.stats.evictions += 1;
            }
            self.cache.insert(0, (model, net));
        }
    }
}

impl InferenceEngine for GoldenEngine {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn infer(&mut self, model: ModelId, images: &[Vec<u8>]) -> Result<Vec<Vec<i64>>> {
        check_geometry(&self.registry, model, images)?;
        self.prepare(model);
        let net = &self.cache[0].1;
        let threads = self.threads.min(images.len()).max(1);
        if threads == 1 {
            let scratch = &mut self.scratch;
            return Ok(images.iter().map(|img| net.infer_u8_with(img, scratch)).collect());
        }
        // Multi-core batch, PR4's deterministic-sharding playbook: the
        // batch is cut into a fixed partition (par::SHARDS, independent
        // of the thread count), shard s is striped to worker s % threads,
        // each worker reuses its own pooled Scratch, and every logit
        // vector is written into a pre-split disjoint slot of `out` — so
        // the result bytes cannot depend on `threads` or the schedule.
        while self.scratch_pool.len() < threads {
            self.scratch_pool.push(Scratch::new());
        }
        let ranges = par::shard_ranges(images.len(), par::SHARDS);
        let mut out: Vec<Vec<i64>> = Vec::new();
        out.resize_with(images.len(), Vec::new);
        let mut slots: Vec<(Range<usize>, &mut [Vec<i64>])> =
            Vec::with_capacity(ranges.len());
        {
            let mut rest: &mut [Vec<i64>] = &mut out;
            for r in &ranges {
                let (head, tail) = rest.split_at_mut(r.len());
                slots.push((r.clone(), head));
                rest = tail;
            }
        }
        let mut buckets: Vec<Vec<(Range<usize>, &mut [Vec<i64>])>> =
            (0..threads).map(|_| Vec::new()).collect();
        for (s, slot) in slots.into_iter().enumerate() {
            buckets[s % threads].push(slot);
        }
        std::thread::scope(|scope| {
            for (bucket, scratch) in
                buckets.into_iter().zip(self.scratch_pool.iter_mut())
            {
                scope.spawn(move || {
                    for (r, slot) in bucket {
                        for (img, dst) in images[r].iter().zip(slot) {
                            *dst = net.infer_u8_with(img, scratch);
                        }
                    }
                });
            }
        });
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "golden"
    }

    fn cache_stats(&self) -> CacheStats {
        self.stats
    }
}

/// Cycle-accurate chip simulator engine (reports hardware latency too).
///
/// The worker's [`Chip`] carries the bounded LRU packed-model cache +
/// scratch arena (PR5 generalized in PR9), so steady-state multi-model
/// batches re-pack nothing while resident — asserted by
/// `chip_engine_packs_once_per_model` below.
pub struct ChipEngine {
    chip: Chip,
    registry: Arc<ModelRegistry>,
    batch: usize,
    /// Simulated chip latency accumulated across batches (us).
    pub simulated_us: f64,
}

impl ChipEngine {
    /// Fast-mode chip engine on the given hardware config.
    pub fn new(hw: HwConfig, registry: Arc<ModelRegistry>, batch: usize) -> Self {
        Self::with_mode(hw, SimMode::Fast, registry, batch)
    }

    /// Chip engine at an explicit fidelity — Exact-mode workers are
    /// viable pool members since the Exact datapath was arena-ized.
    pub fn with_mode(
        hw: HwConfig,
        mode: SimMode,
        registry: Arc<ModelRegistry>,
        batch: usize,
    ) -> Self {
        Self { chip: Chip::new(hw, mode), registry, batch, simulated_us: 0.0 }
    }

    /// Fast-mode engine keeping up to `capacity` models packed.
    pub fn with_cache_capacity(
        hw: HwConfig,
        registry: Arc<ModelRegistry>,
        batch: usize,
        capacity: usize,
    ) -> Self {
        Self {
            chip: Chip::with_cache_capacity(hw, SimMode::Fast, capacity),
            registry,
            batch,
            simulated_us: 0.0,
        }
    }
}

impl InferenceEngine for ChipEngine {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn infer(&mut self, model: ModelId, images: &[Vec<u8>]) -> Result<Vec<Vec<i64>>> {
        check_geometry(&self.registry, model, images)?;
        let m = Arc::clone(self.registry.get(model));
        let mut out = Vec::with_capacity(images.len());
        for img in images {
            let report = self.chip.run(&m, img);
            self.simulated_us += report.latency_us;
            out.push(report.logits);
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "chip-sim"
    }

    fn cache_stats(&self) -> CacheStats {
        self.chip.cache_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::params::{DeployedModel, Kind, Layer};

    fn model() -> DeployedModel {
        DeployedModel {
            name: "e".into(),
            num_steps: 2,
            in_channels: 1,
            in_size: 4,
            layers: vec![
                Layer::Conv {
                    kind: Kind::EncConv,
                    c_out: 2,
                    c_in: 1,
                    k: 1,
                    w: vec![1, -1],
                    bias: vec![0, 0],
                    theta: vec![256 * 50, 256 * 50],
                },
                Layer::Readout { n_out: 10, n_in: 32, w: vec![1; 320] },
            ],
        }
    }

    fn single() -> (Arc<ModelRegistry>, ModelId) {
        ModelRegistry::single(model())
    }

    #[test]
    fn golden_engine_batches() {
        let (reg, id) = single();
        let mut e = GoldenEngine::new(reg, 4);
        let out = e.infer(id, &[vec![100; 16], vec![255; 16]]).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].len(), 10);
    }

    /// PR10: threaded batches are byte-identical to the serial path at
    /// every thread count (fixed shard partition + per-worker Scratch),
    /// including thread counts above the batch size.
    #[test]
    fn threaded_batches_match_serial() {
        let (reg, id) = single();
        let imgs: Vec<Vec<u8>> = (0..13).map(|i| vec![(i * 19) as u8; 16]).collect();
        let mut serial = GoldenEngine::new(Arc::clone(&reg), 4);
        let want = serial.infer(id, &imgs).unwrap();
        for t in [2usize, 3, 4, 8, 32] {
            let mut e = GoldenEngine::new(Arc::clone(&reg), 4).with_threads(t);
            assert_eq!(e.infer(id, &imgs).unwrap(), want, "threads={t}");
        }
    }

    #[test]
    fn chip_engine_accumulates_latency() {
        let (reg, id) = single();
        let mut e = ChipEngine::new(HwConfig::default(), reg, 2);
        e.infer(id, &[vec![100; 16]]).unwrap();
        let after_one = e.simulated_us;
        e.infer(id, &[vec![100; 16], vec![9; 16]]).unwrap();
        assert!(e.simulated_us > after_one);
    }

    #[test]
    fn engines_agree() {
        let (reg, id) = single();
        let mut g = GoldenEngine::new(Arc::clone(&reg), 4);
        let mut c = ChipEngine::new(HwConfig::default(), reg, 4);
        let imgs = vec![vec![37; 16], vec![200; 16]];
        assert_eq!(g.infer(id, &imgs).unwrap(), c.infer(id, &imgs).unwrap());
    }

    /// Serving batches re-use the worker chip's packed model: however
    /// many images flow through, a resident model is packed exactly once.
    #[test]
    fn chip_engine_packs_once_per_model() {
        let (reg, id) = single();
        let mut e = ChipEngine::new(HwConfig::default(), reg, 4);
        let imgs: Vec<Vec<u8>> = (0..4).map(|i| vec![(i * 60) as u8; 16]).collect();
        e.infer(id, &imgs).unwrap();
        e.infer(id, &imgs).unwrap();
        assert_eq!(e.chip.pack_count(), 1);
        let s = e.cache_stats();
        assert_eq!((s.lookups, s.hits, s.misses), (8, 7, 1));
    }

    /// Regression (PR9 satellite): a pixel-count mismatch is a typed
    /// error from both engines, not a panic or garbage logits.
    #[test]
    fn geometry_mismatch_is_a_typed_error() {
        let (reg, id) = single();
        let mut g = GoldenEngine::new(Arc::clone(&reg), 4);
        let mut c = ChipEngine::new(HwConfig::default(), reg, 4);
        // model wants 1x4x4 = 16 pixels; send 15 and 17.
        for bad in [vec![0u8; 15], vec![0u8; 17]] {
            let ge = g.infer(id, &[bad.clone()]).unwrap_err();
            assert!(ge.to_string().contains("expects 16"), "golden: {ge}");
            let ce = c.infer(id, &[bad]).unwrap_err();
            assert!(ce.to_string().contains("expects 16"), "chip: {ce}");
        }
        // A good batch with one bad member fails as a unit (the
        // coordinator then splits and retries per PR6).
        let e = g.infer(id, &[vec![1; 16], vec![2; 3]]).unwrap_err();
        assert!(e.to_string().contains("image 1"), "{e}");
        // And the engines still serve well-formed traffic afterwards.
        assert_eq!(g.infer(id, &[vec![7; 16]]).unwrap().len(), 1);
    }

    /// The golden engine's LRU mirrors the chip's: A/B/A under capacity 2
    /// packs twice, capacity 1 thrashes, counters balance.
    #[test]
    fn golden_engine_lru_counters_balance() {
        use crate::testing::{models, Gen};
        let (a, img_a) = models::random_model_tiny(&mut Gen::new(11));
        let (b, img_b) = models::random_model_tiny(&mut Gen::new(22));
        let mut reg = ModelRegistry::new();
        let ia = reg.register("a", a).unwrap();
        let ib = reg.register("b", b).unwrap();
        let reg = Arc::new(reg);

        let mut two = GoldenEngine::with_cache_capacity(Arc::clone(&reg), 4, 2);
        for _ in 0..3 {
            two.infer(ia, &[img_a.clone()]).unwrap();
            two.infer(ib, &[img_b.clone()]).unwrap();
        }
        let s = two.cache_stats();
        assert_eq!((s.packs, s.evictions, s.lookups), (2, 0, 6));
        assert_eq!(s.hits + s.misses, s.lookups);
        assert_eq!(s.packs, s.misses);

        let mut one = GoldenEngine::with_cache_capacity(reg, 4, 1);
        for _ in 0..3 {
            one.infer(ia, &[img_a.clone()]).unwrap();
            one.infer(ib, &[img_b.clone()]).unwrap();
        }
        let s = one.cache_stats();
        assert_eq!((s.packs, s.evictions, s.hits), (6, 5, 0));
    }

    #[test]
    fn pool_spec_parses() {
        use EngineKind::*;
        let mixed = parse_pool("golden:3,chip-sim:1").unwrap();
        assert_eq!(mixed, vec![Golden, Golden, Golden, ChipSim]);
        assert_eq!(parse_pool("golden").unwrap(), vec![Golden]);
        assert_eq!(parse_pool("chip:2").unwrap(), vec![ChipSim, ChipSim]);
        assert!(parse_pool("pjrt:1").is_err());
        assert!(parse_pool("").is_err());
        assert!(parse_pool("golden:x").is_err());
    }
}

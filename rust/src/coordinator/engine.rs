//! Inference engines the workers can run batches on.

use crate::arch::{Chip, SimMode};
use crate::config::HwConfig;
use crate::runtime::PjrtExecutor;
use crate::snn::{Network, Scratch};
use anyhow::Result;

/// A batch-capable inference backend.
///
/// Not required to be `Send`: the coordinator constructs one engine *per
/// worker thread* (PJRT client handles are thread-local).
///
/// Failure contract (PR6): `infer` may return `Err` for transient
/// failures — the coordinator retries the batch split into singles and
/// surfaces `ServeError::EngineFailed` with the cause once attempts are
/// exhausted.  A *panic* in `infer` is caught by the worker
/// (`catch_unwind`); the engine is assumed corrupted and is rebuilt via
/// the factory passed to `Coordinator::start`, charged against the
/// pool's restart budget.  `fault::FaultEngine` wraps any engine with
/// seeded injections of both, plus latency spikes.
pub trait InferenceEngine {
    /// Preferred batch size (the batcher targets this).
    fn batch_size(&self) -> usize;
    /// Classify a batch of raw u8 CHW images into integer logits.
    fn infer(&mut self, images: &[Vec<u8>]) -> Result<Vec<Vec<i64>>>;
    /// Human-readable backend name for logs/metrics.
    fn name(&self) -> &'static str;
}

/// Engine selector used by the CLI.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    Golden,
    ChipSim,
    Pjrt,
}

/// Golden functional model engine (pure rust, any batch size).
///
/// Owns a [`Scratch`] arena reused across every request the worker
/// serves, so steady-state inference allocates nothing — the worker
/// thread's analogue of the chip's fixed SRAM working set.
pub struct GoldenEngine {
    net: Network,
    batch: usize,
    scratch: Scratch,
}

impl GoldenEngine {
    /// Wrap a loaded network; `batch` is the batcher's grouping target.
    pub fn new(net: Network, batch: usize) -> Self {
        Self { net, batch, scratch: Scratch::new() }
    }
}

impl InferenceEngine for GoldenEngine {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn infer(&mut self, images: &[Vec<u8>]) -> Result<Vec<Vec<i64>>> {
        Ok(images
            .iter()
            .map(|img| self.net.infer_u8_with(img, &mut self.scratch))
            .collect())
    }

    fn name(&self) -> &'static str {
        "golden"
    }
}

/// Cycle-accurate chip simulator engine (reports hardware latency too).
///
/// The worker's [`Chip`] caches its packed model + scratch arena across
/// requests (PR5), so steady-state batches re-pack nothing — asserted by
/// `chip_engine_packs_once_per_model` below.
pub struct ChipEngine {
    chip: Chip,
    net: Network,
    batch: usize,
    /// Simulated chip latency accumulated across batches (us).
    pub simulated_us: f64,
}

impl ChipEngine {
    /// Fast-mode chip engine on the given hardware config.
    pub fn new(hw: HwConfig, net: Network, batch: usize) -> Self {
        Self { chip: Chip::new(hw, SimMode::Fast), net, batch, simulated_us: 0.0 }
    }
}

impl InferenceEngine for ChipEngine {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn infer(&mut self, images: &[Vec<u8>]) -> Result<Vec<Vec<i64>>> {
        let mut out = Vec::with_capacity(images.len());
        for img in images {
            let report = self.chip.run(&self.net.model, img);
            self.simulated_us += report.latency_us;
            out.push(report.logits);
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "chip-sim"
    }
}

/// PJRT engine: runs the AOT-compiled JAX/Pallas module.  Batches smaller
/// than the compiled size are padded with zero images and the padding
/// results dropped.
pub struct PjrtEngine {
    exe: PjrtExecutor,
}

impl PjrtEngine {
    /// Wrap a compiled executable.
    pub fn new(exe: PjrtExecutor) -> Self {
        Self { exe }
    }
}

impl InferenceEngine for PjrtEngine {
    fn batch_size(&self) -> usize {
        self.exe.batch
    }

    fn infer(&mut self, images: &[Vec<u8>]) -> Result<Vec<Vec<i64>>> {
        let pixels = self.exe.channels * self.exe.size * self.exe.size;
        let n = images.len();
        anyhow::ensure!(n <= self.exe.batch, "batch overflow");
        let mut padded: Vec<Vec<u8>> = images.to_vec();
        padded.resize(self.exe.batch, vec![0u8; pixels]);
        let mut logits = self.exe.infer(&padded)?;
        logits.truncate(n);
        Ok(logits)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::params::{DeployedModel, Kind, Layer};

    fn net() -> Network {
        Network::new(DeployedModel {
            name: "e".into(),
            num_steps: 2,
            in_channels: 1,
            in_size: 4,
            layers: vec![
                Layer::Conv {
                    kind: Kind::EncConv,
                    c_out: 2,
                    c_in: 1,
                    k: 1,
                    w: vec![1, -1],
                    bias: vec![0, 0],
                    theta: vec![256 * 50, 256 * 50],
                },
                Layer::Readout { n_out: 10, n_in: 32, w: vec![1; 320] },
            ],
        })
    }

    #[test]
    fn golden_engine_batches() {
        let mut e = GoldenEngine::new(net(), 4);
        let out = e.infer(&[vec![100; 16], vec![255; 16]]).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].len(), 10);
    }

    #[test]
    fn chip_engine_accumulates_latency() {
        let mut e = ChipEngine::new(HwConfig::default(), net(), 2);
        e.infer(&[vec![100; 16]]).unwrap();
        let after_one = e.simulated_us;
        e.infer(&[vec![100; 16], vec![9; 16]]).unwrap();
        assert!(e.simulated_us > after_one);
    }

    #[test]
    fn engines_agree() {
        let mut g = GoldenEngine::new(net(), 4);
        let mut c = ChipEngine::new(HwConfig::default(), net(), 4);
        let imgs = vec![vec![37; 16], vec![200; 16]];
        assert_eq!(g.infer(&imgs).unwrap(), c.infer(&imgs).unwrap());
    }

    /// Serving batches re-use the worker chip's packed model: however
    /// many images flow through, the model is packed exactly once.
    #[test]
    fn chip_engine_packs_once_per_model() {
        let mut e = ChipEngine::new(HwConfig::default(), net(), 4);
        let imgs: Vec<Vec<u8>> = (0..4).map(|i| vec![(i * 60) as u8; 16]).collect();
        e.infer(&imgs).unwrap();
        e.infer(&imgs).unwrap();
        assert_eq!(e.chip.pack_count(), 1);
    }
}

//! Dynamic batcher: groups queued requests up to the engine batch size,
//! waiting at most `max_wait` for stragglers (the classic
//! latency/throughput knob of serving systems).

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// A request travelling through the coordinator.
#[derive(Debug)]
pub struct Request<T> {
    pub id: u64,
    pub payload: T,
    pub enqueued: Instant,
    /// When a worker pulled this request off the queue — stamped by
    /// [`next_batch`], `None` until then.  Feeds the stage trace's
    /// queue-wait / batch-formation split (`telemetry::Trace`).
    pub dequeued: Option<Instant>,
}

/// Pull up to `max_batch` requests: blocks for the first one, then drains
/// greedily, waiting up to `max_wait` total for the batch to fill.  Each
/// request's `dequeued` stamp is set as it is received.  Returns `None`
/// when the channel is closed and drained.
pub fn next_batch<T>(
    rx: &Receiver<Request<T>>,
    max_batch: usize,
    max_wait: Duration,
) -> Option<Vec<Request<T>>> {
    debug_assert!(max_batch > 0);
    let mut first = rx.recv().ok()?;
    first.dequeued = Some(Instant::now());
    let deadline = Instant::now() + max_wait;
    let mut batch = vec![first];
    while batch.len() < max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(mut req) => {
                req.dequeued = Some(Instant::now());
                batch.push(req);
            }
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

/// Partition a formed batch into `(live, expired)` by a per-payload
/// deadline, preserving arrival order within each half.  Requests whose
/// payload carries no deadline are always live.  The coordinator calls
/// this at dequeue so expired requests are shed, never inferred.
pub fn split_expired<T>(
    batch: Vec<Request<T>>,
    now: Instant,
    deadline_of: impl Fn(&T) -> Option<Instant>,
) -> (Vec<Request<T>>, Vec<Request<T>>) {
    let mut live = Vec::with_capacity(batch.len());
    let mut expired = Vec::new();
    for req in batch {
        match deadline_of(&req.payload) {
            Some(d) if d <= now => expired.push(req),
            _ => live.push(req),
        }
    }
    (live, expired)
}

/// Partition a formed batch by a per-payload batch key, preserving both
/// the arrival order of the groups (keyed by first appearance) and the
/// arrival order within each group.  PR9: the coordinator keys on
/// `(ModelId, deadline-class)` so requests for different models — or
/// deadline'd vs. best-effort traffic — never share an engine batch, even
/// though they drain one queue.
pub fn partition_by_key<T, K: PartialEq>(
    batch: Vec<Request<T>>,
    key_of: impl Fn(&T) -> K,
) -> Vec<Vec<Request<T>>> {
    let mut keys: Vec<K> = Vec::new();
    let mut groups: Vec<Vec<Request<T>>> = Vec::new();
    for req in batch {
        let key = key_of(&req.payload);
        match keys.iter().position(|k| *k == key) {
            Some(i) => groups[i].push(req),
            None => {
                keys.push(key);
                groups.push(vec![req]);
            }
        }
    }
    groups
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::time::Duration;

    fn req(id: u64) -> Request<u64> {
        Request { id, payload: id, enqueued: Instant::now(), dequeued: None }
    }

    #[test]
    fn fills_up_to_max_batch() {
        let (tx, rx) = channel();
        for i in 0..5 {
            tx.send(req(i)).unwrap();
        }
        let batch = next_batch(&rx, 3, Duration::from_millis(10)).unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(batch[0].id, 0);
        assert!(
            batch.iter().all(|r| r.dequeued.is_some_and(|d| d >= r.enqueued)),
            "next_batch stamps dequeued on every request"
        );
        let batch2 = next_batch(&rx, 3, Duration::from_millis(10)).unwrap();
        assert_eq!(batch2.len(), 2);
    }

    #[test]
    fn timeout_returns_partial_batch() {
        let (tx, rx) = channel();
        tx.send(req(7)).unwrap();
        let t0 = Instant::now();
        let batch = next_batch(&rx, 8, Duration::from_millis(30)).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn zero_max_wait_returns_first_without_spinning() {
        // max_wait == 0 degenerates to "serve whatever arrived first,
        // alone": the deadline is already past when the drain loop is
        // reached, so the call must return immediately after the
        // blocking recv — no busy-wait, no timeout sleep.
        let (tx, rx) = channel();
        for i in 0..3 {
            tx.send(req(i)).unwrap();
        }
        let t0 = Instant::now();
        let batch = next_batch(&rx, 8, Duration::ZERO).unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].id, 0);
        assert!(t0.elapsed() < Duration::from_millis(50), "zero-wait batch must not block");
        // the rest are still queued, one per call
        assert_eq!(next_batch(&rx, 8, Duration::ZERO).unwrap()[0].id, 1);
        assert_eq!(next_batch(&rx, 8, Duration::ZERO).unwrap()[0].id, 2);
    }

    #[test]
    fn closed_channel_returns_none() {
        let (tx, rx) = channel::<Request<u64>>();
        drop(tx);
        assert!(next_batch(&rx, 4, Duration::from_millis(1)).is_none());
    }

    #[test]
    fn closed_after_partial_drain() {
        let (tx, rx) = channel();
        tx.send(req(1)).unwrap();
        tx.send(req(2)).unwrap();
        drop(tx);
        let batch = next_batch(&rx, 8, Duration::from_millis(50)).unwrap();
        assert_eq!(batch.len(), 2);
        assert!(next_batch(&rx, 8, Duration::from_millis(1)).is_none());
    }

    /// Payload for the split tests: the deadline itself.
    fn dreq(id: u64, deadline: Option<Instant>) -> Request<Option<Instant>> {
        Request { id, payload: deadline, enqueued: Instant::now(), dequeued: None }
    }

    #[test]
    fn split_expired_partitions_and_keeps_order() {
        let now = Instant::now();
        let past = now - Duration::from_millis(5);
        let future = now + Duration::from_secs(5);
        let batch = vec![
            dreq(0, Some(past)),
            dreq(1, Some(future)),
            dreq(2, None),
            dreq(3, Some(past)),
            dreq(4, Some(now)), // exactly-at-deadline counts as expired
        ];
        let (live, expired) = split_expired(batch, now, |d| *d);
        assert_eq!(live.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(expired.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 3, 4]);
    }

    #[test]
    fn split_expired_no_deadlines_all_live() {
        let batch = vec![dreq(0, None), dreq(1, None)];
        let (live, expired) = split_expired(batch, Instant::now(), |d| *d);
        assert_eq!(live.len(), 2);
        assert!(expired.is_empty());
    }

    /// Payload for the partition tests: the batch key itself.
    fn kreq(id: u64, key: u32) -> Request<u32> {
        Request { id, payload: key, enqueued: Instant::now(), dequeued: None }
    }

    #[test]
    fn partition_by_key_groups_and_keeps_order() {
        let batch =
            vec![kreq(0, 7), kreq(1, 9), kreq(2, 7), kreq(3, 8), kreq(4, 9), kreq(5, 7)];
        let groups = partition_by_key(batch, |k| *k);
        let ids: Vec<Vec<u64>> =
            groups.iter().map(|g| g.iter().map(|r| r.id).collect()).collect();
        // groups ordered by first appearance, members in arrival order
        assert_eq!(ids, vec![vec![0, 2, 5], vec![1, 4], vec![3]]);
        assert!(groups.iter().all(|g| g.iter().all(|r| r.payload == g[0].payload)));
    }

    #[test]
    fn partition_by_key_single_key_is_one_group() {
        let batch = vec![kreq(0, 1), kreq(1, 1), kreq(2, 1)];
        let groups = partition_by_key(batch, |k| *k);
        assert_eq!(groups.len(), 1);
        assert_eq!(groups[0].len(), 3);
    }
}

//! Model registry: named [`DeployedModel`]s behind stable [`ModelId`]s.
//!
//! PR9 makes the model a *per-request* property instead of a per-process
//! constant: the coordinator is started with an `Arc<ModelRegistry>`,
//! every submit names a [`ModelId`], and the engines resolve the id to a
//! shared [`DeployedModel`] on demand (packing it into their bounded LRU
//! caches — see [`crate::arch::Chip`] and
//! [`crate::coordinator::GoldenEngine`]).  The registry is immutable
//! after startup, so workers share it without locks.

use std::fmt;
use std::sync::Arc;

use crate::snn::params::DeployedModel;
use anyhow::{anyhow, bail, Result};

/// Stable per-registry model handle.  Ids are dense indices assigned in
/// registration order, so they double as array indices for per-model
/// telemetry slots (`ModelId::index`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ModelId(pub u32);

impl ModelId {
    /// Dense index into per-model slot arrays.
    #[inline]
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

impl fmt::Display for ModelId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "m{}", self.0)
    }
}

/// Immutable set of deployed models shared across the worker pool.
#[derive(Default)]
pub struct ModelRegistry {
    models: Vec<Arc<DeployedModel>>,
    names: Vec<String>,
}

impl ModelRegistry {
    /// Empty registry; add models with [`register`](Self::register) /
    /// [`load_file`](Self::load_file), then wrap in an `Arc`.
    pub fn new() -> Self {
        Self::default()
    }

    /// Convenience for the single-model case: a one-entry registry (named
    /// after the model) already wrapped in an `Arc`, plus its id.
    pub fn single(model: DeployedModel) -> (Arc<Self>, ModelId) {
        let mut reg = Self::new();
        let name = model.name.clone();
        let id = reg.register(&name, model).expect("fresh registry");
        (Arc::new(reg), id)
    }

    /// Register a model under `name`.  Names must be unique.
    pub fn register(&mut self, name: &str, model: DeployedModel) -> Result<ModelId> {
        if name.is_empty() {
            bail!("model name must be non-empty");
        }
        if self.names.iter().any(|n| n == name) {
            bail!("duplicate model name {name:?}");
        }
        let id = ModelId(self.models.len() as u32);
        self.models.push(Arc::new(model));
        self.names.push(name.to_string());
        Ok(id)
    }

    /// Load a `.vsaw` artifact from `path` and register it under `name`.
    pub fn load_file(&mut self, name: &str, path: &str) -> Result<ModelId> {
        let model =
            DeployedModel::from_file(path).map_err(|e| anyhow!("loading {path}: {e}"))?;
        self.register(name, model)
    }

    /// Resolve an id to its model.  Panics on a foreign id — ids are only
    /// minted by this registry, so that is a caller bug, not a request
    /// error.
    pub fn get(&self, id: ModelId) -> &Arc<DeployedModel> {
        &self.models[id.index()]
    }

    /// Look a model up by registration name.
    pub fn by_name(&self, name: &str) -> Option<ModelId> {
        self.names.iter().position(|n| n == name).map(|i| ModelId(i as u32))
    }

    /// The registration name of `id`.
    pub fn name(&self, id: ModelId) -> &str {
        &self.names[id.index()]
    }

    /// Expected input size of `id` in pixels (`C*H*W`) — the request
    /// geometry every engine validates before running a batch.
    pub fn pixels(&self, id: ModelId) -> usize {
        let m = self.get(id);
        m.in_channels * m.in_size * m.in_size
    }

    /// Number of registered models.
    pub fn len(&self) -> usize {
        self.models.len()
    }

    /// True when no model is registered.
    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    /// All ids in registration order.
    pub fn ids(&self) -> impl Iterator<Item = ModelId> {
        (0..self.models.len() as u32).map(ModelId)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{models, Gen};

    #[test]
    fn register_lookup_roundtrip() {
        let (a, _) = models::random_model_tiny(&mut Gen::new(1));
        let (b, _) = models::random_model_tiny(&mut Gen::new(2));
        let mut reg = ModelRegistry::new();
        let ia = reg.register("a", a.clone()).unwrap();
        let ib = reg.register("b", b).unwrap();
        assert_ne!(ia, ib);
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.by_name("a"), Some(ia));
        assert_eq!(reg.by_name("b"), Some(ib));
        assert_eq!(reg.by_name("c"), None);
        assert_eq!(reg.name(ia), "a");
        assert_eq!(reg.pixels(ia), a.in_channels * a.in_size * a.in_size);
        assert_eq!(reg.ids().collect::<Vec<_>>(), vec![ia, ib]);
    }

    #[test]
    fn duplicate_names_rejected() {
        let (m, _) = models::random_model_tiny(&mut Gen::new(3));
        let mut reg = ModelRegistry::new();
        reg.register("m", m.clone()).unwrap();
        assert!(reg.register("m", m).is_err());
    }

    #[test]
    fn single_wraps_one_model() {
        let (m, _) = models::random_model_tiny(&mut Gen::new(4));
        let name = m.name.clone();
        let (reg, id) = ModelRegistry::single(m);
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.by_name(&name), Some(id));
    }

    #[test]
    fn load_file_roundtrips_vsaw_bytes() {
        let (m, _) = models::random_model_tiny(&mut Gen::new(5));
        let dir = std::env::temp_dir().join("vsa_registry_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.vsaw");
        std::fs::write(&path, m.to_bytes()).unwrap();
        let mut reg = ModelRegistry::new();
        let id = reg.load_file("disk", path.to_str().unwrap()).unwrap();
        assert_eq!(reg.get(id).num_steps, m.num_steps);
        assert_eq!(reg.pixels(id), m.in_channels * m.in_size * m.in_size);
        assert!(reg.load_file("bad", "/nonexistent/x.vsaw").is_err());
    }
}

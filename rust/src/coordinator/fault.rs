//! Deterministic fault injection for the serving stack.
//!
//! [`FaultEngine`] wraps any [`InferenceEngine`] and injects transient
//! errors, panics, and latency spikes from a seeded SplitMix64 stream,
//! so the chaos suite (`rust/tests/serve_faults.rs`) and `bench_serve`
//! can drive the coordinator's failure paths reproducibly: one base seed
//! plus [`FaultEngine::seed_for`] gives every worker its own fixed fault
//! schedule, replayed identically on every run.  Faults never alter the
//! wrapped engine's results — a request that completes under injection
//! is bit-identical to a fault-free run on the same image.

use crate::arch::CacheStats;
use crate::coordinator::engine::InferenceEngine;
use crate::coordinator::registry::ModelId;
use crate::util::rng::SplitMix64;
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Injection rates (per engine call) and the spike size.  The three
/// rates partition one uniform draw, so at most one fault fires per
/// call; their sum must stay <= 1.
#[derive(Debug, Clone, Copy)]
pub struct FaultProfile {
    /// P(call returns a transient `Err`) — exercises retry + splitting.
    pub error_rate: f64,
    /// P(call panics) — exercises `catch_unwind` + respawn.
    pub panic_rate: f64,
    /// P(call sleeps `spike` before running) — exercises deadlines.
    pub spike_rate: f64,
    /// Injected latency spike length.
    pub spike: Duration,
}

impl FaultProfile {
    /// No faults — the wrapper becomes a transparent pass-through.
    pub fn clean() -> Self {
        Self { error_rate: 0.0, panic_rate: 0.0, spike_rate: 0.0, spike: Duration::ZERO }
    }

    /// Only transient errors at `rate`.
    pub fn errors(rate: f64) -> Self {
        Self { error_rate: rate, ..Self::clean() }
    }

    /// Only panics at `rate`.
    pub fn panics(rate: f64) -> Self {
        Self { panic_rate: rate, ..Self::clean() }
    }

    /// Only latency spikes of `spike` at `rate`.
    pub fn spikes(rate: f64, spike: Duration) -> Self {
        Self { spike_rate: rate, spike, ..Self::clean() }
    }

    /// A mixed profile at total fault rate `rate`: 60% transient
    /// errors, 20% panics, 20% latency spikes of `spike`.
    pub fn mixed(rate: f64, spike: Duration) -> Self {
        Self { error_rate: 0.6 * rate, panic_rate: 0.2 * rate, spike_rate: 0.2 * rate, spike }
    }
}

/// Injection counters, shared across the pool's wrappers (and across
/// respawns) so tests can assert that faults actually fired.
#[derive(Debug, Default)]
pub struct FaultStats {
    pub calls: AtomicU64,
    pub errors: AtomicU64,
    pub panics: AtomicU64,
    pub spikes: AtomicU64,
}

impl FaultStats {
    /// Total injected faults of any kind.
    pub fn injected(&self) -> u64 {
        self.errors.load(Ordering::Relaxed)
            + self.panics.load(Ordering::Relaxed)
            + self.spikes.load(Ordering::Relaxed)
    }
}

/// An [`InferenceEngine`] wrapper that injects seeded faults.
pub struct FaultEngine {
    inner: Box<dyn InferenceEngine>,
    profile: FaultProfile,
    rng: SplitMix64,
    stats: Arc<FaultStats>,
}

impl FaultEngine {
    /// Wrap `inner` with a fresh counter set.
    pub fn new(inner: Box<dyn InferenceEngine>, profile: FaultProfile, seed: u64) -> Self {
        Self::with_stats(inner, profile, seed, Arc::new(FaultStats::default()))
    }

    /// Wrap `inner`, sharing `stats` with other wrappers (one counter
    /// set per pool; pass the same Arc from every `make_engine` call).
    pub fn with_stats(
        inner: Box<dyn InferenceEngine>,
        profile: FaultProfile,
        seed: u64,
        stats: Arc<FaultStats>,
    ) -> Self {
        Self { inner, profile, rng: SplitMix64::new(seed), stats }
    }

    /// Derive a per-worker seed from one base seed, so each worker draws
    /// an independent but reproducible fault schedule.
    pub fn seed_for(base: u64, worker: usize) -> u64 {
        base ^ (worker as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
    }

    /// The shared injection counters.
    pub fn stats(&self) -> Arc<FaultStats> {
        Arc::clone(&self.stats)
    }
}

impl InferenceEngine for FaultEngine {
    fn batch_size(&self) -> usize {
        self.inner.batch_size()
    }

    fn infer(&mut self, model: ModelId, images: &[Vec<u8>]) -> Result<Vec<Vec<i64>>> {
        self.stats.calls.fetch_add(1, Ordering::Relaxed);
        let u = self.rng.next_f64();
        let p = self.profile;
        if u < p.error_rate {
            self.stats.errors.fetch_add(1, Ordering::Relaxed);
            anyhow::bail!("injected transient fault (u = {u:.4})");
        }
        if u < p.error_rate + p.panic_rate {
            self.stats.panics.fetch_add(1, Ordering::Relaxed);
            panic!("injected engine panic (u = {u:.4})");
        }
        if u < p.error_rate + p.panic_rate + p.spike_rate {
            self.stats.spikes.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(p.spike);
        }
        self.inner.infer(model, images)
    }

    fn name(&self) -> &'static str {
        // Transparent middleware: report the wrapped backend so the
        // coordinator's per-backend telemetry rows stay meaningful.
        self.inner.name()
    }

    fn cache_stats(&self) -> CacheStats {
        self.inner.cache_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic inner engine: logits = [first pixel; 10].
    struct EchoEngine;
    impl InferenceEngine for EchoEngine {
        fn batch_size(&self) -> usize {
            4
        }
        fn infer(&mut self, _m: ModelId, images: &[Vec<u8>]) -> Result<Vec<Vec<i64>>> {
            Ok(images.iter().map(|i| vec![i[0] as i64; 10]).collect())
        }
        fn name(&self) -> &'static str {
            "echo"
        }
    }

    const M: ModelId = ModelId(0);

    /// Record which calls fail for a given (profile, seed) — panics are
    /// not triggered here, only predicted from the same rng stream.
    fn error_schedule(rate: f64, seed: u64, calls: usize) -> Vec<bool> {
        let mut eng = FaultEngine::new(Box::new(EchoEngine), FaultProfile::errors(rate), seed);
        (0..calls).map(|_| eng.infer(M, &[vec![1u8; 4]]).is_err()).collect()
    }

    #[test]
    fn fault_schedule_is_deterministic_per_seed() {
        let a = error_schedule(0.3, 42, 200);
        let b = error_schedule(0.3, 42, 200);
        let c = error_schedule(0.3, 43, 200);
        assert_eq!(a, b, "same seed, same schedule");
        assert_ne!(a, c, "different seed, different schedule");
    }

    #[test]
    fn clean_profile_is_transparent() {
        let mut fe = FaultEngine::new(Box::new(EchoEngine), FaultProfile::clean(), 7);
        let mut plain = EchoEngine;
        let imgs = vec![vec![9u8; 4], vec![200u8; 4]];
        assert_eq!(fe.infer(M, &imgs).unwrap(), plain.infer(M, &imgs).unwrap());
        assert_eq!(fe.stats().injected(), 0);
        assert_eq!(fe.stats().calls.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn error_rate_roughly_honored() {
        let fails = error_schedule(0.25, 1234, 2000).iter().filter(|&&f| f).count();
        // 2000 draws at p=0.25: expect ~500; 6-sigma band is ~±116.
        assert!((380..=620).contains(&fails), "got {fails} injected errors");
    }

    #[test]
    fn results_unchanged_on_non_faulted_calls() {
        let mut fe = FaultEngine::new(Box::new(EchoEngine), FaultProfile::errors(0.5), 99);
        let mut plain = EchoEngine;
        let imgs = vec![vec![37u8; 4]];
        for _ in 0..100 {
            if let Ok(out) = fe.infer(M, &imgs) {
                assert_eq!(out, plain.infer(M, &imgs).unwrap());
            }
        }
        assert!(fe.stats().errors.load(Ordering::Relaxed) > 10);
    }

    #[test]
    fn per_worker_seeds_differ() {
        let s: Vec<u64> = (0..4).map(|w| FaultEngine::seed_for(7, w)).collect();
        for i in 0..s.len() {
            for j in i + 1..s.len() {
                assert_ne!(s[i], s[j]);
            }
        }
    }
}

//! PE, PE array and PE block — the vectorwise datapath (paper Fig. 3).
//!
//! A PE multiplies one spike bit by one binary weight with an AND gate and
//! a sign select: with the chip's encoding (weight -1 stored as 1),
//! `product = spike ? (w_neg ? -1 : +1) : 0`, i.e. `o = {s & w, s}` in the
//! paper's notation.
//!
//! A PE array is `rows x cols` PEs (8 x 3 at the design point): `rows`
//! input spikes broadcast horizontally, `cols` weights broadcast
//! vertically, products summed along the diagonals into `rows + cols - 1`
//! partial sums — one filter-column's contribution to a column of outputs.
//!
//! A PE block holds `arrays_per_block` arrays (3): in one cycle the block
//! consumes input columns `x, x+1, x+2` against the three filter columns
//! and emits one output column of partial sums (Fig. 5(b)):
//! `O(x) = A(x) * W0 + A(x+1) * W1 + A(x+2) * W2`.

/// One processing element: AND gate + sign select.
///
/// `spike` is the input bit; `w_neg` is the stored sign bit (1 encodes
/// weight -1, 0 encodes +1).
#[inline]
pub fn pe_multiply(spike: bool, w_neg: bool) -> i32 {
    match (spike, w_neg) {
        (false, _) => 0,
        (true, false) => 1,
        (true, true) => -1,
    }
}

/// One PE array: `rows` spikes x `cols` weight bits -> `rows + cols - 1`
/// diagonal partial sums.
#[derive(Debug, Clone)]
pub struct PeArray {
    pub rows: usize,
    pub cols: usize,
}

impl PeArray {
    /// Construct with the given geometry (8 x 3 at the design point).
    pub fn new(rows: usize, cols: usize) -> Self {
        Self { rows, cols }
    }

    /// Number of diagonal outputs (10 for 8 x 3).
    #[inline]
    pub fn diag_outputs(&self) -> usize {
        self.rows + self.cols - 1
    }

    /// One cycle: multiply every PE and reduce along diagonals.
    ///
    /// `spikes[r]` is the input column vector (length `rows`);
    /// `w_neg[c]` the weight column (length `cols`, sign-bit encoding).
    /// Output index `d` accumulates products with `r + c == d` — i.e. the
    /// contribution of this filter column to output rows
    /// `y - (cols-1) .. y + rows - 1` of the current output column.
    pub fn cycle(&self, spikes: &[bool], w_neg: &[bool]) -> Vec<i32> {
        let mut out = vec![0i32; self.diag_outputs()];
        self.cycle_into(spikes, w_neg, &mut out);
        out
    }

    /// [`cycle`](Self::cycle) accumulating into a caller-owned buffer of
    /// `diag_outputs()` sums (not zeroed — the block sums its arrays in
    /// place), so a schedule walk allocates nothing per cycle.
    pub fn cycle_into(&self, spikes: &[bool], w_neg: &[bool], out: &mut [i32]) {
        debug_assert_eq!(spikes.len(), self.rows);
        debug_assert_eq!(w_neg.len(), self.cols);
        debug_assert_eq!(out.len(), self.diag_outputs());
        for (r, &s) in spikes.iter().enumerate() {
            if !s {
                continue; // AND gate: zero contribution without a spike
            }
            for (c, &wn) in w_neg.iter().enumerate() {
                out[r + c] += pe_multiply(true, wn);
            }
        }
    }
}

/// One PE block: `arrays` PE arrays sharing an output column (Fig. 5).
#[derive(Debug, Clone)]
pub struct PeBlock {
    pub array: PeArray,
    pub arrays: usize,
}

impl PeBlock {
    /// Construct (3 arrays of 8 x 3 at the design point).
    pub fn new(array: PeArray, arrays: usize) -> Self {
        Self { array, arrays }
    }

    /// One cycle of the block for one input channel.
    ///
    /// `columns[a]` is the input spike column consumed by array `a`
    /// (input columns x+a of the feature map), `w_neg[a]` the sign bits of
    /// filter column `a` (kernel column, length `array.cols` = kernel
    /// height).  Returns the summed diagonal partial sums — the block's
    /// contribution of this input channel to one output column
    /// (accumulator stage 1, Fig. 4).
    pub fn cycle(&self, columns: &[Vec<bool>], w_neg: &[Vec<bool>]) -> Vec<i32> {
        let mut acc = vec![0i32; self.array.diag_outputs()];
        self.cycle_into(columns, w_neg, &mut acc);
        acc
    }

    /// [`cycle`](Self::cycle) into a caller-owned buffer of
    /// `array.diag_outputs()` sums (zeroed here) — the allocation-free
    /// entry used by the Exact-mode schedule walk.
    pub fn cycle_into(&self, columns: &[Vec<bool>], w_neg: &[Vec<bool>], acc: &mut [i32]) {
        debug_assert_eq!(columns.len(), self.arrays);
        debug_assert_eq!(w_neg.len(), self.arrays);
        acc.fill(0);
        for a in 0..self.arrays {
            self.array.cycle_into(&columns[a], &w_neg[a], acc);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, Gen};

    #[test]
    fn pe_truth_table() {
        assert_eq!(pe_multiply(false, false), 0);
        assert_eq!(pe_multiply(false, true), 0);
        assert_eq!(pe_multiply(true, false), 1);
        assert_eq!(pe_multiply(true, true), -1);
    }

    #[test]
    fn array_diagonal_reduction() {
        // 3x2 array: spikes [1,0,1], weights [+1,-1].
        let arr = PeArray::new(3, 2);
        let out = arr.cycle(&[true, false, true], &[false, true]);
        // products: (r0,c0)=+1,(r0,c1)=-1,(r2,c0)=+1,(r2,c1)=-1
        // diagonals: d0=+1, d1=-1, d2=+1, d3=-1
        assert_eq!(out, vec![1, -1, 1, -1]);
    }

    #[test]
    fn array_full_positive() {
        let arr = PeArray::new(8, 3);
        let out = arr.cycle(&[true; 8], &[false; 3]);
        assert_eq!(out.len(), 10);
        // diagonal d counts pairs r+c==d within bounds
        assert_eq!(out[0], 1);
        assert_eq!(out[1], 2);
        assert_eq!(out[2], 3);
        assert_eq!(out[5], 3);
        assert_eq!(out[8], 2);
        assert_eq!(out[9], 1);
        assert_eq!(out.iter().sum::<i32>(), 24); // 8*3 PEs all active
    }

    /// The array equals a direct dot-product model of the same PEs.
    #[test]
    fn array_matches_naive_property() {
        check("pe array vs naive", 200, |g: &mut Gen| {
            let rows = g.usize_in(1, 12);
            let cols = g.usize_in(1, 5);
            let arr = PeArray::new(rows, cols);
            let spikes: Vec<bool> = (0..rows).map(|_| g.bool()).collect();
            let wn: Vec<bool> = (0..cols).map(|_| g.bool()).collect();
            let got = arr.cycle(&spikes, &wn);
            let mut want = vec![0i32; rows + cols - 1];
            for r in 0..rows {
                for c in 0..cols {
                    want[r + c] += pe_multiply(spikes[r], wn[c]);
                }
            }
            assert_eq!(got, want);
        });
    }

    #[test]
    fn block_sums_arrays() {
        let block = PeBlock::new(PeArray::new(2, 1), 2);
        // array 0: spikes [1,1] w=+1 -> diag [1,1]
        // array 1: spikes [1,0] w=-1 -> diag [-1,0]
        let out = block.cycle(
            &[vec![true, true], vec![true, false]],
            &[vec![false], vec![true]],
        );
        assert_eq!(out, vec![0, 1]);
    }
}

//! Chip-sim span timeline (PR8): project the cycle-stamped
//! [`crate::arch::trace::Event`] log onto the span-tracing export, and
//! derive the per-layer utilization report.
//!
//! [`chip_span_sheet`] turns one traced run into a [`SpanSheet`] with
//! three kinds of tracks under the `chip sim` process: a `layers`
//! track (one span per compute layer, annotated with cycles, PE-active
//! %, spikes, DRAM bytes and attributed energy), one track per PE
//! group showing which channel-group passes occupy the array, and a
//! `dram` track carrying every transfer as an instant plus a
//! bytes/cycle counter — so a fused layer pair shows up as a literal
//! gap in the DRAM track where the intermediate spike train would have
//! round-tripped (§IV-B made visible).
//!
//! Cycles convert to wall time at the configured clock
//! (`ns = cycle · 1000 / freq_mhz`), so the chip timeline lines up
//! with serve/train spans recorded in real time.

use std::collections::BTreeMap;

use crate::arch::chip::RunReport;
use crate::arch::schedule::{LayerPlan, PlanKind};
use crate::arch::trace::{Event, Trace};
use crate::config::HwConfig;
use crate::energy::power;
use crate::telemetry::spans::{pids, SpanKind, SpanRecord, SpanSheet};

/// Track ids under [`pids::CHIP`].
const TID_LAYERS: u64 = 0;
const TID_DRAM: u64 = 50;
const TID_PE_BASE: u64 = 100;

fn cycle_ns(cycle: u64, hw: &HwConfig) -> u64 {
    (cycle as f64 * 1000.0 / hw.freq_mhz).round() as u64
}

/// Build the chip timeline for one traced run.  `plans` is the layer
/// plan the run executed (`plan_model` / `plan_spec`) — it supplies
/// each layer's PE-group count.
pub fn chip_span_sheet(
    report: &RunReport,
    trace: &Trace,
    hw: &HwConfig,
    plans: &[LayerPlan],
) -> SpanSheet {
    let mut sheet = SpanSheet::new();
    sheet.name_process(pids::CHIP, "chip sim");
    sheet.name_track(pids::CHIP, TID_LAYERS, "layers");
    sheet.name_track(pids::CHIP, TID_DRAM, "dram");
    let max_groups = plans.iter().map(|p| p.groups(hw)).max().unwrap_or(0);
    for g in 0..max_groups {
        sheet.name_track(pids::CHIP, TID_PE_BASE + g as u64, &format!("pe-group-{g}"));
    }

    // Layer cycle windows from the trace's start/end stamps.
    let mut open = BTreeMap::new();
    let mut window = BTreeMap::new();
    for e in trace.events() {
        match e {
            Event::LayerStart { layer, cycle, .. } => {
                open.insert(*layer, *cycle);
            }
            Event::LayerEnd { layer, cycle, .. } => {
                if let Some(&s) = open.get(layer) {
                    window.insert(*layer, (s, *cycle));
                }
            }
            _ => {}
        }
    }

    for (idx, l) in report.layers.iter().enumerate() {
        let Some(&(c0, c1)) = window.get(&idx) else { continue };
        let ts = cycle_ns(c0, hw);
        let dur = cycle_ns(c1, hw).saturating_sub(ts);
        sheet.push(SpanRecord {
            kind: SpanKind::Span,
            pid: pids::CHIP,
            tid: TID_LAYERS,
            name: format!("L{idx} {:?}", l.kind),
            ts_ns: ts,
            dur_ns: dur,
            args: vec![
                ("cycles", l.cycles as f64),
                ("pe_active_pct", l.utilization * 100.0),
                ("spikes", l.spikes_emitted as f64),
                ("dram_bytes", l.dram_bytes as f64),
                ("energy_pj", power::layer_energy_pj(hw, l)),
            ],
            note: None,
        });

        // PE-group occupancy: the schedule walks a layer's input-channel
        // groups sequentially, so each group gets its slice of the
        // layer's window on its own track.
        if let Some(plan) = plans.get(idx) {
            let groups = plan.groups(hw).max(1) as u64;
            for g in 0..groups {
                let g_ts = ts + dur * g / groups;
                let g_end = ts + dur * (g + 1) / groups;
                sheet.push(SpanRecord {
                    kind: SpanKind::Span,
                    pid: pids::CHIP,
                    tid: TID_PE_BASE + g,
                    name: format!("L{idx}"),
                    ts_ns: g_ts,
                    dur_ns: g_end - g_ts,
                    args: vec![("share", 1.0 / groups as f64)],
                    note: None,
                });
            }
        }

        // Bytes/cycle level while this layer runs (the fusion gap shows
        // as a dip between the paired layers' bulk transfers).
        let bpc = if l.cycles > 0 { l.dram_bytes as f64 / l.cycles as f64 } else { 0.0 };
        sheet.push(dram_counter(ts, bpc));
    }
    sheet.push(dram_counter(cycle_ns(report.cycles, hw), 0.0));

    for e in trace.events() {
        match e {
            Event::DramTransfer { layer, bytes, write, what, cycle } => {
                sheet.push(SpanRecord {
                    kind: SpanKind::Instant,
                    pid: pids::CHIP,
                    tid: TID_DRAM,
                    name: format!("L{layer} {}", if *write { "wr" } else { "rd" }),
                    ts_ns: cycle_ns(*cycle, hw),
                    dur_ns: 0,
                    args: vec![("bytes", *bytes as f64), ("write", *write as u8 as f64)],
                    note: Some((*what).to_string()),
                });
            }
            Event::Fused { first, second, cycle } => {
                sheet.push(SpanRecord {
                    kind: SpanKind::Instant,
                    pid: pids::CHIP,
                    tid: TID_LAYERS,
                    name: format!("fuse L{first}+L{second}"),
                    ts_ns: cycle_ns(*cycle, hw),
                    dur_ns: 0,
                    args: Vec::new(),
                    note: None,
                });
            }
            _ => {}
        }
    }
    sheet
}

fn dram_counter(ts_ns: u64, value: f64) -> SpanRecord {
    SpanRecord {
        kind: SpanKind::Counter,
        pid: pids::CHIP,
        tid: TID_DRAM,
        name: "dram_bytes_per_cycle".to_string(),
        ts_ns,
        dur_ns: 0,
        args: vec![("value", value)],
        note: None,
    }
}

/// One row of the per-layer utilization report.
#[derive(Debug, Clone)]
pub struct UtilRow {
    pub layer: usize,
    pub kind: PlanKind,
    pub cycles: u64,
    /// PE-active percentage (useful ops / cycle·PE capacity).
    pub pe_active_pct: f64,
    pub dram_bytes: u64,
    pub dram_bytes_per_cycle: f64,
    /// Dynamic core energy attributed to this layer.
    pub energy_pj: f64,
    /// This layer's share of the run's dynamic energy.
    pub energy_pct: f64,
}

/// Derive the utilization report from a run's per-layer counters.
pub fn utilization_rows(report: &RunReport, hw: &HwConfig) -> Vec<UtilRow> {
    let total: f64 = report.layers.iter().map(|l| power::layer_energy_pj(hw, l)).sum();
    report
        .layers
        .iter()
        .enumerate()
        .map(|(i, l)| {
            let e = power::layer_energy_pj(hw, l);
            UtilRow {
                layer: i,
                kind: l.kind,
                cycles: l.cycles,
                pe_active_pct: l.utilization * 100.0,
                dram_bytes: l.dram_bytes,
                dram_bytes_per_cycle: if l.cycles > 0 {
                    l.dram_bytes as f64 / l.cycles as f64
                } else {
                    0.0
                },
                energy_pj: e,
                energy_pct: if total > 0.0 { e / total * 100.0 } else { 0.0 },
            }
        })
        .collect()
}

/// Render the utilization report as an aligned table
/// (README §OBSERVABILITY documents the columns).
pub fn render_utilization(report: &RunReport, hw: &HwConfig) -> String {
    let mut out = String::from(
        "layer  kind         cycles  PE-active%   DRAM bytes   B/cycle    energy pJ  energy%\n",
    );
    for r in utilization_rows(report, hw) {
        out.push_str(&format!(
            "L{:<4}  {:<8} {:>9}  {:>10.2}  {:>11}  {:>8.3}  {:>11.1}  {:>7.1}\n",
            r.layer,
            format!("{:?}", r.kind),
            r.cycles,
            r.pe_active_pct,
            r.dram_bytes,
            r.dram_bytes_per_cycle,
            r.energy_pj,
            r.energy_pct,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::chip::tests::micro_model;
    use crate::arch::schedule::plan_model;
    use crate::arch::{Chip, SimMode};
    use crate::config::json::Json;

    fn traced_micro() -> (RunReport, Trace, HwConfig, Vec<LayerPlan>) {
        let model = micro_model(4);
        let image = vec![128u8; 64];
        let hw = HwConfig::default();
        let chip = Chip::new(hw.clone(), SimMode::Fast);
        let (report, trace) = chip.run_traced(&model, &image);
        let plans = plan_model(&model);
        (report, trace, hw, plans)
    }

    #[test]
    fn sheet_has_layer_pe_and_dram_tracks() {
        let (report, trace, hw, plans) = traced_micro();
        let sheet = chip_span_sheet(&report, &trace, &hw, &plans);
        sheet.check_nesting().expect("chip timeline nests");

        let layer_spans = sheet
            .records()
            .iter()
            .filter(|r| r.kind == SpanKind::Span && r.tid == TID_LAYERS)
            .count();
        assert_eq!(layer_spans, report.layers.len());

        let pe_spans = sheet
            .records()
            .iter()
            .filter(|r| r.kind == SpanKind::Span && r.tid >= TID_PE_BASE)
            .count();
        let expect: usize = plans.iter().map(|p| p.groups(&hw)).sum();
        assert_eq!(pe_spans, expect);

        let xfers = sheet
            .records()
            .iter()
            .filter(|r| r.kind == SpanKind::Instant && r.tid == TID_DRAM)
            .count();
        assert!(xfers > 0);
        // One counter sample per layer plus the closing zero.
        let counters =
            sheet.records().iter().filter(|r| r.kind == SpanKind::Counter).count();
        assert_eq!(counters, report.layers.len() + 1);

        let doc = Json::parse(&sheet.to_chrome_json()).expect("valid chrome JSON");
        assert!(doc.get("traceEvents").and_then(Json::as_arr).unwrap().len() > 10);
    }

    /// The fused pair's intermediate spike train never appears on the
    /// DRAM track — the acceptance-criterion gap, checked on the
    /// exported timeline itself.
    #[test]
    fn fused_pair_leaves_a_dram_gap_on_the_timeline() {
        let (report, trace, hw, plans) = traced_micro();
        let sheet = chip_span_sheet(&report, &trace, &hw, &plans);
        let fused: Vec<(usize, usize)> = trace
            .events()
            .iter()
            .filter_map(|e| match e {
                Event::Fused { first, second, .. } => Some((*first, *second)),
                _ => None,
            })
            .collect();
        assert!(!fused.is_empty());
        for &(first, second) in &fused {
            for r in sheet.records() {
                if r.kind != SpanKind::Instant || r.tid != TID_DRAM {
                    continue;
                }
                let what = r.note.as_deref().unwrap_or("");
                let is_write = r.args.iter().any(|&(k, v)| k == "write" && v > 0.0);
                assert!(
                    !(r.name.starts_with(&format!("L{first} ")) && is_write
                        && what == "spikes_out"),
                    "fused L{first} wrote its spike train to DRAM"
                );
                assert!(
                    !(r.name.starts_with(&format!("L{second} ")) && !is_write
                        && what == "spikes_in"),
                    "fused L{second} read a spike train from DRAM"
                );
            }
        }
    }

    #[test]
    fn utilization_report_reconciles_with_run_totals() {
        let (report, _, hw, _) = traced_micro();
        let rows = utilization_rows(&report, &hw);
        assert_eq!(rows.len(), report.layers.len());
        let dram: u64 = rows.iter().map(|r| r.dram_bytes).sum();
        assert_eq!(dram, report.dram.total());
        let pct: f64 = rows.iter().map(|r| r.energy_pct).sum();
        assert!((pct - 100.0).abs() < 1e-6, "energy shares sum to 100, got {pct}");
        for r in &rows {
            assert!(r.pe_active_pct >= 0.0 && r.pe_active_pct <= 100.0);
        }
        let text = render_utilization(&report, &hw);
        assert!(text.lines().count() == rows.len() + 1);
        assert!(text.contains("PE-active%"));
    }
}

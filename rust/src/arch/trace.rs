//! Execution trace recording for the chip simulator.
//!
//! A [`Trace`] collects timestamped scheduler events (layer start/end,
//! fusion decisions, DRAM transfers, IF activity) so a run can be
//! inspected offline — the software analogue of waveform dumping on the
//! RTL.  Rendering is a compact text timeline; `Trace::to_tsv` emits a
//! spreadsheet-friendly dump.

use crate::arch::schedule::PlanKind;

/// One trace event.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// A compute layer began at `cycle`.
    LayerStart { layer: usize, kind: PlanKind, cycle: u64 },
    /// A compute layer finished at `cycle` having fired `spikes`.
    LayerEnd { layer: usize, cycle: u64, spikes: u64 },
    /// Two layers were fused (no DRAM round-trip between them); stamped
    /// with the cycle the pair's first layer begins at.
    Fused { first: usize, second: usize, cycle: u64 },
    /// A DRAM transfer of `bytes` at `cycle`; `write` gives the
    /// direction (true = chip → DRAM, false = DRAM → chip).
    DramTransfer { layer: usize, bytes: u64, write: bool, what: &'static str, cycle: u64 },
}

/// An ordered event log.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    events: Vec<Event>,
}

impl Trace {
    /// Record an event.
    pub fn push(&mut self, e: Event) {
        self.events.push(e);
    }

    /// All events in record order.
    pub fn events(&self) -> &[Event] {
        &self.events
    }

    /// Number of recorded events.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// True when nothing was recorded.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Compact human-readable timeline.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for e in &self.events {
            match e {
                Event::LayerStart { layer, kind, cycle } => {
                    out.push_str(&format!("@{cycle:>10}  L{layer} {kind:?} start\n"));
                }
                Event::LayerEnd { layer, cycle, spikes } => {
                    out.push_str(&format!(
                        "@{cycle:>10}  L{layer} end ({spikes} spikes)\n"
                    ));
                }
                Event::Fused { first, second, cycle } => {
                    out.push_str(&format!("@{cycle:>10}  L{first}+L{second} fused\n"));
                }
                Event::DramTransfer { layer, bytes, write, what, cycle } => {
                    out.push_str(&format!(
                        "@{cycle:>10}  L{layer} DRAM {} {bytes} B ({what})\n",
                        if *write { "<-" } else { "->" }
                    ));
                }
            }
        }
        out
    }

    /// Tab-separated dump (one event per line).
    pub fn to_tsv(&self) -> String {
        let mut out = String::from("event\tlayer\tcycle\tdetail\n");
        for e in &self.events {
            match e {
                Event::LayerStart { layer, kind, cycle } => {
                    out.push_str(&format!("start\t{layer}\t{cycle}\t{kind:?}\n"));
                }
                Event::LayerEnd { layer, cycle, spikes } => {
                    out.push_str(&format!("end\t{layer}\t{cycle}\t{spikes}\n"));
                }
                Event::Fused { first, second, cycle } => {
                    out.push_str(&format!("fused\t{first}\t{cycle}\t{second}\n"));
                }
                Event::DramTransfer { layer, bytes, write, what, cycle } => {
                    out.push_str(&format!(
                        "dram\t{layer}\t{cycle}\t{}{bytes}B:{what}\n",
                        if *write { "w" } else { "r" }
                    ));
                }
            }
        }
        out
    }

    /// Total cycles between the first start and the last end event.
    pub fn span_cycles(&self) -> u64 {
        let start = self.events.iter().find_map(|e| match e {
            Event::LayerStart { cycle, .. } => Some(*cycle),
            _ => None,
        });
        let end = self.events.iter().rev().find_map(|e| match e {
            Event::LayerEnd { cycle, .. } => Some(*cycle),
            _ => None,
        });
        match (start, end) {
            (Some(s), Some(e)) if e >= s => e - s,
            _ => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Trace {
        let mut t = Trace::default();
        t.push(Event::LayerStart { layer: 0, kind: PlanKind::EncConv, cycle: 0 });
        t.push(Event::DramTransfer { layer: 0, bytes: 784, write: false, what: "image", cycle: 0 });
        t.push(Event::LayerEnd { layer: 0, cycle: 1000, spikes: 42 });
        t.push(Event::Fused { first: 0, second: 1, cycle: 1000 });
        t.push(Event::LayerStart { layer: 1, kind: PlanKind::Conv, cycle: 1000 });
        t.push(Event::LayerEnd { layer: 1, cycle: 5000, spikes: 17 });
        t
    }

    #[test]
    fn records_in_order() {
        let t = sample();
        assert_eq!(t.len(), 6);
        assert!(matches!(t.events()[0], Event::LayerStart { layer: 0, .. }));
    }

    #[test]
    fn render_contains_key_lines() {
        let r = sample().render();
        assert!(r.contains("L0 EncConv start"));
        assert!(r.contains("L0+L1 fused"));
        assert!(r.contains("42 spikes"));
        assert!(r.contains("DRAM -> 784 B (image)"));
    }

    #[test]
    fn tsv_has_header_and_rows() {
        let tsv = sample().to_tsv();
        assert!(tsv.starts_with("event\tlayer\tcycle\tdetail\n"));
        assert_eq!(tsv.lines().count(), 7);
        // Every row carries its cycle stamp (PR8): no empty cycle column.
        for row in tsv.lines().skip(1) {
            assert!(!row.split('\t').nth(2).unwrap().is_empty(), "no cycle in {row:?}");
        }
        assert!(tsv.contains("fused\t0\t1000\t1"));
        assert!(tsv.contains("dram\t0\t0\tr784B:image"));
    }

    #[test]
    fn span() {
        assert_eq!(sample().span_cycles(), 5000);
        assert_eq!(Trace::default().span_cycles(), 0);
    }
}

//! Three-stage accumulator (paper Fig. 4) + boundary handling.
//!
//! * **Stage 1** sums the three PE arrays of one block (already folded into
//!   [`crate::arch::pe::PeBlock::cycle`]) and, in encoding mode, shifts
//!   each block's partial sum by its bitplane index (Fig. 7).
//! * **Stage 2/3** reduce the 32 PE blocks with a two-level adder tree and
//!   accumulate channel groups when `C_in > 32` (§III-C).
//!
//! The unit is a pure combinational model plus a pipeline-depth constant
//! the timing model charges once per pass.

/// Pipeline depth of the accumulator (three stages, paper Fig. 4) plus the
/// PE output register — charged as fill cycles once per schedule pass.
pub const PIPELINE_DEPTH: u64 = 4;

/// Reduce per-block column partial sums into one column (stage 2/3).
///
/// `block_psums[b][d]` is block `b`'s diagonal-summed column; `shift[b]`
/// is the left-shift applied at stage 1 (bitplane weight in encoding mode,
/// all zeros for spiking layers).
pub fn reduce_blocks(block_psums: &[Vec<i32>], shifts: &[u32]) -> Vec<i32> {
    let mut out = Vec::new();
    reduce_blocks_into(block_psums, shifts, &mut out);
    out
}

/// [`reduce_blocks`] into a caller-owned buffer (cleared and re-sized
/// here), so the Exact-mode schedule walk reuses one column buffer for
/// every reduction instead of allocating per cycle.
pub fn reduce_blocks_into(block_psums: &[Vec<i32>], shifts: &[u32], out: &mut Vec<i32>) {
    assert_eq!(block_psums.len(), shifts.len());
    out.clear();
    if block_psums.is_empty() {
        return;
    }
    let d = block_psums[0].len();
    out.resize(d, 0);
    for (psum, &sh) in block_psums.iter().zip(shifts) {
        assert_eq!(psum.len(), d, "ragged block outputs");
        for (o, &v) in out.iter_mut().zip(psum) {
            *o += v << sh;
        }
    }
}

/// Boundary accumulator: carries tile-seam partial sums between vertical
/// tiles (paper §III-C/D: the bottom boundary rows of a tile are stored in
/// the boundary SRAM and added to the top rows of the next tile).
#[derive(Debug, Clone)]
pub struct BoundaryBuffer {
    /// psum per output column for the row just above the current tile.
    above: Vec<i32>,
    /// psum per output column for the row just below the current tile.
    below: Vec<i32>,
    pub writes: u64,
    pub reads: u64,
}

impl BoundaryBuffer {
    /// Buffer for `width` output columns.
    pub fn new(width: usize) -> Self {
        Self {
            above: vec![0; width],
            below: vec![0; width],
            writes: 0,
            reads: 0,
        }
    }

    /// Store the two boundary diagonals of column `x` (d=0 row above the
    /// tile, d=max row below the tile).
    pub fn store(&mut self, x: usize, above: i32, below: i32) {
        self.above[x] += above;
        self.below[x] += below;
        self.writes += 1;
    }

    /// Drain the accumulated "below" seam when the next tile starts: these
    /// values belong to that tile's first row... (the caller adds them to
    /// its running psum plane).  Resets the buffer.
    pub fn take(&mut self) -> (Vec<i32>, Vec<i32>) {
        self.reads += 1;
        let above = std::mem::take(&mut self.above);
        let below = std::mem::take(&mut self.below);
        self.above = vec![0; above.len()];
        self.below = vec![0; below.len()];
        (above, below)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduce_plain() {
        let psums = vec![vec![1, -2, 3], vec![4, 5, -6]];
        assert_eq!(reduce_blocks(&psums, &[0, 0]), vec![5, 3, -3]);
    }

    #[test]
    fn reduce_bitplane_shift() {
        // planes 0 and 3: contribution 1*v0 + 8*v1 (Fig. 7 shift-add).
        let psums = vec![vec![1, 1], vec![1, -1]];
        assert_eq!(reduce_blocks(&psums, &[0, 3]), vec![9, -7]);
    }

    #[test]
    fn reduce_empty() {
        assert!(reduce_blocks(&[], &[]).is_empty());
    }

    #[test]
    fn boundary_accumulates_and_drains() {
        let mut b = BoundaryBuffer::new(4);
        b.store(0, 10, 1);
        b.store(0, -3, 2);
        b.store(2, 5, 0);
        let (above, below) = b.take();
        assert_eq!(above, vec![7, 0, 5, 0]);
        assert_eq!(below, vec![3, 0, 0, 0]);
        assert_eq!(b.writes, 3);
        assert_eq!(b.reads, 1);
        // drained
        let (above2, _) = b.take();
        assert_eq!(above2, vec![0, 0, 0, 0]);
    }
}

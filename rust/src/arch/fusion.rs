//! Two-layer fusion planning (paper §III-G).
//!
//! The chip executes two consecutive layers inside the chip: the first
//! layer's output spikes stay in the temp SRAM and feed the second layer
//! directly, halving intermediate DRAM traffic.  The enabling condition is
//! that the weight SRAM holds *both* layers' weights (the paper sizes the
//! weight SRAM "large enough to store the weights of two layers").
//!
//! `plan_fusion` pairs consecutive compute layers greedily, subject to the
//! weight-SRAM capacity; layers whose pair would overflow run alone.

use crate::arch::schedule::LayerPlan;
use crate::config::HwConfig;

/// One fused execution group: `start..start + len` plan indices (len 1
/// or 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FusionGroup {
    pub start: usize,
    pub len: usize,
}

/// Greedy pairing of consecutive layers under the weight-SRAM budget.
pub fn plan_fusion(plans: &[LayerPlan], hw: &HwConfig) -> Vec<FusionGroup> {
    if !hw.layer_fusion {
        return (0..plans.len()).map(|i| FusionGroup { start: i, len: 1 }).collect();
    }
    let budget_bits = hw.weight_sram_bits();
    let mut groups = Vec::new();
    let mut i = 0;
    while i < plans.len() {
        if i + 1 < plans.len()
            && plans[i].weight_bits() + plans[i + 1].weight_bits() <= budget_bits
        {
            groups.push(FusionGroup { start: i, len: 2 });
            i += 2;
        } else {
            groups.push(FusionGroup { start: i, len: 1 });
            i += 1;
        }
    }
    groups
}

/// Fusion roles of plan index `idx` under `groups`:
/// (input comes from temp SRAM, output stays in temp SRAM).
pub fn roles(groups: &[FusionGroup], idx: usize) -> (bool, bool) {
    for g in groups {
        if g.len == 2 {
            if idx == g.start {
                return (false, true); // first of pair: output fused
            }
            if idx == g.start + 1 {
                return (true, false); // second of pair: input fused
            }
        }
    }
    (false, false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::schedule::PlanKind;

    fn plan(c_in: usize, c_out: usize) -> LayerPlan {
        LayerPlan {
            kind: PlanKind::Conv,
            c_in,
            c_out,
            k: 3,
            h: 8,
            w: 8,
            pooled: false,
            model_index: 0,
        }
    }

    #[test]
    fn pairs_when_weights_fit() {
        let hw = HwConfig::default(); // 96 KiB weight SRAM
        // two 64x64x3x3 layers: 2 * 36864 bits = 9 KiB -> fuse
        let plans = vec![plan(64, 64), plan(64, 64)];
        let groups = plan_fusion(&plans, &hw);
        assert_eq!(groups, vec![FusionGroup { start: 0, len: 2 }]);
        assert_eq!(roles(&groups, 0), (false, true));
        assert_eq!(roles(&groups, 1), (true, false));
    }

    #[test]
    fn big_pairs_run_alone() {
        let hw = HwConfig::default();
        // two 256x256x3x3 layers: 2 * 72 KiB = 144 KiB > 96 KiB -> alone
        let plans = vec![plan(256, 256), plan(256, 256)];
        let groups = plan_fusion(&plans, &hw);
        assert_eq!(groups.len(), 2);
        assert!(groups.iter().all(|g| g.len == 1));
        assert_eq!(roles(&groups, 0), (false, false));
    }

    #[test]
    fn disabled_fusion_all_single() {
        let hw = HwConfig { layer_fusion: false, ..HwConfig::default() };
        let plans = vec![plan(64, 64), plan(64, 64), plan(64, 64)];
        let groups = plan_fusion(&plans, &hw);
        assert_eq!(groups.len(), 3);
        assert!(groups.iter().all(|g| g.len == 1));
    }

    /// Property: across randomized layer sizes and SRAM budgets —
    /// including budgets smaller than any single layer — `plan_fusion`
    /// (a) partitions the plan indices exactly once, in order, into
    /// groups of length 1 or 2, and (b) never emits a fused pair whose
    /// combined weights exceed the weight-SRAM budget.
    #[test]
    fn fusion_partition_and_budget_property() {
        use crate::testing::{check, Gen};
        check("plan_fusion partitions in order under budget", 300, |g: &mut Gen| {
            let n = g.usize_in(0, 12);
            let plans: Vec<LayerPlan> = (0..n)
                .map(|_| plan(g.usize_in(1, 512), g.usize_in(1, 512)))
                .collect();
            // 0.05 KiB (410 bits) is below any single 3x3 layer here;
            // 2304 KiB holds even two maximal 512x512x3x3 layers.
            let weight_sram_kb = *g.choose(&[0.05, 1.0, 16.0, 96.0, 2304.0]);
            let hw = HwConfig { weight_sram_kb, layer_fusion: g.bool(), ..HwConfig::default() };
            let groups = plan_fusion(&plans, &hw);

            let mut next = 0usize;
            for fg in &groups {
                assert_eq!(fg.start, next, "groups out of order or overlapping");
                assert!(fg.len == 1 || fg.len == 2, "group of len {}", fg.len);
                next += fg.len;
            }
            assert_eq!(next, plans.len(), "groups do not cover every plan");

            let budget_bits = hw.weight_sram_bits();
            for fg in groups.iter().filter(|fg| fg.len == 2) {
                assert!(hw.layer_fusion, "fused pair with fusion disabled");
                let pair = plans[fg.start].weight_bits() + plans[fg.start + 1].weight_bits();
                assert!(pair <= budget_bits, "pair {pair} bits over budget {budget_bits}");
            }
        });
    }

    #[test]
    fn odd_count_leaves_tail_single() {
        let hw = HwConfig::default();
        let plans = vec![plan(16, 16), plan(16, 16), plan(16, 16)];
        let groups = plan_fusion(&plans, &hw);
        assert_eq!(
            groups,
            vec![
                FusionGroup { start: 0, len: 2 },
                FusionGroup { start: 2, len: 1 }
            ]
        );
    }
}

//! Off-chip DRAM traffic model.
//!
//! Tracks byte traffic by category so the layer-fusion study (§IV-B:
//! 1450.172 KB -> 938.172 KB, -35.3%) and the energy model can report the
//! same breakdown the paper discusses.

/// Traffic category.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Traffic {
    /// Input image (multi-bit, encoding layer).
    Image,
    /// Binary layer weights.
    Weights,
    /// Input spike trains read from DRAM.
    SpikesIn,
    /// Output spike trains written to DRAM.
    SpikesOut,
    /// Membrane potentials (only without tick batching).
    Membrane,
    /// Final logits.
    Logits,
}

const CATEGORIES: [Traffic; 6] = [
    Traffic::Image,
    Traffic::Weights,
    Traffic::SpikesIn,
    Traffic::SpikesOut,
    Traffic::Membrane,
    Traffic::Logits,
];

/// DRAM byte counters, split by direction and category.
#[derive(Debug, Clone, Default)]
pub struct Dram {
    read: [u64; 6],
    write: [u64; 6],
}

impl Traffic {
    /// Stable lowercase name used for metric keys (`telemetry`).
    pub fn name(self) -> &'static str {
        match self {
            Traffic::Image => "image",
            Traffic::Weights => "weights",
            Traffic::SpikesIn => "spikes_in",
            Traffic::SpikesOut => "spikes_out",
            Traffic::Membrane => "membrane",
            Traffic::Logits => "logits",
        }
    }
}

impl Dram {
    fn idx(t: Traffic) -> usize {
        CATEGORIES.iter().position(|&c| c == t).unwrap()
    }

    /// Record a read of `bytes` in category `t`.
    pub fn read(&mut self, t: Traffic, bytes: u64) {
        self.read[Self::idx(t)] += bytes;
    }

    /// Record a write of `bytes` in category `t`.
    pub fn write(&mut self, t: Traffic, bytes: u64) {
        self.write[Self::idx(t)] += bytes;
    }

    /// Total bytes moved (read + write).
    pub fn total(&self) -> u64 {
        self.read.iter().sum::<u64>() + self.write.iter().sum::<u64>()
    }

    /// Total bytes in one category.
    pub fn category(&self, t: Traffic) -> u64 {
        self.read[Self::idx(t)] + self.write[Self::idx(t)]
    }

    /// `(category, read bytes, written bytes)` for every category in
    /// declaration order — the iteration the registry exporter uses.
    pub fn by_category(&self) -> impl Iterator<Item = (Traffic, u64, u64)> + '_ {
        CATEGORIES.iter().map(|&c| (c, self.read[Self::idx(c)], self.write[Self::idx(c)]))
    }

    /// Per-category traffic accumulated since `before` (a clone of this
    /// counter taken earlier): `(category, read delta, write delta)` in
    /// declaration order.  Lets the tracer attribute one layer's DRAM
    /// traffic by category without a second set of counters.
    pub fn delta<'a>(
        &'a self,
        before: &'a Dram,
    ) -> impl Iterator<Item = (Traffic, u64, u64)> + 'a {
        self.by_category().zip(before.by_category()).map(|((c, r_now, w_now), (_, r0, w0))| {
            (c, r_now - r0, w_now - w0)
        })
    }

    /// Human-readable breakdown in KB.
    pub fn report(&self) -> String {
        let mut lines = Vec::new();
        for &c in &CATEGORIES {
            let total = self.category(c);
            if total > 0 {
                lines.push(format!("  {:?}: {:.3} KB", c, total as f64 / 1024.0));
            }
        }
        lines.push(format!("  total: {:.3} KB", self.total() as f64 / 1024.0));
        lines.join("\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn categories_accumulate() {
        let mut d = Dram::default();
        d.read(Traffic::Weights, 100);
        d.write(Traffic::SpikesOut, 50);
        d.read(Traffic::SpikesIn, 50);
        assert_eq!(d.total(), 200);
        assert_eq!(d.category(Traffic::Weights), 100);
        assert_eq!(d.category(Traffic::SpikesIn), 50);
        assert_eq!(d.category(Traffic::Membrane), 0);
    }

    #[test]
    fn delta_attributes_per_category() {
        let mut d = Dram::default();
        d.read(Traffic::Weights, 100);
        let before = d.clone();
        d.read(Traffic::Weights, 20);
        d.write(Traffic::SpikesOut, 50);
        let changed: Vec<_> =
            d.delta(&before).filter(|&(_, r, w)| r + w > 0).collect();
        assert_eq!(changed, vec![(Traffic::Weights, 20, 0), (Traffic::SpikesOut, 0, 50)]);
    }

    #[test]
    fn report_renders_kb() {
        let mut d = Dram::default();
        d.read(Traffic::Image, 2048);
        let r = d.report();
        assert!(r.contains("Image: 2.000 KB"));
        assert!(r.contains("total: 2.000 KB"));
    }
}

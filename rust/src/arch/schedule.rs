//! Timing and traffic model of the vectorwise dataflow (paper Fig. 5/6).
//!
//! The control loop of the chip is, per layer and time step:
//!
//! ```text
//! for o in 0..C_out:                       # output channel
//!   for g in 0..ceil(C_in_eff / 32):       # input-channel group -> blocks
//!     for tile in 0..ceil(H / 8):          # 8-row output tile
//!       for x in 0..W:                     # output column
//!         1 cycle: 32 blocks x 3 arrays x (8 x 3) PEs
//! ```
//!
//! `C_in_eff` is `C_in` for spiking layers and `bitplanes * C_in` for the
//! encoding layer (each bitplane occupies one PE block, Fig. 7).  When the
//! group/tile geometry divides evenly every PE contributes a useful MAC
//! every cycle — the paper's full-utilization claim; ragged edges cost
//! idle PEs, which the model reports as utilization < 1.
//!
//! The same walk charges SRAM accesses and, at layer granularity, DRAM
//! traffic under tick batching (§III-A) and layer fusion (§III-G).

use crate::arch::accumulator::PIPELINE_DEPTH;
use crate::arch::dram::{Dram, Traffic};
use crate::config::models::{LayerKind, ModelSpec};
use crate::config::HwConfig;
use crate::snn::params::{DeployedModel, Kind, Layer};
use crate::util::ceil_div;

/// Compute-layer kind after folding pools into the preceding layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanKind {
    EncConv,
    Conv,
    Fc,
    Readout,
}

/// One compute layer of the execution plan.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerPlan {
    pub kind: PlanKind,
    pub c_in: usize,
    pub c_out: usize,
    pub k: usize,
    /// Spatial size of the layer's input/output (pre-pool); 1 for fc.
    pub h: usize,
    pub w: usize,
    /// Followed by an MP2 (output stored post-pool).
    pub pooled: bool,
    /// Index of the layer in `DeployedModel::layers`.
    pub model_index: usize,
}

impl LayerPlan {
    /// Binary weight bits of this layer.
    pub fn weight_bits(&self) -> u64 {
        (self.c_out * self.c_in * self.k.max(1) * self.k.max(1)) as u64
    }

    /// Input spike bits per time step (fc: flat).
    pub fn in_bits_per_step(&self) -> u64 {
        (self.c_in * self.h * self.w) as u64
    }

    /// Output spike bits per time step, post-pool if pooled.
    pub fn out_bits_per_step(&self) -> u64 {
        let div = if self.pooled { 4 } else { 1 };
        (self.c_out * self.h * self.w / div) as u64
    }

    /// Effective input channels occupying PE blocks (bitplanes expand the
    /// encoding layer, Fig. 7).
    pub fn c_in_effective(&self, hw: &HwConfig) -> usize {
        match self.kind {
            PlanKind::EncConv => self.c_in * hw.encode_bitplanes,
            _ => self.c_in,
        }
    }

    /// Input-channel groups sequenced through the accumulator (§III-C).
    pub fn groups(&self, hw: &HwConfig) -> usize {
        ceil_div(self.c_in_effective(hw), hw.pe_blocks)
    }

    /// Row tiles (8-row vectors at the design point).
    pub fn tiles(&self, hw: &HwConfig) -> usize {
        ceil_div(self.h, hw.rows_per_array)
    }

    /// Cycles for one *pass* over the feature map (one time step of a
    /// spiking layer; the single conv of the encoding layer).  The
    /// accumulator is throughput-pipelined (Fig. 4): it never drains
    /// between column sweeps of the same layer, so the fill latency is
    /// charged once per pass, not per (channel, group, tile) segment.
    pub fn cycles_per_pass(&self, hw: &HwConfig) -> u64 {
        let segments = (self.c_out * self.groups(hw) * self.tiles(hw)) as u64;
        segments * self.w as u64 + PIPELINE_DEPTH
    }

    /// Total cycles across `t_steps` (encoding conv computed once and
    /// re-accumulated by the IF unit, §III-F).
    pub fn cycles(&self, hw: &HwConfig, t_steps: usize) -> u64 {
        match self.kind {
            PlanKind::EncConv => self.cycles_per_pass(hw),
            _ => self.cycles_per_pass(hw) * t_steps as u64,
        }
    }

    /// PE-level ops actually performed (AND-multiply+add pairs), across
    /// all time steps.  Encoding ops count each bitplane.
    pub fn pe_ops(&self, hw: &HwConfig, t_steps: usize) -> u64 {
        let per_pass = (self.c_in_effective(hw) * self.c_out * self.k.max(1) * self.k.max(1))
            as u64
            * (self.h * self.w) as u64;
        match self.kind {
            PlanKind::EncConv => per_pass,
            _ => per_pass * t_steps as u64,
        }
    }

    /// Fraction of PE slots doing useful work.
    pub fn utilization(&self, hw: &HwConfig, t_steps: usize) -> f64 {
        let slots = self.cycles(hw, t_steps) as f64 * hw.total_pes() as f64;
        if slots == 0.0 {
            return 0.0;
        }
        // Each useful MAC = 1 multiply + 1 add = 2 ops; a PE slot does 2.
        self.pe_ops(hw, t_steps) as f64 / slots
    }
}

/// Fold a parsed model into compute-layer plans (pools attach to the
/// preceding compute layer, as the chip's post-processing unit does).
pub fn plan_model(model: &DeployedModel) -> Vec<LayerPlan> {
    let mut plans: Vec<LayerPlan> = Vec::new();
    let mut h = model.in_size;
    let mut w = model.in_size;
    for (idx, layer) in model.layers.iter().enumerate() {
        match layer {
            Layer::Conv { kind, c_out, c_in, k, .. } => {
                plans.push(LayerPlan {
                    kind: if *kind == Kind::EncConv {
                        PlanKind::EncConv
                    } else {
                        PlanKind::Conv
                    },
                    c_in: *c_in,
                    c_out: *c_out,
                    k: *k,
                    h,
                    w,
                    pooled: false,
                    model_index: idx,
                });
            }
            Layer::MaxPool => {
                let last = plans
                    .last_mut()
                    .expect("maxpool cannot be the first layer");
                assert!(!last.pooled, "consecutive pools unsupported");
                last.pooled = true;
                h /= 2;
                w /= 2;
            }
            Layer::Fc { n_out, n_in, .. } => {
                plans.push(LayerPlan {
                    kind: PlanKind::Fc,
                    c_in: *n_in,
                    c_out: *n_out,
                    k: 1,
                    h: 1,
                    w: 1,
                    pooled: false,
                    model_index: idx,
                });
                h = 1;
                w = 1;
            }
            Layer::Readout { n_out, n_in, .. } => {
                plans.push(LayerPlan {
                    kind: PlanKind::Readout,
                    c_in: *n_in,
                    c_out: *n_out,
                    k: 1,
                    h: 1,
                    w: 1,
                    pooled: false,
                    model_index: idx,
                });
            }
        }
    }
    plans
}

/// Fold a Table-I [`ModelSpec`] into compute-layer plans without
/// synthesizing weights — the design-space-exploration path.  Timing,
/// SRAM and DRAM counters are data-independent, so a plan built from the
/// bare spec is interchangeable with one built from a [`DeployedModel`]
/// of the same geometry (asserted by `plan_spec_matches_plan_model`).
pub fn plan_spec(spec: &ModelSpec) -> Vec<LayerPlan> {
    let mut plans: Vec<LayerPlan> = Vec::new();
    let (mut c, mut s) = (spec.in_channels, spec.in_size);
    for (idx, ly) in spec.layers.iter().enumerate() {
        match ly.kind {
            LayerKind::EncConv | LayerKind::Conv => {
                plans.push(LayerPlan {
                    kind: if ly.kind == LayerKind::EncConv {
                        PlanKind::EncConv
                    } else {
                        PlanKind::Conv
                    },
                    c_in: c,
                    c_out: ly.c_out,
                    k: ly.ksize,
                    h: s,
                    w: s,
                    pooled: false,
                    model_index: idx,
                });
                c = ly.c_out;
            }
            LayerKind::MaxPool => {
                let last = plans.last_mut().expect("maxpool cannot be the first layer");
                assert!(!last.pooled, "consecutive pools unsupported");
                last.pooled = true;
                s /= 2;
            }
            LayerKind::Fc | LayerKind::Readout => {
                plans.push(LayerPlan {
                    kind: if ly.kind == LayerKind::Fc { PlanKind::Fc } else { PlanKind::Readout },
                    c_in: c * s * s,
                    c_out: ly.c_out,
                    k: 1,
                    h: 1,
                    w: 1,
                    pooled: false,
                    model_index: idx,
                });
                c = ly.c_out;
                s = 1;
            }
        }
    }
    plans
}

/// Per-layer SRAM access totals for one inference (all T steps).
#[derive(Debug, Clone, Default)]
pub struct SramAccesses {
    /// spike SRAM column reads (one per active block per cycle)
    pub spike_reads: u64,
    /// weight SRAM fetches (one 32-channel tap bundle per pass segment)
    pub weight_reads: u64,
    /// membrane SRAM read-modify-writes (one per neuron per step)
    pub membrane_rmw: u64,
    /// temp SRAM spike writes (bits / 8 per step, rounded up)
    pub temp_writes: u64,
    /// boundary SRAM stores + loads
    pub boundary_ops: u64,
}

impl SramAccesses {
    /// Elementwise sum.
    pub fn add(&mut self, o: &SramAccesses) {
        self.spike_reads += o.spike_reads;
        self.weight_reads += o.weight_reads;
        self.membrane_rmw += o.membrane_rmw;
        self.temp_writes += o.temp_writes;
        self.boundary_ops += o.boundary_ops;
    }

    /// Total access count.
    pub fn total(&self) -> u64 {
        self.spike_reads + self.weight_reads + self.membrane_rmw + self.temp_writes
            + self.boundary_ops
    }
}

/// SRAM accesses charged by the schedule walk for one layer.
pub fn layer_sram(plan: &LayerPlan, hw: &HwConfig, t_steps: usize) -> SramAccesses {
    let groups = plan.groups(hw) as u64;
    let tiles = plan.tiles(hw) as u64;
    let c_out = plan.c_out as u64;
    let w = plan.w as u64;
    let steps = if plan.kind == PlanKind::EncConv { 1 } else { t_steps as u64 };
    let blocks = hw.pe_blocks as u64;
    let neurons = (plan.c_out * plan.h * plan.w) as u64;

    SramAccesses {
        // one column read per active block per cycle; the last group may be
        // ragged but we charge full blocks (the banks are read anyway).
        spike_reads: c_out * groups * tiles * w * blocks * steps,
        weight_reads: c_out * groups * tiles * steps,
        // IF integrates every output neuron every time step (readout
        // accumulates logits instead but still touches its accumulator).
        membrane_rmw: neurons * t_steps as u64,
        temp_writes: ceil_div((neurons * t_steps as u64) as usize, 8) as u64,
        boundary_ops: if plan.k > 1 { c_out * tiles * w * steps * 2 } else { 0 },
    }
}

/// DRAM traffic for one layer under the given fusion role.
///
/// `fused_input`: the layer consumes its input directly from the temp SRAM
/// (second layer of a fused pair) — no DRAM read.
/// `fused_output`: the layer's output stays in the temp SRAM (first layer
/// of a fused pair) — no DRAM write.
pub fn layer_dram(
    plan: &LayerPlan,
    t_steps: usize,
    fused_input: bool,
    fused_output: bool,
    tick_batching: bool,
    dram: &mut Dram,
) {
    let t = t_steps as u64;
    dram.read(Traffic::Weights, ceil_div(plan.weight_bits() as usize, 8) as u64);

    match plan.kind {
        PlanKind::EncConv => {
            // Multi-bit image, one byte per pixel.
            dram.read(Traffic::Image, plan.in_bits_per_step());
        }
        _ if !fused_input => {
            let bytes = ceil_div((plan.in_bits_per_step() * t) as usize, 8) as u64;
            dram.read(Traffic::SpikesIn, bytes);
        }
        _ => {}
    }

    match plan.kind {
        PlanKind::Readout => {
            dram.write(Traffic::Logits, plan.c_out as u64 * 4);
        }
        _ if !fused_output => {
            dram.write(
                Traffic::SpikesOut,
                ceil_div((plan.out_bits_per_step() * t) as usize, 8) as u64,
            );
        }
        _ => {}
    }

    if !tick_batching && plan.kind != PlanKind::Readout {
        // Without tick batching the residual membrane (2 B per neuron)
        // round-trips between consecutive time steps, and weights are
        // re-fetched per step — the cost SpinalFlow's analysis highlights.
        let neurons = (plan.c_out * plan.h * plan.w) as u64;
        dram.write(Traffic::Membrane, neurons * 2 * (t - 1));
        dram.read(Traffic::Membrane, neurons * 2 * (t - 1));
        dram.read(
            Traffic::Weights,
            ceil_div(plan.weight_bits() as usize, 8) as u64 * (t - 1),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HwConfig;

    fn conv_plan(c_in: usize, c_out: usize, hw_size: usize) -> LayerPlan {
        LayerPlan {
            kind: PlanKind::Conv,
            c_in,
            c_out,
            k: 3,
            h: hw_size,
            w: hw_size,
            pooled: false,
            model_index: 0,
        }
    }

    /// The paper's full-utilization claim: when C_in % 32 == 0 and
    /// H % 8 == 0, every PE does useful work every (steady-state) cycle.
    #[test]
    fn full_utilization_when_geometry_divides() {
        let hw = HwConfig::default();
        let plan = conv_plan(128, 128, 32);
        let util = plan.utilization(&hw, 8);
        // PIPELINE_DEPTH fill cycles make it slightly less than 1.
        assert!(util > 0.85, "utilization {util}");
        // Steady state excludes the pipeline-fill cycles: exactly 1.0 when
        // the geometry divides (the paper's full-utilization claim).
        let passes = (plan.c_out * plan.groups(&hw) * plan.tiles(&hw)) as u64;
        let steady_cycles = passes * plan.w as u64 * 8;
        let steady =
            plan.pe_ops(&hw, 8) as f64 / (steady_cycles as f64 * hw.total_pes() as f64);
        assert!((steady - 1.0).abs() < 1e-12, "steady-state utilization {steady}");
    }

    #[test]
    fn ragged_channels_lower_utilization() {
        let hw = HwConfig::default();
        let full = conv_plan(128, 64, 32).utilization(&hw, 8);
        let ragged = conv_plan(100, 64, 32).utilization(&hw, 8); // 4 groups, last 4/32
        assert!(ragged < full);
    }

    #[test]
    fn encoding_runs_once() {
        let hw = HwConfig::default();
        let mut enc = conv_plan(3, 128, 32);
        enc.kind = PlanKind::EncConv;
        assert_eq!(enc.cycles(&hw, 8), enc.cycles_per_pass(&hw));
        // 3 channels x 8 bitplanes = 24 blocks -> 1 group
        assert_eq!(enc.groups(&hw), 1);
    }

    #[test]
    fn cycles_scale_with_time_steps() {
        let hw = HwConfig::default();
        let plan = conv_plan(64, 64, 16);
        assert_eq!(plan.cycles(&hw, 8), 8 * plan.cycles(&hw, 1));
    }

    #[test]
    fn dram_fusion_skips_intermediate() {
        let plan = conv_plan(64, 64, 16);
        let mut a = Dram::default();
        layer_dram(&plan, 8, false, false, true, &mut a);
        let mut b = Dram::default();
        layer_dram(&plan, 8, true, true, true, &mut b);
        assert_eq!(b.category(Traffic::SpikesIn), 0);
        assert_eq!(b.category(Traffic::SpikesOut), 0);
        assert!(a.total() > b.total());
        // weights always loaded
        assert_eq!(
            a.category(Traffic::Weights),
            b.category(Traffic::Weights)
        );
    }

    #[test]
    fn no_tick_batching_charges_membrane() {
        let plan = conv_plan(64, 64, 16);
        let mut a = Dram::default();
        layer_dram(&plan, 8, false, false, false, &mut a);
        assert!(a.category(Traffic::Membrane) > 0);
        // weights re-read per step: 8x the batched amount
        let mut b = Dram::default();
        layer_dram(&plan, 8, false, false, true, &mut b);
        assert_eq!(a.category(Traffic::Weights), 8 * b.category(Traffic::Weights));
    }

    /// `plan_spec` (bare spec, no weights) and `plan_model` (deployed
    /// weights) must produce identical plans for the same geometry.
    #[test]
    fn plan_spec_matches_plan_model() {
        use crate::config::models;
        use crate::snn::params::DeployedModel;
        for name in ["tiny", "mnist", "cifar10"] {
            let spec = models::by_name(name, 8).unwrap();
            let from_spec = plan_spec(&spec);
            let from_model = plan_model(&DeployedModel::synthesize(&spec, 7));
            assert_eq!(from_spec, from_model, "{name}: plan mismatch");
        }
    }

    #[test]
    fn pooled_output_is_quarter() {
        let mut plan = conv_plan(64, 64, 16);
        assert_eq!(plan.out_bits_per_step(), 64 * 256);
        plan.pooled = true;
        assert_eq!(plan.out_bits_per_step(), 64 * 64);
    }
}

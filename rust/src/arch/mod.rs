//! Cycle-accurate simulator of the VSA chip (paper §III).
//!
//! The simulator has two composable halves sharing one schedule:
//!
//! * a **timing model** ([`schedule`]) that walks the vectorwise dataflow
//!   (Fig. 5/6) — 8-row tiles x output columns x input-channel groups x
//!   output channels — and counts cycles, SRAM accesses and DRAM traffic
//!   exactly as the control FSM would issue them;
//! * a **datapath model** ([`pe`], [`accumulator`], [`if_unit`]) that
//!   executes the same schedule gate-for-gate (AND + sign select PEs,
//!   diagonal adders, three-stage accumulator, boundary SRAM, IF fire &
//!   reset) and therefore produces bit-identical spikes to the golden
//!   [`crate::snn::Network`].
//!
//! [`chip::Chip`] ties the two together with tick batching (§III-A: all T
//! time steps of a layer before the next layer) and optional two-layer
//! fusion (§III-G).  `SimMode::Exact` drives every PE; `SimMode::Fast`
//! computes functionally (popcount path) while keeping the identical
//! cycle/traffic counters — property tests assert the two modes agree on
//! both spikes *and* counters.

pub mod accumulator;
pub mod chip;
pub mod dram;
pub mod fusion;
pub mod if_unit;
pub mod pe;
pub mod schedule;
pub mod sram;
pub mod timeline;
pub mod trace;

pub use chip::{CacheStats, Chip, RunReport, SimMode, DEFAULT_MODEL_CACHE};

//! Top-level chip model: tick batching, fusion, both simulation modes.
//!
//! `SimMode::Fast` is **time-batched** (PR5): the packed weight masks and
//! layer plans are built once per distinct model and cached on the
//! [`Chip`] (a batch loop calling [`Chip::run`] per image re-packs
//! nothing), and every layer drives all T time steps through the golden
//! engine's weight-reuse kernels (`conv_t`-family AND-popcount, batched
//! matvec, closed-form encoding IF) out of a cached [`Scratch`] arena —
//! the software mirror of §III-A/§III-B: fetch each weight vector once,
//! apply it to every time step.  The counters (cycles, SRAM, DRAM,
//! pe_ops, membrane accesses) are charged by the identical schedule walk
//! as before; the pre-PR5 per-step fast datapath is frozen verbatim as
//! [`crate::baselines::chip_stepwise`] and `rust/tests/chip_batched.rs`
//! asserts the two produce field-for-field equal [`RunReport`]s.

use std::cell::RefCell;

use crate::arch::accumulator::{reduce_blocks_into, BoundaryBuffer};
use crate::arch::dram::Dram;
use crate::arch::fusion::{plan_fusion, roles, FusionGroup};
use crate::arch::if_unit::IfUnit;
use crate::arch::pe::{PeArray, PeBlock};
use crate::arch::schedule::{layer_dram, layer_sram, plan_model, LayerPlan, PlanKind, SramAccesses};
use crate::config::HwConfig;
use crate::snn::conv::{conv_multibit_into, PackedConv, PackedFc};
use crate::snn::network::{
    flatten_and_matvec, if_fire_channel, if_fire_constant, if_fire_t, reset_train,
};
use crate::snn::params::{DeployedModel, Kind, Layer};
use crate::snn::scratch::Scratch;
use crate::snn::spikemap::SpikeMap;
use crate::telemetry::Registry;

/// Simulation fidelity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimMode {
    /// Drive every PE through the vectorwise schedule (gate-level
    /// arithmetic).  Slow; use for small nets and verification.
    Exact,
    /// Functional compute (time-batched popcount fast path) + the
    /// identical timing and traffic counters.  Bit-identical results,
    /// orders of magnitude faster.
    Fast,
}

/// Per-layer simulation outcome.
#[derive(Debug, Clone)]
pub struct LayerReport {
    pub kind: PlanKind,
    pub cycles: u64,
    pub utilization: f64,
    pub spikes_emitted: u64,
    pub membrane_accesses: u64,
    /// Useful PE ops charged to this layer (MAC = 2 ops).
    pub pe_ops: u64,
    /// DRAM bytes moved for this layer (both directions; shrinks for
    /// fused pairs — the intermediate spike train never travels).
    pub dram_bytes: u64,
    /// SRAM access breakdown for this layer (feeds the per-layer
    /// energy attribution in the utilization report).
    pub sram: SramAccesses,
}

/// Whole-inference outcome.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub logits: Vec<i64>,
    pub cycles: u64,
    pub layers: Vec<LayerReport>,
    pub dram: Dram,
    pub sram: SramAccesses,
    /// Total useful PE ops (MAC = 2 ops) across the run.
    pub pe_ops: u64,
    /// End-to-end latency at the configured clock, in microseconds.
    pub latency_us: f64,
    /// Effective throughput in GOPS (2 ops per MAC).
    pub gops: f64,
    /// Average PE utilization.
    pub utilization: f64,
}

impl RunReport {
    /// Publish this run's counters into a [`Registry`] under `prefix`
    /// (`{prefix}.cycles`, `.dram.read.{category}_bytes`,
    /// `.sram.spike_reads`, `.spikes_emitted`, …) so the chip sim
    /// reports through the same exporter as serve and train
    /// (README §OBSERVABILITY).  Counter values are absolute (set, not
    /// added), so re-exporting the same report is idempotent.
    pub fn export_into(&self, reg: &Registry, prefix: &str) {
        reg.set_counter(&format!("{prefix}.cycles"), self.cycles);
        reg.set_counter(&format!("{prefix}.pe_ops"), self.pe_ops);
        reg.set_counter(&format!("{prefix}.layers"), self.layers.len() as u64);
        let spikes: u64 = self.layers.iter().map(|l| l.spikes_emitted).sum();
        let membrane: u64 = self.layers.iter().map(|l| l.membrane_accesses).sum();
        reg.set_counter(&format!("{prefix}.spikes_emitted"), spikes);
        reg.set_counter(&format!("{prefix}.membrane_accesses"), membrane);
        reg.set_gauge(&format!("{prefix}.latency_us"), self.latency_us);
        reg.set_gauge(&format!("{prefix}.gops"), self.gops);
        reg.set_gauge(&format!("{prefix}.utilization"), self.utilization);
        for (cat, read, write) in self.dram.by_category() {
            reg.set_counter(&format!("{prefix}.dram.read.{}_bytes", cat.name()), read);
            reg.set_counter(&format!("{prefix}.dram.write.{}_bytes", cat.name()), write);
        }
        reg.set_counter(&format!("{prefix}.dram.total_bytes"), self.dram.total());
        reg.set_counter(&format!("{prefix}.sram.spike_reads"), self.sram.spike_reads);
        reg.set_counter(&format!("{prefix}.sram.weight_reads"), self.sram.weight_reads);
        reg.set_counter(&format!("{prefix}.sram.membrane_rmw"), self.sram.membrane_rmw);
        reg.set_counter(&format!("{prefix}.sram.temp_writes"), self.sram.temp_writes);
        reg.set_counter(&format!("{prefix}.sram.boundary_ops"), self.sram.boundary_ops);
        reg.set_counter(&format!("{prefix}.sram.total"), self.sram.total());
    }
}

/// Weight-derived state of one model layer for the fast path, indexed by
/// `DeployedModel::layers` position (pools hold a placeholder so
/// `LayerPlan::model_index` indexes directly).
enum PackedLayer {
    /// Encoding conv consumes the multi-bit image + raw ±1 weights.
    Enc,
    Conv(PackedConv),
    Pool,
    Fc(PackedFc),
    Readout(PackedFc),
}

/// Double-lane FNV-1a over the model's structure and weight bytes.  Two
/// independent 64-bit lanes make an accidental collision (which would
/// silently reuse a stale packed model) negligible without a second pass
/// over the weights.
struct Fingerprint([u64; 2]);

impl Fingerprint {
    fn new() -> Self {
        Self([0xcbf2_9ce4_8422_2325, 0x6c62_272e_07bb_0142])
    }

    #[inline]
    fn mix(&mut self, v: u64) {
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        self.0[0] = (self.0[0] ^ v).wrapping_mul(PRIME);
        self.0[1] = (self.0[1] ^ v.rotate_left(32)).wrapping_mul(PRIME);
    }

    /// Mix a ±1 weight tensor, 8 bytes per lane step (the fixed-size
    /// copy + `from_le_bytes` compiles to one unaligned 8-byte load).
    fn mix_weights(&mut self, w: &[i8]) {
        self.mix(w.len() as u64);
        let mut chunks = w.chunks_exact(8);
        for c in &mut chunks {
            let mut bytes = [0u8; 8];
            for (b, &x) in bytes.iter_mut().zip(c) {
                *b = x as u8;
            }
            self.mix(u64::from_le_bytes(bytes));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut bytes = [0u8; 8];
            for (b, &x) in bytes.iter_mut().zip(rem) {
                *b = x as u8;
            }
            self.mix(u64::from_le_bytes(bytes));
        }
    }
}

/// Cache key: everything the packed state and the plans depend on —
/// geometry and weights.  `num_steps`, `bias` and `theta` are
/// deliberately excluded: they are read live on every run (the packed
/// masks cover only the ±1 weights), so callers may reconfigure T or the
/// IF-BN thresholds between runs at zero packing cost — the paper's
/// reconfigurability claim, kept cheap in the simulator too.
#[derive(PartialEq, Eq)]
struct ModelKey {
    fp: [u64; 2],
    n_layers: usize,
    in_channels: usize,
    in_size: usize,
}

impl ModelKey {
    fn of(model: &DeployedModel) -> Self {
        let mut fp = Fingerprint::new();
        for layer in &model.layers {
            match layer {
                Layer::Conv { kind, c_out, c_in, k, w, .. } => {
                    fp.mix(if *kind == Kind::EncConv { 1 } else { 2 });
                    fp.mix(*c_out as u64);
                    fp.mix(*c_in as u64);
                    fp.mix(*k as u64);
                    fp.mix_weights(w);
                }
                Layer::MaxPool => fp.mix(3),
                Layer::Fc { n_out, n_in, w, .. } => {
                    fp.mix(4);
                    fp.mix(*n_out as u64);
                    fp.mix(*n_in as u64);
                    fp.mix_weights(w);
                }
                Layer::Readout { n_out, n_in, w } => {
                    fp.mix(5);
                    fp.mix(*n_out as u64);
                    fp.mix(*n_in as u64);
                    fp.mix_weights(w);
                }
            }
        }
        Self {
            fp: fp.0,
            n_layers: model.layers.len(),
            in_channels: model.in_channels,
            in_size: model.in_size,
        }
    }
}

/// Packed-model cache counters.  Invariants (asserted by the LRU tests):
/// `hits + misses == lookups` and `packs == misses` — every miss packs
/// exactly one model, every eviction makes room for exactly one pack.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub lookups: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub packs: u64,
}

impl CacheStats {
    /// Fold another cache's counters in (per-worker engines each own a
    /// cache; the pool total is the sum).
    pub fn merge(&mut self, other: &CacheStats) {
        self.lookups += other.lookups;
        self.hits += other.hits;
        self.misses += other.misses;
        self.evictions += other.evictions;
        self.packs += other.packs;
    }

    /// Publish the counters into a [`Registry`] under `prefix`
    /// (`{prefix}.lookups`, `.hits`, `.misses`, `.evictions`, `.packs`).
    /// Values are absolute (set, not added) so re-export is idempotent.
    pub fn export_into(&self, reg: &Registry, prefix: &str) {
        reg.set_counter(&format!("{prefix}.lookups"), self.lookups);
        reg.set_counter(&format!("{prefix}.hits"), self.hits);
        reg.set_counter(&format!("{prefix}.misses"), self.misses);
        reg.set_counter(&format!("{prefix}.evictions"), self.evictions);
        reg.set_counter(&format!("{prefix}.packs"), self.packs);
    }
}

/// Default packed-model cache capacity (models per chip).
pub const DEFAULT_MODEL_CACHE: usize = 4;

/// One resident packed model of the fast path.
struct FastEntry {
    key: ModelKey,
    plans: Vec<LayerPlan>,
    packed: Vec<PackedLayer>,
}

/// Bounded LRU packed-model cache + shared scratch arena of the fast
/// path.  PR5's single-entry fingerprint cache generalized for
/// multi-model serving (PR9): up to `capacity` distinct models stay
/// packed, most-recently-used first; the scratch arena is shared across
/// entries (its buffers grow to the largest resident model and are
/// re-sized per run by the kernels).
struct FastCache {
    /// Resident entries, most-recently-used first.
    entries: Vec<FastEntry>,
    capacity: usize,
    groups: Vec<FusionGroup>,
    scratch: Scratch,
    stats: CacheStats,
}

impl FastCache {
    fn with_capacity(capacity: usize) -> Self {
        Self {
            entries: Vec::new(),
            capacity: capacity.max(1),
            groups: Vec::new(),
            scratch: Scratch::default(),
            stats: CacheStats::default(),
        }
    }

    /// Make the front entry current for `model`: on a key hit this costs
    /// one fingerprint walk over the weight bytes (plus the O(layers)
    /// fusion re-plan); on a miss the plans and packed weight masks are
    /// rebuilt — once per distinct model while it stays resident — and
    /// the least-recently-used entry is evicted when the cache is full.
    fn prepare(&mut self, model: &DeployedModel, hw: &HwConfig) {
        let key = ModelKey::of(model);
        self.stats.lookups += 1;
        if let Some(pos) = self.entries.iter().position(|e| e.key == key) {
            self.stats.hits += 1;
            let hit = self.entries.remove(pos);
            self.entries.insert(0, hit);
        } else {
            self.stats.misses += 1;
            self.stats.packs += 1;
            let plans = plan_model(model);
            let packed = model
                .layers
                .iter()
                .map(|ly| match ly {
                    Layer::Conv { kind: Kind::EncConv, .. } => PackedLayer::Enc,
                    Layer::Conv { c_out, c_in, k, w, .. } => {
                        PackedLayer::Conv(PackedConv::pack(*c_out, *c_in, *k, w))
                    }
                    Layer::MaxPool => PackedLayer::Pool,
                    Layer::Fc { n_out, n_in, w, .. } => {
                        PackedLayer::Fc(PackedFc::pack(*n_out, *n_in, w))
                    }
                    Layer::Readout { n_out, n_in, w } => {
                        PackedLayer::Readout(PackedFc::pack(*n_out, *n_in, w))
                    }
                })
                .collect();
            if self.entries.len() >= self.capacity {
                self.entries.pop();
                self.stats.evictions += 1;
            }
            self.entries.insert(0, FastEntry { key, plans, packed });
        }
        // The fusion plan depends on the live hw config (`Chip::hw` is a
        // pub field and `layer_fusion`/`weight_sram_kb` may be flipped
        // between runs) and is O(layers) cheap: re-derive it every run,
        // exactly like the stepwise engine does.
        self.groups = plan_fusion(&self.entries[0].plans, hw);
    }
}

/// The VSA chip simulator.
pub struct Chip {
    pub hw: HwConfig,
    pub mode: SimMode,
    /// Packed-model cache + scratch arena of the time-batched fast path
    /// (bounded LRU, fingerprint-keyed; see [`FastCache::prepare`]).
    fast: RefCell<FastCache>,
}

impl Chip {
    /// New chip at the given config and fidelity, with the default
    /// packed-model cache capacity ([`DEFAULT_MODEL_CACHE`]).
    pub fn new(hw: HwConfig, mode: SimMode) -> Self {
        Self::with_cache_capacity(hw, mode, DEFAULT_MODEL_CACHE)
    }

    /// New chip whose fast path keeps up to `capacity` distinct models
    /// packed (LRU-evicted beyond that; clamped to at least 1).
    pub fn with_cache_capacity(hw: HwConfig, mode: SimMode, capacity: usize) -> Self {
        Self { hw, mode, fast: RefCell::new(FastCache::with_capacity(capacity)) }
    }

    /// How many times this chip (re)built a packed model.  A batch loop
    /// calling [`Chip::run`] per image must see this stay at 1 per
    /// distinct resident model — the pack-counter regression hook of
    /// `rust/tests/chip_batched.rs`.  Always 0 in `Exact` mode (the
    /// gate-level datapath packs nothing).
    pub fn pack_count(&self) -> u64 {
        self.fast.borrow().stats.packs
    }

    /// Packed-model cache counters (lookups/hits/misses/evictions/packs).
    pub fn cache_stats(&self) -> CacheStats {
        self.fast.borrow().stats
    }

    /// Publish the cache counters into a [`Registry`] under
    /// `{prefix}.model_cache.*`.
    pub fn export_cache_into(&self, reg: &Registry, prefix: &str) {
        self.cache_stats().export_into(reg, &format!("{prefix}.model_cache"));
    }

    /// Run one inference.  `image` is the raw u8 CHW input.
    pub fn run(&self, model: &DeployedModel, image: &[u8]) -> RunReport {
        self.run_inner(model, image, None)
    }

    /// Run one inference recording an execution trace (layer timeline,
    /// fusion decisions, DRAM transfers) — see [`crate::arch::trace`].
    pub fn run_traced(
        &self,
        model: &DeployedModel,
        image: &[u8],
    ) -> (RunReport, crate::arch::trace::Trace) {
        let mut trace = crate::arch::trace::Trace::default();
        let report = self.run_inner(model, image, Some(&mut trace));
        (report, trace)
    }

    /// Analytic per-candidate entry point for design-space exploration:
    /// charges the identical cycle, SRAM and DRAM counters as [`Chip::run`]
    /// without executing the datapath.  The counters are data-independent
    /// (they depend only on layer geometry, the hardware config and the
    /// fusion plan — asserted by `analyze_matches_run_counters`), so a
    /// candidate evaluates in microseconds instead of a full inference.
    /// No weights are needed: the plan comes straight from the
    /// [`ModelSpec`].  `logits` and per-layer spike counts are zero.
    pub fn analyze(&self, spec: &crate::config::models::ModelSpec) -> RunReport {
        let plans = crate::arch::schedule::plan_spec(spec);
        let groups = plan_fusion(&plans, &self.hw);
        let t_steps = spec.num_steps;

        let mut dram = Dram::default();
        let mut sram = SramAccesses::default();
        let mut layer_reports = Vec::with_capacity(plans.len());
        let mut cycles_total = 0u64;
        let mut pe_ops_total = 0u64;

        for (idx, plan) in plans.iter().enumerate() {
            let (fused_in, fused_out) = roles(&groups, idx);
            let dram_before = dram.total();
            layer_dram(plan, t_steps, fused_in, fused_out, true, &mut dram);
            let acc = layer_sram(plan, &self.hw, t_steps);
            sram.add(&acc);
            let cycles = plan.cycles(&self.hw, t_steps);
            cycles_total += cycles;
            let pe_ops = plan.pe_ops(&self.hw, t_steps);
            pe_ops_total += pe_ops;
            layer_reports.push(LayerReport {
                kind: plan.kind,
                cycles,
                utilization: plan.utilization(&self.hw, t_steps),
                spikes_emitted: 0,
                membrane_accesses: acc.membrane_rmw,
                pe_ops,
                dram_bytes: dram.total() - dram_before,
                sram: acc,
            });
        }

        let freq_hz = self.hw.freq_mhz * 1e6;
        let latency_us = cycles_total as f64 / freq_hz * 1e6;
        let gops = (2.0 * pe_ops_total as f64) / (cycles_total as f64 / freq_hz) / 1e9;
        let utilization =
            pe_ops_total as f64 / (cycles_total as f64 * self.hw.total_pes() as f64);

        RunReport {
            logits: Vec::new(),
            cycles: cycles_total,
            layers: layer_reports,
            dram,
            sram,
            pe_ops: pe_ops_total,
            latency_us,
            gops,
            utilization,
        }
    }

    fn run_inner(
        &self,
        model: &DeployedModel,
        image: &[u8],
        trace: Option<&mut crate::arch::trace::Trace>,
    ) -> RunReport {
        match self.mode {
            SimMode::Fast => self.run_batched(model, image, trace),
            SimMode::Exact => self.run_exact(model, image, trace),
        }
    }

    /// The time-batched fast datapath (PR5 tentpole): weights packed once
    /// per model (cached across a batch), each layer drives all T steps
    /// through the golden engine's `conv_t`-family / batched-matvec
    /// kernels out of the cached [`Scratch`] arena (zero steady-state
    /// allocation), the encoding layer fires in closed form from its
    /// single psum, pooling is fused into the IF fire write, and the
    /// readout accumulates its logits fused over the batched psum planes.
    /// Counters are charged by the identical schedule walk as the frozen
    /// per-step baseline ([`crate::baselines::chip_stepwise`]).
    fn run_batched(
        &self,
        model: &DeployedModel,
        image: &[u8],
        mut trace: Option<&mut crate::arch::trace::Trace>,
    ) -> RunReport {
        use crate::arch::trace::Event;
        let mut guard = self.fast.borrow_mut();
        guard.prepare(model, &self.hw);
        // Split borrows: the front (just-prepared) entry is read-only,
        // the scratch arena is mutable, and both live in the cache.
        let FastCache { entries, groups, scratch, .. } = &mut *guard;
        let entry = &entries[0];
        let t_steps = model.num_steps;

        let mut dram = Dram::default();
        let mut sram = SramAccesses::default();
        let mut layer_reports = Vec::with_capacity(entry.plans.len());
        let mut cycles_total = 0u64;
        let mut pe_ops_total = 0u64;
        let mut logits = vec![0i64; 10];

        // Inter-layer spike-train ping-pong buffers, reused across runs
        // (tick batching: the full T-step train of a layer is produced
        // before the next layer starts).  An encoding first layer ignores
        // `cur` and overwrites `nxt`; any other first layer must start
        // from the empty train the stepwise engine starts from, not a
        // previous run's leftovers.
        let mut cur = std::mem::take(&mut scratch.train_in);
        let mut nxt = std::mem::take(&mut scratch.train_out);
        if entry.plans.first().map_or(true, |p| p.kind != PlanKind::EncConv) {
            cur.clear();
        }

        for (idx, plan) in entry.plans.iter().enumerate() {
            let (fused_in, fused_out) = roles(groups, idx);
            // Per-category attribution is only needed when tracing; the
            // clone is off the untraced hot path.
            let dram_snapshot = if trace.is_some() { Some(dram.clone()) } else { None };
            let dram_before = dram.total();
            layer_dram(plan, t_steps, fused_in, fused_out, true, &mut dram);
            let acc = layer_sram(plan, &self.hw, t_steps);
            sram.add(&acc);
            let cycles = plan.cycles(&self.hw, t_steps);
            if let Some(tr) = trace.as_deref_mut() {
                push_layer_events(
                    tr,
                    idx,
                    plan,
                    groups,
                    cycles_total,
                    cycles_total + cycles,
                    dram_snapshot.as_ref().unwrap(),
                    &dram,
                );
            }
            cycles_total += cycles;
            let pe_ops = plan.pe_ops(&self.hw, t_steps);
            pe_ops_total += pe_ops;

            let layer = &model.layers[plan.model_index];
            let (fired, membrane_accesses) = match (&entry.packed[plan.model_index], layer) {
                (PackedLayer::Enc, Layer::Conv { c_out, c_in, k, w, bias, theta, .. }) => {
                    let (h, w_px) = (plan.h, plan.w);
                    let plane = c_out * h * w_px;
                    scratch.ensure_enc(plane);
                    // Conv once; the IF unit re-accumulates the same psum
                    // every step (§III-F), solved in closed form.
                    conv_multibit_into(
                        image,
                        *c_in,
                        h,
                        w_px,
                        w,
                        *c_out,
                        *k,
                        &mut scratch.enc_psum,
                    );
                    let (oh, ow) = if plan.pooled { (h / 2, w_px / 2) } else { (h, w_px) };
                    reset_train(&mut nxt, t_steps, *c_out, oh, ow);
                    let fires = if_fire_constant(
                        &scratch.enc_psum[..plane],
                        t_steps,
                        bias,
                        theta,
                        *c_out,
                        h,
                        w_px,
                        plan.pooled,
                        &mut scratch.v,
                        &mut nxt,
                    );
                    std::mem::swap(&mut cur, &mut nxt);
                    (fires, (t_steps * plane) as u64)
                }
                (PackedLayer::Conv(packed), Layer::Conv { c_out, bias, theta, .. }) => {
                    let (h, w_px) = (plan.h, plan.w);
                    let hw_px = h * w_px;
                    let plane = c_out * hw_px;
                    let steps = cur.len();
                    scratch.ensure_fused(steps, plane, hw_px);
                    let (oh, ow) = if plan.pooled { (h / 2, w_px / 2) } else { (h, w_px) };
                    reset_train(&mut nxt, steps, *c_out, oh, ow);
                    // Fused conv→IF→(pool): one output channel at a time,
                    // its T psum planes cache-resident, each tap's weight
                    // mask loaded once for all T steps.
                    let mut fires = 0u64;
                    if steps > 0 {
                        packed.tap_ones_t(&cur, &mut scratch.ones, &mut scratch.ones_sum);
                        for o in 0..*c_out {
                            packed.conv_channel_t(
                                &cur,
                                o,
                                &scratch.ones_sum[..steps * hw_px],
                                &mut scratch.chan_psum[..steps * hw_px],
                            );
                            fires += if_fire_channel(
                                &scratch.chan_psum[..steps * hw_px],
                                steps,
                                bias[o],
                                theta[o],
                                o,
                                h,
                                w_px,
                                plan.pooled,
                                &mut scratch.v[o * hw_px..(o + 1) * hw_px],
                                &mut nxt,
                            );
                        }
                    }
                    std::mem::swap(&mut cur, &mut nxt);
                    (fires, (steps * plane) as u64)
                }
                (PackedLayer::Fc(packed), Layer::Fc { n_out, bias, theta, .. }) => {
                    let n = *n_out;
                    let steps = flatten_and_matvec(packed, &cur, scratch);
                    reset_train(&mut nxt, steps, n, 1, 1);
                    let fires = if_fire_t(
                        &scratch.psums,
                        n,
                        steps,
                        bias,
                        theta,
                        n,
                        1,
                        1,
                        &mut scratch.v[..n],
                        &mut nxt,
                    );
                    std::mem::swap(&mut cur, &mut nxt);
                    (fires, (steps * n) as u64)
                }
                (PackedLayer::Readout(packed), Layer::Readout { n_out, .. }) => {
                    let n = *n_out;
                    let steps = flatten_and_matvec(packed, &cur, scratch);
                    // Fused readout: logits accumulate straight off the
                    // batched psum planes (no spike train materialized).
                    let mut lg = vec![0i64; n];
                    for t in 0..steps {
                        for (o, l) in lg.iter_mut().enumerate() {
                            *l += scratch.psums[t * n + o] as i64;
                        }
                    }
                    logits = lg;
                    (0, 0)
                }
                _ => unreachable!("plan/layer mismatch"),
            };

            if let Some(tr) = trace.as_deref_mut() {
                tr.push(Event::LayerEnd { layer: idx, cycle: cycles_total, spikes: fired });
            }
            layer_reports.push(LayerReport {
                kind: plan.kind,
                cycles,
                utilization: plan.utilization(&self.hw, t_steps),
                spikes_emitted: fired,
                membrane_accesses,
                pe_ops,
                dram_bytes: dram.total() - dram_before,
                sram: acc,
            });
        }

        // Hand the ping-pong buffers back for the next inference.
        scratch.train_in = cur;
        scratch.train_out = nxt;

        let freq_hz = self.hw.freq_mhz * 1e6;
        let latency_us = cycles_total as f64 / freq_hz * 1e6;
        let gops = (2.0 * pe_ops_total as f64) / (cycles_total as f64 / freq_hz) / 1e9;
        let utilization =
            pe_ops_total as f64 / (cycles_total as f64 * self.hw.total_pes() as f64);

        RunReport {
            logits,
            cycles: cycles_total,
            layers: layer_reports,
            dram,
            sram,
            pe_ops: pe_ops_total,
            latency_us,
            gops,
            utilization,
        }
    }

    /// The gate-level datapath (Exact mode): one time step at a time
    /// through the vectorwise PE schedule — the verification fidelity.
    fn run_exact(
        &self,
        model: &DeployedModel,
        image: &[u8],
        mut trace: Option<&mut crate::arch::trace::Trace>,
    ) -> RunReport {
        use crate::arch::trace::Event;
        let plans = plan_model(model);
        let groups = plan_fusion(&plans, &self.hw);
        let t_steps = model.num_steps;

        let mut dram = Dram::default();
        let mut sram = SramAccesses::default();
        let mut layer_reports = Vec::with_capacity(plans.len());
        let mut cycles_total = 0u64;
        let mut pe_ops_total = 0u64;

        // Inter-layer spike trains (tick batching: the full T-step train of
        // a layer is produced before the next layer starts).
        let mut spikes: Vec<SpikeMap> = Vec::new();
        let mut logits = vec![0i64; 10];

        for (idx, plan) in plans.iter().enumerate() {
            let (fused_in, fused_out) = roles(&groups, idx);
            let dram_snapshot = if trace.is_some() { Some(dram.clone()) } else { None };
            let dram_before = dram.total();
            layer_dram(plan, t_steps, fused_in, fused_out, true, &mut dram);
            let acc = layer_sram(plan, &self.hw, t_steps);
            sram.add(&acc);
            let cycles = plan.cycles(&self.hw, t_steps);
            if let Some(tr) = trace.as_deref_mut() {
                push_layer_events(
                    tr,
                    idx,
                    plan,
                    &groups,
                    cycles_total,
                    cycles_total + cycles,
                    dram_snapshot.as_ref().unwrap(),
                    &dram,
                );
            }
            cycles_total += cycles;
            let pe_ops = plan.pe_ops(&self.hw, t_steps);
            pe_ops_total += pe_ops;

            let layer = &model.layers[plan.model_index];
            let (new_spikes, fired, membrane_accesses, layer_logits) =
                self.run_layer_exact(plan, layer, image, &spikes, t_steps);
            if let Some(l) = layer_logits {
                logits = l;
            }
            spikes = new_spikes;
            if let Some(tr) = trace.as_deref_mut() {
                tr.push(Event::LayerEnd { layer: idx, cycle: cycles_total, spikes: fired });
            }

            layer_reports.push(LayerReport {
                kind: plan.kind,
                cycles,
                utilization: plan.utilization(&self.hw, t_steps),
                spikes_emitted: fired,
                membrane_accesses,
                pe_ops,
                dram_bytes: dram.total() - dram_before,
                sram: acc,
            });
        }

        let freq_hz = self.hw.freq_mhz * 1e6;
        let latency_us = cycles_total as f64 / freq_hz * 1e6;
        let gops = (2.0 * pe_ops_total as f64) / (cycles_total as f64 / freq_hz) / 1e9;
        let utilization =
            pe_ops_total as f64 / (cycles_total as f64 * self.hw.total_pes() as f64);

        RunReport {
            logits,
            cycles: cycles_total,
            layers: layer_reports,
            dram,
            sram,
            pe_ops: pe_ops_total,
            latency_us,
            gops,
            utilization,
        }
    }

    /// Execute one compute layer over all time steps through the PE-level
    /// datapath.  Returns (output spike train, spikes fired, membrane
    /// accesses, logits if this was the readout).
    #[allow(clippy::type_complexity)]
    fn run_layer_exact(
        &self,
        plan: &LayerPlan,
        layer: &Layer,
        image: &[u8],
        spikes_in: &[SpikeMap],
        t_steps: usize,
    ) -> (Vec<SpikeMap>, u64, u64, Option<Vec<i64>>) {
        match (plan.kind, layer) {
            (PlanKind::EncConv, Layer::Conv { c_out, k, w, bias, theta, .. }) => {
                let psum = self.exact_conv(plan, w, *k, |ch, y, x| {
                    // bitplane block: channel ch/planes, plane ch%planes
                    let planes = self.hw.encode_bitplanes;
                    let (c, p) = (ch / planes, ch % planes);
                    (image[(c * plan.h + y) * plan.w + x] >> p) & 1 == 1
                });
                let mut ifu = IfUnit::new(*c_out, plan.h * plan.w, bias, theta);
                let mut train = Vec::with_capacity(t_steps);
                for _ in 0..t_steps {
                    let fired = ifu.step(&psum);
                    train.push(plane_to_map(&fired, *c_out, plan.h, plan.w));
                }
                let out = maybe_pool(train, plan.pooled);
                let fired_total = ifu.fired;
                let acc = ifu.accesses;
                (out, fired_total, acc, None)
            }
            (PlanKind::Conv, Layer::Conv { c_out, k, w, bias, theta, .. }) => {
                let mut ifu = IfUnit::new(*c_out, plan.h * plan.w, bias, theta);
                let mut train = Vec::with_capacity(t_steps);
                for s in spikes_in {
                    let psum = self.exact_conv(plan, w, *k, |ch, y, x| s.get(ch, y, x));
                    let fired = ifu.step(&psum);
                    train.push(plane_to_map(&fired, *c_out, plan.h, plan.w));
                }
                let out = maybe_pool(train, plan.pooled);
                (out, ifu.fired, ifu.accesses, None)
            }
            (PlanKind::Fc, Layer::Fc { n_out, n_in, w, bias, theta }) => {
                let mut ifu = IfUnit::new(*n_out, 1, bias, theta);
                let mut train = Vec::with_capacity(t_steps);
                for s in spikes_in {
                    let psum = self.exact_fc(*n_out, *n_in, w, s);
                    let fired = ifu.step(&psum);
                    train.push(plane_to_map(&fired, *n_out, 1, 1));
                }
                (train, ifu.fired, ifu.accesses, None)
            }
            (PlanKind::Readout, Layer::Readout { n_out, n_in, w }) => {
                let mut logits = vec![0i64; *n_out];
                for s in spikes_in {
                    let psum = self.exact_fc(*n_out, *n_in, w, s);
                    for (l, p) in logits.iter_mut().zip(&psum) {
                        *l += *p as i64;
                    }
                }
                (Vec::new(), 0, 0, Some(logits))
            }
            _ => unreachable!("plan/layer mismatch"),
        }
    }

    /// Exact-mode convolution: drive the PE blocks through the vectorwise
    /// schedule (Fig. 5/6) and reduce through the accumulator + boundary
    /// SRAM.  `spike(ch_eff, y, x)` reads an effective input channel
    /// (bitplane-expanded for the encoding layer).
    fn exact_conv(
        &self,
        plan: &LayerPlan,
        weights: &[i8],
        k: usize,
        spike: impl Fn(usize, usize, usize) -> bool,
    ) -> Vec<i32> {
        let hw = &self.hw;
        let (h, w) = (plan.h, plan.w);
        let rows = hw.rows_per_array;
        let pad = k / 2;
        let c_in_eff = plan.c_in_effective(hw);
        let groups = plan.groups(hw);
        let tiles = plan.tiles(hw);
        let planes = hw.encode_bitplanes;
        let is_enc = plan.kind == PlanKind::EncConv;

        let array = PeArray::new(rows, k);
        let block = PeBlock::new(array, k);
        let diag = rows + k - 1;

        let mut psum = vec![0i32; plan.c_out * h * w];

        // Arena: every per-cycle buffer of the schedule walk is allocated
        // once here and reused — O(c_out * groups * tiles * w) cycles run
        // allocation-free, which makes Exact-mode pool workers viable.
        let mut block_psums: Vec<Vec<i32>> =
            (0..hw.pe_blocks).map(|_| vec![0i32; diag]).collect();
        let mut shifts: Vec<u32> = Vec::with_capacity(hw.pe_blocks);
        let mut columns: Vec<Vec<bool>> = (0..k).map(|_| vec![false; rows]).collect();
        let mut w_neg: Vec<Vec<bool>> = (0..k).map(|_| vec![false; k]).collect();
        let mut col: Vec<i32> = Vec::with_capacity(diag);

        for o in 0..plan.c_out {
            for g in 0..groups {
                let mut boundary = BoundaryBuffer::new(w);
                for tile in 0..tiles {
                    let y0 = tile * rows;
                    for x in 0..w {
                        shifts.clear();
                        let mut used = 0;
                        for b in 0..hw.pe_blocks {
                            let ch_eff = g * hw.pe_blocks + b;
                            if ch_eff >= c_in_eff {
                                break;
                            }
                            // weight channel: bitplanes share the weight of
                            // their source channel (Fig. 7).
                            let wch = if is_enc { ch_eff / planes } else { ch_eff };
                            // input columns consumed by the k arrays
                            for (a, column) in columns.iter_mut().enumerate() {
                                let xi = x as isize + a as isize - pad as isize;
                                for (r, slot) in column.iter_mut().enumerate() {
                                    let yi = y0 + r;
                                    *slot = if xi < 0 || xi >= w as isize || yi >= h {
                                        false
                                    } else {
                                        spike(ch_eff, yi, xi as usize)
                                    };
                                }
                            }
                            // weight sign columns: array a = kernel col kw=a,
                            // array row c = kernel row kh = k-1-c.
                            for (a, wn) in w_neg.iter_mut().enumerate() {
                                for (c, slot) in wn.iter_mut().enumerate() {
                                    let kh = k - 1 - c;
                                    *slot = weights
                                        [((o * plan.c_in + wch) * k + kh) * k + a]
                                        < 0;
                                }
                            }
                            block.cycle_into(&columns, &w_neg, &mut block_psums[used]);
                            shifts.push(if is_enc { (ch_eff % planes) as u32 } else { 0 });
                            used += 1;
                        }
                        reduce_blocks_into(&block_psums[..used], &shifts, &mut col);
                        debug_assert_eq!(col.len(), diag);
                        // scatter diagonals to output rows:
                        // oy = y0 + d - (k - 1) + pad
                        for (d, &v) in col.iter().enumerate() {
                            if v == 0 {
                                continue;
                            }
                            let oy = y0 as isize + d as isize - (k as isize - 1)
                                + pad as isize;
                            if oy >= 0 && (oy as usize) < h {
                                psum[(o * h + oy as usize) * w + x] += v;
                            } else {
                                // tile-seam partials captured by the
                                // boundary SRAM (counted, value folded when
                                // the neighbouring tile scatters).
                                boundary.store(x, 0, 0);
                            }
                        }
                    }
                }
            }
        }
        psum
    }

    /// Exact-mode fc: one PE block per input bit group member, 1x1 arrays.
    fn exact_fc(&self, n_out: usize, n_in: usize, w: &[i8], s: &SpikeMap) -> Vec<i32> {
        let dense = s.to_dense();
        assert_eq!(dense.len(), n_in, "fc input mismatch");
        let array = PeArray::new(1, 1);
        let block = PeBlock::new(array, 1);
        let mut out = vec![0i32; n_out];
        // Arena: one block-psum slot per PE block plus single-bit in/weight
        // columns, reused for every (output, group) cycle of the walk.
        let mut block_psums: Vec<Vec<i32>> =
            (0..self.hw.pe_blocks).map(|_| vec![0i32]).collect();
        let shifts = vec![0u32; self.hw.pe_blocks];
        let mut in_col = [vec![false]];
        let mut wn_col = [vec![false]];
        let mut col: Vec<i32> = Vec::with_capacity(1);
        for (o, out_o) in out.iter_mut().enumerate() {
            for (g, chunk) in dense.chunks(self.hw.pe_blocks).enumerate() {
                for (b, &bit) in chunk.iter().enumerate() {
                    let i = g * self.hw.pe_blocks + b;
                    in_col[0][0] = bit == 1;
                    wn_col[0][0] = w[o * n_in + i] < 0;
                    block.cycle_into(&in_col, &wn_col, &mut block_psums[b]);
                }
                reduce_blocks_into(
                    &block_psums[..chunk.len()],
                    &shifts[..chunk.len()],
                    &mut col,
                );
                *out_o += col[0];
            }
        }
        out
    }
}

/// Emit one layer's trace events (PR8): the fusion decision when this
/// layer opens a fused pair, the layer start, then per-category DRAM
/// transfers — reads stamped at the layer's start cycle, writes at its
/// end cycle, so a fused pair's skipped spike round-trip shows up as a
/// literal gap in the DRAM track.
#[allow(clippy::too_many_arguments)]
fn push_layer_events(
    tr: &mut crate::arch::trace::Trace,
    idx: usize,
    plan: &LayerPlan,
    groups: &[FusionGroup],
    start_cycle: u64,
    end_cycle: u64,
    dram_before: &Dram,
    dram_after: &Dram,
) {
    use crate::arch::trace::Event;
    if groups.iter().any(|g| g.len == 2 && g.start == idx) {
        tr.push(Event::Fused { first: idx, second: idx + 1, cycle: start_cycle });
    }
    tr.push(Event::LayerStart { layer: idx, kind: plan.kind, cycle: start_cycle });
    for (cat, read, write) in dram_after.delta(dram_before) {
        if read > 0 {
            tr.push(Event::DramTransfer {
                layer: idx,
                bytes: read,
                write: false,
                what: cat.name(),
                cycle: start_cycle,
            });
        }
        if write > 0 {
            tr.push(Event::DramTransfer {
                layer: idx,
                bytes: write,
                write: true,
                what: cat.name(),
                cycle: end_cycle,
            });
        }
    }
}

fn plane_to_map(fired: &[bool], c: usize, h: usize, w: usize) -> SpikeMap {
    let mut m = SpikeMap::zeros(c, h, w);
    for ch in 0..c {
        for y in 0..h {
            for x in 0..w {
                if fired[(ch * h + y) * w + x] {
                    m.set(ch, y, x, true);
                }
            }
        }
    }
    m
}

fn maybe_pool(train: Vec<SpikeMap>, pooled: bool) -> Vec<SpikeMap> {
    if pooled {
        train.iter().map(|s| s.maxpool2()).collect()
    } else {
        train
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::snn::conv::conv_multibit;
    use crate::snn::params::Kind;
    use crate::testing::{check, Gen};

    /// Random small conv layer: exact-mode PE psums == packed popcount conv.
    #[test]
    fn exact_conv_matches_packed() {
        check("exact conv vs packed", 25, |g: &mut Gen| {
            let c_in = *g.choose(&[1usize, 3, 16, 33]);
            let c_out = g.usize_in(1, 6);
            let hw_size = g.usize_in(3, 10);
            let weights = g.weights(c_out * c_in * 9);
            let mut sm = SpikeMap::zeros(c_in, hw_size, hw_size);
            for c in 0..c_in {
                for y in 0..hw_size {
                    for x in 0..hw_size {
                        sm.set(c, y, x, g.bool());
                    }
                }
            }
            let plan = LayerPlan {
                kind: PlanKind::Conv,
                c_in,
                c_out,
                k: 3,
                h: hw_size,
                w: hw_size,
                pooled: false,
                model_index: 0,
            };
            let chip = Chip::new(HwConfig::default(), SimMode::Exact);
            let exact = chip.exact_conv(&plan, &weights, 3, |ch, y, x| sm.get(ch, y, x));
            let packed = PackedConv::pack(c_out, c_in, 3, &weights).conv(&sm);
            assert_eq!(exact, packed);
        });
    }

    /// Exact-mode encoding conv == direct multi-bit conv (Fig. 7 identity
    /// through the real bitplane datapath).
    #[test]
    fn exact_encoding_matches_multibit() {
        check("exact encoding vs multibit", 15, |g: &mut Gen| {
            let c_in = g.usize_in(1, 3);
            let c_out = g.usize_in(1, 4);
            let hw_size = g.usize_in(3, 8);
            let weights = g.weights(c_out * c_in * 9);
            let image: Vec<u8> =
                (0..c_in * hw_size * hw_size).map(|_| g.i32_in(0, 255) as u8).collect();
            let plan = LayerPlan {
                kind: PlanKind::EncConv,
                c_in,
                c_out,
                k: 3,
                h: hw_size,
                w: hw_size,
                pooled: false,
                model_index: 0,
            };
            let chip = Chip::new(HwConfig::default(), SimMode::Exact);
            let planes = chip.hw.encode_bitplanes;
            let exact = chip.exact_conv(&plan, &weights, 3, |ch, y, x| {
                let (c, p) = (ch / planes, ch % planes);
                (image[(c * hw_size + y) * hw_size + x] >> p) & 1 == 1
            });
            let direct =
                conv_multibit(&image, c_in, hw_size, hw_size, &weights, c_out, 3);
            assert_eq!(exact, direct);
        });
    }

    pub(crate) fn micro_model(t: usize) -> DeployedModel {
        DeployedModel {
            name: "micro".into(),
            num_steps: t,
            in_channels: 1,
            in_size: 8,
            layers: vec![
                Layer::Conv {
                    kind: Kind::EncConv,
                    c_out: 4,
                    c_in: 1,
                    k: 3,
                    w: (0..36).map(|i| if i % 3 == 0 { -1 } else { 1 }).collect(),
                    bias: vec![0, 10, -10, 256],
                    theta: vec![256 * 100, 256 * 50, 256 * 200, 256 * 25],
                },
                Layer::MaxPool,
                Layer::Conv {
                    kind: Kind::Conv,
                    c_out: 3,
                    c_in: 4,
                    k: 3,
                    w: (0..108).map(|i| if i % 2 == 0 { 1 } else { -1 }).collect(),
                    bias: vec![0, 5, -5],
                    theta: vec![256, 512, 300],
                },
                Layer::Fc {
                    n_out: 6,
                    n_in: 3 * 4 * 4,
                    w: (0..288).map(|i| if i % 5 == 0 { -1 } else { 1 }).collect(),
                    bias: vec![0; 6],
                    theta: vec![256; 6],
                },
                Layer::Readout {
                    n_out: 10,
                    n_in: 6,
                    w: (0..60).map(|i| if i % 4 == 0 { 1 } else { -1 }).collect(),
                },
            ],
        }
    }

    /// Both sim modes produce bit-identical logits + identical counters,
    /// and both match the golden model.
    #[test]
    fn modes_agree_and_match_golden() {
        let model = micro_model(4);
        let image: Vec<u8> = (0..64).map(|i| (i * 37 % 256) as u8).collect();

        let fast = Chip::new(HwConfig::default(), SimMode::Fast).run(&model, &image);
        let exact = Chip::new(HwConfig::default(), SimMode::Exact).run(&model, &image);
        assert_eq!(fast.logits, exact.logits);
        assert_eq!(fast.cycles, exact.cycles);
        assert_eq!(fast.dram.total(), exact.dram.total());
        assert_eq!(fast.sram.total(), exact.sram.total());

        let golden = crate::snn::Network::new(model.clone());
        assert_eq!(fast.logits, golden.infer_u8(&image));
    }

    #[test]
    fn fusion_reduces_dram() {
        let model = micro_model(4);
        let image = vec![128u8; 64];
        let on = Chip::new(HwConfig::default(), SimMode::Fast).run(&model, &image);
        let off = Chip::new(
            HwConfig { layer_fusion: false, ..HwConfig::default() },
            SimMode::Fast,
        )
        .run(&model, &image);
        assert!(on.dram.total() < off.dram.total());
        assert_eq!(on.logits, off.logits); // fusion never changes results
        assert_eq!(on.cycles, off.cycles); // fusion is a bandwidth feature
    }

    /// The analytic DSE entry point charges exactly the counters a real
    /// (functional) run charges — on every Table-I preset and with fusion
    /// both on and off.
    #[test]
    fn analyze_matches_run_counters() {
        use crate::config::models;
        use crate::data::synth;
        use crate::snn::params::DeployedModel;
        for fusion in [true, false] {
            let hw = HwConfig { layer_fusion: fusion, ..HwConfig::default() };
            for (name, t) in [("tiny", 4), ("mnist", 8)] {
                let spec = models::by_name(name, t).unwrap();
                let model = DeployedModel::synthesize(&spec, 7);
                let img = &synth::for_model(name, 3, 0, 1)[0].image;
                let chip = Chip::new(hw.clone(), SimMode::Fast);
                let ran = chip.run(&model, img);
                let analyzed = chip.analyze(&spec);
                assert_eq!(analyzed.cycles, ran.cycles, "{name}: cycles");
                assert_eq!(analyzed.pe_ops, ran.pe_ops, "{name}: pe_ops");
                assert_eq!(analyzed.dram.total(), ran.dram.total(), "{name}: dram");
                assert_eq!(analyzed.sram.total(), ran.sram.total(), "{name}: sram");
                assert_eq!(analyzed.layers.len(), ran.layers.len());
                assert!((analyzed.latency_us - ran.latency_us).abs() < 1e-9);
                assert!((analyzed.utilization - ran.utilization).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn report_metrics_consistent() {
        let model = micro_model(2);
        let image = vec![200u8; 64];
        let r = Chip::new(HwConfig::default(), SimMode::Fast).run(&model, &image);
        assert!(r.cycles > 0);
        assert!(r.latency_us > 0.0);
        assert!(r.utilization > 0.0 && r.utilization <= 1.0);
        assert_eq!(r.layers.len(), 4);
        assert!(r.gops <= HwConfig::default().peak_gops());
    }

    /// The packed-model cache survives a batch of runs and a T
    /// reconfiguration, and invalidates on a weight change.
    #[test]
    fn packed_model_cached_across_runs() {
        let model = micro_model(4);
        let image: Vec<u8> = (0..64).map(|i| (i * 37 % 256) as u8).collect();
        let chip = Chip::new(HwConfig::default(), SimMode::Fast);
        assert_eq!(chip.pack_count(), 0);
        let first = chip.run(&model, &image);
        assert_eq!(chip.pack_count(), 1);
        for _ in 0..3 {
            assert_eq!(chip.run(&model, &image).logits, first.logits);
        }
        assert_eq!(chip.pack_count(), 1, "batch loop must not re-pack");

        // T is read live: reconfiguring steps reuses the packed weights
        // AND the cached run must match a fresh chip at the new T.
        let mut t6 = model.clone();
        t6.num_steps = 6;
        let cached_t6 = chip.run(&t6, &image);
        assert_eq!(chip.pack_count(), 1, "T change must not re-pack");
        let fresh_t6 = Chip::new(HwConfig::default(), SimMode::Fast).run(&t6, &image);
        assert_eq!(cached_t6.logits, fresh_t6.logits);
        assert_eq!(cached_t6.cycles, fresh_t6.cycles);
        assert_eq!(cached_t6.dram.total(), fresh_t6.dram.total());

        // bias/theta are read live too: an in-place threshold change must
        // not re-pack and must still match a fresh chip.
        let mut hot = model.clone();
        if let Layer::Conv { theta, .. } = &mut hot.layers[0] {
            theta[0] = 256 * 10;
        }
        let cached_hot = chip.run(&hot, &image);
        assert_eq!(chip.pack_count(), 1, "theta change must not re-pack");
        let fresh_hot = Chip::new(HwConfig::default(), SimMode::Fast).run(&hot, &image);
        assert_eq!(cached_hot.logits, fresh_hot.logits);

        // A weight flip is a different model: exactly one re-pack.
        let mut other = model.clone();
        if let Layer::Conv { w, .. } = &mut other.layers[0] {
            w[0] = -w[0];
        }
        let r_other = chip.run(&other, &image);
        assert_eq!(chip.pack_count(), 2);
        // And the re-packed weights are actually used (not stale).
        let fresh = Chip::new(HwConfig::default(), SimMode::Fast).run(&other, &image);
        assert_eq!(r_other.logits, fresh.logits);
    }

    /// Mutating the pub hw config between runs must not pair the cached
    /// packed model with a stale fusion plan (the plan is re-derived from
    /// the live hw every run; only the weights are cached).
    #[test]
    fn hw_mutation_rederives_fusion_plan() {
        let model = micro_model(4);
        let image = vec![128u8; 64];
        let mut chip = Chip::new(HwConfig::default(), SimMode::Fast);
        let fused = chip.run(&model, &image);
        chip.hw.layer_fusion = false;
        let unfused = chip.run(&model, &image);
        let fresh = Chip::new(
            HwConfig { layer_fusion: false, ..HwConfig::default() },
            SimMode::Fast,
        )
        .run(&model, &image);
        assert_eq!(unfused.dram.total(), fresh.dram.total());
        assert_eq!(unfused.logits, fresh.logits);
        assert!(fused.dram.total() < unfused.dram.total());
        assert_eq!(chip.pack_count(), 1, "an hw change needs no re-pack");
    }

    /// Two distinct tiny models + matching images for the LRU tests.
    fn two_models() -> (DeployedModel, Vec<u8>, DeployedModel, Vec<u8>) {
        use crate::testing::{models, Gen};
        let (a, img_a) = models::random_model_tiny(&mut Gen::new(0xA11C_E));
        let (b, img_b) = models::random_model_tiny(&mut Gen::new(0xB0B_5EED));
        (a, img_a, b, img_b)
    }

    /// Interleaved A/B/A traffic under capacity 2: both models stay
    /// resident, so the whole interleave packs exactly twice, and the
    /// counters balance (`hits + misses == lookups`, `packs == misses`).
    #[test]
    fn lru_capacity_two_holds_interleaved_models() {
        let (a, img_a, b, img_b) = two_models();
        let chip = Chip::with_cache_capacity(HwConfig::default(), SimMode::Fast, 2);
        let first_a = chip.run(&a, &img_a).logits;
        let first_b = chip.run(&b, &img_b).logits;
        for _ in 0..3 {
            assert_eq!(chip.run(&a, &img_a).logits, first_a);
            assert_eq!(chip.run(&b, &img_b).logits, first_b);
        }
        let s = chip.cache_stats();
        assert_eq!(s.packs, 2, "A/B/A under capacity 2 must pack twice total");
        assert_eq!(s.evictions, 0);
        assert_eq!(s.lookups, 8);
        assert_eq!(s.hits + s.misses, s.lookups);
        assert_eq!(s.packs, s.misses);
    }

    /// Capacity 1 thrashes on the same interleave: every switch is a
    /// miss+evict, with exact counts.
    #[test]
    fn lru_capacity_one_thrashes_with_exact_evictions() {
        let (a, img_a, b, img_b) = two_models();
        let chip = Chip::with_cache_capacity(HwConfig::default(), SimMode::Fast, 1);
        for _ in 0..3 {
            chip.run(&a, &img_a);
            chip.run(&b, &img_b);
        }
        let s = chip.cache_stats();
        assert_eq!(s.lookups, 6);
        assert_eq!(s.hits, 0, "capacity 1 never hits on an A/B interleave");
        assert_eq!(s.misses, 6);
        assert_eq!(s.packs, 6);
        assert_eq!(s.evictions, 5, "every pack after the first evicts");
    }

    /// A cached (LRU-hit) run is bit-identical to a fresh chip — eviction
    /// and re-pack never change results, across a randomized model pair.
    #[test]
    fn lru_cached_logits_bit_identical_to_fresh() {
        use crate::testing::{check, models, Gen};
        check("lru cached vs fresh", 10, |g: &mut Gen| {
            let (a, img_a) = models::random_model_tiny(g);
            let (b, img_b) = models::random_model_tiny(g);
            let chip = Chip::with_cache_capacity(HwConfig::default(), SimMode::Fast, 2);
            // Warm both, then hit both again out of the cache.
            chip.run(&a, &img_a);
            chip.run(&b, &img_b);
            let cached_a = chip.run(&a, &img_a);
            let cached_b = chip.run(&b, &img_b);
            let fresh_a = Chip::new(HwConfig::default(), SimMode::Fast).run(&a, &img_a);
            let fresh_b = Chip::new(HwConfig::default(), SimMode::Fast).run(&b, &img_b);
            assert_eq!(cached_a.logits, fresh_a.logits);
            assert_eq!(cached_b.logits, fresh_b.logits);
            assert_eq!(cached_a.cycles, fresh_a.cycles);
            assert_eq!(cached_b.cycles, fresh_b.cycles);
        });
    }

    /// The cache counters export through the telemetry registry.
    #[test]
    fn cache_counters_export_into_registry() {
        let (a, img_a, b, img_b) = two_models();
        let chip = Chip::with_cache_capacity(HwConfig::default(), SimMode::Fast, 1);
        chip.run(&a, &img_a);
        chip.run(&b, &img_b);
        chip.run(&a, &img_a);
        let reg = Registry::new();
        chip.export_cache_into(&reg, "sim");
        let snap = reg.snapshot();
        let text = snap.render_text();
        assert!(text.contains("sim.model_cache.lookups 3"), "got:\n{text}");
        assert!(text.contains("sim.model_cache.packs 3"), "got:\n{text}");
        assert!(text.contains("sim.model_cache.evictions 2"), "got:\n{text}");
    }
}

#[cfg(test)]
mod trace_tests {
    use super::tests::micro_model;
    use super::*;
    use crate::arch::trace::Event;

    #[test]
    fn traced_run_matches_untraced_and_logs_layers() {
        let model = micro_model(3);
        let image: Vec<u8> = (0..64).map(|i| (i * 11 % 256) as u8).collect();
        let chip = Chip::new(HwConfig::default(), SimMode::Fast);
        let plain = chip.run(&model, &image);
        let (traced, trace) = chip.run_traced(&model, &image);
        assert_eq!(plain.logits, traced.logits);
        assert_eq!(plain.cycles, traced.cycles);
        // 4 compute layers -> 4 starts + 4 ends + per-category dram +
        // fusion events
        let starts = trace
            .events()
            .iter()
            .filter(|e| matches!(e, Event::LayerStart { .. }))
            .count();
        assert_eq!(starts, 4);
        assert_eq!(trace.span_cycles(), traced.cycles);
        assert!(trace.render().contains("EncConv start"));
    }

    /// Every DRAM transfer is stamped inside its layer's cycle window
    /// (PR8 satellite: the events are placeable on a timeline).
    #[test]
    fn dram_events_fall_inside_their_layer_window() {
        let model = micro_model(3);
        let image: Vec<u8> = (0..64).map(|i| (i * 11 % 256) as u8).collect();
        let chip = Chip::new(HwConfig::default(), SimMode::Fast);
        let (_, trace) = chip.run_traced(&model, &image);
        let mut window = std::collections::HashMap::new();
        let mut open = std::collections::HashMap::new();
        for e in trace.events() {
            match e {
                Event::LayerStart { layer, cycle, .. } => {
                    open.insert(*layer, *cycle);
                }
                Event::LayerEnd { layer, cycle, .. } => {
                    window.insert(*layer, (open[layer], *cycle));
                }
                _ => {}
            }
        }
        let mut dram_events = 0;
        for e in trace.events() {
            if let Event::DramTransfer { layer, cycle, .. } = e {
                let (start, end) = window[layer];
                assert!(
                    *cycle >= start && *cycle <= end,
                    "L{layer} transfer at {cycle} outside [{start},{end}]"
                );
                dram_events += 1;
            }
        }
        assert!(dram_events > 0);
    }

    /// A fused pair leaves a gap in the DRAM timeline: the first layer
    /// writes no spike train out, the second reads none in (§IV-B made
    /// visible per-event, not just as a byte total).
    #[test]
    fn fused_pair_skips_the_spike_round_trip() {
        let model = micro_model(4);
        let image = vec![128u8; 64];
        let chip = Chip::new(HwConfig::default(), SimMode::Fast);
        let (_, trace) = chip.run_traced(&model, &image);
        let fused: Vec<(usize, usize)> = trace
            .events()
            .iter()
            .filter_map(|e| match e {
                Event::Fused { first, second, .. } => Some((*first, *second)),
                _ => None,
            })
            .collect();
        assert!(!fused.is_empty(), "micro model must fuse at least one pair");
        for &(first, second) in &fused {
            for e in trace.events() {
                if let Event::DramTransfer { layer, write, what, .. } = e {
                    assert!(
                        !(*layer == first && *write && *what == "spikes_out"),
                        "fused L{first} must not write its spike train"
                    );
                    assert!(
                        !(*layer == second && !*write && *what == "spikes_in"),
                        "fused L{second} must not read a spike train"
                    );
                }
            }
        }
        // And the fusion event itself is stamped at its pair's start.
        let unfused_chip = Chip::new(
            HwConfig { layer_fusion: false, ..HwConfig::default() },
            SimMode::Fast,
        );
        let (_, off) = unfused_chip.run_traced(&model, &image);
        let (first, second) = fused[0];
        let has = |tr: &crate::arch::trace::Trace, layer: usize, write: bool, what: &str| {
            tr.events().iter().any(|e| {
                matches!(e, Event::DramTransfer { layer: l, write: w, what: c, .. }
                    if *l == layer && *w == write && *c == what)
            })
        };
        assert!(has(&off, first, true, "spikes_out"), "unfused run writes the train");
        assert!(has(&off, second, false, "spikes_in"), "unfused run reads it back");
    }

    /// Per-layer report fields (PR8) reconcile with the run totals.
    #[test]
    fn layer_reports_sum_to_run_totals() {
        let model = micro_model(4);
        let image = vec![128u8; 64];
        for mode in [SimMode::Fast, SimMode::Exact] {
            let r = Chip::new(HwConfig::default(), mode).run(&model, &image);
            let pe: u64 = r.layers.iter().map(|l| l.pe_ops).sum();
            assert_eq!(pe, r.pe_ops);
            let dram: u64 = r.layers.iter().map(|l| l.dram_bytes).sum();
            assert_eq!(dram, r.dram.total());
            let mut sram = SramAccesses::default();
            for l in &r.layers {
                sram.add(&l.sram);
            }
            assert_eq!(sram.total(), r.sram.total());
        }
    }
}

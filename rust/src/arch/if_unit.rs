//! IF neuron unit (paper Fig. 1(b), §III-F).
//!
//! Receives convolution psums, accumulates them with the residual membrane
//! potential held in the membrane SRAM, compares against the per-channel
//! IF-BN threshold, fires and hard-resets.  Identical arithmetic to the
//! golden model (`V += FIXED_POINT * psum - bias; fire V >= theta`).

use crate::util::FIXED_POINT;

/// Membrane state + IF-BN parameters for one layer (all neurons).
#[derive(Debug, Clone)]
pub struct IfUnit {
    /// channels (bias/theta granularity)
    pub channels: usize,
    /// neurons per channel (H*W, or 1 for fc)
    pub per_channel: usize,
    bias: Vec<i32>,
    theta: Vec<i32>,
    v: Vec<i32>,
    /// membrane SRAM accesses (read+write pairs), for the energy model
    pub accesses: u64,
    /// total spikes fired
    pub fired: u64,
}

impl IfUnit {
    /// Fresh unit with zero membrane.
    pub fn new(channels: usize, per_channel: usize, bias: &[i32], theta: &[i32]) -> Self {
        assert_eq!(bias.len(), channels);
        assert_eq!(theta.len(), channels);
        assert!(theta.iter().all(|&t| t > 0), "theta must be positive");
        Self {
            channels,
            per_channel,
            bias: bias.to_vec(),
            theta: theta.to_vec(),
            v: vec![0; channels * per_channel],
            accesses: 0,
            fired: 0,
        }
    }

    /// Integrate one time step of psums (channel-major) and fire.
    /// Returns the 0/1 spike plane.
    pub fn step(&mut self, psums: &[i32]) -> Vec<bool> {
        assert_eq!(psums.len(), self.v.len());
        let mut out = vec![false; psums.len()];
        for c in 0..self.channels {
            let (b, th) = (self.bias[c], self.theta[c]);
            for i in c * self.per_channel..(c + 1) * self.per_channel {
                let pre = self.v[i] + FIXED_POINT * psums[i] - b;
                self.accesses += 1; // read-modify-write of the membrane SRAM
                if pre >= th {
                    out[i] = true;
                    self.v[i] = 0;
                    self.fired += 1;
                } else {
                    self.v[i] = pre;
                }
            }
        }
        out
    }

    /// Residual membrane (for golden-model cross-checks).
    pub fn residue(&self) -> &[i32] {
        &self.v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulate_fire_reset() {
        // theta = 10*FP, psum 3 per step, bias 0: fires at step 4 (V=12*FP).
        let mut u = IfUnit::new(1, 1, &[0], &[10 * FIXED_POINT]);
        let mut fires = Vec::new();
        for _ in 0..5 {
            fires.push(u.step(&[3])[0]);
        }
        assert_eq!(fires, vec![false, false, false, true, false]);
        assert_eq!(u.residue()[0], 3 * FIXED_POINT);
        assert_eq!(u.fired, 1);
    }

    #[test]
    fn bias_subtracts_each_step() {
        // bias = 2*FP, psum = 2 -> net zero: never fires.
        let mut u = IfUnit::new(1, 1, &[2 * FIXED_POINT], &[FIXED_POINT]);
        for _ in 0..10 {
            assert!(!u.step(&[2])[0]);
        }
        assert_eq!(u.residue()[0], 0);
    }

    #[test]
    fn per_channel_thresholds() {
        let mut u = IfUnit::new(2, 2, &[0, 0], &[FIXED_POINT, 100 * FIXED_POINT]);
        let spikes = u.step(&[1, 1, 1, 1]);
        assert_eq!(spikes, vec![true, true, false, false]);
    }

    #[test]
    #[should_panic(expected = "theta must be positive")]
    fn rejects_nonpositive_theta() {
        IfUnit::new(1, 1, &[0], &[0]);
    }
}

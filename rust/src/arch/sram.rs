//! On-chip SRAM models with access accounting (paper Fig. 2).
//!
//! The simulator does not store payloads in these models (the datapath
//! carries the data); an [`Sram`] tracks capacity and read/write traffic so
//! the energy model can charge per-access energy, and a [`PingPong`] pair
//! models the double-buffered spike / weight SRAMs.

/// A single SRAM bank.
#[derive(Debug, Clone)]
pub struct Sram {
    pub name: &'static str,
    pub capacity_bytes: usize,
    reads: u64,
    writes: u64,
    read_bytes: u64,
    write_bytes: u64,
    /// high-water mark of bytes resident (set by the scheduler)
    peak_bytes: usize,
}

impl Sram {
    /// New bank with `capacity_bytes` capacity.
    pub fn new(name: &'static str, capacity_bytes: usize) -> Self {
        Self {
            name,
            capacity_bytes,
            reads: 0,
            writes: 0,
            read_bytes: 0,
            write_bytes: 0,
            peak_bytes: 0,
        }
    }

    /// Record a read of `bytes`.
    #[inline]
    pub fn read(&mut self, bytes: usize) {
        self.reads += 1;
        self.read_bytes += bytes as u64;
    }

    /// Record a write of `bytes`.
    #[inline]
    pub fn write(&mut self, bytes: usize) {
        self.writes += 1;
        self.write_bytes += bytes as u64;
    }

    /// Track residency high-water mark; returns false on overflow.
    pub fn reserve(&mut self, bytes: usize) -> bool {
        self.peak_bytes = self.peak_bytes.max(bytes);
        bytes <= self.capacity_bytes
    }

    /// (reads, writes) access counts.
    pub fn accesses(&self) -> (u64, u64) {
        (self.reads, self.writes)
    }

    /// (read, write) byte totals.
    pub fn bytes(&self) -> (u64, u64) {
        (self.read_bytes, self.write_bytes)
    }

    /// Residency high-water mark.
    pub fn peak(&self) -> usize {
        self.peak_bytes
    }
}

/// Double-buffered SRAM pair: `front()` is consumed while `back()` is
/// filled; `swap()` flips the banks (spike ping-pong across time steps,
/// weight ping-pong across fused layers — paper §III-A).
#[derive(Debug, Clone)]
pub struct PingPong {
    banks: [Sram; 2],
    front: usize,
}

impl PingPong {
    /// Two equal banks of `capacity_bytes` each.
    pub fn new(name: &'static str, capacity_bytes: usize) -> Self {
        Self {
            banks: [Sram::new(name, capacity_bytes), Sram::new(name, capacity_bytes)],
            front: 0,
        }
    }

    /// The bank currently being read.
    pub fn front(&mut self) -> &mut Sram {
        &mut self.banks[self.front]
    }

    /// The bank currently being filled.
    pub fn back(&mut self) -> &mut Sram {
        &mut self.banks[1 - self.front]
    }

    /// Flip banks.
    pub fn swap(&mut self) {
        self.front = 1 - self.front;
    }

    /// Combined (reads, writes) across both banks.
    pub fn accesses(&self) -> (u64, u64) {
        let a = self.banks[0].accesses();
        let b = self.banks[1].accesses();
        (a.0 + b.0, a.1 + b.1)
    }

    /// Combined (read, write) bytes across both banks.
    pub fn bytes(&self) -> (u64, u64) {
        let a = self.banks[0].bytes();
        let b = self.banks[1].bytes();
        (a.0 + b.0, a.1 + b.1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_accesses() {
        let mut s = Sram::new("spike", 1024);
        s.read(4);
        s.read(4);
        s.write(8);
        assert_eq!(s.accesses(), (2, 1));
        assert_eq!(s.bytes(), (8, 8));
    }

    #[test]
    fn reserve_tracks_peak_and_overflow() {
        let mut s = Sram::new("weight", 100);
        assert!(s.reserve(60));
        assert!(s.reserve(40));
        assert_eq!(s.peak(), 60);
        assert!(!s.reserve(101));
        assert_eq!(s.peak(), 101);
    }

    #[test]
    fn pingpong_swaps() {
        let mut pp = PingPong::new("spike", 64);
        pp.front().read(1);
        pp.back().write(2);
        pp.swap();
        pp.front().write(2); // old back
        let (r, w) = pp.accesses();
        assert_eq!((r, w), (1, 2));
        let (rb, wb) = pp.bytes();
        assert_eq!((rb, wb), (1, 4));
    }
}

//! # VSA — Reconfigurable Vectorwise Spiking Neural Network Accelerator
//!
//! Full-system reproduction of Lien, Hsu & Chang, *"VSA: Reconfigurable
//! Vectorwise Spiking Neural Network Accelerator"*, ISCAS 2021
//! (10.1109/ISCAS51556.2021.9401181), as a three-layer Rust + JAX + Pallas
//! stack.  This crate is Layer 3: everything that runs at inference time.
//!
//! ## Crate map
//!
//! * [`util`] — bit vectors, deterministic PRNG (cross-language with the
//!   python compile path), statistics.
//! * [`config`] — hand-rolled JSON parser, hardware configuration, and the
//!   Table-I model presets.
//! * [`data`] — synthetic MNIST/CIFAR-like datasets (bit-identical to
//!   `python/compile/datasets.py`) and an IDX loader for real data.
//! * [`snn`] — the bit-exact functional golden model of the deployed
//!   binary-weight spiking network (integer semantics; the contract shared
//!   with the JAX model and the chip).
//! * [`train`] — in-repo STBP training: binary weights (straight-through
//!   estimator), IF-based BN folded into integer thresholds at export,
//!   producing the VSAW artifacts the golden model / chip / DSE consume.
//! * [`arch`] — the cycle-accurate VSA chip simulator: vectorwise PE
//!   blocks, three-stage accumulator, IF neuron unit, SRAM/DRAM hierarchy,
//!   tick batching, two-layer fusion, encoding bitplane mode.
//! * [`dse`] — design-space exploration: declarative search spaces over
//!   the `HwConfig` knobs, a multi-threaded analytic evaluator, and
//!   Pareto-frontier extraction over (throughput, power, area).
//! * [`energy`] — area (KGE) / power / energy model and the technology
//!   normalization used by paper Table III.
//! * [`baselines`] — SpinalFlow-style and BW-SNN-style comparison models.
//! * [`coordinator`] — the serving layer: model registry, request queue,
//!   batcher, heterogeneous worker pools, metrics and backpressure.
//! * [`telemetry`] — mergeable latency histogram sketches, per-request
//!   stage tracing, and the counter/gauge/sketch registry + exporters
//!   shared by serve, the chip sim, and the trainer.
//! * [`testing`] — a miniature property-based testing harness (the
//!   offline environment has no proptest).

pub mod arch;
pub mod baselines;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dse;
pub mod energy;
pub mod metrics;
pub mod snn;
pub mod telemetry;
pub mod testing;
pub mod train;
pub mod util;

//! BW-SNN-style behavioral model (Chuang et al., DAC'20 [4]).
//!
//! BW-SNN is a *fixed-function* five-conv-layer binary-weight SNN ASIC:
//! all weights live on chip (12.75 KB), there is no DRAM traffic in steady
//! state, and the pipeline shape is frozen at tape-out.  Its strength is
//! energy (103.14 TOPS/W normalized); its weaknesses are the fixed
//! topology and very low area efficiency — the contrast the paper draws.
//!
//! The model (a) checks whether a network *fits* the frozen pipeline, and
//! (b) for fitting networks charges fully-pipelined cycles at its clock.

use crate::snn::params::{DeployedModel, Layer};

/// BW-SNN-like design parameters (defaults = published design point).
#[derive(Debug, Clone)]
pub struct BwSnnConfig {
    /// Frozen number of conv layers.
    pub conv_layers: usize,
    /// Maximum on-chip weight storage (bits).
    pub weight_bits_capacity: u64,
    /// Maximum channels per layer the fixed datapath supports.
    pub max_channels: usize,
    pub freq_mhz: f64,
    /// MACs retired per cycle when streaming (fully pipelined array).
    pub macs_per_cycle: u64,
}

impl Default for BwSnnConfig {
    fn default() -> Self {
        Self {
            conv_layers: 5,
            weight_bits_capacity: 12 * 8 * 1024, // ~12 KB of the 12.75 total
            max_channels: 64,
            freq_mhz: 10.0,
            macs_per_cycle: 8208 / 2, // PEs retire a MAC every other cycle
        }
    }
}

/// Why a model cannot run on the fixed-function design.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Misfit {
    TooManyConvLayers { have: usize, max: usize },
    WeightsDontFit { bits: u64, capacity: u64 },
    TooManyChannels { have: usize, max: usize },
}

/// Fixed-function feasibility check — the reconfigurability contrast of
/// Table III ("fixed 5-CONV" vs "Yes").
pub fn fits(cfg: &BwSnnConfig, model: &DeployedModel) -> Result<(), Misfit> {
    let convs = model
        .layers
        .iter()
        .filter(|l| matches!(l, Layer::Conv { .. }))
        .count();
    if convs > cfg.conv_layers {
        return Err(Misfit::TooManyConvLayers { have: convs, max: cfg.conv_layers });
    }
    let mut bits = 0u64;
    let mut max_ch = 0usize;
    for l in &model.layers {
        match l {
            Layer::Conv { c_out, c_in, k, .. } => {
                bits += (c_out * c_in * k * k) as u64;
                max_ch = max_ch.max(*c_out);
            }
            Layer::Fc { n_out, n_in, .. } | Layer::Readout { n_out, n_in, .. } => {
                bits += (n_out * n_in) as u64;
            }
            Layer::MaxPool => {}
        }
    }
    if bits > cfg.weight_bits_capacity {
        return Err(Misfit::WeightsDontFit { bits, capacity: cfg.weight_bits_capacity });
    }
    if max_ch > cfg.max_channels {
        return Err(Misfit::TooManyChannels { have: max_ch, max: cfg.max_channels });
    }
    Ok(())
}

/// Streaming latency for a fitting model (microseconds).
pub fn latency_us(cfg: &BwSnnConfig, macs: u64) -> f64 {
    let cycles = macs.div_ceil(cfg.macs_per_cycle);
    cycles as f64 / (cfg.freq_mhz * 1e6) * 1e6
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::params::Kind;

    fn conv(c_out: usize, c_in: usize) -> Layer {
        Layer::Conv {
            kind: Kind::Conv,
            c_out,
            c_in,
            k: 3,
            w: vec![1; c_out * c_in * 9],
            bias: vec![0; c_out],
            theta: vec![1; c_out],
        }
    }

    fn model_with(layers: Vec<Layer>) -> DeployedModel {
        DeployedModel {
            name: "m".into(),
            num_steps: 4,
            in_channels: 1,
            in_size: 16,
            layers,
        }
    }

    #[test]
    fn small_net_fits() {
        let m = model_with(vec![conv(16, 1), conv(16, 16), conv(32, 16)]);
        assert!(fits(&BwSnnConfig::default(), &m).is_ok());
    }

    #[test]
    fn cifar_net_does_not_fit() {
        // 11 conv layers and 128..256 channels: rejected on every axis.
        let m = model_with(vec![
            conv(128, 3), conv(128, 128), conv(128, 128), conv(192, 128),
            conv(192, 192), conv(192, 192), conv(192, 192), conv(256, 192),
            conv(256, 256), conv(256, 256), conv(256, 256),
        ]);
        match fits(&BwSnnConfig::default(), &m) {
            Err(Misfit::TooManyConvLayers { have: 11, max: 5 }) => {}
            other => panic!("expected layer-count misfit, got {other:?}"),
        }
    }

    #[test]
    fn weight_capacity_enforced() {
        let m = model_with(vec![conv(64, 64), conv(64, 64)]);
        // 2 * 64*64*9 = 73728 bits < 98304 -> fits; triple it to overflow
        let m2 = model_with(vec![conv(64, 64), conv(64, 64), conv(64, 64)]);
        assert!(fits(&BwSnnConfig::default(), &m).is_ok());
        assert!(matches!(
            fits(&BwSnnConfig::default(), &m2),
            Err(Misfit::WeightsDontFit { .. })
        ));
    }

    #[test]
    fn latency_scales_with_macs() {
        let cfg = BwSnnConfig::default();
        assert!(latency_us(&cfg, 2_000_000) > latency_us(&cfg, 1_000_000));
    }
}

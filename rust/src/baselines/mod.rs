//! Comparison designs for Table III and the ablation benches.
//!
//! The paper's comparison columns quote published figures from
//! SpinalFlow [7] and BW-SNN [4] and normalize them to 40 nm / 0.9 V.
//! We carry those published specs verbatim ([`published`]) *and* implement
//! behavioral models of both dataflows ([`spinalflow`], [`bwsnn`]) so the
//! benches can demonstrate the paper's qualitative claims (elementwise
//! sparse processing throughput vs. vectorwise; fixed-function vs.
//! reconfigurable) on the same workloads.
//!
//! [`golden_stepwise`] is a *software* baseline: the pre-refactor
//! per-time-step golden engine, frozen as the measured reference point
//! for the time-batched hot path (see `bench_throughput` /
//! `BENCH_PR1.json`).  [`stbp_scalar`] plays the same role for the
//! trainer: the PR3 scalar STBP hot path, frozen as `bench_train`'s
//! baseline and the forward oracle of `rust/tests/train_parallel.rs`.
//! [`chip_stepwise`] is the chip-simulator twin: the pre-PR5 per-step
//! `SimMode::Fast` datapath (weights re-packed per image, one conv per
//! time step), frozen as `bench_throughput`'s chip baseline
//! (`BENCH_PR5.json`) and the counter-for-counter oracle of
//! `rust/tests/chip_batched.rs`.

pub mod bwsnn;
pub mod chip_stepwise;
pub mod golden_stepwise;
pub mod published;
pub mod spinalflow;
pub mod stbp_scalar;

//! SpinalFlow-style behavioral model (Narayanan et al., ISCA'20 [7]).
//!
//! SpinalFlow processes *sorted, elementwise-sparse* spike streams: each
//! input spike is fetched, its weight row is read, and the PEs accumulate
//! one spike x one output-neuron tile at a time.  Throughput therefore
//! scales with the **spike count** (input sparsity), not the dense MAC
//! count — excellent at extreme sparsity, but far below a dense vectorwise
//! fabric at SNN-typical firing rates, which is the comparison the paper
//! draws in §IV-B ("lower throughput and power efficiency due to their
//! element wise sparse processing").
//!
//! The model charges, per layer and time step:
//! `cycles = spikes_in * ceil(C_out / PEs)` (each spike broadcasts its
//! weight column to a PE tile accumulating C_out partial sums), plus a
//! per-step sort/merge pass over the input spikes.

use crate::snn::params::{DeployedModel, Layer};
use crate::snn::spikemap::SpikeMap;
use crate::snn::Network;
use crate::util::ceil_div;

/// SpinalFlow-like design parameters (defaults = published design point).
#[derive(Debug, Clone)]
pub struct SpinalFlowConfig {
    pub pes: usize,
    pub freq_mhz: f64,
    /// Cycles per input spike per PE-tile pass (weight fetch + MAC).
    pub cycles_per_spike: f64,
    /// Sorting/merge overhead per input spike.
    pub sort_overhead: f64,
}

impl Default for SpinalFlowConfig {
    fn default() -> Self {
        Self {
            pes: 128,
            freq_mhz: 200.0,
            cycles_per_spike: 1.0,
            sort_overhead: 0.25,
        }
    }
}

/// Outcome of a SpinalFlow-style run.
#[derive(Debug, Clone)]
pub struct SpinalFlowReport {
    pub cycles: u64,
    pub latency_us: f64,
    pub total_spikes: u64,
    /// Effective throughput counting the dense-equivalent MACs (2 ops).
    pub effective_gops: f64,
}

/// Run the elementwise model over the same network + input.  Uses the
/// golden model for the functional spike trains (the dataflow changes
/// *when* work happens, not the results).
pub fn run(cfg: &SpinalFlowConfig, model: &DeployedModel, image: &[u8]) -> SpinalFlowReport {
    let net = Network::new(model.clone());
    let (_, trace) = net.infer_traced(image);

    let mut cycles = 0f64;
    let mut total_spikes = 0u64;
    let mut dense_macs = 0u64;

    // Layer l consumes the spike train emitted by layer l-1; the encoding
    // layer consumes the multi-bit image (SpinalFlow's 8-bit datapath
    // treats every nonzero pixel as a "spike" with payload).
    let mut li = 0usize; // index into trace.spike_trains
    for layer in &model.layers {
        match layer {
            Layer::Conv { c_out, c_in, k, .. } => {
                let (spikes_in, h, w): (u64, usize, usize) = if li == 0 {
                    let nz = image.iter().filter(|&&p| p > 0).count() as u64;
                    (nz, model.in_size, model.in_size)
                } else {
                    let train: &Vec<SpikeMap> = &trace.spike_trains[li - 1];
                    (
                        train.iter().map(|s| s.total_spikes()).sum(),
                        train[0].height(),
                        train[0].width(),
                    )
                };
                total_spikes += spikes_in;
                // each spike touches k*k output columns x C_out channels,
                // tiled over the PE array
                let tile_passes = ceil_div(*c_out * k * k, cfg.pes) as f64;
                cycles +=
                    spikes_in as f64 * (cfg.cycles_per_spike * tile_passes + cfg.sort_overhead);
                dense_macs += (*c_out * *c_in * k * k * h * w) as u64
                    * model.num_steps as u64;
                li += 1;
            }
            Layer::MaxPool => {
                li += 1;
            }
            Layer::Fc { n_out, n_in, .. } | Layer::Readout { n_out, n_in, .. } => {
                let train = &trace.spike_trains[li - 1];
                let spikes_in: u64 = train.iter().map(|s| s.total_spikes()).sum();
                total_spikes += spikes_in;
                let tile_passes = ceil_div(*n_out, cfg.pes) as f64;
                cycles +=
                    spikes_in as f64 * (cfg.cycles_per_spike * tile_passes + cfg.sort_overhead);
                dense_macs += (*n_out * *n_in) as u64 * model.num_steps as u64;
                if matches!(layer, Layer::Fc { .. }) {
                    li += 1;
                }
            }
        }
    }

    let cycles = cycles.ceil() as u64;
    let latency_us = cycles as f64 / (cfg.freq_mhz * 1e6) * 1e6;
    let effective_gops = 2.0 * dense_macs as f64 / (latency_us * 1e-6) / 1e9;
    SpinalFlowReport {
        cycles,
        latency_us,
        total_spikes,
        effective_gops,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::params::Kind;

    fn model() -> DeployedModel {
        DeployedModel {
            name: "sf".into(),
            num_steps: 4,
            in_channels: 1,
            in_size: 8,
            layers: vec![
                Layer::Conv {
                    kind: Kind::EncConv,
                    c_out: 8,
                    c_in: 1,
                    k: 3,
                    w: vec![1; 72],
                    bias: vec![0; 8],
                    theta: vec![256 * 60; 8],
                },
                Layer::Readout { n_out: 10, n_in: 512, w: vec![1; 5120] },
            ],
        }
    }

    #[test]
    fn sparser_inputs_run_faster() {
        let cfg = SpinalFlowConfig::default();
        let dense_img = vec![200u8; 64];
        let mut sparse_img = vec![0u8; 64];
        sparse_img[0] = 200;
        sparse_img[32] = 180;
        let dense = run(&cfg, &model(), &dense_img);
        let sparse = run(&cfg, &model(), &sparse_img);
        assert!(sparse.cycles < dense.cycles);
        assert!(sparse.total_spikes < dense.total_spikes);
    }

    #[test]
    fn vectorwise_beats_elementwise_at_typical_rates() {
        // The paper's §IV-B claim: at SNN-typical firing rates the dense
        // vectorwise design has (much) higher effective throughput.
        let cfg = SpinalFlowConfig::default();
        let img: Vec<u8> = (0..64).map(|i| (i * 4) as u8).collect();
        let sf = run(&cfg, &model(), &img);
        let vsa = crate::arch::Chip::new(
            crate::config::HwConfig::default(),
            crate::arch::SimMode::Fast,
        )
        .run(&model(), &img);
        let vsa_gops = 2.0 * vsa.pe_ops as f64 / (vsa.latency_us * 1e-6) / 1e9;
        assert!(
            vsa_gops > sf.effective_gops,
            "vsa {vsa_gops} vs spinalflow {}",
            sf.effective_gops
        );
    }
}

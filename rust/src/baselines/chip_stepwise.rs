//! The pre-refactor per-time-step chip fast mode, frozen as a baseline.
//!
//! This is the `SimMode::Fast` datapath [`crate::arch::Chip`] shipped with
//! before the temporal-batching rewrite (PR5): every layer re-packs its
//! weights per image (`PackedConv::pack` / `PackedFc::pack` inside
//! `run_layer`), spiking layers convolve one time step at a time (each
//! step re-walks the whole weight set — the per-step re-fetch cost the
//! paper's tick batching, §III-A, exists to remove), and psums /
//! fired-flags / spike maps are freshly allocated `Vec`s each step.  The
//! timing, SRAM and DRAM counters are charged by the identical schedule
//! walk as the live simulator, so a [`RunReport`] from this engine must be
//! field-for-field equal to one from the time-batched fast mode.
//!
//! It is kept (a) as the *measured baseline* for `bench_throughput`'s
//! chip before/after rows (`BENCH_PR5.json`) and (b) as the in-test
//! oracle of `rust/tests/chip_batched.rs`.
//!
//! Do not optimize this module; its value is being the fixed reference
//! point.

use crate::arch::chip::{LayerReport, RunReport};
use crate::arch::dram::Dram;
use crate::arch::fusion::{plan_fusion, roles};
use crate::arch::if_unit::IfUnit;
use crate::arch::schedule::{layer_dram, layer_sram, plan_model, LayerPlan, PlanKind, SramAccesses};
use crate::config::HwConfig;
use crate::snn::conv::{conv_multibit, PackedConv, PackedFc};
use crate::snn::params::{DeployedModel, Layer};
use crate::snn::spikemap::SpikeMap;

/// The pre-refactor per-step chip fast mode.
pub struct StepwiseChip {
    pub hw: HwConfig,
}

impl StepwiseChip {
    /// New stepwise chip at the given config (fast fidelity only).
    pub fn new(hw: HwConfig) -> Self {
        Self { hw }
    }

    /// Run one inference.  `image` is the raw u8 CHW input.
    pub fn run(&self, model: &DeployedModel, image: &[u8]) -> RunReport {
        let plans = plan_model(model);
        let groups = plan_fusion(&plans, &self.hw);
        let t_steps = model.num_steps;

        let mut dram = Dram::default();
        let mut sram = SramAccesses::default();
        let mut layer_reports = Vec::with_capacity(plans.len());
        let mut cycles_total = 0u64;
        let mut pe_ops_total = 0u64;

        // Inter-layer spike trains (tick batching: the full T-step train of
        // a layer is produced before the next layer starts).
        let mut spikes: Vec<SpikeMap> = Vec::new();
        let mut logits = vec![0i64; 10];

        for (idx, plan) in plans.iter().enumerate() {
            let (fused_in, fused_out) = roles(&groups, idx);
            let dram_before = dram.total();
            layer_dram(plan, t_steps, fused_in, fused_out, true, &mut dram);
            let acc = layer_sram(plan, &self.hw, t_steps);
            sram.add(&acc);
            let cycles = plan.cycles(&self.hw, t_steps);
            cycles_total += cycles;
            let pe_ops = plan.pe_ops(&self.hw, t_steps);
            pe_ops_total += pe_ops;

            let layer = &model.layers[plan.model_index];
            let (new_spikes, fired, membrane_accesses, layer_logits) =
                Self::run_layer(plan, layer, image, &spikes, t_steps);
            if let Some(l) = layer_logits {
                logits = l;
            }
            spikes = new_spikes;

            layer_reports.push(LayerReport {
                kind: plan.kind,
                cycles,
                utilization: plan.utilization(&self.hw, t_steps),
                spikes_emitted: fired,
                membrane_accesses,
                pe_ops,
                dram_bytes: dram.total() - dram_before,
                sram: acc,
            });
        }

        let freq_hz = self.hw.freq_mhz * 1e6;
        let latency_us = cycles_total as f64 / freq_hz * 1e6;
        let gops = (2.0 * pe_ops_total as f64) / (cycles_total as f64 / freq_hz) / 1e9;
        let utilization =
            pe_ops_total as f64 / (cycles_total as f64 * self.hw.total_pes() as f64);

        RunReport {
            logits,
            cycles: cycles_total,
            layers: layer_reports,
            dram,
            sram,
            pe_ops: pe_ops_total,
            latency_us,
            gops,
            utilization,
        }
    }

    /// Execute one compute layer over all time steps (the frozen per-step
    /// fast datapath).  Returns (output spike train, spikes fired,
    /// membrane accesses, logits if this was the readout).
    #[allow(clippy::type_complexity)]
    fn run_layer(
        plan: &LayerPlan,
        layer: &Layer,
        image: &[u8],
        spikes_in: &[SpikeMap],
        t_steps: usize,
    ) -> (Vec<SpikeMap>, u64, u64, Option<Vec<i64>>) {
        match (plan.kind, layer) {
            (PlanKind::EncConv, Layer::Conv { c_out, c_in, k, w, bias, theta, .. }) => {
                let psum = conv_multibit(image, *c_in, plan.h, plan.w, w, *c_out, *k);
                let mut ifu = IfUnit::new(*c_out, plan.h * plan.w, bias, theta);
                let mut train = Vec::with_capacity(t_steps);
                for _ in 0..t_steps {
                    let fired = ifu.step(&psum);
                    train.push(plane_to_map(&fired, *c_out, plan.h, plan.w));
                }
                let out = maybe_pool(train, plan.pooled);
                let fired_total = ifu.fired;
                let acc = ifu.accesses;
                (out, fired_total, acc, None)
            }
            (PlanKind::Conv, Layer::Conv { c_out, c_in, k, w, bias, theta, .. }) => {
                let packed = PackedConv::pack(*c_out, *c_in, *k, w);
                let mut ifu = IfUnit::new(*c_out, plan.h * plan.w, bias, theta);
                let mut train = Vec::with_capacity(t_steps);
                for s in spikes_in {
                    let psum = packed.conv(s);
                    let fired = ifu.step(&psum);
                    train.push(plane_to_map(&fired, *c_out, plan.h, plan.w));
                }
                let out = maybe_pool(train, plan.pooled);
                (out, ifu.fired, ifu.accesses, None)
            }
            (PlanKind::Fc, Layer::Fc { n_out, n_in, w, bias, theta }) => {
                let packed = PackedFc::pack(*n_out, *n_in, w);
                let mut ifu = IfUnit::new(*n_out, 1, bias, theta);
                let mut train = Vec::with_capacity(t_steps);
                for s in spikes_in {
                    let psum = packed.matvec(&s.to_flat_words());
                    let fired = ifu.step(&psum);
                    train.push(plane_to_map(&fired, *n_out, 1, 1));
                }
                (train, ifu.fired, ifu.accesses, None)
            }
            (PlanKind::Readout, Layer::Readout { n_out, n_in, w }) => {
                let packed = PackedFc::pack(*n_out, *n_in, w);
                let mut logits = vec![0i64; *n_out];
                for s in spikes_in {
                    let psum = packed.matvec(&s.to_flat_words());
                    for (l, p) in logits.iter_mut().zip(&psum) {
                        *l += *p as i64;
                    }
                }
                (Vec::new(), 0, 0, Some(logits))
            }
            _ => unreachable!("plan/layer mismatch"),
        }
    }
}

fn plane_to_map(fired: &[bool], c: usize, h: usize, w: usize) -> SpikeMap {
    let mut m = SpikeMap::zeros(c, h, w);
    for ch in 0..c {
        for y in 0..h {
            for x in 0..w {
                if fired[(ch * h + y) * w + x] {
                    m.set(ch, y, x, true);
                }
            }
        }
    }
    m
}

fn maybe_pool(train: Vec<SpikeMap>, pooled: bool) -> Vec<SpikeMap> {
    if pooled {
        train.iter().map(|s| s.maxpool2()).collect()
    } else {
        train
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::models;
    use crate::data::synth;
    use crate::snn::Network;

    #[test]
    fn stepwise_chip_matches_golden_on_tiny() {
        let model = crate::snn::params::DeployedModel::synthesize(&models::tiny(4), 11);
        let chip = StepwiseChip::new(HwConfig::default());
        let net = Network::new(model.clone());
        for s in synth::tiny_like(5, 0, 3) {
            assert_eq!(chip.run(&model, &s.image).logits, net.infer_u8(&s.image));
        }
    }
}

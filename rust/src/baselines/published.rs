//! Published figures of the comparison designs (paper Table III sources:
//! SpinalFlow, ISCA'20 [7]; BW-SNN, DAC'20 [4]).

use crate::energy::report::DesignRow;
use crate::energy::tech;

/// SpinalFlow column as printed in Table III.
pub fn spinalflow_row() -> DesignRow {
    DesignRow {
        name: "SpinalFlow [7]".into(),
        tech_nm: 28.0,
        voltage: None,
        freq_mhz: Some(200.0),
        reconfigurable: "Yes".into(),
        precision: "8 fixed".into(),
        pe_number: 128,
        sram_kb: 585.0,
        peak_gops: 51.2,
        area_kge: None,
        area_eff: None,
        area_eff_norm: None,
        core_power_mw: Some(162.4),
        power_eff_tops_w: Some(0.315),
        power_eff_norm: None, // the paper leaves this cell "-"
    }
}

/// BW-SNN column as printed in Table III (with footnote normalizations).
pub fn bwsnn_row() -> DesignRow {
    let area_eff = 0.286;
    let power_eff = 103.14;
    DesignRow {
        name: "BW-SNN [4]".into(),
        tech_nm: 90.0,
        voltage: Some(0.6),
        freq_mhz: Some(10.0),
        reconfigurable: "fixed 5-CONV".into(),
        precision: "binary".into(),
        pe_number: 8208,
        sram_kb: 12.75,
        peak_gops: 64.46,
        area_kge: Some(225.0),
        area_eff: Some(area_eff),
        area_eff_norm: Some(tech::area_eff_to_40nm(area_eff, 90.0)),
        core_power_mw: Some(0.625),
        power_eff_tops_w: Some(power_eff),
        power_eff_norm: Some(tech::power_eff_to_40nm_0v9(power_eff, 90.0, 0.6)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spinalflow_matches_paper() {
        let r = spinalflow_row();
        assert_eq!(r.pe_number, 128);
        assert_eq!(r.peak_gops, 51.2);
        assert_eq!(r.core_power_mw, Some(162.4));
    }

    #[test]
    fn bwsnn_normalizations_match_footnotes() {
        let r = bwsnn_row();
        // footnote 1: 0.286 -> 0.644 at 40nm
        assert!((r.area_eff_norm.unwrap() - 0.644).abs() < 0.01);
        // footnote 2: 103.14 unchanged after 40nm/0.9V normalization
        assert!((r.power_eff_norm.unwrap() - 103.14).abs() < 0.5);
    }
}

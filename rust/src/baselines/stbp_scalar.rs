//! The PR3 scalar STBP trainer, frozen verbatim as a baseline — the
//! training-side analogue of [`super::golden_stepwise`].
//!
//! This is the pre-PR4 hot path: unblocked conv/matmul inner loops, the
//! encoding layer materializing T identical psum copies, `sign_vec`
//! re-run for every layer in `backward`, the readout backward looping
//! its T identical per-step products, and single-threaded BN.  It
//! exists for two jobs:
//!
//! * **measured baseline** — `bench_train` times one training step here
//!   against the PR4 path (`BENCH_PR4.json` rows; the acceptance bar is
//!   >= 3x steps/sec on the mnist model at 4 threads);
//! * **forward oracle** — PR4's forward restructure (blocked kernels,
//!   broadcast psums, cached binarized weights, sharded BN) is
//!   *bit-exact* by construction, and `rust/tests/train_parallel.rs`
//!   asserts logits and every spike train against this frozen code.
//!   (The backward is *not* bit-identical: PR4 re-groups the weight
//!   gradient reductions — per-shard buffers, summed-over-T readout —
//!   which is deterministic but rounds differently.)
//!
//! Only the training configuration PR3 benched is frozen: hard spikes,
//! binarized weights, batch-statistics BN.  Everything here operates on
//! the live [`Net`] so baseline and current trainer share one
//! parameter state.

use crate::train::binarize::sign_vec;
use crate::train::ifbn::{BnCache, IfBn, BN_EPS, V_TH};
use crate::train::stbp::{LayerGrads, Net, TrainLayer};

/// PR3's rectangular-surrogate half-width (== `stbp::SURR_HALF`).
const SURR_HALF: f32 = 0.5;

/// Per-layer caches of one scalar forward pass.
#[derive(Debug, Clone, Default)]
pub struct ScalarCache {
    pub spikes: Vec<f32>,
    pub v_pre: Vec<f32>,
    pub bn: BnCache,
    pub c: usize,
    pub h: usize,
    pub w: usize,
}

/// Everything one scalar forward pass produces.
pub struct ScalarForward {
    pub logits: Vec<f32>,
    pub batch: usize,
    pub caches: Vec<ScalarCache>,
}

/// PR3 training forward: hard spikes, binarized weights, batch-stat BN.
pub fn forward(net: &Net, images: &[f32], batch: usize) -> ScalarForward {
    let t_steps = net.spec.num_steps;
    let (mut h, mut w) = (net.spec.in_size, net.spec.in_size);
    assert_eq!(images.len(), batch * net.spec.in_channels * h * w, "image geometry");
    let mut caches: Vec<ScalarCache> = Vec::with_capacity(net.layers.len());
    let mut logits: Option<Vec<f32>> = None;

    for ly in &net.layers {
        match ly {
            TrainLayer::Conv { enc: true, c_out, c_in, k, w: wts, bn } => {
                let wb = sign_vec(wts);
                let hw = h * w;
                let f = c_out * hw;
                let mut y = vec![0.0f32; batch * f];
                conv2d_same(images, batch, *c_in, h, w, &wb, *c_out, *k, &mut y);
                let bn_cache = bn_normalize_train(bn, &mut y, batch, hw);
                // PR3: the shared psum plane was copied T times.
                let mut psums = vec![0.0f32; t_steps * batch * f];
                for t in 0..t_steps {
                    psums[t * batch * f..(t + 1) * batch * f].copy_from_slice(&y);
                }
                let mut spikes = vec![0.0f32; t_steps * batch * f];
                let mut v_pre = vec![0.0f32; t_steps * batch * f];
                if_forward(&psums, t_steps, batch * f, &mut spikes, &mut v_pre);
                caches.push(ScalarCache { spikes, v_pre, bn: bn_cache, c: *c_out, h, w });
            }
            TrainLayer::Conv { enc: false, c_out, c_in, k, w: wts, bn } => {
                let wb = sign_vec(wts);
                let hw = h * w;
                let f = c_out * hw;
                let n = t_steps * batch;
                let x_in = &caches.last().expect("conv input").spikes;
                let mut y = vec![0.0f32; n * f];
                conv2d_same(x_in, n, *c_in, h, w, &wb, *c_out, *k, &mut y);
                let bn_cache = bn_normalize_train(bn, &mut y, n, hw);
                let mut spikes = vec![0.0f32; n * f];
                let mut v_pre = vec![0.0f32; n * f];
                if_forward(&y, t_steps, batch * f, &mut spikes, &mut v_pre);
                caches.push(ScalarCache { spikes, v_pre, bn: bn_cache, c: *c_out, h, w });
            }
            TrainLayer::MaxPool => {
                let prev = caches.last().expect("pool input");
                let (c, oh, ow) = (prev.c, h / 2, w / 2);
                let n = t_steps * batch;
                let mut spikes = vec![0.0f32; n * c * oh * ow];
                maxpool2(&prev.spikes, n, c, h, w, &mut spikes);
                h = oh;
                w = ow;
                caches.push(ScalarCache { spikes, c, h, w, ..ScalarCache::default() });
            }
            TrainLayer::Fc { n_out, n_in, w: wts, bn } => {
                let wb = sign_vec(wts);
                let n = t_steps * batch;
                let x_in = &caches.last().expect("fc input").spikes;
                let mut y = vec![0.0f32; n * n_out];
                matmul_nt(x_in, n, *n_in, &wb, *n_out, &mut y);
                let bn_cache = bn_normalize_train(bn, &mut y, n, 1);
                let mut spikes = vec![0.0f32; n * n_out];
                let mut v_pre = vec![0.0f32; n * n_out];
                if_forward(&y, t_steps, batch * n_out, &mut spikes, &mut v_pre);
                h = 1;
                w = 1;
                caches.push(ScalarCache { spikes, v_pre, bn: bn_cache, c: *n_out, h, w });
            }
            TrainLayer::Readout { n_out, n_in, w: wts } => {
                let wb = sign_vec(wts);
                let n = t_steps * batch;
                let x_in = &caches.last().expect("readout input").spikes;
                let mut y = vec![0.0f32; n * n_out];
                matmul_nt(x_in, n, *n_in, &wb, *n_out, &mut y);
                let mut lg = vec![0.0f32; batch * n_out];
                for t in 0..t_steps {
                    for (l, &v) in lg.iter_mut().zip(&y[t * batch * n_out..]) {
                        *l += v;
                    }
                }
                logits = Some(lg);
                caches.push(ScalarCache::default());
                break;
            }
        }
    }
    ScalarForward {
        logits: logits.expect("network has no readout layer"),
        batch,
        caches,
    }
}

/// PR3 backward: `sign_vec` re-run per weight layer, readout gradients
/// accumulated per time step.
pub fn backward(
    net: &Net,
    fwd: &ScalarForward,
    images: &[f32],
    dlogits: &[f32],
) -> Vec<LayerGrads> {
    let t_steps = net.spec.num_steps;
    let batch = fwd.batch;
    let mut grads: Vec<LayerGrads> =
        net.layers.iter().map(|_| LayerGrads::default()).collect();
    let mut d_spikes: Vec<f32> = Vec::new();

    for li in (0..net.layers.len()).rev() {
        let cache = &fwd.caches[li];
        let x_in_spikes = if li > 0 { Some(&fwd.caches[li - 1].spikes) } else { None };
        match &net.layers[li] {
            TrainLayer::Readout { n_out, n_in, w: wts } => {
                let wb = sign_vec(wts);
                let x_in = x_in_spikes.expect("readout has an input layer");
                let mut dw = vec![0.0f32; wts.len()];
                let mut dx = vec![0.0f32; t_steps * batch * n_in];
                for t in 0..t_steps {
                    matmul_nt_grads(
                        &x_in[t * batch * n_in..(t + 1) * batch * n_in],
                        batch,
                        *n_in,
                        &wb,
                        *n_out,
                        dlogits,
                        &mut dx[t * batch * n_in..(t + 1) * batch * n_in],
                        &mut dw,
                    );
                }
                grads[li].w = dw;
                d_spikes = dx;
            }
            TrainLayer::Fc { n_out, n_in, w: wts, bn } => {
                let wb = sign_vec(wts);
                let x_in = x_in_spikes.expect("fc has an input layer");
                if_backward(&mut d_spikes, &cache.spikes, &cache.v_pre, t_steps, batch * n_out);
                let n = t_steps * batch;
                let mut dgamma = vec![0.0f32; *n_out];
                let mut dbeta = vec![0.0f32; *n_out];
                bn_backward(bn, &cache.bn, &mut d_spikes, n, 1, &mut dgamma, &mut dbeta);
                let mut dw = vec![0.0f32; wts.len()];
                let mut dx = vec![0.0f32; n * n_in];
                matmul_nt_grads(x_in, n, *n_in, &wb, *n_out, &d_spikes, &mut dx, &mut dw);
                grads[li] = LayerGrads { w: dw, gamma: dgamma, beta: dbeta };
                d_spikes = dx;
            }
            TrainLayer::MaxPool => {
                let prev = &fwd.caches[li - 1];
                let n = t_steps * batch;
                let mut dx = vec![0.0f32; n * prev.c * prev.h * prev.w];
                maxpool2_grads(
                    &prev.spikes,
                    n,
                    prev.c,
                    prev.h,
                    prev.w,
                    &cache.spikes,
                    &d_spikes,
                    &mut dx,
                );
                d_spikes = dx;
            }
            TrainLayer::Conv { enc, c_out, c_in, k, w: wts, bn } => {
                let wb = sign_vec(wts);
                let (h, w) = (cache.h, cache.w);
                let hw = h * w;
                let m = batch * c_out * hw;
                if_backward(&mut d_spikes, &cache.spikes, &cache.v_pre, t_steps, m);
                let mut dgamma = vec![0.0f32; *c_out];
                let mut dbeta = vec![0.0f32; *c_out];
                let mut dw = vec![0.0f32; wts.len()];
                if *enc {
                    let bf = batch * c_out * hw;
                    let mut dy = vec![0.0f32; bf];
                    for t in 0..t_steps {
                        for (d, &g) in dy.iter_mut().zip(&d_spikes[t * bf..(t + 1) * bf]) {
                            *d += g;
                        }
                    }
                    bn_backward(bn, &cache.bn, &mut dy, batch, hw, &mut dgamma, &mut dbeta);
                    let mut dx = vec![0.0f32; batch * c_in * hw];
                    conv2d_same_grads(
                        images, batch, *c_in, h, w, &wb, *c_out, *k, &dy, &mut dx, &mut dw,
                    );
                    d_spikes = Vec::new();
                } else {
                    let n = t_steps * batch;
                    let x_in = x_in_spikes.expect("conv has an input layer");
                    bn_backward(bn, &cache.bn, &mut d_spikes, n, hw, &mut dgamma, &mut dbeta);
                    let mut dx = vec![0.0f32; n * c_in * hw];
                    conv2d_same_grads(
                        x_in, n, *c_in, h, w, &wb, *c_out, *k, &d_spikes, &mut dx, &mut dw,
                    );
                    d_spikes = dx;
                }
                grads[li] = LayerGrads { w: dw, gamma: dgamma, beta: dbeta };
            }
        }
    }
    grads
}

/// PR3 post-step EMA update (same arithmetic as `Net::apply_bn_ema`).
pub fn apply_bn_ema(net: &mut Net, fwd: &ScalarForward) {
    for (ly, cache) in net.layers.iter_mut().zip(&fwd.caches) {
        match ly {
            TrainLayer::Conv { bn, .. } | TrainLayer::Fc { bn, .. } => {
                if !cache.bn.mu_b.is_empty() {
                    bn.ema_update(&cache.bn);
                }
            }
            TrainLayer::MaxPool | TrainLayer::Readout { .. } => {}
        }
    }
}

// ---- frozen PR3 kernels ------------------------------------------------

fn if_forward(psums: &[f32], t_steps: usize, m: usize, spikes: &mut [f32], v_pre: &mut [f32]) {
    assert_eq!(psums.len(), t_steps * m, "psum geometry");
    let mut v_res = vec![0.0f32; m];
    for t in 0..t_steps {
        let ps = &psums[t * m..(t + 1) * m];
        let sp = &mut spikes[t * m..(t + 1) * m];
        let vp = &mut v_pre[t * m..(t + 1) * m];
        for j in 0..m {
            let pre = v_res[j] + ps[j];
            let o = if pre >= V_TH { 1.0 } else { 0.0 };
            v_res[j] = pre * (1.0 - o);
            sp[j] = o;
            vp[j] = pre;
        }
    }
}

fn if_backward(d_spikes: &mut [f32], spikes: &[f32], v_pre: &[f32], t_steps: usize, m: usize) {
    let mut g_vres = vec![0.0f32; m];
    for t in (0..t_steps).rev() {
        let base = t * m;
        for j in 0..m {
            let vp = v_pre[base + j];
            let g_o = d_spikes[base + j] - g_vres[j] * vp;
            let window = if (vp - V_TH).abs() < SURR_HALF { 1.0 } else { 0.0 };
            let g = g_vres[j] * (1.0 - spikes[base + j]) + g_o * window;
            d_spikes[base + j] = g;
            g_vres[j] = g;
        }
    }
}

fn bn_normalize_train(bn: &IfBn, x: &mut [f32], n: usize, s: usize) -> BnCache {
    let c = bn.channels();
    assert_eq!(x.len(), n * c * s, "bn input geometry");
    let cnt = (n * s) as f64;
    let mut mu_b = vec![0.0f32; c];
    let mut var_b = vec![0.0f32; c];
    let mut sigma = vec![0.0f32; c];
    for ch in 0..c {
        let mut sum = 0.0f64;
        let mut sumsq = 0.0f64;
        for r in 0..n {
            let plane = &x[(r * c + ch) * s..(r * c + ch + 1) * s];
            for &v in plane {
                sum += v as f64;
                sumsq += v as f64 * v as f64;
            }
        }
        let m = sum / cnt;
        let v = (sumsq / cnt - m * m).max(0.0);
        mu_b[ch] = m as f32;
        var_b[ch] = v as f32;
        sigma[ch] = ((v + BN_EPS).sqrt()) as f32;
    }
    let mut xn = vec![0.0f32; x.len()];
    for r in 0..n {
        for ch in 0..c {
            let base = (r * c + ch) * s;
            let (m, sg) = (mu_b[ch], sigma[ch]);
            let (g, b) = (bn.gamma[ch], bn.beta[ch]);
            for j in 0..s {
                let z = (x[base + j] - m) / sg;
                xn[base + j] = z;
                x[base + j] = g * z + b;
            }
        }
    }
    BnCache { xn, sigma, mu_b, var_b }
}

fn bn_backward(
    bn: &IfBn,
    cache: &BnCache,
    dy: &mut [f32],
    n: usize,
    s: usize,
    dgamma: &mut [f32],
    dbeta: &mut [f32],
) {
    let c = bn.channels();
    let cnt = (n * s) as f64;
    for ch in 0..c {
        let mut sum_dy = 0.0f64;
        let mut sum_dyxn = 0.0f64;
        for r in 0..n {
            let base = (r * c + ch) * s;
            for j in 0..s {
                let g = dy[base + j] as f64;
                sum_dy += g;
                sum_dyxn += g * cache.xn[base + j] as f64;
            }
        }
        dgamma[ch] = sum_dyxn as f32;
        dbeta[ch] = sum_dy as f32;
        let mean_dy = (sum_dy / cnt) as f32;
        let mean_dyxn = (sum_dyxn / cnt) as f32;
        let scale = bn.gamma[ch] / cache.sigma[ch];
        for r in 0..n {
            let base = (r * c + ch) * s;
            for j in 0..s {
                dy[base + j] = scale
                    * (dy[base + j] - mean_dy - cache.xn[base + j] * mean_dyxn);
            }
        }
    }
}

fn conv2d_same(
    x: &[f32],
    n: usize,
    c_in: usize,
    h: usize,
    w: usize,
    wts: &[f32],
    c_out: usize,
    k: usize,
    out: &mut [f32],
) {
    let pad = (k / 2) as isize;
    let hw = h * w;
    out.fill(0.0);
    for img in 0..n {
        let xin = &x[img * c_in * hw..(img + 1) * c_in * hw];
        let xout = &mut out[img * c_out * hw..(img + 1) * c_out * hw];
        for o in 0..c_out {
            for i in 0..c_in {
                let plane = &xin[i * hw..(i + 1) * hw];
                for kh in 0..k {
                    for kw in 0..k {
                        let wv = wts[((o * c_in + i) * k + kh) * k + kw];
                        let dy = kh as isize - pad;
                        let dx = kw as isize - pad;
                        let y0 = (-dy).max(0) as usize;
                        let y1 = (h as isize - dy).clamp(0, h as isize) as usize;
                        let x0 = (-dx).max(0) as usize;
                        let x1 = (w as isize - dx).clamp(0, w as isize) as usize;
                        for y in y0..y1 {
                            let src = ((y as isize + dy) as usize) * w;
                            let dst = o * hw + y * w;
                            for xx in x0..x1 {
                                xout[dst + xx] +=
                                    wv * plane[src + (xx as isize + dx) as usize];
                            }
                        }
                    }
                }
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn conv2d_same_grads(
    x: &[f32],
    n: usize,
    c_in: usize,
    h: usize,
    w: usize,
    wts: &[f32],
    c_out: usize,
    k: usize,
    dy: &[f32],
    dx: &mut [f32],
    dw: &mut [f32],
) {
    let pad = (k / 2) as isize;
    let hw = h * w;
    dx.fill(0.0);
    dw.fill(0.0);
    for img in 0..n {
        let xin = &x[img * c_in * hw..(img + 1) * c_in * hw];
        let dyi = &dy[img * c_out * hw..(img + 1) * c_out * hw];
        let dxi = &mut dx[img * c_in * hw..(img + 1) * c_in * hw];
        for o in 0..c_out {
            let dplane = &dyi[o * hw..(o + 1) * hw];
            for i in 0..c_in {
                let plane = &xin[i * hw..(i + 1) * hw];
                let gplane = &mut dxi[i * hw..(i + 1) * hw];
                for kh in 0..k {
                    for kw in 0..k {
                        let widx = ((o * c_in + i) * k + kh) * k + kw;
                        let wv = wts[widx];
                        let dyk = kh as isize - pad;
                        let dxk = kw as isize - pad;
                        let y0 = (-dyk).max(0) as usize;
                        let y1 = (h as isize - dyk).clamp(0, h as isize) as usize;
                        let x0 = (-dxk).max(0) as usize;
                        let x1 = (w as isize - dxk).clamp(0, w as isize) as usize;
                        let mut acc = 0.0f32;
                        for y in y0..y1 {
                            let src = ((y as isize + dyk) as usize) * w;
                            let dst = y * w;
                            for xx in x0..x1 {
                                let xi = src + (xx as isize + dxk) as usize;
                                let g = dplane[dst + xx];
                                acc += g * plane[xi];
                                gplane[xi] += g * wv;
                            }
                        }
                        dw[widx] += acc;
                    }
                }
            }
        }
    }
}

fn matmul_nt(x: &[f32], n: usize, n_in: usize, wts: &[f32], n_out: usize, out: &mut [f32]) {
    for r in 0..n {
        let xi = &x[r * n_in..(r + 1) * n_in];
        let oi = &mut out[r * n_out..(r + 1) * n_out];
        for (o, ov) in oi.iter_mut().enumerate() {
            let wr = &wts[o * n_in..(o + 1) * n_in];
            let mut acc = 0.0f32;
            for (a, b) in xi.iter().zip(wr) {
                acc += a * b;
            }
            *ov = acc;
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn matmul_nt_grads(
    x: &[f32],
    n: usize,
    n_in: usize,
    wts: &[f32],
    n_out: usize,
    dy: &[f32],
    dx: &mut [f32],
    dw: &mut [f32],
) {
    dx.fill(0.0);
    for r in 0..n {
        let xi = &x[r * n_in..(r + 1) * n_in];
        let dyi = &dy[r * n_out..(r + 1) * n_out];
        let dxi = &mut dx[r * n_in..(r + 1) * n_in];
        for (o, &g) in dyi.iter().enumerate() {
            if g == 0.0 {
                continue;
            }
            let wr = &wts[o * n_in..(o + 1) * n_in];
            let dwr = &mut dw[o * n_in..(o + 1) * n_in];
            for j in 0..n_in {
                dxi[j] += g * wr[j];
                dwr[j] += g * xi[j];
            }
        }
    }
}

fn maxpool2(x: &[f32], n: usize, c: usize, h: usize, w: usize, out: &mut [f32]) {
    let (oh, ow) = (h / 2, w / 2);
    for m in 0..n * c {
        let xi = &x[m * h * w..(m + 1) * h * w];
        let oi = &mut out[m * oh * ow..(m + 1) * oh * ow];
        for y in 0..oh {
            for xx in 0..ow {
                let base = 2 * y * w + 2 * xx;
                let v = xi[base]
                    .max(xi[base + 1])
                    .max(xi[base + w])
                    .max(xi[base + w + 1]);
                oi[y * ow + xx] = v;
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn maxpool2_grads(
    x: &[f32],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    pooled: &[f32],
    dy: &[f32],
    dx: &mut [f32],
) {
    let (oh, ow) = (h / 2, w / 2);
    dx.fill(0.0);
    for m in 0..n * c {
        let xi = &x[m * h * w..(m + 1) * h * w];
        let pi = &pooled[m * oh * ow..(m + 1) * oh * ow];
        let di = &dy[m * oh * ow..(m + 1) * oh * ow];
        let gi = &mut dx[m * h * w..(m + 1) * h * w];
        for y in 0..oh {
            for xx in 0..ow {
                let j = y * ow + xx;
                let base = 2 * y * w + 2 * xx;
                let top = pi[j];
                for off in [0, 1, w, w + 1] {
                    if xi[base + off] == top {
                        gi[base + off] += di[j];
                        break;
                    }
                }
            }
        }
    }
}

//! The pre-refactor per-time-step golden engine, frozen as a software
//! baseline.
//!
//! This is the inference loop the golden [`crate::snn::Network`] shipped
//! with before the time-batched rewrite (PR1): every time step re-walks
//! the layer's weights, psums / fired-flags / spike maps are freshly
//! allocated `Vec`s, the encoding psum is cloned T times, and fired
//! booleans round-trip through `Vec<bool>` before being re-packed into
//! `SpikeMap`s.  It is kept (a) as the *measured baseline* for
//! `bench_throughput`'s before/after numbers — the software analogue of
//! the elementwise-vs-vectorwise comparison the paper draws in §IV-B —
//! and (b) as a bit-exactness oracle for the fused hot path in property
//! tests.
//!
//! Do not optimize this module; its value is being the fixed reference
//! point.

use crate::snn::conv::{conv_multibit, PackedConv, PackedFc};
use crate::snn::params::{DeployedModel, Kind, Layer};
use crate::snn::spikemap::SpikeMap;
use crate::util::FIXED_POINT;

enum Prepared {
    EncConv {
        c_out: usize,
        c_in: usize,
        k: usize,
        w: Vec<i8>,
        bias: Vec<i32>,
        theta: Vec<i32>,
    },
    Conv {
        packed: PackedConv,
        bias: Vec<i32>,
        theta: Vec<i32>,
    },
    MaxPool,
    Fc {
        packed: PackedFc,
        bias: Vec<i32>,
        theta: Vec<i32>,
    },
    Readout {
        packed: PackedFc,
    },
}

/// The pre-refactor per-step golden engine.
pub struct StepwiseGolden {
    pub model: DeployedModel,
    prepared: Vec<Prepared>,
}

impl StepwiseGolden {
    /// Pack a deployed model (same preparation as the hot-path engine).
    pub fn new(model: DeployedModel) -> Self {
        let prepared = model
            .layers
            .iter()
            .map(|ly| match ly {
                Layer::Conv { kind: Kind::EncConv, c_out, c_in, k, w, bias, theta } => {
                    Prepared::EncConv {
                        c_out: *c_out,
                        c_in: *c_in,
                        k: *k,
                        w: w.clone(),
                        bias: bias.clone(),
                        theta: theta.clone(),
                    }
                }
                Layer::Conv { c_out, c_in, k, w, bias, theta, .. } => Prepared::Conv {
                    packed: PackedConv::pack(*c_out, *c_in, *k, w),
                    bias: bias.clone(),
                    theta: theta.clone(),
                },
                Layer::MaxPool => Prepared::MaxPool,
                Layer::Fc { n_out, n_in, w, bias, theta } => Prepared::Fc {
                    packed: PackedFc::pack(*n_out, *n_in, w),
                    bias: bias.clone(),
                    theta: theta.clone(),
                },
                Layer::Readout { n_out, n_in, w } => Prepared::Readout {
                    packed: PackedFc::pack(*n_out, *n_in, w),
                },
            })
            .collect();
        Self { model, prepared }
    }

    /// IF dynamics over per-step psums: `V += FP * psum - bias`, fire at
    /// `V >= theta`, hard reset.  Returns (spikes per step, final residue).
    fn if_fire(
        psums_per_t: &[Vec<i32>],
        bias: &[i32],
        theta: &[i32],
        c: usize,
        hw: usize,
    ) -> (Vec<Vec<bool>>, Vec<i32>) {
        let n = c * hw;
        let mut v = vec![0i32; n];
        let mut spikes = Vec::with_capacity(psums_per_t.len());
        for psum in psums_per_t {
            debug_assert_eq!(psum.len(), n);
            let mut fired = vec![false; n];
            for ch in 0..c {
                let (b, th) = (bias[ch], theta[ch]);
                for i in ch * hw..(ch + 1) * hw {
                    let pre = v[i] + FIXED_POINT * psum[i] - b;
                    if pre >= th {
                        fired[i] = true;
                        v[i] = 0;
                    } else {
                        v[i] = pre;
                    }
                }
            }
            spikes.push(fired);
        }
        (spikes, v)
    }

    /// Inference on a raw u8 CHW image; returns the integer logits.
    pub fn infer_u8(&self, image: &[u8]) -> Vec<i64> {
        let t_steps = self.model.num_steps;
        let (mut h, mut w) = (self.model.in_size, self.model.in_size);
        assert_eq!(
            image.len(),
            self.model.in_channels * h * w,
            "image geometry mismatch"
        );

        let mut spikes: Vec<SpikeMap> = Vec::new();

        for prep in &self.prepared {
            match prep {
                Prepared::EncConv { c_out, c_in, k, w: wts, bias, theta } => {
                    let psum = conv_multibit(image, *c_in, h, w, wts, *c_out, *k);
                    let psums: Vec<Vec<i32>> = (0..t_steps).map(|_| psum.clone()).collect();
                    let (fired, _residue) = Self::if_fire(&psums, bias, theta, *c_out, h * w);
                    spikes = fired
                        .iter()
                        .map(|f| bools_to_map(f, *c_out, h, w))
                        .collect();
                }
                Prepared::Conv { packed, bias, theta } => {
                    let psums: Vec<Vec<i32>> =
                        spikes.iter().map(|s| packed.conv(s)).collect();
                    let (fired, _residue) =
                        Self::if_fire(&psums, bias, theta, packed.c_out, h * w);
                    spikes = fired
                        .iter()
                        .map(|f| bools_to_map(f, packed.c_out, h, w))
                        .collect();
                }
                Prepared::MaxPool => {
                    spikes = spikes.iter().map(|s| s.maxpool2()).collect();
                    h /= 2;
                    w /= 2;
                }
                Prepared::Fc { packed, bias, theta } => {
                    let psums: Vec<Vec<i32>> = spikes
                        .iter()
                        .map(|s| packed.matvec(&s.to_flat_words()))
                        .collect();
                    let (fired, _residue) =
                        Self::if_fire(&psums, bias, theta, packed.n_out, 1);
                    spikes = fired
                        .iter()
                        .map(|f| bools_to_map(f, packed.n_out, 1, 1))
                        .collect();
                    h = 1;
                    w = 1;
                }
                Prepared::Readout { packed } => {
                    let mut logits = vec![0i64; packed.n_out];
                    for s in &spikes {
                        for (o, p) in packed.matvec(&s.to_flat_words()).iter().enumerate() {
                            logits[o] += *p as i64;
                        }
                    }
                    return logits;
                }
            }
        }
        panic!("network has no readout layer");
    }
}

fn bools_to_map(fired: &[bool], c: usize, h: usize, w: usize) -> SpikeMap {
    let mut m = SpikeMap::zeros(c, h, w);
    for ch in 0..c {
        for y in 0..h {
            for x in 0..w {
                if fired[(ch * h + y) * w + x] {
                    m.set(ch, y, x, true);
                }
            }
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::models;
    use crate::data::synth;
    use crate::snn::Network;

    #[test]
    fn stepwise_matches_hot_path_on_tiny() {
        let model = DeployedModel::synthesize(&models::tiny(4), 11);
        let stepwise = StepwiseGolden::new(model.clone());
        let net = Network::new(model);
        for s in synth::tiny_like(5, 0, 4) {
            assert_eq!(stepwise.infer_u8(&s.image), net.infer_u8(&s.image));
        }
    }
}

//! `vsa` — the launcher for the VSA reproduction.
//!
//! ```text
//! vsa models                                   # Table I structures
//! vsa simulate --model cifar10 [--mode fast|exact] [--no-fusion]
//! vsa table3   [--model cifar10]               # Table III report
//! vsa fusion   [--model cifar10]               # §IV-B DRAM study
//! vsa dse      --space small --workload mnist  # Pareto design sweep
//! vsa infer    --engine golden|chip --model mnist --count 8
//! vsa serve    --model mnist --model tiny --pool golden:2,chip-sim:1
//! vsa serve-bench --model tiny --fault-rate 0.1 --requests 512
//! vsa train    --model tiny --dataset synth --epochs 6 --seed 7
//! vsa eval     --weights artifacts/tiny_t4_trained.vsaw [--steps T]
//! vsa metrics-diff base.json now.json --max-regress 20
//! vsa selftest                                 # cross-layer consistency
//! ```

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use vsa::arch::schedule::plan_model;
use vsa::arch::{timeline, Chip, SimMode, DEFAULT_MODEL_CACHE};
use vsa::baselines::published;
use vsa::cli::Args;
use vsa::config::json::{self, Json};
use vsa::config::{models, HwConfig};
use vsa::dse;
use vsa::coordinator::{
    parse_pool, run_load, ChipEngine, Coordinator, CoordinatorConfig, EngineKind, FaultEngine,
    FaultProfile, FaultStats, GoldenEngine, InferenceEngine, LoadSpec, ModelRegistry, ModelTraffic,
    ServeError,
};
use vsa::data::synth;
use vsa::energy::{power, report};
use vsa::data::idx;
use vsa::snn::params::DeployedModel;
use vsa::snn::Network;
use vsa::telemetry::{diff_snapshots, Registry, SpanCollector};
use vsa::train;
use vsa::util::stats::argmax;

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let result = match args.command.as_str() {
        "models" => cmd_models(),
        "simulate" => cmd_simulate(&args),
        "table3" => cmd_table3(&args),
        "fusion" => cmd_fusion(&args),
        "dse" => cmd_dse(&args),
        "infer" => cmd_infer(&args),
        "serve" => cmd_serve(&args),
        "serve-bench" => cmd_serve_bench(&args),
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "metrics-diff" => cmd_metrics_diff(&args),
        "selftest" => cmd_selftest(&args),
        "" | "help" => {
            print!("{HELP}");
            Ok(())
        }
        other => Err(anyhow::anyhow!("unknown command '{other}'\n{HELP}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const HELP: &str = "\
vsa — Reconfigurable Vectorwise SNN Accelerator (ISCAS'21) reproduction

commands:
  models      print the Table I network structures and op counts
  simulate    run the cycle-accurate chip simulator on one inference
  table3      regenerate the paper's Table III comparison
  fusion      regenerate the §IV-B layer-fusion DRAM study
  dse         sweep the reconfigurable design space, emit a Pareto report
  infer       classify synthetic samples (golden | chip engines)
  serve       run the multi-model serving coordinator demo
  serve-bench drive the coordinator under seeded fault injection
  train       STBP-train a binary-weight SNN, export a VSAW artifact
  eval        golden-model accuracy of an artifact (optionally at --steps T)
  metrics-diff compare two vsa-metrics-v1 snapshots, gate on regressions
  selftest    cross-check the golden model against the chip simulator

common flags: --model tiny|mnist|cifar10  --artifacts DIR  --steps T

dse flags:    --space tiny|small|wide  --workload mnist|cifar10|both
              --sample N (0 = full grid)  --seed S  --threads N
              --top N  --tolerance EPS  --out FILE.json  --csv FILE.csv
              --artifact FILE.vsaw (adds the measured accuracy objective)
              --acc-count N  --acc-seed S

train flags:  --model tiny|mnist|micro  --dataset synth|mnist  --steps T
              --epochs N  --batches-per-epoch N  --batch B  --lr LR
              --momentum M  --seed S  --out FILE.vsaw  --eval-count N
              --threads N (batch-parallel workers; artifacts are
              byte-identical for every N)

eval flags:   --weights FILE.vsaw  --dataset synth|mnist  --count N
              --seed S  --steps T (override the artifact's T)
              --threads N (shard samples over N workers; counts are
              identical for every N)

infer flags:  --engine golden|chip-sim  --count N  --batch B  --seed S
              --threads N (golden engine: shard each batch over N
              workers — logits are byte-identical for every N)

serve flags:  --model NAME | NAME=FILE.vsaw (repeatable — each occurrence
              deploys one model; presets synthesize when untrained)
              --pool golden:3,chip-sim:1 (heterogeneous worker pool;
              default: --engine golden|chip times --workers N)
              --cache-cap K (per-engine packed-model LRU capacity)
              --requests N  --batch B  --deadline-ms D  --retries N
              --restart-budget N  --stats-interval MS
              --metrics-out FILE.json (write the final metrics snapshot)

serve-bench:  --model tiny|mnist|cifar10 (repeatable — two or more
              occurrences drive an equally-weighted mixed-model load)
              --steps T  --requests N  --workers N  --batch B
              --submitters N  --fault-rate P  --spike-ms MS
              --deadline-ms D  --submit-wait-ms W  --seed S
              --metrics-out FILE.json
              (weights are synthesized — no artifacts directory needed)

simulate:     --mode fast|exact  --no-fusion  --trace  --trace-tsv FILE
              --metrics (print registry text)  --metrics-out FILE.json
              (falls back to a synthesized model when no artifacts exist)

metrics-diff: vsa metrics-diff A.json B.json [--max-regress PCT]
              per-key deltas of two vsa-metrics-v1 snapshots; exits
              nonzero when a key regresses beyond PCT percent

tracing:      serve/serve-bench/train/simulate all take --trace-out
              FILE.json — a Chrome trace-event export (vsa-trace-v1,
              open in https://ui.perfetto.dev or chrome://tracing);
              simulate also prints a per-layer utilization report

telemetry:    serve/simulate/train all export the same vsa-metrics-v1
              JSON schema (see README OBSERVABILITY); train also takes
              --metrics-out FILE.json

env:          VSA_FORCE_SCALAR=1 pins the AND-popcount kernels to the
              scalar flavor (results are bit-identical either way; the
              hardware flavors are only faster)
";

/// Resolve one `--model` value to a named [`DeployedModel`].
///
/// `name=path.vsaw` loads the artifact and serves it under `name`; a
/// bare `*.vsaw` path serves it under the file stem; a preset name
/// (tiny|mnist|cifar10) prefers the trained artifact `vsa train` writes
/// into the artifacts directory and synthesizes weights otherwise, so
/// every command works without any artifacts on disk.
fn resolve_model(
    value: &str,
    dir: &str,
    steps: usize,
    seed: u64,
) -> anyhow::Result<(String, DeployedModel)> {
    if let Some((name, path)) = value.split_once('=') {
        anyhow::ensure!(!name.is_empty(), "empty model name in '{value}'");
        return Ok((name.to_string(), DeployedModel::from_file(path)?));
    }
    if value.ends_with(".vsaw") {
        let stem =
            std::path::Path::new(value).file_stem().and_then(|s| s.to_str()).unwrap_or("model");
        return Ok((stem.to_string(), DeployedModel::from_file(value)?));
    }
    let trained = format!("{dir}/{value}_t{steps}_trained.vsaw");
    if std::path::Path::new(&trained).exists() {
        return Ok((value.to_string(), DeployedModel::from_file(&trained)?));
    }
    let spec = models::by_name(value, steps).ok_or_else(|| {
        anyhow::anyhow!("'{value}' is neither a .vsaw artifact nor a preset (tiny|mnist|cifar10)")
    })?;
    eprintln!("note: no trained artifact for '{value}' in {dir}/; synthesizing weights");
    Ok((value.to_string(), DeployedModel::synthesize(&spec, seed)))
}

fn load_network(args: &Args) -> anyhow::Result<(String, Network)> {
    let model = args.get("model", "mnist");
    let dir = args.get("artifacts", "artifacts");
    let steps = args.get_usize("steps", 4)?;
    let seed = args.get_u64("seed", 7)?;
    let (name, deployed) = resolve_model(&model, &dir, steps, seed)?;
    Ok((name, Network::new(deployed)))
}

fn hw_from_args(args: &Args) -> anyhow::Result<HwConfig> {
    let mut hw = match args.get_opt("hw-config") {
        Some(path) => HwConfig::from_file(path).map_err(|e| anyhow::anyhow!(e))?,
        None => HwConfig::default(),
    };
    if args.has("no-fusion") {
        hw.layer_fusion = false;
    }
    Ok(hw)
}

fn cmd_models() -> anyhow::Result<()> {
    for name in ["mnist", "cifar10", "tiny"] {
        let spec = models::by_name(name, 8).unwrap();
        println!("== {} (T = {})", spec.name, spec.num_steps);
        let shapes = spec.feature_shapes();
        for (ly, shape) in spec.layers.iter().zip(&shapes) {
            println!("  {:?} c_out={} <- input {:?}", ly.kind, ly.c_out, shape);
        }
        println!(
            "  weights: {:.1} Kbit   MACs/inference: {:.1} M\n",
            spec.weight_bits() as f64 / 1000.0,
            spec.macs_per_inference() as f64 / 1e6
        );
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    // Trained artifact when one exists, synthesized weights otherwise
    // (see `resolve_model`) — cycle/traffic behaviour is weight-
    // independent, so smoke runs need no artifacts directory.
    let (model, net) = load_network(args)?;
    let hw = hw_from_args(args)?;
    let mode = match args.get("mode", "fast").as_str() {
        "exact" => SimMode::Exact,
        _ => SimMode::Fast,
    };
    let seed = args.get_u64("seed", 7)?;
    let sample = &synth::batch(seed, 0, 1, net.model.in_channels, net.model.in_size)[0];
    let tracing = args.has("trace")
        || args.get_opt("trace-out").is_some()
        || args.get_opt("trace-tsv").is_some();

    let t0 = Instant::now();
    let chip = Chip::new(hw.clone(), mode);
    let (r, trace) = if tracing {
        let (r, t) = chip.run_traced(&net.model, &sample.image);
        (r, Some(t))
    } else {
        (chip.run(&net.model, &sample.image), None)
    };
    let wall = t0.elapsed();

    println!("model={model} mode={mode:?} fusion={}", hw.layer_fusion);
    println!(
        "cycles={}  chip-latency={:.1} us @ {:.0} MHz  (sim wall time {:.1} ms)",
        r.cycles,
        r.latency_us,
        hw.freq_mhz,
        wall.as_secs_f64() * 1e3
    );
    println!(
        "PE ops={}  effective={:.1} GOPS (peak {:.0})  utilization={:.1}%",
        r.pe_ops,
        r.gops,
        hw.peak_gops(),
        r.utilization * 100.0
    );
    println!("DRAM traffic:\n{}", r.dram.report());
    println!("predicted class = {}", argmax(&r.logits));
    println!("\nper-layer:");
    for (i, l) in r.layers.iter().enumerate() {
        println!(
            "  L{i:<2} {:?}: cycles={} util={:.1}% spikes={}",
            l.kind,
            l.cycles,
            l.utilization * 100.0,
            l.spikes_emitted
        );
    }
    if let Some(trace) = trace {
        println!("\nutilization report:\n{}", timeline::render_utilization(&r, &hw));
        if let Some(path) = args.get_opt("trace-out") {
            let plans = plan_model(&net.model);
            let sheet = timeline::chip_span_sheet(&r, &trace, &hw, &plans);
            std::fs::write(path, sheet.to_chrome_json() + "\n")?;
            println!("timeline written to {path} ({} events) — open in Perfetto", sheet.len());
        }
        if let Some(path) = args.get_opt("trace-tsv") {
            std::fs::write(path, trace.to_tsv())?;
            println!("trace TSV written to {path} ({} events)", trace.len());
        }
        if args.has("trace") {
            println!("\nexecution trace:\n{}", trace.render());
        }
    }
    if args.has("metrics") || args.get_opt("metrics-out").is_some() {
        let reg = Registry::new();
        r.export_into(&reg, "sim");
        let snap = reg.snapshot();
        if args.has("metrics") {
            print!("\nmetrics:\n{}", snap.render_text());
        }
        if let Some(path) = args.get_opt("metrics-out") {
            std::fs::write(path, snap.to_json() + "\n")?;
            println!("\nmetrics written to {path}");
        }
    }
    Ok(())
}

fn cmd_table3(args: &Args) -> anyhow::Result<()> {
    let (model, net) = load_network(args)?;
    let hw = hw_from_args(args)?;
    let chip = Chip::new(hw.clone(), SimMode::Fast);
    let sample = &synth::batch(7, 0, 1, net.model.in_channels, net.model.in_size)[0];
    let r = chip.run(&net.model, &sample.image);

    let rows = vec![
        report::this_work(&hw, &r),
        published::spinalflow_row(),
        published::bwsnn_row(),
    ];
    println!("Table III — performance summary (workload: {model})\n");
    print!("{}", report::render_table3(&rows));
    println!(
        "\nmeasured on {model}: {} cycles, {:.1} us/inference, core power {:.3} mW",
        r.cycles,
        r.latency_us,
        power::core_power_mw(&hw, &r)
    );
    Ok(())
}

fn cmd_fusion(args: &Args) -> anyhow::Result<()> {
    let (model, net) = load_network(args)?;
    let sample = &synth::batch(7, 0, 1, net.model.in_channels, net.model.in_size)[0];

    let hw_on = HwConfig::default();
    let hw_off = HwConfig { layer_fusion: false, ..HwConfig::default() };
    let on = Chip::new(hw_on, SimMode::Fast).run(&net.model, &sample.image);
    let off = Chip::new(hw_off, SimMode::Fast).run(&net.model, &sample.image);

    let on_kb = on.dram.total() as f64 / 1024.0;
    let off_kb = off.dram.total() as f64 / 1024.0;
    println!("Layer-fusion DRAM study ({model}, T={})", net.model.num_steps);
    println!("  without fusion: {off_kb:.3} KB");
    println!("  with fusion:    {on_kb:.3} KB");
    println!("  reduction:      {:.1}%", (1.0 - on_kb / off_kb) * 100.0);
    println!("  paper (CIFAR-10): 1450.172 KB -> 938.172 KB (-35.3%)");
    println!("\nwith-fusion breakdown:\n{}", on.dram.report());
    println!("\nwithout-fusion breakdown:\n{}", off.dram.report());
    Ok(())
}

fn cmd_dse(args: &Args) -> anyhow::Result<()> {
    let space_name = args.get("space", "small");
    let space = dse::SearchSpace::by_name(&space_name)
        .ok_or_else(|| anyhow::anyhow!("unknown space '{space_name}' (tiny|small|wide)"))?;
    let workload = args.get("workload", "mnist");
    let workloads: Vec<&str> = match workload.as_str() {
        "both" => vec!["mnist", "cifar10"],
        "mnist" => vec!["mnist"],
        "cifar10" => vec!["cifar10"],
        other => anyhow::bail!("unknown workload '{other}' (mnist|cifar10|both)"),
    };
    let sample = args.get_usize("sample", 0)?;
    let seed = args.get_u64("seed", 7)?;
    let default_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let threads = args.get_usize("threads", default_threads)?;
    let top = args.get_usize("top", 5)?;
    let tolerance = args.get_f64("tolerance", 0.05)?;
    let out = args.get("out", "dse_report.json");

    let t0 = Instant::now();
    let drawn: Vec<dse::Candidate> =
        if sample == 0 { space.cartesian().collect() } else { space.sample(sample, seed) };
    let candidates: Vec<dse::Candidate> = drawn
        .into_iter()
        .filter(|c| dse::validate(c, &workloads).is_ok())
        .collect();
    anyhow::ensure!(!candidates.is_empty(), "no valid candidates in space '{space_name}'");
    println!(
        "space '{space_name}': {} grid points, {} drawn valid candidates, workloads {:?}",
        space.len(),
        candidates.len(),
        workloads
    );

    // Optional measured-accuracy objective: a trained artifact scored at
    // every distinct T in the sweep (golden model, held-out samples).
    let acc_map = match args.get_opt("artifact") {
        Some(path) => {
            let artifact = DeployedModel::from_file(path)?;
            let acc_count = args.get_usize("acc-count", 64)?;
            let acc_seed = args.get_u64("acc-seed", 7)?;
            let map = dse::accuracy_by_t(
                &artifact,
                candidates.iter().map(|c| c.num_steps),
                acc_count,
                acc_seed,
            );
            println!(
                "accuracy objective from {path} ({} held-out samples/T):",
                acc_count
            );
            for (t, a) in &map {
                println!("  T={t}: {:.3}", a);
            }
            Some(map)
        }
        None => None,
    };

    let results = dse::evaluate_all_with(&candidates, &workloads, threads, acc_map.as_ref());
    let front = dse::frontier(&results);
    let wall = t0.elapsed();
    println!(
        "evaluated {} candidates on {threads} threads in {:.1} ms\n",
        results.len(),
        wall.as_secs_f64() * 1e3
    );
    print!("{}", dse::report::render(&results, &front, top));

    // Where the published design point lands.  The slack comparison is
    // pinned to the paper's T (see `dse::paper_slack_at_t`): lower-T
    // candidates do strictly less compute and dominate trivially while
    // paying an accuracy cost the analytic model does not score.
    let paper = dse::Candidate::paper();
    let paper_slack = dse::paper_slack_at_t(&results).map(|s| {
        let t = paper.num_steps;
        let verdict = if s < 0.0 {
            format!("strictly Pareto-optimal at T={t} (slack {s:.4})")
        } else if s <= tolerance {
            format!("on/within tolerance {tolerance} of the T={t} frontier (slack {s:.4})")
        } else {
            format!("OFF the T={t} frontier (slack {s:.4} > tolerance {tolerance})")
        };
        println!("\npaper design point [{}]: {verdict}", paper.id());
        if let Some(full) = dse::find_by_id(&results, &paper.id()) {
            let fs = dse::slack(&results[full], &results);
            if fs > s {
                println!(
                    "  (full sweep incl. the T axis: slack {fs:.4} — lower-T points \
                     dominate by trading accuracy, which the model does not score)"
                );
            }
        }
        s
    });

    let meta = dse::report::SweepMeta {
        space: space.name.clone(),
        workloads: workloads.iter().map(|w| w.to_string()).collect(),
        grid_size: space.len(),
        sampled: sample,
        seed,
        threads,
    };
    let doc = dse::report::to_json(&meta, &results, &front, paper_slack);
    std::fs::write(&out, json::to_string(&doc) + "\n")?;
    println!("\nJSON report written to {out}");
    if let Some(csv_path) = args.get_opt("csv") {
        std::fs::write(csv_path, dse::report::to_csv(&results, &front))?;
        println!("frontier CSV ({} rows) written to {csv_path}", front.len());
    }
    Ok(())
}

fn cmd_infer(args: &Args) -> anyhow::Result<()> {
    let engine_kind = EngineKind::parse(&args.get("engine", "golden"))?;
    let count = args.get_usize("count", 8)?;
    let batch = args.get_usize("batch", 8)?;
    let threads = args.get_usize("threads", 1)?.max(1);
    let dir = args.get("artifacts", "artifacts");
    let steps = args.get_usize("steps", 4)?;
    let seed = args.get_u64("seed", 7)?;
    let (name, deployed) = resolve_model(&args.get("model", "mnist"), &dir, steps, seed)?;
    let (channels, size) = (deployed.in_channels, deployed.in_size);
    let (registry, mid) = ModelRegistry::single(deployed);

    let mut engine: Box<dyn InferenceEngine> = match engine_kind {
        EngineKind::ChipSim => {
            if threads > 1 {
                println!("note: --threads applies to the golden engine only (chip-sim is serial)");
            }
            Box::new(ChipEngine::new(HwConfig::default(), registry, batch))
        }
        EngineKind::Golden => Box::new(GoldenEngine::new(registry, batch).with_threads(threads)),
    };

    let samples = synth::batch(11, 0, count, channels, size);
    let mut correct = 0usize;
    let t0 = Instant::now();
    for chunk in samples.chunks(engine.batch_size()) {
        let images: Vec<Vec<u8>> = chunk.iter().map(|s| s.image.clone()).collect();
        let logits = engine.infer(mid, &images)?;
        for (s, l) in chunk.iter().zip(&logits) {
            let pred = argmax(l);
            if pred == s.label {
                correct += 1;
            }
        }
    }
    let dt = t0.elapsed();
    println!(
        "{} on {name}: {count} samples in {:.1} ms ({:.1} inf/s), accuracy {}/{count}",
        engine.name(),
        dt.as_secs_f64() * 1e3,
        count as f64 / dt.as_secs_f64(),
        correct
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let requests = args.get_usize("requests", 64)?;
    let batch = args.get_usize("batch", 8)?;
    let dir = args.get("artifacts", "artifacts");
    let steps = args.get_usize("steps", 4)?;
    let seed = args.get_u64("seed", 7)?;
    let cache_cap = args.get_usize("cache-cap", DEFAULT_MODEL_CACHE)?;

    // Every `--model` occurrence deploys one model into the shared
    // registry (default: a single mnist).
    let mut model_flags: Vec<String> =
        args.get_all("model").into_iter().map(String::from).collect();
    if model_flags.is_empty() {
        model_flags.push("mnist".to_string());
    }
    let mut registry = ModelRegistry::new();
    let mut ids = Vec::with_capacity(model_flags.len());
    for value in &model_flags {
        let (name, deployed) = resolve_model(value, &dir, steps, seed)?;
        ids.push(registry.register(&name, deployed)?);
    }
    let registry = Arc::new(registry);
    let n_models = ids.len();

    // Worker pool: an explicit heterogeneous `--pool` spec wins;
    // otherwise `--engine` replicated `--workers` times.
    let pool = match args.get_opt("pool") {
        Some(spec) => parse_pool(spec)?,
        None => {
            let kind = EngineKind::parse(&args.get("engine", "golden"))?;
            vec![kind; args.get_usize("workers", 2)?.max(1)]
        }
    };
    let workers = pool.len();

    let deadline = args
        .get_opt("deadline-ms")
        .map(|_| args.get_millis("deadline-ms", Duration::ZERO))
        .transpose()?;
    let cfg = CoordinatorConfig {
        workers,
        max_batch: batch,
        deadline,
        max_retries: args.get_u64("retries", 2)? as u32,
        restart_budget: args.get_u64("restart-budget", 4)? as u32,
        ..CoordinatorConfig::default()
    };
    let spans = args.get_opt("trace-out").map(|_| SpanCollector::new());
    let make_engine = {
        let pool = pool.clone();
        let reg = Arc::clone(&registry);
        move |w: usize| -> Box<dyn InferenceEngine> {
            match pool[w] {
                EngineKind::ChipSim => Box::new(ChipEngine::with_cache_capacity(
                    HwConfig::default(),
                    Arc::clone(&reg),
                    batch,
                    cache_cap,
                )),
                EngineKind::Golden => {
                    Box::new(GoldenEngine::with_cache_capacity(Arc::clone(&reg), batch, cache_cap))
                }
            }
        }
    };
    let mut coord =
        Coordinator::start_with_spans(cfg, Arc::clone(&registry), spans.clone(), make_engine);

    // Periodic observability: a reporter thread publishes a fresh
    // registry snapshot every --stats-interval while requests drain.
    // A fresh `Registry` per tick because sketch export is merge-
    // additive (see `Coordinator::export_into`).
    let stats_interval = args
        .get_opt("stats-interval")
        .map(|_| args.get_millis("stats-interval", Duration::ZERO))
        .transpose()?
        .filter(|iv| !iv.is_zero());

    // Interleave the request stream across the deployed models (request
    // i goes to model i mod n), each model fed synthetic samples
    // matching its own input geometry.
    let per_model = requests.div_ceil(n_models);
    let streams: Vec<Vec<_>> = ids
        .iter()
        .map(|&id| {
            let m = registry.get(id);
            synth::batch(23, 0, per_model, m.in_channels, m.in_size)
        })
        .collect();
    let mut correct = vec![0usize; n_models];
    let mut shed = 0usize;
    let mut failed = 0usize;
    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| -> anyhow::Result<()> {
        if let Some(iv) = stats_interval {
            scope.spawn(|| {
                let mut last = Instant::now();
                while !stop.load(Ordering::Relaxed) {
                    std::thread::sleep(Duration::from_millis(20));
                    if last.elapsed() < iv || stop.load(Ordering::Relaxed) {
                        continue;
                    }
                    last = Instant::now();
                    let reg = Registry::new();
                    coord.export_into(&reg, "serve");
                    print!("--- serve metrics ---\n{}", reg.snapshot().render_text());
                }
            });
        }
        let run = (|| -> anyhow::Result<()> {
            let mut receivers = Vec::with_capacity(requests);
            for i in 0..requests {
                let (m, j) = (i % n_models, i / n_models);
                receivers.push((m, j, coord.submit(ids[m], streams[m][j].image.clone())?));
            }
            for (m, j, rx) in receivers {
                match rx.recv()? {
                    Ok(res) => {
                        if argmax(&res.logits) == streams[m][j].label {
                            correct[m] += 1;
                        }
                    }
                    Err(ServeError::Rejected(_)) => shed += 1,
                    Err(_) => failed += 1,
                }
            }
            Ok(())
        })();
        stop.store(true, Ordering::Relaxed);
        run
    })?;
    // Quiesce first: per-model and model-cache rows are exact only
    // after the workers have joined (counters mirror once per batch).
    coord.drain();
    if let Some(path) = args.get_opt("metrics-out") {
        let reg = Registry::new();
        coord.export_into(&reg, "serve");
        std::fs::write(path, reg.snapshot().to_json() + "\n")?;
        println!("metrics written to {path}");
    }
    let cache = coord.cache_totals();
    let stats = coord.shutdown();
    let mut pool_desc = String::new();
    for kind in [EngineKind::Golden, EngineKind::ChipSim] {
        let n = pool.iter().filter(|&&k| k == kind).count();
        if n > 0 {
            if !pool_desc.is_empty() {
                pool_desc.push_str(" + ");
            }
            pool_desc.push_str(&format!("{}x{n}", kind.name()));
        }
    }
    println!(
        "served {} requests over {n_models} model(s) on {workers} workers [{pool_desc}] \
         (batch <= {batch})",
        stats.completed
    );
    println!(
        "  throughput {:.1} req/s   mean batch {:.2}",
        stats.throughput_rps, stats.mean_batch
    );
    println!(
        "  latency ms: p50 {:.2}  p95 {:.2}  p99 {:.2}  p999 {:.2}  max {:.2}",
        stats.latency_ms_p50,
        stats.latency_ms_p95,
        stats.latency_ms_p99,
        stats.latency_ms_p999,
        stats.latency_ms_max
    );
    for line in stats.stages.render().lines() {
        println!("  {line}");
    }
    println!(
        "  failed {failed}  shed {shed}  retries {}  worker restarts {}",
        stats.retries, stats.worker_restarts
    );
    for (m, &id) in ids.iter().enumerate() {
        let sent = requests / n_models + usize::from(m < requests % n_models);
        println!("  model {}: accuracy {}/{sent}", registry.name(id), correct[m]);
    }
    println!(
        "  model cache: {} lookups, {} hits, {} misses, {} evictions",
        cache.lookups, cache.hits, cache.misses, cache.evictions
    );
    write_trace(args, spans.as_ref())?;
    Ok(())
}

/// Write the Chrome trace-event export to `--trace-out` (call only
/// after `Coordinator::shutdown` — worker recorders flush at join).
fn write_trace(args: &Args, spans: Option<&Arc<SpanCollector>>) -> anyhow::Result<()> {
    if let (Some(spans), Some(path)) = (spans, args.get_opt("trace-out")) {
        let sheet = spans.sheet();
        std::fs::write(path, sheet.to_chrome_json() + "\n")?;
        println!("trace written to {path} ({} spans) — open in Perfetto", sheet.len());
    }
    Ok(())
}

/// Artifact-free load benchmark: a synthesized model behind a seeded
/// [`FaultEngine`], driven by the shared closed-loop generator.  The
/// same code path `benches/bench_serve.rs` records into BENCH_PR7.json.
fn cmd_serve_bench(args: &Args) -> anyhow::Result<()> {
    let mut names: Vec<String> = args.get_all("model").into_iter().map(String::from).collect();
    if names.is_empty() {
        names.push("tiny".to_string());
    }
    let steps = args.get_usize("steps", 4)?;
    let requests = args.get_usize("requests", 512)?;
    let workers = args.get_usize("workers", 2)?;
    let batch = args.get_usize("batch", 8)?;
    let submitters = args.get_usize("submitters", 4)?;
    let fault_rate = args.get_f64("fault-rate", 0.0)?;
    anyhow::ensure!((0.0..=1.0).contains(&fault_rate), "--fault-rate must be in [0, 1]");
    let seed = args.get_u64("seed", 7)?;
    let spike = args.get_millis("spike-ms", Duration::from_millis(2))?;
    let deadline = args
        .get_opt("deadline-ms")
        .map(|_| args.get_millis("deadline-ms", Duration::ZERO))
        .transpose()?;
    let submit_wait = args
        .get_opt("submit-wait-ms")
        .map(|_| args.get_millis("submit-wait-ms", Duration::ZERO))
        .transpose()?;

    // One synthesized model per `--model` occurrence, equally weighted
    // in the generated traffic (distinct seeds keep the weights apart).
    let mut registry = ModelRegistry::new();
    let mut ids = Vec::with_capacity(names.len());
    for (i, name) in names.iter().enumerate() {
        let spec = models::by_name(name, steps)
            .ok_or_else(|| anyhow::anyhow!("unknown model '{name}' (tiny|mnist|cifar10)"))?;
        let deployed = DeployedModel::synthesize(&spec, seed.wrapping_add(i as u64));
        ids.push(registry.register(name, deployed)?);
    }
    let registry = Arc::new(registry);

    let profile = FaultProfile::mixed(fault_rate, spike);
    let fstats = Arc::new(FaultStats::default());
    let cfg = CoordinatorConfig {
        workers,
        max_batch: batch,
        deadline,
        ..CoordinatorConfig::default()
    };
    let spans = args.get_opt("trace-out").map(|_| SpanCollector::new());
    let mut coord = Coordinator::start_with_spans(cfg, Arc::clone(&registry), spans.clone(), {
        let reg = Arc::clone(&registry);
        let fstats = Arc::clone(&fstats);
        move |w| -> Box<dyn InferenceEngine> {
            let inner = Box::new(GoldenEngine::new(Arc::clone(&reg), batch));
            let seed_w = FaultEngine::seed_for(seed, w);
            Box::new(FaultEngine::with_stats(inner, profile, seed_w, Arc::clone(&fstats)))
        }
    });

    let per = 64.min(requests.max(1));
    let traffic: Vec<ModelTraffic> = ids
        .iter()
        .map(|&id| {
            let m = registry.get(id);
            let images = synth::batch(seed, 0, per, m.in_channels, m.in_size)
                .into_iter()
                .map(|s| s.image)
                .collect();
            ModelTraffic { model: id, weight: 1, images }
        })
        .collect();
    let load = LoadSpec { requests, submitters, submit_wait };
    let report = run_load(&coord, &traffic, &load);
    coord.drain(); // exact per-model / cache rows in the export below
    if let Some(path) = args.get_opt("metrics-out") {
        let reg = Registry::new();
        coord.export_into(&reg, "serve");
        std::fs::write(path, reg.snapshot().to_json() + "\n")?;
        println!("metrics written to {path}");
    }
    let stats = coord.shutdown();

    println!(
        "serve-bench {} (T={steps}): {requests} requests, {workers} workers, \
         fault rate {:.1}%",
        names.join("+"),
        fault_rate * 100.0
    );
    println!("  {}", report.render());
    println!(
        "  injected {} errors / {} panics / {} spikes over {} engine calls",
        fstats.errors.load(std::sync::atomic::Ordering::Relaxed),
        fstats.panics.load(std::sync::atomic::Ordering::Relaxed),
        fstats.spikes.load(std::sync::atomic::Ordering::Relaxed),
        fstats.calls.load(std::sync::atomic::Ordering::Relaxed)
    );
    println!(
        "  throughput {:.1} req/s   latency ms: p50 {:.2}  p99 {:.2}  p999 {:.2}  max {:.2}",
        stats.throughput_rps,
        stats.latency_ms_p50,
        stats.latency_ms_p99,
        stats.latency_ms_p999,
        stats.latency_ms_max
    );
    for line in stats.stages.render().lines() {
        println!("  {line}");
    }
    println!(
        "  completed {}  failed {}  shed {}  retries {}  worker restarts {}",
        stats.completed, stats.failed, stats.shed, stats.retries, stats.worker_restarts
    );
    anyhow::ensure!(report.total() == requests as u64, "load tally mismatch");
    anyhow::ensure!(
        stats.completed + stats.failed + stats.shed == stats.submitted,
        "coordinator counters do not balance"
    );
    write_trace(args, spans.as_ref())?;
    Ok(())
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let model = args.get("model", "tiny");
    let dataset = match args.get("dataset", "synth").as_str() {
        "synth" => train::Dataset::Synth,
        "mnist" => train::Dataset::Mnist,
        other => anyhow::bail!("unknown dataset '{other}' (synth|mnist)"),
    };
    let num_steps = args.get_usize("steps", 4)?;
    let cfg = train::TrainConfig {
        model: model.clone(),
        num_steps,
        dataset,
        epochs: args.get_usize("epochs", 6)?,
        batches_per_epoch: args.get_usize("batches-per-epoch", 50)?,
        batch: args.get_usize("batch", 32)?,
        lr: args.get_f64("lr", 0.1)?,
        momentum: args.get_f64("momentum", 0.9)? as f32,
        seed: args.get_u64("seed", 7)?,
        log_every: args.get_usize("log-every", 25)?,
        threads: args.get_usize("threads", 1)?,
    };
    let out_path =
        args.get("out", &format!("artifacts/{model}_t{num_steps}_trained.vsaw"));

    let spans = args.get_opt("trace-out").map(|_| SpanCollector::new());
    let t0 = Instant::now();
    let outcome = train::train_traced(&cfg, spans.as_ref())?;
    let wall = t0.elapsed();
    write_trace(args, spans.as_ref())?;
    let deployed = train::write_artifact(&outcome.net, &out_path)?;
    println!(
        "trained {model} (T={num_steps}) for {} steps in {:.1} s: final loss {:.4}, \
         batch acc {:.3}",
        outcome.steps,
        wall.as_secs_f64(),
        outcome.final_loss,
        outcome.final_batch_acc
    );
    println!("artifact: {out_path} ({} bytes)", deployed.to_bytes().len());
    println!("  phases: {}", outcome.phases.render());
    if let Some(path) = args.get_opt("metrics-out") {
        let reg = Registry::new();
        outcome.phases.export_into(&reg, "train");
        reg.set_counter("train.steps", outcome.steps as u64);
        reg.set_gauge("train.final_loss", outcome.final_loss as f64);
        std::fs::write(path, reg.snapshot().to_json() + "\n")?;
        println!("metrics written to {path}");
    }

    let count = args.get_usize("eval-count", 256)?;
    let samples = match cfg.dataset {
        train::Dataset::Synth => train::holdout_synth(&outcome.net.spec, cfg.seed, count),
        train::Dataset::Mnist => idx::mnist_if_available(count)
            .ok_or_else(|| anyhow::anyhow!("t10k IDX files missing for held-out eval"))?,
    };
    let (correct, total) = train::eval_golden_threaded(&deployed, &samples, cfg.threads);
    println!(
        "deployed golden-model accuracy: {correct}/{total} ({:.1}%) held out",
        100.0 * correct as f64 / total.max(1) as f64
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> anyhow::Result<()> {
    let path = args.require("weights")?;
    let mut model = DeployedModel::from_file(&path)?;
    let t = args.get_usize("steps", model.num_steps)?;
    anyhow::ensure!(t > 0, "--steps (T) must be positive");
    model.num_steps = t;
    let count = args.get_usize("count", 256)?;
    let seed = args.get_u64("seed", 7)?;
    let threads = args.get_usize("threads", 1)?.max(1);
    let samples = match args.get("dataset", "synth").as_str() {
        // Same held-out stream as `vsa train`'s final report.
        "synth" => train::holdout_samples(model.in_channels, model.in_size, seed, count),
        "mnist" => {
            let s = idx::mnist_if_available(count)
                .ok_or_else(|| anyhow::anyhow!("data/mnist/t10k-* IDX files not found"))?;
            anyhow::ensure!(!s.is_empty(), "MNIST test split is empty");
            anyhow::ensure!(
                s[0].channels == model.in_channels && s[0].size == model.in_size,
                "MNIST geometry does not match artifact ({}x{})",
                model.in_channels,
                model.in_size
            );
            s
        }
        other => anyhow::bail!("unknown dataset '{other}' (synth|mnist)"),
    };
    let t0 = Instant::now();
    let (correct, total) = train::eval_golden_threaded(&model, &samples, threads);
    println!(
        "eval {}: accuracy {correct}/{total} ({:.1}%) at T={t} in {:.1} ms",
        model.name,
        100.0 * correct as f64 / total.max(1) as f64,
        t0.elapsed().as_secs_f64() * 1e3
    );
    Ok(())
}

/// Compare two `vsa-metrics-v1` snapshots and gate on regressions:
/// `vsa metrics-diff baseline.json current.json [--max-regress PCT]`.
/// Exits nonzero when any shared key moved in its worse direction by
/// more than PCT percent (default: report-only, never gate).
fn cmd_metrics_diff(args: &Args) -> anyhow::Result<()> {
    anyhow::ensure!(
        args.positional.len() == 2,
        "usage: vsa metrics-diff <a.json> <b.json> [--max-regress PCT]"
    );
    let max_regress = args.get_f64("max-regress", f64::INFINITY)?;
    let read = |path: &str| -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read {path}: {e}"))?;
        Json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))
    };
    let a = read(&args.positional[0])?;
    let b = read(&args.positional[1])?;
    let report = diff_snapshots(&a, &b, max_regress).map_err(|e| anyhow::anyhow!(e))?;
    print!("{}", report.render());
    anyhow::ensure!(
        !report.has_regressions(),
        "{} key(s) regressed beyond {max_regress}%: {}",
        report.regressions.len(),
        report.regressions.join(", ")
    );
    Ok(())
}

fn cmd_selftest(args: &Args) -> anyhow::Result<()> {
    let dir = args.get("artifacts", "artifacts");
    let steps = args.get_usize("steps", 4)?;
    for preset in ["tiny", "mnist"] {
        let (name, deployed) = resolve_model(preset, &dir, steps, 99)?;
        let sample = &synth::batch(99, 0, 1, deployed.in_channels, deployed.in_size)[0];

        // Direct golden vs cycle-accurate simulator on the raw model.
        let net = Network::new(deployed.clone());
        let golden = net.infer_u8(&sample.image);
        let sim = Chip::new(HwConfig::default(), SimMode::Fast)
            .run(&net.model, &sample.image)
            .logits;
        anyhow::ensure!(golden == sim, "{name}: sim != golden");

        // Same check through the serving engines (registry + ModelId).
        let (registry, mid) = ModelRegistry::single(deployed);
        let mut gold_eng = GoldenEngine::new(Arc::clone(&registry), 1);
        let mut chip_eng = ChipEngine::new(HwConfig::default(), registry, 1);
        let ge = gold_eng.infer(mid, std::slice::from_ref(&sample.image))?;
        let ce = chip_eng.infer(mid, std::slice::from_ref(&sample.image))?;
        anyhow::ensure!(ge[0] == golden && ce[0] == golden, "{name}: engine mismatch");
        println!("{name}: golden == chip-sim (direct and via engines)  ({golden:?})");
    }
    println!("selftest OK");
    Ok(())
}

//! A compiled PJRT executable for one (model, batch) artifact.
//!
//! The real implementation binds the `xla` crate (PJRT CPU client) and is
//! gated behind the `xla` cargo feature, which requires the vendored
//! `xla` crate the offline image does not ship.  Without the feature a
//! stub with the identical API is compiled instead: `load` fails with a
//! descriptive error, so every caller (CLI, coordinator, examples)
//! degrades gracefully to the golden or chip-sim engines.

#[cfg(feature = "xla")]
mod real {
    use anyhow::{Context, Result};

    /// A compiled inference executable bound to a PJRT CPU client.
    ///
    /// The artifact's only runtime parameter is the image batch
    /// `(B, C, H, W) f32` (weights are baked in as constants — the chip
    /// analogue of weights resident in the weight SRAM); the output is the
    /// 1-tuple of `(B, 10) f32` integer-valued logits.
    pub struct PjrtExecutor {
        client: xla::PjRtClient,
        exe: xla::PjRtLoadedExecutable,
        pub batch: usize,
        pub channels: usize,
        pub size: usize,
    }

    impl PjrtExecutor {
        /// Compile an HLO-text artifact on a fresh CPU client.
        pub fn load(path: &str, batch: usize, channels: usize, size: usize) -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("parse HLO text {path}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compile {path}"))?;
            Ok(Self { client, exe, batch, channels, size })
        }

        /// Platform string of the underlying client (for logs).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Run one batch of u8 images (padded/truncated to the compiled
        /// batch size by the caller).  Returns per-image integer logits.
        pub fn infer(&self, images: &[Vec<u8>]) -> Result<Vec<Vec<i64>>> {
            anyhow::ensure!(
                images.len() == self.batch,
                "executor compiled for batch {}, got {}",
                self.batch,
                images.len()
            );
            let pixels = self.channels * self.size * self.size;
            let mut flat = Vec::with_capacity(self.batch * pixels);
            for img in images {
                anyhow::ensure!(img.len() == pixels, "image geometry mismatch");
                flat.extend(img.iter().map(|&p| p as f32));
            }
            let input = xla::Literal::vec1(&flat).reshape(&[
                self.batch as i64,
                self.channels as i64,
                self.size as i64,
                self.size as i64,
            ])?;
            let result = self.exe.execute::<xla::Literal>(&[input])?[0][0]
                .to_literal_sync()?;
            let out = result.to_tuple1()?; // lowered with return_tuple=True
            let values = out.to_vec::<f32>()?;
            anyhow::ensure!(
                values.len() == self.batch * 10,
                "unexpected output size {}",
                values.len()
            );
            Ok(values
                .chunks_exact(10)
                .map(|row| row.iter().map(|&v| v.round() as i64).collect())
                .collect())
        }
    }
}

#[cfg(not(feature = "xla"))]
mod stub {
    use anyhow::Result;

    /// Offline stand-in for the PJRT executor: loading always fails with
    /// a descriptive error (same public surface as the real one).
    pub struct PjrtExecutor {
        pub batch: usize,
        pub channels: usize,
        pub size: usize,
    }

    impl PjrtExecutor {
        /// Always fails: the PJRT backend is not compiled in.
        pub fn load(
            path: &str,
            _batch: usize,
            _channels: usize,
            _size: usize,
        ) -> Result<Self> {
            Err(anyhow::anyhow!(
                "PJRT backend not compiled in (vendor the xla crate, wire it as an \
                 optional dependency, and build with `--features xla` to execute \
                 {path}); use the golden or chip engines"
            ))
        }

        /// Platform string of the underlying client (for logs).
        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        /// Unreachable in practice (`load` never constructs a stub), but
        /// kept API-identical.
        pub fn infer(&self, _images: &[Vec<u8>]) -> Result<Vec<Vec<i64>>> {
            Err(anyhow::anyhow!("PJRT backend not compiled in"))
        }
    }
}

#[cfg(feature = "xla")]
pub use real::PjrtExecutor;
#[cfg(not(feature = "xla"))]
pub use stub::PjrtExecutor;

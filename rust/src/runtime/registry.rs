//! Artifact registry: parse `artifacts/manifest.json` and locate the
//! right HLO module / weight file for a (model, batch) request.

use crate::config::json::Json;
use anyhow::{Context, Result};

/// One manifest entry (one compiled artifact).
#[derive(Debug, Clone)]
pub struct ManifestEntry {
    pub name: String,
    pub hlo: String,
    pub weights: String,
    pub batch: usize,
    pub num_steps: usize,
    pub in_channels: usize,
    pub in_size: usize,
    pub num_classes: usize,
    pub pallas: bool,
}

/// The parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: String,
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &str) -> Result<Self> {
        let path = format!("{dir}/manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {path} (run `make artifacts`)"))?;
        let v = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        let arr = v.as_arr().context("manifest must be a JSON array")?;
        let mut entries = Vec::with_capacity(arr.len());
        for e in arr {
            let get_str = |k: &str| -> Result<String> {
                Ok(e.get(k)
                    .and_then(Json::as_str)
                    .with_context(|| format!("manifest entry missing {k}"))?
                    .to_string())
            };
            let get_usize = |k: &str| -> Result<usize> {
                e.get(k)
                    .and_then(Json::as_usize)
                    .with_context(|| format!("manifest entry missing {k}"))
            };
            entries.push(ManifestEntry {
                name: get_str("name")?,
                hlo: get_str("hlo")?,
                weights: get_str("weights")?,
                batch: get_usize("batch")?,
                num_steps: get_usize("num_steps")?,
                in_channels: get_usize("in_channels")?,
                in_size: get_usize("in_size")?,
                num_classes: get_usize("num_classes")?,
                pallas: e.get("pallas").and_then(Json::as_bool).unwrap_or(false),
            });
        }
        Ok(Self { dir: dir.to_string(), entries })
    }

    /// Find the entry for `model` with the largest batch <= `want_batch`
    /// (or the smallest batch if none fit).
    pub fn find(&self, model: &str, want_batch: usize) -> Option<&ManifestEntry> {
        let mut candidates: Vec<&ManifestEntry> =
            self.entries.iter().filter(|e| e.name == model).collect();
        candidates.sort_by_key(|e| e.batch);
        candidates
            .iter()
            .rev()
            .find(|e| e.batch <= want_batch)
            .copied()
            .or_else(|| candidates.first().copied())
    }

    /// Absolute path of an entry's HLO file.
    pub fn hlo_path(&self, e: &ManifestEntry) -> String {
        format!("{}/{}", self.dir, e.hlo)
    }

    /// Absolute path of an entry's weight file.
    pub fn weights_path(&self, e: &ManifestEntry) -> String {
        format!("{}/{}", self.dir, e.weights)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &std::path::Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"[
              {"name":"m","hlo":"m1.hlo.txt","weights":"m.vsaw","batch":1,
               "num_steps":8,"in_channels":1,"in_size":28,"num_classes":10,"pallas":true},
              {"name":"m","hlo":"m8.hlo.txt","weights":"m.vsaw","batch":8,
               "num_steps":8,"in_channels":1,"in_size":28,"num_classes":10,"pallas":true}
            ]"#,
        )
        .unwrap();
    }

    #[test]
    fn find_prefers_largest_fitting_batch() {
        let dir = std::env::temp_dir().join("vsa_manifest_test");
        write_manifest(&dir);
        let m = Manifest::load(dir.to_str().unwrap()).unwrap();
        assert_eq!(m.find("m", 8).unwrap().batch, 8);
        assert_eq!(m.find("m", 4).unwrap().batch, 1);
        assert_eq!(m.find("m", 100).unwrap().batch, 8);
        assert!(m.find("nope", 1).is_none());
    }

    #[test]
    fn paths_join_dir() {
        let dir = std::env::temp_dir().join("vsa_manifest_test2");
        write_manifest(&dir);
        let m = Manifest::load(dir.to_str().unwrap()).unwrap();
        let e = m.find("m", 1).unwrap();
        assert!(m.hlo_path(e).ends_with("m1.hlo.txt"));
        assert!(m.weights_path(e).ends_with("m.vsaw"));
    }
}

//! PJRT runtime: load and execute the AOT artifacts from rust.
//!
//! `python/compile/aot.py` lowers the deployed JAX/Pallas graphs to HLO
//! text at build time; this module compiles them on the PJRT CPU client
//! (`xla` crate) and executes them natively.  Python never runs on the
//! request path — the `vsa` binary is self-contained once `artifacts/`
//! exists.

pub mod executor;
pub mod registry;

pub use executor::PjrtExecutor;
pub use registry::{Manifest, ManifestEntry};

//! Minimal recursive-descent JSON parser (serde is unavailable offline).
//!
//! Supports the full JSON grammar minus exotic escapes (`\uXXXX` is decoded
//! for the BMP). Used for `artifacts/manifest.json`, hardware config files
//! and benchmark output.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: src.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As f64 (numbers only).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// As integer (numbers that round-trip exactly).
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 => Some(*n as i64),
            _ => None,
        }
    }

    /// As usize.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    /// As string slice.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        if self.pos + 4 > self.bytes.len() {
                            return Err(self.err("bad \\u escape"));
                        }
                        let hex =
                            std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|_| self.err("bad \\u escape"))?;
                        self.pos += 4;
                        s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-assemble the UTF-8 multibyte sequence.
                    let start = self.pos - 1;
                    let width = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + width).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Serialize a [`Json`] value (compact form, keys sorted — BTreeMap order).
pub fn to_string(v: &Json) -> String {
    let mut s = String::new();
    write_json(v, &mut s);
    s
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '\r' => out.push_str("\\r"),
                    c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(item, out);
            }
            out.push(']');
        }
        Json::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(&Json::Str(k.clone()), out);
                out.push(':');
                write_json(val, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_i64(), Some(1));
        assert_eq!(arr[2].get("b").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn parse_manifest_like() {
        let src = r#"[{"name":"mnist","hlo":"mnist_t8_b1.hlo.txt","batch":1,
                      "num_steps":8,"in_channels":1,"in_size":28,"pallas":true}]"#;
        let v = Json::parse(src).unwrap();
        let e = &v.as_arr().unwrap()[0];
        assert_eq!(e.get("num_steps").unwrap().as_usize(), Some(8));
        assert_eq!(e.get("pallas").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"x"],"b":{"c":null,"d":true}}"#;
        let v = Json::parse(src).unwrap();
        let s = to_string(&v);
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u0041\\u00e9\"").unwrap(),
            Json::Str("Aé".to_string())
        );
    }
}

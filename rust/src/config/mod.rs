//! Configuration system: JSON parsing, hardware config, model presets.

pub mod hw;
pub mod json;
pub mod models;

pub use hw::HwConfig;
pub use models::{LayerKind, LayerSpec, ModelSpec};

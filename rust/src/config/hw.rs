//! Hardware configuration of the VSA chip (paper §III, Table III).
//!
//! Every dimension of the accelerator is a config knob ("reconfigurable"
//! in the paper's sense: different models, different inference time steps,
//! encoding layer on/off, layer fusion on/off), with the published design
//! point as the default.

use crate::config::json::Json;

/// Full chip configuration.  Defaults reproduce the paper's design point:
/// 32 PE blocks x 3 PE arrays x (8 x 3) PEs = 2304 PEs, 500 MHz, 40 nm.
#[derive(Debug, Clone, PartialEq)]
pub struct HwConfig {
    /// Number of PE blocks; each block processes one input channel of one
    /// time step (32 in the paper).
    pub pe_blocks: usize,
    /// PE arrays per block (3 in the paper — one per filter column).
    pub arrays_per_block: usize,
    /// PE rows per array: input-vector height processed per cycle
    /// (8 in the paper).
    pub rows_per_array: usize,
    /// PE columns per array: filter taps per column (3 in the paper,
    /// matching the 3x3 kernels).
    pub cols_per_array: usize,
    /// Clock frequency in MHz (500 in the paper).
    pub freq_mhz: f64,
    /// Technology node in nm (40 in the paper).
    pub tech_nm: f64,
    /// Supply voltage in volts (0.9 in the paper).
    pub voltage: f64,
    /// Weight SRAM capacity in KiB (sized for two layers — layer fusion).
    pub weight_sram_kb: f64,
    /// Spike ping-pong SRAM capacity in KiB (both banks).
    pub spike_sram_kb: f64,
    /// Membrane SRAMs in KiB (two banks, §III-F).
    pub membrane_sram_kb: f64,
    /// Temp (output spike) SRAM in KiB.
    pub temp_sram_kb: f64,
    /// Boundary SRAM in KiB (tile-edge partial sums, §III-C).
    pub boundary_sram_kb: f64,
    /// Two-layer fusion enabled (§III-G).
    pub layer_fusion: bool,
    /// Bitplanes for the encoding layer (8 = u8 inputs).
    pub encode_bitplanes: usize,
    /// Off-chip DRAM energy per byte, pJ (energy model input).
    pub dram_pj_per_byte: f64,
}

impl Default for HwConfig {
    fn default() -> Self {
        // SRAM budget totals 230.3125 KB as reported in Table III.
        Self {
            pe_blocks: 32,
            arrays_per_block: 3,
            rows_per_array: 8,
            cols_per_array: 3,
            freq_mhz: 500.0,
            tech_nm: 40.0,
            voltage: 0.9,
            weight_sram_kb: 96.0,
            spike_sram_kb: 64.0,
            membrane_sram_kb: 48.0,
            temp_sram_kb: 16.0,
            boundary_sram_kb: 6.3125,
            layer_fusion: true,
            encode_bitplanes: 8,
            dram_pj_per_byte: 20.0,
        }
    }
}

impl HwConfig {
    /// Total PE count (2304 at the paper's design point).
    pub fn total_pes(&self) -> usize {
        self.pe_blocks * self.arrays_per_block * self.rows_per_array * self.cols_per_array
    }

    /// Peak throughput in GOPS: every PE does one MAC (2 ops) per cycle.
    /// 2304 PEs x 0.5 GHz x 2 = 2304 GOPS — Table III's headline number.
    pub fn peak_gops(&self) -> f64 {
        self.total_pes() as f64 * self.freq_mhz * 1e6 * 2.0 / 1e9
    }

    /// Weight SRAM capacity in bits (the budget `plan_fusion` packs into).
    pub fn weight_sram_bits(&self) -> u64 {
        (self.weight_sram_kb * 1024.0 * 8.0) as u64
    }

    /// Per-bank capacity of the ping-pong spike SRAM in bits
    /// (`spike_sram_kb` counts both banks, Table III).
    pub fn spike_bank_bits(&self) -> u64 {
        (self.spike_sram_kb / 2.0 * 1024.0 * 8.0) as u64
    }

    /// Compact, stable signature naming every DSE-swept knob.  Used as the
    /// deterministic Pareto tie-break and as the report label, so two runs
    /// of the same sweep always order identical candidates identically.
    /// Float knobs print exactly (`{}`), not rounded: distinct configs
    /// must never share a signature.
    pub fn signature(&self) -> String {
        format!(
            "{}x{}x({}x{}) f{} w{} sp{} bp{} {}",
            self.pe_blocks,
            self.arrays_per_block,
            self.rows_per_array,
            self.cols_per_array,
            self.freq_mhz,
            self.weight_sram_kb,
            self.spike_sram_kb,
            self.encode_bitplanes,
            if self.layer_fusion { "fuse" } else { "nofuse" }
        )
    }

    /// Total on-chip SRAM in KiB.
    pub fn total_sram_kb(&self) -> f64 {
        self.weight_sram_kb
            + self.spike_sram_kb
            + self.membrane_sram_kb
            + self.temp_sram_kb
            + self.boundary_sram_kb
    }

    /// Parse from a JSON object; missing fields keep their defaults.
    pub fn from_json(v: &Json) -> Result<Self, String> {
        let mut cfg = Self::default();
        let obj = match v {
            Json::Obj(_) => v,
            _ => return Err("hw config must be a JSON object".into()),
        };
        macro_rules! take_usize {
            ($field:ident) => {
                if let Some(x) = obj.get(stringify!($field)) {
                    cfg.$field = x
                        .as_usize()
                        .ok_or(concat!(stringify!($field), " must be a non-negative integer"))?;
                }
            };
        }
        macro_rules! take_f64 {
            ($field:ident) => {
                if let Some(x) = obj.get(stringify!($field)) {
                    cfg.$field = x
                        .as_f64()
                        .ok_or(concat!(stringify!($field), " must be a number"))?;
                }
            };
        }
        take_usize!(pe_blocks);
        take_usize!(arrays_per_block);
        take_usize!(rows_per_array);
        take_usize!(cols_per_array);
        take_usize!(encode_bitplanes);
        take_f64!(freq_mhz);
        take_f64!(tech_nm);
        take_f64!(voltage);
        take_f64!(weight_sram_kb);
        take_f64!(spike_sram_kb);
        take_f64!(membrane_sram_kb);
        take_f64!(temp_sram_kb);
        take_f64!(boundary_sram_kb);
        take_f64!(dram_pj_per_byte);
        if let Some(x) = obj.get("layer_fusion") {
            cfg.layer_fusion = x.as_bool().ok_or("layer_fusion must be a bool")?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Reject degenerate configurations.
    pub fn validate(&self) -> Result<(), String> {
        if self.pe_blocks == 0 || self.arrays_per_block == 0 {
            return Err("PE geometry must be non-zero".into());
        }
        if self.rows_per_array == 0 || self.cols_per_array == 0 {
            return Err("PE array geometry must be non-zero".into());
        }
        if self.freq_mhz <= 0.0 {
            return Err("frequency must be positive".into());
        }
        if self.encode_bitplanes == 0 || self.encode_bitplanes > 16 {
            return Err("encode_bitplanes must be in 1..=16".into());
        }
        if self.weight_sram_kb <= 0.0 || self.spike_sram_kb <= 0.0 {
            return Err("weight and spike SRAM capacities must be positive".into());
        }
        if self.membrane_sram_kb < 0.0 || self.temp_sram_kb < 0.0 || self.boundary_sram_kb < 0.0 {
            return Err("SRAM capacities must be non-negative".into());
        }
        Ok(())
    }

    /// Load from a JSON file.
    pub fn from_file(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        let v = Json::parse(&text).map_err(|e| e.to_string())?;
        Self::from_json(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_design_point() {
        let cfg = HwConfig::default();
        assert_eq!(cfg.total_pes(), 2304);
        assert!((cfg.peak_gops() - 2304.0).abs() < 1e-9);
        assert!((cfg.total_sram_kb() - 230.3125).abs() < 1e-9);
    }

    #[test]
    fn from_json_overrides() {
        let v = Json::parse(r#"{"pe_blocks": 16, "freq_mhz": 200, "layer_fusion": false}"#)
            .unwrap();
        let cfg = HwConfig::from_json(&v).unwrap();
        assert_eq!(cfg.pe_blocks, 16);
        assert_eq!(cfg.freq_mhz, 200.0);
        assert!(!cfg.layer_fusion);
        // untouched fields keep defaults
        assert_eq!(cfg.rows_per_array, 8);
    }

    #[test]
    fn rejects_degenerate() {
        let v = Json::parse(r#"{"pe_blocks": 0}"#).unwrap();
        assert!(HwConfig::from_json(&v).is_err());
        let v = Json::parse(r#"{"encode_bitplanes": 99}"#).unwrap();
        assert!(HwConfig::from_json(&v).is_err());
        let v = Json::parse(r#"{"weight_sram_kb": 0}"#).unwrap();
        assert!(HwConfig::from_json(&v).is_err());
    }

    #[test]
    fn sram_bit_budgets() {
        let cfg = HwConfig::default();
        assert_eq!(cfg.weight_sram_bits(), 96 * 1024 * 8);
        // ping-pong: half of the 64 KiB total per bank
        assert_eq!(cfg.spike_bank_bits(), 32 * 1024 * 8);
    }

    #[test]
    fn signature_is_stable_and_distinguishes_knobs() {
        let a = HwConfig::default();
        assert_eq!(a.signature(), "32x3x(8x3) f500 w96 sp64 bp8 fuse");
        let b = HwConfig { layer_fusion: false, ..HwConfig::default() };
        assert_ne!(a.signature(), b.signature());
    }
}

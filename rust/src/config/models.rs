//! Network descriptions (paper Table I) — the rust twin of
//! `python/compile/model.py::ModelSpec`.

/// Layer type in a Table-I network.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    /// Encoding conv layer: multi-bit input, bitplane datapath (§III-E).
    EncConv,
    /// Spiking conv layer: binary spikes in, binary spikes out.
    Conv,
    /// 2x2/2 max pool (OR on spikes).
    MaxPool,
    /// Spiking fully-connected layer.
    Fc,
    /// Final non-firing accumulation layer (logits).
    Readout,
}

/// One layer of a network.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerSpec {
    pub kind: LayerKind,
    /// Output channels / neurons (0 for pools).
    pub c_out: usize,
    /// Conv kernel size (3 everywhere in the paper).
    pub ksize: usize,
}

impl LayerSpec {
    fn conv(kind: LayerKind, c_out: usize) -> Self {
        Self { kind, c_out, ksize: 3 }
    }
    fn pool() -> Self {
        Self { kind: LayerKind::MaxPool, c_out: 0, ksize: 0 }
    }
    fn dense(kind: LayerKind, c_out: usize) -> Self {
        Self { kind, c_out, ksize: 0 }
    }
}

/// A full network: geometry + layers + time steps.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    pub in_channels: usize,
    pub in_size: usize,
    pub layers: Vec<LayerSpec>,
    pub num_steps: usize,
}

impl ModelSpec {
    /// (C, H, W) feature shape *entering* each layer.
    pub fn feature_shapes(&self) -> Vec<(usize, usize, usize)> {
        let mut shapes = Vec::with_capacity(self.layers.len());
        let (mut c, mut s) = (self.in_channels, self.in_size);
        for ly in &self.layers {
            shapes.push((c, s, s));
            match ly.kind {
                LayerKind::EncConv | LayerKind::Conv => c = ly.c_out,
                LayerKind::MaxPool => s /= 2,
                LayerKind::Fc | LayerKind::Readout => {
                    c = ly.c_out;
                    s = 1;
                }
            }
        }
        shapes
    }

    /// Binary weight bits of the whole model.
    pub fn weight_bits(&self) -> usize {
        let shapes = self.feature_shapes();
        self.layers
            .iter()
            .zip(&shapes)
            .map(|(ly, &(c_in, h, w))| match ly.kind {
                LayerKind::EncConv | LayerKind::Conv => ly.c_out * c_in * ly.ksize * ly.ksize,
                LayerKind::Fc | LayerKind::Readout => ly.c_out * c_in * h * w,
                LayerKind::MaxPool => 0,
            })
            .sum()
    }

    /// Total MAC operations for one inference at `num_steps` time steps
    /// (conv layers run per step; the encoding conv runs once, §III-F).
    pub fn macs_per_inference(&self) -> u64 {
        let shapes = self.feature_shapes();
        let t = self.num_steps as u64;
        self.layers
            .iter()
            .zip(&shapes)
            .map(|(ly, &(c_in, h, w))| match ly.kind {
                LayerKind::EncConv => {
                    (ly.c_out * c_in * ly.ksize * ly.ksize * h * w) as u64
                }
                LayerKind::Conv => {
                    (ly.c_out * c_in * ly.ksize * ly.ksize * h * w) as u64 * t
                }
                LayerKind::Fc | LayerKind::Readout => (ly.c_out * c_in * h * w) as u64 * t,
                LayerKind::MaxPool => 0,
            })
            .sum()
    }
}

/// MNIST network (Table I): 64Conv(enc)-MP2-64Conv-MP2-128fc-10fc.
pub fn mnist(num_steps: usize) -> ModelSpec {
    ModelSpec {
        name: "mnist".into(),
        in_channels: 1,
        in_size: 28,
        layers: vec![
            LayerSpec::conv(LayerKind::EncConv, 64),
            LayerSpec::pool(),
            LayerSpec::conv(LayerKind::Conv, 64),
            LayerSpec::pool(),
            LayerSpec::dense(LayerKind::Fc, 128),
            LayerSpec::dense(LayerKind::Readout, 10),
        ],
        num_steps,
    }
}

/// CIFAR-10 network (Table I): 128C(enc)-128C-128C-MP2-192Cx4-MP2-256Cx4-
/// MP2-256fc-10fc.
pub fn cifar10(num_steps: usize) -> ModelSpec {
    let mut layers = Vec::new();
    let plan: &[i64] = &[128, 128, 128, -1, 192, 192, 192, 192, -1, 256, 256, 256, 256, -1];
    let mut first = true;
    for &p in plan {
        if p < 0 {
            layers.push(LayerSpec::pool());
        } else if first {
            layers.push(LayerSpec::conv(LayerKind::EncConv, p as usize));
            first = false;
        } else {
            layers.push(LayerSpec::conv(LayerKind::Conv, p as usize));
        }
    }
    layers.push(LayerSpec::dense(LayerKind::Fc, 256));
    layers.push(LayerSpec::dense(LayerKind::Readout, 10));
    ModelSpec {
        name: "cifar10".into(),
        in_channels: 3,
        in_size: 32,
        layers,
        num_steps,
    }
}

/// Tiny test network — mirrors `python/compile/model.py::tiny_spec`.
pub fn tiny(num_steps: usize) -> ModelSpec {
    ModelSpec {
        name: "tiny".into(),
        in_channels: 1,
        in_size: 12,
        layers: vec![
            LayerSpec::conv(LayerKind::EncConv, 16),
            LayerSpec::pool(),
            LayerSpec::conv(LayerKind::Conv, 32),
            LayerSpec::pool(),
            LayerSpec::dense(LayerKind::Fc, 64),
            LayerSpec::dense(LayerKind::Readout, 10),
        ],
        num_steps,
    }
}

/// Micro network — the smallest spec with every layer kind the trainer
/// supports; sized so STBP gradient tests and CI train smokes run in
/// debug-mode milliseconds.
pub fn micro(num_steps: usize) -> ModelSpec {
    ModelSpec {
        name: "micro".into(),
        in_channels: 1,
        in_size: 8,
        layers: vec![
            LayerSpec::conv(LayerKind::EncConv, 8),
            LayerSpec::pool(),
            LayerSpec::dense(LayerKind::Fc, 32),
            LayerSpec::dense(LayerKind::Readout, 10),
        ],
        num_steps,
    }
}

/// Look up a preset by name.
pub fn by_name(name: &str, num_steps: usize) -> Option<ModelSpec> {
    match name {
        "mnist" => Some(mnist(num_steps)),
        "cifar10" => Some(cifar10(num_steps)),
        "tiny" => Some(tiny(num_steps)),
        "micro" => Some(micro(num_steps)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mnist_table1() {
        let m = mnist(8);
        assert_eq!(m.layers.len(), 6);
        let shapes = m.feature_shapes();
        assert_eq!(shapes[0], (1, 28, 28));
        assert_eq!(shapes[2], (64, 14, 14));
        assert_eq!(shapes[4], (64, 7, 7)); // fc sees 3136 inputs
    }

    #[test]
    fn cifar10_table1() {
        let m = cifar10(8);
        let convs: Vec<usize> = m
            .layers
            .iter()
            .filter(|l| matches!(l.kind, LayerKind::Conv | LayerKind::EncConv))
            .map(|l| l.c_out)
            .collect();
        assert_eq!(convs, vec![128, 128, 128, 192, 192, 192, 192, 256, 256, 256, 256]);
        let shapes = m.feature_shapes();
        assert_eq!(*shapes.last().unwrap(), (256, 1, 1));
        assert_eq!(shapes[shapes.len() - 2], (256, 4, 4)); // fc in = 4096
    }

    #[test]
    fn macs_scale_with_time_steps() {
        let a = cifar10(1).macs_per_inference();
        let b = cifar10(8).macs_per_inference();
        assert!(b > 6 * a && b < 8 * a); // encoding conv amortized across T
    }

    #[test]
    fn weight_bits_reasonable() {
        // MNIST: 64*1*9 + 64*64*9 + 128*3136 + 10*128 = 440,000 bits.
        assert_eq!(mnist(8).weight_bits(), 64 * 9 + 64 * 64 * 9 + 128 * 3136 + 10 * 128);
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("mnist", 8).is_some());
        assert!(by_name("micro", 2).is_some());
        assert!(by_name("nope", 8).is_none());
    }

    #[test]
    fn micro_shapes() {
        let m = micro(2);
        let shapes = m.feature_shapes();
        assert_eq!(shapes[0], (1, 8, 8));
        assert_eq!(shapes[2], (8, 4, 4)); // fc sees 128 inputs
        assert_eq!(*shapes.last().unwrap(), (32, 1, 1));
    }
}

//! Hand-rolled command-line parsing (clap is unavailable offline).
//!
//! Grammar: `vsa <command> [--flag value]... [--switch]... [positional]...`
//! Flags may use `--key value` or `--key=value`.

use std::collections::BTreeMap;
use std::time::Duration;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    flags: BTreeMap<String, String>,
    /// Every flag occurrence in argv order — `flags` keeps only the last
    /// value per key, this keeps them all (PR9: repeatable `--model`).
    occurrences: Vec<(String, String)>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse(raw: impl IntoIterator<Item = String>) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        if let Some(cmd) = it.next() {
            if cmd.starts_with('-') {
                return Err(format!("expected a command, got flag '{cmd}'"));
            }
            out.command = cmd;
        }
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if stripped.is_empty() {
                    return Err("bare '--' is not supported".into());
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                    out.occurrences.push((k.to_string(), v.to_string()));
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    let v = it.next().unwrap();
                    out.flags.insert(stripped.to_string(), v.clone());
                    out.occurrences.push((stripped.to_string(), v));
                } else {
                    out.switches.push(stripped.to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// String flag with default.
    pub fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Optional string flag.
    pub fn get_opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    /// Every occurrence of a repeatable flag, in argv order (empty when
    /// the flag never appears).  `get`/`get_opt` see only the last one.
    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.occurrences.iter().filter(|(k, _)| k == key).map(|(_, v)| v.as_str()).collect()
    }

    /// Mandatory string flag — errors with the flag name when absent.
    pub fn require(&self, key: &str) -> anyhow::Result<String> {
        self.flags
            .get(key)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("missing required flag --{key}"))
    }

    /// Parse a typed flag with a default; `what` names the expected type
    /// in the error message.
    fn get_parsed<T: std::str::FromStr>(
        &self,
        key: &str,
        default: T,
        what: &str,
    ) -> anyhow::Result<T> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects {what}, got '{v}'")),
        }
    }

    /// usize flag with default.
    pub fn get_usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        self.get_parsed(key, default, "an integer")
    }

    /// u64 flag with default.
    pub fn get_u64(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        self.get_parsed(key, default, "an integer")
    }

    /// f64 flag with default.
    pub fn get_f64(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        self.get_parsed(key, default, "a number")
    }

    /// Duration flag expressed in (possibly fractional) milliseconds.
    pub fn get_millis(&self, key: &str, default: Duration) -> anyhow::Result<Duration> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => {
                let ms: f64 = v
                    .parse()
                    .map_err(|_| anyhow::anyhow!("--{key} expects milliseconds, got '{v}'"))?;
                anyhow::ensure!(ms.is_finite() && ms >= 0.0, "--{key} must be >= 0, got '{v}'");
                Ok(Duration::from_secs_f64(ms / 1e3))
            }
        }
    }

    /// Boolean switch (present or not).
    pub fn has(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn command_flags_switches_positional() {
        // note: a bare `--switch` followed by a non-flag token would bind
        // as `--flag value`; positionals therefore come before switches.
        let a = parse("simulate --model cifar10 --steps=8 extra --no-fusion");
        assert_eq!(a.command, "simulate");
        assert_eq!(a.get("model", "tiny"), "cifar10");
        assert_eq!(a.get_usize("steps", 4).unwrap(), 8);
        assert!(a.has("no-fusion"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("serve");
        assert_eq!(a.get("model", "mnist"), "mnist");
        assert_eq!(a.get_usize("workers", 2).unwrap(), 2);
        assert!(!a.has("verbose"));
    }

    #[test]
    fn trailing_switch_then_flag() {
        let a = parse("x --fast --n 3");
        assert!(a.has("fast"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 3);
    }

    #[test]
    fn bad_integer_reports_error() {
        let a = parse("x --n abc");
        assert!(a.get_usize("n", 0).is_err());
    }

    #[test]
    fn require_names_the_missing_flag() {
        let a = parse("eval --count 4");
        assert_eq!(a.require("count").unwrap(), "4");
        let err = a.require("weights").unwrap_err().to_string();
        assert!(err.contains("--weights"), "error should name the flag: {err}");
    }

    #[test]
    fn millis_flag_parses_fractional_and_rejects_junk() {
        let a = parse("serve --deadline-ms 2.5 --bad-ms oops --neg-ms -1");
        let d = a.get_millis("deadline-ms", Duration::ZERO).unwrap();
        assert_eq!(d, Duration::from_micros(2500));
        let fallback = Duration::from_millis(7);
        assert_eq!(a.get_millis("absent-ms", fallback).unwrap(), fallback);
        assert!(a.get_millis("bad-ms", Duration::ZERO).is_err());
        assert!(a.get_millis("neg-ms", Duration::ZERO).is_err());
    }

    #[test]
    fn flag_as_command_rejected() {
        assert!(Args::parse(vec!["--oops".to_string()]).is_err());
    }

    #[test]
    fn repeated_flags_keep_every_occurrence_in_order() {
        let a = parse("serve --model a=x.vsaw --workers 2 --model b=y.vsaw --model tiny");
        assert_eq!(a.get_all("model"), vec!["a=x.vsaw", "b=y.vsaw", "tiny"]);
        assert_eq!(a.get("model", "-"), "tiny", "get() sees the last occurrence");
        assert_eq!(a.get_all("workers"), vec!["2"]);
        assert!(a.get_all("absent").is_empty());
    }
}

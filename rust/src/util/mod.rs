//! Shared substrate: PRNG, bit vectors, statistics, fixed-point helpers.

pub mod bitvec;
pub mod rng;
pub mod stats;

/// Fixed-point scale for IF-BN bias/threshold quantization.  Must match
/// `python/compile/kernels/ref.py::FIXED_POINT`: membrane arithmetic is
/// `FIXED_POINT * conv_out - bias_q` compared against `theta_q`.
pub const FIXED_POINT: i32 = 256;

/// Ceiling division for unsigned sizes.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 8), 0);
        assert_eq!(ceil_div(1, 8), 1);
        assert_eq!(ceil_div(8, 8), 1);
        assert_eq!(ceil_div(9, 8), 2);
    }
}

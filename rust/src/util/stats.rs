//! Small statistics helpers shared by benchmarks, metrics and reports.

/// Index of the maximum element under `gt` (first on ties).  The one
/// argmax implementation behind [`argmax`] and [`argmax_f32`].
fn argmax_by<T: Copy>(xs: &[T], gt: impl Fn(T, T) -> bool) -> usize {
    assert!(!xs.is_empty(), "argmax of empty slice");
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate().skip(1) {
        if gt(x, xs[best]) {
            best = i;
        }
    }
    best
}

/// Index of the maximum element (first on ties). Panics on empty input.
pub fn argmax(xs: &[i64]) -> usize {
    argmax_by(xs, |a, b| a > b)
}

/// f32 argmax under the IEEE total order (first on ties).  NaN-safe:
/// `>` on floats is false whenever either side is NaN, so a plain
/// comparison loop silently returns index 0 for a NaN-led slice —
/// `total_cmp` keeps the scan deterministic (positive NaN orders above
/// +inf, negative NaN below -inf).  Callers that must reject diverged
/// rows scan the whole row for non-finite values rather than just the
/// selected element (see `train::count_correct`).
pub fn argmax_f32(xs: &[f32]) -> usize {
    argmax_by(xs, |a, b| a.total_cmp(&b).is_gt())
}

/// Arithmetic mean of f64 samples (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// `q`-quantile (0..=1) by nearest-rank on a sorted copy.  Sorts with the
/// IEEE total order, so NaN samples sort last instead of panicking.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    quantile_sorted(&v, q)
}

/// Nearest-rank quantile of an already-sorted (ascending) slice — use
/// when several quantiles come from one sort (see
/// [`Accumulator::percentiles`]).
///
/// Conventions shared with `telemetry::HistogramSketch::quantile_ns`
/// (cross-checked in `tests/telemetry.rs` so reports can't mix two
/// percentile definitions): empty input returns 0.0, rank is
/// `round((len-1) * q)`, and `q` is clamped to `[0, 1]` with NaN
/// reading as 0 — an out-of-range `q` used to index out of bounds and
/// panic.
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let q = if q.is_nan() { 0.0 } else { q.clamp(0.0, 1.0) };
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Online latency/throughput accumulator used by the coordinator metrics.
#[derive(Debug, Default, Clone)]
pub struct Accumulator {
    samples: Vec<f64>,
}

impl Accumulator {
    /// Record one sample.
    pub fn push(&mut self, v: f64) {
        self.samples.push(v);
    }

    /// Number of samples recorded.
    pub fn count(&self) -> usize {
        self.samples.len()
    }

    /// Mean of recorded samples.
    pub fn mean(&self) -> f64 {
        mean(&self.samples)
    }

    /// p50/p95/p99 summary from a single sorted copy of the samples.
    pub fn percentiles(&self) -> (f64, f64, f64) {
        let mut v = self.samples.clone();
        v.sort_by(f64::total_cmp);
        (
            quantile_sorted(&v, 0.50),
            quantile_sorted(&v, 0.95),
            quantile_sorted(&v, 0.99),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_ties_prefer_first() {
        assert_eq!(argmax(&[1, 5, 5, 2]), 1);
        assert_eq!(argmax(&[-3]), 0);
        assert_eq!(argmax_f32(&[1.0, 5.0, 5.0, 2.0]), 1);
        assert_eq!(argmax_f32(&[-3.0]), 0);
    }

    #[test]
    fn argmax_f32_is_nan_safe() {
        // The old `>` loop returned 0 whenever xs[0] was NaN; under the
        // total order the true maximum of the finite tail still loses
        // only to NaN itself, deterministically.
        assert_eq!(argmax_f32(&[f32::NAN, 1.0, 2.0]), 0, "NaN orders above +inf");
        assert_eq!(argmax_f32(&[1.0, f32::NAN, 2.0]), 1);
        assert_eq!(argmax_f32(&[1.0, 3.0, 2.0]), 1, "finite path unchanged");
        assert_eq!(argmax_f32(&[f32::NEG_INFINITY, f32::INFINITY]), 1);
    }

    #[test]
    fn mean_stddev() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn quantiles() {
        let xs: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 100.0);
        assert_eq!(quantile(&xs, 0.5), 51.0); // round(49.5) -> index 50
    }

    #[test]
    fn quantile_clamps_out_of_range_q() {
        let xs = [1.0, 2.0, 3.0];
        assert_eq!(quantile(&xs, 1.5), 3.0, "q > 1 used to panic");
        assert_eq!(quantile(&xs, -0.5), 1.0);
        assert_eq!(quantile(&xs, f64::NAN), 1.0, "NaN q reads as 0");
    }

    #[test]
    fn quantile_tolerates_nan() {
        // NaN sorts last under the total order — no panic, and the lower
        // quantiles still see the finite samples.
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert!(quantile(&xs, 1.0).is_nan());
    }

    #[test]
    fn percentiles_match_single_quantiles() {
        let mut acc = Accumulator::default();
        for i in (1..=100).rev() {
            acc.push(i as f64);
        }
        let (p50, p95, p99) = acc.percentiles();
        assert_eq!(p50, 51.0);
        assert_eq!(p95, 95.0);
        assert_eq!(p99, 99.0);
    }

    #[test]
    fn accumulator_summary() {
        let mut acc = Accumulator::default();
        for i in 1..=10 {
            acc.push(i as f64);
        }
        assert_eq!(acc.count(), 10);
        assert_eq!(acc.mean(), 5.5);
    }
}

//! splitmix64 — the cross-language deterministic PRNG.
//!
//! Bit-identical to `python/compile/datasets.py::splitmix64`; the synthetic
//! datasets on both sides of the stack are generated from this sequence, so
//! integration tests can compare logits computed in JAX against the rust
//! golden model on the *same* images.

/// splitmix64 state machine (public domain algorithm, Steele et al.).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Seed the generator with an arbitrary 64-bit state.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform value in `[0, n)` via modulo (bias is irrelevant for the
    /// synthetic-data use case and must match the python side exactly).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn next_index(&mut self, n: usize) -> usize {
        (self.next_below(n as u64)) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Cross-language anchor values — the python test suite asserts the
    /// same two outputs (tests/test_model.py::test_splitmix64_known_values).
    #[test]
    fn known_sequence_matches_python() {
        let mut rng = SplitMix64::new(0);
        assert_eq!(rng.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(rng.next_u64(), 0x6E78_9E6A_A1B9_65F4);
    }

    #[test]
    fn next_below_in_range() {
        let mut rng = SplitMix64::new(123);
        for _ in 0..1000 {
            assert!(rng.next_below(7) < 7);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = SplitMix64::new(9);
        for _ in 0..1000 {
            let v = rng.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }
}

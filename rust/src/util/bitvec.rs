//! Dense bit vectors packed into u64 words.
//!
//! The whole hot path of both the golden model and the cycle-accurate
//! simulator works on channel-packed spike words: a binary multiply with
//! +-1 weights followed by a sum reduces to popcounts
//! (`sum = popcnt(spikes) - 2 * popcnt(spikes & w_neg)`), which is the
//! software analogue of the chip's AND-gate PEs + diagonal adders.

/// A fixed-length bit vector stored as little-endian u64 words.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitVec {
    len: usize,
    words: Vec<u64>,
}

impl BitVec {
    /// All-zero bit vector of `len` bits.
    pub fn zeros(len: usize) -> Self {
        Self {
            len,
            words: vec![0; len.div_ceil(64)],
        }
    }

    /// Number of bits.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no bits are stored.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Backing words (the last word's unused high bits are always zero).
    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Set bit `i` to `v`.
    #[inline]
    pub fn set(&mut self, i: usize, v: bool) {
        debug_assert!(i < self.len);
        let (w, b) = (i / 64, i % 64);
        if v {
            self.words[w] |= 1 << b;
        } else {
            self.words[w] &= !(1 << b);
        }
    }

    /// Read bit `i`.
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Number of set bits.
    #[inline]
    pub fn count_ones(&self) -> u32 {
        self.words.iter().map(|w| w.count_ones()).sum()
    }

    /// popcnt(self AND other) — the binary-conv primitive.
    #[inline]
    pub fn and_popcount(&self, other: &BitVec) -> u32 {
        debug_assert_eq!(self.len, other.len);
        self.words
            .iter()
            .zip(&other.words)
            .map(|(a, b)| (a & b).count_ones())
            .sum()
    }

    /// Build from an iterator of bools.
    pub fn from_bools(bits: impl IntoIterator<Item = bool>) -> Self {
        let bits: Vec<bool> = bits.into_iter().collect();
        let mut v = Self::zeros(bits.len());
        for (i, b) in bits.iter().enumerate() {
            if *b {
                v.set(i, true);
            }
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    #[test]
    fn set_get_roundtrip() {
        let mut v = BitVec::zeros(130);
        v.set(0, true);
        v.set(64, true);
        v.set(129, true);
        assert!(v.get(0) && v.get(64) && v.get(129));
        assert!(!v.get(1) && !v.get(63) && !v.get(128));
        assert_eq!(v.count_ones(), 3);
        v.set(64, false);
        assert_eq!(v.count_ones(), 2);
    }

    #[test]
    fn and_popcount_matches_naive() {
        let mut rng = SplitMix64::new(5);
        for _ in 0..20 {
            let n = 1 + rng.next_index(200);
            let a = BitVec::from_bools((0..n).map(|_| rng.next_below(2) == 1));
            let b = BitVec::from_bools((0..n).map(|_| rng.next_below(2) == 1));
            let naive = (0..n).filter(|&i| a.get(i) && b.get(i)).count() as u32;
            assert_eq!(a.and_popcount(&b), naive);
        }
    }

    #[test]
    fn unused_high_bits_stay_zero() {
        let v = BitVec::from_bools((0..65).map(|_| true));
        assert_eq!(v.count_ones(), 65);
        assert_eq!(v.words()[1], 1); // only bit 0 of word 1
    }
}

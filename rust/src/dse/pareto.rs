//! Pareto-frontier extraction over (throughput up, power down, area down).

use crate::dse::evaluate::CandidateResult;

/// `a` dominates `b`: no worse in every objective and strictly better in
/// at least one.  The measured accuracy objective (present when the
/// sweep ran against a trained artifact) participates whenever both
/// sides carry it — so a lower-T candidate no longer dominates "for
/// free": it must also not lose accuracy (the paper's Fig. 8
/// trade-off).  Accuracy is ignored when either side lacks it.
pub fn dominates(a: &CandidateResult, b: &CandidateResult) -> bool {
    let mut no_worse = a.throughput_ips >= b.throughput_ips
        && a.power_mw <= b.power_mw
        && a.area_kge <= b.area_kge;
    let mut strictly = a.throughput_ips > b.throughput_ips
        || a.power_mw < b.power_mw
        || a.area_kge < b.area_kge;
    if let (Some(aa), Some(ab)) = (a.accuracy, b.accuracy) {
        no_worse = no_worse && aa >= ab;
        strictly = strictly || aa > ab;
    }
    no_worse && strictly
}

/// Indices (into `results`) of the non-dominated set, sorted by
/// (throughput desc, power asc, area asc, accuracy desc, candidate id
/// asc).  The id is unique per design point, so the sort key is a total
/// order and the frontier is byte-for-byte reproducible across runs and
/// thread counts.  Every objective in [`dominates`] appears in the key
/// (missing accuracy compares equal), preserving the invariant the
/// prefix scan below depends on: a dominator sorts strictly earlier.
pub fn frontier(results: &[CandidateResult]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..results.len()).collect();
    idx.sort_by(|&a, &b| {
        let (ra, rb) = (&results[a], &results[b]);
        rb.throughput_ips
            .total_cmp(&ra.throughput_ips)
            .then(ra.power_mw.total_cmp(&rb.power_mw))
            .then(ra.area_kge.total_cmp(&rb.area_kge))
            .then(
                rb.accuracy
                    .unwrap_or(f64::NEG_INFINITY)
                    .total_cmp(&ra.accuracy.unwrap_or(f64::NEG_INFINITY)),
            )
            .then_with(|| ra.candidate.id().cmp(&rb.candidate.id()))
    });
    // Any dominator sorts strictly earlier under this key (better or equal
    // in each sort component, strictly better in one), and domination is
    // transitive, so comparing against the already-kept prefix suffices —
    // O(n * frontier) instead of O(n^2) full scans.
    let mut kept: Vec<usize> = Vec::with_capacity(idx.len());
    for &i in &idx {
        if !kept.iter().any(|&j| dominates(&results[j], &results[i])) {
            kept.push(i);
        }
    }
    kept
}

/// How far inside the frontier a point sits: the largest relative margin
/// `eps` such that some *other* point is at least `eps` better in every
/// objective simultaneously.  A frontier point has `slack <= 0` (no
/// all-around improver exists); a dominated point has `slack >= 0`.
/// Ties in any objective pin the slack at 0 for both sides, so the
/// paper-point regression test asserts `slack <= tolerance` rather than
/// frontier membership.  The value is floored at -1.0 — which also
/// covers a point with no comparators — keeping it finite for JSON
/// serialization.
pub fn slack(point: &CandidateResult, results: &[CandidateResult]) -> f64 {
    slack_among(point, results.iter())
}

fn slack_among<'a>(
    point: &CandidateResult,
    others: impl Iterator<Item = &'a CandidateResult>,
) -> f64 {
    let id = point.candidate.id();
    let mut worst = -1.0f64;
    for other in others {
        if other.candidate.id() == id {
            continue;
        }
        let gain_thr = (other.throughput_ips - point.throughput_ips) / point.throughput_ips;
        let gain_pow = (point.power_mw - other.power_mw) / point.power_mw;
        let gain_area = (point.area_kge - other.area_kge) / point.area_kge;
        worst = worst.max(gain_thr.min(gain_pow).min(gain_area));
    }
    worst
}

/// Epsilon-dominance slack of the paper's published design point against
/// only the candidates sharing its T.  Chip-vs-chip optimality is only
/// meaningful at a fixed time-step setting: lower-T candidates do
/// strictly less compute and dominate trivially while paying an accuracy
/// cost the analytic model does not score (the paper's Fig. 8
/// accuracy-vs-T trade-off).  `None` when the paper point is not part of
/// `results`.
pub fn paper_slack_at_t(results: &[CandidateResult]) -> Option<f64> {
    let paper = crate::dse::space::Candidate::paper();
    let id = paper.id();
    let point = results.iter().find(|r| r.candidate.id() == id)?;
    Some(slack_among(
        point,
        results.iter().filter(|r| r.candidate.num_steps == paper.num_steps),
    ))
}

/// Index of the result whose candidate id matches, if present.
pub fn find_by_id(results: &[CandidateResult], id: &str) -> Option<usize> {
    results.iter().position(|r| r.candidate.id() == id)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::space::Candidate;

    fn point(id_steps: usize, thr: f64, pow: f64, area: f64) -> CandidateResult {
        // distinct num_steps gives each synthetic point a distinct id
        let mut c = Candidate::paper();
        c.num_steps = id_steps;
        CandidateResult {
            candidate: c,
            per_workload: Vec::new(),
            throughput_ips: thr,
            power_mw: pow,
            area_kge: area,
            tops_per_w: 0.0,
            accuracy: None,
        }
    }

    #[test]
    fn domination_rules() {
        let a = point(1, 10.0, 5.0, 100.0);
        let b = point(2, 8.0, 6.0, 120.0);
        assert!(dominates(&a, &b));
        assert!(!dominates(&b, &a));
        // equal points never dominate each other
        let c = point(3, 10.0, 5.0, 100.0);
        assert!(!dominates(&a, &c) && !dominates(&c, &a));
        // trade-off: faster but hotter — no domination either way
        let d = point(4, 12.0, 7.0, 100.0);
        assert!(!dominates(&a, &d) && !dominates(&d, &a));
    }

    #[test]
    fn accuracy_objective_blocks_free_domination() {
        // a is all-around better on the chip objectives but loses
        // accuracy (the lower-T story): with the objective measured,
        // neither dominates; without it, a dominates.
        let mut a = point(1, 10.0, 5.0, 100.0);
        let mut b = point(2, 8.0, 6.0, 120.0);
        assert!(dominates(&a, &b));
        a.accuracy = Some(0.80);
        b.accuracy = Some(0.95);
        assert!(!dominates(&a, &b) && !dominates(&b, &a));
        // equal chip objectives + better accuracy -> domination
        let mut c = point(3, 10.0, 5.0, 100.0);
        c.accuracy = Some(0.95);
        a.accuracy = Some(0.80);
        assert!(dominates(&c, &a));
        // both on the frontier when accuracy splits them
        let f = frontier(&[a.clone(), b.clone()]);
        assert_eq!(f.len(), 2);
    }

    #[test]
    fn frontier_drops_dominated_only() {
        let pts = vec![
            point(1, 10.0, 5.0, 100.0), // frontier
            point(2, 8.0, 6.0, 120.0),  // dominated by #1
            point(3, 12.0, 7.0, 100.0), // frontier (faster, hotter)
            point(4, 6.0, 2.0, 80.0),   // frontier (slow, cool, small)
        ];
        let f = frontier(&pts);
        assert_eq!(f, vec![2, 0, 3]); // throughput-desc order
    }

    #[test]
    fn frontier_order_is_deterministic_under_ties() {
        // identical objectives, ids differ via num_steps: id order breaks
        // the tie the same way every run
        let pts = vec![point(2, 10.0, 5.0, 100.0), point(1, 10.0, 5.0, 100.0)];
        let f = frontier(&pts);
        assert_eq!(f.len(), 2);
        assert_eq!(f, vec![1, 0]); // "... T1" sorts before "... T2"
    }

    #[test]
    fn slack_signs() {
        let pts = vec![
            point(1, 10.0, 5.0, 100.0),
            point(2, 8.0, 6.0, 120.0),
            point(3, 6.0, 2.0, 80.0),
        ];
        // frontier point: nothing improves on it all-around
        assert!(slack(&pts[0], &pts) <= 0.0);
        // dominated point: #1 beats it by 25% thr / ~17% pow / ~17% area
        let s = slack(&pts[1], &pts);
        assert!(s > 0.16 && s < 0.17, "slack {s}");
        // no comparators: floored at -1.0 (finite, JSON-serializable)
        assert_eq!(slack(&pts[0], &pts[..1]), -1.0);
    }

    #[test]
    fn paper_slack_pins_t() {
        // paper point (T=8, exactly Candidate::paper()'s id) plus an
        // all-around-better T=4 point and an all-around-worse T=8 point
        // (distinct id via a different clock): the pinned slack must
        // ignore the cross-T dominator but count the same-T peer.
        let paper = point(8, 10.0, 5.0, 100.0);
        let faster_t4 = point(4, 20.0, 4.0, 90.0);
        let mut worse_t8 = point(8, 8.0, 6.0, 120.0);
        worse_t8.candidate.hw.freq_mhz = 250.0;
        let pts = vec![paper, faster_t4, worse_t8];
        let s = paper_slack_at_t(&pts).unwrap();
        assert_eq!(s, -0.2, "pinned slack must ignore the T=4 dominator, got {s}");
        assert!(paper_slack_at_t(&pts[1..]).is_none(), "paper point absent");
    }
}

//! Design-space exploration of the reconfigurable VSA chip.
//!
//! The paper's headline claim is reconfigurability — PE geometry, SRAM
//! split, clock, time steps, fusion and the encoding layer are all knobs —
//! but a single published design point.  This subsystem turns the analytic
//! timing model ([`crate::arch::Chip::analyze`]) and the energy/area
//! models ([`crate::energy`]) into a search engine:
//!
//! * [`space`] — a declarative [`space::SearchSpace`] with cartesian and
//!   seeded random-sampling iterators plus validity filtering;
//! * [`evaluate`] — a multi-threaded driver scoring each candidate on
//!   latency/throughput, DRAM traffic, core power, area and TOPS/W per
//!   workload (Table I presets);
//! * [`pareto`] — dominated-point pruning over (throughput, power, area)
//!   with a deterministic total-order tie-break;
//! * [`report`] — JSON output (via `config::json`) and a rendered table
//!   in the style of `energy::report`.
//!
//! Entry points: the `vsa dse` subcommand and
//! `examples/design_space.rs`.  The paper's design point is asserted to
//! lie on (or within a small documented slack of) the extracted frontier
//! by `rust/tests/dse_frontier.rs`.

pub mod evaluate;
pub mod pareto;
pub mod report;
pub mod space;

pub use evaluate::{
    accuracy_by_t, evaluate_all, evaluate_all_with, evaluate_one, evaluate_one_with,
    CandidateResult, WorkloadMetrics,
};
pub use pareto::{dominates, find_by_id, frontier, paper_slack_at_t, slack};
pub use space::{validate, Candidate, SearchSpace};

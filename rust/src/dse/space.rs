//! Declarative search space over the reconfigurable chip's knobs.
//!
//! Every `HwConfig` dimension the paper calls "reconfigurable" is an axis
//! here; the space is the cartesian product of the axis lists.  A
//! [`Candidate`] pairs a hardware configuration with the inference
//! time-step count T (an SNN deployment knob the paper reconfigures per
//! model, so it sweeps alongside the chip).  Candidates are filtered by
//! [`validate`] before evaluation so the analytic timing model is only
//! applied where its assumptions hold.

use std::collections::BTreeSet;

use crate::arch::schedule::{plan_spec, PlanKind};
use crate::config::{models, HwConfig};
use crate::util::rng::SplitMix64;

/// One point of the design space.
#[derive(Debug, Clone, PartialEq)]
pub struct Candidate {
    pub hw: HwConfig,
    /// Inference time steps the workloads run at.
    pub num_steps: usize,
}

impl Candidate {
    /// The paper's published design point (default `HwConfig`, T = 8).
    pub fn paper() -> Self {
        Self { hw: HwConfig::default(), num_steps: 8 }
    }

    /// Stable identifier: the hardware signature plus T.  Lexicographic
    /// order of ids is the deterministic tie-break everywhere in `dse`.
    pub fn id(&self) -> String {
        format!("{} T{}", self.hw.signature(), self.num_steps)
    }
}

/// Axis lists for every swept knob; the space is their cartesian product.
/// Un-swept `HwConfig` fields (tech node, voltage, membrane/temp/boundary
/// SRAMs, DRAM energy) keep their defaults.
#[derive(Debug, Clone)]
pub struct SearchSpace {
    pub name: String,
    pub pe_blocks: Vec<usize>,
    pub arrays_per_block: Vec<usize>,
    pub rows_per_array: Vec<usize>,
    pub cols_per_array: Vec<usize>,
    pub freq_mhz: Vec<f64>,
    pub weight_sram_kb: Vec<f64>,
    pub spike_sram_kb: Vec<f64>,
    pub encode_bitplanes: Vec<usize>,
    pub layer_fusion: Vec<bool>,
    pub num_steps: Vec<usize>,
}

impl SearchSpace {
    /// Laptop-scale grid around the published design point: 648 points,
    /// all 648 valid for MNIST and 432 for CIFAR-10 (the 64 KiB weight
    /// SRAM cannot hold CIFAR-10's largest conv layer).
    pub fn small() -> Self {
        Self {
            name: "small".into(),
            pe_blocks: vec![16, 32, 64],
            arrays_per_block: vec![3],
            rows_per_array: vec![4, 8, 16],
            cols_per_array: vec![3],
            freq_mhz: vec![250.0, 500.0, 800.0],
            weight_sram_kb: vec![64.0, 96.0, 192.0],
            spike_sram_kb: vec![32.0, 64.0],
            encode_bitplanes: vec![8],
            layer_fusion: vec![false, true],
            num_steps: vec![4, 8],
        }
    }

    /// CI-smoke grid: 8 points including the paper's design point.
    pub fn tiny() -> Self {
        Self {
            name: "tiny".into(),
            pe_blocks: vec![16, 32],
            arrays_per_block: vec![3],
            rows_per_array: vec![8],
            cols_per_array: vec![3],
            freq_mhz: vec![250.0, 500.0],
            weight_sram_kb: vec![96.0],
            spike_sram_kb: vec![64.0],
            encode_bitplanes: vec![8],
            layer_fusion: vec![false, true],
            num_steps: vec![8],
        }
    }

    /// Wide space for random sampling (~17k grid points): adds binary
    /// (1-bitplane) encoding, more block counts/clocks and more SRAM
    /// splits.  Arrays narrower than the 3x3 kernels are excluded up
    /// front — validity rule 5 would reject every such point for the
    /// Table-I workloads, wasting the sample budget.
    pub fn wide() -> Self {
        Self {
            name: "wide".into(),
            pe_blocks: vec![8, 16, 32, 64, 128],
            arrays_per_block: vec![3, 6],
            rows_per_array: vec![4, 8, 16],
            cols_per_array: vec![3],
            freq_mhz: vec![125.0, 250.0, 500.0, 800.0],
            weight_sram_kb: vec![32.0, 64.0, 96.0, 192.0],
            spike_sram_kb: vec![32.0, 64.0, 128.0],
            encode_bitplanes: vec![1, 8],
            layer_fusion: vec![false, true],
            num_steps: vec![1, 4, 8],
        }
    }

    /// Look up a preset space by name.
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "small" => Some(Self::small()),
            "tiny" => Some(Self::tiny()),
            "wide" => Some(Self::wide()),
            _ => None,
        }
    }

    fn axis_sizes(&self) -> [usize; 10] {
        [
            self.pe_blocks.len(),
            self.arrays_per_block.len(),
            self.rows_per_array.len(),
            self.cols_per_array.len(),
            self.freq_mhz.len(),
            self.weight_sram_kb.len(),
            self.spike_sram_kb.len(),
            self.encode_bitplanes.len(),
            self.layer_fusion.len(),
            self.num_steps.len(),
        ]
    }

    /// Number of grid points (cartesian product of the axes).
    pub fn len(&self) -> usize {
        self.axis_sizes().iter().product()
    }

    /// True when any axis is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Candidate at linear grid index `i` (row-major over the axes).
    fn candidate_at(&self, i: usize) -> Candidate {
        let sizes = self.axis_sizes();
        let mut digits = [0usize; 10];
        let mut rest = i;
        for (d, &s) in digits.iter_mut().zip(&sizes) {
            *d = rest % s;
            rest /= s;
        }
        let hw = HwConfig {
            pe_blocks: self.pe_blocks[digits[0]],
            arrays_per_block: self.arrays_per_block[digits[1]],
            rows_per_array: self.rows_per_array[digits[2]],
            cols_per_array: self.cols_per_array[digits[3]],
            freq_mhz: self.freq_mhz[digits[4]],
            weight_sram_kb: self.weight_sram_kb[digits[5]],
            spike_sram_kb: self.spike_sram_kb[digits[6]],
            encode_bitplanes: self.encode_bitplanes[digits[7]],
            layer_fusion: self.layer_fusion[digits[8]],
            ..HwConfig::default()
        };
        Candidate { hw, num_steps: self.num_steps[digits[9]] }
    }

    /// Iterator over the full cartesian grid, in a fixed deterministic
    /// order.
    pub fn cartesian(&self) -> impl Iterator<Item = Candidate> + '_ {
        (0..self.len()).map(|i| self.candidate_at(i))
    }

    /// Up to `n` *distinct* grid points drawn uniformly with a seeded
    /// PRNG — the random-sampling iterator for spaces too large to
    /// enumerate.  Deterministic for a fixed seed; returns fewer than `n`
    /// only when the grid itself is smaller.
    pub fn sample(&self, n: usize, seed: u64) -> Vec<Candidate> {
        let len = self.len();
        if len == 0 {
            return Vec::new();
        }
        if n >= len {
            return self.cartesian().collect();
        }
        let mut rng = SplitMix64::new(seed);
        let mut seen = BTreeSet::new();
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            let i = rng.next_index(len);
            if seen.insert(i) {
                out.push(self.candidate_at(i));
            }
        }
        out
    }
}

/// Validity of a candidate for a set of workloads.  Each rule keeps the
/// analytic timing/traffic model honest (an invalid point would be
/// mis-modelled, not merely slow):
///
/// 1. [`HwConfig::validate`] — non-degenerate geometry and capacities.
/// 2. Every conv layer's weights fit the weight SRAM: under tick batching
///    the kernel stack is replayed across all T steps from on-chip memory
///    (the DRAM model charges conv weights exactly once).  Dense layers
///    are exempt — the vectorwise walk visits output channels outermost,
///    so they stream one weight row at a time.
/// 3. With fusion on, at least one adjacent layer pair must fit the
///    weight SRAM together, else `plan_fusion` degrades to the fusion-off
///    schedule and the candidate duplicates another design point.
/// 4. Each ping-pong spike bank holds the largest single-step inter-layer
///    spike plane (producer writes one bank while the consumer reads the
///    other).  The encoding layer reads the multi-bit image from DRAM,
///    not the spike SRAM, so its input is exempt.
/// 5. The PE fabric covers the conv kernels: the vectorwise schedule
///    assigns one PE array per kernel column and one PE column per tap
///    (Fig. 5), so `arrays_per_block` and `cols_per_array` must both be
///    at least k for every conv layer — otherwise the one-cycle-per-
///    output-column timing claim does not hold.
pub fn validate(cand: &Candidate, workloads: &[&str]) -> Result<(), String> {
    cand.hw.validate()?;
    for name in workloads {
        let spec = models::by_name(name, cand.num_steps)
            .ok_or_else(|| format!("unknown workload '{name}'"))?;
        let plans = plan_spec(&spec);
        for p in &plans {
            if p.k > 1 && (cand.hw.arrays_per_block < p.k || cand.hw.cols_per_array < p.k) {
                return Err(format!(
                    "{name}: {}x({}-wide) PE arrays cannot cover a {}x{} kernel in one cycle",
                    cand.hw.arrays_per_block, cand.hw.cols_per_array, p.k, p.k
                ));
            }
        }
        let budget = cand.hw.weight_sram_bits();
        for p in &plans {
            if matches!(p.kind, PlanKind::EncConv | PlanKind::Conv) && p.weight_bits() > budget {
                return Err(format!(
                    "{name}: conv layer {} needs {} weight bits > {} SRAM bits",
                    p.model_index,
                    p.weight_bits(),
                    budget
                ));
            }
        }
        if cand.hw.layer_fusion {
            let any_pair = plans
                .windows(2)
                .any(|pair| pair[0].weight_bits() + pair[1].weight_bits() <= budget);
            if !any_pair {
                return Err(format!("{name}: fusion enabled but no layer pair fits the SRAM"));
            }
        }
        let bank = cand.hw.spike_bank_bits();
        for p in &plans {
            if p.kind != PlanKind::EncConv && p.in_bits_per_step() > bank {
                return Err(format!(
                    "{name}: layer {} spike plane of {} bits exceeds the {}-bit bank",
                    p.model_index,
                    p.in_bits_per_step(),
                    bank
                ));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cartesian_covers_the_grid_exactly_once() {
        let space = SearchSpace::tiny();
        let cands: Vec<Candidate> = space.cartesian().collect();
        assert_eq!(cands.len(), space.len());
        let ids: BTreeSet<String> = cands.iter().map(|c| c.id()).collect();
        assert_eq!(ids.len(), cands.len(), "duplicate grid points");
    }

    #[test]
    fn paper_point_is_in_small_and_tiny() {
        let paper = Candidate::paper().id();
        for space in [SearchSpace::small(), SearchSpace::tiny()] {
            assert!(
                space.cartesian().any(|c| c.id() == paper),
                "{}: paper design point missing",
                space.name
            );
        }
    }

    #[test]
    fn sampling_is_deterministic_and_distinct() {
        let space = SearchSpace::wide();
        let a = space.sample(50, 42);
        let b = space.sample(50, 42);
        assert_eq!(a.len(), 50);
        assert!(a.iter().zip(&b).all(|(x, y)| x.id() == y.id()));
        let ids: BTreeSet<String> = a.iter().map(|c| c.id()).collect();
        assert_eq!(ids.len(), 50);
        let c = space.sample(50, 43);
        assert!(a.iter().zip(&c).any(|(x, y)| x.id() != y.id()));
    }

    #[test]
    fn sample_larger_than_grid_returns_grid() {
        let space = SearchSpace::tiny();
        assert_eq!(space.sample(10_000, 1).len(), space.len());
    }

    #[test]
    fn paper_point_valid_for_both_workloads() {
        assert_eq!(validate(&Candidate::paper(), &["mnist", "cifar10"]), Ok(()));
    }

    #[test]
    fn small_weight_sram_invalid_for_cifar_convs() {
        // 64 KiB cannot hold CIFAR-10's 256x256x3x3 conv (72 KiB)...
        let mut cand = Candidate::paper();
        cand.hw.weight_sram_kb = 64.0;
        assert!(validate(&cand, &["cifar10"]).is_err());
        // ...but MNIST's largest conv is 4.5 KiB.
        assert_eq!(validate(&cand, &["mnist"]), Ok(()));
    }

    #[test]
    fn tiny_spike_bank_invalid_for_cifar_planes() {
        // CIFAR-10's 128x32x32 inter-layer plane is 16 KiB; a 16 KiB
        // ping-pong SRAM leaves only an 8 KiB bank.
        let mut cand = Candidate::paper();
        cand.hw.spike_sram_kb = 16.0;
        assert!(validate(&cand, &["cifar10"]).is_err());
        assert_eq!(validate(&cand, &["mnist"]), Ok(()));
    }

    #[test]
    fn fusion_needs_one_fitting_pair() {
        let mut cand = Candidate::paper();
        // 4.5 KiB = 36864 bits: exactly holds MNIST's largest conv
        // (rule 2 passes) but not the smallest pair, enc + conv2 =
        // 576 + 36864 = 37440 bits — so only the fusion rule can fire.
        cand.hw.weight_sram_kb = 4.5;
        let err = validate(&cand, &["mnist"]).unwrap_err();
        assert!(err.contains("fusion"), "unexpected error: {err}");
        // the same budget is fine once the fusion knob is off
        cand.hw.layer_fusion = false;
        assert_eq!(validate(&cand, &["mnist"]), Ok(()));
    }

    #[test]
    fn skinny_arrays_cannot_run_3x3_kernels() {
        let mut cand = Candidate::paper();
        cand.hw.arrays_per_block = 1;
        assert!(validate(&cand, &["mnist"]).is_err());
        cand.hw.arrays_per_block = 3;
        cand.hw.cols_per_array = 1;
        assert!(validate(&cand, &["mnist"]).is_err());
    }

    #[test]
    fn small_space_has_enough_valid_candidates() {
        let space = SearchSpace::small();
        let valid = space
            .cartesian()
            .filter(|c| validate(c, &["mnist"]).is_ok())
            .count();
        assert!(valid >= 200, "only {valid} valid candidates for mnist");
        let valid_cifar = space
            .cartesian()
            .filter(|c| validate(c, &["cifar10"]).is_ok())
            .count();
        assert!(valid_cifar >= 200, "only {valid_cifar} valid candidates for cifar10");
    }
}

//! DSE reporting: a machine-readable JSON document (the `config::json`
//! value model, so it round-trips through the repo's own parser) and a
//! rendered frontier table that reuses the Table III column layout of
//! [`crate::energy::report`].

use std::collections::BTreeMap;

use crate::config::json::Json;
use crate::dse::evaluate::CandidateResult;
use crate::energy::report as ereport;

/// Sweep provenance recorded in the JSON report.
#[derive(Debug, Clone)]
pub struct SweepMeta {
    pub space: String,
    pub workloads: Vec<String>,
    /// Cartesian grid size of the space (before filtering/sampling).
    pub grid_size: usize,
    /// Random-sample size (0 = the full grid was enumerated).
    pub sampled: usize,
    pub seed: u64,
    pub threads: usize,
}

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn candidate_json(r: &CandidateResult) -> Json {
    let hw = &r.candidate.hw;
    let per: Vec<Json> = r
        .per_workload
        .iter()
        .map(|m| {
            obj(vec![
                ("workload", Json::Str(m.workload.clone())),
                ("cycles", num(m.cycles as f64)),
                ("latency_us", num(m.latency_us)),
                ("inf_per_sec", num(m.inf_per_sec)),
                ("dram_bytes", num(m.dram_bytes as f64)),
                ("core_power_mw", num(m.core_power_mw)),
                ("utilization", num(m.utilization)),
            ])
        })
        .collect();
    let mut entries = vec![
        ("id", Json::Str(r.candidate.id())),
        ("pe_blocks", num(hw.pe_blocks as f64)),
        ("arrays_per_block", num(hw.arrays_per_block as f64)),
        ("rows_per_array", num(hw.rows_per_array as f64)),
        ("cols_per_array", num(hw.cols_per_array as f64)),
        ("freq_mhz", num(hw.freq_mhz)),
        ("weight_sram_kb", num(hw.weight_sram_kb)),
        ("spike_sram_kb", num(hw.spike_sram_kb)),
        ("encode_bitplanes", num(hw.encode_bitplanes as f64)),
        ("layer_fusion", Json::Bool(hw.layer_fusion)),
        ("num_steps", num(r.candidate.num_steps as f64)),
        ("total_pes", num(hw.total_pes() as f64)),
        ("throughput_ips", num(r.throughput_ips)),
        ("power_mw", num(r.power_mw)),
        ("area_kge", num(r.area_kge)),
        ("tops_per_w", num(r.tops_per_w)),
        ("per_workload", Json::Arr(per)),
    ];
    if let Some(acc) = r.accuracy {
        entries.push(("accuracy", num(acc)));
    }
    obj(entries)
}

/// Render the sweep as CSV: one row per **frontier** point carrying
/// every knob and every objective, ready for scatter plotting
/// (`vsa dse --csv frontier.csv`).  The `accuracy` column is empty when
/// the sweep ran without a reference artifact.
pub fn to_csv(results: &[CandidateResult], frontier: &[usize]) -> String {
    let mut out = String::from(
        "rank,id,pe_blocks,arrays_per_block,rows_per_array,cols_per_array,\
         freq_mhz,weight_sram_kb,spike_sram_kb,encode_bitplanes,layer_fusion,\
         num_steps,total_pes,throughput_ips,power_mw,area_kge,tops_per_w,accuracy\n",
    );
    for (rank, &i) in frontier.iter().enumerate() {
        let r = &results[i];
        let hw = &r.candidate.hw;
        let acc = r.accuracy.map_or(String::new(), |a| format!("{a}"));
        out.push_str(&format!(
            "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}\n",
            rank + 1,
            r.candidate.id(),
            hw.pe_blocks,
            hw.arrays_per_block,
            hw.rows_per_array,
            hw.cols_per_array,
            hw.freq_mhz,
            hw.weight_sram_kb,
            hw.spike_sram_kb,
            hw.encode_bitplanes,
            hw.layer_fusion,
            r.candidate.num_steps,
            hw.total_pes(),
            r.throughput_ips,
            r.power_mw,
            r.area_kge,
            r.tops_per_w,
            acc
        ));
    }
    out
}

/// Assemble the full sweep report.  `frontier` indexes into `results`;
/// `paper_slack` is the epsilon-dominance slack of the paper's design
/// point when it was part of the sweep (computed by the caller, normally
/// pinned to the paper's T — see the `dse` CLI).
pub fn to_json(
    meta: &SweepMeta,
    results: &[CandidateResult],
    frontier: &[usize],
    paper_slack: Option<f64>,
) -> Json {
    let frontier_rows: Vec<Json> = frontier.iter().map(|&i| candidate_json(&results[i])).collect();
    let mut entries = vec![
        ("schema", Json::Str("vsa-dse-v1".into())),
        ("space", Json::Str(meta.space.clone())),
        (
            "workloads",
            Json::Arr(meta.workloads.iter().map(|w| Json::Str(w.clone())).collect()),
        ),
        ("grid_size", num(meta.grid_size as f64)),
        ("sampled", num(meta.sampled as f64)),
        // string, not number: a u64 seed above 2^53 would lose digits in
        // the f64 value model and break replayability of the sweep
        ("seed", Json::Str(meta.seed.to_string())),
        ("threads", num(meta.threads as f64)),
        ("candidates_evaluated", num(results.len() as f64)),
        ("frontier_size", num(frontier.len() as f64)),
        (
            "objectives",
            obj(vec![
                ("throughput_ips", Json::Str("geomean inf/s across workloads, maximize".into())),
                ("power_mw", Json::Str("worst-case core power, minimize".into())),
                ("area_kge", Json::Str("logic + SRAM macro proxy, minimize".into())),
            ]),
        ),
        ("frontier", Json::Arr(frontier_rows)),
    ];
    if let Some(s) = paper_slack {
        entries.push(("paper_point_slack", num(s)));
    }
    obj(entries)
}

/// Render the frontier for humans: a ranked summary table plus the
/// Table III-style column view (via [`ereport::render_table3`]) of the
/// `top` highest-throughput frontier designs.
pub fn render(results: &[CandidateResult], frontier: &[usize], top: usize) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "Pareto frontier: {} of {} evaluated candidates (throughput vs power vs area)\n\n",
        frontier.len(),
        results.len()
    ));
    out.push_str(&format!(
        "{:<5} {:<38} {:>12} {:>10} {:>10} {:>9}\n",
        "rank", "candidate", "inf/s", "mW", "KGE", "TOPS/W"
    ));
    for (rank, &i) in frontier.iter().enumerate() {
        let r = &results[i];
        out.push_str(&format!(
            "{:<5} {:<38} {:>12.1} {:>10.3} {:>10.1} {:>9.2}\n",
            format!("#{}", rank + 1),
            r.candidate.id(),
            r.throughput_ips,
            r.power_mw,
            r.area_kge,
            r.tops_per_w
        ));
    }

    let shown = top.min(frontier.len());
    if shown > 0 {
        out.push_str("\nTable III-style view of the top designs (by throughput):\n\n");
        let rows: Vec<ereport::DesignRow> = frontier[..shown]
            .iter()
            .enumerate()
            .map(|(rank, &i)| {
                let r = &results[i];
                ereport::design_row(&format!("#{}", rank + 1), &r.candidate.hw, r.power_mw)
            })
            .collect();
        out.push_str(&ereport::render_table3(&rows));
        out.push_str("\nlegend:\n");
        for (rank, &i) in frontier[..shown].iter().enumerate() {
            out.push_str(&format!("  #{}  {}\n", rank + 1, results[i].candidate.id()));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::json;
    use crate::dse::{evaluate, pareto, space};

    fn tiny_sweep() -> (Vec<CandidateResult>, Vec<usize>) {
        let cands: Vec<space::Candidate> = space::SearchSpace::tiny()
            .cartesian()
            .filter(|c| space::validate(c, &["mnist"]).is_ok())
            .collect();
        let results = evaluate::evaluate_all(&cands, &["mnist"], 2);
        let front = pareto::frontier(&results);
        (results, front)
    }

    #[test]
    fn json_roundtrips_through_own_parser() {
        let (results, front) = tiny_sweep();
        let meta = SweepMeta {
            space: "tiny".into(),
            workloads: vec!["mnist".into()],
            grid_size: 8,
            sampled: 0,
            seed: 7,
            threads: 2,
        };
        let doc = to_json(&meta, &results, &front, Some(0.0));
        let text = json::to_string(&doc);
        let parsed = Json::parse(&text).expect("report parses");
        assert_eq!(parsed.get("schema").unwrap().as_str(), Some("vsa-dse-v1"));
        assert_eq!(parsed.get("frontier_size").unwrap().as_usize(), Some(front.len()));
        let rows = parsed.get("frontier").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), front.len());
        assert!(rows[0].get("throughput_ips").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn render_lists_every_frontier_point() {
        let (results, front) = tiny_sweep();
        let text = render(&results, &front, 3);
        assert!(text.contains("Pareto frontier"));
        assert!(text.contains("#1"));
        for &i in &front {
            assert!(text.contains(&results[i].candidate.id()));
        }
        // Table III-style section present
        assert!(text.contains("PE number"));
    }
}

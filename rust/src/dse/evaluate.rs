//! Multi-threaded candidate evaluation through the analytic chip model.
//!
//! Each candidate runs every workload through [`Chip::analyze`] — the
//! data-independent timing/SRAM/DRAM walk of `arch::schedule` — and is
//! scored on the three Pareto objectives (throughput, core power, area)
//! plus the derived TOPS/W figure.  Evaluation is pure, so results are
//! bit-identical for any thread count.
//!
//! With a trained artifact (`vsa dse --artifact model.vsaw`, see
//! [`accuracy_by_t`]) candidates additionally carry a measured **accuracy
//! objective**: the golden model's held-out accuracy at the candidate's
//! T.  Accuracy depends only on T (and the artifact) among the searched
//! knobs, so it is measured once per distinct T and joined in — making
//! the paper's Fig. 8 accuracy-vs-T trade-off a first-class Pareto axis
//! instead of an unmodeled excuse (see `pareto::dominates`).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};

use crate::arch::{Chip, SimMode};
use crate::config::models;
use crate::dse::space::Candidate;
use crate::energy::{area, power};
use crate::snn::params::DeployedModel;

/// Per-workload figures of one candidate.
#[derive(Debug, Clone)]
pub struct WorkloadMetrics {
    pub workload: String,
    pub cycles: u64,
    pub latency_us: f64,
    pub inf_per_sec: f64,
    pub dram_bytes: u64,
    pub core_power_mw: f64,
    pub utilization: f64,
}

/// One evaluated candidate with its Pareto objectives.
#[derive(Debug, Clone)]
pub struct CandidateResult {
    pub candidate: Candidate,
    pub per_workload: Vec<WorkloadMetrics>,
    /// Maximize: geometric mean of inferences/sec across the workloads
    /// (scale-free, so MNIST's kHz rates don't drown CIFAR-10's).
    pub throughput_ips: f64,
    /// Minimize: worst-case core power across the workloads, mW.
    pub power_mw: f64,
    /// Minimize: total silicon proxy (logic + SRAM macros), KGE.
    pub area_kge: f64,
    /// Peak power efficiency at the worst-case power, TOPS/W.
    pub tops_per_w: f64,
    /// Maximize: golden-model held-out accuracy of the reference
    /// artifact at this candidate's T (`None` without an artifact).
    pub accuracy: Option<f64>,
}

/// Evaluate one candidate on the given workload presets.
pub fn evaluate_one(cand: &Candidate, workloads: &[&str]) -> CandidateResult {
    evaluate_one_with(cand, workloads, None)
}

/// [`evaluate_one`] joining in the per-T accuracy table when present.
pub fn evaluate_one_with(
    cand: &Candidate,
    workloads: &[&str],
    accuracy_by_t: Option<&BTreeMap<usize, f64>>,
) -> CandidateResult {
    let chip = Chip::new(cand.hw.clone(), SimMode::Fast);
    let mut per_workload = Vec::with_capacity(workloads.len());
    for name in workloads {
        let spec = models::by_name(name, cand.num_steps).expect("validated workload");
        let r = chip.analyze(&spec);
        per_workload.push(WorkloadMetrics {
            workload: (*name).to_string(),
            cycles: r.cycles,
            latency_us: r.latency_us,
            inf_per_sec: 1e6 / r.latency_us,
            dram_bytes: r.dram.total(),
            core_power_mw: power::core_power_mw(&cand.hw, &r),
            utilization: r.utilization,
        });
    }
    let throughput_ips = geomean(per_workload.iter().map(|m| m.inf_per_sec));
    let power_mw = per_workload.iter().map(|m| m.core_power_mw).fold(0.0, f64::max);
    CandidateResult {
        throughput_ips,
        power_mw,
        area_kge: area::total_area_kge(&cand.hw),
        tops_per_w: power::power_efficiency_tops_w(&cand.hw, power_mw),
        accuracy: accuracy_by_t.map(|acc| acc[&cand.num_steps]),
        candidate: cand.clone(),
        per_workload,
    }
}

/// Evaluate all candidates across `threads` OS threads.  Workers stripe
/// over a shared index; results come back in input order.
pub fn evaluate_all(
    cands: &[Candidate],
    workloads: &[&str],
    threads: usize,
) -> Vec<CandidateResult> {
    evaluate_all_with(cands, workloads, threads, None)
}

/// [`evaluate_all`] with an optional per-T accuracy table (from
/// [`accuracy_by_t`]); every candidate's T must have an entry.
pub fn evaluate_all_with(
    cands: &[Candidate],
    workloads: &[&str],
    threads: usize,
    accuracy: Option<&BTreeMap<usize, f64>>,
) -> Vec<CandidateResult> {
    let n_threads = threads.max(1).min(cands.len().max(1));
    let next = AtomicUsize::new(0);
    let mut indexed: Vec<(usize, CandidateResult)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..n_threads)
            .map(|_| {
                s.spawn(|| {
                    let mut out = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= cands.len() {
                            break;
                        }
                        out.push((i, evaluate_one_with(&cands[i], workloads, accuracy)));
                    }
                    out
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("dse worker panicked"))
            .collect()
    });
    indexed.sort_by_key(|(i, _)| *i);
    indexed.into_iter().map(|(_, r)| r).collect()
}

/// Golden-model held-out accuracy of `artifact` at each T in `ts`
/// (deduplicated): the artifact's trained thresholds are kept and only
/// `num_steps` is overridden — exactly the paper's Fig. 8 sweep, using
/// the synthetic corpus in the artifact's input geometry.
pub fn accuracy_by_t(
    artifact: &DeployedModel,
    ts: impl IntoIterator<Item = usize>,
    count: usize,
    seed: u64,
) -> BTreeMap<usize, f64> {
    let samples =
        crate::train::holdout_samples(artifact.in_channels, artifact.in_size, seed, count);
    let mut out = BTreeMap::new();
    for t in ts {
        out.entry(t).or_insert_with(|| {
            let mut model = artifact.clone();
            model.num_steps = t;
            let (correct, total) = crate::train::eval_golden(&model, &samples);
            correct as f64 / total.max(1) as f64
        });
    }
    out
}

fn geomean(xs: impl Iterator<Item = f64>) -> f64 {
    let (mut sum, mut n) = (0.0f64, 0usize);
    for x in xs {
        sum += x.ln();
        n += 1;
    }
    if n == 0 {
        0.0
    } else {
        (sum / n as f64).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HwConfig;

    #[test]
    fn paper_point_metrics_sane() {
        let r = evaluate_one(&Candidate::paper(), &["mnist", "cifar10"]);
        assert_eq!(r.per_workload.len(), 2);
        assert!(r.throughput_ips > 0.0);
        assert!(r.power_mw > power::LEAKAGE_MW);
        assert!(r.area_kge > 0.0);
        // CIFAR-10 is the slower, hungrier workload
        assert!(r.per_workload[0].inf_per_sec > r.per_workload[1].inf_per_sec);
        let worst = r.per_workload.iter().map(|m| m.core_power_mw).fold(0.0, f64::max);
        assert_eq!(r.power_mw, worst);
    }

    #[test]
    fn accuracy_join_is_per_t() {
        let artifact = DeployedModel::synthesize(&models::micro(4), 7);
        let acc = accuracy_by_t(&artifact, [2usize, 4, 2, 8], 16, 7);
        assert_eq!(acc.len(), 3); // deduplicated
        assert!(acc.values().all(|&a| (0.0..=1.0).contains(&a)));
        // deterministic
        assert_eq!(acc, accuracy_by_t(&artifact, [2usize, 4, 8], 16, 7));
        // joined onto results at the candidate's T
        let cand = Candidate { hw: HwConfig::default(), num_steps: 4 };
        let r = evaluate_one_with(&cand, &["mnist"], Some(&acc));
        assert_eq!(r.accuracy, Some(acc[&4]));
        assert_eq!(evaluate_one(&cand, &["mnist"]).accuracy, None);
    }

    #[test]
    fn evaluation_deterministic_across_thread_counts() {
        let cands: Vec<Candidate> = crate::dse::space::SearchSpace::tiny().cartesian().collect();
        let a = evaluate_all(&cands, &["mnist"], 1);
        let b = evaluate_all(&cands, &["mnist"], 4);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.candidate.id(), y.candidate.id());
            assert_eq!(x.throughput_ips.to_bits(), y.throughput_ips.to_bits());
            assert_eq!(x.power_mw.to_bits(), y.power_mw.to_bits());
            assert_eq!(x.area_kge.to_bits(), y.area_kge.to_bits());
        }
    }

    #[test]
    fn more_pes_mean_more_throughput_for_divisible_geometry() {
        // CIFAR-10's early layers have C_in = 128: 32 -> 64 blocks halves
        // the group count and therefore the cycle count.
        let hw32 = HwConfig { pe_blocks: 32, ..HwConfig::default() };
        let hw64 = HwConfig { pe_blocks: 64, ..HwConfig::default() };
        let small = Candidate { hw: hw32, num_steps: 8 };
        let big = Candidate { hw: hw64, num_steps: 8 };
        let rs = evaluate_one(&small, &["cifar10"]);
        let rb = evaluate_one(&big, &["cifar10"]);
        assert!(rb.throughput_ips > rs.throughput_ips);
        assert!(rb.area_kge > rs.area_kge);
    }
}

//! Shared counters for the simulator and the serving coordinator.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

/// A monotonically increasing counter (thread-safe).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Increment by one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A named collection of counters with stable ordering (for reports).
#[derive(Debug, Default)]
pub struct Registry {
    counters: BTreeMap<String, Counter>,
}

impl Registry {
    /// Get or create a counter.
    pub fn counter(&mut self, name: &str) -> &Counter {
        self.counters.entry(name.to_string()).or_default()
    }

    /// Snapshot all counters.
    pub fn snapshot(&self) -> BTreeMap<String, u64> {
        self.counters
            .iter()
            .map(|(k, v)| (k.clone(), v.get()))
            .collect()
    }

    /// Render a compact single-line report.
    pub fn render(&self) -> String {
        self.snapshot()
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_registry() {
        let mut reg = Registry::default();
        reg.counter("a").add(3);
        reg.counter("a").inc();
        reg.counter("b").inc();
        let snap = reg.snapshot();
        assert_eq!(snap["a"], 4);
        assert_eq!(snap["b"], 1);
        assert_eq!(reg.render(), "a=4 b=1");
    }
}

//! Back-compat shim: the counter map that lived here grew into the
//! full [`crate::telemetry`] subsystem (PR7) — counters, gauges,
//! latency sketches, and stable-ordered text/JSON exporters.  Existing
//! `metrics::{Counter, Registry}` paths keep working; new code should
//! import from `telemetry` directly.

pub use crate::telemetry::registry::{Counter, Gauge, Registry, Snapshot};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shim_paths_still_work() {
        let reg = Registry::new();
        reg.counter("a").add(3);
        reg.counter("a").inc();
        reg.counter("b").inc();
        let snap = reg.snapshot();
        assert_eq!(snap.counters["a"], 4);
        assert_eq!(snap.counters["b"], 1);
        assert_eq!(snap.render_text(), "# counters\na 4\nb 1\n");
    }
}

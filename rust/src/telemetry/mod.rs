//! End-to-end telemetry (PR7): mergeable latency sketches, per-request
//! stage tracing, and a unified counter/gauge/sketch registry with
//! stable-ordered text + JSON exporters.
//!
//! Three pieces (README §OBSERVABILITY):
//!
//! * [`sketch`] — `HistogramSketch` / `AtomicSketch`: dependency-free
//!   log-bucketed latency histograms with a proven ≤ 1.5625% relative
//!   error bound, O(buckets) memory, lock-free per-worker shards, and
//!   deterministic merge (replaces the coordinator's unbounded latency
//!   vector).
//! * [`trace`] — `Trace` / `Stage`: queue / batch / engine / backoff /
//!   deliver breakdown carried by every served request; stage times sum
//!   to the end-to-end latency by construction.
//! * [`registry`] — `Registry` / `Snapshot`: named metrics shared by
//!   the serve path (`vsa serve --stats-interval`), the chip simulator
//!   (DRAM/SRAM/spike counters) and the trainer (per-epoch phase
//!   timings), exported as sorted text or `vsa-metrics-v1` JSON.
//!
//! PR8 adds two more:
//!
//! * [`spans`] — `SpanCollector` / `SpanRecorder` / `SpanSheet`:
//!   hierarchical span tracing with per-thread ring buffers and
//!   deterministic Chrome trace-event export (`vsa-trace-v1`,
//!   `--trace-out` on serve / train / simulate).
//! * [`diff`] — `vsa metrics-diff`: per-key snapshot comparison with a
//!   relative regression gate for CI.

pub mod diff;
pub mod registry;
pub mod sketch;
pub mod spans;
pub mod trace;

pub use diff::{diff_snapshots, DiffReport};
pub use registry::{Counter, Gauge, Registry, Snapshot, SCHEMA};
pub use sketch::{AtomicSketch, HistogramSketch, LatencySummary, BUCKETS, REL_ERROR, SUB};
pub use spans::{SpanCollector, SpanRecord, SpanRecorder, SpanSheet, TRACE_SCHEMA};
pub use trace::{Stage, Trace};

//! Unified metrics registry: named counters, gauges and latency
//! sketches with stable-ordered text and JSON snapshot formats (PR7).
//!
//! Grown out of the old `metrics.rs` counter map (which is now a shim
//! over this module).  The taxonomy (README §OBSERVABILITY):
//!
//! * **counter** — monotone `u64` event count (`serve.completed`,
//!   `sim.dram.read.weights_bytes`);
//! * **gauge** — last-written `f64` level (`serve.throughput_rps`,
//!   `train.loss`);
//! * **sketch** — a mergeable [`HistogramSketch`] of latency samples,
//!   snapshotted as its percentile summary.
//!
//! Handles are `Arc`-shared and lock-free to update; the registry's
//! internal maps are `BTreeMap`s behind a mutex that is only taken on
//! registration and snapshot, never on the metric hot path.  Snapshots
//! iterate the sorted maps, so both `render_text()` and `to_json()` are
//! byte-deterministic for a given set of metric values.

use super::sketch::{AtomicSketch, HistogramSketch};
use crate::config::json::{self, Json};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Snapshot schema tag written into the JSON export.
pub const SCHEMA: &str = "vsa-metrics-v1";

/// A monotonically increasing counter (thread-safe, lock-free).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Increment by one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    /// Overwrite with an absolute value (used when exporting an
    /// already-aggregated count into a registry).
    pub fn store(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }
}

/// A last-value-wins `f64` level (bits in an `AtomicU64`).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    /// Set the level.
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Current level.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// A named collection of counters, gauges and sketches.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    sketches: Mutex<BTreeMap<String, Arc<AtomicSketch>>>,
}

impl Registry {
    /// An empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Get or create a counter handle.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut map = self.counters.lock().unwrap();
        Arc::clone(map.entry(name.to_string()).or_default())
    }

    /// Get or create a gauge handle.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut map = self.gauges.lock().unwrap();
        Arc::clone(map.entry(name.to_string()).or_default())
    }

    /// Get or create a sketch handle.
    pub fn sketch(&self, name: &str) -> Arc<AtomicSketch> {
        let mut map = self.sketches.lock().unwrap();
        Arc::clone(map.entry(name.to_string()).or_insert_with(|| Arc::new(AtomicSketch::new())))
    }

    /// Set a counter to an absolute value (exporter convenience).
    pub fn set_counter(&self, name: &str, v: u64) {
        self.counter(name).store(v);
    }

    /// Set a gauge (exporter convenience).
    pub fn set_gauge(&self, name: &str, v: f64) {
        self.gauge(name).set(v);
    }

    /// Merge an owned sketch into the named sketch.  NOTE: merging is
    /// additive — exporters that publish a cumulative sketch should
    /// merge into a *fresh* registry per snapshot tick, not re-merge
    /// into a long-lived one.
    pub fn merge_sketch(&self, name: &str, s: &HistogramSketch) {
        self.sketch(name).merge_from(s);
    }

    /// Consistent point-in-time copy of every metric.
    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            counters: self
                .counters
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), v.get()))
                .collect(),
            gauges: self
                .gauges
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), v.get()))
                .collect(),
            sketches: self
                .sketches
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), v.snapshot()))
                .collect(),
        }
    }
}

/// An owned, stable-ordered snapshot of a [`Registry`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Snapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, f64>,
    pub sketches: BTreeMap<String, HistogramSketch>,
}

impl Snapshot {
    /// Multi-line `name value` text format, sections sorted and keys
    /// sorted within each section (byte-deterministic).
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        if !self.counters.is_empty() {
            out.push_str("# counters\n");
            for (k, v) in &self.counters {
                out.push_str(&format!("{k} {v}\n"));
            }
        }
        if !self.gauges.is_empty() {
            out.push_str("# gauges\n");
            for (k, v) in &self.gauges {
                out.push_str(&format!("{k} {v:.6}\n"));
            }
        }
        if !self.sketches.is_empty() {
            out.push_str("# sketches (ms)\n");
            for (k, s) in &self.sketches {
                out.push_str(&format!("{k} {}\n", s.summary().render()));
            }
        }
        out
    }

    /// Compact JSON document (schema [`SCHEMA`]), keys sorted — the
    /// artifact format uploaded by CI next to the bench trajectory.
    pub fn to_json(&self) -> String {
        let mut root = BTreeMap::new();
        root.insert("schema".to_string(), Json::Str(SCHEMA.to_string()));
        root.insert(
            "counters".to_string(),
            Json::Obj(
                self.counters.iter().map(|(k, &v)| (k.clone(), Json::Num(v as f64))).collect(),
            ),
        );
        root.insert(
            "gauges".to_string(),
            Json::Obj(self.gauges.iter().map(|(k, &v)| (k.clone(), Json::Num(v))).collect()),
        );
        let sketches = self
            .sketches
            .iter()
            .map(|(k, s)| {
                let sum = s.summary();
                let mut o = BTreeMap::new();
                o.insert("count".to_string(), Json::Num(sum.count as f64));
                o.insert("mean_ms".to_string(), Json::Num(sum.mean_ms));
                o.insert("p50_ms".to_string(), Json::Num(sum.p50_ms));
                o.insert("p95_ms".to_string(), Json::Num(sum.p95_ms));
                o.insert("p99_ms".to_string(), Json::Num(sum.p99_ms));
                o.insert("p999_ms".to_string(), Json::Num(sum.p999_ms));
                o.insert("max_ms".to_string(), Json::Num(sum.max_ms));
                (k.clone(), Json::Obj(o))
            })
            .collect();
        root.insert("sketches".to_string(), Json::Obj(sketches));
        json::to_string(&Json::Obj(root))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn handles_are_shared_and_lock_free_to_update() {
        let reg = Registry::new();
        let a = reg.counter("serve.completed");
        let b = reg.counter("serve.completed");
        a.add(3);
        b.inc();
        assert_eq!(reg.counter("serve.completed").get(), 4);
        reg.gauge("train.loss").set(0.25);
        assert_eq!(reg.gauge("train.loss").get(), 0.25);
        reg.sketch("serve.latency").record(Duration::from_millis(2));
        assert_eq!(reg.sketch("serve.latency").count(), 1);
    }

    #[test]
    fn snapshot_formats_are_stable_ordered() {
        let reg = Registry::new();
        // Register deliberately out of order; output must sort.
        reg.set_counter("b.two", 2);
        reg.set_counter("a.one", 1);
        reg.set_gauge("z.level", 1.5);
        reg.sketch("m.lat").record(Duration::from_millis(1));
        let snap = reg.snapshot();
        let text = snap.render_text();
        let a = text.find("a.one 1").unwrap();
        let b = text.find("b.two 2").unwrap();
        assert!(a < b, "counters sorted");
        assert!(text.contains("z.level 1.500000"));
        assert!(text.contains("m.lat n 1"));
        assert_eq!(text, reg.snapshot().render_text(), "re-snapshot is byte-identical");

        let parsed = Json::parse(&snap.to_json()).expect("snapshot JSON parses");
        assert_eq!(parsed.get("schema").unwrap().as_str(), Some(SCHEMA));
        assert_eq!(parsed.get("counters").unwrap().get("a.one").unwrap().as_i64(), Some(1));
        let lat = parsed.get("sketches").unwrap().get("m.lat").unwrap();
        assert_eq!(lat.get("count").unwrap().as_i64(), Some(1));
        assert!(lat.get("p50_ms").unwrap().as_f64().unwrap() > 0.0);
    }
}

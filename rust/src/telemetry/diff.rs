//! `vsa-metrics-v1` snapshot comparison (PR8): per-key deltas and a
//! regression gate for CI.
//!
//! [`diff_snapshots`] flattens two registry snapshots (counters,
//! gauges, and every exported sketch column) into one sorted key
//! space, reports the delta for every key present in both, and flags
//! regressions past a relative threshold.  Most metrics are
//! lower-is-better (latencies, failure counts); a small suffix list
//! marks the higher-is-better ones (throughput, completions).  Keys
//! present on only one side are listed informationally but never gate
//! — adding a metric must not break CI.

use std::collections::BTreeMap;

use crate::config::json::Json;

/// Sketch columns exported by `Snapshot::to_json`, flattened as
/// `sketches.<name>.<column>`.
const SKETCH_COLS: [&str; 7] =
    ["count", "mean_ms", "p50_ms", "p95_ms", "p99_ms", "p999_ms", "max_ms"];

/// Key suffixes where a *decrease* is the regression.
const HIGHER_IS_BETTER: [&str; 6] =
    ["throughput_rps", "completed", "alive_workers", "gops", "utilization", "accuracy"];

/// One compared key.
#[derive(Debug, Clone)]
pub struct Delta {
    pub key: String,
    pub a: f64,
    pub b: f64,
    /// Relative change in the *worse* direction (0 when b improved).
    pub regress_frac: f64,
}

/// Full comparison result.
#[derive(Debug, Default)]
pub struct DiffReport {
    pub deltas: Vec<Delta>,
    pub only_a: Vec<String>,
    pub only_b: Vec<String>,
    /// Keys whose `regress_frac` exceeded the threshold.
    pub regressions: Vec<String>,
}

impl DiffReport {
    pub fn has_regressions(&self) -> bool {
        !self.regressions.is_empty()
    }

    /// Human-readable table, one key per line, regressions marked.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let width = self.deltas.iter().map(|d| d.key.len()).max().unwrap_or(0);
        for d in &self.deltas {
            let rel = if d.a.abs() > 1e-9 { (d.b - d.a) / d.a.abs() * 100.0 } else { 0.0 };
            let mark = if self.regressions.contains(&d.key) { "  REGRESSION" } else { "" };
            out.push_str(&format!(
                "{:width$}  {:>14.4} -> {:>14.4}  ({:+.1}%){mark}\n",
                d.key, d.a, d.b, rel
            ));
        }
        for k in &self.only_a {
            out.push_str(&format!("{k:width$}  only in A\n"));
        }
        for k in &self.only_b {
            out.push_str(&format!("{k:width$}  only in B\n"));
        }
        out
    }
}

/// Flatten a snapshot into `counters.* / gauges.* / sketches.*.*`.
fn flatten(doc: &Json) -> Result<BTreeMap<String, f64>, String> {
    let schema = doc.get("schema").and_then(Json::as_str);
    if schema != Some(crate::telemetry::SCHEMA) {
        return Err(format!(
            "expected schema {:?}, got {:?}",
            crate::telemetry::SCHEMA,
            schema
        ));
    }
    let mut flat = BTreeMap::new();
    for section in ["counters", "gauges"] {
        if let Some(Json::Obj(map)) = doc.get(section) {
            for (k, v) in map {
                let n = v.as_f64().ok_or_else(|| format!("{section}.{k}: not a number"))?;
                flat.insert(format!("{section}.{k}"), n);
            }
        }
    }
    if let Some(Json::Obj(map)) = doc.get("sketches") {
        for (k, sk) in map {
            for col in SKETCH_COLS {
                if let Some(n) = sk.get(col).and_then(Json::as_f64) {
                    flat.insert(format!("sketches.{k}.{col}"), n);
                }
            }
        }
    }
    Ok(flat)
}

/// Compare two parsed `vsa-metrics-v1` documents.  `max_regress_pct`
/// is the allowed worse-direction relative change in percent
/// (`f64::INFINITY` = report-only, never gate).
pub fn diff_snapshots(a: &Json, b: &Json, max_regress_pct: f64) -> Result<DiffReport, String> {
    let fa = flatten(a)?;
    let fb = flatten(b)?;
    let mut report = DiffReport::default();
    for (key, &va) in &fa {
        let Some(&vb) = fb.get(key) else {
            report.only_a.push(key.clone());
            continue;
        };
        // Worse direction: up for most metrics, down for the
        // higher-is-better suffixes.
        let higher_better = HIGHER_IS_BETTER.iter().any(|s| key.ends_with(s));
        let worse = if higher_better { va - vb } else { vb - va };
        let regress_frac = if va.abs() < 1e-9 && vb.abs() < 1e-9 {
            0.0 // both effectively zero: no signal either way
        } else {
            (worse / va.abs().max(1e-9)).max(0.0)
        };
        if regress_frac * 100.0 > max_regress_pct {
            report.regressions.push(key.clone());
        }
        report.deltas.push(Delta { key: key.clone(), a: va, b: vb, regress_frac });
    }
    for key in fb.keys() {
        if !fa.contains_key(key) {
            report.only_b.push(key.clone());
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::Registry;
    use std::time::Duration;

    fn snapshot_json(completed: u64, fail: u64, lat_ms: u64, rps: f64) -> Json {
        let reg = Registry::new();
        reg.counter("serve.completed").add(completed);
        reg.counter("serve.failed").add(fail);
        reg.gauge("serve.throughput_rps").set(rps);
        reg.sketch("serve.latency").record(Duration::from_millis(lat_ms));
        Json::parse(&reg.snapshot().to_json()).expect("snapshot parses")
    }

    #[test]
    fn identical_snapshots_never_regress() {
        let a = snapshot_json(100, 0, 5, 800.0);
        let b = snapshot_json(100, 0, 5, 800.0);
        let r = diff_snapshots(&a, &b, 0.0).unwrap();
        assert!(!r.has_regressions(), "{:?}", r.regressions);
        assert!(r.only_a.is_empty() && r.only_b.is_empty());
        assert!(!r.deltas.is_empty());
    }

    #[test]
    fn latency_increase_and_throughput_drop_both_gate() {
        let a = snapshot_json(100, 0, 5, 800.0);
        let slow = snapshot_json(100, 0, 20, 800.0);
        let r = diff_snapshots(&a, &slow, 50.0).unwrap();
        assert!(r.regressions.iter().any(|k| k.starts_with("sketches.serve.latency")));

        let choked = snapshot_json(100, 0, 5, 100.0);
        let r = diff_snapshots(&a, &choked, 50.0).unwrap();
        assert_eq!(r.regressions, vec!["gauges.serve.throughput_rps".to_string()]);

        // Improvements in the same columns never gate.
        let fast = snapshot_json(100, 0, 1, 2000.0);
        let r = diff_snapshots(&a, &fast, 0.0).unwrap();
        assert!(!r.has_regressions(), "{:?}", r.regressions);
    }

    #[test]
    fn zero_to_nonzero_failure_is_a_regression_at_any_threshold() {
        let clean = snapshot_json(100, 0, 5, 800.0);
        let broken = snapshot_json(100, 3, 5, 800.0);
        // 0 -> 3 failures: relative change is huge, so even a very
        // generous percentage threshold trips.
        let r = diff_snapshots(&clean, &broken, 1000.0).unwrap();
        assert_eq!(r.regressions, vec!["counters.serve.failed".to_string()]);
    }

    #[test]
    fn one_sided_keys_inform_but_never_gate() {
        let a = snapshot_json(100, 0, 5, 800.0);
        let reg = Registry::new();
        reg.counter("serve.completed").add(100);
        reg.counter("serve.new_metric").add(7);
        let b = Json::parse(&reg.snapshot().to_json()).unwrap();
        let r = diff_snapshots(&a, &b, 0.0).unwrap();
        assert!(r.only_a.iter().any(|k| k.contains("latency")));
        assert_eq!(r.only_b, vec!["counters.serve.new_metric".to_string()]);
        assert!(!r.regressions.iter().any(|k| k.contains("new_metric")));
    }

    #[test]
    fn schema_mismatch_is_an_error() {
        let bogus = Json::parse(r#"{"schema":"nope","counters":{}}"#).unwrap();
        let a = snapshot_json(1, 0, 1, 1.0);
        assert!(diff_snapshots(&a, &bogus, 0.0).is_err());
    }

    #[test]
    fn render_mentions_regressions() {
        let a = snapshot_json(100, 0, 5, 800.0);
        let b = snapshot_json(100, 5, 5, 800.0);
        let r = diff_snapshots(&a, &b, 10.0).unwrap();
        assert!(r.render().contains("REGRESSION"));
    }
}

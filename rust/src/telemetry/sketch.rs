//! Log-bucketed latency histogram sketch (hdrhistogram-style, PR7).
//!
//! Replaces the coordinator's unbounded per-request latency vector with
//! O([`BUCKETS`]) memory and a **proven relative-error bound**.  Values
//! are nanosecond ticks placed into a fixed log-linear bucket layout:
//! each power-of-two octave is cut into [`SUB`] equal sub-buckets, so a
//! bucket at scale `2^g` has width `2^g` and lower bound `>= SUB * 2^g`.
//! The quantile estimate is the midpoint of the bucket holding the
//! nearest-rank sample (same `round((n-1)*q)` rank convention as
//! [`crate::util::stats::quantile_sorted`]), hence
//!
//! > |estimate − exact| / exact ≤ 1 / (2·SUB) = [`REL_ERROR`] (1.5625%)
//!
//! unconditionally: width-1 buckets (values below `2*SUB` ns) are exact,
//! and wider buckets start at `SUB` times their width.  Estimates are
//! additionally clamped to the tracked exact `[min, max]`, so a
//! single-sample sketch reports that sample exactly and `quantile(1.0)`
//! is the true maximum.
//!
//! Sketches are **mergeable**: bucket counts are `u64`, so merging is
//! associative, commutative, and byte-deterministic however samples were
//! sharded across workers — the property the coordinator's fixed-order
//! shard merge relies on (README §OBSERVABILITY).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// log2 of the sub-buckets per power-of-two octave.
pub const SUB_BITS: u32 = 5;
/// Sub-buckets per octave (32).
pub const SUB: u64 = 1 << SUB_BITS;
/// Fixed bucket count covering the full `u64` nanosecond range.
pub const BUCKETS: usize = (64 - SUB_BITS as usize + 1) * SUB as usize;
/// Worst-case relative error of a quantile estimate vs the exact
/// nearest-rank sample: half a bucket width over the bucket's lower
/// bound, `1 / (2 * SUB)`.
pub const REL_ERROR: f64 = 1.0 / (2 * SUB) as f64;

/// Bucket index of a nanosecond value (monotone non-decreasing in `v`).
#[inline]
pub fn bucket_of(v: u64) -> usize {
    if v < SUB {
        v as usize
    } else {
        let msb = 63 - v.leading_zeros();
        let shift = msb - SUB_BITS;
        let top = (v >> shift) as usize - SUB as usize;
        (shift as usize + 1) * SUB as usize + top
    }
}

/// `[lo, hi)` nanosecond bounds of bucket `i` (inverse of [`bucket_of`]).
#[inline]
pub fn bucket_bounds(i: usize) -> (u64, u64) {
    if i < SUB as usize {
        (i as u64, i as u64 + 1)
    } else {
        let shift = (i / SUB as usize - 1) as u32;
        let top = (i % SUB as usize) as u64;
        let lo = (SUB + top) << shift;
        (lo, lo + (1u64 << shift))
    }
}

/// Representative value of bucket `i`: exact for width-1 buckets, the
/// midpoint otherwise.
#[inline]
fn bucket_mid(i: usize) -> f64 {
    let (lo, hi) = bucket_bounds(i);
    if hi - lo == 1 {
        lo as f64
    } else {
        lo as f64 + (hi - lo) as f64 / 2.0
    }
}

#[inline]
fn ns_of(d: Duration) -> u64 {
    d.as_nanos().min(u64::MAX as u128) as u64
}

/// A merged / owned histogram sketch (see the module docs).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistogramSketch {
    counts: Vec<u64>,
    count: u64,
    sum_ns: u64,
    min_ns: u64,
    max_ns: u64,
}

impl Default for HistogramSketch {
    fn default() -> Self {
        Self::new()
    }
}

impl HistogramSketch {
    /// An empty sketch (fixed [`BUCKETS`]-slot layout).
    pub fn new() -> Self {
        Self { counts: vec![0; BUCKETS], count: 0, sum_ns: 0, min_ns: u64::MAX, max_ns: 0 }
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// True when no sample has been recorded.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Record one nanosecond sample.
    pub fn record_ns(&mut self, v: u64) {
        self.counts[bucket_of(v)] += 1;
        self.count += 1;
        self.sum_ns = self.sum_ns.saturating_add(v);
        self.min_ns = self.min_ns.min(v);
        self.max_ns = self.max_ns.max(v);
    }

    /// Record a duration (saturating at `u64::MAX` ns ≈ 584 years).
    pub fn record(&mut self, d: Duration) {
        self.record_ns(ns_of(d));
    }

    /// Record a millisecond sample given as `f64`.  NaN-safe: non-finite
    /// samples are ignored (a NaN latency carries no information) and
    /// negative ones clamp to zero — no panic on any input.
    pub fn record_ms(&mut self, ms: f64) {
        if !ms.is_finite() {
            return;
        }
        self.record_ns((ms.max(0.0) * 1e6).round().min(u64::MAX as f64) as u64);
    }

    /// Merge another sketch's samples into this one.  Associative and
    /// commutative (pure `u64` arithmetic): any merge order over the same
    /// shards yields an identical sketch.
    pub fn merge(&mut self, other: &HistogramSketch) {
        debug_assert_eq!(self.counts.len(), other.counts.len(), "fixed layout");
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns = self.sum_ns.saturating_add(other.sum_ns);
        self.min_ns = self.min_ns.min(other.min_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    /// Nearest-rank `q`-quantile estimate in nanoseconds (0.0 when
    /// empty).  `q` is clamped to `[0, 1]`; a NaN `q` reads as 0.  The
    /// estimate is within [`REL_ERROR`] of the exact quantile of the
    /// recorded samples and clamped to the exact `[min, max]`.
    pub fn quantile_ns(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let q = if q.is_nan() { 0.0 } else { q.clamp(0.0, 1.0) };
        let rank = ((self.count - 1) as f64 * q).round() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            seen += c;
            if seen > rank {
                return bucket_mid(i).clamp(self.min_ns as f64, self.max_ns as f64);
            }
        }
        self.max_ns as f64
    }

    /// [`Self::quantile_ns`] in milliseconds.
    pub fn quantile_ms(&self, q: f64) -> f64 {
        self.quantile_ns(q) / 1e6
    }

    /// Mean of the recorded samples in milliseconds (0.0 when empty).
    pub fn mean_ms(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum_ns as f64 / self.count as f64 / 1e6
    }

    /// Exact maximum recorded sample in milliseconds (0.0 when empty).
    pub fn max_ms(&self) -> f64 {
        self.max_ns as f64 / 1e6
    }

    /// Exact minimum recorded sample in milliseconds (0.0 when empty).
    pub fn min_ms(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.min_ns as f64 / 1e6
    }

    /// The standard percentile summary of this sketch.
    pub fn summary(&self) -> LatencySummary {
        LatencySummary {
            count: self.count,
            mean_ms: self.mean_ms(),
            p50_ms: self.quantile_ms(0.50),
            p95_ms: self.quantile_ms(0.95),
            p99_ms: self.quantile_ms(0.99),
            p999_ms: self.quantile_ms(0.999),
            max_ms: self.max_ms(),
        }
    }
}

/// Percentile summary derived from one [`HistogramSketch`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LatencySummary {
    pub count: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
    pub max_ms: f64,
}

impl LatencySummary {
    /// One-line rendering used by `vsa serve` / `vsa serve-bench`.
    pub fn render(&self) -> String {
        format!(
            "n {} mean {:.3} p50 {:.3} p95 {:.3} p99 {:.3} p999 {:.3} max {:.3}",
            self.count, self.mean_ms, self.p50_ms, self.p95_ms, self.p99_ms, self.p999_ms,
            self.max_ms
        )
    }
}

/// Lock-free shard of a [`HistogramSketch`]: relaxed atomic bucket
/// counters a single writer (or several) can record into without any
/// shared lock, snapshotted into an owned sketch for merging.  The
/// coordinator gives each worker its own shard, so the delivery hot
/// path never contends (README §OBSERVABILITY).
#[derive(Debug)]
pub struct AtomicSketch {
    counts: Box<[AtomicU64]>,
    count: AtomicU64,
    sum_ns: AtomicU64,
    min_ns: AtomicU64,
    max_ns: AtomicU64,
}

impl Default for AtomicSketch {
    fn default() -> Self {
        Self::new()
    }
}

impl AtomicSketch {
    /// An empty shard.
    pub fn new() -> Self {
        Self {
            counts: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            min_ns: AtomicU64::new(u64::MAX),
            max_ns: AtomicU64::new(0),
        }
    }

    /// Record one nanosecond sample (relaxed atomics, no lock).
    pub fn record_ns(&self, v: u64) {
        self.counts[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(v, Ordering::Relaxed);
        self.min_ns.fetch_min(v, Ordering::Relaxed);
        self.max_ns.fetch_max(v, Ordering::Relaxed);
    }

    /// Record a duration.
    pub fn record(&self, d: Duration) {
        self.record_ns(ns_of(d));
    }

    /// Samples recorded so far.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Merge an owned sketch into this shard (used by registry export).
    pub fn merge_from(&self, other: &HistogramSketch) {
        for (a, &b) in self.counts.iter().zip(&other.counts) {
            if b > 0 {
                a.fetch_add(b, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count, Ordering::Relaxed);
        self.sum_ns.fetch_add(other.sum_ns, Ordering::Relaxed);
        self.min_ns.fetch_min(other.min_ns, Ordering::Relaxed);
        self.max_ns.fetch_max(other.max_ns, Ordering::Relaxed);
    }

    /// Owned snapshot of this shard.  Quiescent shards (workers joined,
    /// or a single-threaded writer) snapshot exactly; a snapshot taken
    /// mid-run may lag in-flight samples but never tears a counter.
    pub fn snapshot(&self) -> HistogramSketch {
        let mut out = HistogramSketch::new();
        for (dst, src) in out.counts.iter_mut().zip(self.counts.iter()) {
            *dst = src.load(Ordering::Relaxed);
        }
        out.count = self.count.load(Ordering::Relaxed);
        out.sum_ns = self.sum_ns.load(Ordering::Relaxed);
        out.min_ns = self.min_ns.load(Ordering::Relaxed);
        out.max_ns = self.max_ns.load(Ordering::Relaxed);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_layout_is_monotone_and_self_inverse() {
        // Exhaustive near the origin, sampled across every octave.
        let mut prev = 0usize;
        for v in 0u64..4096 {
            let b = bucket_of(v);
            assert!(b >= prev, "monotone at {v}");
            prev = b;
            let (lo, hi) = bucket_bounds(b);
            assert!(lo <= v && v < hi, "v={v} in [{lo},{hi})");
        }
        for shift in 0..58u32 {
            for &v in &[SUB << shift, (SUB << shift) + 1, ((2 * SUB) << shift) - 1] {
                let (lo, hi) = bucket_bounds(bucket_of(v));
                assert!(lo <= v && v < hi, "v={v} in [{lo},{hi})");
                assert!(lo >= SUB * (hi - lo), "rel-width invariant at {v}");
            }
        }
        assert!(bucket_of(u64::MAX) < BUCKETS);
    }

    #[test]
    fn empty_and_single_sample_edges() {
        let mut s = HistogramSketch::new();
        assert!(s.is_empty());
        for q in [0.0, 0.5, 0.999, 1.0, -2.0, f64::NAN] {
            assert_eq!(s.quantile_ns(q), 0.0);
        }
        assert_eq!(s.mean_ms(), 0.0);
        assert_eq!(s.max_ms(), 0.0);
        assert_eq!(s.min_ms(), 0.0);
        // One sample: every quantile is exactly that sample (clamped to
        // the tracked min == max).
        s.record(Duration::from_nanos(123_456_789));
        for q in [0.0, 0.5, 0.95, 1.0] {
            assert_eq!(s.quantile_ns(q), 123_456_789.0);
        }
        assert_eq!(s.summary().count, 1);
    }

    #[test]
    fn record_ms_is_nan_safe() {
        let mut s = HistogramSketch::new();
        s.record_ms(f64::NAN);
        s.record_ms(f64::INFINITY);
        s.record_ms(f64::NEG_INFINITY);
        assert!(s.is_empty(), "non-finite samples are ignored");
        s.record_ms(-3.0);
        assert_eq!(s.quantile_ms(0.5), 0.0, "negative clamps to zero");
        s.record_ms(2.5);
        assert_eq!(s.count(), 2);
        assert_eq!(s.quantile_ms(1.0), 2.5);
    }

    #[test]
    fn quantile_clamps_q_instead_of_panicking() {
        let mut s = HistogramSketch::new();
        for v in [10_000u64, 20_000, 30_000] {
            s.record_ns(v);
        }
        assert_eq!(s.quantile_ns(-0.5), 10_000.0);
        assert_eq!(s.quantile_ns(1.5), 30_000.0);
        assert_eq!(s.quantile_ns(f64::NAN), 10_000.0, "NaN q reads as 0");
    }

    #[test]
    fn atomic_shard_snapshot_matches_owned() {
        let shard = AtomicSketch::new();
        let mut owned = HistogramSketch::new();
        for v in [5u64, 77, 1 << 20, 1 << 40, 999_999] {
            shard.record_ns(v);
            owned.record_ns(v);
        }
        assert_eq!(shard.snapshot(), owned);
        assert_eq!(shard.count(), 5);
        // merge_from doubles every moment.
        shard.merge_from(&owned);
        let doubled = shard.snapshot();
        assert_eq!(doubled.count(), 10);
        assert_eq!(doubled.quantile_ns(1.0), owned.quantile_ns(1.0));
    }
}

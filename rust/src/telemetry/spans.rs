//! Hierarchical span tracing (PR8): per-thread ring buffers with a
//! deterministic flush order and Chrome trace-event JSON export.
//!
//! Aggregates (PR7's sketches and counters) answer "how much"; spans
//! answer "where did it go".  A [`SpanCollector`] hands out one
//! [`SpanRecorder`] per thread; each recorder owns its ring buffer
//! outright, so recording is plain memory writes — no locks, no
//! atomics, no allocation beyond the ring itself (the hot-path cost is
//! one `Instant` read and a slot write).  Rings keep the latest
//! `capacity` records and count what they overwrote.  On flush (or
//! recorder drop) the ring moves into the collector under a mutex once
//! per thread; [`SpanCollector::sheet`] then orders lanes by their
//! caller-assigned lane id, so the exported byte stream is identical
//! at any thread count or join order.
//!
//! The export is the Chrome trace-event format (`vsa-trace-v1`): load
//! it in Perfetto (<https://ui.perfetto.dev>) or `chrome://tracing`.
//! Span `pid`s name coarse tracks-groups (see [`pids`]), `tid`s name
//! tracks within them; see README §OBSERVABILITY.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::config::json::{self, Json};

/// Schema tag written into `otherData.schema` of every export.
pub const TRACE_SCHEMA: &str = "vsa-trace-v1";

/// Well-known process ids — Perfetto groups tracks by pid, so each
/// instrumented subsystem gets one.
pub mod pids {
    /// Coordinator worker threads (tid = worker index).
    pub const SERVE_WORKERS: u32 = 0;
    /// Per-request span trees (tid = request id).
    pub const SERVE_REQUESTS: u32 = 1;
    /// Trainer step/phase spans.
    pub const TRAIN: u32 = 2;
    /// Chip-simulator cycle timeline (layers, PE groups, DRAM).
    pub const CHIP: u32 = 3;
}

/// What a [`SpanRecord`] renders as.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// A complete span (`ph: "X"`): `ts_ns` .. `ts_ns + dur_ns`.
    Span,
    /// A point event (`ph: "i"`): `dur_ns` is ignored.
    Instant,
    /// A counter sample (`ph: "C"`): `args` holds the series values.
    Counter,
}

/// One recorded event.  Timestamps are nanoseconds since the
/// collector's epoch (or any caller-chosen zero for synthetic sheets).
#[derive(Debug, Clone)]
pub struct SpanRecord {
    pub kind: SpanKind,
    pub pid: u32,
    pub tid: u64,
    pub name: String,
    pub ts_ns: u64,
    pub dur_ns: u64,
    /// Numeric key/values exported under `args`.
    pub args: Vec<(&'static str, f64)>,
    /// Free-form annotation exported as `args.what`.
    pub note: Option<String>,
}

/// Fixed-capacity keep-latest ring.  Chronological order is restored
/// on drain; `seq` counts every push so drops are exact.
struct Ring {
    slots: Vec<SpanRecord>,
    cap: usize,
    head: usize,
    seq: u64,
}

impl Ring {
    fn new(cap: usize) -> Ring {
        Ring { slots: Vec::new(), cap: cap.max(1), head: 0, seq: 0 }
    }

    fn push(&mut self, r: SpanRecord) {
        self.seq += 1;
        if self.slots.len() < self.cap {
            self.slots.push(r);
        } else {
            self.slots[self.head] = r;
            self.head = (self.head + 1) % self.cap;
        }
    }

    /// Records overwritten since the last drain.
    fn dropped(&self) -> u64 {
        self.seq - self.slots.len() as u64
    }

    /// Take all records in chronological order and reset.
    fn drain(&mut self) -> Vec<SpanRecord> {
        let head = self.head;
        let mut v = std::mem::take(&mut self.slots);
        v.rotate_left(head);
        self.head = 0;
        self.seq = 0;
        v
    }
}

#[derive(Default)]
struct Inner {
    /// Flushed lanes: (lane id, records, dropped count).
    lanes: Vec<(u32, Vec<SpanRecord>, u64)>,
    track_names: BTreeMap<(u32, u64), String>,
    process_names: BTreeMap<u32, String>,
}

/// Shared sink for every thread's recorder.  Cheap to clone via `Arc`;
/// the mutex is taken only on flush, naming, and [`sheet`].
///
/// [`sheet`]: SpanCollector::sheet
pub struct SpanCollector {
    epoch: Instant,
    inner: Mutex<Inner>,
}

impl SpanCollector {
    pub fn new() -> Arc<SpanCollector> {
        Arc::new(SpanCollector { epoch: Instant::now(), inner: Mutex::new(Inner::default()) })
    }

    /// Nanoseconds since the collector was created.
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Map an `Instant` onto the collector's clock (pre-epoch → 0).
    pub fn ns_of(&self, t: Instant) -> u64 {
        match t.checked_duration_since(self.epoch) {
            Some(d) => d.as_nanos() as u64,
            None => 0,
        }
    }

    /// Hand out a recorder.  `lane` fixes this recorder's position in
    /// the flush order (use the worker index); `pid`/`tid` are the
    /// default track for the stack API ([`SpanRecorder::begin`]).
    pub fn recorder(self: &Arc<Self>, lane: u32, pid: u32, tid: u64, cap: usize) -> SpanRecorder {
        SpanRecorder {
            lane,
            pid,
            tid,
            collector: Arc::clone(self),
            ring: Ring::new(cap),
            stack: Vec::new(),
        }
    }

    /// Label a pid in the trace UI.
    pub fn name_process(&self, pid: u32, name: &str) {
        self.inner.lock().unwrap().process_names.insert(pid, name.to_string());
    }

    /// Label a (pid, tid) track in the trace UI.
    pub fn name_track(&self, pid: u32, tid: u64, name: &str) {
        self.inner.lock().unwrap().track_names.insert((pid, tid), name.to_string());
    }

    /// Collect every flushed lane into one sheet, ordered by lane id
    /// (stable for ties), so export bytes don't depend on thread join
    /// order.  Lanes flushed after this call go into the next sheet.
    pub fn sheet(&self) -> SpanSheet {
        let mut inner = self.inner.lock().unwrap();
        let mut lanes = std::mem::take(&mut inner.lanes);
        lanes.sort_by_key(|(lane, _, _)| *lane);
        let mut sheet = SpanSheet::new();
        sheet.track_names = inner.track_names.clone();
        sheet.process_names = inner.process_names.clone();
        for (_, records, dropped) in lanes {
            sheet.dropped += dropped;
            sheet.records.extend(records);
        }
        sheet
    }
}

/// Per-thread recorder.  NOT `Sync` — each thread owns exactly one, so
/// recording needs no synchronization at all.  Flushes its ring into
/// the collector on [`flush`] and on drop.
///
/// [`flush`]: SpanRecorder::flush
pub struct SpanRecorder {
    lane: u32,
    pid: u32,
    tid: u64,
    collector: Arc<SpanCollector>,
    ring: Ring,
    /// Open spans for the stack API: (name, start ns).
    stack: Vec<(String, u64)>,
}

impl SpanRecorder {
    /// Nanoseconds since the collector's epoch.
    pub fn now_ns(&self) -> u64 {
        self.collector.now_ns()
    }

    /// Map an `Instant` onto the collector's clock.
    pub fn ns_of(&self, t: Instant) -> u64 {
        self.collector.ns_of(t)
    }

    /// Open a span on this recorder's own track, timed now.
    pub fn begin(&mut self, name: &str) {
        self.stack.push((name.to_string(), self.now_ns()));
    }

    /// Close the innermost open span, timed now.
    pub fn end(&mut self) {
        self.end_with(&[]);
    }

    /// Close the innermost open span with `args` attached.
    pub fn end_with(&mut self, args: &[(&'static str, f64)]) {
        if let Some((name, start)) = self.stack.pop() {
            let now = self.now_ns();
            self.ring.push(SpanRecord {
                kind: SpanKind::Span,
                pid: self.pid,
                tid: self.tid,
                name,
                ts_ns: start,
                dur_ns: now.saturating_sub(start),
                args: args.to_vec(),
                note: None,
            });
        }
    }

    /// Record a complete span on an explicit track with explicit
    /// timestamps (for reconstructing trees from measurements taken
    /// elsewhere, e.g. the coordinator's per-request accounting).
    #[allow(clippy::too_many_arguments)]
    pub fn span_at(
        &mut self,
        pid: u32,
        tid: u64,
        name: &str,
        ts_ns: u64,
        dur_ns: u64,
        args: &[(&'static str, f64)],
        note: Option<&str>,
    ) {
        self.ring.push(SpanRecord {
            kind: SpanKind::Span,
            pid,
            tid,
            name: name.to_string(),
            ts_ns,
            dur_ns,
            args: args.to_vec(),
            note: note.map(str::to_string),
        });
    }

    /// Record a point event.
    pub fn instant(
        &mut self,
        pid: u32,
        tid: u64,
        name: &str,
        ts_ns: u64,
        args: &[(&'static str, f64)],
        note: Option<&str>,
    ) {
        self.ring.push(SpanRecord {
            kind: SpanKind::Instant,
            pid,
            tid,
            name: name.to_string(),
            ts_ns,
            dur_ns: 0,
            args: args.to_vec(),
            note: note.map(str::to_string),
        });
    }

    /// Record a counter sample (one series named `value`).
    pub fn counter(&mut self, pid: u32, tid: u64, name: &str, ts_ns: u64, value: f64) {
        self.ring.push(SpanRecord {
            kind: SpanKind::Counter,
            pid,
            tid,
            name: name.to_string(),
            ts_ns,
            dur_ns: 0,
            args: vec![("value", value)],
            note: None,
        });
    }

    /// Move the ring's contents into the collector.  Called
    /// automatically on drop; safe to call repeatedly.
    pub fn flush(&mut self) {
        let dropped = self.ring.dropped();
        let records = self.ring.drain();
        if records.is_empty() && dropped == 0 {
            return;
        }
        self.collector.inner.lock().unwrap().lanes.push((self.lane, records, dropped));
    }
}

impl Drop for SpanRecorder {
    fn drop(&mut self) {
        self.flush();
    }
}

/// A finished, ordered set of records plus track metadata — the unit
/// of export.  Built by [`SpanCollector::sheet`] or assembled directly
/// (the chip timeline synthesizes one from cycle stamps).
#[derive(Default)]
pub struct SpanSheet {
    records: Vec<SpanRecord>,
    /// Records lost to ring overwrites (exported in `otherData`).
    pub dropped: u64,
    track_names: BTreeMap<(u32, u64), String>,
    process_names: BTreeMap<u32, String>,
}

impl SpanSheet {
    pub fn new() -> SpanSheet {
        SpanSheet::default()
    }

    pub fn push(&mut self, r: SpanRecord) {
        self.records.push(r);
    }

    pub fn name_process(&mut self, pid: u32, name: &str) {
        self.process_names.insert(pid, name.to_string());
    }

    pub fn name_track(&mut self, pid: u32, tid: u64, name: &str) {
        self.track_names.insert((pid, tid), name.to_string());
    }

    pub fn records(&self) -> &[SpanRecord] {
        &self.records
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Serialize as Chrome trace-event JSON (`vsa-trace-v1`).
    ///
    /// Metadata events (process/thread names, sorted) come first, then
    /// every record in sheet order.  Timestamps are microseconds
    /// (fractional — Chrome's native unit).  Output is byte-identical
    /// for identical sheets: key order comes from `BTreeMap`, number
    /// formatting from the shared [`json`] writer.
    pub fn to_chrome_json(&self) -> String {
        let mut events = Vec::new();
        for (pid, name) in &self.process_names {
            events.push(meta_event(*pid, 0, "process_name", name));
        }
        for ((pid, tid), name) in &self.track_names {
            events.push(meta_event(*pid, *tid, "thread_name", name));
        }
        for r in &self.records {
            let mut e = BTreeMap::new();
            e.insert("pid".to_string(), Json::Num(r.pid as f64));
            e.insert("tid".to_string(), Json::Num(r.tid as f64));
            e.insert("name".to_string(), Json::Str(r.name.clone()));
            e.insert("cat".to_string(), Json::Str("vsa".to_string()));
            e.insert("ts".to_string(), Json::Num(r.ts_ns as f64 / 1000.0));
            let ph = match r.kind {
                SpanKind::Span => {
                    e.insert("dur".to_string(), Json::Num(r.dur_ns as f64 / 1000.0));
                    "X"
                }
                SpanKind::Instant => {
                    // scope "t": thread-scoped tick mark.
                    e.insert("s".to_string(), Json::Str("t".to_string()));
                    "i"
                }
                SpanKind::Counter => "C",
            };
            e.insert("ph".to_string(), Json::Str(ph.to_string()));
            if !r.args.is_empty() || r.note.is_some() {
                let mut args = BTreeMap::new();
                for (k, v) in &r.args {
                    args.insert(k.to_string(), Json::Num(*v));
                }
                if let Some(note) = &r.note {
                    args.insert("what".to_string(), Json::Str(note.clone()));
                }
                e.insert("args".to_string(), Json::Obj(args));
            }
            events.push(Json::Obj(e));
        }

        let mut other = BTreeMap::new();
        other.insert("schema".to_string(), Json::Str(TRACE_SCHEMA.to_string()));
        other.insert("dropped".to_string(), Json::Num(self.dropped as f64));
        let mut doc = BTreeMap::new();
        doc.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
        doc.insert("otherData".to_string(), Json::Obj(other));
        doc.insert("traceEvents".to_string(), Json::Arr(events));
        json::to_string(&Json::Obj(doc))
    }

    /// Verify the structural invariant behind the export: on every
    /// (pid, tid) track, spans either nest (child fully inside parent)
    /// or are disjoint — no partial overlap.  Returns the first
    /// violation found.
    pub fn check_nesting(&self) -> Result<(), String> {
        let mut tracks: BTreeMap<(u32, u64), Vec<(u64, u64, &str)>> = BTreeMap::new();
        for r in &self.records {
            if r.kind == SpanKind::Span {
                let end = r.ts_ns.saturating_add(r.dur_ns);
                tracks.entry((r.pid, r.tid)).or_default().push((r.ts_ns, end, &r.name));
            }
        }
        for ((pid, tid), mut spans) in tracks {
            // Parent-before-child order: by start, widest first on ties.
            spans.sort_by_key(|&(ts, end, _)| (ts, std::cmp::Reverse(end)));
            let mut open: Vec<(u64, &str)> = Vec::new();
            for (ts, end, name) in spans {
                while let Some(&(top_end, _)) = open.last() {
                    if top_end <= ts {
                        open.pop();
                    } else {
                        break;
                    }
                }
                if let Some(&(top_end, top_name)) = open.last() {
                    if end > top_end {
                        return Err(format!(
                            "track ({pid},{tid}): span '{name}' [{ts},{end}) ends past \
                             enclosing '{top_name}' [..,{top_end})"
                        ));
                    }
                }
                open.push((end, name));
            }
        }
        Ok(())
    }
}

fn meta_event(pid: u32, tid: u64, kind: &str, name: &str) -> Json {
    let mut args = BTreeMap::new();
    args.insert("name".to_string(), Json::Str(name.to_string()));
    let mut e = BTreeMap::new();
    e.insert("ph".to_string(), Json::Str("M".to_string()));
    e.insert("pid".to_string(), Json::Num(pid as f64));
    e.insert("tid".to_string(), Json::Num(tid as f64));
    e.insert("name".to_string(), Json::Str(kind.to_string()));
    e.insert("args".to_string(), Json::Obj(args));
    Json::Obj(e)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(ts: u64, dur: u64, name: &str) -> SpanRecord {
        SpanRecord {
            kind: SpanKind::Span,
            pid: 0,
            tid: 0,
            name: name.to_string(),
            ts_ns: ts,
            dur_ns: dur,
            args: Vec::new(),
            note: None,
        }
    }

    #[test]
    fn ring_keeps_latest_and_counts_drops() {
        let mut ring = Ring::new(4);
        for i in 0..10u64 {
            ring.push(rec(i, 1, "r"));
        }
        assert_eq!(ring.dropped(), 6);
        let drained = ring.drain();
        let ts: Vec<u64> = drained.iter().map(|r| r.ts_ns).collect();
        assert_eq!(ts, vec![6, 7, 8, 9], "chronological, latest kept");
        assert_eq!(ring.dropped(), 0, "drain resets the drop count");
    }

    #[test]
    fn stack_api_nests_and_flushes_on_drop() {
        let col = SpanCollector::new();
        {
            let mut r = col.recorder(0, 7, 1, 64);
            r.begin("outer");
            r.begin("inner");
            r.end();
            r.end_with(&[("n", 2.0)]);
        } // drop flushes
        let sheet = col.sheet();
        assert_eq!(sheet.len(), 2);
        // Ring order is end order: inner closed first.
        assert_eq!(sheet.records()[0].name, "inner");
        assert_eq!(sheet.records()[1].name, "outer");
        let inner = &sheet.records()[0];
        let outer = &sheet.records()[1];
        assert!(outer.ts_ns <= inner.ts_ns);
        assert!(inner.ts_ns + inner.dur_ns <= outer.ts_ns + outer.dur_ns);
        sheet.check_nesting().expect("proper nesting");
    }

    #[test]
    fn nesting_check_rejects_partial_overlap() {
        let mut sheet = SpanSheet::new();
        sheet.push(rec(0, 100, "a"));
        sheet.push(rec(50, 100, "b")); // ends at 150 > a's 100
        assert!(sheet.check_nesting().is_err());

        let mut ok = SpanSheet::new();
        ok.push(rec(0, 100, "a"));
        ok.push(rec(50, 50, "b")); // ends exactly with a: contained
        ok.push(rec(100, 20, "c")); // disjoint
        ok.check_nesting().expect("containment and disjoint both fine");
    }

    #[test]
    fn chrome_export_parses_and_carries_schema() {
        let mut sheet = SpanSheet::new();
        sheet.name_process(3, "chip");
        sheet.name_track(3, 0, "layers");
        sheet.push(rec(1000, 500, "L0"));
        sheet.push(SpanRecord {
            kind: SpanKind::Counter,
            pid: 3,
            tid: 50,
            name: "dram".to_string(),
            ts_ns: 1000,
            dur_ns: 0,
            args: vec![("value", 2.5)],
            note: None,
        });
        sheet.push(SpanRecord {
            kind: SpanKind::Instant,
            pid: 3,
            tid: 50,
            name: "xfer".to_string(),
            ts_ns: 1200,
            dur_ns: 0,
            args: vec![("bytes", 784.0)],
            note: Some("image".to_string()),
        });
        let text = sheet.to_chrome_json();
        let doc = Json::parse(&text).expect("valid JSON");
        let schema = doc.get("otherData").and_then(|o| o.get("schema"));
        assert_eq!(schema.and_then(Json::as_str), Some(TRACE_SCHEMA));
        let events = doc.get("traceEvents").and_then(Json::as_arr).expect("events");
        // 2 metadata + 3 records.
        assert_eq!(events.len(), 5);
        assert_eq!(events[0].get("ph").and_then(Json::as_str), Some("M"));
        let span = &events[2];
        assert_eq!(span.get("ph").and_then(Json::as_str), Some("X"));
        assert_eq!(span.get("ts").and_then(Json::as_f64), Some(1.0));
        assert_eq!(span.get("dur").and_then(Json::as_f64), Some(0.5));
        let inst = &events[4];
        let what = inst.get("args").and_then(|a| a.get("what"));
        assert_eq!(what.and_then(Json::as_str), Some("image"));
    }

    #[test]
    fn flush_order_is_lane_order_not_flush_order() {
        let col = SpanCollector::new();
        let mut late = col.recorder(1, 0, 1, 8);
        let mut early = col.recorder(0, 0, 0, 8);
        late.span_at(0, 1, "lane1", 10, 5, &[], None);
        early.span_at(0, 0, "lane0", 20, 5, &[], None);
        late.flush(); // lane 1 flushes first...
        early.flush();
        let sheet = col.sheet();
        // ...but lane 0 still exports first.
        assert_eq!(sheet.records()[0].name, "lane0");
        assert_eq!(sheet.records()[1].name, "lane1");
    }

    #[test]
    fn export_is_deterministic() {
        let build = || {
            let col = SpanCollector::new();
            col.name_process(0, "p");
            let mut r = col.recorder(0, 0, 0, 8);
            r.span_at(0, 0, "a", 100, 50, &[("k", 1.5)], Some("note"));
            r.counter(0, 9, "c", 120, 3.0);
            drop(r);
            col.sheet().to_chrome_json()
        };
        assert_eq!(build(), build());
    }
}

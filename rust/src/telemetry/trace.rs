//! Per-request stage tracing for the serving coordinator (PR7).
//!
//! Every request that reaches a terminal outcome carries a [`Trace`]
//! splitting its end-to-end latency into the pipeline stages below, so
//! "where did my p99 go" is answerable from per-stage sketches instead
//! of a single opaque latency number:
//!
//! * **queue** — submit (`enqueued`) until a worker dequeued it;
//! * **batch** — dequeued until its batch was formed and handed to the
//!   engine path;
//! * **engine** — wall time inside engine attempts (summed over
//!   retries);
//! * **backoff** — measured retry-backoff sleeps;
//! * **deliver** — the residual: batch bookkeeping, response delivery,
//!   and waiting while *earlier batchmates'* retries ran (computed as
//!   `total − others`, saturating, so [`Trace::total`] reconstructs the
//!   end-to-end latency exactly by construction).

use std::time::Duration;

/// Pipeline stages of one request (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    Queue,
    Batch,
    Engine,
    Backoff,
    Deliver,
}

impl Stage {
    /// All stages, in pipeline order (the order stats and exports use).
    pub const ALL: [Stage; 5] =
        [Stage::Queue, Stage::Batch, Stage::Engine, Stage::Backoff, Stage::Deliver];

    /// Stable lowercase name used for metric keys and report rows.
    pub fn name(self) -> &'static str {
        match self {
            Stage::Queue => "queue",
            Stage::Batch => "batch",
            Stage::Engine => "engine",
            Stage::Backoff => "backoff",
            Stage::Deliver => "deliver",
        }
    }
}

/// Stage-time breakdown of one served request.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Trace {
    pub queue: Duration,
    pub batch: Duration,
    pub engine: Duration,
    pub backoff: Duration,
    pub deliver: Duration,
}

impl Trace {
    /// Build a trace from measured stage times plus the end-to-end
    /// latency; `deliver` absorbs the unattributed residual so the
    /// stages always sum back to `total` exactly.
    pub fn from_parts(
        total: Duration,
        queue: Duration,
        batch: Duration,
        engine: Duration,
        backoff: Duration,
    ) -> Self {
        let accounted = queue + batch + engine + backoff;
        Trace { queue, batch, engine, backoff, deliver: total.saturating_sub(accounted) }
    }

    /// Sum of all stage times (== the request's end-to-end latency for
    /// traces built via [`Trace::from_parts`]).
    pub fn total(&self) -> Duration {
        self.queue + self.batch + self.engine + self.backoff + self.deliver
    }

    /// The stage's duration (for iterating [`Stage::ALL`]).
    pub fn stage(&self, s: Stage) -> Duration {
        match s {
            Stage::Queue => self.queue,
            Stage::Batch => self.batch,
            Stage::Engine => self.engine,
            Stage::Backoff => self.backoff,
            Stage::Deliver => self.deliver,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_parts_reconstructs_total_exactly() {
        let t = Trace::from_parts(
            Duration::from_micros(1000),
            Duration::from_micros(100),
            Duration::from_micros(50),
            Duration::from_micros(700),
            Duration::from_micros(25),
        );
        assert_eq!(t.total(), Duration::from_micros(1000));
        assert_eq!(t.deliver, Duration::from_micros(125));
        // Over-accounted parts (clock skew between stamps) saturate
        // rather than panic; total then reflects the accounted sum.
        let t = Trace::from_parts(
            Duration::from_micros(10),
            Duration::from_micros(100),
            Duration::ZERO,
            Duration::ZERO,
            Duration::ZERO,
        );
        assert_eq!(t.deliver, Duration::ZERO);
        assert_eq!(t.total(), Duration::from_micros(100));
    }

    #[test]
    fn stage_accessor_matches_fields() {
        let t = Trace {
            queue: Duration::from_nanos(1),
            batch: Duration::from_nanos(2),
            engine: Duration::from_nanos(3),
            backoff: Duration::from_nanos(4),
            deliver: Duration::from_nanos(5),
        };
        let sum: Duration = Stage::ALL.iter().map(|&s| t.stage(s)).sum();
        assert_eq!(sum, t.total());
        assert_eq!(Stage::Engine.name(), "engine");
    }
}

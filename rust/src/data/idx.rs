//! IDX (MNIST) file loader.
//!
//! If the user drops real `train-images-idx3-ubyte` / `t10k-*` files under
//! `data/mnist/`, the benchmarks consume them instead of the synthetic
//! corpus.  Supports the two IDX variants MNIST uses: u8 3-D image tensors
//! (magic 0x0803) and u8 1-D label vectors (magic 0x0801).

use crate::data::Sample;
use std::io::Read;

/// Load an IDX3 image file: returns (images flat u8, rows, cols).
pub fn load_images(path: &str) -> Result<(Vec<Vec<u8>>, usize, usize), String> {
    let mut buf = Vec::new();
    std::fs::File::open(path)
        .map_err(|e| format!("{path}: {e}"))?
        .read_to_end(&mut buf)
        .map_err(|e| format!("{path}: {e}"))?;
    if buf.len() < 16 {
        return Err(format!("{path}: truncated header"));
    }
    let magic = u32::from_be_bytes(buf[0..4].try_into().unwrap());
    if magic != 0x0000_0803 {
        return Err(format!("{path}: bad magic {magic:#x} (want 0x803)"));
    }
    let n = u32::from_be_bytes(buf[4..8].try_into().unwrap()) as usize;
    let rows = u32::from_be_bytes(buf[8..12].try_into().unwrap()) as usize;
    let cols = u32::from_be_bytes(buf[12..16].try_into().unwrap()) as usize;
    // A corrupt/hostile header can make `n * rows * cols` overflow
    // (panic in debug, wrapped bound check then slice OOB in release) —
    // compute the body size checked and report instead.
    let need = n
        .checked_mul(rows)
        .and_then(|v| v.checked_mul(cols))
        .and_then(|v| v.checked_add(16))
        .ok_or_else(|| {
            format!("{path}: corrupt header ({n} x {rows} x {cols} images overflows)")
        })?;
    if buf.len() < need {
        return Err(format!("{path}: truncated body ({} < {need})", buf.len()));
    }
    let images = (0..n)
        .map(|i| buf[16 + i * rows * cols..16 + (i + 1) * rows * cols].to_vec())
        .collect();
    Ok((images, rows, cols))
}

/// Load an IDX1 label file.
pub fn load_labels(path: &str) -> Result<Vec<u8>, String> {
    let mut buf = Vec::new();
    std::fs::File::open(path)
        .map_err(|e| format!("{path}: {e}"))?
        .read_to_end(&mut buf)
        .map_err(|e| format!("{path}: {e}"))?;
    if buf.len() < 8 {
        return Err(format!("{path}: truncated header"));
    }
    let magic = u32::from_be_bytes(buf[0..4].try_into().unwrap());
    if magic != 0x0000_0801 {
        return Err(format!("{path}: bad magic {magic:#x} (want 0x801)"));
    }
    let n = u32::from_be_bytes(buf[4..8].try_into().unwrap()) as usize;
    let need = n
        .checked_add(8)
        .ok_or_else(|| format!("{path}: corrupt header ({n} labels overflows)"))?;
    if buf.len() < need {
        return Err(format!("{path}: truncated body"));
    }
    Ok(buf[8..need].to_vec())
}

/// Load paired images+labels into [`Sample`]s; `limit` caps the count.
pub fn load_samples(
    images_path: &str,
    labels_path: &str,
    limit: usize,
) -> Result<Vec<Sample>, String> {
    let (images, rows, cols) = load_images(images_path)?;
    let labels = load_labels(labels_path)?;
    if rows != cols {
        return Err(format!("non-square images {rows}x{cols} unsupported"));
    }
    // Zipping unequal splits would silently truncate a mislabeled
    // dataset to the shorter side — refuse instead.
    if images.len() != labels.len() {
        return Err(format!(
            "image/label count mismatch: {} images ({images_path}) vs {} labels \
             ({labels_path})",
            images.len(),
            labels.len()
        ));
    }
    Ok(images
        .into_iter()
        .zip(labels)
        .take(limit)
        .map(|(image, label)| Sample {
            image,
            channels: 1,
            size: rows,
            label: label as usize,
        })
        .collect())
}

/// Real MNIST test split under `data/mnist/`, if present.
pub fn mnist_if_available(limit: usize) -> Option<Vec<Sample>> {
    pair_if_available(
        "data/mnist/t10k-images-idx3-ubyte",
        "data/mnist/t10k-labels-idx1-ubyte",
        limit,
    )
}

/// Real MNIST *train* split under `data/mnist/`, if present — consumed
/// by `vsa train --dataset mnist`.
pub fn mnist_train_if_available(limit: usize) -> Option<Vec<Sample>> {
    pair_if_available(
        "data/mnist/train-images-idx3-ubyte",
        "data/mnist/train-labels-idx1-ubyte",
        limit,
    )
}

fn pair_if_available(imgs: &str, labs: &str, limit: usize) -> Option<Vec<Sample>> {
    if std::path::Path::new(imgs).exists() && std::path::Path::new(labs).exists() {
        load_samples(imgs, labs, limit).ok()
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_idx3(path: &std::path::Path, n: usize, side: usize) {
        let mut f = std::fs::File::create(path).unwrap();
        f.write_all(&0x0803u32.to_be_bytes()).unwrap();
        f.write_all(&(n as u32).to_be_bytes()).unwrap();
        f.write_all(&(side as u32).to_be_bytes()).unwrap();
        f.write_all(&(side as u32).to_be_bytes()).unwrap();
        let body: Vec<u8> = (0..n * side * side).map(|i| (i % 251) as u8).collect();
        f.write_all(&body).unwrap();
    }

    fn write_idx1(path: &std::path::Path, labels: &[u8]) {
        let mut f = std::fs::File::create(path).unwrap();
        f.write_all(&0x0801u32.to_be_bytes()).unwrap();
        f.write_all(&(labels.len() as u32).to_be_bytes()).unwrap();
        f.write_all(labels).unwrap();
    }

    #[test]
    fn roundtrip_synthetic_idx() {
        let dir = std::env::temp_dir().join("vsa_idx_test");
        std::fs::create_dir_all(&dir).unwrap();
        let ip = dir.join("imgs");
        let lp = dir.join("labels");
        write_idx3(&ip, 3, 4);
        write_idx1(&lp, &[7, 1, 9]);
        let samples =
            load_samples(ip.to_str().unwrap(), lp.to_str().unwrap(), 10).unwrap();
        assert_eq!(samples.len(), 3);
        assert_eq!(samples[0].size, 4);
        assert_eq!(samples[2].label, 9);
        assert_eq!(samples[1].at(0, 0, 0), (16 % 251) as u8);
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("vsa_idx_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("junk");
        std::fs::write(&p, b"not an idx file....").unwrap();
        assert!(load_images(p.to_str().unwrap()).is_err());
        assert!(load_labels(p.to_str().unwrap()).is_err());
    }

    #[test]
    fn corrupt_header_overflow_is_an_error_not_a_panic() {
        // Valid magic, dimensions whose product overflows usize: must
        // return Err (previously: debug overflow panic, or a wrapped
        // size check followed by an out-of-bounds slice in release).
        let dir = std::env::temp_dir().join("vsa_idx_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("overflow");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&0x0803u32.to_be_bytes());
        bytes.extend_from_slice(&u32::MAX.to_be_bytes()); // n
        bytes.extend_from_slice(&u32::MAX.to_be_bytes()); // rows
        bytes.extend_from_slice(&u32::MAX.to_be_bytes()); // cols
        bytes.extend_from_slice(&[0u8; 8]); // tiny body
        std::fs::write(&p, &bytes).unwrap();
        let err = load_images(p.to_str().unwrap()).unwrap_err();
        assert!(err.contains("corrupt header"), "unhelpful error: {err}");
    }

    #[test]
    fn image_label_count_mismatch_is_an_error() {
        // 3 images zipped with 2 labels used to silently truncate.
        let dir = std::env::temp_dir().join("vsa_idx_test4");
        std::fs::create_dir_all(&dir).unwrap();
        let ip = dir.join("imgs");
        let lp = dir.join("labels");
        write_idx3(&ip, 3, 4);
        write_idx1(&lp, &[7, 1]);
        let err = load_samples(ip.to_str().unwrap(), lp.to_str().unwrap(), 10).unwrap_err();
        assert!(err.contains("mismatch"), "unhelpful error: {err}");
        assert!(err.contains("3 images") && err.contains("2 labels"), "{err}");
    }
}

//! Datasets: synthetic MNIST/CIFAR-like generators (bit-identical to the
//! python compile path) plus an IDX loader for real MNIST files.

pub mod idx;
pub mod synth;

/// A labelled u8 image in CHW layout.
#[derive(Debug, Clone)]
pub struct Sample {
    pub image: Vec<u8>,
    pub channels: usize,
    pub size: usize,
    pub label: usize,
}

impl Sample {
    /// Pixel accessor (channel, y, x).
    #[inline]
    pub fn at(&self, c: usize, y: usize, x: usize) -> u8 {
        self.image[(c * self.size + y) * self.size + x]
    }
}

//! Synthetic dataset generator — bit-identical twin of
//! `python/compile/datasets.py`.
//!
//! Every draw order and integer operation matches the python source so the
//! two languages generate the same u8 pixels; integration tests rely on
//! this to compare JAX logits against the rust golden model sample by
//! sample (see DESIGN.md §Substitutions for why the data is synthetic).

use crate::data::Sample;
use crate::util::rng::SplitMix64;

/// Per-class template coefficients — identical table in datasets.py.
const P1: [i64; 10] = [3, 5, 7, 11, 13, 17, 19, 23, 29, 31];
const P2: [i64; 10] = [7, 3, 11, 5, 17, 13, 23, 19, 37, 29];
const P3: [i64; 10] = [0, 9, 4, 13, 6, 15, 2, 11, 8, 17];

/// Deterministic class template pixel in [0, 255].
#[inline]
pub fn template_pixel(cls: usize, ch: usize, x: i64, y: i64) -> i64 {
    let a = (x * P1[cls] + y * P2[cls] + P3[cls] + ch as i64 * 5).rem_euclid(29);
    let b = if (x / 4 + y / 4 + cls as i64 + ch as i64).rem_euclid(3) == 0 {
        64
    } else {
        0
    };
    (a * 7 + b).min(255)
}

/// Generate one (channels, size, size) u8 image for class `cls`.
///
/// Matches `datasets.synth_image(seed, index, cls, channels, size)`.
pub fn image(seed: u64, index: u64, cls: usize, channels: usize, size: usize) -> Sample {
    let state = seed
        .wrapping_mul(1_000_003)
        .wrapping_add(index.wrapping_mul(7919))
        .wrapping_add(cls as u64);
    let mut rng = SplitMix64::new(state);
    let dx = (rng.next_below(7) as i64) - 3;
    let dy = (rng.next_below(7) as i64) - 3;

    let mut img = vec![0u8; channels * size * size];
    let s = size as i64;
    for c in 0..channels {
        for yy in 0..s {
            for xx in 0..s {
                let sx = (xx + dx).rem_euclid(s);
                let sy = (yy + dy).rem_euclid(s);
                let noise = (rng.next_below(64) as i64) - 32;
                let v = (template_pixel(cls, c, sx, sy) + noise).clamp(0, 255);
                img[(c * size + yy as usize) * size + xx as usize] = v as u8;
            }
        }
    }
    Sample {
        image: img,
        channels,
        size,
        label: cls,
    }
}

/// Generate `count` samples with balanced labels `(start + i) % 10`.
pub fn batch(seed: u64, start: u64, count: usize, channels: usize, size: usize) -> Vec<Sample> {
    (0..count)
        .map(|i| {
            let cls = ((start + i as u64) % 10) as usize;
            image(seed, start + i as u64, cls, channels, size)
        })
        .collect()
}

/// (1, 28, 28) MNIST-like samples.
pub fn mnist_like(seed: u64, start: u64, count: usize) -> Vec<Sample> {
    batch(seed, start, count, 1, 28)
}

/// (3, 32, 32) CIFAR-like samples.
pub fn cifar_like(seed: u64, start: u64, count: usize) -> Vec<Sample> {
    batch(seed, start, count, 3, 32)
}

/// (1, 12, 12) tiny samples for the test network.
pub fn tiny_like(seed: u64, start: u64, count: usize) -> Vec<Sample> {
    batch(seed, start, count, 1, 12)
}

/// Samples matching a model preset's input geometry.
pub fn for_model(name: &str, seed: u64, start: u64, count: usize) -> Vec<Sample> {
    match name {
        "mnist" => mnist_like(seed, start, count),
        "cifar10" => cifar_like(seed, start, count),
        "micro" => batch(seed, start, count, 1, 8),
        _ => tiny_like(seed, start, count),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = image(42, 0, 3, 1, 12);
        let b = image(42, 0, 3, 1, 12);
        assert_eq!(a.image, b.image);
    }

    #[test]
    fn labels_balanced() {
        let samples = batch(1, 0, 50, 1, 12);
        let mut counts = [0usize; 10];
        for s in &samples {
            counts[s.label] += 1;
        }
        assert!(counts.iter().all(|&c| c == 5));
    }

    #[test]
    fn distinct_classes_differ() {
        let a = image(7, 0, 0, 1, 16);
        let b = image(7, 0, 1, 1, 16);
        assert_ne!(a.image, b.image);
    }

    /// Cross-language anchor: pixel values must match the python
    /// generator.  Regenerate with:
    /// `python -c "from compile.datasets import synth_image;
    ///  print(synth_image(42, 7, 3, 1, 12)[0, :2, :4])"`
    #[test]
    fn cross_language_anchor() {
        let s = image(42, 7, 3, 1, 12);
        // Values checked against the python implementation in CI (the
        // integration test test_cross_language.py writes a fresh dump);
        // here we pin basic invariants the formula guarantees.
        assert_eq!(s.channels, 1);
        assert_eq!(s.size, 12);
        assert_eq!(s.label, 3);
        assert!(s.image.iter().any(|&p| p > 0));
    }
}

//! Miniature property-based testing harness (proptest substitute).
//!
//! Usage:
//! ```no_run
//! use vsa::testing::{Gen, check};
//! check("add is commutative", 100, |g: &mut Gen| {
//!     let (a, b) = (g.i64_in(-100, 100), g.i64_in(-100, 100));
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! Each case gets a deterministic per-index seed; a failure panics with
//! the case index *and* a ready-to-paste
//! `check_one("<name>", <seed>, <index>, <property>)` line so the failing
//! case reproduces without re-running the whole suite.

pub mod models;

use crate::util::rng::SplitMix64;

/// Base seed [`check`] derives every case seed from.
pub const DEFAULT_SEED: u64 = 0x5EED_0000;

/// Per-case seed derivation shared by [`check`] and [`check_one`].
#[inline]
fn case_seed(seed: u64, index: u64) -> u64 {
    seed ^ index.wrapping_mul(0x9E37_79B9)
}

/// Random input generator handed to property bodies.
pub struct Gen {
    rng: SplitMix64,
}

impl Gen {
    /// Create from a raw seed.
    pub fn new(seed: u64) -> Self {
        Self { rng: SplitMix64::new(seed) }
    }

    /// Uniform u64.
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.next_index(hi - lo + 1)
    }

    /// Uniform i64 in `[lo, hi]` (inclusive).
    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.rng.next_below((hi - lo + 1) as u64) as i64
    }

    /// Uniform i32 in `[lo, hi]` (inclusive).
    pub fn i32_in(&mut self, lo: i32, hi: i32) -> i32 {
        self.i64_in(lo as i64, hi as i64) as i32
    }

    /// Bernoulli(1/2) bool.
    pub fn bool(&mut self) -> bool {
        self.rng.next_below(2) == 1
    }

    /// Random +-1 weight vector.
    pub fn weights(&mut self, n: usize) -> Vec<i8> {
        (0..n).map(|_| if self.bool() { 1 } else { -1 }).collect()
    }

    /// Random 0/1 spike vector with the given firing probability numerator
    /// out of 100.
    pub fn spikes(&mut self, n: usize, pct: u64) -> Vec<u8> {
        (0..n)
            .map(|_| (self.rng.next_below(100) < pct) as u8)
            .collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.next_index(xs.len())]
    }

    /// Pick one element with probability proportional to its weight.
    /// Entries with weight 0 are never chosen; the total weight must be
    /// positive.  Consumes exactly one draw from the stream, like
    /// [`Gen::choose`].
    pub fn choose_weighted<'a, T>(&mut self, weighted: &'a [(T, u64)]) -> &'a T {
        let total: u64 = weighted.iter().map(|(_, w)| *w).sum();
        assert!(total > 0, "choose_weighted needs a positive total weight");
        let mut r = self.rng.next_below(total);
        for (x, w) in weighted {
            if r < *w {
                return x;
            }
            r -= w;
        }
        unreachable!("next_below(total) < total")
    }
}

/// Run `cases` generated cases of a property under [`DEFAULT_SEED`].
/// Panics as soon as one case fails, reporting the failing index and the
/// [`check_one`] call that reproduces it.
pub fn check(name: &str, cases: u64, prop: impl FnMut(&mut Gen)) {
    check_seeded(name, DEFAULT_SEED, cases, prop)
}

/// [`check`] under an explicit base seed (for re-rolling a suite without
/// touching its property body).
pub fn check_seeded(name: &str, seed: u64, cases: u64, mut prop: impl FnMut(&mut Gen)) {
    for i in 0..cases {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen::new(case_seed(seed, i));
            prop(&mut g);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {i}: {msg}\n  \
                 reproduce: check_one(\"{name}\", {seed:#x}, {i}, <property>)"
            );
        }
    }
}

/// Re-run a single case of a property — paste the arguments straight from
/// a [`check`] failure message (for shrinking a failure by hand).
pub fn check_one(name: &str, seed: u64, index: u64, mut prop: impl FnMut(&mut Gen)) {
    let mut g = Gen::new(case_seed(seed, index));
    prop(&mut g);
    println!("property '{name}': case {index} (seed {seed:#x}) passed");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("xor twice is identity", 50, |g| {
            let a = g.u64();
            let b = g.u64();
            assert_eq!(a ^ b ^ b, a);
        });
    }

    #[test]
    #[should_panic(expected = "failed at case")]
    fn failing_property_reports_case() {
        check("always fails eventually", 50, |g| {
            assert!(g.u64() % 7 != 0, "hit a multiple of 7");
        });
    }

    #[test]
    #[should_panic(expected = "reproduce: check_one(\"always fails\", 0x5eed0000, 0,")]
    fn failure_message_is_a_pasteable_repro() {
        check("always fails", 3, |_| panic!("boom"));
    }

    #[test]
    fn check_one_replays_the_reported_case() {
        // Find the first failing index the slow way, then reproduce it
        // with check_one and confirm the generator stream is identical.
        let mut failing = None;
        for i in 0..50u64 {
            let mut g = Gen::new(super::case_seed(DEFAULT_SEED, i));
            if g.u64() % 7 == 0 {
                failing = Some(i);
                break;
            }
        }
        let i = failing.expect("a multiple of 7 appears within 50 cases");
        let result = std::panic::catch_unwind(|| {
            check_one("finds multiples of 7", DEFAULT_SEED, i, |g| {
                assert!(g.u64() % 7 != 0);
            });
        });
        assert!(result.is_err(), "check_one must replay the failing draw");
        // A passing case replays cleanly.
        check_one("passes elsewhere", DEFAULT_SEED, i, |g| {
            let _ = g.u64();
        });
    }

    #[test]
    fn gen_ranges() {
        let mut g = Gen::new(1);
        for _ in 0..1000 {
            let v = g.usize_in(3, 9);
            assert!((3..=9).contains(&v));
            let w = g.i64_in(-5, 5);
            assert!((-5..=5).contains(&w));
        }
    }

    #[test]
    fn choose_weighted_respects_weights() {
        let mut g = Gen::new(42);
        let table = [("never", 0u64), ("rare", 1), ("common", 9)];
        let mut rare = 0usize;
        let mut common = 0usize;
        for _ in 0..2000 {
            match *g.choose_weighted(&table) {
                "never" => panic!("zero-weight entry chosen"),
                "rare" => rare += 1,
                _ => common += 1,
            }
        }
        assert_eq!(rare + common, 2000);
        // 9:1 odds: loose bounds that hold with overwhelming probability.
        assert!(common > rare * 4, "common {common} vs rare {rare}");
        assert!(rare > 50, "rare {rare} should still appear ~200 times");
    }

    #[test]
    fn choose_weighted_all_mass_on_one_entry() {
        let mut g = Gen::new(7);
        for _ in 0..100 {
            assert_eq!(*g.choose_weighted(&[(1u8, 0u64), (2, 5), (3, 0)]), 2);
        }
    }

    #[test]
    #[should_panic(expected = "positive total weight")]
    fn choose_weighted_rejects_zero_total() {
        Gen::new(1).choose_weighted(&[("a", 0u64), ("b", 0)]);
    }
}

//! Miniature property-based testing harness (proptest substitute).
//!
//! Usage (`no_run`: doctest executables lack the libxla rpath):
//! ```no_run
//! use vsa::testing::{Gen, check};
//! check("add is commutative", 100, |g: &mut Gen| {
//!     let (a, b) = (g.i64_in(-100, 100), g.i64_in(-100, 100));
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! Each case gets a deterministic per-index seed; failures report the case
//! index so a run can be reproduced with [`check_one`].

use crate::util::rng::SplitMix64;

/// Random input generator handed to property bodies.
pub struct Gen {
    rng: SplitMix64,
}

impl Gen {
    /// Create from a raw seed.
    pub fn new(seed: u64) -> Self {
        Self { rng: SplitMix64::new(seed) }
    }

    /// Uniform u64.
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.next_index(hi - lo + 1)
    }

    /// Uniform i64 in `[lo, hi]` (inclusive).
    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.rng.next_below((hi - lo + 1) as u64) as i64
    }

    /// Uniform i32 in `[lo, hi]` (inclusive).
    pub fn i32_in(&mut self, lo: i32, hi: i32) -> i32 {
        self.i64_in(lo as i64, hi as i64) as i32
    }

    /// Bernoulli(1/2) bool.
    pub fn bool(&mut self) -> bool {
        self.rng.next_below(2) == 1
    }

    /// Random +-1 weight vector.
    pub fn weights(&mut self, n: usize) -> Vec<i8> {
        (0..n).map(|_| if self.bool() { 1 } else { -1 }).collect()
    }

    /// Random 0/1 spike vector with the given firing probability numerator
    /// out of 100.
    pub fn spikes(&mut self, n: usize, pct: u64) -> Vec<u8> {
        (0..n)
            .map(|_| (self.rng.next_below(100) < pct) as u8)
            .collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.next_index(xs.len())]
    }
}

/// Run `cases` generated cases of a property.  Panics (with the failing
/// case index) as soon as one case fails.
pub fn check(name: &str, cases: u64, mut prop: impl FnMut(&mut Gen)) {
    for i in 0..cases {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen::new(0x5EED_0000 ^ i.wrapping_mul(0x9E37_79B9));
            prop(&mut g);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed at case {i}: {msg}");
        }
    }
}

/// Re-run a single case (for shrinking a failure by hand).
pub fn check_one(case: u64, mut prop: impl FnMut(&mut Gen)) {
    let mut g = Gen::new(0x5EED_0000 ^ case.wrapping_mul(0x9E37_79B9));
    prop(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("xor twice is identity", 50, |g| {
            let a = g.u64();
            let b = g.u64();
            assert_eq!(a ^ b ^ b, a);
        });
    }

    #[test]
    #[should_panic(expected = "failed at case")]
    fn failing_property_reports_case() {
        check("always fails eventually", 50, |g| {
            assert!(g.u64() % 7 != 0, "hit a multiple of 7");
        });
    }

    #[test]
    fn gen_ranges() {
        let mut g = Gen::new(1);
        for _ in 0..1000 {
            let v = g.usize_in(3, 9);
            assert!((3..=9).contains(&v));
            let w = g.i64_in(-5, 5);
            assert!((-5..=5).contains(&w));
        }
    }
}

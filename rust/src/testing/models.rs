//! Shared randomized-model generators for the property suites.
//!
//! `rust/tests/sim_vs_golden.rs` and `rust/tests/chip_batched.rs` both
//! differential-test engines on random networks; the generators live here
//! so every suite draws from the same model distribution (and a failing
//! case from one suite reproduces in another).

use crate::snn::params::{DeployedModel, Kind, Layer};
use crate::testing::Gen;
use crate::util::FIXED_POINT;

/// Build a random small network: enc conv -> [pool] -> conv -> fc ->
/// readout, plus a matching random input image.  Sized for the popcount
/// fast paths (golden engine, `SimMode::Fast`): spatial sizes up to 16,
/// channel counts crossing no word boundary below 33.
pub fn random_model(g: &mut Gen) -> (DeployedModel, Vec<u8>) {
    let in_size = *g.choose(&[8usize, 12, 16]);
    let c1 = *g.choose(&[4usize, 8, 16]);
    let c2 = *g.choose(&[4usize, 8, 33]);
    let t = g.usize_in(1, 6);
    let pool = g.bool();
    let mid = if pool { in_size / 2 } else { in_size };
    let n_fc = g.usize_in(4, 12);

    let mut layers = vec![Layer::Conv {
        kind: Kind::EncConv,
        c_out: c1,
        c_in: 1,
        k: 3,
        w: g.weights(c1 * 9),
        bias: (0..c1).map(|_| g.i32_in(-500, 500) * FIXED_POINT / 4).collect(),
        theta: (0..c1)
            .map(|_| g.i32_in(1, 300) * FIXED_POINT)
            .collect(),
    }];
    if pool {
        layers.push(Layer::MaxPool);
    }
    layers.push(Layer::Conv {
        kind: Kind::Conv,
        c_out: c2,
        c_in: c1,
        k: 3,
        w: g.weights(c2 * c1 * 9),
        bias: (0..c2).map(|_| g.i32_in(-4, 4) * FIXED_POINT).collect(),
        theta: (0..c2).map(|_| g.i32_in(1, 12) * FIXED_POINT).collect(),
    });
    layers.push(Layer::Fc {
        n_out: n_fc,
        n_in: c2 * mid * mid,
        w: g.weights(n_fc * c2 * mid * mid),
        bias: (0..n_fc).map(|_| g.i32_in(-2, 2) * FIXED_POINT).collect(),
        theta: (0..n_fc).map(|_| g.i32_in(1, 6) * FIXED_POINT).collect(),
    });
    layers.push(Layer::Readout {
        n_out: 10,
        n_in: n_fc,
        w: g.weights(10 * n_fc),
    });

    let model = DeployedModel {
        name: "prop".into(),
        num_steps: t,
        in_channels: 1,
        in_size,
        layers,
    };
    let image: Vec<u8> = (0..in_size * in_size).map(|_| g.i32_in(0, 255) as u8).collect();
    (model, image)
}

/// [`random_model`] shrunk for the gate-level `SimMode::Exact` datapath
/// (every PE simulated in software): tiny spatial sizes and channel
/// counts so a 100-case differential suite stays fast in debug builds.
/// Odd spatial sizes are weighted in so pooled layers exercise the
/// dropped-trailing-row/col path.
pub fn random_model_tiny(g: &mut Gen) -> (DeployedModel, Vec<u8>) {
    let in_size = *g.choose_weighted(&[(6usize, 2u64), (7, 1), (8, 2), (9, 1)]);
    let c1 = g.usize_in(1, 4);
    let c2 = g.usize_in(1, 5);
    let t = g.usize_in(1, 3);
    let pool = g.bool();
    let mid = if pool { in_size / 2 } else { in_size };
    let n_fc = g.usize_in(2, 6);

    let mut layers = vec![Layer::Conv {
        kind: Kind::EncConv,
        c_out: c1,
        c_in: 1,
        k: 3,
        w: g.weights(c1 * 9),
        bias: (0..c1).map(|_| g.i32_in(-200, 200) * FIXED_POINT / 4).collect(),
        theta: (0..c1).map(|_| g.i32_in(1, 200) * FIXED_POINT).collect(),
    }];
    if pool {
        layers.push(Layer::MaxPool);
    }
    layers.push(Layer::Conv {
        kind: Kind::Conv,
        c_out: c2,
        c_in: c1,
        k: 3,
        w: g.weights(c2 * c1 * 9),
        bias: (0..c2).map(|_| g.i32_in(-3, 3) * FIXED_POINT).collect(),
        theta: (0..c2).map(|_| g.i32_in(1, 8) * FIXED_POINT).collect(),
    });
    layers.push(Layer::Fc {
        n_out: n_fc,
        n_in: c2 * mid * mid,
        w: g.weights(n_fc * c2 * mid * mid),
        bias: (0..n_fc).map(|_| g.i32_in(-2, 2) * FIXED_POINT).collect(),
        theta: (0..n_fc).map(|_| g.i32_in(1, 4) * FIXED_POINT).collect(),
    });
    layers.push(Layer::Readout {
        n_out: 10,
        n_in: n_fc,
        w: g.weights(10 * n_fc),
    });

    let model = DeployedModel {
        name: "prop-tiny".into(),
        num_steps: t,
        in_channels: 1,
        in_size,
        layers,
    };
    let image: Vec<u8> = (0..in_size * in_size).map(|_| g.i32_in(0, 255) as u8).collect();
    (model, image)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_are_deterministic_and_well_formed() {
        for f in [random_model, random_model_tiny] {
            let (a, img_a) = f(&mut Gen::new(123));
            let (b, img_b) = f(&mut Gen::new(123));
            assert_eq!(img_a, img_b);
            assert_eq!(a.num_steps, b.num_steps);
            assert_eq!(a.layers.len(), b.layers.len());
            assert_eq!(img_a.len(), a.in_size * a.in_size);
            assert!(matches!(a.layers.last(), Some(Layer::Readout { .. })));
        }
    }
}

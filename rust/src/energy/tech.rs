//! First-order technology scaling — the normalization rules of the paper's
//! Table III footnotes ("normalized area efficiency scaled to 40nm",
//! "normalized power efficiency scaled to 40nm and 0.9V").

/// Scale a logic area (gate count is node-independent, but *density*
/// comparisons across nodes scale with feature size squared).  Table III
/// normalizes *area efficiency* (GOPS/KGE): gate count is already a
/// node-neutral metric, so the paper's footnote-1 normalization scales the
/// GOPS side by the frequency capability ratio of the nodes.  We follow
/// the common convention: linear frequency scaling with 1/node.
pub fn area_eff_to_40nm(gops_per_kge: f64, node_nm: f64) -> f64 {
    gops_per_kge * (node_nm / 40.0)
}

/// Normalize a power-efficiency figure (TOPS/W) measured at `node_nm`,
/// `voltage` to the paper's 40 nm / 0.9 V reference: dynamic power scales
/// with C V^2 (capacitance ~ node), so efficiency scales with
/// `(node/40) * (V/0.9)^2`.
pub fn power_eff_to_40nm_0v9(tops_per_w: f64, node_nm: f64, voltage: f64) -> f64 {
    tops_per_w * (node_nm / 40.0) * (voltage / 0.9).powi(2)
}

/// Dynamic-power scale factor from a reference node/voltage to a target
/// node/voltage (P ∝ C V^2 f; per-op energy E ∝ C V^2 ∝ node * V^2).
pub fn energy_scale(from_nm: f64, from_v: f64, to_nm: f64, to_v: f64) -> f64 {
    (to_nm / from_nm) * (to_v / from_v).powi(2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_at_reference() {
        assert_eq!(area_eff_to_40nm(20.0, 40.0), 20.0);
        assert_eq!(power_eff_to_40nm_0v9(25.9, 40.0, 0.9), 25.9);
        assert_eq!(energy_scale(40.0, 0.9, 40.0, 0.9), 1.0);
    }

    /// Table III footnote 1: BW-SNN's 0.286 GOPS/KGE at 90 nm normalizes
    /// to ~0.644 at 40 nm (paper prints 0.644).
    #[test]
    fn bwsnn_area_normalization_matches_paper() {
        let norm = area_eff_to_40nm(0.286, 90.0);
        assert!((norm - 0.6435).abs() < 0.01, "got {norm}");
    }

    /// Table III footnote 2: BW-SNN's 103.14 TOPS/W at 90 nm / 0.6 V is
    /// printed unchanged in the normalized row (103.14): 90/40*(0.6/0.9)^2
    /// = 2.25 * 0.444 = 1.0.
    #[test]
    fn bwsnn_power_normalization_matches_paper() {
        let norm = power_eff_to_40nm_0v9(103.14, 90.0, 0.6);
        assert!((norm - 103.14).abs() < 0.5, "got {norm}");
    }

    #[test]
    fn smaller_node_cheaper_energy() {
        assert!(energy_scale(40.0, 0.9, 28.0, 0.9) < 1.0);
        assert!(energy_scale(40.0, 0.9, 90.0, 0.9) > 1.0);
    }
}

//! Gate-count (KGE) area model, calibrated to the paper's 114.98 KGE
//! logic area at the 2304-PE design point.
//!
//! Component formulas are parametric in the hardware config so
//! reconfigured chips (different PE counts, different SRAM splits) get a
//! consistent estimate; the single `CONTROL_KGE` residual absorbs control
//! logic, muxing and the post-processing unit and is the one calibrated
//! constant (see the calibration test).

use crate::config::HwConfig;

/// Gate equivalents per PE: AND gate + sign select + its share of the
/// stage-1 diagonal adder chain (a 2-input adder amortized over the PEs
/// feeding it).  Calibrated so the design point hits 114.98 KGE.
pub const PE_GE: f64 = 31.4;

/// GE per bit of a 2-input adder (standard-cell full adder ~ 3 GE/bit
/// including carry).
pub const ADDER_GE_PER_BIT: f64 = 3.0;

/// Partial-sum width through the accumulator tree (bits).
pub const PSUM_BITS: f64 = 16.0;

/// GE per IF-neuron lane (adder + comparator + reset mux, 24-bit).
pub const IF_LANE_GE: f64 = 360.0;

/// Calibrated control / post-processing / misc residual (KGE).
pub const CONTROL_KGE: f64 = 15.0;

/// Area breakdown in KGE.
#[derive(Debug, Clone)]
pub struct AreaBreakdown {
    pub pes_kge: f64,
    pub accumulator_kge: f64,
    pub if_unit_kge: f64,
    pub control_kge: f64,
}

impl AreaBreakdown {
    /// Total logic KGE.
    pub fn total(&self) -> f64 {
        self.pes_kge + self.accumulator_kge + self.if_unit_kge + self.control_kge
    }
}

/// Estimate the logic area of a configuration.
pub fn logic_area(hw: &HwConfig) -> AreaBreakdown {
    let pes = hw.total_pes() as f64;
    let diag = (hw.rows_per_array + hw.cols_per_array - 1) as f64;

    // Stage-1 diagonal adders are folded into PE_GE (they scale with the
    // PE count).  Stage-2/3 tree: (blocks - 1) two-input adders per
    // diagonal lane, plus the group-accumulation adder per lane.
    let tree_adders =
        ((hw.pe_blocks - 1) as f64 + 1.0) * diag * ADDER_GE_PER_BIT * PSUM_BITS;
    // Bitplane shifters for the encoding mode: one barrel shifter per block.
    let shifters = hw.pe_blocks as f64 * 0.5 * PSUM_BITS * ADDER_GE_PER_BIT;

    // IF unit: one lane per row of the output column vector.
    let if_lanes = (hw.rows_per_array * hw.pe_blocks / 8).max(8) as f64;

    AreaBreakdown {
        pes_kge: pes * PE_GE / 1000.0,
        accumulator_kge: (tree_adders + shifters) / 1000.0,
        if_unit_kge: if_lanes * IF_LANE_GE / 1000.0,
        control_kge: CONTROL_KGE,
    }
}

/// Area efficiency in GOPS/KGE (Table III row "Area eff.").
pub fn area_efficiency(hw: &HwConfig) -> f64 {
    hw.peak_gops() / logic_area(hw).total()
}

/// First-order SRAM macro cost in gate equivalents per bit.  A 6T bitcell
/// is ~1.5 GE of raw transistors (GE = 4-transistor NAND2); compiled SRAM
/// macros are roughly twice as dense as standard-cell logic, so ~0.75
/// GE/bit is the conventional first-order figure.
pub const SRAM_GE_PER_BIT: f64 = 0.75;

/// Total silicon-area proxy in KGE: logic plus SRAM macros.  Table III
/// reports the two separately (KGE and KB); the design-space exploration
/// needs a single area objective so SRAM-capacity knobs trade against PE
/// count on the same axis.  At the design point the SRAMs dominate
/// (~1415 KGE-equivalent vs 115 KGE of logic), as they do on the die.
pub fn total_area_kge(hw: &HwConfig) -> f64 {
    logic_area(hw).total() + hw.total_sram_kb() * 1024.0 * 8.0 * SRAM_GE_PER_BIT / 1000.0
}

// ---------------------------------------------------------------------------
// IF-BN ablation (paper §II-B): hardware cost of explicit BatchNorm vs the
// folded IF-BN formulation.
// ---------------------------------------------------------------------------

/// GE of an explicit per-lane BatchNorm unit: a fixed-point multiplier
/// (gamma/sigma), an adder (beta/mu) and normalization muxing.  A 16x16
/// array multiplier is ~16^2 full-adder cells (~3 GE each) plus reduction.
pub const BN_EXPLICIT_LANE_GE: f64 = 16.0 * 16.0 * 3.0 + 2.0 * ADDER_GE_PER_BIT * PSUM_BITS;

/// GE of the folded IF-BN per lane: one extra subtractor for the
/// pre-computed bias (the threshold comparison already exists in the IF
/// neuron) — paper Eq. (4).
pub const BN_FOLDED_LANE_GE: f64 = ADDER_GE_PER_BIT * PSUM_BITS;

/// Extra logic area (KGE) an *explicit* BN implementation would add to the
/// neuron unit, vs the folded IF-BN the chip uses — the §II-B claim
/// ("BN suffers from complex computation and high hardware cost")
/// quantified.  Returns (explicit_kge, folded_kge).
pub fn bn_overhead(hw: &HwConfig) -> (f64, f64) {
    let lanes = (hw.rows_per_array * hw.pe_blocks / 8).max(8) as f64;
    (
        lanes * BN_EXPLICIT_LANE_GE / 1000.0,
        lanes * BN_FOLDED_LANE_GE / 1000.0,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Calibration: the default configuration must reproduce the paper's
    /// logic area (114.98 KGE) and area efficiency (20.038 GOPS/KGE)
    /// within 2%.
    #[test]
    fn design_point_matches_table3() {
        let hw = HwConfig::default();
        let area = logic_area(&hw);
        let total = area.total();
        assert!(
            (total - 114.98).abs() / 114.98 < 0.02,
            "logic area {total} KGE vs paper 114.98"
        );
        let eff = area_efficiency(&hw);
        assert!(
            (eff - 20.038).abs() / 20.038 < 0.03,
            "area efficiency {eff} vs paper 20.038"
        );
    }

    #[test]
    fn pes_dominate() {
        let area = logic_area(&HwConfig::default());
        assert!(area.pes_kge > area.accumulator_kge);
        assert!(area.pes_kge > area.if_unit_kge + area.control_kge);
    }

    #[test]
    fn if_bn_folding_saves_area() {
        // §II-B: folded IF-BN must be far cheaper than explicit BN.
        let (explicit, folded) = bn_overhead(&HwConfig::default());
        assert!(explicit > 10.0 * folded, "explicit {explicit} vs folded {folded}");
        // and the explicit version would be a visible fraction of the chip
        let total = logic_area(&HwConfig::default()).total();
        assert!(explicit / total > 0.1);
    }

    #[test]
    fn total_area_charges_sram() {
        let hw = HwConfig::default();
        let logic = logic_area(&hw).total();
        let total = total_area_kge(&hw);
        assert!(total > logic);
        // 230.3125 KB * 8192 bit/KB * 0.75 GE/bit = ~1415 KGE of SRAM
        assert!((total - logic - 1415.04).abs() < 1.0, "got {}", total - logic);
        // shrinking the weight SRAM must shrink the area objective
        let small = HwConfig { weight_sram_kb: 48.0, ..HwConfig::default() };
        assert!(total_area_kge(&small) < total);
    }

    #[test]
    fn scales_with_pe_count() {
        let half = HwConfig { pe_blocks: 16, ..HwConfig::default() };
        let full = logic_area(&HwConfig::default()).total();
        let small = logic_area(&half).total();
        assert!(small < full);
        assert!(small > full * 0.4); // control residual does not scale
    }
}

//! Area / power / energy model + technology normalization (Table III).
//!
//! The paper reports synthesis results (TSMC 40 nm, Design Compiler).  We
//! have no synthesis flow in this environment, so the model is analytical
//! (DESIGN.md §Substitutions): gate counts from component formulas with
//! one calibrated control/misc residual, and per-event energies calibrated
//! once so the CIFAR-10 design point lands on the paper's 88.968 mW.
//! Counts (PE ops, SRAM accesses, DRAM bytes) come from the cycle-accurate
//! simulator; only the per-event constants are calibrated.

pub mod area;
pub mod power;
pub mod report;
pub mod tech;

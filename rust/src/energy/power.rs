//! Event-based power model, calibrated at the CIFAR-10 design point
//! (paper Table III: 88.968 mW core power at 500 MHz, 40 nm, 0.9 V).
//!
//! `core_power_mw` charges per-event energies against the event counts the
//! cycle-accurate simulator produced.  The two calibrated constants are
//! `E_PE_PJ` and `LEAKAGE_MW` (see the calibration test in
//! `rust/tests/sim_vs_golden.rs` and `benches/bench_table3_perf.rs`);
//! SRAM energies use standard 40 nm per-access figures.

use crate::arch::chip::{LayerReport, RunReport};
use crate::config::HwConfig;
use crate::energy::tech;

/// Energy per PE operation (AND + add share), pJ at 40 nm / 0.9 V.
/// Calibrated so the CIFAR-10 workload lands on the paper's 88.968 mW.
pub const E_PE_PJ: f64 = 0.06612;
/// Energy per spike-SRAM column read (8-bit word), pJ.
pub const E_SPIKE_READ_PJ: f64 = 0.8;
/// Energy per weight-SRAM fetch (32-channel tap bundle), pJ.
pub const E_WEIGHT_READ_PJ: f64 = 6.0;
/// Energy per membrane read-modify-write (2 x 16-bit access), pJ.
pub const E_MEMBRANE_RMW_PJ: f64 = 2.4;
/// Energy per temp-SRAM spike write (byte), pJ.
pub const E_TEMP_WRITE_PJ: f64 = 0.8;
/// Energy per boundary-SRAM operation, pJ.
pub const E_BOUNDARY_PJ: f64 = 1.2;
/// Static leakage at the design point, mW.
pub const LEAKAGE_MW: f64 = 4.0;

/// Core power (mW) for a simulated run at the configured clock.
///
/// Scales with technology via [`tech::energy_scale`] when the config is
/// not at the 40 nm / 0.9 V reference.
pub fn core_power_mw(hw: &HwConfig, report: &RunReport) -> f64 {
    let runtime_s = report.cycles as f64 / (hw.freq_mhz * 1e6);
    if runtime_s == 0.0 {
        return LEAKAGE_MW;
    }
    let scale = tech::energy_scale(40.0, 0.9, hw.tech_nm, hw.voltage);
    let pj = report.pe_ops as f64 * E_PE_PJ
        + report.sram.spike_reads as f64 * E_SPIKE_READ_PJ
        + report.sram.weight_reads as f64 * E_WEIGHT_READ_PJ
        + report.sram.membrane_rmw as f64 * E_MEMBRANE_RMW_PJ
        + report.sram.temp_writes as f64 * E_TEMP_WRITE_PJ
        + report.sram.boundary_ops as f64 * E_BOUNDARY_PJ;
    LEAKAGE_MW + pj * scale * 1e-12 / runtime_s * 1e3
}

/// Dynamic core energy attributed to one layer, pJ (PR8: feeds the
/// per-layer energy column of the simulate utilization report).  The
/// same per-event charges as [`core_power_mw`] against the layer's own
/// counters, so summing over `report.layers` recovers the run's total
/// dynamic energy exactly (leakage is a whole-run cost and is excluded
/// here).
pub fn layer_energy_pj(hw: &HwConfig, l: &LayerReport) -> f64 {
    let scale = tech::energy_scale(40.0, 0.9, hw.tech_nm, hw.voltage);
    let pj = l.pe_ops as f64 * E_PE_PJ
        + l.sram.spike_reads as f64 * E_SPIKE_READ_PJ
        + l.sram.weight_reads as f64 * E_WEIGHT_READ_PJ
        + l.sram.membrane_rmw as f64 * E_MEMBRANE_RMW_PJ
        + l.sram.temp_writes as f64 * E_TEMP_WRITE_PJ
        + l.sram.boundary_ops as f64 * E_BOUNDARY_PJ;
    pj * scale
}

/// DRAM energy for a run, mJ (off-chip; not part of core power, reported
/// separately like the paper's DRAM-access discussion).
pub fn dram_energy_mj(hw: &HwConfig, report: &RunReport) -> f64 {
    report.dram.total() as f64 * hw.dram_pj_per_byte * 1e-9
}

/// Power efficiency in TOPS/W at *peak* throughput (Table III convention:
/// peak GOPS / core power).
pub fn power_efficiency_tops_w(hw: &HwConfig, core_mw: f64) -> f64 {
    (hw.peak_gops() / 1000.0) / (core_mw / 1000.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{Chip, SimMode};
    use crate::config::HwConfig;
    use crate::snn::params::{DeployedModel, Kind, Layer};

    fn small_model() -> DeployedModel {
        DeployedModel {
            name: "p".into(),
            num_steps: 4,
            in_channels: 1,
            in_size: 8,
            layers: vec![
                Layer::Conv {
                    kind: Kind::EncConv,
                    c_out: 8,
                    c_in: 1,
                    k: 3,
                    w: vec![1; 72],
                    bias: vec![0; 8],
                    theta: vec![256 * 50; 8],
                },
                Layer::Readout { n_out: 10, n_in: 8 * 64, w: vec![1; 5120] },
            ],
        }
    }

    #[test]
    fn power_positive_and_scales_with_voltage() {
        let hw = HwConfig::default();
        let report = Chip::new(hw.clone(), SimMode::Fast).run(&small_model(), &[128; 64]);
        let p = core_power_mw(&hw, &report);
        assert!(p > LEAKAGE_MW);

        let hw_lv = HwConfig { voltage: 0.6, ..hw.clone() };
        let report_lv = Chip::new(hw_lv.clone(), SimMode::Fast).run(&small_model(), &[128; 64]);
        assert!(core_power_mw(&hw_lv, &report_lv) < p);
    }

    #[test]
    fn efficiency_from_peak() {
        let hw = HwConfig::default();
        // paper: 2304 GOPS / 88.968 mW = 25.897 TOPS/W
        let eff = power_efficiency_tops_w(&hw, 88.968);
        assert!((eff - 25.9).abs() < 0.05, "got {eff}");
    }

    /// Per-layer dynamic energy sums back to the run total implied by
    /// `core_power_mw` minus leakage (same charges, different slicing).
    #[test]
    fn layer_energy_sums_to_dynamic_total() {
        let hw = HwConfig::default();
        let report = Chip::new(hw.clone(), SimMode::Fast).run(&small_model(), &[128; 64]);
        let per_layer: f64 = report.layers.iter().map(|l| layer_energy_pj(&hw, l)).sum();
        let runtime_s = report.cycles as f64 / (hw.freq_mhz * 1e6);
        let dynamic_mw = core_power_mw(&hw, &report) - LEAKAGE_MW;
        let total_pj = dynamic_mw * 1e-3 * runtime_s * 1e12;
        assert!(per_layer > 0.0);
        assert!(
            (per_layer - total_pj).abs() <= 1e-6 * total_pj.max(1.0),
            "per-layer {per_layer} pJ vs run {total_pj} pJ"
        );
    }

    #[test]
    fn dram_energy_counts_bytes() {
        let hw = HwConfig::default();
        let report = Chip::new(hw.clone(), SimMode::Fast).run(&small_model(), &[128; 64]);
        assert!(dram_energy_mj(&hw, &report) > 0.0);
    }
}

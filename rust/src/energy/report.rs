//! Table III row construction and rendering.

use crate::arch::chip::RunReport;
use crate::config::HwConfig;
use crate::energy::{area, power, tech};

/// One column of Table III (a design under comparison).
#[derive(Debug, Clone)]
pub struct DesignRow {
    pub name: String,
    pub tech_nm: f64,
    pub voltage: Option<f64>,
    pub freq_mhz: Option<f64>,
    pub reconfigurable: String,
    pub precision: String,
    pub pe_number: usize,
    pub sram_kb: f64,
    pub peak_gops: f64,
    pub area_kge: Option<f64>,
    pub area_eff: Option<f64>,
    pub area_eff_norm: Option<f64>,
    pub core_power_mw: Option<f64>,
    pub power_eff_tops_w: Option<f64>,
    pub power_eff_norm: Option<f64>,
}

/// Build the "This work" column from a simulated run.
pub fn this_work(hw: &HwConfig, report: &RunReport) -> DesignRow {
    design_row("This work", hw, power::core_power_mw(hw, report))
}

/// Build a design column for any configuration from its core power —
/// shared by Table III ("This work") and the DSE Pareto report, where the
/// power comes from an analytic [`crate::arch::Chip::analyze`] evaluation.
pub fn design_row(name: &str, hw: &HwConfig, core_mw: f64) -> DesignRow {
    let area_kge = area::logic_area(hw).total();
    let eff = power::power_efficiency_tops_w(hw, core_mw);
    DesignRow {
        name: name.into(),
        tech_nm: hw.tech_nm,
        voltage: Some(hw.voltage),
        freq_mhz: Some(hw.freq_mhz),
        reconfigurable: "Yes".into(),
        precision: "binary".into(),
        pe_number: hw.total_pes(),
        sram_kb: hw.total_sram_kb(),
        peak_gops: hw.peak_gops(),
        area_kge: Some(area_kge),
        area_eff: Some(hw.peak_gops() / area_kge),
        area_eff_norm: Some(tech::area_eff_to_40nm(hw.peak_gops() / area_kge, hw.tech_nm)),
        core_power_mw: Some(core_mw),
        power_eff_tops_w: Some(eff),
        power_eff_norm: Some(tech::power_eff_to_40nm_0v9(eff, hw.tech_nm, hw.voltage)),
    }
}

fn fmt_opt(v: Option<f64>, digits: usize) -> String {
    v.map(|x| format!("{x:.*}", digits)).unwrap_or_else(|| "-".into())
}

/// Render rows as the paper's Table III layout (designs as columns).
pub fn render_table3(rows: &[DesignRow]) -> String {
    let mut out = String::new();
    let header: Vec<String> = rows.iter().map(|r| r.name.clone()).collect();
    let lines: Vec<(&str, Box<dyn Fn(&DesignRow) -> String>)> = vec![
        ("Technology (nm)", Box::new(|r: &DesignRow| format!("{:.0}", r.tech_nm))),
        ("Voltage (V)", Box::new(|r| fmt_opt(r.voltage, 1))),
        ("Frequency (MHz)", Box::new(|r| fmt_opt(r.freq_mhz, 0))),
        ("Reconfigurable", Box::new(|r| r.reconfigurable.clone())),
        ("Precision", Box::new(|r| r.precision.clone())),
        ("PE number", Box::new(|r| format!("{}", r.pe_number))),
        ("SRAM (KB)", Box::new(|r| format!("{:.4}", r.sram_kb))),
        ("Peak Throughput (GOPS)", Box::new(|r| format!("{:.1}", r.peak_gops))),
        ("Area (KGE, logic)", Box::new(|r| fmt_opt(r.area_kge, 2))),
        ("Area eff. (GOPS/KGE)", Box::new(|r| fmt_opt(r.area_eff, 3))),
        ("Area eff. (norm. 40nm)", Box::new(|r| fmt_opt(r.area_eff_norm, 3))),
        ("Core power (mW)", Box::new(|r| fmt_opt(r.core_power_mw, 3))),
        ("Power eff. (TOPS/W)", Box::new(|r| fmt_opt(r.power_eff_tops_w, 2))),
        ("Power eff. (norm.)", Box::new(|r| fmt_opt(r.power_eff_norm, 2))),
    ];

    out.push_str(&format!("{:<26}", ""));
    for h in &header {
        out.push_str(&format!("{h:>18}"));
    }
    out.push('\n');
    for (label, f) in &lines {
        out.push_str(&format!("{label:<26}"));
        for r in rows {
            out.push_str(&format!("{:>18}", f(r)));
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{Chip, SimMode};
    use crate::snn::params::{DeployedModel, Kind, Layer};

    fn tiny() -> DeployedModel {
        DeployedModel {
            name: "t".into(),
            num_steps: 2,
            in_channels: 1,
            in_size: 8,
            layers: vec![
                Layer::Conv {
                    kind: Kind::EncConv,
                    c_out: 4,
                    c_in: 1,
                    k: 3,
                    w: vec![1; 36],
                    bias: vec![0; 4],
                    theta: vec![256; 4],
                },
                Layer::Readout { n_out: 10, n_in: 256, w: vec![-1; 2560] },
            ],
        }
    }

    #[test]
    fn this_work_row_sane() {
        let hw = HwConfig::default();
        let r = Chip::new(hw.clone(), SimMode::Fast).run(&tiny(), &[255; 64]);
        let row = this_work(&hw, &r);
        assert_eq!(row.pe_number, 2304);
        assert!((row.peak_gops - 2304.0).abs() < 1e-9);
        assert!(row.core_power_mw.unwrap() > 0.0);
        // at the reference node the normalized figures equal the raw ones
        assert!((row.area_eff.unwrap() - row.area_eff_norm.unwrap()).abs() < 1e-9);
    }

    #[test]
    fn render_contains_all_rows() {
        let hw = HwConfig::default();
        let r = Chip::new(hw.clone(), SimMode::Fast).run(&tiny(), &[255; 64]);
        let table = render_table3(&[this_work(&hw, &r)]);
        for label in ["Technology", "PE number", "Power eff."] {
            assert!(table.contains(label), "missing {label}");
        }
    }
}

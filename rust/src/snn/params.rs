//! VSAW weight file reader — the rust side of
//! `python/compile/params_io.py` (same format doc there).

use std::fmt;

/// Layer kind codes in the VSAW format.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    EncConv,
    Conv,
    MaxPool,
    Fc,
    Readout,
}

/// One deployed layer's parameters.
#[derive(Debug, Clone)]
pub enum Layer {
    /// Conv layer (encoding or spiking): weights (c_out, c_in, k, k) as
    /// +-1 i8, quantized IF-BN bias/theta per output channel.
    Conv {
        kind: Kind,
        c_out: usize,
        c_in: usize,
        k: usize,
        /// Row-major (o, i, kh, kw), values in {-1, +1}.
        w: Vec<i8>,
        bias: Vec<i32>,
        theta: Vec<i32>,
    },
    MaxPool,
    /// Spiking fully-connected layer.
    Fc {
        n_out: usize,
        n_in: usize,
        w: Vec<i8>,
        bias: Vec<i32>,
        theta: Vec<i32>,
    },
    /// Final non-firing accumulation layer.
    Readout { n_out: usize, n_in: usize, w: Vec<i8> },
}

/// A deployed model read from a VSAW file.
#[derive(Debug, Clone)]
pub struct DeployedModel {
    pub name: String,
    pub num_steps: usize,
    pub in_channels: usize,
    pub in_size: usize,
    pub layers: Vec<Layer>,
}

/// VSAW parse error.
#[derive(Debug)]
pub struct ParseError(pub String);

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "VSAW parse error: {}", self.0)
    }
}
impl std::error::Error for ParseError {}

/// Overflow-safe dimension product with a sanity cap (found by the
/// byte-flip fuzz test: corrupted u32 dims overflowed the multiply).
fn checked_size(dims: &[usize]) -> Result<usize, ParseError> {
    const MAX_TENSOR_ELEMS: usize = 1 << 30;
    let mut n: usize = 1;
    for &d in dims {
        n = n
            .checked_mul(d)
            .filter(|&v| v <= MAX_TENSOR_ELEMS)
            .ok_or_else(|| ParseError(format!("implausible tensor dims {dims:?}")))?;
    }
    Ok(n)
}

struct Reader<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Reader<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError(format!("{msg} (at byte {})", self.off))
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8], ParseError> {
        if self.off + n > self.buf.len() {
            return Err(self.err("unexpected EOF"));
        }
        let s = &self.buf[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, ParseError> {
        Ok(self.bytes(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, ParseError> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn i8_vec(&mut self, n: usize) -> Result<Vec<i8>, ParseError> {
        Ok(self.bytes(n)?.iter().map(|&b| b as i8).collect())
    }

    fn i32_vec(&mut self, n: usize) -> Result<Vec<i32>, ParseError> {
        let raw = self.bytes(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

impl DeployedModel {
    /// Parse a VSAW v1 byte buffer.
    pub fn parse(buf: &[u8]) -> Result<Self, ParseError> {
        let mut r = Reader { buf, off: 0 };
        if r.bytes(4)? != b"VSAW" {
            return Err(ParseError("bad magic (want VSAW)".into()));
        }
        let version = r.u32()?;
        if version != 1 {
            return Err(ParseError(format!("unsupported version {version}")));
        }
        let name_len = r.u32()? as usize;
        let name = String::from_utf8(r.bytes(name_len)?.to_vec())
            .map_err(|_| ParseError("bad name utf-8".into()))?;
        let num_steps = r.u32()? as usize;
        let in_channels = r.u32()? as usize;
        let in_size = r.u32()? as usize;
        let num_layers = r.u32()? as usize;
        if num_layers > 4096 {
            return Err(ParseError(format!("implausible layer count {num_layers}")));
        }

        let mut layers = Vec::with_capacity(num_layers);
        for _ in 0..num_layers {
            let code = r.u8()?;
            match code {
                0 | 1 => {
                    let c_out = r.u32()? as usize;
                    let c_in = r.u32()? as usize;
                    let k = r.u32()? as usize;
                    let n = checked_size(&[c_out, c_in, k, k])?;
                    let w = r.i8_vec(n)?;
                    if let Some(bad) = w.iter().find(|&&v| v != 1 && v != -1) {
                        return Err(ParseError(format!("non-binary weight {bad}")));
                    }
                    let bias = r.i32_vec(c_out)?;
                    let theta = r.i32_vec(c_out)?;
                    if theta.iter().any(|&t| t <= 0) {
                        return Err(ParseError("non-positive theta".into()));
                    }
                    layers.push(Layer::Conv {
                        kind: if code == 0 { Kind::EncConv } else { Kind::Conv },
                        c_out,
                        c_in,
                        k,
                        w,
                        bias,
                        theta,
                    });
                }
                2 => layers.push(Layer::MaxPool),
                3 => {
                    let n_out = r.u32()? as usize;
                    let n_in = r.u32()? as usize;
                    let w = r.i8_vec(checked_size(&[n_out, n_in])?)?;
                    let bias = r.i32_vec(n_out)?;
                    let theta = r.i32_vec(n_out)?;
                    layers.push(Layer::Fc { n_out, n_in, w, bias, theta });
                }
                4 => {
                    let n_out = r.u32()? as usize;
                    let n_in = r.u32()? as usize;
                    let w = r.i8_vec(checked_size(&[n_out, n_in])?)?;
                    layers.push(Layer::Readout { n_out, n_in, w });
                }
                c => return Err(ParseError(format!("unknown layer code {c}"))),
            }
        }
        if r.off != buf.len() {
            return Err(ParseError(format!(
                "trailing bytes: {} unread",
                buf.len() - r.off
            )));
        }
        Ok(DeployedModel {
            name,
            num_steps,
            in_channels,
            in_size,
            layers,
        })
    }

    /// Read from a file path.
    pub fn from_file(path: &str) -> Result<Self, ParseError> {
        let buf =
            std::fs::read(path).map_err(|e| ParseError(format!("{path}: {e}")))?;
        Self::parse(&buf)
    }

    /// Serialize to VSAW v1 bytes — the exact inverse of [`parse`] and
    /// the rust twin of `python/compile/params_io.py::save_deployed`.
    /// `vsa train` exports artifacts through this writer.
    ///
    /// [`parse`]: DeployedModel::parse
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend(b"VSAW");
        b.extend(1u32.to_le_bytes());
        b.extend((self.name.len() as u32).to_le_bytes());
        b.extend(self.name.as_bytes());
        b.extend((self.num_steps as u32).to_le_bytes());
        b.extend((self.in_channels as u32).to_le_bytes());
        b.extend((self.in_size as u32).to_le_bytes());
        b.extend((self.layers.len() as u32).to_le_bytes());
        for ly in &self.layers {
            match ly {
                Layer::Conv { kind, c_out, c_in, k, w, bias, theta } => {
                    b.push(if *kind == Kind::EncConv { 0 } else { 1 });
                    b.extend((*c_out as u32).to_le_bytes());
                    b.extend((*c_in as u32).to_le_bytes());
                    b.extend((*k as u32).to_le_bytes());
                    b.extend(w.iter().map(|&v| v as u8));
                    for &v in bias {
                        b.extend(v.to_le_bytes());
                    }
                    for &v in theta {
                        b.extend(v.to_le_bytes());
                    }
                }
                Layer::MaxPool => b.push(2),
                Layer::Fc { n_out, n_in, w, bias, theta } => {
                    b.push(3);
                    b.extend((*n_out as u32).to_le_bytes());
                    b.extend((*n_in as u32).to_le_bytes());
                    b.extend(w.iter().map(|&v| v as u8));
                    for &v in bias {
                        b.extend(v.to_le_bytes());
                    }
                    for &v in theta {
                        b.extend(v.to_le_bytes());
                    }
                }
                Layer::Readout { n_out, n_in, w } => {
                    b.push(4);
                    b.extend((*n_out as u32).to_le_bytes());
                    b.extend((*n_in as u32).to_le_bytes());
                    b.extend(w.iter().map(|&v| v as u8));
                }
            }
        }
        b
    }

    /// Deterministically synthesize deployed parameters for a Table-I
    /// model spec: random ±1 weights and IF-BN bias/theta in ranges that
    /// yield SNN-typical firing rates.  Benches and artifact-free tests
    /// use this to exercise the real model geometries without the python
    /// compile path.
    pub fn synthesize(spec: &crate::config::models::ModelSpec, seed: u64) -> Self {
        use crate::config::models::LayerKind;
        use crate::util::rng::SplitMix64;
        use crate::util::FIXED_POINT;

        let mut rng = SplitMix64::new(seed ^ 0xD1E5_EED5_0B5E_55ED);
        let mut weights = |n: usize| -> Vec<i8> {
            (0..n).map(|_| if rng.next_below(2) == 1 { 1 } else { -1 }).collect()
        };
        let shapes = spec.feature_shapes();
        let mut layers = Vec::with_capacity(spec.layers.len());
        for (li, (ly, &(c_in, fh, fw))) in spec.layers.iter().zip(&shapes).enumerate() {
            // Per-layer parameter stream: mix the layer index so repeated
            // same-width layers (e.g. cifar10's 192-channel block) get
            // independent bias/theta draws.
            let li = li as u64;
            match ly.kind {
                LayerKind::EncConv => {
                    let w = weights(ly.c_out * c_in * ly.ksize * ly.ksize);
                    let mut rng2 =
                        SplitMix64::new(seed ^ li.wrapping_mul(0x9E37_79B9) ^ ly.c_out as u64);
                    layers.push(Layer::Conv {
                        kind: Kind::EncConv,
                        c_out: ly.c_out,
                        c_in,
                        k: ly.ksize,
                        w,
                        bias: (0..ly.c_out)
                            .map(|_| (rng2.next_below(256) as i32 - 128) * FIXED_POINT)
                            .collect(),
                        // pixel-scale thresholds: fires every 1-4 steps on
                        // typical synthetic images
                        theta: (0..ly.c_out)
                            .map(|_| (60 + rng2.next_below(200) as i32) * FIXED_POINT)
                            .collect(),
                    });
                }
                LayerKind::Conv => {
                    let w = weights(ly.c_out * c_in * ly.ksize * ly.ksize);
                    let salt = li.wrapping_mul(0x9E37_79B9) ^ ((ly.c_out as u64) << 8);
                    let mut rng2 = SplitMix64::new(seed ^ salt);
                    layers.push(Layer::Conv {
                        kind: Kind::Conv,
                        c_out: ly.c_out,
                        c_in,
                        k: ly.ksize,
                        w,
                        bias: (0..ly.c_out)
                            .map(|_| (rng2.next_below(9) as i32 - 4) * FIXED_POINT)
                            .collect(),
                        theta: (0..ly.c_out)
                            .map(|_| (1 + rng2.next_below(12) as i32) * FIXED_POINT)
                            .collect(),
                    });
                }
                LayerKind::MaxPool => layers.push(Layer::MaxPool),
                LayerKind::Fc => {
                    let n_in = c_in * fh * fw;
                    let w = weights(ly.c_out * n_in);
                    let salt = li.wrapping_mul(0x9E37_79B9) ^ ((ly.c_out as u64) << 16);
                    let mut rng2 = SplitMix64::new(seed ^ salt);
                    layers.push(Layer::Fc {
                        n_out: ly.c_out,
                        n_in,
                        w,
                        bias: (0..ly.c_out)
                            .map(|_| (rng2.next_below(5) as i32 - 2) * FIXED_POINT)
                            .collect(),
                        theta: (0..ly.c_out)
                            .map(|_| (1 + rng2.next_below(6) as i32) * FIXED_POINT)
                            .collect(),
                    });
                }
                LayerKind::Readout => {
                    let n_in = c_in * fh * fw;
                    layers.push(Layer::Readout {
                        n_out: ly.c_out,
                        n_in,
                        w: weights(ly.c_out * n_in),
                    });
                }
            }
        }
        DeployedModel {
            name: spec.name.clone(),
            num_steps: spec.num_steps,
            in_channels: spec.in_channels,
            in_size: spec.in_size,
            layers,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hand-build a tiny VSAW buffer: one 1->1 conv (k=1) + readout.
    fn tiny_buf() -> Vec<u8> {
        let mut b = Vec::new();
        b.extend(b"VSAW");
        b.extend(1u32.to_le_bytes());
        b.extend(2u32.to_le_bytes());
        b.extend(b"ab");
        b.extend(4u32.to_le_bytes()); // T
        b.extend(1u32.to_le_bytes()); // in_ch
        b.extend(5u32.to_le_bytes()); // in_size
        b.extend(2u32.to_le_bytes()); // layers
        // enc conv 1x1x1
        b.push(0);
        b.extend(1u32.to_le_bytes());
        b.extend(1u32.to_le_bytes());
        b.extend(1u32.to_le_bytes());
        b.push(1i8 as u8); // weight +1
        b.extend(0i32.to_le_bytes()); // bias
        b.extend(256i32.to_le_bytes()); // theta
        // readout 10 x 25
        b.push(4);
        b.extend(10u32.to_le_bytes());
        b.extend(25u32.to_le_bytes());
        b.extend(std::iter::repeat_n(0xFFu8, 250)); // all -1
        b
    }

    #[test]
    fn parse_tiny() {
        let m = DeployedModel::parse(&tiny_buf()).unwrap();
        assert_eq!(m.name, "ab");
        assert_eq!(m.num_steps, 4);
        assert_eq!(m.layers.len(), 2);
        match &m.layers[1] {
            Layer::Readout { n_out, n_in, w } => {
                assert_eq!((*n_out, *n_in), (10, 25));
                assert!(w.iter().all(|&v| v == -1));
            }
            other => panic!("wrong layer {other:?}"),
        }
    }

    #[test]
    fn rejects_corrupt() {
        let mut b = tiny_buf();
        b[0] = b'X';
        assert!(DeployedModel::parse(&b).is_err());

        let mut b = tiny_buf();
        b.push(0); // trailing garbage
        assert!(DeployedModel::parse(&b).is_err());

        let b = tiny_buf();
        assert!(DeployedModel::parse(&b[..b.len() - 10]).is_err());
    }

    #[test]
    fn synthesize_matches_spec_geometry() {
        let spec = crate::config::models::tiny(4);
        let m = DeployedModel::synthesize(&spec, 7);
        assert_eq!(m.num_steps, 4);
        assert_eq!(m.in_size, 12);
        assert_eq!(m.layers.len(), spec.layers.len());
        // deterministic per seed
        let m2 = DeployedModel::synthesize(&spec, 7);
        match (&m.layers[0], &m2.layers[0]) {
            (Layer::Conv { w: a, theta: ta, .. }, Layer::Conv { w: b, theta: tb, .. }) => {
                assert_eq!(a, b);
                assert_eq!(ta, tb);
                assert!(ta.iter().all(|&t| t > 0));
            }
            other => panic!("unexpected layers {other:?}"),
        }
        // fc sees the pooled feature volume: 32 * 3 * 3
        match &m.layers[4] {
            Layer::Fc { n_in, n_out, w, .. } => {
                assert_eq!((*n_out, *n_in), (64, 32 * 3 * 3));
                assert_eq!(w.len(), 64 * 32 * 9);
            }
            other => panic!("unexpected layer {other:?}"),
        }
    }

    #[test]
    fn to_bytes_is_parse_inverse() {
        // writer(reader(buf)) == buf on the hand-built buffer...
        let buf = tiny_buf();
        let m = DeployedModel::parse(&buf).unwrap();
        assert_eq!(m.to_bytes(), buf);
        // ...and reader(writer(model)) == model on a synthesized one.
        let spec = crate::config::models::tiny(4);
        let m = DeployedModel::synthesize(&spec, 3);
        let re = DeployedModel::parse(&m.to_bytes()).unwrap();
        assert_eq!(re.name, m.name);
        assert_eq!(re.num_steps, m.num_steps);
        assert_eq!(re.to_bytes(), m.to_bytes());
    }

    #[test]
    fn rejects_nonbinary_weight() {
        let mut b = tiny_buf();
        // weight byte of the conv layer: magic(4)+ver(4)+len(4)+"ab"(2)
        // +T(4)+ch(4)+size(4)+n(4)+kind(1)+3*dims(12) = byte 43
        b[43] = 3;
        assert!(DeployedModel::parse(&b).is_err());
    }
}

//! Reusable scratch arena for the time-batched inference hot path.
//!
//! The golden [`crate::snn::Network`] is the software twin of the chip's
//! vectorwise dataflow, and like the chip it should not "allocate" working
//! memory per time step: the chip's psum registers, membrane SRAM and
//! spike SRAM banks are fixed buffers reused across layers and steps
//! (§III-A, §III-F).  A `Scratch` is the software analogue — one arena,
//! owned by the *caller* (one per worker thread in the coordinator), grown
//! on first use and reused for every subsequent inference, so
//! `Network::run` performs zero heap allocation in steady state (apart
//! from the small returned logits vector).  The chip simulator's
//! time-batched fast mode ([`crate::arch::Chip`], PR5) holds one arena in
//! its packed-model cache and drives the same kernels through it.
//!
//! Buffers only ever grow; running a large model then a small one keeps
//! the large capacity around, which is exactly what a serving worker
//! wants.

use crate::snn::spikemap::SpikeMap;

/// Caller-owned working memory for [`crate::snn::Network`] inference.
#[derive(Debug, Default)]
pub struct Scratch {
    /// Inter-layer spike-train ping-pong buffers (the software spike
    /// SRAM banks).  Taken out of the arena for the duration of a run.
    pub(crate) train_in: Vec<SpikeMap>,
    pub(crate) train_out: Vec<SpikeMap>,
    /// Full T-step psum planes: `conv_t` output (plane t at
    /// `[t * c_out * h * w ..]`) and fc psums (`[t * n_out + o]`).
    pub(crate) psums: Vec<i32>,
    /// Per-output-channel T-step psum planes (`[t * h * w + j]`) for the
    /// fused conv→IF→pool path — small enough to stay cache-resident.
    pub(crate) chan_psum: Vec<i32>,
    /// Per-step per-pixel spike popcounts (`[t * h * w + j]`).
    pub(crate) ones: Vec<i32>,
    /// Tap-summed popcounts, shared by every output channel.
    pub(crate) ones_sum: Vec<i32>,
    /// The encoding layer's single multi-bit conv result (§III-F).
    pub(crate) enc_psum: Vec<i32>,
    /// Membrane potentials of the layer currently firing.
    pub(crate) v: Vec<i32>,
    /// Packed flat spike words for the fc/readout layers
    /// (`[t * words ..]`).
    pub(crate) flat: Vec<u64>,
}

fn grow_i32(buf: &mut Vec<i32>, n: usize) {
    if buf.len() < n {
        buf.resize(n, 0);
    }
}

impl Scratch {
    /// Fresh empty arena; buffers grow on first use.
    pub fn new() -> Self {
        Self::default()
    }

    /// Ensure the `conv_t` buffers can hold `t` planes of `c_out * hw`
    /// psums plus the per-step popcount planes.
    pub(crate) fn ensure_conv_t(&mut self, t: usize, plane: usize, hw: usize) {
        grow_i32(&mut self.psums, t * plane);
        self.ensure_ones(t, hw);
        grow_i32(&mut self.chan_psum, t * hw);
    }

    /// Ensure the per-step popcount planes for `t` steps of `hw` pixels.
    pub(crate) fn ensure_ones(&mut self, t: usize, hw: usize) {
        grow_i32(&mut self.ones, t * hw);
        grow_i32(&mut self.ones_sum, t * hw);
    }

    /// Ensure the fused conv→IF path buffers (per-channel psums + full
    /// membrane plane).
    pub(crate) fn ensure_fused(&mut self, t: usize, plane: usize, hw: usize) {
        self.ensure_ones(t, hw);
        grow_i32(&mut self.chan_psum, t * hw);
        grow_i32(&mut self.v, plane);
    }

    /// Ensure the encoding-layer psum + membrane buffers.
    pub(crate) fn ensure_enc(&mut self, plane: usize) {
        grow_i32(&mut self.enc_psum, plane);
        grow_i32(&mut self.v, plane);
    }

    /// Ensure the fc-path buffers: `t * words` flat spike words,
    /// `t * n_out` psums, `n_out` membranes.
    pub(crate) fn ensure_fc(&mut self, t: usize, words: usize, n_out: usize) {
        if self.flat.len() < t * words {
            self.flat.resize(t * words, 0);
        }
        grow_i32(&mut self.psums, t * n_out);
        grow_i32(&mut self.v, n_out);
    }

    /// The psum buffer filled by [`crate::snn::conv::PackedConv::conv_t`]
    /// (plane `t` at `[t * c_out * h * w ..][.. c_out * h * w]`) and by
    /// [`crate::snn::conv::PackedFc::matvec_t`] (`[t * n_out + o]`).
    pub fn psums(&self) -> &[i32] {
        &self.psums
    }
}

//! Binary convolution primitives: a popcount-packed fast path and a naive
//! reference.
//!
//! With 0/1 spikes `s` and +-1 weights `w`, the partial sum over a channel
//! group is `sum = popcnt(s) - 2 * popcnt(s & w_neg)` where `w_neg` marks
//! the -1 weights — exactly the chip's AND-gate + sign trick (§III-B:
//! `o = {s & w, s}`) vectorized over 64 channels per word.

use crate::snn::popcount;
use crate::snn::scratch::Scratch;
use crate::snn::spikemap::SpikeMap;
use crate::util::ceil_div;

/// Pre-packed binary conv weights for the popcount fast path.
#[derive(Debug, Clone)]
pub struct PackedConv {
    pub c_out: usize,
    pub c_in: usize,
    pub k: usize,
    /// words per input-channel group = ceil(c_in / 64)
    wpp: usize,
    /// neg-mask words, indexed [(o * k + kh) * k + kw][word]
    neg: Vec<u64>,
}

impl PackedConv {
    /// Pack (o, i, kh, kw) +-1 weights (-1 becomes a set bit, the chip's
    /// sign-bit storage).
    pub fn pack(c_out: usize, c_in: usize, k: usize, w: &[i8]) -> Self {
        assert_eq!(w.len(), c_out * c_in * k * k);
        let wpp = ceil_div(c_in.max(1), 64);
        let mut neg = vec![0u64; c_out * k * k * wpp];
        for o in 0..c_out {
            for i in 0..c_in {
                for kh in 0..k {
                    for kw in 0..k {
                        if w[((o * c_in + i) * k + kh) * k + kw] < 0 {
                            let tap = (o * k + kh) * k + kw;
                            neg[tap * wpp + i / 64] |= 1u64 << (i % 64);
                        }
                    }
                }
            }
        }
        Self { c_out, c_in, k, wpp, neg }
    }

    /// Neg-mask words for (o, kh, kw).
    #[inline]
    pub fn neg_words(&self, o: usize, kh: usize, kw: usize) -> &[u64] {
        let tap = (o * self.k + kh) * self.k + kw;
        &self.neg[tap * self.wpp..(tap + 1) * self.wpp]
    }

    /// 'Same'-padded stride-1 conv of one spike map; output (c_out, H, W)
    /// row-major i32.
    ///
    /// Optimized (EXPERIMENTS.md §Perf): the weight-independent
    /// `popcnt(s)` term is reduced over all K x K taps **once** and shared
    /// by every output channel, and the weight-dependent AND-popcount runs
    /// tap-major over contiguous word slices so the `wpp`-word inner loop
    /// vectorizes.
    pub fn conv(&self, spikes: &SpikeMap) -> Vec<i32> {
        assert_eq!(spikes.channels(), self.c_in, "channel mismatch");
        assert_eq!(spikes.wpp(), self.wpp, "packing mismatch");
        let (h, w) = (spikes.height(), spikes.width());
        let pad = self.k / 2;
        let wpp = self.wpp;
        let words = spikes.raw_words();

        // Per-pixel spike popcount.
        let mut ones = vec![0i32; h * w];
        for (i, one) in ones.iter_mut().enumerate() {
            *one = popcount::popcount(&words[i * wpp..(i + 1) * wpp]) as i32;
        }
        // Tap-summed popcount — identical for every output channel: for
        // each output pixel, the sum of `ones` over its valid taps.
        let mut ones_sum = vec![0i32; h * w];
        for kh in 0..self.k {
            for kw in 0..self.k {
                let dy = kh as isize - pad as isize;
                let dx = kw as isize - pad as isize;
                for y in 0..h {
                    let ny = y as isize + dy;
                    if ny < 0 || ny >= h as isize {
                        continue;
                    }
                    let (x0, x1) = clip_range(dx, w);
                    let src = (ny as usize * w) as isize + dx;
                    for x in x0..x1 {
                        ones_sum[y * w + x] += ones[(src + x as isize) as usize];
                    }
                }
            }
        }

        let mut out = vec![0i32; self.c_out * h * w];
        for o in 0..self.c_out {
            let plane = &mut out[o * h * w..(o + 1) * h * w];
            plane.copy_from_slice(&ones_sum);
            for kh in 0..self.k {
                let dy = kh as isize - pad as isize;
                for kw in 0..self.k {
                    let dx = kw as isize - pad as isize;
                    let negw = self.neg_words(o, kh, kw);
                    if negw.iter().all(|&v| v == 0) {
                        continue; // all +1 weights for this tap
                    }
                    for y in 0..h {
                        let ny = y as isize + dy;
                        if ny < 0 || ny >= h as isize {
                            continue;
                        }
                        let (x0, x1) = clip_range(dx, w);
                        let row_base = ny as usize * w;
                        let row = &mut plane[y * w..(y + 1) * w];
                        if wpp == 1 {
                            let n0 = negw[0];
                            for x in x0..x1 {
                                let p = (row_base as isize + x as isize + dx) as usize;
                                row[x] -= 2 * (words[p] & n0).count_ones() as i32;
                            }
                        } else {
                            for x in x0..x1 {
                                let p =
                                    (row_base as isize + x as isize + dx) as usize * wpp;
                                let and_pop =
                                    popcount::and_popcount(&words[p..p + wpp], negw);
                                row[x] -= 2 * and_pop as i32;
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// Time-batched 'same'-padded stride-1 conv over a whole spike train.
    ///
    /// The chip's vectorwise reuse (§III-B, tick batching §III-A) loads a
    /// weight vector once and applies it to every spatial position of
    /// every time step before moving on.  This is the software mirror:
    /// the loop nest is tap-major *outside* the timestep loop, so each
    /// `(o, kh, kw)` neg-mask is fetched once and applied to all T spike
    /// maps — amortizing weight traffic T× exactly like the chip — and
    /// all working memory comes from the caller's [`Scratch`] arena
    /// (zero allocation in steady state).
    ///
    /// Output: plane for step `t` at
    /// `scratch.psums()[t * c_out * h * w ..][.. c_out * h * w]`,
    /// bit-exact with [`PackedConv::conv`] / [`conv_naive`] per step.
    pub fn conv_t(&self, spikes: &[SpikeMap], scratch: &mut Scratch) {
        let t_steps = spikes.len();
        if t_steps == 0 {
            return;
        }
        let (h, w) = (spikes[0].height(), spikes[0].width());
        for s in spikes {
            assert_eq!(s.channels(), self.c_in, "channel mismatch");
            assert_eq!(s.wpp(), self.wpp, "packing mismatch");
            assert!(s.height() == h && s.width() == w, "geometry mismatch");
        }
        let hw = h * w;
        let plane = self.c_out * hw;
        scratch.ensure_conv_t(t_steps, plane, hw);
        self.tap_ones_t(spikes, &mut scratch.ones, &mut scratch.ones_sum);
        for o in 0..self.c_out {
            self.conv_channel_t(
                spikes,
                o,
                &scratch.ones_sum[..t_steps * hw],
                &mut scratch.chan_psum[..t_steps * hw],
            );
            for t in 0..t_steps {
                scratch.psums[t * plane + o * hw..t * plane + (o + 1) * hw]
                    .copy_from_slice(&scratch.chan_psum[t * hw..(t + 1) * hw]);
            }
        }
    }

    /// Weight-independent popcount planes for a spike train: per-pixel
    /// spike counts (`ones[t*hw + j]`) and their K×K tap sums
    /// (`ones_sum[t*hw + j]`), shared by every output channel.
    pub(crate) fn tap_ones_t(
        &self,
        spikes: &[SpikeMap],
        ones: &mut [i32],
        ones_sum: &mut [i32],
    ) {
        let t_steps = spikes.len();
        if t_steps == 0 {
            return;
        }
        let (h, w) = (spikes[0].height(), spikes[0].width());
        let hw = h * w;
        let wpp = self.wpp;
        let pad = self.k / 2;
        for (t, s) in spikes.iter().enumerate() {
            let words = s.raw_words();
            let ones_t = &mut ones[t * hw..(t + 1) * hw];
            if wpp == 1 {
                for (i, one) in ones_t.iter_mut().enumerate() {
                    *one = words[i].count_ones() as i32;
                }
            } else {
                for (i, one) in ones_t.iter_mut().enumerate() {
                    *one = popcount::popcount(&words[i * wpp..(i + 1) * wpp]) as i32;
                }
            }
        }
        ones_sum[..t_steps * hw].fill(0);
        for kh in 0..self.k {
            for kw in 0..self.k {
                let dy = kh as isize - pad as isize;
                let dx = kw as isize - pad as isize;
                for t in 0..t_steps {
                    let ones_t = &ones[t * hw..(t + 1) * hw];
                    let sum_t = &mut ones_sum[t * hw..(t + 1) * hw];
                    for y in 0..h {
                        let ny = y as isize + dy;
                        if ny < 0 || ny >= h as isize {
                            continue;
                        }
                        let (x0, x1) = clip_range(dx, w);
                        let src = (ny as usize * w) as isize + dx;
                        for x in x0..x1 {
                            sum_t[y * w + x] += ones_t[(src + x as isize) as usize];
                        }
                    }
                }
            }
        }
    }

    /// T-step psums of ONE output channel (`out[t*hw + j]`), given the
    /// precomputed `ones_sum` planes.  Each tap's neg-mask is loaded once
    /// for all T steps; the per-channel output (T·H·W i32s) is small
    /// enough to stay cache-resident, which is what lets the fused
    /// conv→IF→pool path in [`crate::snn::Network`] run the whole layer
    /// out of L1/L2.
    pub(crate) fn conv_channel_t(
        &self,
        spikes: &[SpikeMap],
        o: usize,
        ones_sum: &[i32],
        out: &mut [i32],
    ) {
        let t_steps = spikes.len();
        let (h, w) = (spikes[0].height(), spikes[0].width());
        let hw = h * w;
        let wpp = self.wpp;
        let pad = self.k / 2;
        out[..t_steps * hw].copy_from_slice(&ones_sum[..t_steps * hw]);
        for kh in 0..self.k {
            let dy = kh as isize - pad as isize;
            for kw in 0..self.k {
                let dx = kw as isize - pad as isize;
                let negw = self.neg_words(o, kh, kw);
                if negw.iter().all(|&v| v == 0) {
                    continue; // all +1 weights for this tap
                }
                for (t, s) in spikes.iter().enumerate() {
                    let words = s.raw_words();
                    let plane = &mut out[t * hw..(t + 1) * hw];
                    for y in 0..h {
                        let ny = y as isize + dy;
                        if ny < 0 || ny >= h as isize {
                            continue;
                        }
                        let (x0, x1) = clip_range(dx, w);
                        let row_base = ny as usize * w;
                        let row = &mut plane[y * w..(y + 1) * w];
                        if wpp == 1 {
                            let n0 = negw[0];
                            for x in x0..x1 {
                                let p = (row_base as isize + x as isize + dx) as usize;
                                row[x] -= 2 * (words[p] & n0).count_ones() as i32;
                            }
                        } else {
                            for x in x0..x1 {
                                let p =
                                    (row_base as isize + x as isize + dx) as usize * wpp;
                                let and_pop =
                                    popcount::and_popcount(&words[p..p + wpp], negw);
                                row[x] -= 2 * and_pop as i32;
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Valid output-x range `[x0, x1)` for a tap shifted by `dx` on width `w`.
#[inline]
fn clip_range(dx: isize, w: usize) -> (usize, usize) {
    let x0 = if dx < 0 { (-dx) as usize } else { 0 };
    let x1 = if dx > 0 { w - dx as usize } else { w };
    (x0, x1)
}

/// Naive reference conv over dense spikes — the test oracle for
/// [`PackedConv::conv`].  Input `spikes` dense 0/1 (c_in, h, w) row-major.
pub fn conv_naive(
    spikes: &[u8],
    c_in: usize,
    h: usize,
    w: usize,
    weights: &[i8],
    c_out: usize,
    k: usize,
) -> Vec<i32> {
    let pad = k / 2;
    let mut out = vec![0i32; c_out * h * w];
    for o in 0..c_out {
        for y in 0..h {
            for x in 0..w {
                let mut acc = 0i32;
                for i in 0..c_in {
                    for kh in 0..k {
                        for kw in 0..k {
                            let ny = y as isize + kh as isize - pad as isize;
                            let nx = x as isize + kw as isize - pad as isize;
                            if ny < 0 || ny >= h as isize || nx < 0 || nx >= w as isize {
                                continue;
                            }
                            let s = spikes[(i * h + ny as usize) * w + nx as usize];
                            if s != 0 {
                                acc += weights[((o * c_in + i) * k + kh) * k + kw] as i32;
                            }
                        }
                    }
                }
                out[(o * h + y) * w + x] = acc;
            }
        }
    }
    out
}

/// [`conv_multibit`] into a caller buffer, with the boundary checks
/// hoisted out of the pixel loop (the encoding conv runs once per image,
/// §III-F, but it is the largest single kernel of small-T inference, so
/// the golden hot path uses this variant).  Bit-exact with
/// [`conv_multibit`].
#[allow(clippy::too_many_arguments)]
pub fn conv_multibit_into(
    image: &[u8],
    c_in: usize,
    h: usize,
    w: usize,
    weights: &[i8],
    c_out: usize,
    k: usize,
    out: &mut [i32],
) {
    assert!(out.len() >= c_out * h * w, "psum buffer too small");
    let pad = k / 2;
    out[..c_out * h * w].fill(0);
    for o in 0..c_out {
        let plane = &mut out[o * h * w..(o + 1) * h * w];
        for i in 0..c_in {
            let img = &image[i * h * w..(i + 1) * h * w];
            for kh in 0..k {
                let dy = kh as isize - pad as isize;
                let y0 = (-dy).max(0) as usize;
                let y1 = ((h as isize - dy).min(h as isize)).max(0) as usize;
                for kw in 0..k {
                    let dx = kw as isize - pad as isize;
                    let (x0, x1) = clip_range(dx, w);
                    let wv = weights[((o * c_in + i) * k + kh) * k + kw] as i32;
                    for y in y0..y1 {
                        let src = &img[(y as isize + dy) as usize * w..][..w];
                        let dst = &mut plane[y * w..(y + 1) * w];
                        if wv > 0 {
                            for x in x0..x1 {
                                dst[x] += src[(x as isize + dx) as usize] as i32;
                            }
                        } else {
                            for x in x0..x1 {
                                dst[x] -= src[(x as isize + dx) as usize] as i32;
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Multi-bit (encoding layer) conv: u8 image, +-1 weights, i32 psums.
/// Small `c_in` (1 or 3), so a direct loop is fine.
pub fn conv_multibit(
    image: &[u8],
    c_in: usize,
    h: usize,
    w: usize,
    weights: &[i8],
    c_out: usize,
    k: usize,
) -> Vec<i32> {
    let pad = k / 2;
    let mut out = vec![0i32; c_out * h * w];
    for o in 0..c_out {
        for y in 0..h {
            for x in 0..w {
                let mut acc = 0i32;
                for i in 0..c_in {
                    for kh in 0..k {
                        let ny = y as isize + kh as isize - pad as isize;
                        if ny < 0 || ny >= h as isize {
                            continue;
                        }
                        for kw in 0..k {
                            let nx = x as isize + kw as isize - pad as isize;
                            if nx < 0 || nx >= w as isize {
                                continue;
                            }
                            let p = image[(i * h + ny as usize) * w + nx as usize] as i32;
                            acc += p * weights[((o * c_in + i) * k + kh) * k + kw] as i32;
                        }
                    }
                }
                out[(o * h + y) * w + x] = acc;
            }
        }
    }
    out
}

/// Packed binary matmul for fc layers: psum[o] = popcnt(s) - 2*popcnt(s & neg_o).
#[derive(Debug, Clone)]
pub struct PackedFc {
    pub n_out: usize,
    pub n_in: usize,
    words: usize,
    neg: Vec<u64>,
}

impl PackedFc {
    /// Pack (n_out, n_in) +-1 weights.
    pub fn pack(n_out: usize, n_in: usize, w: &[i8]) -> Self {
        assert_eq!(w.len(), n_out * n_in);
        let words = ceil_div(n_in.max(1), 64);
        let mut neg = vec![0u64; n_out * words];
        for o in 0..n_out {
            for i in 0..n_in {
                if w[o * n_in + i] < 0 {
                    neg[o * words + i / 64] |= 1u64 << (i % 64);
                }
            }
        }
        Self { n_out, n_in, words, neg }
    }

    /// Words per flat spike vector (`ceil(n_in / 64)`).
    #[inline]
    pub fn words(&self) -> usize {
        self.words
    }

    /// psums for one time step of flat spikes (packed words, C-major order).
    pub fn matvec(&self, spike_words: &[u64]) -> Vec<i32> {
        let mut out = vec![0i32; self.n_out];
        self.matvec_into(spike_words, &mut out);
        out
    }

    /// [`PackedFc::matvec`] into a caller buffer — the allocation-free
    /// variant for hot paths that run a matvec per step/request.
    /// Bit-exact with [`PackedFc::matvec`].
    pub fn matvec_into(&self, spike_words: &[u64], out: &mut [i32]) {
        assert_eq!(spike_words.len(), self.words);
        assert!(out.len() >= self.n_out, "psum buffer too small");
        let total = popcount::popcount(spike_words) as i32;
        for (o, slot) in out[..self.n_out].iter_mut().enumerate() {
            let neg = &self.neg[o * self.words..(o + 1) * self.words];
            *slot = total - 2 * popcount::and_popcount(spike_words, neg) as i32;
        }
    }

    /// Time-batched matvec over T steps of flat spikes (step `t` at
    /// `flat[t * words ..][.. words]`), writing psums to
    /// `out[t * n_out + o]`.  Each output row's neg-mask is loaded once
    /// and applied to all T steps — the fc twin of
    /// [`PackedConv::conv_t`]'s weight-reuse ordering — and nothing is
    /// allocated.  Bit-exact with per-step [`PackedFc::matvec`].
    pub fn matvec_t(&self, flat: &[u64], t_steps: usize, out: &mut [i32]) {
        assert_eq!(flat.len(), t_steps * self.words);
        assert!(out.len() >= t_steps * self.n_out, "psum buffer too small");
        let w = self.words;
        for t in 0..t_steps {
            let total = popcount::popcount(&flat[t * w..(t + 1) * w]) as i32;
            out[t * self.n_out..(t + 1) * self.n_out].fill(total);
        }
        // Channel-blocked reduction: FC_BLOCK rows of neg-masks (4 KiB at
        // the CIFAR-scale fc's 64 words/row) stay L1-resident across all T
        // steps, and each step's spike words are streamed once per block
        // instead of once per output row.  i32 popcount sums are
        // order-independent, so the blocking is bit-exact with the
        // row-major order (and with per-step [`PackedFc::matvec`]).
        const FC_BLOCK: usize = 8;
        for o0 in (0..self.n_out).step_by(FC_BLOCK) {
            let o1 = (o0 + FC_BLOCK).min(self.n_out);
            let mut live = [false; FC_BLOCK];
            let mut any = false;
            for o in o0..o1 {
                let nz = self.neg[o * w..(o + 1) * w].iter().any(|&v| v != 0);
                live[o - o0] = nz;
                any |= nz;
            }
            if !any {
                continue; // all +1 weights in this block: psum == total
            }
            for t in 0..t_steps {
                let sw = &flat[t * w..(t + 1) * w];
                let row = &mut out[t * self.n_out..(t + 1) * self.n_out];
                if w == 1 {
                    let s0 = sw[0];
                    for o in o0..o1 {
                        if live[o - o0] {
                            row[o] -= 2 * (s0 & self.neg[o]).count_ones() as i32;
                        }
                    }
                } else {
                    for o in o0..o1 {
                        if live[o - o0] {
                            let neg = &self.neg[o * w..(o + 1) * w];
                            row[o] -= 2 * popcount::and_popcount(sw, neg) as i32;
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    fn random_case(rng: &mut SplitMix64, c_in: usize, c_out: usize, hw: usize, k: usize) {
        let dense: Vec<u8> = (0..c_in * hw * hw).map(|_| (rng.next_below(2)) as u8).collect();
        let weights: Vec<i8> = (0..c_out * c_in * k * k)
            .map(|_| if rng.next_below(2) == 1 { 1 } else { -1 })
            .collect();
        let mut sm = SpikeMap::zeros(c_in, hw, hw);
        for c in 0..c_in {
            for y in 0..hw {
                for x in 0..hw {
                    sm.set(c, y, x, dense[(c * hw + y) * hw + x] == 1);
                }
            }
        }
        let packed = PackedConv::pack(c_out, c_in, k, &weights);
        let fast = packed.conv(&sm);
        let naive = conv_naive(&dense, c_in, hw, hw, &weights, c_out, k);
        assert_eq!(fast, naive);
    }

    #[test]
    fn packed_conv_matches_naive() {
        let mut rng = SplitMix64::new(11);
        random_case(&mut rng, 1, 1, 5, 3);
        random_case(&mut rng, 3, 8, 6, 3);
        random_case(&mut rng, 64, 16, 7, 3);
        random_case(&mut rng, 65, 4, 5, 3); // crosses the word boundary
        random_case(&mut rng, 128, 8, 4, 1);
        random_case(&mut rng, 16, 8, 8, 5);
    }

    #[test]
    fn conv_t_matches_per_step_conv() {
        let mut rng = SplitMix64::new(29);
        for &(c_in, c_out, hw, k, t) in &[
            (1usize, 2usize, 5usize, 3usize, 4usize),
            (65, 4, 6, 3, 2),
            (33, 3, 4, 1, 8),
            (16, 2, 7, 5, 1),
        ] {
            let weights: Vec<i8> = (0..c_out * c_in * k * k)
                .map(|_| if rng.next_below(2) == 1 { 1 } else { -1 })
                .collect();
            let train: Vec<SpikeMap> = (0..t)
                .map(|_| {
                    let mut sm = SpikeMap::zeros(c_in, hw, hw);
                    for c in 0..c_in {
                        for y in 0..hw {
                            for x in 0..hw {
                                sm.set(c, y, x, rng.next_below(2) == 1);
                            }
                        }
                    }
                    sm
                })
                .collect();
            let packed = PackedConv::pack(c_out, c_in, k, &weights);
            let mut scratch = Scratch::new();
            packed.conv_t(&train, &mut scratch);
            let plane = c_out * hw * hw;
            for (ti, s) in train.iter().enumerate() {
                assert_eq!(
                    &scratch.psums()[ti * plane..(ti + 1) * plane],
                    &packed.conv(s)[..],
                    "step {ti} diverges"
                );
            }
        }
    }

    #[test]
    fn matvec_t_matches_per_step_matvec() {
        let mut rng = SplitMix64::new(31);
        for &(n_in, n_out, t) in &[(10usize, 4usize, 3usize), (64, 10, 1), (130, 7, 8)] {
            let w: Vec<i8> = (0..n_out * n_in)
                .map(|_| if rng.next_below(2) == 1 { 1 } else { -1 })
                .collect();
            let packed = PackedFc::pack(n_out, n_in, &w);
            let words = packed.words();
            let mut flat = vec![0u64; t * words];
            for ti in 0..t {
                for i in 0..n_in {
                    if rng.next_below(2) == 1 {
                        flat[ti * words + i / 64] |= 1u64 << (i % 64);
                    }
                }
            }
            let mut out = vec![0i32; t * n_out];
            packed.matvec_t(&flat, t, &mut out);
            for ti in 0..t {
                let per_step = packed.matvec(&flat[ti * words..(ti + 1) * words]);
                assert_eq!(&out[ti * n_out..(ti + 1) * n_out], &per_step[..]);
            }
        }
    }

    #[test]
    fn conv_multibit_into_matches_reference() {
        let mut rng = SplitMix64::new(37);
        let cases = [(1usize, 4usize, 7usize, 3usize), (3, 2, 5, 3), (2, 3, 4, 1), (1, 2, 6, 5)];
        for &(c_in, c_out, hw, k) in &cases {
            let img: Vec<u8> =
                (0..c_in * hw * hw).map(|_| rng.next_below(256) as u8).collect();
            let w: Vec<i8> = (0..c_out * c_in * k * k)
                .map(|_| if rng.next_below(2) == 1 { 1 } else { -1 })
                .collect();
            let reference = conv_multibit(&img, c_in, hw, hw, &w, c_out, k);
            let mut fast = vec![7i32; c_out * hw * hw];
            conv_multibit_into(&img, c_in, hw, hw, &w, c_out, k, &mut fast);
            assert_eq!(fast, reference);
        }
    }

    #[test]
    fn conv_multibit_all_plus_one_sums_window() {
        // 1x3x3 image, one +1 3x3 filter: center output = sum of all pixels.
        let img: Vec<u8> = (1..=9).collect();
        let w = vec![1i8; 9];
        let out = conv_multibit(&img, 1, 3, 3, &w, 1, 3);
        assert_eq!(out[(0 * 3 + 1) * 3 + 1], 45);
        // corner (0,0): window covers pixels (0..2, 0..2) = 1+2+4+5 = 12
        assert_eq!(out[0], 12);
    }

    #[test]
    fn packed_fc_matches_naive() {
        let mut rng = SplitMix64::new(13);
        let mut fast_into = Vec::new();
        for &(n_in, n_out) in &[(10usize, 4usize), (64, 10), (100, 3), (130, 7)] {
            let spikes: Vec<u8> = (0..n_in).map(|_| rng.next_below(2) as u8).collect();
            let w: Vec<i8> = (0..n_out * n_in)
                .map(|_| if rng.next_below(2) == 1 { 1 } else { -1 })
                .collect();
            let mut words = vec![0u64; n_in.div_ceil(64)];
            for (i, &s) in spikes.iter().enumerate() {
                if s == 1 {
                    words[i / 64] |= 1 << (i % 64);
                }
            }
            let packed = PackedFc::pack(n_out, n_in, &w);
            let fast = packed.matvec(&words);
            // Caller-buffer variant reuses one (oversized) buffer across
            // geometries, exactly like the hot paths do.
            fast_into.resize(fast_into.len().max(n_out), 0);
            fast_into.fill(-7);
            packed.matvec_into(&words, &mut fast_into);
            let naive: Vec<i32> = (0..n_out)
                .map(|o| {
                    (0..n_in)
                        .map(|i| spikes[i] as i32 * w[o * n_in + i] as i32)
                        .sum()
                })
                .collect();
            assert_eq!(fast, naive);
            assert_eq!(&fast_into[..n_out], &naive[..]);
        }
    }
}

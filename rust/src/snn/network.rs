//! The golden functional network: integer-exact deployed inference.

use crate::snn::conv::{conv_multibit, PackedConv, PackedFc};
use crate::snn::params::{DeployedModel, Kind, Layer};
use crate::snn::spikemap::SpikeMap;
use crate::util::FIXED_POINT;

/// A prepared (weight-packed) layer ready for inference.
enum Prepared {
    EncConv {
        c_out: usize,
        c_in: usize,
        k: usize,
        w: Vec<i8>,
        bias: Vec<i32>,
        theta: Vec<i32>,
    },
    Conv {
        packed: PackedConv,
        bias: Vec<i32>,
        theta: Vec<i32>,
    },
    MaxPool,
    Fc {
        packed: PackedFc,
        bias: Vec<i32>,
        theta: Vec<i32>,
    },
    Readout {
        packed: PackedFc,
    },
}

/// Per-layer spike trains and membrane residues, for simulator cross-checks.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    /// For each spiking layer (enc/conv/pool/fc): the (T) spike maps it
    /// *emitted*, in network order.
    pub spike_trains: Vec<Vec<SpikeMap>>,
    /// Residual membrane after the last time step for each firing layer
    /// (row-major (C, H, W), or (N) for fc), in network order.
    pub residues: Vec<Vec<i32>>,
}

/// The bit-exact golden model of a deployed VSA network.
pub struct Network {
    pub model: DeployedModel,
    prepared: Vec<Prepared>,
}

impl Network {
    /// Build from parsed VSAW parameters (packs weights for the popcount
    /// fast path once, like the chip loading its weight SRAM).
    pub fn new(model: DeployedModel) -> Self {
        let prepared = model
            .layers
            .iter()
            .map(|ly| match ly {
                Layer::Conv { kind: Kind::EncConv, c_out, c_in, k, w, bias, theta } => {
                    Prepared::EncConv {
                        c_out: *c_out,
                        c_in: *c_in,
                        k: *k,
                        w: w.clone(),
                        bias: bias.clone(),
                        theta: theta.clone(),
                    }
                }
                Layer::Conv { c_out, c_in, k, w, bias, theta, .. } => Prepared::Conv {
                    packed: PackedConv::pack(*c_out, *c_in, *k, w),
                    bias: bias.clone(),
                    theta: theta.clone(),
                },
                Layer::MaxPool => Prepared::MaxPool,
                Layer::Fc { n_out, n_in, w, bias, theta } => Prepared::Fc {
                    packed: PackedFc::pack(*n_out, *n_in, w),
                    bias: bias.clone(),
                    theta: theta.clone(),
                },
                Layer::Readout { n_out, n_in, w } => Prepared::Readout {
                    packed: PackedFc::pack(*n_out, *n_in, w),
                },
            })
            .collect();
        Self { model, prepared }
    }

    /// Load a VSAW file and prepare it.
    pub fn from_vsaw_file(path: &str) -> Result<Self, crate::snn::params::ParseError> {
        Ok(Self::new(DeployedModel::from_file(path)?))
    }

    /// Inference on a raw u8 CHW image; returns the 10 integer logits.
    pub fn infer_u8(&self, image: &[u8]) -> Vec<i64> {
        self.run(image, None)
    }

    /// Inference capturing every intermediate spike train + residue.
    pub fn infer_traced(&self, image: &[u8]) -> (Vec<i64>, Trace) {
        let mut trace = Trace::default();
        let logits = self.run(image, Some(&mut trace));
        (logits, trace)
    }

    /// IF dynamics over per-step psums: `V += FP * psum - bias`, fire at
    /// `V >= theta`, hard reset.  Returns (spikes per step, final residue).
    fn if_fire(
        psums_per_t: &[Vec<i32>],
        bias: &[i32],
        theta: &[i32],
        c: usize,
        hw: usize,
    ) -> (Vec<Vec<bool>>, Vec<i32>) {
        let n = c * hw;
        let mut v = vec![0i32; n];
        let mut spikes = Vec::with_capacity(psums_per_t.len());
        for psum in psums_per_t {
            debug_assert_eq!(psum.len(), n);
            let mut fired = vec![false; n];
            for ch in 0..c {
                let (b, th) = (bias[ch], theta[ch]);
                for i in ch * hw..(ch + 1) * hw {
                    let pre = v[i] + FIXED_POINT * psum[i] - b;
                    if pre >= th {
                        fired[i] = true;
                        v[i] = 0;
                    } else {
                        v[i] = pre;
                    }
                }
            }
            spikes.push(fired);
        }
        (spikes, v)
    }

    fn run(&self, image: &[u8], mut trace: Option<&mut Trace>) -> Vec<i64> {
        let t_steps = self.model.num_steps;
        let (mut h, mut w) = (self.model.in_size, self.model.in_size);
        assert_eq!(
            image.len(),
            self.model.in_channels * h * w,
            "image geometry mismatch"
        );

        // spikes[t] is the current inter-layer spike train.
        let mut spikes: Vec<SpikeMap> = Vec::new();

        for prep in &self.prepared {
            match prep {
                Prepared::EncConv { c_out, c_in, k, w: wts, bias, theta } => {
                    // Conv once, accumulate the same psum every step (§III-F).
                    let psum = conv_multibit(image, *c_in, h, w, wts, *c_out, *k);
                    let psums: Vec<Vec<i32>> = (0..t_steps).map(|_| psum.clone()).collect();
                    let (fired, residue) = Self::if_fire(&psums, bias, theta, *c_out, h * w);
                    spikes = fired
                        .iter()
                        .map(|f| bools_to_map(f, *c_out, h, w))
                        .collect();
                    if let Some(tr) = trace.as_deref_mut() {
                        tr.spike_trains.push(spikes.clone());
                        tr.residues.push(residue);
                    }
                }
                Prepared::Conv { packed, bias, theta } => {
                    let psums: Vec<Vec<i32>> =
                        spikes.iter().map(|s| packed.conv(s)).collect();
                    let (fired, residue) =
                        Self::if_fire(&psums, bias, theta, packed.c_out, h * w);
                    spikes = fired
                        .iter()
                        .map(|f| bools_to_map(f, packed.c_out, h, w))
                        .collect();
                    if let Some(tr) = trace.as_deref_mut() {
                        tr.spike_trains.push(spikes.clone());
                        tr.residues.push(residue);
                    }
                }
                Prepared::MaxPool => {
                    spikes = spikes.iter().map(|s| s.maxpool2()).collect();
                    h /= 2;
                    w /= 2;
                    if let Some(tr) = trace.as_deref_mut() {
                        tr.spike_trains.push(spikes.clone());
                    }
                }
                Prepared::Fc { packed, bias, theta } => {
                    let psums: Vec<Vec<i32>> = spikes
                        .iter()
                        .map(|s| packed.matvec(&s.to_flat_words()))
                        .collect();
                    let (fired, residue) =
                        Self::if_fire(&psums, bias, theta, packed.n_out, 1);
                    spikes = fired
                        .iter()
                        .map(|f| bools_to_map(f, packed.n_out, 1, 1))
                        .collect();
                    h = 1;
                    w = 1;
                    if let Some(tr) = trace.as_deref_mut() {
                        tr.spike_trains.push(spikes.clone());
                        tr.residues.push(residue);
                    }
                }
                Prepared::Readout { packed } => {
                    let mut logits = vec![0i64; packed.n_out];
                    for s in &spikes {
                        for (o, p) in packed.matvec(&s.to_flat_words()).iter().enumerate() {
                            logits[o] += *p as i64;
                        }
                    }
                    return logits;
                }
            }
        }
        panic!("network has no readout layer");
    }
}

fn bools_to_map(fired: &[bool], c: usize, h: usize, w: usize) -> SpikeMap {
    let mut m = SpikeMap::zeros(c, h, w);
    for ch in 0..c {
        for y in 0..h {
            for x in 0..w {
                if fired[(ch * h + y) * w + x] {
                    m.set(ch, y, x, true);
                }
            }
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::params::{DeployedModel, Kind, Layer};

    /// 1-channel 4x4 input, enc conv (1 filter, k=1, w=+1), readout.
    fn micro_model() -> DeployedModel {
        DeployedModel {
            name: "micro".into(),
            num_steps: 3,
            in_channels: 1,
            in_size: 4,
            layers: vec![
                Layer::Conv {
                    kind: Kind::EncConv,
                    c_out: 1,
                    c_in: 1,
                    k: 1,
                    w: vec![1],
                    bias: vec![0],
                    // theta 256*100: pixel value >= 100 fires each step.
                    theta: vec![256 * 100],
                    },
                Layer::Readout {
                    n_out: 2,
                    n_in: 16,
                    // row 0 all +1 (counts spikes), row 1 all -1.
                    w: {
                        let mut v = vec![1i8; 16];
                        v.extend(vec![-1i8; 16]);
                        v
                    },
                },
            ],
        }
    }

    #[test]
    fn encoding_if_and_readout_semantics() {
        let net = Network::new(micro_model());
        // pixel 0 = 250: V=250*256 each step -> fires every step (>=100*256).
        // pixel 1 = 50: fires at t=1 (V=100*256) and t=3 (accumulates to
        //               50,100 after reset at t=1 -> fires at t=3; T=3 so
        //               steps t=0,1,2 -> fires at step 1 only.
        // pixel 2 = 0: never fires.
        let mut img = vec![0u8; 16];
        img[0] = 250;
        img[1] = 50;
        let logits = net.infer_u8(&img);
        // spike counts: pixel0 fires 3x, pixel1 1x -> total 4 spikes.
        assert_eq!(logits[0], 4);
        assert_eq!(logits[1], -4);
    }

    #[test]
    fn traced_matches_plain() {
        let net = Network::new(micro_model());
        let mut img = vec![10u8; 16];
        img[3] = 200;
        let plain = net.infer_u8(&img);
        let (traced, trace) = net.infer_traced(&img);
        assert_eq!(plain, traced);
        assert_eq!(trace.spike_trains.len(), 1); // enc layer only
        assert_eq!(trace.spike_trains[0].len(), 3); // T spike maps
        assert_eq!(trace.residues.len(), 1);
    }

    #[test]
    fn residue_accumulates_subthreshold() {
        let net = Network::new(micro_model());
        let mut img = vec![0u8; 16];
        img[5] = 30; // 3 steps x 30 = 90 < 100 -> no fire, residue 90*256
        let (logits, trace) = net.infer_traced(&img);
        assert_eq!(logits[0], 0);
        assert_eq!(trace.residues[0][5], 90 * 256);
    }
}

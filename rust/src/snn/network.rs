//! The golden functional network: integer-exact deployed inference.
//!
//! The hot path is **time-batched and allocation-free in steady state**
//! (PR1 tentpole): each layer processes its whole T-step spike train
//! before the next layer starts (tick batching, §III-A), each weight
//! vector is loaded once and applied to all T steps (vectorwise reuse,
//! §III-B), conv→IF→maxpool runs fused per output channel so pooled
//! layers never materialize the pre-pool spike train (the software twin
//! of two-layer fusion, §III-G/§III-D), and all working memory lives in a
//! caller-owned [`Scratch`] arena.  The encoding layer convolves the
//! multi-bit image once and streams that single psum through a
//! closed-form IF solution (§III-F: the per-step input is constant, so
//! fire times are periodic).
//!
//! The pre-refactor per-time-step implementation is preserved verbatim as
//! [`crate::baselines::golden_stepwise::StepwiseGolden`] — the bench
//! baseline and a bit-exactness oracle.

use crate::snn::conv::{conv_multibit_into, PackedConv, PackedFc};
use crate::snn::params::{DeployedModel, Kind, Layer};
use crate::snn::scratch::Scratch;
use crate::snn::spikemap::SpikeMap;
use crate::util::FIXED_POINT;

/// A prepared (weight-packed) layer ready for inference.
enum Prepared {
    EncConv {
        c_out: usize,
        c_in: usize,
        k: usize,
        w: Vec<i8>,
        bias: Vec<i32>,
        theta: Vec<i32>,
    },
    Conv {
        packed: PackedConv,
        bias: Vec<i32>,
        theta: Vec<i32>,
    },
    MaxPool,
    Fc {
        packed: PackedFc,
        bias: Vec<i32>,
        theta: Vec<i32>,
    },
    Readout {
        packed: PackedFc,
    },
}

/// Per-layer spike trains and membrane residues, for simulator cross-checks.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    /// For each spiking layer (enc/conv/pool/fc): the (T) spike maps it
    /// *emitted*, in network order.
    pub spike_trains: Vec<Vec<SpikeMap>>,
    /// Residual membrane after the last time step for each firing layer
    /// (row-major (C, H, W), or (N) for fc), in network order.
    pub residues: Vec<Vec<i32>>,
}

/// The bit-exact golden model of a deployed VSA network.
pub struct Network {
    pub model: DeployedModel,
    prepared: Vec<Prepared>,
}

impl Network {
    /// Build from parsed VSAW parameters (packs weights for the popcount
    /// fast path once, like the chip loading its weight SRAM).
    pub fn new(model: DeployedModel) -> Self {
        let prepared = model
            .layers
            .iter()
            .map(|ly| match ly {
                Layer::Conv { kind: Kind::EncConv, c_out, c_in, k, w, bias, theta } => {
                    Prepared::EncConv {
                        c_out: *c_out,
                        c_in: *c_in,
                        k: *k,
                        w: w.clone(),
                        bias: bias.clone(),
                        theta: theta.clone(),
                    }
                }
                Layer::Conv { c_out, c_in, k, w, bias, theta, .. } => Prepared::Conv {
                    packed: PackedConv::pack(*c_out, *c_in, *k, w),
                    bias: bias.clone(),
                    theta: theta.clone(),
                },
                Layer::MaxPool => Prepared::MaxPool,
                Layer::Fc { n_out, n_in, w, bias, theta } => Prepared::Fc {
                    packed: PackedFc::pack(*n_out, *n_in, w),
                    bias: bias.clone(),
                    theta: theta.clone(),
                },
                Layer::Readout { n_out, n_in, w } => Prepared::Readout {
                    packed: PackedFc::pack(*n_out, *n_in, w),
                },
            })
            .collect();
        Self { model, prepared }
    }

    /// Load a VSAW file and prepare it.
    pub fn from_vsaw_file(path: &str) -> Result<Self, crate::snn::params::ParseError> {
        Ok(Self::new(DeployedModel::from_file(path)?))
    }

    /// Inference on a raw u8 CHW image; returns the 10 integer logits.
    /// Allocates a throwaway [`Scratch`] — hot callers should hold one
    /// and use [`Network::infer_u8_with`].
    pub fn infer_u8(&self, image: &[u8]) -> Vec<i64> {
        let mut scratch = Scratch::new();
        self.run(image, &mut scratch, None)
    }

    /// Inference reusing a caller-owned [`Scratch`] arena: after the
    /// first call at a given model geometry, the run performs zero heap
    /// allocation apart from the returned logits vector.
    pub fn infer_u8_with(&self, image: &[u8], scratch: &mut Scratch) -> Vec<i64> {
        self.run(image, scratch, None)
    }

    /// Inference capturing every intermediate spike train + residue.
    pub fn infer_traced(&self, image: &[u8]) -> (Vec<i64>, Trace) {
        let mut scratch = Scratch::new();
        let mut trace = Trace::default();
        let logits = self.run(image, &mut scratch, Some(&mut trace));
        (logits, trace)
    }

    fn run(
        &self,
        image: &[u8],
        scratch: &mut Scratch,
        mut trace: Option<&mut Trace>,
    ) -> Vec<i64> {
        let t_steps = self.model.num_steps;
        let (mut h, mut w) = (self.model.in_size, self.model.in_size);
        assert_eq!(
            image.len(),
            self.model.in_channels * h * w,
            "image geometry mismatch"
        );

        // conv→IF→pool fuses only when not tracing: the trace records the
        // pre-pool spike train the chip simulator cross-checks against.
        let fuse = trace.is_none();

        // Take the spike-train ping-pong buffers out of the arena so the
        // remaining scratch fields stay borrowable by the kernels.
        let mut cur = std::mem::take(&mut scratch.train_in);
        let mut nxt = std::mem::take(&mut scratch.train_out);

        let mut logits: Option<Vec<i64>> = None;
        let mut i = 0;
        while i < self.prepared.len() {
            match &self.prepared[i] {
                Prepared::EncConv { c_out, c_in, k, w: wts, bias, theta } => {
                    let pool_next = fuse
                        && matches!(self.prepared.get(i + 1), Some(Prepared::MaxPool));
                    let plane = c_out * h * w;
                    scratch.ensure_enc(plane);
                    // Conv once; the IF unit re-accumulates the same psum
                    // every step (§III-F) — no cloning, no re-convolving.
                    conv_multibit_into(
                        image,
                        *c_in,
                        h,
                        w,
                        wts,
                        *c_out,
                        *k,
                        &mut scratch.enc_psum,
                    );
                    let (oh, ow) = if pool_next { (h / 2, w / 2) } else { (h, w) };
                    reset_train(&mut nxt, t_steps, *c_out, oh, ow);
                    if_fire_constant(
                        &scratch.enc_psum[..plane],
                        t_steps,
                        bias,
                        theta,
                        *c_out,
                        h,
                        w,
                        pool_next,
                        &mut scratch.v,
                        &mut nxt,
                    );
                    if let Some(tr) = trace.as_deref_mut() {
                        tr.spike_trains.push(nxt.clone());
                        tr.residues.push(scratch.v[..plane].to_vec());
                    }
                    if pool_next {
                        h = oh;
                        w = ow;
                        i += 2;
                    } else {
                        i += 1;
                    }
                    std::mem::swap(&mut cur, &mut nxt);
                }
                Prepared::Conv { packed, bias, theta } => {
                    let pool_next = fuse
                        && matches!(self.prepared.get(i + 1), Some(Prepared::MaxPool));
                    let steps = cur.len();
                    let hw = h * w;
                    let plane = packed.c_out * hw;
                    scratch.ensure_fused(steps, plane, hw);
                    packed.tap_ones_t(&cur, &mut scratch.ones, &mut scratch.ones_sum);
                    let (oh, ow) = if pool_next { (h / 2, w / 2) } else { (h, w) };
                    reset_train(&mut nxt, steps, packed.c_out, oh, ow);
                    // Fused conv→IF→(pool): one output channel at a time,
                    // its T psum planes cache-resident, fired bits written
                    // straight into the packed (possibly pooled) maps.
                    let channels = if steps > 0 {
                        packed.c_out
                    } else {
                        scratch.v[..plane].fill(0); // residue of an empty train
                        0
                    };
                    for o in 0..channels {
                        packed.conv_channel_t(
                            &cur,
                            o,
                            &scratch.ones_sum[..steps * hw],
                            &mut scratch.chan_psum[..steps * hw],
                        );
                        if_fire_channel(
                            &scratch.chan_psum[..steps * hw],
                            steps,
                            bias[o],
                            theta[o],
                            o,
                            h,
                            w,
                            pool_next,
                            &mut scratch.v[o * hw..(o + 1) * hw],
                            &mut nxt,
                        );
                    }
                    if let Some(tr) = trace.as_deref_mut() {
                        tr.spike_trains.push(nxt.clone());
                        tr.residues.push(scratch.v[..plane].to_vec());
                    }
                    if pool_next {
                        h = oh;
                        w = ow;
                        i += 2;
                    } else {
                        i += 1;
                    }
                    std::mem::swap(&mut cur, &mut nxt);
                }
                Prepared::MaxPool => {
                    let c = cur.first().map_or(0, |m| m.channels());
                    reset_train(&mut nxt, cur.len(), c, h / 2, w / 2);
                    for (s, d) in cur.iter().zip(nxt.iter_mut()) {
                        s.maxpool2_into(d);
                    }
                    h /= 2;
                    w /= 2;
                    if let Some(tr) = trace.as_deref_mut() {
                        tr.spike_trains.push(nxt.clone());
                    }
                    i += 1;
                    std::mem::swap(&mut cur, &mut nxt);
                }
                Prepared::Fc { packed, bias, theta } => {
                    let steps = flatten_and_matvec(packed, &cur, scratch);
                    reset_train(&mut nxt, steps, packed.n_out, 1, 1);
                    if_fire_t(
                        &scratch.psums,
                        packed.n_out,
                        steps,
                        bias,
                        theta,
                        packed.n_out,
                        1,
                        1,
                        &mut scratch.v[..packed.n_out],
                        &mut nxt,
                    );
                    h = 1;
                    w = 1;
                    if let Some(tr) = trace.as_deref_mut() {
                        tr.spike_trains.push(nxt.clone());
                        tr.residues.push(scratch.v[..packed.n_out].to_vec());
                    }
                    i += 1;
                    std::mem::swap(&mut cur, &mut nxt);
                }
                Prepared::Readout { packed } => {
                    let steps = flatten_and_matvec(packed, &cur, scratch);
                    let mut lg = vec![0i64; packed.n_out];
                    for t in 0..steps {
                        for (o, l) in lg.iter_mut().enumerate() {
                            *l += scratch.psums[t * packed.n_out + o] as i64;
                        }
                    }
                    logits = Some(lg);
                    break;
                }
            }
        }

        // Hand the ping-pong buffers back for the next inference.
        scratch.train_in = cur;
        scratch.train_out = nxt;
        logits.expect("network has no readout layer")
    }
}

/// Shared fc/readout preamble: pack the spike train's flat words into the
/// arena and run the time-batched matvec.  Psums land in
/// `scratch.psums[t * n_out + o]`; returns the step count.  Shared with
/// the chip simulator's time-batched fast mode (`arch::chip`).
pub(crate) fn flatten_and_matvec(
    packed: &PackedFc,
    cur: &[SpikeMap],
    scratch: &mut Scratch,
) -> usize {
    let steps = cur.len();
    let words = packed.words();
    scratch.ensure_fc(steps, words, packed.n_out);
    for (t, s) in cur.iter().enumerate() {
        s.to_flat_words_into(&mut scratch.flat[t * words..(t + 1) * words]);
    }
    packed.matvec_t(&scratch.flat[..steps * words], steps, &mut scratch.psums);
    steps
}

/// Resize a reusable spike train to exactly `t` maps of (c, h, w),
/// cleared, without reallocating word buffers that already fit.
pub(crate) fn reset_train(train: &mut Vec<SpikeMap>, t: usize, c: usize, h: usize, w: usize) {
    train.truncate(t);
    for m in train.iter_mut() {
        m.reset(c, h, w);
    }
    while train.len() < t {
        train.push(SpikeMap::zeros(c, h, w));
    }
}

/// IF dynamics over per-step psum planes (`psums[t * stride ..]`),
/// writing fired bits directly into the packed spike maps (no
/// `Vec<bool>` round-trip).  `V += FIXED_POINT * psum - bias`, fire at
/// `V >= theta`, hard reset.  `v` must cover `c * h * w` and is reset
/// here.  Returns the number of spikes fired (the chip simulator's
/// per-layer `spikes_emitted` counter).
#[allow(clippy::too_many_arguments)]
pub(crate) fn if_fire_t(
    psums: &[i32],
    stride: usize,
    t_steps: usize,
    bias: &[i32],
    theta: &[i32],
    c: usize,
    h: usize,
    w: usize,
    v: &mut [i32],
    out: &mut [SpikeMap],
) -> u64 {
    let hw = h * w;
    let n = c * hw;
    let mut fired = 0u64;
    v[..n].fill(0);
    for t in 0..t_steps {
        let psum = &psums[t * stride..t * stride + n];
        let m = &mut out[t];
        for ch in 0..c {
            let (b, th) = (bias[ch], theta[ch]);
            for y in 0..h {
                for x in 0..w {
                    let j = ch * hw + y * w + x;
                    let pre = v[j] + FIXED_POINT * psum[j] - b;
                    if pre >= th {
                        v[j] = 0;
                        fired += 1;
                        m.or_bit(ch, y, x);
                    } else {
                        v[j] = pre;
                    }
                }
            }
        }
    }
    fired
}

/// IF dynamics for ONE output channel over its T-step psum planes
/// (`psums[t * h * w + j]`), optionally fusing the 2×2 max pool by OR-ing
/// fired bits into the pooled map position.  `v` covers `h * w` for this
/// channel and is reset here.  Returns the number of spikes fired
/// (pre-pool: every fire event counts, even when several OR into the
/// same pooled bit).
#[allow(clippy::too_many_arguments)]
pub(crate) fn if_fire_channel(
    psums: &[i32],
    t_steps: usize,
    bias: i32,
    theta: i32,
    ch: usize,
    h: usize,
    w: usize,
    pooled: bool,
    v: &mut [i32],
    out: &mut [SpikeMap],
) -> u64 {
    let hw = h * w;
    // Pooled output bounds (odd trailing rows/cols are dropped, exactly
    // like `SpikeMap::maxpool2`).
    let (oh, ow) = (h / 2, w / 2);
    let mut fired = 0u64;
    v[..hw].fill(0);
    for t in 0..t_steps {
        let psum = &psums[t * hw..(t + 1) * hw];
        let m = &mut out[t];
        for y in 0..h {
            for x in 0..w {
                let j = y * w + x;
                let pre = v[j] + FIXED_POINT * psum[j] - bias;
                if pre >= theta {
                    v[j] = 0;
                    fired += 1;
                    emit(m, ch, y, x, pooled, oh, ow);
                } else {
                    v[j] = pre;
                }
            }
        }
    }
    fired
}

/// IF dynamics when every step receives the SAME psum (the encoding
/// layer, §III-F).  With a constant per-step increment `d = FP*psum - b`
/// and hard reset, the fire pattern is periodic and solvable in closed
/// form per neuron: no fire when `d <= 0`; otherwise the neuron fires
/// every `ceil(theta / d)` steps.  Bit-exact with stepping the plain IF
/// recurrence (verified against the stepwise oracle), O(#spikes) instead
/// of O(T · neurons).  Returns the number of spikes fired (pre-pool).
#[allow(clippy::too_many_arguments)]
pub(crate) fn if_fire_constant(
    psum: &[i32],
    t_steps: usize,
    bias: &[i32],
    theta: &[i32],
    c: usize,
    h: usize,
    w: usize,
    pooled: bool,
    v: &mut [i32],
    out: &mut [SpikeMap],
) -> u64 {
    let hw = h * w;
    let (oh, ow) = (h / 2, w / 2);
    let mut fired = 0u64;
    for ch in 0..c {
        let (b, th) = (bias[ch], theta[ch]);
        for y in 0..h {
            for x in 0..w {
                let j = ch * hw + y * w + x;
                let d = FIXED_POINT * psum[j] - b;
                if th <= 0 {
                    // Degenerate threshold: fall back to the literal
                    // recurrence (parsers reject theta <= 0, but direct
                    // model builders might not).
                    let mut vj = 0i32;
                    for m in out.iter_mut().take(t_steps) {
                        let pre = vj + d;
                        if pre >= th {
                            vj = 0;
                            fired += 1;
                            emit(m, ch, y, x, pooled, oh, ow);
                        } else {
                            vj = pre;
                        }
                    }
                    v[j] = vj;
                } else if d <= 0 {
                    // Monotonically non-increasing from 0: never fires.
                    v[j] = (d as i64 * t_steps as i64) as i32;
                } else {
                    // Fires whenever the accumulated potential first
                    // reaches theta: every p = ceil(theta / d) steps.
                    let p = ((th as i64 + d as i64 - 1) / d as i64) as usize;
                    let fires = t_steps / p;
                    fired += fires as u64;
                    let mut t = p - 1;
                    for _ in 0..fires {
                        emit(&mut out[t], ch, y, x, pooled, oh, ow);
                        t += p;
                    }
                    v[j] = ((t_steps % p) as i64 * d as i64) as i32;
                }
            }
        }
    }
    fired
}

#[inline]
fn emit(m: &mut SpikeMap, ch: usize, y: usize, x: usize, pooled: bool, oh: usize, ow: usize) {
    if pooled {
        let (py, px) = (y / 2, x / 2);
        if py < oh && px < ow {
            m.or_bit(ch, py, px);
        }
    } else {
        m.or_bit(ch, y, x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::params::{DeployedModel, Kind, Layer};

    /// 1-channel 4x4 input, enc conv (1 filter, k=1, w=+1), readout.
    fn micro_model() -> DeployedModel {
        DeployedModel {
            name: "micro".into(),
            num_steps: 3,
            in_channels: 1,
            in_size: 4,
            layers: vec![
                Layer::Conv {
                    kind: Kind::EncConv,
                    c_out: 1,
                    c_in: 1,
                    k: 1,
                    w: vec![1],
                    bias: vec![0],
                    // theta 256*100: pixel value >= 100 fires each step.
                    theta: vec![256 * 100],
                    },
                Layer::Readout {
                    n_out: 2,
                    n_in: 16,
                    // row 0 all +1 (counts spikes), row 1 all -1.
                    w: {
                        let mut v = vec![1i8; 16];
                        v.extend(vec![-1i8; 16]);
                        v
                    },
                },
            ],
        }
    }

    #[test]
    fn encoding_if_and_readout_semantics() {
        let net = Network::new(micro_model());
        // pixel 0 = 250: V=250*256 each step -> fires every step (>=100*256).
        // pixel 1 = 50: fires at t=1 (V=100*256) and t=3 (accumulates to
        //               50,100 after reset at t=1 -> fires at t=3; T=3 so
        //               steps t=0,1,2 -> fires at step 1 only.
        // pixel 2 = 0: never fires.
        let mut img = vec![0u8; 16];
        img[0] = 250;
        img[1] = 50;
        let logits = net.infer_u8(&img);
        // spike counts: pixel0 fires 3x, pixel1 1x -> total 4 spikes.
        assert_eq!(logits[0], 4);
        assert_eq!(logits[1], -4);
    }

    #[test]
    fn traced_matches_plain() {
        let net = Network::new(micro_model());
        let mut img = vec![10u8; 16];
        img[3] = 200;
        let plain = net.infer_u8(&img);
        let (traced, trace) = net.infer_traced(&img);
        assert_eq!(plain, traced);
        assert_eq!(trace.spike_trains.len(), 1); // enc layer only
        assert_eq!(trace.spike_trains[0].len(), 3); // T spike maps
        assert_eq!(trace.residues.len(), 1);
    }

    #[test]
    fn residue_accumulates_subthreshold() {
        let net = Network::new(micro_model());
        let mut img = vec![0u8; 16];
        img[5] = 30; // 3 steps x 30 = 90 < 100 -> no fire, residue 90*256
        let (logits, trace) = net.infer_traced(&img);
        assert_eq!(logits[0], 0);
        assert_eq!(trace.residues[0][5], 90 * 256);
    }

    #[test]
    fn scratch_reuse_is_deterministic() {
        let net = Network::new(micro_model());
        let mut scratch = Scratch::new();
        let mut img = vec![0u8; 16];
        img[0] = 250;
        img[7] = 130;
        let first = net.infer_u8_with(&img, &mut scratch);
        for _ in 0..3 {
            assert_eq!(net.infer_u8_with(&img, &mut scratch), first);
        }
        // Different image through the same (dirty) scratch.
        let clean = net.infer_u8(&[9u8; 16]);
        assert_eq!(net.infer_u8_with(&[9u8; 16], &mut scratch), clean);
    }
}

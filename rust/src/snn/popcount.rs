//! Wide AND-popcount primitives shared by every packed binary kernel.
//!
//! The paper's vectorwise datapath (§III-B) is an AND-gate array feeding a
//! popcount tree; the software mirror is `popcnt(s & w_neg)` over packed
//! `u64` words.  This module provides the one hot reduction the conv and fc
//! kernels share, in three bit-identical flavors selected once at runtime:
//!
//! * **scalar** — lane-unrolled (4 independent accumulators) portable Rust;
//!   always compiled, and the oracle the wide paths are pinned against.
//! * **popcnt** — the same body compiled with the x86_64 `popcnt` feature so
//!   `count_ones()` lowers to the hardware instruction.
//! * **avx2** — 256-bit AND + the nibble-LUT/`vpsadbw` popcount (Mula's
//!   method), 4 words per vector step.
//!
//! Integer popcount sums are associative, so every flavor returns the exact
//! same value for the same input — dispatch can never change results, only
//! speed.  `VSA_FORCE_SCALAR=1` (or [`set_force_scalar`] from tests/benches)
//! pins the scalar fallback so CI can gate the oracle on every run.

use std::sync::atomic::{AtomicU8, Ordering};

const UNINIT: u8 = 0;
const SCALAR: u8 = 1;
#[cfg(target_arch = "x86_64")]
const POPCNT: u8 = 2;
#[cfg(target_arch = "x86_64")]
const AVX2: u8 = 3;

/// Cached dispatch level; `UNINIT` until first use or a forced override.
static LEVEL: AtomicU8 = AtomicU8::new(UNINIT);

fn detect() -> u8 {
    if std::env::var_os("VSA_FORCE_SCALAR").is_some_and(|v| !v.is_empty() && v != "0") {
        return SCALAR;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_64_feature_detected!("avx2") {
            return AVX2;
        }
        if is_x86_64_feature_detected!("popcnt") {
            return POPCNT;
        }
    }
    SCALAR
}

#[inline]
fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l != UNINIT {
        return l;
    }
    let l = detect();
    LEVEL.store(l, Ordering::Relaxed);
    l
}

/// Force (or release) the always-compiled scalar fallback.  Tests and
/// benches use this to compare the wide paths against the oracle in one
/// process; `VSA_FORCE_SCALAR=1` does the same from the environment.
pub fn set_force_scalar(force: bool) {
    LEVEL.store(if force { SCALAR } else { UNINIT }, Ordering::Relaxed);
}

/// Name of the active kernel flavor (for bench rows / logs).
pub fn active_kernel() -> &'static str {
    match level() {
        SCALAR => "scalar",
        #[cfg(target_arch = "x86_64")]
        POPCNT => "popcnt",
        #[cfg(target_arch = "x86_64")]
        AVX2 => "avx2",
        _ => "scalar",
    }
}

/// `popcnt(a & b)` over word slices (shorter slice bounds the reduction).
#[inline]
pub fn and_popcount(a: &[u64], b: &[u64]) -> u32 {
    match level() {
        #[cfg(target_arch = "x86_64")]
        AVX2 => unsafe { and_popcount_avx2(a, b) },
        #[cfg(target_arch = "x86_64")]
        POPCNT => unsafe { and_popcount_popcnt(a, b) },
        _ => and_popcount_scalar(a, b),
    }
}

/// `popcnt(a)` over a word slice.
#[inline]
pub fn popcount(a: &[u64]) -> u32 {
    match level() {
        #[cfg(target_arch = "x86_64")]
        AVX2 => unsafe { popcount_avx2(a) },
        #[cfg(target_arch = "x86_64")]
        POPCNT => unsafe { popcount_popcnt(a) },
        _ => popcount_scalar(a),
    }
}

/// Lane-unrolled scalar reduction: 4 independent accumulators break the
/// add chain so the portable path still issues ~4 popcounts per cycle.
#[inline]
pub(crate) fn and_popcount_scalar(a: &[u64], b: &[u64]) -> u32 {
    let n = a.len().min(b.len());
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0u32, 0u32, 0u32, 0u32);
    for c in 0..chunks {
        let i = c * 4;
        s0 += (a[i] & b[i]).count_ones();
        s1 += (a[i + 1] & b[i + 1]).count_ones();
        s2 += (a[i + 2] & b[i + 2]).count_ones();
        s3 += (a[i + 3] & b[i + 3]).count_ones();
    }
    let mut total = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        total += (a[i] & b[i]).count_ones();
    }
    total
}

#[inline]
pub(crate) fn popcount_scalar(a: &[u64]) -> u32 {
    let chunks = a.len() / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0u32, 0u32, 0u32, 0u32);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i].count_ones();
        s1 += a[i + 1].count_ones();
        s2 += a[i + 2].count_ones();
        s3 += a[i + 3].count_ones();
    }
    let mut total = s0 + s1 + s2 + s3;
    for i in chunks * 4..a.len() {
        total += a[i].count_ones();
    }
    total
}

// The `popcnt` flavors reuse the scalar bodies: inlining under
// `#[target_feature]` recompiles them with hardware popcount enabled.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "popcnt")]
unsafe fn and_popcount_popcnt(a: &[u64], b: &[u64]) -> u32 {
    and_popcount_scalar(a, b)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "popcnt")]
unsafe fn popcount_popcnt(a: &[u64]) -> u32 {
    popcount_scalar(a)
}

/// AVX2 AND-popcount: nibble lookup (`vpshufb`) + `vpsadbw` horizontal
/// sum, 4 `u64` words per iteration, scalar tail for the remainder.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn and_popcount_avx2(a: &[u64], b: &[u64]) -> u32 {
    use std::arch::x86_64::*;
    let n = a.len().min(b.len());
    let chunks = n / 4;
    #[rustfmt::skip]
    let lut = _mm256_setr_epi8(
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
    );
    let low_mask = _mm256_set1_epi8(0x0f);
    let mut acc = _mm256_setzero_si256();
    for c in 0..chunks {
        let va = _mm256_loadu_si256(a.as_ptr().add(c * 4) as *const __m256i);
        let vb = _mm256_loadu_si256(b.as_ptr().add(c * 4) as *const __m256i);
        let v = _mm256_and_si256(va, vb);
        let lo = _mm256_and_si256(v, low_mask);
        let hi = _mm256_and_si256(_mm256_srli_epi16(v, 4), low_mask);
        let cnt = _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi));
        acc = _mm256_add_epi64(acc, _mm256_sad_epu8(cnt, _mm256_setzero_si256()));
    }
    let mut lanes = [0u64; 4];
    _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
    let mut total = (lanes[0] + lanes[1] + lanes[2] + lanes[3]) as u32;
    for i in chunks * 4..n {
        total += (a[i] & b[i]).count_ones();
    }
    total
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn popcount_avx2(a: &[u64]) -> u32 {
    use std::arch::x86_64::*;
    let chunks = a.len() / 4;
    #[rustfmt::skip]
    let lut = _mm256_setr_epi8(
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
    );
    let low_mask = _mm256_set1_epi8(0x0f);
    let mut acc = _mm256_setzero_si256();
    for c in 0..chunks {
        let v = _mm256_loadu_si256(a.as_ptr().add(c * 4) as *const __m256i);
        let lo = _mm256_and_si256(v, low_mask);
        let hi = _mm256_and_si256(_mm256_srli_epi16(v, 4), low_mask);
        let cnt = _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi));
        acc = _mm256_add_epi64(acc, _mm256_sad_epu8(cnt, _mm256_setzero_si256()));
    }
    let mut lanes = [0u64; 4];
    _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
    let mut total = (lanes[0] + lanes[1] + lanes[2] + lanes[3]) as u32;
    for i in chunks * 4..a.len() {
        total += a[i].count_ones();
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    fn ref_and_pop(a: &[u64], b: &[u64]) -> u32 {
        a.iter().zip(b).map(|(x, y)| (x & y).count_ones()).sum()
    }

    #[test]
    fn all_flavors_match_word_at_a_time_reference() {
        let mut rng = SplitMix64::new(0x9d0c);
        // Lane-boundary lengths: below/at/above the 4-word unroll, plus
        // all-zero and all-ones words.
        for &n in &[0usize, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 64, 65] {
            let a: Vec<u64> = (0..n).map(|_| rng.next()).collect();
            let b: Vec<u64> = (0..n).map(|_| rng.next()).collect();
            let want = ref_and_pop(&a, &b);
            let want_pop: u32 = a.iter().map(|v| v.count_ones()).sum();
            assert_eq!(and_popcount_scalar(&a, &b), want, "scalar n={n}");
            assert_eq!(popcount_scalar(&a), want_pop, "scalar pop n={n}");
            assert_eq!(and_popcount(&a, &b), want, "dispatched n={n}");
            assert_eq!(popcount(&a), want_pop, "dispatched pop n={n}");
            set_force_scalar(true);
            assert_eq!(and_popcount(&a, &b), want, "forced-scalar n={n}");
            assert_eq!(popcount(&a), want_pop, "forced-scalar pop n={n}");
            set_force_scalar(false);
            let zeros = vec![0u64; n];
            let ones = vec![u64::MAX; n];
            assert_eq!(and_popcount(&ones, &zeros), 0);
            assert_eq!(and_popcount(&ones, &ones), 64 * n as u32);
            assert_eq!(popcount(&ones), 64 * n as u32);
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn hardware_flavors_match_scalar() {
        let mut rng = SplitMix64::new(0xfeed);
        for &n in &[1usize, 3, 4, 5, 8, 9, 64, 100] {
            let a: Vec<u64> = (0..n).map(|_| rng.next()).collect();
            let b: Vec<u64> = (0..n).map(|_| rng.next()).collect();
            let want = and_popcount_scalar(&a, &b);
            let want_pop = popcount_scalar(&a);
            if is_x86_64_feature_detected!("popcnt") {
                assert_eq!(unsafe { and_popcount_popcnt(&a, &b) }, want);
                assert_eq!(unsafe { popcount_popcnt(&a) }, want_pop);
            }
            if is_x86_64_feature_detected!("avx2") {
                assert_eq!(unsafe { and_popcount_avx2(&a, &b) }, want);
                assert_eq!(unsafe { popcount_avx2(&a) }, want_pop);
            }
        }
    }
}

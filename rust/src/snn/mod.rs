//! Functional golden model of the deployed binary-weight spiking network.
//!
//! Integer-exact twin of `python/compile/model.py::forward_deployed` (and
//! therefore of the AOT-compiled HLO modules): same spikes, same membrane
//! residues, same logits, on the same VSAW weights.  The cycle-accurate
//! simulator in [`crate::arch`] is verified spike-for-spike against this
//! model.
//!
//! ## Numerical contract (see python/compile/kernels/ref.py)
//!
//! * weights are +-1 (stored as i8);
//! * spikes are 0/1;
//! * IF-BN bias/theta are integers premultiplied by
//!   [`crate::util::FIXED_POINT`], so membrane arithmetic is
//!   `V += FIXED_POINT * conv_out - bias;  fire when V >= theta` with a
//!   hard reset (`V = 0`) after each fire;
//! * the encoding layer convolves the multi-bit image **once** and
//!   re-accumulates the same psum every time step (paper §III-F);
//! * the readout layer accumulates raw (unscaled) psums into the logits.

pub mod conv;
pub mod network;
pub mod params;
pub mod popcount;
pub mod scratch;
pub mod spikemap;

pub use network::Network;
pub use scratch::Scratch;
pub use spikemap::SpikeMap;

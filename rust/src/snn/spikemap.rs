//! Channel-packed spike tensors.
//!
//! A `SpikeMap` stores one time step of a (C, H, W) binary feature map
//! with the channel axis packed into u64 words per pixel — the layout the
//! popcount-based binary convolution consumes.  This is the software
//! mirror of the chip's spike SRAM word organization (one vectorwise read
//! delivers a whole channel group, §III-A).

use crate::util::ceil_div;

/// One time step of binary activations, channel-packed per pixel.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpikeMap {
    channels: usize,
    height: usize,
    width: usize,
    /// words per pixel = ceil(channels / 64)
    wpp: usize,
    /// data[(y * width + x) * wpp + w]
    data: Vec<u64>,
}

impl SpikeMap {
    /// All-zero map.
    pub fn zeros(channels: usize, height: usize, width: usize) -> Self {
        let wpp = ceil_div(channels.max(1), 64);
        Self {
            channels,
            height,
            width,
            wpp,
            data: vec![0; height * width * wpp],
        }
    }

    /// Re-shape to an all-zero (c, h, w) map, reusing the existing word
    /// buffer.  After the first call at a given geometry this performs no
    /// heap allocation — the reuse primitive of the inference hot path.
    pub fn reset(&mut self, channels: usize, height: usize, width: usize) {
        let wpp = ceil_div(channels.max(1), 64);
        self.channels = channels;
        self.height = height;
        self.width = width;
        self.wpp = wpp;
        let n = height * width * wpp;
        self.data.clear();
        self.data.resize(n, 0);
    }

    /// Geometry accessors.
    pub fn channels(&self) -> usize {
        self.channels
    }
    pub fn height(&self) -> usize {
        self.height
    }
    pub fn width(&self) -> usize {
        self.width
    }
    /// Words per pixel.
    pub fn wpp(&self) -> usize {
        self.wpp
    }

    /// Set spike (c, y, x).
    #[inline]
    pub fn set(&mut self, c: usize, y: usize, x: usize, v: bool) {
        debug_assert!(c < self.channels && y < self.height && x < self.width);
        let idx = (y * self.width + x) * self.wpp + c / 64;
        if v {
            self.data[idx] |= 1u64 << (c % 64);
        } else {
            self.data[idx] &= !(1u64 << (c % 64));
        }
    }

    /// Read spike (c, y, x).
    #[inline]
    pub fn get(&self, c: usize, y: usize, x: usize) -> bool {
        let idx = (y * self.width + x) * self.wpp + c / 64;
        (self.data[idx] >> (c % 64)) & 1 == 1
    }

    /// OR a spike into (c, y, x) — the write primitive of the packed IF
    /// fire path (the map is pre-cleared, so only set bits are touched).
    #[inline]
    pub fn or_bit(&mut self, c: usize, y: usize, x: usize) {
        debug_assert!(c < self.channels && y < self.height && x < self.width);
        let idx = (y * self.width + x) * self.wpp + c / 64;
        self.data[idx] |= 1u64 << (c % 64);
    }

    /// The channel words of one pixel.
    #[inline]
    pub fn pixel_words(&self, y: usize, x: usize) -> &[u64] {
        let base = (y * self.width + x) * self.wpp;
        &self.data[base..base + self.wpp]
    }

    /// The raw packed words, `(y * width + x) * wpp + w` indexed — the
    /// contiguous view the optimized convolution inner loop walks.
    #[inline]
    pub fn raw_words(&self) -> &[u64] {
        &self.data
    }

    /// Spike count (over channels) at one pixel.
    #[inline]
    pub fn pixel_popcount(&self, y: usize, x: usize) -> u32 {
        self.pixel_words(y, x).iter().map(|w| w.count_ones()).sum()
    }

    /// Total spike count.
    pub fn total_spikes(&self) -> u64 {
        self.data.iter().map(|w| w.count_ones() as u64).sum()
    }

    /// 2x2/2 max pool (OR over each window) — paper's MP2 on spikes.
    pub fn maxpool2(&self) -> SpikeMap {
        let mut out = SpikeMap::zeros(self.channels, self.height / 2, self.width / 2);
        self.maxpool2_into(&mut out);
        out
    }

    /// `maxpool2` into a caller-owned (pre-reset) map — allocation-free.
    pub fn maxpool2_into(&self, out: &mut SpikeMap) {
        debug_assert_eq!(out.channels, self.channels);
        debug_assert_eq!(out.height, self.height / 2);
        debug_assert_eq!(out.width, self.width / 2);
        for y in 0..out.height {
            for x in 0..out.width {
                let base = (y * out.width + x) * out.wpp;
                for w in 0..self.wpp {
                    let a = self.pixel_words(2 * y, 2 * x)[w];
                    let b = self.pixel_words(2 * y, 2 * x + 1)[w];
                    let c = self.pixel_words(2 * y + 1, 2 * x)[w];
                    let d = self.pixel_words(2 * y + 1, 2 * x + 1)[w];
                    out.data[base + w] = a | b | c | d;
                }
            }
        }
    }

    /// Number of words `to_flat_words`/`to_flat_words_into` produce.
    #[inline]
    pub fn flat_words_len(&self) -> usize {
        ceil_div((self.channels * self.height * self.width).max(1), 64)
    }

    /// Flatten to (c, y, x) C-major bit order — matches numpy's
    /// `spikes.reshape(-1)` on a (C, H, W) array.  Returned as packed u64
    /// words (bit i of the flattened vector = word i/64, bit i%64).
    pub fn to_flat_words(&self) -> Vec<u64> {
        let mut words = vec![0u64; self.flat_words_len()];
        self.to_flat_words_into(&mut words);
        words
    }

    /// `to_flat_words` into a caller buffer (zeroed first) — the
    /// allocation-free variant the time-batched fc path uses.
    pub fn to_flat_words_into(&self, out: &mut [u64]) {
        let n = self.flat_words_len();
        out.fill(0); // whole buffer: no stale bits beyond this map's words
        let out = &mut out[..n];
        let hw = self.height * self.width;
        if hw == 1 {
            // (C, 1, 1) maps are already C-major packed: a straight copy.
            out.copy_from_slice(&self.data);
            return;
        }
        // Walk set bits only (trailing_zeros skip) — §Perf optimization:
        // firing rates are ~30-50%, so this roughly halves the transpose.
        for (pix, chunk) in self.data.chunks_exact(self.wpp).enumerate() {
            for (wi, &word) in chunk.iter().enumerate() {
                let mut m = word;
                while m != 0 {
                    let b = m.trailing_zeros() as usize;
                    m &= m - 1;
                    let i = (wi * 64 + b) * hw + pix;
                    out[i / 64] |= 1u64 << (i % 64);
                }
            }
        }
    }

    /// Dense 0/1 bytes in (C, H, W) order — for interop and tests.
    pub fn to_dense(&self) -> Vec<u8> {
        let mut out = vec![0u8; self.channels * self.height * self.width];
        for c in 0..self.channels {
            for y in 0..self.height {
                for x in 0..self.width {
                    out[(c * self.height + y) * self.width + x] = self.get(c, y, x) as u8;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    #[test]
    fn set_get() {
        let mut m = SpikeMap::zeros(130, 4, 4);
        m.set(0, 0, 0, true);
        m.set(129, 3, 3, true);
        m.set(64, 1, 2, true);
        assert!(m.get(0, 0, 0) && m.get(129, 3, 3) && m.get(64, 1, 2));
        assert!(!m.get(1, 0, 0));
        assert_eq!(m.total_spikes(), 3);
        assert_eq!(m.pixel_popcount(1, 2), 1);
    }

    #[test]
    fn maxpool_is_or() {
        let mut m = SpikeMap::zeros(2, 4, 4);
        m.set(0, 0, 1, true); // window (0,0)
        m.set(1, 3, 3, true); // window (1,1)
        let p = m.maxpool2();
        assert!(p.get(0, 0, 0));
        assert!(p.get(1, 1, 1));
        assert!(!p.get(1, 0, 0));
        assert_eq!(p.total_spikes(), 2);
    }

    #[test]
    fn flat_order_matches_numpy_chw() {
        let mut m = SpikeMap::zeros(3, 2, 2);
        m.set(1, 0, 1, true); // flat index (1*2+0)*2+1 = 5
        m.set(2, 1, 0, true); // flat index (2*2+1)*2+0 = 10
        let words = m.to_flat_words();
        assert_eq!(words[0], (1 << 5) | (1 << 10));
    }

    #[test]
    fn flat_into_matches_alloc_variant() {
        let mut rng = SplitMix64::new(77);
        for &(c, h, w) in &[(3usize, 2usize, 2usize), (130, 3, 3), (70, 1, 1), (5, 1, 1)] {
            let mut m = SpikeMap::zeros(c, h, w);
            for ch in 0..c {
                for y in 0..h {
                    for x in 0..w {
                        m.set(ch, y, x, rng.next_below(2) == 1);
                    }
                }
            }
            let alloc = m.to_flat_words();
            let mut buf = vec![0xFFFF_FFFF_FFFF_FFFFu64; m.flat_words_len()];
            m.to_flat_words_into(&mut buf);
            assert_eq!(alloc, buf);
        }
    }

    #[test]
    fn reset_reuses_and_zeroes() {
        let mut m = SpikeMap::zeros(64, 4, 4);
        m.set(3, 1, 1, true);
        m.reset(130, 2, 2);
        assert_eq!(m.channels(), 130);
        assert_eq!(m.height(), 2);
        assert_eq!(m.wpp(), 3);
        assert_eq!(m.total_spikes(), 0);
        m.or_bit(129, 1, 1);
        assert!(m.get(129, 1, 1));
        assert_eq!(m.total_spikes(), 1);
    }

    #[test]
    fn maxpool_into_matches_alloc_variant() {
        let mut rng = SplitMix64::new(8);
        let mut m = SpikeMap::zeros(66, 6, 6);
        for c in 0..66 {
            for y in 0..6 {
                for x in 0..6 {
                    m.set(c, y, x, rng.next_below(2) == 1);
                }
            }
        }
        let mut out = SpikeMap::zeros(1, 1, 1);
        out.reset(66, 3, 3);
        m.maxpool2_into(&mut out);
        assert_eq!(out, m.maxpool2());
    }

    #[test]
    fn dense_roundtrip() {
        let mut rng = SplitMix64::new(3);
        let mut m = SpikeMap::zeros(5, 3, 3);
        for c in 0..5 {
            for y in 0..3 {
                for x in 0..3 {
                    m.set(c, y, x, rng.next_below(2) == 1);
                }
            }
        }
        let d = m.to_dense();
        for c in 0..5 {
            for y in 0..3 {
                for x in 0..3 {
                    assert_eq!(d[(c * 3 + y) * 3 + x] == 1, m.get(c, y, x));
                }
            }
        }
    }
}

//! Micro-benchmarks of the L3 hot paths — the targets of the §Perf
//! optimization pass (EXPERIMENTS.md §Perf records before/after).
//!
//! Run: `cargo bench --bench bench_pe_hotpath`

#[path = "harness.rs"]
mod harness;

use harness::{bench, quick_mode, section};
use vsa::arch::pe::{PeArray, PeBlock};
use vsa::snn::conv::{conv_naive, PackedConv, PackedFc};
use vsa::snn::spikemap::SpikeMap;
use vsa::snn::Scratch;
use vsa::testing::Gen;

fn random_spikemap(g: &mut Gen, c: usize, s: usize) -> SpikeMap {
    let mut m = SpikeMap::zeros(c, s, s);
    for ch in 0..c {
        for y in 0..s {
            for x in 0..s {
                m.set(ch, y, x, g.bool());
            }
        }
    }
    m
}

fn main() {
    let mut g = Gen::new(42);
    let quick = quick_mode();

    section("binary conv: packed popcount vs naive (the golden/sim hot path)");
    let c_in = 128;
    let c_out = 128;
    let s = 32;
    let w = g.weights(c_out * c_in * 9);
    let sm = random_spikemap(&mut g, c_in, s);
    let dense = sm.to_dense();
    let packed = PackedConv::pack(c_out, c_in, 3, &w);

    let conv_iters = if quick { 2 } else { 5 };
    let t_packed = bench("packed conv 128x128x32x32", 1, conv_iters, || {
        std::hint::black_box(packed.conv(&sm));
    });
    if !quick {
        let t_naive = bench("naive conv  128x128x32x32", 0, 1, || {
            std::hint::black_box(conv_naive(&dense, c_in, s, s, &w, c_out, 3));
        });
        println!(
            "  popcount speedup: {:.1}x (the AND+sign trick of paper §III-B, 64 channels/word)",
            t_naive.mean_ms / t_packed.mean_ms
        );
    }

    section("temporal batching: conv_t over T steps vs T per-step convs");
    let t_steps = 8;
    let train: Vec<SpikeMap> = (0..t_steps).map(|_| random_spikemap(&mut g, c_in, s)).collect();
    let mut scratch = Scratch::new();
    // warm the arena so the timed region is allocation-free
    packed.conv_t(&train, &mut scratch);
    let t_iters = if quick { 2 } else { 5 };
    let t_batched = bench("conv_t 128x128x32x32 T=8 (tap-major)", 1, t_iters, || {
        packed.conv_t(&train, &mut scratch);
        std::hint::black_box(scratch.psums().len());
    });
    let t_per_step = bench("8 x conv   128x128x32x32 (per step)", 1, t_iters, || {
        for sm in &train {
            std::hint::black_box(packed.conv(sm));
        }
    });
    println!(
        "  temporal amortization: {:.2}x per train (weight vectors loaded once for \
         all T — §III-A/§III-B)",
        t_per_step.mean_ms / t_batched.mean_ms
    );

    section("packed fc matvec (fc layers + readout)");
    let n_in = 4096;
    let n_out = 256;
    let wf = g.weights(n_out * n_in);
    let fc = PackedFc::pack(n_out, n_in, &wf);
    let spikes: Vec<u64> = (0..n_in.div_ceil(64)).map(|_| g.u64()).collect();
    let fc_iters = if quick { 20 } else { 100 };
    let mut fc_psums = vec![0i32; n_out];
    let t_fc = bench("fc 4096->256 matvec", 10, fc_iters, || {
        fc.matvec_into(&spikes, &mut fc_psums);
        std::hint::black_box(fc_psums[0]);
    });
    let flat_t: Vec<u64> = (0..t_steps * n_in.div_ceil(64)).map(|_| g.u64()).collect();
    let mut fc_out = vec![0i32; t_steps * n_out];
    let t_fc_t = bench("fc 4096->256 matvec_t T=8", 10, fc_iters, || {
        fc.matvec_t(&flat_t, t_steps, &mut fc_out);
        std::hint::black_box(fc_out[0]);
    });
    println!(
        "  fc temporal amortization: {:.2}x per train",
        t_fc.mean_ms * t_steps as f64 / t_fc_t.mean_ms
    );

    section("exact-mode PE datapath (gate-level cycle)");
    let array = PeArray::new(8, 3);
    let block = PeBlock::new(array, 3);
    let cols: Vec<Vec<bool>> = (0..3).map(|_| (0..8).map(|_| g.bool()).collect()).collect();
    let wn: Vec<Vec<bool>> = (0..3).map(|_| (0..3).map(|_| g.bool()).collect()).collect();
    bench("PeBlock::cycle (3 arrays x 8x3)", 100, 10_000, || {
        std::hint::black_box(block.cycle(&cols, &wn));
    });

    section("spikemap primitives");
    let m = random_spikemap(&mut g, 256, 16);
    bench("maxpool2 256ch 16x16", 10, 1000, || {
        std::hint::black_box(m.maxpool2());
    });
    bench("to_flat_words 256ch 16x16", 10, 1000, || {
        std::hint::black_box(m.to_flat_words());
    });
}

//! Regenerates paper **Fig. 8** — ANN vs binary-weight SNN accuracy as a
//! function of inference time steps — on the synthetic datasets (DESIGN.md
//! §Substitutions explains the dataset stand-in).
//!
//! The sweep itself is STBP training (python, L2).  Run it once with
//!
//! ```sh
//! cd python && python -m compile.train --fig8 --spec tiny --steps 200 \
//!     --json-out ../artifacts/fig8_tiny.json
//! ```
//!
//! then `cargo bench --bench bench_fig8_accuracy` renders the figure's
//! series (paper trend alongside measured) and additionally evaluates the
//! shipped trained checkpoint through the *rust golden engine* at every
//! reconfigured T — the hardware-side half of the figure.

#[path = "harness.rs"]
mod harness;

use harness::section;
use vsa::config::json::Json;
use vsa::data::synth;
use vsa::snn::Network;
use vsa::util::stats::argmax;

/// Paper Fig. 8 series (read off the plot): accuracy vs T.
const PAPER_MNIST_SNN: &[(usize, f64)] =
    &[(1, 0.9850), (2, 0.9910), (4, 0.9935), (6, 0.9940), (8, 0.9945)];
const PAPER_MNIST_ANN: f64 = 0.9950;
const PAPER_CIFAR_SNN: &[(usize, f64)] =
    &[(1, 0.8250), (2, 0.8650), (4, 0.8900), (6, 0.9000), (8, 0.9028)];
const PAPER_CIFAR_ANN: f64 = 0.9100;

fn render_paper() {
    section("paper Fig. 8 (reference series)");
    println!("  MNIST : ANN {PAPER_MNIST_ANN:.4}");
    for (t, a) in PAPER_MNIST_SNN {
        println!("    SNN T={t}: {a:.4}");
    }
    println!("  CIFAR-10 : ANN {PAPER_CIFAR_ANN:.4}");
    for (t, a) in PAPER_CIFAR_SNN {
        println!("    SNN T={t}: {a:.4}");
    }
}

fn render_measured() {
    let Ok(text) = std::fs::read_to_string("artifacts/fig8_tiny.json") else {
        println!("\n  (no measured sweep found — run the python --fig8 sweep above)");
        return;
    };
    let Ok(v) = Json::parse(&text) else { return };
    section("measured Fig. 8 sweep (synthetic dataset, STBP-trained)");
    let ann = v.get("ann_acc").and_then(Json::as_f64).unwrap_or(f64::NAN);
    println!("  ANN (full-precision twin): {ann:.3}");
    if let Some(series) = v.get("series").and_then(Json::as_arr) {
        let mut prev = 0.0;
        let mut monotonic = true;
        for p in series {
            let t = p.get("T").and_then(Json::as_i64).unwrap_or(-1);
            let acc = p.get("snn_acc").and_then(Json::as_f64).unwrap_or(f64::NAN);
            let dep = p.get("snn_deployed_acc").and_then(Json::as_f64).unwrap_or(f64::NAN);
            println!("  SNN T={t}: train-view {acc:.3}  deployed(int) {dep:.3}");
            if acc + 0.05 < prev {
                monotonic = false;
            }
            prev = prev.max(acc);
        }
        println!(
            "  trend check: accuracy {} with T, approaching the ANN — the figure's shape",
            if monotonic { "rises" } else { "does NOT rise (investigate)" }
        );
    }
}

/// Hardware half: the trained checkpoint reconfigured to different T on
/// the rust golden engine (deployed integer semantics).
fn rust_side_reconfig() {
    let Ok(net) = Network::from_vsaw_file("artifacts/tiny_trained.vsaw") else {
        println!("\n  (no trained checkpoint — run `make train`)");
        return;
    };
    section("deployed checkpoint reconfigured across T (rust golden engine)");
    let samples = synth::tiny_like(1007, 10_000_000, 200);
    println!("  {:>3} {:>10}", "T", "accuracy");
    for t in [1, 2, 4, 6, 8] {
        let mut model = net.model.clone();
        model.num_steps = t;
        let reconf = Network::new(model);
        let correct = samples
            .iter()
            .filter(|s| argmax(&reconf.infer_u8(&s.image)) == s.label)
            .count();
        println!("  {t:>3} {:>10.3}", correct as f64 / samples.len() as f64);
    }
    println!(
        "  (trained at T=4; nearby T still classifies — the \
         reconfigurable-time-steps claim)"
    );
}

fn main() {
    render_paper();
    render_measured();
    rust_side_reconfig();
}

//! Minimal bench harness shared by all `harness = false` bench targets
//! (criterion is unavailable in the offline crate set).
//!
//! Provides wall-clock timing with warmup + repetition statistics, and a
//! uniform "paper vs measured" table printer so every bench emits the
//! rows of the table/figure it regenerates.

#![allow(dead_code)]

use std::time::Instant;

/// Timing summary of a benched closure.
#[derive(Debug, Clone)]
pub struct Timing {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub min_ms: f64,
    pub max_ms: f64,
}

/// Run `f` `iters` times (after `warmup` unrecorded runs) and report.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = samples.iter().cloned().fold(0.0f64, f64::max);
    let t = Timing {
        name: name.to_string(),
        iters,
        mean_ms: mean,
        min_ms: min,
        max_ms: max,
    };
    println!(
        "  {:<40} {:>10.3} ms/iter (min {:.3}, max {:.3}, n={})",
        t.name, t.mean_ms, t.min_ms, t.max_ms, t.iters
    );
    t
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Print one "paper vs measured" comparison row.
pub fn compare(metric: &str, paper: &str, measured: &str, note: &str) {
    println!("  {metric:<34} paper: {paper:<18} measured: {measured:<18} {note}");
}

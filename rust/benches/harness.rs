//! Minimal bench harness shared by all `harness = false` bench targets
//! (criterion is unavailable in the offline crate set).
//!
//! Provides wall-clock timing with warmup + repetition statistics, and a
//! uniform "paper vs measured" table printer so every bench emits the
//! rows of the table/figure it regenerates.

#![allow(dead_code)]

use std::time::Instant;

/// Timing summary of a benched closure.
#[derive(Debug, Clone)]
pub struct Timing {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub min_ms: f64,
    pub max_ms: f64,
}

/// Run `f` `iters` times (after `warmup` unrecorded runs) and report.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = samples.iter().cloned().fold(0.0f64, f64::max);
    let t = Timing {
        name: name.to_string(),
        iters,
        mean_ms: mean,
        min_ms: min,
        max_ms: max,
    };
    println!(
        "  {:<40} {:>10.3} ms/iter (min {:.3}, max {:.3}, n={})",
        t.name, t.mean_ms, t.min_ms, t.max_ms, t.iters
    );
    t
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// CI smoke mode: `cargo bench --bench <b> -- --quick` (or
/// `VSA_BENCH_QUICK=1`) shrinks iteration counts and skips the slow,
/// artifact-dependent sections.  `VSA_BENCH_QUICK=0`/empty/`false`
/// count as off.
pub fn quick_mode() -> bool {
    if std::env::args().any(|a| a == "--quick") {
        return true;
    }
    match std::env::var("VSA_BENCH_QUICK") {
        Ok(v) => !matches!(v.as_str(), "" | "0" | "false" | "no"),
        Err(_) => false,
    }
}

/// JSON-escape a string (hand-rolled: serde is unavailable offline).
/// Escapes per RFC 8259; non-ASCII passes through as UTF-8.
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Machine-readable bench report: collects rows while a bench runs and
/// writes one JSON file (e.g. `BENCH_PR1.json`) so the perf trajectory is
/// tracked across PRs.
#[derive(Default)]
pub struct JsonReport {
    rows: Vec<String>,
}

impl JsonReport {
    pub fn new() -> Self {
        Self::default()
    }

    /// One engine/model throughput measurement.
    pub fn throughput(&mut self, engine: &str, model: &str, images_per_sec: f64, note: &str) {
        self.rows.push(format!(
            "{{\"kind\": \"throughput\", \"engine\": \"{}\", \"model\": \"{}\", \
             \"images_per_sec\": {:.3}, \"note\": \"{}\"}}",
            json_escape(engine),
            json_escape(model),
            images_per_sec,
            json_escape(note)
        ));
    }

    /// One derived ratio (e.g. speedup vs a baseline measured in the same
    /// run).
    pub fn ratio(&mut self, name: &str, value: f64, note: &str) {
        self.rows.push(format!(
            "{{\"kind\": \"ratio\", \"name\": \"{}\", \"value\": {:.3}, \"note\": \"{}\"}}",
            json_escape(name),
            value,
            json_escape(note)
        ));
    }

    /// One serving load-test measurement at a given injected fault rate
    /// (PR6: `bench_serve` / `vsa serve-bench`; PR7 adds the sketch-
    /// derived p999/max tail columns).
    #[allow(clippy::too_many_arguments)]
    pub fn serve(
        &mut self,
        model: &str,
        fault_rate: f64,
        rps: f64,
        p50_ms: f64,
        p99_ms: f64,
        p999_ms: f64,
        max_ms: f64,
        shed_rate: f64,
        retry_rate: f64,
        fail_rate: f64,
    ) {
        self.rows.push(format!(
            "{{\"kind\": \"serve\", \"model\": \"{}\", \"fault_rate\": {:.4}, \
             \"rps\": {:.3}, \"p50_ms\": {:.4}, \"p99_ms\": {:.4}, \"p999_ms\": {:.4}, \
             \"max_ms\": {:.4}, \"shed_rate\": {:.4}, \"retry_rate\": {:.4}, \
             \"fail_rate\": {:.4}}}",
            json_escape(model),
            fault_rate,
            rps,
            p50_ms,
            p99_ms,
            p999_ms,
            max_ms,
            shed_rate,
            retry_rate,
            fail_rate
        ));
    }

    /// One per-model row of the multi-model mixed-traffic load test
    /// (PR9: `bench_serve` two-model section): per-model latency
    /// percentiles from the coordinator's exported sketches plus the
    /// pool-wide packed-model cache hit rate for the whole run.
    pub fn serve_model(
        &mut self,
        model: &str,
        pool: &str,
        completed: u64,
        p50_ms: f64,
        p99_ms: f64,
        cache_hit_rate: f64,
    ) {
        self.rows.push(format!(
            "{{\"kind\": \"serve_model\", \"model\": \"{}\", \"pool\": \"{}\", \
             \"completed\": {}, \"p50_ms\": {:.4}, \"p99_ms\": {:.4}, \
             \"cache_hit_rate\": {:.4}}}",
            json_escape(model),
            json_escape(pool),
            completed,
            p50_ms,
            p99_ms,
            cache_hit_rate
        ));
    }

    /// Write the report; the schema key lets downstream tooling evolve.
    pub fn write(&self, path: &str) {
        let mut body = String::from("{\n  \"schema\": \"vsa-bench-v1\",\n  \"results\": [\n");
        for (i, row) in self.rows.iter().enumerate() {
            body.push_str("    ");
            body.push_str(row);
            if i + 1 < self.rows.len() {
                body.push(',');
            }
            body.push('\n');
        }
        body.push_str("  ]\n}\n");
        match std::fs::write(path, &body) {
            Ok(()) => println!("\nwrote {} ({} rows)", path, self.rows.len()),
            Err(e) => eprintln!("\nfailed to write {path}: {e}"),
        }
    }
}

/// Print one "paper vs measured" comparison row.
pub fn compare(metric: &str, paper: &str, measured: &str, note: &str) {
    println!("  {metric:<34} paper: {paper:<18} measured: {measured:<18} {note}");
}

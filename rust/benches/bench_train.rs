//! Training-loop throughput: STBP steps/sec across the PR trajectory —
//! the frozen PR3 scalar baseline (`baselines::stbp_scalar`) vs the
//! PR4 fixed hot path at 1 thread vs the PR4 batch-parallel path at
//! [`PAR_THREADS`] threads — plus the export + golden-eval path of a
//! finished artifact.  Results land in `BENCH_PR4.json` (uploaded as a
//! CI artifact); the acceptance bar is >= 3x parallel-vs-scalar on the
//! mnist model at 4 threads on a quiet 4-core machine.
//!
//! Run: `cargo bench --bench bench_train` (add `-- --quick` for the CI
//! smoke subset — micro plus a small-batch mnist row).

#[path = "harness.rs"]
mod harness;

use harness::{bench, quick_mode, section, JsonReport};
use vsa::baselines::stbp_scalar;
use vsa::config::models;
use vsa::data::synth;
use vsa::train::{self, optim, tensor, Net, SpikeMode};

/// Thread count of the parallel rows (the acceptance configuration).
const PAR_THREADS: usize = 4;

fn images_for(spec: &models::ModelSpec, batch: usize) -> (Vec<f32>, Vec<usize>) {
    let samples = synth::batch(7, 0, batch, spec.in_channels, spec.in_size);
    let plane = spec.in_channels * spec.in_size * spec.in_size;
    let mut images = vec![0.0f32; batch * plane];
    let mut labels = vec![0usize; batch];
    for (r, s) in samples.iter().enumerate() {
        for (dst, &px) in images[r * plane..(r + 1) * plane].iter_mut().zip(&s.image) {
            *dst = px as f32 / 255.0;
        }
        labels[r] = s.label;
    }
    (images, labels)
}

/// One full PR3-scalar training step (frozen baseline).
fn step_scalar(
    net: &mut Net,
    opt: &mut optim::Sgd,
    images: &[f32],
    labels: &[usize],
    batch: usize,
    dlogits: &mut [f32],
) {
    let classes = net.classes();
    let t = net.spec.num_steps as f32;
    let fwd = stbp_scalar::forward(net, images, batch);
    tensor::softmax_ce(&fwd.logits, batch, classes, labels, t, dlogits);
    let grads = stbp_scalar::backward(net, &fwd, images, dlogits);
    opt.step(net, &grads, 0.05);
    stbp_scalar::apply_bn_ema(net, &fwd);
}

/// One full PR4 training step at `threads`.
fn step_current(
    net: &mut Net,
    opt: &mut optim::Sgd,
    images: &[f32],
    labels: &[usize],
    batch: usize,
    dlogits: &mut [f32],
    threads: usize,
) {
    let classes = net.classes();
    let t = net.spec.num_steps as f32;
    let fwd = net.forward(images, batch, SpikeMode::Hard, true, threads);
    tensor::softmax_ce(&fwd.logits, batch, classes, labels, t, dlogits);
    let grads = net.backward(&fwd, images, dlogits, true, threads);
    opt.step(net, &grads, 0.05);
    net.apply_bn_ema(&fwd);
}

/// Bench the three trajectory points on one model; returns steps/sec as
/// (scalar_pr3, fixed_1thread, parallel).
fn bench_model(
    name: &str,
    spec: &models::ModelSpec,
    batch: usize,
    iters: usize,
    report: &mut JsonReport,
) -> (f64, f64, f64) {
    let (images, labels) = images_for(spec, batch);
    // threads == 0 selects the frozen PR3 scalar baseline.
    let mut run_variant = |label: &str, threads: usize| -> f64 {
        let mut net = Net::init(spec, 7);
        let mut opt = optim::Sgd::new(&net, 0.9);
        let mut dlogits = vec![0.0f32; batch * net.classes()];
        let t = bench(&format!("{name} {label} (batch {batch})"), 1, iters, || {
            if threads == 0 {
                step_scalar(&mut net, &mut opt, &images, &labels, batch, &mut dlogits);
            } else {
                step_current(&mut net, &mut opt, &images, &labels, batch, &mut dlogits, threads);
            }
        });
        report.throughput(
            &format!("stbp-{label}"),
            name,
            batch as f64 / (t.mean_ms / 1e3),
            "trainer samples/sec (fwd+bwd+step)",
        );
        1e3 / t.mean_ms
    };
    let scalar = run_variant("pr3-scalar", 0);
    let fixed = run_variant("pr4-fixed t1", 1);
    let par = run_variant("pr4-parallel t4", PAR_THREADS);
    println!(
        "    -> steps/sec: scalar {scalar:.2}  fixed {fixed:.2}  parallel {par:.2}  \
         (fixed/scalar {:.2}x, parallel/scalar {:.2}x)",
        fixed / scalar,
        par / scalar
    );
    report.ratio(
        &format!("train_fixed_vs_pr3_scalar_{name}"),
        fixed / scalar,
        "steps/sec, 1 thread vs frozen PR3 scalar",
    );
    report.ratio(
        &format!("train_parallel_vs_pr3_scalar_{name}"),
        par / scalar,
        &format!("steps/sec, {PAR_THREADS} threads vs frozen PR3 scalar (bar: >= 3x on mnist)"),
    );
    (scalar, fixed, par)
}

fn bench_export_eval(spec: &models::ModelSpec, iters: usize) {
    let net = Net::init(spec, 7);
    let samples = train::holdout_synth(spec, 7, 64);
    bench(&format!("{} export + golden eval (64 imgs)", spec.name), 1, iters, || {
        let model = train::deploy(&net);
        let _ = train::eval_golden(&model, &samples);
    });
}

fn main() {
    let mut report = JsonReport::new();
    section("STBP training hot path (PR3 scalar -> PR4 fixed -> PR4 parallel)");
    let micro_iters = if quick_mode() { 3 } else { 10 };
    bench_model("micro T=4", &models::micro(4), 16, micro_iters, &mut report);
    if quick_mode() {
        // CI smoke: a small-batch mnist row keeps the acceptance ratio
        // observable without laptop-scale runtime.
        bench_model("mnist T=4", &models::mnist(4), 8, 2, &mut report);
    } else {
        bench_model("tiny  T=4", &models::tiny(4), 32, 3, &mut report);
        bench_model("mnist T=4", &models::mnist(4), 32, 2, &mut report);
    }
    section("export + deployed eval");
    bench_export_eval(&models::micro(4), if quick_mode() { 2 } else { 5 });
    report.write("BENCH_PR4.json");
}

//! Training-loop throughput: STBP steps/sec for the micro and tiny
//! models, plus the export + golden-eval path of a finished artifact.
//!
//! Run: `cargo bench --bench bench_train` (add `-- --quick` for the CI
//! smoke subset — micro only).

#[path = "harness.rs"]
mod harness;

use harness::{bench, quick_mode, section};
use vsa::config::models;
use vsa::data::synth;
use vsa::train::{self, optim, tensor, Net, SpikeMode};

fn images_for(spec: &models::ModelSpec, batch: usize) -> (Vec<f32>, Vec<usize>) {
    let samples = synth::batch(7, 0, batch, spec.in_channels, spec.in_size);
    let plane = spec.in_channels * spec.in_size * spec.in_size;
    let mut images = vec![0.0f32; batch * plane];
    let mut labels = vec![0usize; batch];
    for (r, s) in samples.iter().enumerate() {
        for (dst, &px) in images[r * plane..(r + 1) * plane].iter_mut().zip(&s.image) {
            *dst = px as f32 / 255.0;
        }
        labels[r] = s.label;
    }
    (images, labels)
}

fn bench_model(name: &str, spec: &models::ModelSpec, batch: usize, iters: usize) {
    let mut net = Net::init(spec, 7);
    let mut opt = optim::Sgd::new(&net, 0.9);
    let (images, labels) = images_for(spec, batch);
    let classes = net.classes();
    let mut dlogits = vec![0.0f32; batch * classes];
    let t = bench(&format!("{name} fwd+bwd+step (batch {batch})"), 1, iters, || {
        let fwd = net.forward(&images, batch, SpikeMode::Hard, true);
        tensor::softmax_ce(
            &fwd.logits,
            batch,
            classes,
            &labels,
            spec.num_steps as f32,
            &mut dlogits,
        );
        let grads = net.backward(&fwd, &images, &dlogits, true);
        opt.step(&mut net, &grads, 0.05);
        net.apply_bn_ema(&fwd);
    });
    println!(
        "    -> {:.1} samples/sec through the trainer",
        batch as f64 / (t.mean_ms / 1e3)
    );

    let samples = train::holdout_synth(spec, 7, 64);
    bench(&format!("{name} export + golden eval (64 imgs)"), 1, iters.min(5), || {
        let model = train::deploy(&net);
        let _ = train::eval_golden(&model, &samples);
    });
}

fn main() {
    section("STBP training hot path");
    bench_model("micro T=4", &models::micro(4), 16, if quick_mode() { 3 } else { 10 });
    if !quick_mode() {
        bench_model("tiny  T=4", &models::tiny(4), 32, 3);
    }
}

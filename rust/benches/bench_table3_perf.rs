//! Regenerates paper **Table III** — performance summary and comparison
//! with SpinalFlow [7] and BW-SNN [4] — from the cycle-accurate simulator
//! + area/power model, on the CIFAR-10 workload.
//!
//! Run: `cargo bench --bench bench_table3_perf`

#[path = "harness.rs"]
mod harness;

use harness::{bench, compare, section};
use vsa::arch::{Chip, SimMode};
use vsa::baselines::published;
use vsa::config::HwConfig;
use vsa::data::synth;
use vsa::energy::{area, power, report};
use vsa::snn::Network;

fn main() {
    let net = match Network::from_vsaw_file("artifacts/cifar10_t8.vsaw") {
        Ok(n) => n,
        Err(e) => {
            eprintln!("run `make artifacts` first: {e}");
            std::process::exit(1);
        }
    };
    let hw = HwConfig::default();
    let img = &synth::cifar_like(7, 0, 1)[0].image;

    section("simulation wall time (fast mode, full CIFAR-10 net, T=8)");
    let chip = Chip::new(hw.clone(), SimMode::Fast);
    let mut last = None;
    bench("cifar10 full-net cycle-accurate sim", 1, 3, || {
        last = Some(chip.run(&net.model, img));
    });
    let r = last.unwrap();

    section("Table III — this work vs published designs");
    let rows = vec![
        report::this_work(&hw, &r),
        published::spinalflow_row(),
        published::bwsnn_row(),
    ];
    print!("{}", report::render_table3(&rows));

    section("paper vs measured (this work column)");
    let kge = area::logic_area(&hw).total();
    let mw = power::core_power_mw(&hw, &r);
    let eff = power::power_efficiency_tops_w(&hw, mw);
    compare("PE number", "2304", &format!("{}", hw.total_pes()), "(exact by construction)");
    compare("Peak throughput (GOPS)", "2304", &format!("{:.0}", hw.peak_gops()), "");
    compare("SRAM (KB)", "230.3125", &format!("{:.4}", hw.total_sram_kb()), "");
    compare("Area (KGE)", "114.98", &format!("{kge:.2}"), "(analytical model, calibrated)");
    compare(
        "Area eff. (GOPS/KGE)",
        "20.038",
        &format!("{:.3}", hw.peak_gops() / kge),
        "",
    );
    compare("Core power (mW)", "88.968", &format!("{mw:.3}"), "(event-energy model)");
    compare("Power eff. (TOPS/W)", "25.9", &format!("{eff:.1}"), "");
    compare(
        "Achieved GOPS on CIFAR-10",
        "n/a (paper reports peak)",
        &format!("{:.0} ({:.0}% util)", r.gops, r.utilization * 100.0),
        "",
    );

    section("comparison shape (who wins, by what factor)");
    let sf = published::spinalflow_row();
    let bw = published::bwsnn_row();
    println!(
        "  peak GOPS:   this {:.0}  vs SpinalFlow {:.1} ({:.0}x)  vs BW-SNN {:.1} ({:.0}x)",
        hw.peak_gops(),
        sf.peak_gops,
        hw.peak_gops() / sf.peak_gops,
        bw.peak_gops,
        hw.peak_gops() / bw.peak_gops
    );
    println!(
        "  power eff.:  this {:.1} TOPS/W vs SpinalFlow {:.3} ({:.0}x better); \
         BW-SNN {:.1} (fixed-function, {:.1}x better than this)",
        eff,
        sf.power_eff_tops_w.unwrap(),
        eff / sf.power_eff_tops_w.unwrap(),
        bw.power_eff_tops_w.unwrap(),
        bw.power_eff_tops_w.unwrap() / eff
    );
    println!(
        "  area eff.:   this {:.2} GOPS/KGE vs BW-SNN {:.3} normalized ({:.0}x better)",
        hw.peak_gops() / kge,
        bw.area_eff_norm.unwrap(),
        (hw.peak_gops() / kge) / bw.area_eff_norm.unwrap()
    );
    println!(
        "  (matches the paper's ordering: VSA wins throughput + area eff. and beats \
         the reconfigurable baseline on power eff.; only the fixed-function ASIC is \
         more power-efficient.)"
    );

    section("IF-BN ablation (paper §II-B: BN folded into the IF neuron)");
    let (explicit, folded) = area::bn_overhead(&hw);
    println!(
        "  explicit BatchNorm unit: {explicit:.2} KGE ({:.1}% of the chip's logic)",
        explicit / kge * 100.0
    );
    println!("  folded IF-BN (Eq. 4):    {folded:.2} KGE ({:.0}x smaller)", explicit / folded);
    println!(
        "  (the multiplier/divider of per-step BN is replaced by one pre-computed \
         bias subtract + the comparator the IF neuron already has)"
    );
}

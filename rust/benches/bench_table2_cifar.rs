//! Regenerates paper **Table II** — CIFAR-10 accuracy comparison against
//! prior SNNs (precision / time steps / accuracy).
//!
//! The literature rows are published constants; "Ours" combines the
//! paper's reported figure with the measured synthetic-dataset result
//! (DESIGN.md §Substitutions: no real CIFAR-10 in this environment, so
//! absolute accuracy is reported side-by-side, and the *structural* claims
//! — binary weights, T=8, orders-of-magnitude fewer time steps — are
//! checked directly against the deployed model.
//!
//! Run: `cargo bench --bench bench_table2_cifar`

#[path = "harness.rs"]
mod harness;

use harness::section;
use vsa::config::json::Json;
use vsa::snn::params::Layer;
use vsa::snn::Network;

struct Row {
    model: &'static str,
    precision: &'static str,
    time_steps: usize,
    accuracy: f64,
}

const LITERATURE: &[Row] = &[
    Row {
        model: "Sengupta et al. [14]",
        precision: "full-precision",
        time_steps: 2500,
        accuracy: 0.9155,
    },
    Row { model: "Wu et al. [8]", precision: "full-precision", time_steps: 12, accuracy: 0.9053 },
    Row {
        model: "Rathi et al. [15]",
        precision: "full-precision",
        time_steps: 200,
        accuracy: 0.9202,
    },
    Row { model: "RMP-SNN [16]", precision: "full-precision", time_steps: 256, accuracy: 0.9304 },
    Row { model: "Wang et al. [17]", precision: "binary", time_steps: 100, accuracy: 0.9019 },
    Row { model: "Ours (paper)", precision: "binary", time_steps: 8, accuracy: 0.9028 },
];

fn main() {
    section("Table II — CIFAR-10 accuracy comparison");
    println!(
        "  {:<24} {:<16} {:>10} {:>10}",
        "Model", "Precision", "Time steps", "Accuracy"
    );
    for r in LITERATURE {
        println!(
            "  {:<24} {:<16} {:>10} {:>9.2}%",
            r.model,
            r.precision,
            r.time_steps,
            r.accuracy * 100.0
        );
    }

    // Measured row (synthetic dataset) if the fig8 sweep ran.
    if let Ok(text) = std::fs::read_to_string("artifacts/fig8_tiny.json") {
        if let Ok(v) = Json::parse(&text) {
            if let Some(series) = v.get("series").and_then(Json::as_arr) {
                if let Some(last) = series.last() {
                    let t = last.get("T").and_then(Json::as_i64).unwrap_or(-1);
                    let acc =
                        last.get("snn_deployed_acc").and_then(Json::as_f64).unwrap_or(f64::NAN);
                    println!(
                        "  {:<24} {:<16} {:>10} {:>9.2}%  (synthetic stand-in dataset)",
                        "Ours (measured)",
                        "binary",
                        t,
                        acc * 100.0
                    );
                }
            }
        }
    }

    section("structural claims checked against the deployed model");
    match Network::from_vsaw_file("artifacts/cifar10_t8.vsaw") {
        Ok(net) => {
            println!("  time steps T = {} (paper: 8)", net.model.num_steps);
            assert_eq!(net.model.num_steps, 8);
            let binary = net.model.layers.iter().all(|l| match l {
                Layer::Conv { w, .. } | Layer::Fc { w, .. } | Layer::Readout { w, .. } => {
                    w.iter().all(|&x| x == 1 || x == -1)
                }
                Layer::MaxPool => true,
            });
            println!("  all weights binary (+-1): {binary}");
            assert!(binary);
            let best_prior = LITERATURE
                .iter()
                .filter(|r| !r.model.starts_with("Ours"))
                .map(|r| r.time_steps)
                .min()
                .unwrap();
            let best_binary_prior = LITERATURE
                .iter()
                .filter(|r| r.precision == "binary" && !r.model.starts_with("Ours"))
                .map(|r| r.time_steps)
                .min()
                .unwrap();
            println!(
                "  time-step reduction: {:.1}x vs best prior ({best_prior} -> 8), \
                 {:.1}x vs best binary prior ({best_binary_prior} -> 8)",
                best_prior as f64 / 8.0,
                best_binary_prior as f64 / 8.0
            );
        }
        Err(e) => eprintln!("  run `make artifacts` first: {e}"),
    }
    println!(
        "\n  shape check: ours is the ONLY binary-weight entry at single-digit time \
         steps, within ~1pt of full-precision accuracy — the paper's Table II claim."
    );
}

//! Regenerates the paper's **§IV-B DRAM claim**: layer fusion reduces
//! off-chip traffic for one CIFAR-10 inference from 1450.172 KB to
//! 938.172 KB (-35.3%).  Also sweeps the weight-SRAM budget (the fusion
//! enabler) and the tick-batching ablation.
//!
//! Run: `cargo bench --bench bench_dram_fusion`

#[path = "harness.rs"]
mod harness;

use harness::{compare, section};
use vsa::arch::dram::Traffic;
use vsa::arch::fusion::plan_fusion;
use vsa::arch::schedule::plan_model;
use vsa::arch::{Chip, SimMode};
use vsa::config::HwConfig;
use vsa::data::synth;
use vsa::snn::Network;

fn main() {
    let net = match Network::from_vsaw_file("artifacts/cifar10_t8.vsaw") {
        Ok(n) => n,
        Err(e) => {
            eprintln!("run `make artifacts` first: {e}");
            std::process::exit(1);
        }
    };
    let img = &synth::cifar_like(7, 0, 1)[0].image;

    let on = Chip::new(HwConfig::default(), SimMode::Fast).run(&net.model, img);
    let off = Chip::new(
        HwConfig { layer_fusion: false, ..HwConfig::default() },
        SimMode::Fast,
    )
    .run(&net.model, img);
    let on_kb = on.dram.total() as f64 / 1024.0;
    let off_kb = off.dram.total() as f64 / 1024.0;

    section("layer fusion, CIFAR-10, T=8 (paper §IV-B)");
    compare("DRAM without fusion (KB)", "1450.172", &format!("{off_kb:.3}"), "");
    compare("DRAM with fusion (KB)", "938.172", &format!("{on_kb:.3}"), "");
    compare(
        "reduction",
        "35.3%",
        &format!("{:.1}%", (1.0 - on_kb / off_kb) * 100.0),
        "(shape: fusion saves the intermediate spike round-trips)",
    );

    section("traffic breakdown (with fusion)");
    println!("{}", on.dram.report());
    println!(
        "  spikes saved by fusion: {:.1} KB",
        (off.dram.category(Traffic::SpikesIn) + off.dram.category(Traffic::SpikesOut)
            - on.dram.category(Traffic::SpikesIn)
            - on.dram.category(Traffic::SpikesOut)) as f64
            / 1024.0
    );

    section("fusion coverage vs weight-SRAM budget (ablation)");
    println!(
        "{:>14} {:>12} {:>14} {:>9}",
        "wSRAM (KB)", "fused pairs", "DRAM (KB)", "saved"
    );
    for budget in [24.0, 48.0, 96.0, 144.0, 192.0, 256.0] {
        let hw = HwConfig { weight_sram_kb: budget, ..HwConfig::default() };
        let plans = plan_model(&net.model);
        let pairs = plan_fusion(&plans, &hw).iter().filter(|g| g.len == 2).count();
        let r = Chip::new(hw, SimMode::Fast).run(&net.model, img);
        let kb = r.dram.total() as f64 / 1024.0;
        println!(
            "{budget:>14.0} {pairs:>12} {kb:>14.3} {:>8.1}%",
            (1.0 - kb / off_kb) * 100.0
        );
    }
    println!(
        "  (larger weight SRAM -> more pairs fuse -> more traffic saved; the paper \
         sizes the weight SRAM 'large enough for two layers')"
    );

    section("tick-batching ablation (membrane + weight re-fetch without it)");
    let plans = plan_model(&net.model);
    let mut with_tb = vsa::arch::dram::Dram::default();
    let mut without_tb = vsa::arch::dram::Dram::default();
    for p in &plans {
        vsa::arch::schedule::layer_dram(p, 8, false, false, true, &mut with_tb);
        vsa::arch::schedule::layer_dram(p, 8, false, false, false, &mut without_tb);
    }
    compare(
        "DRAM with tick batching (KB)",
        "(paper's design choice)",
        &format!("{:.1}", with_tb.total() as f64 / 1024.0),
        "",
    );
    compare(
        "DRAM without (naive per-step)",
        "(motivation, §I)",
        &format!(
            "{:.1}  ({:.1}x)",
            without_tb.total() as f64 / 1024.0,
            without_tb.total() as f64 / with_tb.total() as f64
        ),
        "",
    );
}
